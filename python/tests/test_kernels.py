"""L1 correctness: every Pallas kernel (interpret) vs the pure-jnp oracle
vs hand-rolled numpy. Hypothesis sweeps shapes and value ranges."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import hist as hist_kernel
from compile.kernels import ref, splitscore, ssescan

TILE_M = hist_kernel.TILE_M


def numpy_hist(bins, labels, mask, n_bins, n_classes):
    out = np.zeros((n_bins, n_classes), np.float64)
    for b, l, m in zip(bins, labels, mask):
        out[b, l] += m
    return out


def make_inputs(seed, m, n_bins, n_classes, pad_frac=0.2):
    rng = np.random.default_rng(seed)
    bins = rng.integers(0, n_bins, m).astype(np.int32)
    labels = rng.integers(0, n_classes, m).astype(np.int32)
    n_valid = int(m * (1 - pad_frac))
    mask = np.zeros(m, np.float32)
    mask[:n_valid] = 1.0
    return jnp.array(bins), jnp.array(labels), jnp.array(mask)


class TestHist:
    @pytest.mark.parametrize("n_bins,n_classes", [(4, 2), (16, 8), (256, 32)])
    def test_matches_numpy(self, n_bins, n_classes):
        bins, labels, mask = make_inputs(1, TILE_M * 2, n_bins, n_classes)
        got = hist_kernel.hist(bins, labels, mask, n_bins=n_bins, n_classes=n_classes)
        want = numpy_hist(
            np.asarray(bins), np.asarray(labels), np.asarray(mask), n_bins, n_classes
        )
        np.testing.assert_allclose(np.asarray(got), want, rtol=0, atol=0)

    def test_matches_ref(self):
        bins, labels, mask = make_inputs(2, TILE_M, 32, 8)
        got = hist_kernel.hist(bins, labels, mask, n_bins=32, n_classes=8)
        want = ref.hist_ref(bins, labels, mask, 32, 8)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want))

    def test_mask_zero_rows_do_not_count(self):
        bins, labels, mask = make_inputs(3, TILE_M, 8, 3, pad_frac=0.5)
        got = hist_kernel.hist(bins, labels, mask, n_bins=8, n_classes=3)
        assert float(np.asarray(got).sum()) == float(np.asarray(mask).sum())

    def test_multi_tile_accumulation(self):
        # Grid > 1: the constant-index output block must accumulate.
        bins, labels, mask = make_inputs(4, TILE_M * 4, 8, 4, pad_frac=0.0)
        got = hist_kernel.hist(bins, labels, mask, n_bins=8, n_classes=4)
        assert float(np.asarray(got).sum()) == TILE_M * 4

    def test_rejects_unaligned_m(self):
        bins, labels, mask = make_inputs(5, 100, 4, 2)
        with pytest.raises(AssertionError):
            hist_kernel.hist(bins, labels, mask, n_bins=4, n_classes=2)

    @settings(max_examples=20, deadline=None)
    @given(
        seed=st.integers(0, 10_000),
        n_bins=st.integers(2, 64),
        n_classes=st.integers(2, 16),
        tiles=st.integers(1, 3),
    )
    def test_hypothesis_sweep(self, seed, n_bins, n_classes, tiles):
        bins, labels, mask = make_inputs(seed, TILE_M * tiles, n_bins, n_classes)
        got = hist_kernel.hist(bins, labels, mask, n_bins=n_bins, n_classes=n_classes)
        want = numpy_hist(
            np.asarray(bins), np.asarray(labels), np.asarray(mask), n_bins, n_classes
        )
        np.testing.assert_allclose(np.asarray(got), want)


def numpy_split_scores(counts, rest):
    """Independent numpy re-derivation of Algorithm 3 over all candidates."""
    b, c = counts.shape
    prefix = np.cumsum(counts, axis=0)
    tot = prefix[-1]
    le = np.full(b, ref.NEG_SENTINEL)
    gt = np.full(b, ref.NEG_SENTINEL)

    def ig(pos, neg):
        tp, tn = pos.sum(), neg.sum()
        if tp == 0 or tn == 0:
            return ref.NEG_SENTINEL
        t = tp + tn
        r = 0.0
        for x in pos:
            if x > 0:
                r += x / t * np.log(x / tp)
        for x in neg:
            if x > 0:
                r += x / t * np.log(x / tn)
        return r

    for i in range(b):
        le[i] = ig(prefix[i], tot - prefix[i] + rest)
        gt[i] = ig(tot - prefix[i], prefix[i] + rest)
    return le, gt


class TestSplitScores:
    def test_matches_numpy(self):
        rng = np.random.default_rng(7)
        counts = jnp.array(rng.integers(0, 50, (16, 4)).astype(np.float32))
        rest = jnp.array(rng.integers(0, 20, 4).astype(np.float32))
        le, gt = splitscore.split_scores(counts, rest)
        le_np, gt_np = numpy_split_scores(np.asarray(counts), np.asarray(rest))
        np.testing.assert_allclose(np.asarray(le), le_np, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(gt), gt_np, rtol=1e-5)

    def test_matches_ref(self):
        rng = np.random.default_rng(8)
        counts = jnp.array(rng.integers(0, 9, (256, 32)).astype(np.float32))
        rest = jnp.array(rng.integers(0, 5, 32).astype(np.float32))
        le, gt = splitscore.split_scores(counts, rest)
        le_r, gt_r = ref.split_scores_ref(counts, rest)
        np.testing.assert_allclose(np.asarray(le), np.asarray(le_r), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(gt), np.asarray(gt_r), rtol=1e-6)

    def test_paper_worked_example(self):
        """Paper Tables 1–4 on the binned domain: numeric values 1..5 as
        bins 0..4 with classes a,b,c and the categorical counts as rest;
        the best candidate must be `≤ 2` (bin 1) at ≈ −0.87."""
        # cnt[bin, class]: a: 3,4,4,5 → bins 2,3,3,4; b: 1,1,2,2,3; c: 3,4,4,5,5
        counts = np.zeros((5, 3), np.float32)
        for v in [3, 4, 4, 5]:
            counts[v - 1, 0] += 1
        for v in [1, 1, 2, 2, 3]:
            counts[v - 1, 1] += 1
        for v in [3, 4, 4, 5, 5]:
            counts[v - 1, 2] += 1
        rest = jnp.array([3.0, 3.0, 2.0], jnp.float32)  # x,x,y / y,y,z / z,z
        le, gt = splitscore.split_scores(jnp.array(counts), rest)
        le = np.asarray(le)
        best_bin = int(le.argmax())
        assert best_bin == 1  # value 2
        assert abs(le[best_bin] - (-0.87)) < 0.01
        # Other pinned cells (≤1, ≤4; >1):
        assert abs(le[0] - (-0.99)) < 0.01
        assert abs(le[3] - (-1.08)) < 0.01
        assert abs(np.asarray(gt)[0] - (-1.06)) < 0.01

    def test_empty_side_sentinel(self):
        counts = jnp.zeros((8, 4), jnp.float32)
        rest = jnp.zeros((4,), jnp.float32)
        le, gt = splitscore.split_scores(counts, rest)
        assert np.all(np.asarray(le) <= ref.NEG_SENTINEL / 2)
        assert np.all(np.asarray(gt) <= ref.NEG_SENTINEL / 2)

    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 10_000),
        n_bins=st.integers(2, 64),
        n_classes=st.integers(2, 12),
    )
    def test_hypothesis_sweep(self, seed, n_bins, n_classes):
        rng = np.random.default_rng(seed)
        counts = rng.integers(0, 30, (n_bins, n_classes)).astype(np.float32)
        rest = rng.integers(0, 10, n_classes).astype(np.float32)
        le, gt = splitscore.split_scores(jnp.array(counts), jnp.array(rest))
        le_np, gt_np = numpy_split_scores(counts, rest)
        np.testing.assert_allclose(np.asarray(le), le_np, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(gt), gt_np, rtol=1e-4, atol=1e-5)


class TestSseScan:
    def numpy_sse(self, values, mask):
        n = int(mask.sum())
        vals = values[:n]
        out = np.full(len(values), ref.NEG_SENTINEL)
        tot = vals.sum()
        for i in range(n - 1):
            if vals[i + 1] == vals[i]:
                continue
            lo = vals[: i + 1]
            hi = vals[i + 1 :]
            out[i] = lo.sum() ** 2 / len(lo) + hi.sum() ** 2 / len(hi)
        return out

    def test_matches_numpy(self):
        rng = np.random.default_rng(11)
        m = 512
        values = np.sort(rng.normal(size=m).astype(np.float32))
        mask = np.ones(m, np.float32)
        mask[400:] = 0.0
        values[400:] = values[399]  # padding mirrors aot padding
        got = np.asarray(ssescan.sse_scan(jnp.array(values), jnp.array(mask)))
        want = self.numpy_sse(values, mask)
        valid = want > ref.NEG_SENTINEL / 2
        np.testing.assert_allclose(got[valid], want[valid], rtol=1e-4)
        assert np.all(got[~valid] <= ref.NEG_SENTINEL / 2)

    def test_matches_ref(self):
        rng = np.random.default_rng(12)
        values = jnp.sort(jnp.array(rng.normal(size=256).astype(np.float32)))
        mask = jnp.ones((256,), jnp.float32)
        got = ssescan.sse_scan(values, mask)
        want = ref.sse_scan_ref(values, mask)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)

    def test_bimodal_argmax_at_gap(self):
        values = jnp.array([1.0, 1.0, 1.0, 1.0, 9.0, 9.0, 9.0, 9.0], jnp.float32)
        mask = jnp.ones((8,), jnp.float32)
        s = np.asarray(ssescan.sse_scan(values, mask))
        assert int(s.argmax()) == 3  # boundary of the low cluster

    def test_constant_labels_all_sentinel(self):
        values = jnp.full((16,), 5.0, jnp.float32)
        mask = jnp.ones((16,), jnp.float32)
        s = np.asarray(ssescan.sse_scan(values, mask))
        assert np.all(s <= ref.NEG_SENTINEL / 2)

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 10_000), n=st.integers(2, 200))
    def test_hypothesis_sweep(self, seed, n):
        rng = np.random.default_rng(seed)
        m = 256
        vals = np.sort(rng.integers(0, 20, n).astype(np.float32))
        values = np.concatenate([vals, np.full(m - n, vals[-1], np.float32)])
        mask = np.concatenate([np.ones(n, np.float32), np.zeros(m - n, np.float32)])
        got = np.asarray(ssescan.sse_scan(jnp.array(values), jnp.array(mask)))
        want = self.numpy_sse(values, mask)
        valid = want > ref.NEG_SENTINEL / 2
        np.testing.assert_allclose(got[valid], want[valid], rtol=1e-3)
        assert np.all(got[~valid] <= ref.NEG_SENTINEL / 2)
