"""L2: the composed split_select graph and its AOT lowering."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import aot, model
from compile.kernels import ref
from compile.kernels.hist import TILE_M


def make_case(seed, m, n_bins, n_classes, valid_frac=0.8):
    rng = np.random.default_rng(seed)
    n_valid = int(m * valid_frac)
    bins = np.zeros(m, np.int32)
    labels = np.zeros(m, np.int32)
    bins[:n_valid] = np.sort(rng.integers(0, n_bins, n_valid))  # sorted, like rust
    labels[:n_valid] = rng.integers(0, n_classes, n_valid)
    mask = np.zeros(m, np.float32)
    mask[:n_valid] = 1.0
    rest = rng.integers(0, 6, n_classes).astype(np.float32)
    return (
        jnp.array(bins),
        jnp.array(labels),
        jnp.array(mask),
        jnp.array(rest),
    )


class TestSplitSelect:
    def test_matches_ref_end_to_end(self):
        bins, labels, mask, rest = make_case(1, TILE_M * 2, 256, 32)
        le, gt = model.split_select(bins, labels, mask, rest, n_bins=256)
        le_r, gt_r = ref.split_select_ref(bins, labels, mask, rest, 256)
        np.testing.assert_allclose(np.asarray(le), np.asarray(le_r), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(gt), np.asarray(gt_r), rtol=1e-5)

    def test_argmax_identifies_planted_split(self):
        # Plant a perfect split at bin 3: classes 0 below, 1 above.
        m = TILE_M
        bins = np.sort(np.random.default_rng(2).integers(0, 8, m)).astype(np.int32)
        labels = (bins > 3).astype(np.int32)
        mask = np.ones(m, np.float32)
        rest = np.zeros(2, np.float32)
        le, _ = model.split_select(
            jnp.array(bins), jnp.array(labels), jnp.array(mask), jnp.array(rest), n_bins=8
        )
        assert int(np.asarray(le).argmax()) == 3
        assert abs(float(np.asarray(le)[3])) < 1e-6  # pure split → ig 0

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 1000), n_classes=st.integers(2, 8))
    def test_hypothesis_consistency(self, seed, n_classes):
        bins, labels, mask, rest = make_case(seed, TILE_M, 16, n_classes)
        le, gt = model.split_select(bins, labels, mask, rest, n_bins=16)
        le_r, gt_r = ref.split_select_ref(bins, labels, mask, rest, 16)
        np.testing.assert_allclose(np.asarray(le), np.asarray(le_r), rtol=1e-4)
        np.testing.assert_allclose(np.asarray(gt), np.asarray(gt_r), rtol=1e-4)


class TestAot:
    def test_lowered_hlo_text_is_parseable_hlo(self):
        text = aot.lower_split_select(TILE_M, 16, 4)
        assert "HloModule" in text
        # One fused module: entry computation consumes 4 params.
        assert "ENTRY" in text
        for p in range(4):
            assert f"parameter({p})" in text

    def test_label_split_lowering(self):
        text = aot.lower_label_split(TILE_M)
        assert "HloModule" in text
        assert "parameter(1)" in text

    def test_variants_are_tile_aligned(self):
        for v in aot.VARIANTS:
            assert v["m"] % TILE_M == 0
            assert v["b"] <= v["m"]

    def test_manifest_written(self, tmp_path):
        import subprocess, sys, json, os

        out = tmp_path / "arts"
        env = dict(os.environ)
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--small-only"],
            check=True,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            env=env,
        )
        manifest = json.loads((out / "manifest.json").read_text())
        assert manifest["version"] == 1
        names = {a["name"] for a in manifest["artifacts"]}
        assert "split_select_m4096" in names
        for a in manifest["artifacts"]:
            assert (out / a["path"]).exists()
