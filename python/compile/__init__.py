"""Build-time compile package: L2 JAX model + L1 Pallas kernels + AOT.

Python here runs ONCE (``make artifacts``) to lower the split-selection
hot-spot to HLO text; the Rust coordinator executes the artifacts via
PJRT. Nothing in this package is imported at request time.
"""
