"""Layer-2 JAX model: the split-selection compute graph.

Composes the L1 kernels into the function the Rust coordinator calls per
(node, feature): histogram → prefix-sum scores. Lowered once by ``aot.py``
into a single fused HLO module per (M, B, C) variant; no host round-trips
inside one selection call.
"""

import functools

import jax

from .kernels import hist as hist_kernel
from .kernels import splitscore, ssescan


@functools.partial(jax.jit, static_argnames=("n_bins",))
def split_select(bin_ids, labels, mask, rest, *, n_bins):
    """Score every binned numeric split candidate of one feature.

    Args:
      bin_ids: i32[M] quantile-bin id per (sorted) numeric row; padded.
      labels:  i32[M] class id per row; padding rows are zeros.
      mask:    f32[M] 1.0 for real rows, 0.0 for padding.
      rest:    f32[C] per-class categorical+missing counts (the rows that
               evaluate false under every numeric predicate).
      n_bins:  static B.

    Returns:
      (le, gt): f32[B] simplified information gain of ``≤ edge(b)`` and
      ``> edge(b)`` for every bin b; empty-side candidates are
      NEG_SENTINEL.
    """
    n_classes = rest.shape[0]
    counts = hist_kernel.hist(
        bin_ids, labels, mask, n_bins=n_bins, n_classes=n_classes
    )
    return splitscore.split_scores(counts, rest)


@jax.jit
def label_split_select(values, mask):
    """Regression label-split scores (Algorithm 6) for sorted labels."""
    return (ssescan.sse_scan(values, mask),)


def split_select_abstract(m, n_bins, n_classes):
    """ShapeDtypeStructs for lowering a (M, B, C) variant."""
    import jax.numpy as jnp

    return (
        jax.ShapeDtypeStruct((m,), jnp.int32),
        jax.ShapeDtypeStruct((m,), jnp.int32),
        jax.ShapeDtypeStruct((m,), jnp.float32),
        jax.ShapeDtypeStruct((n_classes,), jnp.float32),
    )
