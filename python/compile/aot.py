"""AOT lowering: JAX/Pallas → HLO **text** artifacts + manifest.json.

HLO text (never ``.serialize()``) is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage:  python -m compile.aot --out-dir ../artifacts
"""

import argparse
import functools
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model

# Exported (M, B, C) variants. M is padded example count (multiple of the
# hist kernel's TILE_M=1024); B is the bin count; C the padded class count.
VARIANTS = [
    dict(m=4_096, b=256, c=32),
    dict(m=32_768, b=256, c=32),
    dict(m=262_144, b=256, c=32),
]

# Regression label-split scan variants (M only).
SSE_VARIANTS = [dict(m=4_096), dict(m=32_768)]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_split_select(m, b, c) -> str:
    fn = functools.partial(model.split_select, n_bins=b)
    args = model.split_select_abstract(m, b, c)
    return to_hlo_text(jax.jit(fn).lower(*args))


def lower_label_split(m) -> str:
    import jax.numpy as jnp

    args = (
        jax.ShapeDtypeStruct((m,), jnp.float32),
        jax.ShapeDtypeStruct((m,), jnp.float32),
    )
    return to_hlo_text(jax.jit(model.label_split_select).lower(*args))


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument(
        "--small-only",
        action="store_true",
        help="lower only the smallest variant (fast CI path)",
    )
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"version": 1, "artifacts": []}

    variants = VARIANTS[:1] if args.small_only else VARIANTS
    for v in variants:
        name = f"split_select_m{v['m']}"
        path = f"{name}.hlo.txt"
        text = lower_split_select(v["m"], v["b"], v["c"])
        with open(os.path.join(args.out_dir, path), "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            dict(name=name, path=path, m=v["m"], b=v["b"], c=v["c"])
        )
        print(f"lowered {name}: {len(text)} chars")

    sse_variants = SSE_VARIANTS[:1] if args.small_only else SSE_VARIANTS
    for v in sse_variants:
        name = f"label_split_m{v['m']}"
        path = f"{name}.hlo.txt"
        text = lower_label_split(v["m"])
        with open(os.path.join(args.out_dir, path), "w") as f:
            f.write(text)
        # b/c are 0 for the scan artifacts (single-vector kernel).
        manifest["artifacts"].append(dict(name=name, path=path, m=v["m"], b=0, c=0))
        print(f"lowered {name}: {len(text)} chars")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {args.out_dir}/manifest.json ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
