"""Prefix-sum + information-gain scoring of all binned split candidates.

The Pallas form of paper Algorithm 4 lines 10–28 on the binned domain:
given the [B, C] histogram from ``hist`` and the per-class
categorical+missing counts ``rest[C]`` (always the negative side — the
hybrid/missing semantics), compute for every bin b the simplified
information gain of ``≤ edge(b)`` and ``> edge(b)``.

Single-block kernel: B·C f32 = 32 KiB lives entirely in VMEM; the scan is
``jnp.cumsum`` along B; each candidate's heuristic is the O(C) reduction
of Algorithm 3, vectorized across all B candidates at once. Empty-side
candidates are marked with ``NEG_SENTINEL`` so the Rust consumer skips
them.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_SENTINEL


def _info_gain(pos, neg):
    """Vectorized Algorithm 3 over rows of [B, C] count matrices."""
    tot_p = pos.sum(-1)
    tot_n = neg.sum(-1)
    tot = tot_p + tot_n

    def side(x, tx):
        tx_safe = jnp.maximum(tx, 1.0)[..., None]
        term = x * jnp.log(jnp.maximum(x, 1e-30) / tx_safe)
        return jnp.where(x > 0, term, 0.0).sum(-1)

    ret = (side(pos, tot_p) + side(neg, tot_n)) / jnp.maximum(tot, 1.0)
    valid = (tot_p > 0) & (tot_n > 0)
    return jnp.where(valid, ret, NEG_SENTINEL)


def _score_kernel(counts_ref, rest_ref, le_ref, gt_ref):
    counts = counts_ref[...]  # [B, C]
    rest = rest_ref[...]  # [C]
    prefix = jnp.cumsum(counts, axis=0)  # cnt(bin ≤ b) — the prefix sum
    tot = prefix[-1]  # [C] numeric totals
    le_ref[...] = _info_gain(prefix, (tot - prefix) + rest[None, :])
    gt_ref[...] = _info_gain(tot - prefix, prefix + rest[None, :])


@jax.jit
def split_scores(counts, rest):
    """(le[B], gt[B]) information-gain scores from a [B, C] histogram."""
    n_bins, n_classes = counts.shape
    return pl.pallas_call(
        _score_kernel,
        in_specs=[
            pl.BlockSpec((n_bins, n_classes), lambda: (0, 0)),
            pl.BlockSpec((n_classes,), lambda: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((n_bins,), lambda: (0,)),
            pl.BlockSpec((n_bins,), lambda: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_bins,), jnp.float32),
            jax.ShapeDtypeStruct((n_bins,), jnp.float32),
        ],
        interpret=True,
    )(counts, rest)
