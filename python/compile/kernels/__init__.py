"""Layer-1 Pallas kernels for the Superfast Selection hot-spot.

- ``hist``: label histogram over binned feature values (MXU-friendly
  one-hot matmul formulation, tiled over examples).
- ``splitscore``: prefix-sum + simplified-information-gain scores for all
  binary split candidates (paper Algorithm 3 / 4 on a binned domain).
- ``ssescan``: regression label split (paper Algorithm 6) as a prefix scan.
- ``ref``: pure-jnp oracle implementations used by pytest.

All kernels run with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); on real TPU hardware the same BlockSpecs tile VMEM.
"""

from . import hist, ref, splitscore, ssescan  # noqa: F401
