"""Regression label split (paper Algorithm 6) as a Pallas prefix scan.

Input: the node's label values sorted ascending (padded; ``mask`` marks
real entries). Output: for every position i, the SSE criterion of the
split ``label ≤ values[i]`` in prefix-sum form
``Σ_≤² / n_≤ + Σ_>² / n_>`` (maximizing it minimizes SSE, Eq. 3 with the
constant dropped). Non-boundary positions (inside a run of equal labels),
padding, and the last valid position score ``NEG_SENTINEL``.

Single-block kernel: an M-vector plus two cumsums — trivially
VMEM-resident for the exported variants.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_SENTINEL


def _sse_kernel(values_ref, mask_ref, out_ref):
    values = values_ref[...]
    mask = mask_ref[...]
    v = values * mask
    cum_n = jnp.cumsum(mask)
    cum_s = jnp.cumsum(v)
    tot_n = cum_n[-1]
    tot_s = cum_s[-1]
    n_neg = tot_n - cum_n
    s_neg = tot_s - cum_s
    score = cum_s**2 / jnp.maximum(cum_n, 1.0) + s_neg**2 / jnp.maximum(n_neg, 1.0)
    next_vals = jnp.concatenate([values[1:], values[-1:]])
    next_mask = jnp.concatenate([mask[1:], jnp.zeros((1,), mask.dtype)])
    is_boundary = (next_vals != values) | (next_mask == 0)
    valid = (mask > 0) & is_boundary & (n_neg > 0) & (cum_n > 0)
    out_ref[...] = jnp.where(valid, score, NEG_SENTINEL)


@jax.jit
def sse_scan(values, mask):
    """score[i] of the label split ``≤ values[i]`` (see module docstring)."""
    m = values.shape[0]
    return pl.pallas_call(
        _sse_kernel,
        in_specs=[
            pl.BlockSpec((m,), lambda: (0,)),
            pl.BlockSpec((m,), lambda: (0,)),
        ],
        out_specs=pl.BlockSpec((m,), lambda: (0,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        interpret=True,
    )(values, mask)
