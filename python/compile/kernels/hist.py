"""Label histogram over binned feature values — the Pallas hot-spot.

TPU mapping of the paper's statistics-collection pass (Algorithm 4
lines 2–9): rather than a scatter per example (hostile to the MXU), each
tile of ``TM`` examples builds two one-hot matrices and multiplies them —
``counts += onehot_bins[TM, B]ᵀ · (mask · onehot_labels)[TM, C]`` — so the
histogram is a chain of ``[B, TM] × [TM, C]`` matmuls accumulated into a
VMEM-resident ``[B, C]`` block (B=256, C=32 → 32 KiB f32, far under the
~16 MiB VMEM budget; per-step footprint ≈ TM·(B+C+3)·4 bytes).

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; on TPU the same BlockSpecs compile natively.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Examples per grid step. 1024 keeps the one-hot tiles ≈1 MiB and divides
# every exported M variant.
TILE_M = 1024


def _hist_kernel(bin_ref, label_ref, mask_ref, out_ref, *, n_bins, n_classes):
    """One grid step: accumulate a TM-tile into the [B, C] output block."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    bins = bin_ref[...]  # [TM] i32
    labels = label_ref[...]  # [TM] i32
    mask = mask_ref[...]  # [TM] f32

    onehot_b = (bins[:, None] == jax.lax.iota(jnp.int32, n_bins)[None, :]).astype(
        jnp.float32
    )  # [TM, B]
    onehot_c = (labels[:, None] == jax.lax.iota(jnp.int32, n_classes)[None, :]).astype(
        jnp.float32
    )  # [TM, C]
    # Mask folds into the label side so padding rows contribute nothing.
    contrib = jnp.dot(onehot_b.T, onehot_c * mask[:, None])  # [B, C] (MXU)
    out_ref[...] += contrib


@functools.partial(jax.jit, static_argnames=("n_bins", "n_classes"))
def hist(bin_ids, labels, mask, *, n_bins, n_classes):
    """counts[b, c] = Σ_i mask[i] · [bin_ids[i] = b] · [labels[i] = c].

    ``bin_ids``/``labels`` are i32[M], ``mask`` f32[M]; M must be a
    multiple of TILE_M (aot.py pads).
    """
    m = bin_ids.shape[0]
    assert m % TILE_M == 0, f"M={m} must be a multiple of {TILE_M}"
    grid = (m // TILE_M,)
    return pl.pallas_call(
        functools.partial(_hist_kernel, n_bins=n_bins, n_classes=n_classes),
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_M,), lambda i: (i,)),
            pl.BlockSpec((TILE_M,), lambda i: (i,)),
            pl.BlockSpec((TILE_M,), lambda i: (i,)),
        ],
        # Constant index map: the [B, C] accumulator stays resident in
        # VMEM across all grid steps.
        out_specs=pl.BlockSpec((n_bins, n_classes), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_bins, n_classes), jnp.float32),
        interpret=True,
    )(bin_ids, labels, mask)
