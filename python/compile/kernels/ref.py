"""Pure-jnp oracle for every kernel: the correctness reference.

These mirror the Rust native engine's semantics on the binned domain:
``rest`` carries the per-class categorical+missing counts, which join the
negative side of every numeric candidate (missing values "left
untouched"). Scores are the paper's simplified information gain
(Algorithm 3), natural log. Empty-side candidates score ``NEG_SENTINEL``.
"""

import jax.numpy as jnp

NEG_SENTINEL = -1e30


def hist_ref(bin_ids, labels, mask, n_bins, n_classes):
    """Masked 2-D histogram: counts[b, c] = Σ_i mask·[bin=b]·[label=c]."""
    onehot_b = (bin_ids[:, None] == jnp.arange(n_bins)[None, :]).astype(jnp.float32)
    onehot_c = (labels[:, None] == jnp.arange(n_classes)[None, :]).astype(jnp.float32)
    return (onehot_b * mask[:, None].astype(jnp.float32)).T @ onehot_c


def info_gain_rows(pos, neg):
    """Paper Algorithm 3 row-wise: pos/neg are [..., C] count matrices.

    Returns the simplified information gain (−H(T|a) up to the constant
    H(T)); invalid (empty-side) rows get NEG_SENTINEL.
    """
    tot_p = pos.sum(-1)
    tot_n = neg.sum(-1)
    tot = tot_p + tot_n

    def side(x, tx):
        tx_safe = jnp.maximum(tx, 1.0)[..., None]
        term = x * jnp.log(jnp.maximum(x, 1e-30) / tx_safe)
        return jnp.where(x > 0, term, 0.0).sum(-1)

    ret = (side(pos, tot_p) + side(neg, tot_n)) / jnp.maximum(tot, 1.0)
    valid = (tot_p > 0) & (tot_n > 0)
    return jnp.where(valid, ret, NEG_SENTINEL)


def split_scores_ref(counts, rest):
    """Score all ``≤ bin`` and ``> bin`` candidates from a [B, C] histogram.

    ``rest[c]`` = categorical + missing count of class c (always negative
    side). Returns (le[B], gt[B]).
    """
    prefix = jnp.cumsum(counts, axis=0)  # [B, C] — cnt(bin ≤ b)
    tot = prefix[-1]  # [C]
    le_pos = prefix
    le_neg = (tot - prefix) + rest[None, :]
    gt_pos = tot - prefix
    gt_neg = prefix + rest[None, :]
    return info_gain_rows(le_pos, le_neg), info_gain_rows(gt_pos, gt_neg)


def split_select_ref(bin_ids, labels, mask, rest, n_bins):
    """End-to-end oracle: histogram then scores."""
    counts = hist_ref(bin_ids, labels, mask, n_bins, rest.shape[0])
    return split_scores_ref(counts, rest)


def sse_scan_ref(values, mask):
    """Regression label-split scan (paper Algorithm 6) on sorted values.

    ``values`` must be ascending within the masked prefix (mask is 1 for
    the first n entries, 0 for padding). Returns score[i] for the split
    ``label ≤ values[i]``: sum²/n on both sides (higher = lower SSE);
    positions that are not run boundaries (values[i+1] == values[i]),
    padding, and the last valid position score NEG_SENTINEL.
    """
    m = values.shape[0]
    v = values * mask
    cum_n = jnp.cumsum(mask)
    cum_s = jnp.cumsum(v)
    tot_n = cum_n[-1]
    tot_s = cum_s[-1]
    n_neg = tot_n - cum_n
    s_neg = tot_s - cum_s
    score = cum_s**2 / jnp.maximum(cum_n, 1.0) + s_neg**2 / jnp.maximum(n_neg, 1.0)
    next_vals = jnp.concatenate([values[1:], values[-1:]])
    next_mask = jnp.concatenate([mask[1:], jnp.zeros((1,), mask.dtype)])
    is_boundary = (next_vals != values) | (next_mask == 0)
    valid = (mask > 0) & is_boundary & (n_neg > 0) & (cum_n > 0)
    return jnp.where(valid, score, NEG_SENTINEL)
