//! Ablation: Superfast vs generic selection inside the *full* UDT build
//! (Table 5 isolates a single feature; this measures whole-tree training
//! on several dataset shapes — narrow/wide, low/high cardinality).
//!
//!   cargo bench --bench ablation_engine

use udt::bench_support::{bench, BenchConfig, Table};
use udt::data::synth::{generate_classification, SynthSpec};
use udt::tree::{Backend, TrainConfig, Tree};

fn main() {
    let cfg = BenchConfig::from_env();
    let mut table = Table::new(&[
        "workload", "rows", "feat", "cardinality", "superfast(ms)", "generic(ms)", "speedup",
    ]);

    let workloads = [
        ("narrow/low-card", 20_000usize, 8usize, 64usize),
        ("narrow/high-card", 20_000, 8, 4096),
        ("wide/low-card", 5_000, 64, 64),
        ("wide/high-card", 5_000, 64, 2048),
    ];
    for (name, rows, feats, card) in workloads {
        let rows = ((rows as f64 * cfg.scale) as usize).max(1000);
        let mut spec = SynthSpec::classification(name, rows, feats, 3);
        spec.numeric_cardinality = card;
        spec.cat_frac = 0.1;
        let ds = generate_classification(&spec, 42);

        let fast_cfg = TrainConfig::default();
        let m_fast = bench("superfast", &cfg, || {
            let _ = Tree::fit(&ds, &fast_cfg).unwrap();
        });
        let slow_cfg = TrainConfig {
            backend: Backend::Generic,
            ..Default::default()
        };
        let m_slow = bench("generic", &cfg, || {
            let _ = Tree::fit(&ds, &slow_cfg).unwrap();
        });
        table.row(vec![
            name.into(),
            rows.to_string(),
            feats.to_string(),
            card.to_string(),
            format!("{:.0}", m_fast.mean_ms()),
            format!("{:.0}", m_slow.mean_ms()),
            format!("{:.1}x", m_slow.mean_ms() / m_fast.mean_ms()),
        ]);
        eprintln!("done {name}");
    }

    println!("\n== Ablation: selection engine inside full UDT training ==");
    println!("{}", table.render());
    println!(
        "expectation: speedup grows with numeric cardinality N (the O(M·N) vs O(M+N·C) gap)."
    );
}
