//! Predict-path throughput & latency: boxed node walk vs compiled
//! struct-of-arrays tables, for a single tree and a bagged forest on
//! synthetic hybrid data.
//!
//! Reports batch rows/sec (full-dataset batches) and single-row p50
//! latency for both paths, and writes a machine-readable
//! `BENCH_predict.json` at the repository root so the serving-path perf
//! trajectory is tracked PR-over-PR alongside `BENCH_table6.json`.
//!
//!   cargo bench --bench predict
//!
//! UDT_BENCH_SCALE scales the row count (1.0 = 100k rows);
//! UDT_BENCH_RUNS the repetitions.

use udt::bench_support::{bench, write_bench_json, BenchConfig, Measurement, Table};
use udt::data::synth::{generate_classification, SynthSpec};
use udt::data::value::Value;
use udt::inference::RowFrame;
use udt::tree::forest::{Forest, ForestConfig};
use udt::util::json::Json;
use udt::util::timer::Timer;
use udt::{Model, SavedModel, Udt};

/// Single-row latency: time each of `reps` one-row predictions and keep
/// every sample so percentiles are meaningful.
fn single_row_latency(name: &str, reps: usize, mut f: impl FnMut(usize)) -> Measurement {
    let mut runs = Vec::with_capacity(reps);
    for i in 0..reps {
        let t = Timer::start();
        f(i);
        runs.push(t.ms());
    }
    Measurement {
        name: name.to_string(),
        runs,
    }
}

fn main() {
    let cfg = BenchConfig::from_env();
    let n_rows = ((100_000.0 * cfg.scale) as usize).max(1_000);
    let mut spec = SynthSpec::classification("predict_bench", n_rows, 12, 4);
    spec.cat_frac = 0.25;
    spec.hybrid_frac = 0.1;
    spec.missing_frac = 0.03;
    let ds = generate_classification(&spec, 42);
    eprintln!(
        "predict bench: {} rows × {} features (UDT_BENCH_SCALE to change)",
        ds.n_rows(),
        ds.n_features()
    );

    let tree = Udt::builder().fit(&ds).expect("train tree");
    let forest = Forest::fit(
        &ds,
        &ForestConfig {
            n_trees: 10,
            ..Default::default()
        },
    )
    .expect("train forest");
    let models = [
        ("single_tree", Model::SingleTree(tree)),
        ("forest", Model::Forest(forest)),
    ];

    // Shared inputs: materialized rows for the boxed path, one columnar
    // frame for the compiled path.
    let rows: Vec<Vec<Value>> = (0..ds.n_rows()).map(|r| ds.row(r)).collect();
    let frame = RowFrame::from_dataset(&ds);
    let single_reps = 2_000usize.min(ds.n_rows());

    let mut table = Table::new(&[
        "model", "path", "batch(ms)", "rows/sec", "p50 row(µs)",
    ]);
    let mut json_rows: Vec<Json> = Vec::new();
    for (name, model) in &models {
        let saved = SavedModel::new(model.clone(), &ds);
        let compiled = saved.compile().expect("compile");

        let boxed_batch = bench(&format!("{name}/boxed"), &cfg, || {
            let labels = model.predict_batch(&rows).expect("boxed batch");
            assert_eq!(labels.len(), rows.len());
        });
        let compiled_batch = bench(&format!("{name}/compiled"), &cfg, || {
            let preds = compiled.predict_frame(&frame).expect("compiled batch");
            assert_eq!(preds.len(), frame.n_rows());
        });
        let boxed_single = single_row_latency(name, single_reps, |i| {
            model.predict_row(&rows[i]).expect("boxed row");
        });
        let compiled_single = single_row_latency(name, single_reps, |i| {
            compiled.predict_row(&rows[i]).expect("compiled row");
        });

        for (path, batch, single) in [
            ("boxed", &boxed_batch, &boxed_single),
            ("compiled", &compiled_batch, &compiled_single),
        ] {
            let batch_ms = batch.min_ms();
            let rows_per_sec = rows.len() as f64 / (batch_ms / 1e3).max(1e-9);
            let p50_us = single.percentile_ms(0.5) * 1e3;
            table.row(vec![
                name.to_string(),
                path.to_string(),
                format!("{batch_ms:.1}"),
                format!("{rows_per_sec:.0}"),
                format!("{p50_us:.2}"),
            ]);
            json_rows.push(Json::obj(vec![
                ("model", Json::Str(name.to_string())),
                ("path", Json::Str(path.to_string())),
                ("batch_ms", Json::Num(batch_ms)),
                ("rows_per_sec", Json::Num(rows_per_sec)),
                ("p50_row_us", Json::Num(p50_us)),
            ]));
        }
        eprintln!("done {name}");
    }

    println!("\n== Predict throughput: boxed vs compiled ==");
    println!("{}", table.render());

    let artifact = Json::obj(vec![
        ("bench", Json::Str("predict".into())),
        ("rows", Json::Num(ds.n_rows() as f64)),
        ("features", Json::Num(ds.n_features() as f64)),
        ("measured", Json::Bool(true)),
        ("cases", Json::Arr(json_rows)),
    ]);
    match write_bench_json("predict", &artifact) {
        Ok(p) => eprintln!("wrote {}", p.display()),
        Err(e) => eprintln!("could not write bench artifact: {e}"),
    }
}
