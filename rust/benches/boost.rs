//! Gradient-boosted training throughput on the shared sort cache.
//!
//! The boosting claim worth measuring: residual labels change every
//! round but feature order does not, so all N rounds filter one cached
//! `SortedIndex` — training cost per round is the split-finding pass,
//! not a re-sort. This bench trains a boosted ensemble on a regression
//! and a binary-classification workload, reports wall-clock, row-visits
//! per second (`rows × rounds / s`) and rounds per second against a
//! single full-tree baseline on the same dataset, and asserts that the
//! whole run sorted each column exactly once.
//!
//! Writes a machine-readable `BENCH_boost.json` at the repository root
//! so the boosting-path perf trajectory is tracked PR-over-PR alongside
//! `BENCH_table6.json` / `BENCH_predict.json` / `BENCH_ingest.json`.
//!
//!   cargo bench --bench boost
//!
//! UDT_BENCH_SCALE scales the row count (1.0 = 100k rows);
//! UDT_BENCH_RUNS the repetitions.

use udt::bench_support::{bench, write_bench_json, BenchConfig, Table};
use udt::data::synth::{generate_any, SynthSpec};
use udt::tree::boost::{Boosted, BoostedConfig};
use udt::util::json::Json;
use udt::{Tree, Udt};

const ROUNDS: usize = 50;

fn main() {
    let cfg = BenchConfig::from_env();
    let n_rows = ((100_000.0 * cfg.scale) as usize).max(2_000);

    let reg = generate_any(&SynthSpec::regression("boost_reg", n_rows, 10), 42);
    let mut cls_spec = SynthSpec::classification("boost_cls", n_rows, 10, 2);
    cls_spec.cat_frac = 0.2;
    cls_spec.noise = 0.1;
    let cls = generate_any(&cls_spec, 43);
    eprintln!(
        "boost bench: {} rows x 10 features, {ROUNDS} rounds (UDT_BENCH_SCALE to change)",
        n_rows
    );

    let boost_cfg = BoostedConfig {
        n_rounds: ROUNDS,
        learning_rate: 0.1,
        max_depth: 4,
        subsample: 1.0,
        n_threads: 0,
        ..Default::default()
    };
    let tree_cfg = Udt::builder().threads(0).build().expect("tree config");

    let mut table = Table::new(&[
        "workload", "rows", "rounds", "tree(ms)", "boost(ms)", "row-visits/s", "rounds/s",
        "boost/tree",
    ]);
    let mut json_cases: Vec<Json> = Vec::new();
    for (name, ds) in [("regression", &reg), ("binary", &cls)] {
        // Single full-tree baseline on the same dataset (also warms the
        // sort cache, mirroring production: sort once, fit many).
        let tree_m = bench(&format!("{name}/tree"), &cfg, || {
            let t = Tree::fit(ds, &tree_cfg).expect("train tree");
            assert!(t.n_nodes() >= 1);
        });
        let boost_m = bench(&format!("{name}/boost"), &cfg, || {
            let b = Boosted::fit(ds, &boost_cfg).expect("train boosted");
            assert_eq!(b.n_rounds(), ROUNDS);
        });
        // The whole bench — baseline, warmup and every timed run — must
        // have sorted each column exactly once.
        assert_eq!(
            ds.sort_index_builds(),
            1,
            "{name}: boosting re-sorted the dataset"
        );

        let tree_ms = tree_m.min_ms();
        let boost_ms = boost_m.min_ms();
        let row_visits_per_sec =
            (ds.n_rows() * ROUNDS) as f64 / (boost_ms / 1e3).max(1e-9);
        let rounds_per_sec = ROUNDS as f64 / (boost_ms / 1e3).max(1e-9);
        table.row(vec![
            name.to_string(),
            ds.n_rows().to_string(),
            ROUNDS.to_string(),
            format!("{tree_ms:.1}"),
            format!("{boost_ms:.1}"),
            format!("{row_visits_per_sec:.0}"),
            format!("{rounds_per_sec:.1}"),
            format!("{:.2}x", boost_ms / tree_ms.max(1e-9)),
        ]);
        json_cases.push(Json::obj(vec![
            ("workload", Json::Str(name.to_string())),
            ("rows", Json::Num(ds.n_rows() as f64)),
            ("rounds", Json::Num(ROUNDS as f64)),
            ("tree_train_ms", Json::Num(tree_ms)),
            ("boost_train_ms", Json::Num(boost_ms)),
            ("row_visits_per_sec", Json::Num(row_visits_per_sec)),
            ("rounds_per_sec", Json::Num(rounds_per_sec)),
            ("boost_vs_tree", Json::Num(boost_ms / tree_ms.max(1e-9))),
            ("sort_index_builds", Json::Num(ds.sort_index_builds() as f64)),
        ]));
        eprintln!("done {name}");
    }

    println!("\n== Boosted training on the shared sort cache ({ROUNDS} rounds) ==");
    println!("{}", table.render());

    let artifact = Json::obj(vec![
        ("bench", Json::Str("boost".into())),
        ("rows", Json::Num(n_rows as f64)),
        ("rounds", Json::Num(ROUNDS as f64)),
        ("measured", Json::Bool(true)),
        ("cases", Json::Arr(json_cases)),
    ]);
    match write_bench_json("boost", &artifact) {
        Ok(p) => eprintln!("wrote {}", p.display()),
        Err(e) => eprintln!("could not write bench artifact: {e}"),
    }
}
