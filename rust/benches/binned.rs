//! Exact vs histogram-binned split selection on the table6-style
//! synthetic workload.
//!
//! The binned backend's claim worth measuring: quantizing every numeric
//! column once into at most `B` dataset-level bins turns per-node split
//! selection into an O(rows) histogram accumulation plus an O(B) scan,
//! and parent-minus-sibling subtraction halves (or better) the rows that
//! ever feed a histogram — at the price of thresholds snapped to bin
//! edges. This bench trains the exact Superfast baseline and the binned
//! backend at B ∈ {32, 256} on the same high-cardinality classification
//! table, reporting train wall-clock, training rows per second, the
//! accumulated histogram rows per second (root + smaller children only —
//! the subtraction witness), the histogram scratch footprint and the
//! test-accuracy delta against exact.
//!
//! Writes a machine-readable `BENCH_binned.json` at the repository root
//! so the binned-path perf trajectory is tracked PR-over-PR alongside
//! the other BENCH_*.json artifacts.
//!
//!   cargo bench --bench binned
//!
//! UDT_BENCH_SCALE scales the row count (1.0 = 200k rows);
//! UDT_BENCH_RUNS the repetitions.

use udt::bench_support::{bench, write_bench_json, BenchConfig, Table};
use udt::data::synth::{generate_any, SynthSpec};
use udt::tree::builder::fit_rows_with_stats;
use udt::tree::{Backend, TrainConfig};
use udt::util::json::Json;

fn main() {
    let cfg = BenchConfig::from_env();
    let n_rows = ((200_000.0 * cfg.scale) as usize).max(4_000);
    let mut spec = SynthSpec::classification("binned_t6", n_rows, 12, 5);
    spec.cat_frac = 0.15;
    spec.hybrid_frac = 0.05;
    spec.missing_frac = 0.02;
    spec.noise = 0.05;
    // Deep numeric grids so both bin budgets genuinely coarsen the
    // threshold set instead of binning losslessly.
    spec.numeric_cardinality = (n_rows / 10).max(1_000);
    eprintln!(
        "binned bench: {n_rows} rows x 12 features, numeric cardinality {} \
         (UDT_BENCH_SCALE to change)",
        spec.numeric_cardinality
    );

    let mut table = Table::new(&[
        "case", "rows", "B", "train(ms)", "train-rows/s", "acc", "Δacc", "hist-rows/s",
        "scratch(KiB)",
    ]);
    let mut json_cases: Vec<Json> = Vec::new();
    let mut exact_acc = 0.0;
    for (case, max_bins) in [("exact", None), ("binned_32", Some(32)), ("binned_256", Some(256))] {
        // A fresh dataset instance per case (same seed, identical data)
        // so each one carries its own sort/bin caches and the
        // quantize-once assertions below stay per-budget.
        let ds = generate_any(&spec, 42);
        let (train, _val, test) = ds.split_indices(0.8, 0.1, 1);
        let tc = TrainConfig {
            backend: match max_bins {
                Some(b) => Backend::Binned { max_bins: b },
                None => Backend::Superfast,
            },
            n_threads: 0,
            ..Default::default()
        };
        // Un-timed fit: warms the sort + bin caches (mirroring
        // production: quantize once, fit many) and yields the tree
        // quality plus the subtraction counters.
        let (tree, stats) = fit_rows_with_stats(&ds, &train, &tc, None).expect("train");
        let acc = tree.accuracy_rows(&ds, &test).expect("accuracy");
        if max_bins.is_none() {
            exact_acc = acc;
        }
        let m = bench(case, &cfg, || {
            let (t, _) = fit_rows_with_stats(&ds, &train, &tc, None).expect("train");
            assert!(t.n_nodes() >= 1);
        });
        // The whole case — warmup and every timed run — must have sorted
        // each column exactly once and (binned only) quantized once.
        assert_eq!(ds.sort_index_builds(), 1, "{case}: re-sorted the dataset");
        assert_eq!(
            ds.bin_index_builds(),
            usize::from(max_bins.is_some()),
            "{case}: re-quantized the dataset"
        );

        let train_ms = m.min_ms();
        let train_s = (train_ms / 1e3).max(1e-9);
        let rows_per_sec = train.len() as f64 / train_s;
        let hist_rows_per_sec = stats.hist_rows_accumulated as f64 / train_s;
        table.row(vec![
            case.to_string(),
            ds.n_rows().to_string(),
            max_bins.map_or_else(|| "-".to_string(), |b| b.to_string()),
            format!("{train_ms:.1}"),
            format!("{rows_per_sec:.0}"),
            format!("{acc:.3}"),
            format!("{:+.4}", acc - exact_acc),
            format!("{hist_rows_per_sec:.0}"),
            (stats.hist_scratch_bytes / 1024).to_string(),
        ]);
        json_cases.push(Json::obj(vec![
            ("case", Json::Str(case.to_string())),
            ("max_bins", Json::Num(max_bins.unwrap_or(0) as f64)),
            ("train_rows", Json::Num(train.len() as f64)),
            ("train_ms", Json::Num(train_ms)),
            ("train_rows_per_sec", Json::Num(rows_per_sec)),
            ("accuracy", Json::Num(acc)),
            ("accuracy_delta", Json::Num(acc - exact_acc)),
            ("hist_rows_accumulated", Json::Num(stats.hist_rows_accumulated as f64)),
            ("hist_rows_per_sec", Json::Num(hist_rows_per_sec)),
            ("hist_scratch_bytes", Json::Num(stats.hist_scratch_bytes as f64)),
        ]));
        eprintln!("done {case}");
    }

    println!("\n== Exact vs histogram-binned training ({n_rows} rows) ==");
    println!("{}", table.render());

    let artifact = Json::obj(vec![
        ("bench", Json::Str("binned".into())),
        ("rows", Json::Num(n_rows as f64)),
        ("numeric_cardinality", Json::Num(spec.numeric_cardinality as f64)),
        ("measured", Json::Bool(true)),
        ("cases", Json::Arr(json_cases)),
    ]);
    match write_bench_json("binned", &artifact) {
        Ok(p) => eprintln!("wrote {}", p.display()),
        Err(e) => eprintln!("could not write bench artifact: {e}"),
    }
}
