//! Spawn-per-call vs the persistent worker pool.
//!
//! Before this runtime existed, every `parallel_map*` call paid
//! `std::thread::scope` spawn + join for a fresh set of OS threads —
//! once per tree level, per boost round × level, per predict batch, per
//! CSV parse. This bench measures exactly that tax: a faithful private
//! copy of the old scoped implementation against the pool, at 16 / 1k /
//! 100k trivial tasks per batch (the 16-task tier is the shallow-
//! frontier shape where spawn overhead dominated), plus an end-to-end
//! table6-style training run on the pool with its batch count — from
//! which the per-train spawn overhead the pool removed is estimated as
//! `batches × (scoped µs/batch − pool µs/batch)` at the small tier.
//!
//! Writes a machine-readable `BENCH_parallel.json` at the repository
//! root so the runtime's perf trajectory is tracked PR-over-PR
//! alongside the other BENCH_*.json artifacts.
//!
//!   cargo bench --bench parallel
//!
//! UDT_BENCH_SCALE scales the training rows (1.0 = 200k);
//! UDT_BENCH_RUNS the repetitions.

use std::cell::UnsafeCell;
use std::hint::black_box;
use std::sync::atomic::{AtomicUsize, Ordering};

use udt::bench_support::{bench, write_bench_json, BenchConfig, Table};
use udt::coordinator::parallel::parallel_map;
use udt::data::synth::{generate_any, SynthSpec};
use udt::tree::TrainConfig;
use udt::util::json::Json;

/// The pre-pool implementation, kept verbatim as the comparator:
/// `thread::scope` spawns a fresh worker set per call, items pulled
/// one-by-one from an atomic cursor.
fn scoped_map<T: Send, R: Send>(
    items: Vec<T>,
    n_threads: usize,
    f: impl Fn(T) -> R + Sync,
) -> Vec<R> {
    struct Slot<V>(UnsafeCell<Option<V>>);
    // SAFETY: each slot is touched by exactly one worker — the one that won
    // its index from the cursor — so shared `&Slot` never aliases a write.
    unsafe impl<V: Send> Sync for Slot<V> {}

    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = n_threads.max(1).min(n);
    if workers == 1 {
        return items.into_iter().map(f).collect();
    }
    let slots: Vec<Slot<T>> = items.into_iter().map(|t| Slot(UnsafeCell::new(Some(t)))).collect();
    let results: Vec<Slot<R>> = (0..n).map(|_| Slot(UnsafeCell::new(None))).collect();
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                // SAFETY: `fetch_add` handed index `i` to this worker
                // alone; the scope join publishes the writes.
                let item = unsafe { (*slots[i].0.get()).take() }.expect("item present");
                let r = f(item);
                // SAFETY: same exclusivity argument — index `i` belongs to
                // this worker alone; the scope join publishes the write.
                unsafe { *results[i].0.get() = Some(r) };
            });
        }
    });
    results
        .into_iter()
        .map(|s| s.0.into_inner().expect("worker completed"))
        .collect()
}

/// The trivial task: cheap enough that per-batch runtime overhead (not
/// the work) is what gets measured.
fn task(x: usize) -> usize {
    x.wrapping_mul(2654435761) ^ (x >> 7)
}

fn main() {
    let cfg = BenchConfig::from_env();
    let threads = udt::runtime::cores();
    eprintln!(
        "parallel bench: spawn-per-call vs persistent pool on {threads} cores \
         (UDT_BENCH_SCALE scales the training tier)"
    );

    let mut table = Table::new(&["case", "tasks", "scoped(us)", "pool(us)", "speedup"]);
    let mut json_cases: Vec<Json> = Vec::new();
    let mut small_tier_saving_us = 0.0;
    for &tasks in &[16usize, 1_000, 100_000] {
        let scoped = bench(&format!("scoped_{tasks}"), &cfg, || {
            let items: Vec<usize> = (0..tasks).collect();
            black_box(scoped_map(items, threads, task));
        });
        let pooled = bench(&format!("pool_{tasks}"), &cfg, || {
            let items: Vec<usize> = (0..tasks).collect();
            black_box(parallel_map(items, 0, task));
        });
        let scoped_us = scoped.min_ms() * 1e3;
        let pool_us = pooled.min_ms() * 1e3;
        if tasks == 16 {
            small_tier_saving_us = (scoped_us - pool_us).max(0.0);
        }
        table.row(vec![
            format!("batch_{tasks}"),
            tasks.to_string(),
            format!("{scoped_us:.1}"),
            format!("{pool_us:.1}"),
            format!("{:.2}x", scoped_us / pool_us.max(1e-9)),
        ]);
        json_cases.push(Json::obj(vec![
            ("case", Json::Str(format!("batch_{tasks}"))),
            ("tasks", Json::Num(tasks as f64)),
            ("scoped_us_per_batch", Json::Num(scoped_us)),
            ("pool_us_per_batch", Json::Num(pool_us)),
        ]));
        eprintln!("done batch_{tasks}");
    }

    // End-to-end: a table6-style training run on the pool, with the
    // batch count the old runtime would have paid a spawn set for.
    let n_rows = ((200_000.0 * cfg.scale) as usize).max(4_000);
    let mut spec = SynthSpec::classification("parallel_t6", n_rows, 12, 5);
    spec.cat_frac = 0.15;
    spec.noise = 0.05;
    let ds = generate_any(&spec, 42);
    let tc = TrainConfig {
        n_threads: 0,
        ..Default::default()
    };
    // Un-timed warm fit: builds the sort cache so the timed runs
    // measure training, and warms the pool.
    let warm = udt::Tree::fit(&ds, &tc).expect("train");
    assert!(warm.n_nodes() >= 3);
    let before = udt::runtime::pool_stats();
    let m = bench("train_table6", &cfg, || {
        let t = udt::Tree::fit(&ds, &tc).expect("train");
        assert!(t.n_nodes() >= 3);
    });
    let delta = udt::runtime::pool_stats().delta_since(&before);
    // The closure ran warmup + timed times inside the delta window.
    let fits = (cfg.warmup + cfg.runs).max(1);
    let batches_per_train = delta.batches_submitted as f64 / fits as f64;
    let est_saved_ms = batches_per_train * small_tier_saving_us / 1e3;
    let train_ms = m.min_ms();
    eprintln!("done train_table6");

    println!("\n== Spawn-per-call vs persistent pool ({threads} cores) ==");
    println!("{}", table.render());
    println!(
        "train_table6: {n_rows} rows, {train_ms:.1} ms/train, {batches_per_train:.0} pool \
         batches/train, est. spawn overhead removed {est_saved_ms:.2} ms/train"
    );

    let artifact = Json::obj(vec![
        ("bench", Json::Str("parallel".into())),
        ("cores", Json::Num(threads as f64)),
        ("measured", Json::Bool(true)),
        ("cases", Json::Arr(json_cases)),
        (
            "train",
            Json::obj(vec![
                ("rows", Json::Num(n_rows as f64)),
                ("train_ms", Json::Num(train_ms)),
                ("pool_batches_per_train", Json::Num(batches_per_train)),
                ("pool_tasks", Json::Num(delta.tasks_executed as f64)),
                ("threads_spawned_during_train", Json::Num(delta.threads_spawned_total as f64)),
                ("est_spawn_overhead_removed_ms", Json::Num(est_saved_ms)),
            ]),
        ),
    ]);
    match write_bench_json("parallel", &artifact) {
        Ok(p) => eprintln!("wrote {}", p.display()),
        Err(e) => eprintln!("could not write bench artifact: {e}"),
    }
}
