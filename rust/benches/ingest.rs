//! Ingest throughput: legacy row-materializing CSV parsing vs the
//! streaming chunk-parallel typed path (1 thread and all cores).
//!
//! The paper's headline (KDD99-10%, 494K×41, training in under a
//! second) only holds if the data layer keeps up: the legacy path
//! materialized every cell as a heap `String` (~20M allocations for
//! KDD99) before typing anything, while the streaming path parses
//! borrowed field slices straight into typed column shards. This bench
//! tracks parse wall-clock, MB/s, rows/sec and resident bytes for both,
//! and writes `BENCH_ingest.json` at the repo root so the trajectory is
//! visible PR-over-PR.
//!
//!   cargo bench --bench ingest

use udt::bench_support::{bench, write_bench_json, BenchConfig, Table};
use udt::data::csv::{load_csv_str, load_csv_str_rowwise, to_csv_string, CsvOptions};
use udt::data::synth::{generate_classification, SynthSpec};
use udt::util::json::Json;

fn main() {
    let cfg = BenchConfig::from_env();
    // KDD99-10%-shaped workload: ~494K rows × 41 features, hybrid mix.
    let rows = ((494_021.0 * cfg.scale) as usize).max(5_000);
    let mut spec = SynthSpec::classification("ingest", rows, 41, 23);
    spec.cat_frac = 0.17;
    spec.hybrid_frac = 0.05;
    spec.missing_frac = 0.01;
    let ds = generate_classification(&spec, 42);
    let csv = to_csv_string(&ds);
    let mb = csv.len() as f64 / 1e6;
    eprintln!(
        "ingest: {} rows x {} features, {:.1} MB of CSV (UDT_BENCH_SCALE to change)",
        ds.n_rows(),
        ds.n_features(),
        mb
    );

    let mut table = Table::new(&["path", "parse(ms)", "MB/s", "rows/s", "dataset(MB)"]);
    let mut json_cases: Vec<Json> = Vec::new();
    let n_rows = ds.n_rows();
    let mut run_case = |name: &str, f: &dyn Fn() -> udt::Dataset| {
        let parsed = f();
        let dataset_bytes = parsed.approx_bytes();
        drop(parsed);
        let m = bench(name, &cfg, || {
            let _ = f();
        });
        let ms = m.mean_ms();
        let mbps = mb / (ms / 1000.0).max(1e-9);
        let rps = n_rows as f64 / (ms / 1000.0).max(1e-9);
        table.row(vec![
            name.to_string(),
            format!("{ms:.1}"),
            format!("{mbps:.0}"),
            format!("{rps:.0}"),
            format!("{:.1}", dataset_bytes as f64 / 1e6),
        ]);
        json_cases.push(Json::obj(vec![
            ("name", Json::Str(name.to_string())),
            ("parse_ms", Json::Num(ms)),
            ("mb_per_sec", Json::Num(mbps)),
            ("rows_per_sec", Json::Num(rps)),
            ("dataset_bytes", Json::Num(dataset_bytes as f64)),
        ]));
        eprintln!("done {name}");
    };
    run_case("rowwise (legacy)", &|| {
        load_csv_str_rowwise("b", &csv, &CsvOptions::default()).unwrap()
    });
    run_case("streaming x1", &|| {
        load_csv_str(
            "b",
            &csv,
            &CsvOptions {
                n_threads: 1,
                ..Default::default()
            },
        )
        .unwrap()
    });
    run_case("streaming xN", &|| {
        load_csv_str(
            "b",
            &csv,
            &CsvOptions {
                n_threads: 0,
                ..Default::default()
            },
        )
        .unwrap()
    });

    // Transient footprint estimate of the legacy path: one `String` per
    // cell (24-byte header + payload) on top of the raw text — the
    // allocation storm the streaming path deletes.
    let width = ds.n_features() + 1;
    let rowwise_transient = n_rows * width * std::mem::size_of::<String>() + csv.len();

    println!("\n== Ingest: legacy rowwise vs streaming chunk-parallel ==");
    println!("{}", table.render());
    println!(
        "legacy transient estimate: {:.1} MB of cell Strings before any typing",
        rowwise_transient as f64 / 1e6
    );

    let artifact = Json::obj(vec![
        ("bench", Json::Str("ingest".into())),
        ("scale", Json::Num(cfg.scale)),
        ("rows", Json::Num(n_rows as f64)),
        ("features", Json::Num(ds.n_features() as f64)),
        ("csv_mb", Json::Num(mb)),
        (
            "rowwise_transient_bytes_est",
            Json::Num(rowwise_transient as f64),
        ),
        ("cases", Json::Arr(json_cases)),
    ]);
    match write_bench_json("ingest", &artifact) {
        Ok(p) => eprintln!("wrote {}", p.display()),
        Err(e) => eprintln!("could not write bench artifact: {e}"),
    }
}
