//! Ablation: Training-Only-Once Tuning vs generic retraining-based tuning
//! (paper §4 text: churn modeling, 227.5 settings — 10 ms once-tuned vs
//! 16.8 s retrained).
//!
//!   cargo bench --bench ablation_tuning

use udt::bench_support::{BenchConfig, Table};
use udt::data::synth::{generate_classification, registry};
use udt::tree::tuning::{tune, tune_by_retraining, TuneGrid};
use udt::tree::{TrainConfig, Tree};

fn main() {
    let cfg = BenchConfig::from_env();
    let scale = if std::env::var("UDT_BENCH_SCALE").is_err() { 1.0 } else { cfg.scale };

    let mut spec = registry::find("churn_modeling").unwrap().spec.scaled(scale);
    spec.noise = 0.2;
    let ds = generate_classification(&spec, 42);
    let (train, val, _) = ds.split_indices(0.8, 0.1, 7);
    let train_cfg = TrainConfig::default();
    let full = Tree::fit_rows(&ds, &train, &train_cfg).expect("train");
    eprintln!(
        "churn-modeling shape: full tree {} nodes depth {}",
        full.n_nodes(),
        full.depth
    );

    // Once-tuning over the paper's full grid.
    let grid = TuneGrid::default();
    let fast = tune(&full, &ds, &val, train.len(), &grid).expect("once-tuner");

    // Retraining baseline over a reduced grid, projected to the full grid
    // (running 200+ retrainings is exactly the cost the paper avoids).
    let small = TuneGrid {
        min_split_steps: 10,
        ..Default::default()
    };
    let slow = tune_by_retraining(&ds, &train, &val, &train_cfg, full.depth as usize, &small)
        .expect("retraining tuner");
    let per_setting = slow.tune_ms / slow.n_settings as f64;
    let projected = per_setting * fast.n_settings as f64;

    let mut table = Table::new(&["tuner", "settings", "total(ms)", "ms/setting", "val metric"]);
    table.row(vec![
        "training-only-once".into(),
        fast.n_settings.to_string(),
        format!("{:.1}", fast.tune_ms),
        format!("{:.4}", fast.tune_ms / fast.n_settings as f64),
        format!("{:.4}", fast.best_metric),
    ]);
    table.row(vec![
        format!("generic retraining (measured {} settings)", slow.n_settings),
        fast.n_settings.to_string(),
        format!("{projected:.0} (projected)"),
        format!("{per_setting:.2}"),
        format!("{:.4}", slow.best_metric),
    ]);
    println!("\n== Ablation: tuning strategies (churn_modeling, scale {scale}) ==");
    println!("{}", table.render());
    println!(
        "speedup at equal grids: {:.0}× (paper: 16.8 s vs 10 ms ≈ 1680×)",
        projected / fast.tune_ms
    );

    assert!(
        projected / fast.tune_ms > 50.0,
        "once-tuning should be ≫ retraining (got {:.0}×)",
        projected / fast.tune_ms
    );
    // Both tuners find settings of comparable validation quality.
    assert!((fast.best_metric - slow.best_metric).abs() < 0.05);
    eprintln!("ablation_tuning: assertions passed");
}
