//! Paper Table 7: UDT on the 5 regression datasets — full-tree stats,
//! tune time, test MAE/RMSE, tuned-tree stats.
//!
//!   cargo bench --bench table7        (0.25× scale by default)
//!   UDT_BENCH_SCALE=1.0 cargo bench --bench table7

use udt::bench_support::{BenchConfig, Table};
use udt::coordinator::pipeline::{run_pipeline, Quality};
use udt::data::synth::{generate_any, registry};
use udt::tree::tuning::TuneGrid;
use udt::tree::TrainConfig;

fn main() {
    let cfg = BenchConfig::from_env();
    let scale = if std::env::var("UDT_BENCH_SCALE").is_err() {
        0.25
    } else {
        cfg.scale
    };
    eprintln!("table7: scale {scale}");

    let mut table = Table::new(&[
        "dataset", "rows", "feat", "nodes", "depth", "train(ms)", "tune(ms)", "MAE",
        "RMSE", "t.nodes", "t.depth", "t.train(ms)",
    ]);
    for entry in registry::regression_registry() {
        let ds = generate_any(&entry.spec.scaled(scale), 42);
        let train_cfg = TrainConfig {
            n_threads: 0,
            ..Default::default()
        };
        let rep = run_pipeline(&ds, &train_cfg, &TuneGrid::default(), 1).expect("pipeline");
        let (mae, rmse) = match rep.quality {
            Quality::Regression { mae, rmse } => (mae, rmse),
            _ => unreachable!(),
        };
        table.row(vec![
            rep.dataset.clone(),
            rep.n_examples.to_string(),
            rep.n_features.to_string(),
            rep.full_nodes.to_string(),
            rep.full_depth.to_string(),
            format!("{:.0}", rep.full_train_ms),
            format!("{:.1}", rep.tune_ms),
            format!("{mae:.3}"),
            format!("{rmse:.3}"),
            rep.tuned_nodes.to_string(),
            rep.tuned_depth.to_string(),
            format!("{:.0}", rep.tuned_train_ms),
        ]);
        eprintln!("done {}", rep.dataset);
    }
    println!("\n== Table 7: UDT on regression datasets (scale {scale}) ==");
    println!("{}", table.render());
    println!("== CSV ==\n{}", table.to_csv());
}
