//! Paper Table 6: UDT on the 19 classification datasets — full-tree
//! nodes/depth/train-ms, tune-ms, test accuracy, tuned-tree
//! nodes/depth/retrain-ms.
//!
//! Datasets are shape-matched synthetics (DESIGN.md §6). Default scale is
//! 0.1× row counts so the full suite runs in minutes; set
//! UDT_BENCH_SCALE=1.0 for paper-sized runs (kdd99_full at 4.9M rows
//! needs several GB of RAM and is skipped above 2M rows unless
//! UDT_BENCH_FULL=1).
//!
//! Besides the printed table, the run writes a machine-readable
//! `BENCH_table6.json` (train wall-clock, rows/sec, peak arena bytes per
//! dataset) at the repository root so the perf trajectory is tracked
//! PR-over-PR.
//!
//!   cargo bench --bench table6

use udt::bench_support::{write_bench_json, BenchConfig, Table};
use udt::coordinator::pipeline::{run_pipeline, Quality};
use udt::data::synth::{generate_any, registry};
use udt::tree::tuning::TuneGrid;
use udt::tree::TrainConfig;
use udt::util::json::Json;

fn main() {
    let cfg = BenchConfig::from_env();
    let scale = if cfg.scale == 1.0 && std::env::var("UDT_BENCH_SCALE").is_err() {
        0.1
    } else {
        cfg.scale
    };
    let full = std::env::var("UDT_BENCH_FULL").is_ok();
    eprintln!("table6: scale {scale} (UDT_BENCH_SCALE to change; UDT_BENCH_FULL=1 for kdd99_full)");

    let mut table = Table::new(&[
        "dataset", "rows", "feat", "cls", "nodes", "depth", "train(ms)", "tune(ms)",
        "acc", "t.nodes", "t.depth", "t.train(ms)", "paper(train/tune/acc)",
    ]);
    let mut json_rows: Vec<Json> = Vec::new();
    for entry in registry::classification_registry() {
        let spec = entry.spec.scaled(scale);
        if spec.n_rows > 2_000_000 && !full {
            eprintln!("skipping {} at {} rows (set UDT_BENCH_FULL=1)", spec.name, spec.n_rows);
            continue;
        }
        let ds = generate_any(&spec, 42);
        let train_cfg = TrainConfig {
            n_threads: 0,
            ..Default::default()
        };
        let rep = run_pipeline(&ds, &train_cfg, &TuneGrid::default(), 1).expect("pipeline");
        let acc = match rep.quality {
            Quality::Accuracy(a) => a,
            _ => unreachable!(),
        };
        let rows_per_sec = rep.n_train as f64 / (rep.full_train_ms / 1000.0).max(1e-9);
        json_rows.push(Json::obj(vec![
            ("dataset", Json::Str(rep.dataset.clone())),
            ("rows", Json::Num(rep.n_examples as f64)),
            ("train_rows", Json::Num(rep.n_train as f64)),
            ("features", Json::Num(rep.n_features as f64)),
            ("classes", Json::Num(rep.n_labels as f64)),
            ("nodes", Json::Num(rep.full_nodes as f64)),
            ("train_ms", Json::Num(rep.full_train_ms)),
            ("tune_ms", Json::Num(rep.tune_ms)),
            ("rows_per_sec", Json::Num(rows_per_sec)),
            ("peak_arena_bytes", Json::Num(rep.peak_arena_bytes as f64)),
            ("accuracy", Json::Num(acc)),
        ]));
        table.row(vec![
            rep.dataset.clone(),
            rep.n_examples.to_string(),
            rep.n_features.to_string(),
            rep.n_labels.to_string(),
            rep.full_nodes.to_string(),
            rep.full_depth.to_string(),
            format!("{:.0}", rep.full_train_ms),
            format!("{:.1}", rep.tune_ms),
            format!("{acc:.3}"),
            rep.tuned_nodes.to_string(),
            rep.tuned_depth.to_string(),
            format!("{:.0}", rep.tuned_train_ms),
            format!(
                "{:.0}/{:.0}/{:.2}",
                entry.paper_train_ms * scale, // linear first-order scaling
                entry.paper_tune_ms * scale,
                entry.paper_quality
            ),
        ]);
        eprintln!("done {}", rep.dataset);
    }
    println!("\n== Table 6: UDT on classification datasets (scale {scale}) ==");
    println!("{}", table.render());
    println!("== CSV ==\n{}", table.to_csv());

    let artifact = Json::obj(vec![
        ("bench", Json::Str("table6".into())),
        ("scale", Json::Num(scale)),
        ("datasets", Json::Arr(json_rows)),
    ]);
    match write_bench_json("table6", &artifact) {
        Ok(p) => eprintln!("wrote {}", p.display()),
        Err(e) => eprintln!("could not write bench artifact: {e}"),
    }
}
