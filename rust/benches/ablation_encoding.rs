//! Ablation: no-pre-encoding memory/time (paper §4: one-hot on "credit
//! card" would need ~39 GB and could not run on the 8 GB test machine;
//! UDT trains directly at ~90 MB peak).
//!
//! We measure (a) UDT's actual footprint + training time on hybrid data,
//! (b) the materialized size and encode time of an integer/one-hot
//! pre-encoding pass, at several categorical vocabulary sizes.
//!
//!   cargo bench --bench ablation_encoding

use udt::bench_support::{BenchConfig, Table};
use udt::data::synth::{generate_classification, SynthSpec};
use udt::data::value::Value;
use udt::tree::{TrainConfig, Tree};
use udt::util::timer::Timer;

fn main() {
    let cfg = BenchConfig::from_env();
    let rows = ((60_000 as f64) * cfg.scale) as usize;
    let mut table = Table::new(&[
        "vocab/feature", "udt(MB)", "one-hot(MB)", "ratio", "encode(ms)", "train-direct(ms)",
    ]);

    for vocab in [8usize, 64, 256, 1024] {
        let mut spec = SynthSpec::classification("enc", rows.max(2000), 12, 2);
        spec.cat_frac = 0.75;
        spec.cat_vocab = vocab;
        let ds = generate_classification(&spec, 42);

        // (a) Direct UDT training on hybrid values.
        let t = Timer::start();
        let _tree = Tree::fit(&ds, &TrainConfig::default()).unwrap();
        let direct_ms = t.ms();

        // (b) One-hot materialization: one f64 column per distinct
        // category per categorical feature (plus numerics). We actually
        // build it (then drop it) to measure encode time honestly.
        let t = Timer::start();
        let mut onehot_cols = 0usize;
        let mut encoded: Vec<Vec<f64>> = Vec::new();
        for col in &ds.columns {
            let stats = col.stats();
            if stats.n_cat > 0 {
                // Distinct categories in this column.
                let mut seen = std::collections::BTreeSet::new();
                for v in col.iter() {
                    if let Value::Cat(c) = v {
                        seen.insert(c.0);
                    }
                }
                for &cat in &seen {
                    let mut dense = vec![0.0f64; ds.n_rows()];
                    for (i, v) in col.iter().enumerate() {
                        if matches!(v, Value::Cat(c) if c.0 == cat) {
                            dense[i] = 1.0;
                        }
                    }
                    encoded.push(dense);
                    onehot_cols += 1;
                }
            } else {
                encoded.push(
                    col.iter()
                        .map(|v| v.as_num().unwrap_or(f64::NAN))
                        .collect(),
                );
                onehot_cols += 1;
            }
        }
        let encode_ms = t.ms();
        let onehot_bytes = onehot_cols * ds.n_rows() * 8;
        let udt_bytes = ds.approx_bytes();
        drop(encoded);

        table.row(vec![
            vocab.to_string(),
            format!("{:.1}", udt_bytes as f64 / 1e6),
            format!("{:.1}", onehot_bytes as f64 / 1e6),
            format!("{:.1}x", onehot_bytes as f64 / udt_bytes as f64),
            format!("{encode_ms:.0}"),
            format!("{direct_ms:.0}"),
        ]);
        eprintln!("done vocab={vocab}");
    }

    println!("\n== Ablation: pre-encoding cost vs direct hybrid training ==");
    println!("{}", table.render());
    println!("expectation: one-hot blow-up grows with vocabulary; UDT footprint is flat.");
}
