//! Serving-layer latency & throughput under mostly-idle connection load:
//! the epoll reactor backend vs the thread-per-connection fallback.
//!
//! For each backend × connection tier (1, 100, 10k by default), the
//! bench starts a fresh server, ramps up `tier − 1` idle-but-live
//! connections (each ping-verified, so the server has really registered
//! it), then measures sequential single-row request latency on one
//! active connection: p50/p99 per request plus req/s over the whole run.
//! The point of the idle crowd is that it is *not* free on the threads
//! backend (one parked OS thread each) while the reactor carries it as
//! a few hundred bytes of state per connection.
//!
//! Writes `BENCH_serve.json` at the repository root (or
//! `$UDT_BENCH_DIR`) so the serve-path trajectory is tracked
//! PR-over-PR:
//!
//!   cargo bench --bench serve
//!
//! UDT_BENCH_SCALE scales the connection tiers and the request count
//! (CI smoke runs tiny tiers); the fd rlimit is raised best-effort
//! before the 10k tier.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{mpsc, Arc};
use std::time::Duration;
use udt::bench_support::{write_bench_json, BenchConfig, Measurement, Table};
use udt::coordinator::reactor;
use udt::coordinator::serve::{ServeBackend, ServeConfig, Server};
use udt::data::synth::{generate_classification, SynthSpec};
use udt::util::json::Json;
use udt::util::timer::Timer;
use udt::{Model, SavedModel, Udt};

const TIERS: [usize; 3] = [1, 100, 10_000];
const REQUEST_LINE: &str = "[1.0, 2.0, 3.0, 4.0]";

fn saved_model() -> SavedModel {
    let mut spec = SynthSpec::classification("serve_bench", 2_000, 4, 3);
    spec.cat_frac = 0.25;
    let ds = generate_classification(&spec, 42);
    let tree = Udt::builder().fit(&ds).expect("train tree");
    SavedModel::new(Model::SingleTree(tree), &ds)
}

struct Case {
    backend: &'static str,
    tier: usize,
    achieved: usize,
    requests: usize,
    p50_ms: f64,
    p99_ms: f64,
    req_per_sec: f64,
}

/// Ping-verified connection: the server has accepted and registered it.
fn connect_verified(addr: std::net::SocketAddr) -> std::io::Result<TcpStream> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(Duration::from_secs(10)))?;
    stream.write_all(b"ping\n")?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    if line.trim() != "\"pong\"" {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("bad ping reply: {line:?}"),
        ));
    }
    Ok(stream)
}

fn run_case(backend: ServeBackend, tier: usize, n_requests: usize) -> Case {
    let server = Server::new(saved_model()).expect("server");
    let cfg = ServeConfig {
        backend,
        max_connections: tier + 64,
        ..Default::default()
    };
    let (tx, rx) = mpsc::channel();
    let s2 = Arc::clone(&server);
    let handle = std::thread::spawn(move || {
        s2.serve_with(cfg, "127.0.0.1:0", |addr| tx.send(addr).unwrap())
            .expect("serve");
    });
    let addr = rx.recv().unwrap();

    // The idle crowd. Failures (fd limits, kernel backlog) degrade the
    // tier rather than killing the bench; the achieved count is reported
    // so a partial ramp is visible in the artifact, never silent.
    let mut idle = Vec::with_capacity(tier.saturating_sub(1));
    for _ in 1..tier {
        match connect_verified(addr) {
            Ok(s) => idle.push(s),
            Err(e) => {
                eprintln!(
                    "  ramp stopped at {} connections: {e}",
                    idle.len() + 1
                );
                break;
            }
        }
    }

    // The one active connection, measured request-by-request.
    let achieved = idle.len() + 1;
    let mut active = connect_verified(addr).expect("active connection");
    let mut reader = BufReader::new(active.try_clone().expect("clone"));
    let mut line = String::new();
    let mut runs = Vec::with_capacity(n_requests);
    let total = Timer::start();
    for _ in 0..n_requests {
        let t = Timer::start();
        active.write_all(REQUEST_LINE.as_bytes()).expect("write");
        active.write_all(b"\n").expect("write");
        line.clear();
        reader.read_line(&mut line).expect("read");
        runs.push(t.ms());
        assert!(!line.contains("error"), "request failed: {line}");
    }
    let total_ms = total.ms();

    active.write_all(b"\"shutdown\"\n").expect("shutdown");
    line.clear();
    reader.read_line(&mut line).expect("bye");
    handle.join().expect("serve thread");
    drop(idle);

    let m = Measurement {
        name: format!("{}@{}", backend.name(), tier),
        runs,
    };
    Case {
        backend: backend.name(),
        tier,
        achieved,
        requests: n_requests,
        p50_ms: m.percentile_ms(0.5),
        p99_ms: m.percentile_ms(0.99),
        req_per_sec: n_requests as f64 / (total_ms / 1e3).max(1e-9),
    }
}

fn main() {
    let cfg = BenchConfig::from_env();
    match reactor::raise_nofile_limit() {
        Ok(lim) => eprintln!("serve bench: fd limit {lim}"),
        Err(e) => eprintln!("serve bench: could not raise fd limit ({e})"),
    }
    let backends: Vec<ServeBackend> = if reactor::SUPPORTED {
        vec![ServeBackend::Threads, ServeBackend::Reactor]
    } else {
        vec![ServeBackend::Threads]
    };
    let tiers: Vec<usize> = TIERS
        .iter()
        .map(|&t| ((t as f64 * cfg.scale).round() as usize).max(1))
        .collect();
    let n_requests = ((2_000.0 * cfg.scale) as usize).max(200);
    eprintln!(
        "serve bench: tiers {tiers:?}, {n_requests} requests per case \
         (UDT_BENCH_SCALE to change)"
    );

    let mut table = Table::new(&[
        "backend",
        "conns",
        "achieved",
        "p50(ms)",
        "p99(ms)",
        "req/s",
    ]);
    let mut json_rows: Vec<Json> = Vec::new();
    for &backend in &backends {
        for &tier in &tiers {
            eprintln!("case {} @ {} connections...", backend.name(), tier);
            let case = run_case(backend, tier, n_requests);
            table.row(vec![
                case.backend.to_string(),
                case.tier.to_string(),
                case.achieved.to_string(),
                format!("{:.3}", case.p50_ms),
                format!("{:.3}", case.p99_ms),
                format!("{:.0}", case.req_per_sec),
            ]);
            json_rows.push(Json::obj(vec![
                ("backend", Json::Str(case.backend.to_string())),
                ("idle_conns", Json::Num(case.tier as f64)),
                ("achieved_conns", Json::Num(case.achieved as f64)),
                ("requests", Json::Num(case.requests as f64)),
                ("p50_ms", Json::Num(case.p50_ms)),
                ("p99_ms", Json::Num(case.p99_ms)),
                ("req_per_sec", Json::Num(case.req_per_sec)),
            ]));
        }
    }

    println!("\n== Serve latency under idle connection load ==");
    println!("{}", table.render());

    let artifact = Json::obj(vec![
        ("bench", Json::Str("serve".into())),
        (
            "tiers",
            Json::Arr(tiers.iter().map(|&t| Json::Num(t as f64)).collect()),
        ),
        ("requests_per_case", Json::Num(n_requests as f64)),
        ("measured", Json::Bool(true)),
        ("cases", Json::Arr(json_rows)),
    ]);
    match write_bench_json("serve", &artifact) {
        Ok(p) => eprintln!("wrote {}", p.display()),
        Err(e) => eprintln!("could not write bench artifact: {e}"),
    }
}
