//! Ablation: native Superfast engine vs the XLA (AOT JAX/Pallas via PJRT)
//! backend, per node size. Requires `make artifacts`; exits 0 with a
//! notice otherwise.
//!
//! On CPU the XLA path pays a fixed per-call PJRT cost, so the native
//! engine wins; the bench quantifies that overhead and verifies score
//! agreement (exact at ≤256 distinct values). On TPU the same artifacts
//! turn the histogram into MXU matmuls (DESIGN.md §8).
//!
//!   make artifacts && cargo bench --bench ablation_xla

use udt::bench_support::{bench, BenchConfig, Table};
use udt::data::synth::{generate_classification, SynthSpec};
use udt::runtime::xla_split::{XlaSelection, XlaSelectionConfig};
use udt::selection::heuristic::{ClassCriterion, Criterion};
use udt::selection::superfast::{best_split_on_feat, FeatureView, LabelsView, Scratch};

fn main() {
    let Some(xla) = XlaSelection::load_default(XlaSelectionConfig { min_rows: 1 }) else {
        eprintln!("ablation_xla: artifacts not built (run `make artifacts`) — skipping");
        return;
    };
    let cfg = BenchConfig::from_env();
    let mut table = Table::new(&[
        "node rows", "native(ms)", "xla(ms)", "xla/native", "Δscore",
    ]);

    for rows in [1_000usize, 4_000, 16_000, 64_000, 250_000] {
        let rows = ((rows as f64 * cfg.scale) as usize).max(500);
        let mut spec = SynthSpec::classification("xab", rows, 1, 2);
        spec.numeric_cardinality = 200; // exact binning
        spec.cat_frac = 0.0;
        spec.hybrid_frac = 0.0;
        spec.missing_frac = 0.0;
        let ds = generate_classification(&spec, 42);
        let col = &ds.columns[0];
        let row_ids: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let sorted = col.sorted_numeric();
        let view = FeatureView::new(0, col, &row_ids, &sorted.0, &sorted.1);
        let labels = LabelsView::from_labels(&ds.labels);
        let crit = Criterion::Class(ClassCriterion::InfoGain);

        let m_native = bench("native", &cfg, || {
            let _ = best_split_on_feat(&view, &labels, crit);
        });
        let mut scratch = Scratch::new();
        let m_xla = bench("xla", &cfg, || {
            let _ = xla.best_split_on_feat(&view, &labels, crit, &mut scratch);
        });

        let native = best_split_on_feat(&view, &labels, crit).unwrap();
        let accel = xla
            .best_split_on_feat(&view, &labels, crit, &mut scratch)
            .unwrap();
        let delta = (native.score - accel.score).abs();
        assert!(delta < 1e-4, "score mismatch {delta}");

        table.row(vec![
            rows.to_string(),
            format!("{:.3}", m_native.mean_ms()),
            format!("{:.3}", m_xla.mean_ms()),
            format!("{:.1}x", m_xla.mean_ms() / m_native.mean_ms()),
            format!("{delta:.2e}"),
        ]);
        eprintln!("done rows={rows}");
    }

    println!("\n== Ablation: native vs XLA selection backend (CPU PJRT) ==");
    println!("{}", table.render());
}
