//! Paper Table 5 + its figure: generic vs Superfast Selection on a single
//! feature of a credit-card-fraud-shaped dataset, sizes 10K–100K.
//!
//! Paper reference series (ms, on an M2 MacBook Air, C++):
//!   size:      10K 20K 30K 40K  50K  60K  70K  80K   90K  100K
//!   generic:   1.8K 6.8K 15K 27K 42K 61K 83K 110K 142K 178K
//!   superfast: 4    10   15  23  28  32  38  44   51   58
//! The reproduction asserts the *shape*: superfast ~linear in M, generic
//! ~quadratic-ish (M·N with N ∝ M), crossover immediate.
//!
//!   cargo bench --bench table5
//!   UDT_BENCH_RUNS=10 cargo bench --bench table5   # paper-style 10 runs

use udt::bench_support::{table5, BenchConfig};

fn main() {
    let cfg = BenchConfig::from_env();
    let sizes: Vec<usize> = table5::paper_sizes()
        .into_iter()
        .map(|s| ((s as f64 * cfg.scale) as usize).max(1000))
        .collect();
    eprintln!(
        "table5: sizes {:?} ({} runs each; UDT_BENCH_SCALE={})",
        sizes, cfg.runs, cfg.scale
    );

    let table = table5::run(&sizes, cfg.runs, 42);
    println!("\n== Table 5: time (ms) of split selection on a single feature ==");
    println!("{}", table.render());
    println!("== Figure series (CSV) ==");
    println!("{}", table.to_csv());

    // Shape assertions (who wins, by what factor).
    let first = table5::measure(sizes[0], cfg.runs, 42);
    let last = table5::measure(*sizes.last().unwrap(), cfg.runs, 42);
    assert!(first.agree && last.agree, "engines must agree");
    assert!(
        last.generic_ms / last.superfast_ms > 20.0,
        "superfast should dominate at 100K (got {:.0}x)",
        last.generic_ms / last.superfast_ms
    );
    // Generic grows superlinearly vs superfast's linear growth.
    let generic_growth = last.generic_ms / first.generic_ms;
    let superfast_growth = last.superfast_ms / first.superfast_ms;
    assert!(
        generic_growth > 2.0 * superfast_growth,
        "generic growth {generic_growth:.1}x vs superfast {superfast_growth:.1}x"
    );
    eprintln!("table5: shape assertions passed");
}
