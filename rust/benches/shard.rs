//! Out-of-core sharded training vs in-memory binned training.
//!
//! Measures the three numbers the shard subsystem promises: (1) the
//! streaming CSV → shard-directory write rate (MB/s of source CSV), (2)
//! the wall-clock cost of training through bounded-RAM shard windows
//! relative to the same binned fit with the whole dataset resident, and
//! (3) the memory headline itself — `peak_shard_window_bytes` (the
//! largest decoded window ever resident) against the full in-memory
//! dataset footprint.
//!
//! Writes a machine-readable `BENCH_shard.json` at the repository root
//! so the out-of-core trajectory is tracked PR-over-PR alongside the
//! other BENCH_*.json artifacts.
//!
//!   cargo bench --bench shard
//!
//! UDT_BENCH_SCALE scales the row count (1.0 = 120k rows);
//! UDT_BENCH_RUNS the repetitions.

use udt::bench_support::{bench, write_bench_json, BenchConfig, Table};
use udt::data::csv::{load_csv_str, to_csv_string, CsvOptions};
use udt::data::shard::shard_csv_str;
use udt::data::synth::{generate_any, SynthSpec};
use udt::data::ShardedDataset;
use udt::tree::sharded::fit_sharded;
use udt::tree::{Backend, TrainConfig, Tree};
use udt::util::json::Json;

fn main() {
    let cfg = BenchConfig::from_env();
    let n_rows = ((120_000.0 * cfg.scale) as usize).max(4_000);
    let mut spec = SynthSpec::classification("shard_t6", n_rows, 12, 5);
    spec.cat_frac = 0.15;
    spec.hybrid_frac = 0.05;
    spec.missing_frac = 0.02;
    spec.noise = 0.05;
    spec.numeric_cardinality = (n_rows / 10).max(1_000);
    eprintln!(
        "shard bench: {n_rows} rows x 12 features, numeric cardinality {} \
         (UDT_BENCH_SCALE to change)",
        spec.numeric_cardinality
    );

    let csv = to_csv_string(&generate_any(&spec, 42));
    let csv_bytes = csv.len();
    let opts = CsvOptions::default();
    let dir = std::env::temp_dir().join(format!("udt-bench-shard-{}", std::process::id()));
    // 8 shards: windows genuinely cycle and the bins sidecar pass is
    // exercised shard by shard.
    let rows_per_shard = (n_rows / 8).max(1);

    // (1) Streaming shard write: CSV text → shard directory, never
    // materializing the dataset.
    let m_write = bench("shard_write", &cfg, || {
        let _ = std::fs::remove_dir_all(&dir);
        let manifest =
            shard_csv_str("shard_t6", &csv, &dir, &opts, rows_per_shard).expect("shard write");
        assert!(manifest.shards.len() >= 2);
    });
    let write_ms = m_write.min_ms();
    let write_mb_s = csv_bytes as f64 / 1e6 / (write_ms / 1e3).max(1e-9);

    let ds = load_csv_str("shard_t6", &csv, &opts).expect("parse csv");
    let sds = ShardedDataset::open(&dir).expect("open shards");
    let tc = TrainConfig {
        backend: Backend::Binned { max_bins: 256 },
        n_threads: 0,
        ..Default::default()
    };

    // Un-timed warmups: the sharded fit builds the bin sidecars once
    // (quantize once, fit many — the same contract as the in-memory
    // backend's dataset-level caches), the in-memory fit sorts + bins.
    let (_, shard_stats) = fit_sharded(&sds, &tc).expect("sharded fit");
    Tree::fit(&ds, &tc).expect("in-memory fit");

    // (2) Train wall-clock, both engines on identical bits.
    let m_shard = bench("train_sharded", &cfg, || {
        let (t, _) = fit_sharded(&sds, &tc).expect("sharded fit");
        assert!(t.n_nodes() >= 1);
    });
    let m_mem = bench("train_in_memory", &cfg, || {
        let t = Tree::fit(&ds, &tc).expect("in-memory fit");
        assert!(t.n_nodes() >= 1);
    });
    let shard_ms = m_shard.min_ms();
    let mem_ms = m_mem.min_ms();

    // (3) The memory headline.
    let dataset_bytes = ds.approx_bytes();
    let window_bytes = shard_stats.peak_shard_window_bytes;
    assert!(window_bytes > 0 && window_bytes < dataset_bytes);

    let mut table = Table::new(&[
        "case", "rows", "ms", "csv MB/s", "peak window(KiB)", "dataset(KiB)", "passes",
    ]);
    table.row(vec![
        "shard_write".into(),
        n_rows.to_string(),
        format!("{write_ms:.1}"),
        format!("{write_mb_s:.1}"),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);
    table.row(vec![
        "train_sharded".into(),
        n_rows.to_string(),
        format!("{shard_ms:.1}"),
        "-".into(),
        (window_bytes / 1024).to_string(),
        (dataset_bytes / 1024).to_string(),
        shard_stats.shard_passes.to_string(),
    ]);
    table.row(vec![
        "train_in_memory".into(),
        n_rows.to_string(),
        format!("{mem_ms:.1}"),
        "-".into(),
        "-".into(),
        (dataset_bytes / 1024).to_string(),
        "-".into(),
    ]);
    println!("\n== Out-of-core sharded vs in-memory binned training ({n_rows} rows) ==");
    println!("{}", table.render());

    let artifact = Json::obj(vec![
        ("bench", Json::Str("shard".into())),
        ("rows", Json::Num(n_rows as f64)),
        ("csv_bytes", Json::Num(csv_bytes as f64)),
        ("rows_per_shard", Json::Num(rows_per_shard as f64)),
        ("measured", Json::Bool(true)),
        (
            "cases",
            Json::Arr(vec![
                Json::obj(vec![
                    ("case", Json::Str("shard_write".into())),
                    ("ms", Json::Num(write_ms)),
                    ("csv_mb_per_sec", Json::Num(write_mb_s)),
                ]),
                Json::obj(vec![
                    ("case", Json::Str("train_sharded".into())),
                    ("ms", Json::Num(shard_ms)),
                    ("peak_shard_window_bytes", Json::Num(window_bytes as f64)),
                    ("dataset_bytes", Json::Num(dataset_bytes as f64)),
                    (
                        "window_over_dataset",
                        Json::Num(window_bytes as f64 / dataset_bytes as f64),
                    ),
                    ("shard_passes", Json::Num(shard_stats.shard_passes as f64)),
                ]),
                Json::obj(vec![
                    ("case", Json::Str("train_in_memory".into())),
                    ("ms", Json::Num(mem_ms)),
                    ("sharded_over_in_memory", Json::Num(shard_ms / mem_ms.max(1e-9))),
                ]),
            ]),
        ),
    ]);
    match write_bench_json("shard", &artifact) {
        Ok(p) => eprintln!("wrote {}", p.display()),
        Err(e) => eprintln!("could not write bench artifact: {e}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}
