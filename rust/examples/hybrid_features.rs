//! Hybrid features without pre-encoding — the paper's §2 headline.
//!
//! Builds a dataset whose columns mix numbers, category strings and
//! missing cells *in the same column*, trains UDT directly on it, and
//! contrasts the memory footprint with what one-hot encoding would need
//! (the paper: 39 GB one-hot vs ~90 MB for UDT on "credit card").
//!
//!     cargo run --release --example hybrid_features

use udt::data::csv::{load_csv_str, CsvOptions};
use udt::data::value::Value;
use udt::{Estimator, Udt};

fn main() -> udt::Result<()> {
    // A CSV with genuinely hybrid columns: "status" mixes numeric codes
    // and strings; "income" has missing cells. No encoding happens —
    // cells parse as numbers first, then as interned categoricals.
    let mut csv = String::from("age,income,status,label\n");
    let statuses = ["single", "married", "divorced"];
    for i in 0..3000u32 {
        let age = 20 + (i * 7) % 50;
        let income = if i % 11 == 0 {
            String::new() // missing
        } else {
            format!("{}", 20_000 + (i * 137) % 80_000)
        };
        // Hybrid column: mostly strings, sometimes a numeric code.
        let status = if i % 5 == 0 {
            format!("{}", i % 3) // numeric code
        } else {
            statuses[(i % 3) as usize].to_string()
        };
        let label = if (age > 40 && i % 3 == 0) || status == "married" {
            "approve"
        } else {
            "reject"
        };
        csv.push_str(&format!("{age},{income},{status},{label}\n"));
    }

    let ds = load_csv_str("hybrid", &csv, &CsvOptions::default())?;
    println!("column composition (numeric / categorical / missing):");
    for c in &ds.columns {
        let s = c.stats();
        println!("  {:8} {:5} / {:4} / {:4}", c.name, s.n_num, s.n_cat, s.n_missing);
    }

    let tree = Udt::builder().fit(&ds)?;
    println!(
        "\ntrained on hybrid data directly: {} nodes, depth {}, accuracy {:.3}",
        tree.n_nodes(),
        tree.depth,
        tree.accuracy(&ds)?
    );

    // Memory comparison vs one-hot encoding (every distinct categorical
    // value becomes a column of M doubles).
    let distinct_cats = ds.interner.len();
    let onehot_cols = ds.n_features() + distinct_cats;
    let onehot_bytes = ds.n_rows() * onehot_cols * 8;
    println!(
        "\nno-pre-encoding footprint: {:.2} MB | one-hot equivalent: {:.2} MB ({} extra columns)",
        ds.approx_bytes() as f64 / 1e6,
        onehot_bytes as f64 / 1e6,
        distinct_cats
    );

    // Missing values at prediction time route to the negative branch —
    // untouched, never imputed. The Estimator surface checks arity and
    // returns a typed error instead of panicking on bad requests.
    let p = tree.predict_row(&[Value::Num(55.0), Value::Missing, Value::Missing])?;
    println!("\nprediction with missing cells: {p:?}");
    Ok(())
}
