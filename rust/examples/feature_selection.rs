//! Superfast Selection as a feature-selection filter — the second
//! use-case in the paper's title. Ranks the 753 features of a
//! parkinson-shaped dataset by best-split gain, keeps the top 32, and
//! compares training time + accuracy of the filtered model against the
//! full-width one.
//!
//!     cargo run --release --example feature_selection

use udt::data::synth::{generate_classification, registry};
use udt::selection::feature_rank::{rank_features, top_k};
use udt::selection::heuristic::{ClassCriterion, Criterion};
use udt::tree::Tree;
use udt::util::timer::Timer;
use udt::Udt;

fn main() -> udt::Result<()> {
    // Parkinson shape: 765 examples × 753 features — the classic
    // feature-selection regime.
    let spec = registry::find("parkinson").unwrap().spec;
    let ds = generate_classification(&spec, 42);
    println!(
        "dataset: {} rows × {} features, {} classes",
        ds.n_rows(),
        ds.n_features(),
        ds.labels.n_classes()
    );

    let criterion = Criterion::Class(ClassCriterion::InfoGain);
    let t = Timer::start();
    let ranked = rank_features(&ds, criterion)?;
    println!(
        "\nranked all {} features in {:.1} ms (Superfast, one O(M + N·C) pass each)",
        ranked.len(),
        t.ms()
    );
    println!("top 5:");
    for f in ranked.iter().take(5) {
        println!("  {:12} gain={:.5}", f.name, f.gain);
    }

    let (train, _, test) = ds.split_indices(0.8, 0.1, 7);
    let cfg = Udt::builder().build()?;

    let t = Timer::start();
    let full = Tree::fit_rows(&ds, &train, &cfg)?;
    let full_ms = t.ms();
    let full_acc = full.accuracy_rows(&ds, &test)?;

    let (filtered, kept) = top_k(&ds, criterion, 32)?;
    let t = Timer::start();
    let slim = Tree::fit_rows(&filtered, &train, &cfg)?;
    let slim_ms = t.ms();
    let test_filtered = filtered.subset(&test);
    let all: Vec<u32> = (0..test_filtered.n_rows() as u32).collect();
    let slim_acc = slim.accuracy_rows(&test_filtered, &all)?;

    println!("\nfull  ({} features): train {:.0} ms, test acc {:.3}", ds.n_features(), full_ms, full_acc);
    println!(
        "top32 ({} features): train {:.0} ms ({:.1}× faster), test acc {:.3}",
        kept.len(),
        slim_ms,
        full_ms / slim_ms.max(0.001),
        slim_acc
    );
    Ok(())
}
