//! Regression with UDT: the paper's Algorithm 6 label-split strategy
//! (binarize targets at the best SSE threshold, then 2-class Superfast
//! Selection) versus classic direct-SSE CART, on a wine-quality-shaped
//! dataset.
//!
//!     cargo run --release --example regression

use udt::coordinator::metrics::RegReport;
use udt::data::synth::{generate_regression, registry};
use udt::tree::{RegStrategy, Tree};
use udt::util::timer::Timer;
use udt::Udt;

fn main() -> udt::Result<()> {
    let spec = registry::find("wine_quality").unwrap().spec;
    let ds = generate_regression(&spec, 42);
    let (train, _, test) = ds.split_indices(0.8, 0.1, 3);
    println!(
        "dataset: {} rows × {} features (regression)",
        ds.n_rows(),
        ds.n_features()
    );

    for (name, strategy) in [
        ("label-split (paper Alg. 6)", RegStrategy::LabelSplit),
        ("direct SSE (classic CART)", RegStrategy::DirectSse),
    ] {
        let cfg = Udt::builder().reg_strategy(strategy).build()?;
        let t = Timer::start();
        let tree = Tree::fit_rows(&ds, &train, &cfg)?;
        let ms = t.ms();
        let rep = RegReport::from_tree(&tree, &ds, &test)?;
        println!(
            "{name:28} {:6} nodes depth {:3} in {:7.1} ms | test MAE {:.3} RMSE {:.3} R² {:.3}",
            tree.n_nodes(),
            tree.depth,
            ms,
            rep.mae,
            rep.rmse,
            rep.r2
        );
    }
    Ok(())
}
