//! Serving demo: train a model, ship it as a `SavedModel`, start the TCP
//! prediction server, fire a burst of batched client requests, report
//! latency/throughput, shut down. All in one process (client threads ↔
//! server threads).
//!
//!     cargo run --release --example serve [--forest]

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::mpsc;
use udt::coordinator::serve::Server;
use udt::data::synth::{generate_classification, SynthSpec};
use udt::util::timer::Timer;
use udt::{Forest, Model, SavedModel, Udt};

fn main() -> udt::Result<()> {
    let want_forest = std::env::args().any(|a| a == "--forest");
    let mut spec = SynthSpec::classification("serve_demo", 20_000, 12, 4);
    spec.cat_frac = 0.3;
    let ds = generate_classification(&spec, 42);
    let model = if want_forest {
        Model::Forest(Forest::builder().n_trees(8).fit(&ds)?)
    } else {
        Model::SingleTree(Udt::builder().fit(&ds)?)
    };
    println!(
        "model: kind={} nodes={} — starting server",
        model.kind(),
        model.n_nodes()
    );

    // Compiles the model once; every request then runs on the flattened
    // inference tables (see `udt::inference`).
    let server = Server::new(SavedModel::new(model, &ds))?;
    let (tx, rx) = mpsc::channel();
    let server2 = server.clone();
    let server_thread = std::thread::spawn(move || {
        server2
            .serve("127.0.0.1:0", |addr| tx.send(addr).unwrap())
            .unwrap();
    });
    let addr = rx.recv().expect("server bound");
    println!("listening on {addr}");

    // Client burst: 4 connections × 50 batches × 64 rows.
    let n_clients = 4;
    let batches = 50;
    let batch_size = 64;
    let t = Timer::start();
    let mut handles = Vec::new();
    for client in 0..n_clients {
        let ds = ds.clone();
        handles.push(std::thread::spawn(move || -> udt::Result<f64> {
            let stream = TcpStream::connect(addr)?;
            let mut writer = stream.try_clone()?;
            let mut reader = BufReader::new(stream);
            let mut lat_ms = 0.0;
            for b in 0..batches {
                let mut req = String::from("[");
                for i in 0..batch_size {
                    let r = (client * 7919 + b * 131 + i) % ds.n_rows();
                    if i > 0 {
                        req.push(',');
                    }
                    req.push('[');
                    for (f, col) in ds.columns.iter().enumerate() {
                        if f > 0 {
                            req.push(',');
                        }
                        match col.get(r) {
                            udt::data::value::Value::Num(x) => req.push_str(&format!("{x}")),
                            udt::data::value::Value::Cat(c) => {
                                req.push_str(&format!("\"{}\"", ds.interner.name(c)))
                            }
                            udt::data::value::Value::Missing => req.push_str("null"),
                        }
                    }
                    req.push(']');
                }
                req.push_str("]\n");
                let t = Timer::start();
                writer.write_all(req.as_bytes())?;
                let mut line = String::new();
                reader.read_line(&mut line)?;
                lat_ms += t.ms();
                assert!(line.starts_with('['), "unexpected response: {line}");
            }
            Ok(lat_ms / batches as f64)
        }));
    }
    let mut mean_latency = 0.0;
    for h in handles {
        mean_latency += h.join().unwrap()?;
    }
    mean_latency /= n_clients as f64;
    let total = (n_clients * batches * batch_size) as f64;
    let wall_s = t.elapsed().as_secs_f64();
    println!(
        "{total} predictions in {:.2} s → {:.0} preds/s; mean batch latency {:.2} ms ({} rows/batch)",
        wall_s,
        total / wall_s,
        mean_latency,
        batch_size
    );

    // Shut down.
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(b"\"shutdown\"\n")?;
    let mut line = String::new();
    BufReader::new(stream).read_line(&mut line)?;
    server_thread.join().unwrap();
    println!("server stopped");
    Ok(())
}
