//! Training-Only-Once Tuning vs generic retraining — the paper's §4
//! churn-modeling comparison (tuning 227.5 settings: 10 ms once-tuned vs
//! 16.8 s retrained).
//!
//!     cargo run --release --example tuning_once

use udt::data::synth::{generate_classification, registry};
use udt::tree::tuning::{tune, tune_by_retraining, TuneGrid};
use udt::tree::Tree;
use udt::util::timer::Timer;
use udt::Udt;

fn main() -> udt::Result<()> {
    // Churn-modeling shape (10k × 10, 2 classes), with label noise so
    // tuning has something to do.
    let mut spec = registry::find("churn_modeling").unwrap().spec;
    spec.noise = 0.2;
    let ds = generate_classification(&spec, 42);
    let (train, val, test) = ds.split_indices(0.8, 0.1, 7);

    let cfg = Udt::builder().build()?;
    let t = Timer::start();
    let full = Tree::fit_rows(&ds, &train, &cfg)?;
    println!(
        "full tree: {} nodes, depth {}, trained in {:.0} ms",
        full.n_nodes(),
        full.depth,
        t.ms()
    );

    // Training-Only-Once Tuning: all settings from one trained tree.
    let grid = TuneGrid::default();
    let fast = tune(&full, &ds, &val, train.len(), &grid)?;
    println!(
        "training-once tuning: {} settings in {:.1} ms → depth {}, min_split {} (val acc {:.4})",
        fast.n_settings, fast.tune_ms, fast.best_max_depth, fast.best_min_split, fast.best_metric
    );

    // Generic tuning: one full retraining per setting. Use a reduced grid
    // to keep the demo short, then scale the comparison to the full grid.
    let small_grid = TuneGrid {
        min_split_steps: 10,
        ..Default::default()
    };
    let slow = tune_by_retraining(&ds, &train, &val, &cfg, full.depth as usize, &small_grid)?;
    let per_setting = slow.tune_ms / slow.n_settings as f64;
    println!(
        "generic tuning: {} settings in {:.0} ms ({:.1} ms/setting) → projected {:.1} s for the full {}-setting grid",
        slow.n_settings,
        slow.tune_ms,
        per_setting,
        per_setting * fast.n_settings as f64 / 1000.0,
        fast.n_settings
    );
    println!(
        "speedup at equal grids: {:.0}×",
        per_setting * fast.n_settings as f64 / fast.tune_ms
    );

    // Both tuners should pick settings of comparable validation quality.
    let pruned = udt::tree::prune::prune(&full, fast.best_max_depth, fast.best_min_split);
    println!(
        "tuned tree: {} nodes, depth {}, test accuracy {:.4} (full tree: {:.4})",
        pruned.n_nodes(),
        pruned.depth,
        pruned.accuracy_rows(&ds, &test)?,
        full.accuracy_rows(&ds, &test)?
    );
    Ok(())
}
