//! End-to-end driver — proves all layers compose on the paper's headline
//! workload: a KDD99-10%-shaped dataset (494,020 × 41, 23 classes).
//!
//! The paper's claim (§Abstract): single training < 1 s, Training-Only-
//! Once Tuning of ~215 settings < 0.25 s, on a laptop. This driver runs
//! the full system — synthetic substrate → parallel UDT training →
//! once-tuning → pruning → test evaluation → any-model serving — and,
//! when AOT artifacts are present (and the `xla` feature is on), a
//! three-layer XLA spot-check of the root split.
//!
//!     cargo run --release --example end_to_end [scale]
//!
//! `scale` defaults to 1.0 (the full 494k rows); pass 0.1 for a fast run.

use udt::coordinator::pipeline::{run_pipeline_model, Quality};
use udt::coordinator::serve::Server;
use udt::data::synth::{generate_any, registry};
use udt::tree::tuning::TuneGrid;
use udt::util::timer::Timer;
use udt::{SavedModel, Udt};

fn main() -> udt::Result<()> {
    let scale: f64 = std::env::args()
        .nth(1)
        .map(|s| s.parse().expect("scale must be a number"))
        .unwrap_or(1.0);

    let entry = registry::find("kdd99_10").unwrap();
    println!(
        "=== UDT end-to-end driver: kdd99-10% shape (scale {scale}) ===\n\
         paper reference: train 977 ms, tune 245 ms, acc 1.0 (Table 6)\n"
    );

    let t = Timer::start();
    let ds = generate_any(&entry.spec.scaled(scale), 42);
    println!(
        "[1/5] dataset: {} rows × {} features, {} classes, ~{:.0} MB ({:.1} s to generate)",
        ds.n_rows(),
        ds.n_features(),
        ds.labels.n_classes(),
        ds.approx_bytes() as f64 / 1e6,
        t.elapsed().as_secs_f64()
    );

    // Full pipeline with all cores; the tuned artifact comes back as a
    // servable Model::TunedTree.
    let cfg = Udt::builder().threads(0).build()?;
    let (rep, model) = run_pipeline_model(&ds, &cfg, &TuneGrid::default(), 1)?;
    println!(
        "[2/5] full tree: {} nodes, depth {} — trained in {:.0} ms {}",
        rep.full_nodes,
        rep.full_depth,
        rep.full_train_ms,
        if rep.full_train_ms < 1000.0 * scale.max(0.2) {
            "(within the paper's <1 s band)"
        } else {
            ""
        }
    );
    println!(
        "[3/5] training-only-once tuning: {} settings in {:.1} ms → max_depth={}, min_split={}",
        rep.n_settings, rep.tune_ms, rep.best_max_depth, rep.best_min_split
    );
    let acc = match rep.quality {
        Quality::Accuracy(a) => a,
        _ => unreachable!(),
    };
    println!(
        "[4/5] tuned tree: {} nodes, depth {} — test accuracy {:.4}",
        rep.tuned_nodes, rep.tuned_depth, acc
    );

    // Serving spot check: the *tuned* model (caps baked into the
    // compiled tables) answers a prediction request through the server.
    let server = Server::new(SavedModel::new(model, &ds))?;
    let row = ds.row(0);
    let cells: Vec<String> = row
        .iter()
        .map(|v| match v {
            udt::data::value::Value::Num(x) => format!("{x}"),
            udt::data::value::Value::Cat(c) => format!("\"{}\"", ds.interner.name(*c)),
            udt::data::value::Value::Missing => "null".into(),
        })
        .collect();
    let resp = server.handle(&format!("[{}]", cells.join(",")));
    println!("[5/5] serving (tuned tree): row 0 → {resp}");

    // Optional three-layer spot check via the AOT artifacts.
    if let Some(xla) =
        udt::runtime::xla_split::XlaSelection::load_default(Default::default())
    {
        use udt::selection::heuristic::{ClassCriterion, Criterion};
        use udt::selection::superfast::{FeatureView, LabelsView, Scratch};
        let rows: Vec<u32> = (0..ds.n_rows().min(30_000) as u32).collect();
        let (all_rows, all_vals) = ds.columns[0].sorted_numeric();
        let mut sorted = (Vec::new(), Vec::new());
        for (r, v) in all_rows.into_iter().zip(all_vals) {
            if (r as usize) < rows.len() {
                sorted.0.push(r);
                sorted.1.push(v);
            }
        }
        let view = FeatureView::new(0, &ds.columns[0], &rows, &sorted.0, &sorted.1);
        let lv = LabelsView::from_labels(&ds.labels);
        let mut scratch = Scratch::new();
        let crit = Criterion::Class(ClassCriterion::InfoGain);
        let a = xla.best_split_on_feat(&view, &lv, crit, &mut scratch);
        let b = udt::selection::superfast::best_split_on_feat(&view, &lv, crit);
        println!(
            "[xla]  root-split spot check: xla={:?} native={:?}",
            a.map(|s| (s.op, (s.score * 1e4).round() / 1e4)),
            b.map(|s| (s.op, (s.score * 1e4).round() / 1e4)),
        );
    } else {
        println!("[xla]  artifacts not built — skipping three-layer spot check");
    }

    println!("\n=== end-to-end complete ===");
    Ok(())
}
