//! Quickstart: generate a small tabular dataset, train through the
//! fluent builder, tune once, prune, and evaluate — the whole paper
//! pipeline in ~30 lines.
//!
//!     cargo run --release --example quickstart

use udt::coordinator::pipeline::{run_pipeline, Quality};
use udt::data::synth::{generate_classification, SynthSpec};
use udt::tree::tuning::TuneGrid;
use udt::Udt;

fn main() -> udt::Result<()> {
    // 20k examples, 10 features (mixed numeric/categorical/missing), 3 classes.
    let mut spec = SynthSpec::classification("quickstart", 20_000, 10, 3);
    spec.noise = 0.08;
    let ds = generate_classification(&spec, 42);
    println!(
        "dataset: {} rows × {} features, {} classes (~{:.1} MB)",
        ds.n_rows(),
        ds.n_features(),
        ds.labels.n_classes(),
        ds.approx_bytes() as f64 / 1e6
    );

    // The builder validates before training: bad settings are typed
    // errors, not panics.
    let cfg = Udt::builder().threads(0).build()?;
    let report = run_pipeline(&ds, &cfg, &TuneGrid::default(), 1)?;
    println!(
        "full tree:  {} nodes, depth {}, trained in {:.1} ms",
        report.full_nodes, report.full_depth, report.full_train_ms
    );
    println!(
        "tuning:     {} hyper-parameter settings evaluated in {:.2} ms (training-only-once)",
        report.n_settings, report.tune_ms
    );
    println!(
        "tuned tree: {} nodes, depth {} (max_depth={}, min_split={})",
        report.tuned_nodes, report.tuned_depth, report.best_max_depth, report.best_min_split
    );
    match report.quality {
        Quality::Accuracy(acc) => println!("test accuracy: {acc:.4}"),
        Quality::Regression { mae, rmse } => println!("test MAE {mae:.3} RMSE {rmse:.3}"),
    }
    Ok(())
}
