//! The three layers composing: split selection through the AOT-compiled
//! JAX/Pallas artifacts (L1 kernels → L2 graph → L3 Rust via PJRT).
//!
//! Requires `make artifacts` and the `xla` cargo feature first. Trains
//! the same tree with the native Superfast engine and with the XLA
//! backend, comparing results and timing. Without artifacts (or without
//! the feature) it exits with a notice.
//!
//!     make artifacts && cargo run --release --features xla --example xla_split

use std::sync::Arc;
use udt::data::synth::{generate_classification, SynthSpec};
use udt::runtime::xla_split::{XlaSelection, XlaSelectionConfig};
use udt::tree::Backend;
use udt::util::timer::Timer;
use udt::Udt;

fn main() -> udt::Result<()> {
    let Some(xla_sel) = XlaSelection::load_default(XlaSelectionConfig::default()) else {
        eprintln!(
            "artifacts not found — run `make artifacts` and build with `--features xla`"
        );
        std::process::exit(2);
    };

    // ≤128 distinct numeric values per feature → quantile binning is
    // exact and both backends score identical candidate sets.
    let mut spec = SynthSpec::classification("xla_demo", 30_000, 8, 4);
    spec.numeric_cardinality = 128;
    let ds = generate_classification(&spec, 42);

    let t = Timer::start();
    let native = Udt::builder().fit(&ds)?;
    let native_ms = t.ms();

    let t = Timer::start();
    let accel = Udt::builder()
        .backend(Backend::Xla(Arc::new(xla_sel)))
        .fit(&ds)?;
    let accel_ms = t.ms();

    println!(
        "native engine: {} nodes, depth {}, acc {:.4}, {:.0} ms",
        native.n_nodes(),
        native.depth,
        native.accuracy(&ds)?,
        native_ms
    );
    println!(
        "xla backend:   {} nodes, depth {}, acc {:.4}, {:.0} ms",
        accel.n_nodes(),
        accel.depth,
        accel.accuracy(&ds)?,
        accel_ms
    );
    println!(
        "note: on CPU the XLA path pays per-call PJRT overhead; its value is\n\
         demonstrating the AOT pipeline (the same artifacts compile for TPU,\n\
         where the [B,C] histogram matmul hits the MXU — DESIGN.md §8)."
    );
    Ok(())
}
