//! The `udt-analyze` rule set: project unsafe-hygiene invariants
//! checked against [masked](super::lexer) source.
//!
//! | rule id          | invariant                                                        |
//! |------------------|------------------------------------------------------------------|
//! | `safety-comment` | every `unsafe` occurrence is preceded by a `SAFETY:` comment     |
//! | `thread-spawn`   | no `thread::spawn`/`scope`/`Builder` outside `runtime/pool.rs`   |
//! | `no-unwrap`      | no `.unwrap()` / `.expect(` / `panic!` in library code           |
//! | `as-truncation`  | no narrowing `as` casts in the byte-level decoders               |
//! | `waiver-syntax`  | every `ANALYZE-ALLOW` comment parses and names a known rule      |
//!
//! Findings can be waived in-source with
//! `ANALYZE-ALLOW(no-unwrap): slice length pinned by take()` — a `//`
//! comment that *begins* with the marker (mid-prose mentions, like the
//! ones in this paragraph, are ignored), names the rule and gives a
//! non-empty reason. A waiver on line *L* covers findings on lines *L*
//! and *L + 1*, so it works both trailing on the offending line and on
//! its own line directly above. Waivers are counted and reported,
//! never silent; `waiver-syntax` findings cannot themselves be waived.
//!
//! Scope rules:
//! * `no-unwrap` and `thread-spawn` apply to **library code** only:
//!   files under `tests/`, `benches/`, `examples/`, files named
//!   `main.rs`, and `#[cfg(test)]` spans inside library files are
//!   exempt.
//! * `as-truncation` applies only to the byte-level decoder files
//!   (`data/shard/format.rs`, `coordinator/reactor/sys.rs`) where a
//!   silent truncation corrupts on-disk or kernel data.
//! * `safety-comment` applies everywhere — an unsound `unsafe` in a
//!   bench corrupts memory just as well as one in `src/`.

use super::lexer::{mask, Comment};

/// Rule identifiers, stable across releases; `Rule::id` is the string
/// used in findings, waivers and the CLI summary.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Rule {
    SafetyComment,
    ThreadSpawn,
    NoUnwrap,
    AsTruncation,
    WaiverSyntax,
}

impl Rule {
    pub fn id(self) -> &'static str {
        match self {
            Rule::SafetyComment => "safety-comment",
            Rule::ThreadSpawn => "thread-spawn",
            Rule::NoUnwrap => "no-unwrap",
            Rule::AsTruncation => "as-truncation",
            Rule::WaiverSyntax => "waiver-syntax",
        }
    }

    /// All rules, in reporting order.
    pub fn all() -> [Rule; 5] {
        [
            Rule::SafetyComment,
            Rule::ThreadSpawn,
            Rule::NoUnwrap,
            Rule::AsTruncation,
            Rule::WaiverSyntax,
        ]
    }
}

/// Rule ids a waiver may name (`waiver-syntax` is deliberately absent:
/// a malformed waiver cannot be waived by another waiver).
pub const WAIVABLE: [&str; 4] = [
    "safety-comment",
    "thread-spawn",
    "no-unwrap",
    "as-truncation",
];

/// One unwaived violation at `line` (1-based) of the analyzed file.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: Rule,
    pub line: usize,
    pub message: String,
}

/// One parsed `ANALYZE-ALLOW` comment. `used` is set when it absorbed
/// at least one finding; unused waivers are reported (stale waivers
/// rot) but are not failures.
#[derive(Debug, Clone)]
pub struct Waiver {
    pub line: usize,
    pub rule: String,
    pub reason: String,
    pub used: bool,
}

/// Everything the rules produced for one file: surviving findings
/// (line-sorted) plus every waiver encountered.
#[derive(Debug, Clone)]
pub struct FileAnalysis {
    pub findings: Vec<Finding>,
    pub waivers: Vec<Waiver>,
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// True when the `pat` occurrence at byte `off` in `code` sits on
/// identifier boundaries (so `unsafe` does not fire inside
/// `unsafe_marker`, nor `as u8` inside `as u816`).
fn on_word_boundary(code: &str, off: usize, pat: &str) -> bool {
    let bytes = code.as_bytes();
    let before_ok = off == 0 || !is_ident(bytes[off - 1]);
    let end = off + pat.len();
    let after_ok = end >= bytes.len() || !is_ident(bytes[end]);
    before_ok && after_ok
}

/// Byte offsets where each line of `code` starts; `line_of` maps a
/// byte offset back to its 1-based line via binary search.
fn line_starts(code: &str) -> Vec<usize> {
    let mut starts = vec![0usize];
    for (i, b) in code.bytes().enumerate() {
        if b == b'\n' {
            starts.push(i + 1);
        }
    }
    starts
}

fn line_of(starts: &[usize], off: usize) -> usize {
    match starts.binary_search(&off) {
        Ok(i) => i + 1,
        Err(i) => i,
    }
}

/// Per-line flags computed from path + masked source, shared by every
/// rule so the exemption logic exists exactly once.
struct FileContext {
    /// Masked code (comments/literal contents blanked).
    code: String,
    /// Masked code split by line (index 0 = line 1).
    lines: Vec<String>,
    starts: Vec<usize>,
    comments: Vec<Comment>,
    /// `comment_cover[l]` / `safety_cover[l]`: 1-based line `l` is
    /// covered by a comment / by a comment carrying a SAFETY marker.
    comment_cover: Vec<bool>,
    safety_cover: Vec<bool>,
    /// Lines inside a `#[cfg(test)]` item span.
    test_line: Vec<bool>,
    /// File-level exemptions derived from the path.
    lib_code: bool,
    decoder_file: bool,
    pool_file: bool,
}

impl FileContext {
    fn new(rel_path: &str, src: &str) -> FileContext {
        let masked = mask(src);
        let lines: Vec<String> = masked.code.split('\n').map(|l| l.to_string()).collect();
        let n_lines = lines.len();
        let starts = line_starts(&masked.code);

        let mut comment_cover = vec![false; n_lines + 2];
        let mut safety_cover = vec![false; n_lines + 2];
        for c in &masked.comments {
            let span = c.text.matches('\n').count();
            let has_safety = c.text.contains("SAFETY") || c.text.contains("# Safety");
            for l in c.line..=(c.line + span).min(n_lines) {
                comment_cover[l] = true;
                if has_safety {
                    safety_cover[l] = true;
                }
            }
        }

        // Normalize so `/tests/` matches whether the relative path is
        // `tests/foo.rs` or `rust/tests/foo.rs`, on any separator.
        let p = format!("/{}", rel_path.replace('\\', "/"));
        let lib_code = !(p.contains("/tests/")
            || p.contains("/benches/")
            || p.contains("/examples/")
            || p.ends_with("/main.rs"));
        let decoder_file =
            p.ends_with("data/shard/format.rs") || p.ends_with("coordinator/reactor/sys.rs");
        let pool_file = p.ends_with("runtime/pool.rs");

        let mut ctx = FileContext {
            code: masked.code,
            lines,
            starts,
            comments: masked.comments,
            comment_cover,
            safety_cover,
            test_line: vec![false; n_lines + 2],
            lib_code,
            decoder_file,
            pool_file,
        };
        ctx.mark_test_spans();
        ctx
    }

    /// Mark every line belonging to a `#[cfg(test)]` item. The span
    /// runs from the attribute to the matching `}` of the first brace
    /// that follows it (or the first top-level `;` for a braceless
    /// item). Brace matching on masked code is exact: comment and
    /// string braces are already blanked.
    fn mark_test_spans(&mut self) {
        let bytes = self.code.as_bytes();
        let n_lines = self.lines.len();
        let occurrences: Vec<usize> = self
            .code
            .match_indices("#[cfg(test)]")
            .map(|(off, _)| off)
            .collect();
        for off in occurrences {
            let start_line = line_of(&self.starts, off);
            let mut i = off + "#[cfg(test)]".len();
            let mut end_line = start_line;
            // Find the item's first `{` or a terminating `;`.
            while i < bytes.len() {
                match bytes[i] {
                    b'{' => {
                        let mut depth = 1usize;
                        i += 1;
                        while i < bytes.len() && depth > 0 {
                            match bytes[i] {
                                b'{' => depth += 1,
                                b'}' => depth -= 1,
                                _ => {}
                            }
                            i += 1;
                        }
                        end_line = line_of(&self.starts, i.saturating_sub(1));
                        break;
                    }
                    b';' => {
                        end_line = line_of(&self.starts, i);
                        break;
                    }
                    _ => i += 1,
                }
            }
            if i >= bytes.len() {
                end_line = n_lines;
            }
            for l in start_line..=end_line.min(n_lines) {
                self.test_line[l] = true;
            }
        }
    }

    fn masked_line(&self, l: usize) -> &str {
        if l >= 1 && l <= self.lines.len() {
            &self.lines[l - 1]
        } else {
            ""
        }
    }

    /// The `safety-comment` satisfaction walk: a SAFETY comment on the
    /// `unsafe` line itself, or reachable upward through lines that are
    /// comment-only, blank, or attribute-only. The first code line
    /// stops the walk.
    fn safety_reachable(&self, line: usize) -> bool {
        if self.safety_cover[line] {
            return true;
        }
        let mut l = line;
        while l > 1 {
            l -= 1;
            if self.safety_cover[l] {
                return true;
            }
            let t = self.masked_line(l).trim();
            let pass_through = t.is_empty() || t.starts_with("#[") || t.starts_with("#!");
            if !pass_through {
                return false;
            }
        }
        false
    }
}

/// Run every rule over one file. `rel_path` is workspace-relative with
/// `/` separators (used only for exemption matching and messages —
/// the caller prefixes it onto findings when rendering).
pub fn check_file(rel_path: &str, src: &str) -> FileAnalysis {
    let ctx = FileContext::new(rel_path, src);
    let mut findings: Vec<Finding> = Vec::new();
    let mut waivers: Vec<Waiver> = Vec::new();

    // ---- waiver-syntax: parse every ANALYZE-ALLOW comment first ----
    // A waiver must *begin* its comment (after doc-comment sigils and
    // whitespace); a mid-prose mention of the marker is documentation,
    // not a waiver, and is ignored entirely.
    for c in &ctx.comments {
        let t = c.text.trim_start_matches(['/', '!', '*', ' ', '\t']);
        if t.starts_with("ANALYZE-ALLOW") {
            match parse_waiver(t) {
                Ok((rule, reason)) => waivers.push(Waiver {
                    line: c.line,
                    rule,
                    reason,
                    used: false,
                }),
                Err(why) => findings.push(Finding {
                    rule: Rule::WaiverSyntax,
                    line: c.line,
                    message: format!("malformed ANALYZE-ALLOW waiver: {why}"),
                }),
            }
        }
    }

    // ---- safety-comment: every `unsafe` needs a reachable SAFETY ----
    let mut flagged_lines: Vec<usize> = Vec::new();
    for (off, _) in ctx.code.match_indices("unsafe") {
        if !on_word_boundary(&ctx.code, off, "unsafe") {
            continue;
        }
        let line = line_of(&ctx.starts, off);
        if flagged_lines.contains(&line) {
            continue; // one finding per line even if `unsafe` repeats
        }
        if !ctx.safety_reachable(line) {
            flagged_lines.push(line);
            findings.push(Finding {
                rule: Rule::SafetyComment,
                line,
                message: "`unsafe` without a preceding SAFETY comment".to_string(),
            });
        }
    }

    // ---- thread-spawn: raw thread primitives live in the pool only ----
    if ctx.lib_code && !ctx.pool_file {
        for pat in ["thread::spawn", "thread::scope", "thread::Builder"] {
            for (off, _) in ctx.code.match_indices(pat) {
                if !on_word_boundary(&ctx.code, off, pat) {
                    continue;
                }
                let line = line_of(&ctx.starts, off);
                if ctx.test_line[line] {
                    continue;
                }
                findings.push(Finding {
                    rule: Rule::ThreadSpawn,
                    line,
                    message: format!(
                        "`{pat}` outside runtime/pool.rs (route work through runtime::pool)"
                    ),
                });
            }
        }
    }

    // ---- no-unwrap: library code returns UdtError, it doesn't panic ----
    if ctx.lib_code {
        for pat in [".unwrap()", ".expect(", "panic!"] {
            for (off, _) in ctx.code.match_indices(pat) {
                // `.expect(`/`.unwrap()` start with `.` so the leading
                // boundary is inherent; `panic!` needs the ident check
                // (and its trailing `!`/`(` is a natural boundary).
                let bytes = ctx.code.as_bytes();
                if pat == "panic!" && off > 0 && is_ident(bytes[off - 1]) {
                    continue;
                }
                let line = line_of(&ctx.starts, off);
                if ctx.test_line[line] {
                    continue;
                }
                findings.push(Finding {
                    rule: Rule::NoUnwrap,
                    line,
                    message: format!("`{pat}` in library code (return a typed UdtError)"),
                });
            }
        }
    }

    // ---- as-truncation: byte-level decoders must not narrow silently ----
    if ctx.decoder_file {
        for pat in ["as u8", "as u16", "as u32", "as i8", "as i16", "as i32"] {
            for (off, _) in ctx.code.match_indices(pat) {
                if !on_word_boundary(&ctx.code, off, pat) {
                    continue;
                }
                let line = line_of(&ctx.starts, off);
                if ctx.test_line[line] {
                    continue;
                }
                findings.push(Finding {
                    rule: Rule::AsTruncation,
                    line,
                    message: format!("narrowing `{pat}` cast in a byte-level decoder"),
                });
            }
        }
    }

    // ---- apply waivers: a waiver on line L covers L and L + 1 ----
    findings.retain(|f| {
        if f.rule == Rule::WaiverSyntax {
            return true;
        }
        for w in waivers.iter_mut() {
            if w.rule == f.rule.id() && (w.line == f.line || w.line + 1 == f.line) {
                w.used = true;
                return false;
            }
        }
        true
    });

    findings.sort_by_key(|f| (f.line, f.rule.id()));
    FileAnalysis { findings, waivers }
}

/// Parse a comment that begins with the waiver marker. Returns
/// `(rule, reason)` or a diagnostic for the `waiver-syntax` finding.
fn parse_waiver(text: &str) -> Result<(String, String), String> {
    let rest = &text["ANALYZE-ALLOW".len()..];
    let rest = match rest.strip_prefix('(') {
        Some(r) => r,
        None => return Err("expected `(` after ANALYZE-ALLOW".to_string()),
    };
    let close = match rest.find(')') {
        Some(i) => i,
        None => return Err("unclosed `(` in ANALYZE-ALLOW".to_string()),
    };
    let rule = rest[..close].trim().to_string();
    if !WAIVABLE.contains(&rule.as_str()) {
        return Err(format!(
            "unknown or unwaivable rule `{rule}` (waivable: {})",
            WAIVABLE.join(", ")
        ));
    }
    let after = &rest[close + 1..];
    let after = match after.trim_start().strip_prefix(':') {
        Some(r) => r,
        None => match after.strip_prefix(':') {
            Some(r) => r,
            None => return Err("expected `: reason` after ANALYZE-ALLOW(rule)".to_string()),
        },
    };
    // Reason runs to end-of-line: a waiver inside a multi-line block
    // comment covers its own line, not the whole comment.
    let reason = after.lines().next().unwrap_or("").trim().to_string();
    if reason.is_empty() {
        return Err("empty reason in ANALYZE-ALLOW waiver".to_string());
    }
    Ok((rule, reason))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn findings_of(path: &str, src: &str) -> Vec<(String, usize)> {
        check_file(path, src)
            .findings
            .iter()
            .map(|f| (f.rule.id().to_string(), f.line))
            .collect()
    }

    #[test]
    fn undocumented_unsafe_is_flagged_documented_is_not() {
        let bad = "fn f() {\n    unsafe { g() }\n}\n";
        assert_eq!(findings_of("src/a.rs", bad), vec![("safety-comment".into(), 2)]);
        let good = "fn f() {\n    // SAFETY: g has no preconditions here\n    unsafe { g() }\n}\n";
        assert!(findings_of("src/a.rs", good).is_empty());
    }

    #[test]
    fn safety_walk_passes_attributes_blanks_and_doc_comments() {
        let src = "/// Does a thing.\n///\n/// # Safety\n/// caller upholds X\n#[inline]\npub unsafe fn f() {}\n";
        assert!(findings_of("src/a.rs", src).is_empty());
    }

    #[test]
    fn safety_walk_stops_at_code() {
        let src = "// SAFETY: stale, detached\nlet x = 1;\nunsafe { g() }\n";
        assert_eq!(findings_of("src/a.rs", src), vec![("safety-comment".into(), 3)]);
    }

    #[test]
    fn thread_spawn_flagged_outside_pool_only() {
        let src = "fn f() { std::thread::spawn(|| {}); }\n";
        assert_eq!(findings_of("src/coordinator/x.rs", src), vec![("thread-spawn".into(), 1)]);
        assert!(findings_of("src/runtime/pool.rs", src).is_empty());
        assert!(findings_of("tests/x.rs", src).is_empty());
    }

    #[test]
    fn unwrap_flagged_in_lib_exempt_in_tests_benches_main_and_cfg_test() {
        let src = "fn f() { x.unwrap(); }\n";
        assert_eq!(findings_of("src/a.rs", src), vec![("no-unwrap".into(), 1)]);
        assert!(findings_of("tests/a.rs", src).is_empty());
        assert!(findings_of("benches/a.rs", src).is_empty());
        assert!(findings_of("src/main.rs", src).is_empty());
        let gated = "fn f() -> Option<u8> { None }\n#[cfg(test)]\nmod tests {\n    fn g() { super::f().unwrap(); }\n}\n";
        assert!(findings_of("src/a.rs", gated).is_empty());
    }

    #[test]
    fn expect_and_panic_are_flagged_but_not_lookalikes() {
        let src = "fn f() { x.expect(\"boom\"); panic!(\"no\"); }\n";
        let got = findings_of("src/a.rs", src);
        assert_eq!(got.len(), 2);
        let fine = "fn f() { p.expect_lit(\"x\"); set_panic_on = 1; }\n";
        assert!(findings_of("src/a.rs", fine).is_empty());
    }

    #[test]
    fn as_truncation_only_in_decoder_files_and_not_widening() {
        let src = "fn f(x: u64) -> u32 { x as u32 }\n";
        assert_eq!(
            findings_of("src/data/shard/format.rs", src),
            vec![("as-truncation".into(), 1)]
        );
        assert!(findings_of("src/tree/builder.rs", src).is_empty());
        let wide = "fn f(x: u8) -> usize { x as usize }\n";
        assert!(findings_of("src/data/shard/format.rs", wide).is_empty());
    }

    #[test]
    fn waivers_cover_same_and_next_line_and_are_marked_used() {
        let trailing =
            "fn f() { x.unwrap(); } // ANALYZE-ALLOW(no-unwrap): invariant documented here\n";
        let r = check_file("src/a.rs", trailing);
        assert!(r.findings.is_empty());
        assert!(r.waivers[0].used);
        let above = "// ANALYZE-ALLOW(no-unwrap): invariant documented here\nfn f() { x.unwrap(); }\n";
        let r = check_file("src/a.rs", above);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
        // Two lines below: out of the waiver window.
        let far = "// ANALYZE-ALLOW(no-unwrap): too far away\nfn g() {}\nfn f() { x.unwrap(); }\n";
        let r = check_file("src/a.rs", far);
        assert_eq!(r.findings.len(), 1);
        assert!(!r.waivers[0].used);
    }

    #[test]
    fn malformed_waivers_are_findings() {
        for bad in [
            "fn f() {} // ANALYZE-ALLOW: no parens\n",
            "fn f() {} // ANALYZE-ALLOW(not-a-rule): reason\n",
            "fn f() {} // ANALYZE-ALLOW(no-unwrap):\n",
            "fn f() {} // ANALYZE-ALLOW(waiver-syntax): cannot waive the waiver rule\n",
        ] {
            let got = findings_of("src/a.rs", bad);
            assert_eq!(got.len(), 1, "{bad:?} -> {got:?}");
            assert_eq!(got[0].0, "waiver-syntax");
        }
    }

    #[test]
    fn violations_inside_string_literals_are_invisible() {
        let src = "fn f() { log(\"unsafe x.unwrap() panic! thread::spawn\"); }\n";
        assert!(findings_of("src/a.rs", src).is_empty());
    }
}
