//! `udt-analyze` — the project's zero-dependency source lint.
//!
//! The crate's hot path rides on an unsafe concurrency core
//! ([`crate::runtime::pool`]'s lifetime-erased job refs and
//! `UnsafeCell` result slots, [`crate::coordinator::reactor`]'s raw
//! syscalls and `repr(C, packed)` kernel structs). This module is the
//! static third of the correctness tooling that keeps that core honest
//! (the dynamic third is the cfg-gated race witness in
//! `runtime::pool::check`; the compile-time third is the `const`
//! layout assertions in `coordinator/reactor/sys.rs`):
//!
//! * [`lexer`] masks Rust source — comments and literal contents
//!   blanked, line structure preserved — with no external parser;
//! * [`rules`] enforces the unsafe-hygiene invariants (see its table)
//!   over the masked text and applies `ANALYZE-ALLOW` waivers;
//! * this module walks the source tree, aggregates per-file results
//!   into a [`TreeReport`], and renders the `file:line: [rule] msg`
//!   listing behind `udt analyze`.
//!
//! Run it locally with `cargo run --release -- analyze`; CI runs the
//! same command as a blocking gate. Exit is non-zero iff any unwaived
//! finding survives.

pub mod lexer;
pub mod rules;

pub use rules::{check_file, FileAnalysis, Finding, Rule, Waiver};

use crate::error::{Result, UdtError};
use std::path::{Path, PathBuf};

/// One analyzed file: its workspace-relative path (`/`-separated) and
/// what the rules produced for it.
#[derive(Debug, Clone)]
pub struct FileReport {
    pub path: String,
    pub analysis: FileAnalysis,
}

/// The whole tree's results, in sorted path order.
#[derive(Debug, Clone, Default)]
pub struct TreeReport {
    pub files: Vec<FileReport>,
}

impl TreeReport {
    /// Total unwaived findings across every file.
    pub fn total_findings(&self) -> usize {
        self.files.iter().map(|f| f.analysis.findings.len()).sum()
    }

    /// `(rule id, used waiver count)` for every rule with at least one
    /// used waiver, in [`Rule::all`] order.
    pub fn waiver_counts(&self) -> Vec<(&'static str, usize)> {
        Rule::all()
            .iter()
            .filter_map(|r| {
                let n = self
                    .files
                    .iter()
                    .flat_map(|f| f.analysis.waivers.iter())
                    .filter(|w| w.used && w.rule == r.id())
                    .count();
                if n > 0 {
                    Some((r.id(), n))
                } else {
                    None
                }
            })
            .collect()
    }

    /// Waivers that absorbed no finding — stale, worth deleting.
    pub fn unused_waivers(&self) -> Vec<(String, usize, String)> {
        let mut out = Vec::new();
        for f in &self.files {
            for w in &f.analysis.waivers {
                if !w.used {
                    out.push((f.path.clone(), w.line, w.rule.clone()));
                }
            }
        }
        out
    }

    /// Human-readable listing: one `path:line: [rule] message` per
    /// finding, then the waiver summary. Stable ordering (paths sorted
    /// by the walker, findings line-sorted per file) so CI diffs are
    /// meaningful.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for f in &self.files {
            for finding in &f.analysis.findings {
                out.push_str(&format!(
                    "{}:{}: [{}] {}\n",
                    f.path,
                    finding.line,
                    finding.rule.id(),
                    finding.message
                ));
            }
        }
        let n_files = self.files.len();
        let n_findings = self.total_findings();
        out.push_str(&format!(
            "udt-analyze: {} file(s) scanned, {} finding(s)\n",
            n_files, n_findings
        ));
        for (rule, n) in self.waiver_counts() {
            out.push_str(&format!("  waived [{rule}]: {n}\n"));
        }
        for (path, line, rule) in self.unused_waivers() {
            out.push_str(&format!("  unused waiver at {path}:{line} [{rule}]\n"));
        }
        out
    }
}

/// Analyze one in-memory source file (the test-fixture entry point —
/// identical rule behavior to the tree walk).
pub fn analyze_source(rel_path: &str, src: &str) -> FileAnalysis {
    check_file(rel_path, src)
}

/// Analyze every `.rs` file under `root`'s source directories.
///
/// `root` may be the workspace root (containing `rust/src`) or the
/// package root (containing `src`); both layouts resolve. Scans
/// `src/`, `tests/`, `benches/` and `examples/` recursively, skipping
/// any `target/` directory, in sorted path order.
pub fn analyze_tree(root: &Path) -> Result<TreeReport> {
    let base = if root.join("rust").join("src").is_dir() {
        root.join("rust")
    } else if root.join("src").is_dir() {
        root.to_path_buf()
    } else {
        return Err(UdtError::Usage(format!(
            "analyze: no src/ under {} (pass the workspace or package root)",
            root.display()
        )));
    };

    let mut files: Vec<PathBuf> = Vec::new();
    for dir in ["src", "tests", "benches", "examples"] {
        let d = base.join(dir);
        if d.is_dir() {
            collect_rs(&d, &mut files)?;
        }
    }
    files.sort();

    let mut report = TreeReport::default();
    for path in files {
        let rel = path
            .strip_prefix(&base)
            .unwrap_or(&path)
            .to_string_lossy()
            .replace('\\', "/");
        let src = std::fs::read_to_string(&path).map_err(UdtError::Io)?;
        report.files.push(FileReport {
            path: rel.clone(),
            analysis: check_file(&rel, &src),
        });
    }
    Ok(report)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let entries = std::fs::read_dir(dir).map_err(UdtError::Io)?;
    for entry in entries {
        let entry = entry.map_err(UdtError::Io)?;
        let path = entry.path();
        if path.is_dir() {
            if path.file_name().map(|n| n == "target").unwrap_or(false) {
                continue;
            }
            collect_rs(&path, out)?;
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_renders_findings_and_waiver_counts() {
        let mut report = TreeReport::default();
        report.files.push(FileReport {
            path: "src/a.rs".to_string(),
            analysis: check_file("src/a.rs", "fn f() { x.unwrap(); }\n"),
        });
        report.files.push(FileReport {
            path: "src/b.rs".to_string(),
            analysis: check_file(
                "src/b.rs",
                "fn f() { x.unwrap(); } // ANALYZE-ALLOW(no-unwrap): demo reason\n",
            ),
        });
        assert_eq!(report.total_findings(), 1);
        let rendered = report.render();
        assert!(rendered.contains("src/a.rs:1: [no-unwrap]"));
        assert!(rendered.contains("waived [no-unwrap]: 1"));
        assert!(rendered.contains("2 file(s) scanned, 1 finding(s)"));
    }

    #[test]
    fn unused_waivers_are_surfaced_not_fatal() {
        let mut report = TreeReport::default();
        report.files.push(FileReport {
            path: "src/a.rs".to_string(),
            analysis: check_file(
                "src/a.rs",
                "// ANALYZE-ALLOW(no-unwrap): nothing here needs this\nfn f() {}\n",
            ),
        });
        assert_eq!(report.total_findings(), 0);
        assert_eq!(report.unused_waivers().len(), 1);
        assert!(report.render().contains("unused waiver at src/a.rs:1"));
    }
}
