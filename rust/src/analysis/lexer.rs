//! Comment- and string-aware masking of Rust source text.
//!
//! `udt-analyze` is zero-dependency by design (the offline image has no
//! `syn`/`proc-macro2`), so instead of parsing Rust it *masks* it: one
//! linear scan classifies every character as code, comment or literal
//! content, and produces
//!
//! * `code` — the source with comment text and string/char literal
//!   *contents* blanked to spaces (delimiters and newlines kept, so
//!   byte-for-byte line structure survives), and
//! * `comments` — every comment's text with the line it starts on.
//!
//! Rules then pattern-match on the masked code — `unsafe` inside a
//! string literal or a doc example can never fire a finding — and read
//! waivers / `SAFETY:` markers from the comment list. This is exactly
//! the split that lets the analyzer scan its own fixture-bearing test
//! sources without tripping on the violations embedded in their string
//! literals.
//!
//! The scanner understands the token shapes that matter for masking:
//! line comments (`//`, `///`, `//!`), nested block comments
//! (`/* /* */ */`), string literals with escapes, raw strings with any
//! hash arity (`r#"…"#`), byte strings (`b"…"`, `br#"…"#`), char and
//! byte-char literals, and the char-vs-lifetime ambiguity (`'a'` vs
//! `'a`). It does not need to understand anything else about Rust.

/// One comment, with the 1-based line its opening `//` or `/*` sits on.
/// Multi-line block comments are recorded once, at their start line,
/// with their full text (newlines included).
#[derive(Debug, Clone)]
pub struct Comment {
    pub line: usize,
    pub text: String,
}

/// The result of [`mask`]: blanked source plus the extracted comments.
#[derive(Debug, Clone)]
pub struct MaskedSource {
    /// Source text with comment text and literal contents replaced by
    /// spaces. Newlines and literal delimiters are preserved, so line
    /// numbers and gross code shape match the original exactly.
    pub code: String,
    /// Every comment, in source order.
    pub comments: Vec<Comment>,
}

enum State {
    Code,
    LineComment,
    BlockComment(u32),
    Str,
    RawStr(u32),
}

fn ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Mask `src` (see module docs). Total, never fails: malformed source
/// degrades to "everything after the confusing point is literal
/// content", which is the conservative direction for a linter (it can
/// only suppress findings in broken files, never invent them in valid
/// ones).
pub fn mask(src: &str) -> MaskedSource {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut code = String::with_capacity(src.len());
    let mut comments: Vec<Comment> = Vec::new();

    let mut line = 1usize;
    let mut i = 0usize;
    let mut state = State::Code;
    // Accumulator for the comment currently being scanned.
    let mut ctext = String::new();
    let mut cline = 0usize;
    // Last code character emitted (for literal-prefix disambiguation:
    // the `r` in `number"` is part of an identifier, not a raw-string
    // prefix).
    let mut prev_code: char = '\0';

    while i < n {
        let c = chars[i];
        let next = if i + 1 < n { chars[i + 1] } else { '\0' };
        match state {
            State::Code => {
                if c == '/' && next == '/' {
                    state = State::LineComment;
                    cline = line;
                    ctext.clear();
                    code.push(' ');
                    code.push(' ');
                    i += 2;
                } else if c == '/' && next == '*' {
                    state = State::BlockComment(1);
                    cline = line;
                    ctext.clear();
                    code.push(' ');
                    code.push(' ');
                    i += 2;
                } else if c == '"' {
                    state = State::Str;
                    code.push('"');
                    i += 1;
                } else if (c == 'r' || c == 'b') && !ident_char(prev_code) {
                    // Possible literal prefix: r"…", r#"…"#, b"…", br"…",
                    // br#"…"#, b'…'. Look ahead without committing.
                    let mut j = i + 1;
                    if c == 'b' && j < n && chars[j] == 'r' {
                        j += 1;
                    }
                    let mut hashes = 0u32;
                    while j < n && chars[j] == '#' {
                        hashes += 1;
                        j += 1;
                    }
                    let raw = j > i + 1 || (c == 'r');
                    if j < n && chars[j] == '"' && (raw || c == 'b') {
                        // Emit the prefix + opening quote verbatim.
                        for k in i..=j {
                            code.push(chars[k]);
                        }
                        i = j + 1;
                        state = if raw { State::RawStr(hashes) } else { State::Str };
                        prev_code = '"';
                    } else if c == 'b' && hashes == 0 && i + 1 < n && chars[i + 1] == '\'' {
                        // Byte-char literal b'…': emit the prefix, let the
                        // generic char-literal arm consume the rest.
                        code.push(c);
                        prev_code = c;
                        i += 1;
                    } else {
                        code.push(c);
                        prev_code = c;
                        i += 1;
                    }
                } else if c == '\'' {
                    // Char literal vs lifetime/label: 'x' and '\n' are
                    // literals; 'a (no closing quote two ahead) is a
                    // lifetime and stays plain code.
                    let two_ahead = if i + 2 < n { chars[i + 2] } else { '\0' };
                    if next == '\\' || two_ahead == '\'' {
                        code.push('\'');
                        i += 1;
                        // Consume masked content until the closing quote.
                        while i < n {
                            let cc = chars[i];
                            if cc == '\\' {
                                code.push(' ');
                                i += 1;
                                if i < n {
                                    if chars[i] == '\n' {
                                        line += 1;
                                        code.push('\n');
                                    } else {
                                        code.push(' ');
                                    }
                                    i += 1;
                                }
                            } else if cc == '\'' {
                                code.push('\'');
                                i += 1;
                                break;
                            } else {
                                if cc == '\n' {
                                    // Unterminated char literal: bail out
                                    // conservatively at the line break.
                                    line += 1;
                                    code.push('\n');
                                    i += 1;
                                    break;
                                }
                                code.push(' ');
                                i += 1;
                            }
                        }
                        prev_code = '\'';
                    } else {
                        code.push('\'');
                        prev_code = '\'';
                        i += 1;
                    }
                } else {
                    if c == '\n' {
                        line += 1;
                    }
                    code.push(c);
                    prev_code = c;
                    i += 1;
                }
            }
            State::LineComment => {
                if c == '\n' {
                    comments.push(Comment {
                        line: cline,
                        text: ctext.clone(),
                    });
                    state = State::Code;
                    line += 1;
                    code.push('\n');
                    i += 1;
                } else {
                    ctext.push(c);
                    code.push(' ');
                    i += 1;
                }
            }
            State::BlockComment(depth) => {
                if c == '*' && next == '/' {
                    code.push(' ');
                    code.push(' ');
                    i += 2;
                    if depth == 1 {
                        comments.push(Comment {
                            line: cline,
                            text: ctext.clone(),
                        });
                        state = State::Code;
                    } else {
                        state = State::BlockComment(depth - 1);
                    }
                } else if c == '/' && next == '*' {
                    code.push(' ');
                    code.push(' ');
                    ctext.push_str("/*");
                    i += 2;
                    state = State::BlockComment(depth + 1);
                } else {
                    if c == '\n' {
                        line += 1;
                        code.push('\n');
                    } else {
                        code.push(' ');
                    }
                    ctext.push(c);
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    code.push(' ');
                    i += 1;
                    if i < n {
                        if chars[i] == '\n' {
                            line += 1;
                            code.push('\n');
                        } else {
                            code.push(' ');
                        }
                        i += 1;
                    }
                } else if c == '"' {
                    code.push('"');
                    state = State::Code;
                    prev_code = '"';
                    i += 1;
                } else {
                    if c == '\n' {
                        line += 1;
                        code.push('\n');
                    } else {
                        code.push(' ');
                    }
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                if c == '"' {
                    let mut h = 0u32;
                    let mut j = i + 1;
                    while j < n && chars[j] == '#' && h < hashes {
                        h += 1;
                        j += 1;
                    }
                    if h == hashes {
                        for k in i..j {
                            code.push(chars[k]);
                        }
                        i = j;
                        state = State::Code;
                        prev_code = '"';
                    } else {
                        code.push(' ');
                        i += 1;
                    }
                } else {
                    if c == '\n' {
                        line += 1;
                        code.push('\n');
                    } else {
                        code.push(' ');
                    }
                    i += 1;
                }
            }
        }
    }
    // Flush a comment left open at EOF (file ends inside `//` or `/*`).
    match state {
        State::LineComment | State::BlockComment(_) => {
            comments.push(Comment {
                line: cline,
                text: ctext,
            });
        }
        _ => {}
    }
    MaskedSource { code, comments }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_comments_are_blanked_and_collected() {
        let src = "let x = 1; // trailing note\n// full line\nlet y = 2;\n";
        let m = mask(src);
        assert!(!m.code.contains("trailing"));
        assert!(!m.code.contains("full line"));
        assert!(m.code.contains("let x = 1;"));
        assert!(m.code.contains("let y = 2;"));
        assert_eq!(m.comments.len(), 2);
        assert_eq!(m.comments[0].line, 1);
        assert_eq!(m.comments[0].text, " trailing note");
        assert_eq!(m.comments[1].line, 2);
        // Line structure is preserved exactly.
        assert_eq!(m.code.matches('\n').count(), src.matches('\n').count());
    }

    #[test]
    fn nested_block_comments_end_at_the_outer_close() {
        let src = "a /* one /* two */ still */ b\n";
        let m = mask(src);
        assert!(m.code.contains('a'));
        assert!(m.code.contains('b'));
        assert!(!m.code.contains("still"));
        assert_eq!(m.comments.len(), 1);
        assert!(m.comments[0].text.contains("one"));
        assert!(m.comments[0].text.contains("still"));
    }

    #[test]
    fn string_contents_are_masked_but_code_is_not() {
        let src = "call(\"unsafe .unwrap() // not a comment\"); done();\n";
        let m = mask(src);
        assert!(!m.code.contains("unsafe"));
        assert!(!m.code.contains("unwrap"));
        assert!(m.code.contains("call(\""));
        assert!(m.code.contains("done();"));
        assert!(m.comments.is_empty());
    }

    #[test]
    fn escaped_quotes_do_not_end_strings() {
        let src = r#"x("a\"b // still string"); // real comment"#;
        let m = mask(src);
        assert!(!m.code.contains("still string"));
        assert_eq!(m.comments.len(), 1);
        assert_eq!(m.comments[0].text, " real comment");
    }

    #[test]
    fn raw_strings_with_hashes_mask_their_contents() {
        let src = "let f = r#\"// ANALYZE-ALLOW(no-unwrap): fake\"#; real();\n";
        let m = mask(src);
        assert!(!m.code.contains("ANALYZE-ALLOW"));
        assert!(m.code.contains("real();"));
        assert!(m.comments.is_empty());
    }

    #[test]
    fn byte_strings_and_byte_chars_are_literals() {
        let src = "out.push(b'\\n'); let s = b\"unsafe\"; tail();\n";
        let m = mask(src);
        assert!(!m.code.contains("unsafe"));
        assert!(m.code.contains("tail();"));
    }

    #[test]
    fn lifetimes_are_code_char_literals_are_masked() {
        let src = "fn f<'a>(x: &'a str) { let c = 'u'; let q = '\\''; }\n";
        let m = mask(src);
        // Lifetimes survive as code…
        assert!(m.code.contains("<'a>"));
        assert!(m.code.contains("&'a str"));
        // …char contents do not.
        assert!(!m.code.contains("'u'"));
    }

    #[test]
    fn multiline_block_comment_is_recorded_at_its_start_line() {
        let src = "one();\n/* SAFETY: spans\n   lines */\nunsafe_marker();\n";
        let m = mask(src);
        assert_eq!(m.comments.len(), 1);
        assert_eq!(m.comments[0].line, 2);
        assert!(m.comments[0].text.contains("SAFETY: spans"));
        assert!(m.code.contains("unsafe_marker();"));
        assert_eq!(m.code.matches('\n').count(), 4);
    }

    #[test]
    fn r_as_last_ident_char_is_not_a_raw_string_prefix() {
        let src = "let number = 4; let r = 1; format!(\"{number}\");\n";
        let m = mask(src);
        assert!(m.code.contains("let number = 4;"));
        assert!(m.code.contains("let r = 1;"));
    }

    #[test]
    fn comment_open_at_eof_is_flushed() {
        let m = mask("x(); // no trailing newline");
        assert_eq!(m.comments.len(), 1);
        assert_eq!(m.comments[0].text, " no trailing newline");
    }
}
