//! `udt` — the launcher.
//!
//! Subcommands:
//!   train        train a tree or forest on a CSV or registered dataset
//!                (or out-of-core from a shard directory via --shards)
//!   shard        stream a CSV into an on-disk columnar shard directory
//!   pipeline     the paper's full train→tune→prune→evaluate pipeline
//!   predict      load a serialized model and evaluate it over a CSV
//!   gen-data     materialize a registered synthetic dataset as CSV
//!   bench-selection  Table 5 (generic vs superfast, single feature)
//!   bench-suite      Table 6 / Table 7 rows
//!   serve        TCP prediction server over a registry of compiled
//!                models (`--model name=path` repeatable)
//!   artifacts    inspect the AOT artifact manifest
//!   analyze      run the udt-analyze source lint (unsafe hygiene,
//!                thread discipline, unwrap audit, decoder casts)
//!
//! Run `udt <subcommand> --help` for options. Every training command
//! accepts `--set key=value` overrides (e.g. `--set tune.min_split_steps=50`
//! or `--set forest.n_trees=25`) on top of an optional `--config` file.

use udt::config::Config;
use udt::coordinator::pipeline::{run_pipeline_model, Quality};
use udt::coordinator::registry::ModelRegistry;
use udt::coordinator::serve::{ServeBackend, Server};
use udt::data::csv::{load_csv, CsvOptions};
use udt::data::dataset::TaskKind;
use udt::data::synth::{generate_any, registry};
use udt::selection::heuristic::ClassCriterion;
use udt::tree::Backend;
use udt::util::cli::{Args, Command};
use udt::util::timer::Timer;
use udt::{Boosted, Forest, Model, Result, SavedModel, Tree, Udt, UdtError};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run(args: &[String]) -> Result<()> {
    let Some(sub) = args.first() else {
        print_usage();
        return Ok(());
    };
    let rest = &args[1..];
    match sub.as_str() {
        "train" => cmd_train(rest),
        "shard" => cmd_shard(rest),
        "pipeline" => cmd_pipeline(rest),
        "predict" => cmd_predict(rest),
        "gen-data" => cmd_gen_data(rest),
        "rank-features" => cmd_rank_features(rest),
        "bench-selection" => cmd_bench_selection(rest),
        "bench-suite" => cmd_bench_suite(rest),
        "serve" => cmd_serve(rest),
        "artifacts" => cmd_artifacts(rest),
        "analyze" => cmd_analyze(rest),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => Err(UdtError::usage(format!(
            "unknown subcommand `{other}` (try `udt help`)"
        ))),
    }
}

fn print_usage() {
    println!(
        "udt — Ultrafast Decision Tree (Superfast Selection reproduction)\n\
         \n\
         subcommands:\n\
           train            train a tree, forest or boosted ensemble (CSV or --dataset)\n\
           shard            stream a CSV into an on-disk shard directory (out-of-core)\n\
           pipeline         train → tune (once) → prune → evaluate\n\
           predict          evaluate a serialized model over a CSV\n\
           gen-data         write a registry dataset to CSV\n\
           rank-features    Superfast Selection as a feature-selection filter\n\
           bench-selection  Table 5: generic vs superfast on one feature\n\
           bench-suite      Table 6/7 rows over the dataset registry\n\
           serve            TCP server over a registry of compiled models\n\
           artifacts        list AOT artifacts and their shapes\n\
           analyze          source lint: SAFETY comments, thread discipline,\n\
                            unwrap audit, decoder casts (non-zero on findings)\n"
    );
}

/// Shared training options → a validated `TrainConfig` via the builder.
fn train_config(a: &Args, cfg: &Config) -> Result<udt::TrainConfig> {
    let crit_default = cfg.get_or("train.criterion", "info_gain");
    let criterion = a.get_or("criterion", &crit_default);
    let criterion = ClassCriterion::parse(criterion)
        .ok_or_else(|| UdtError::usage(format!("unknown criterion `{criterion}`")))?;
    let backend_default = cfg.get_or("train.backend", "superfast");
    let backend = match a.get_or("backend", &backend_default) {
        "superfast" => Backend::Superfast,
        "generic" => Backend::Generic,
        "xla" => {
            let xla = udt::runtime::xla_split::XlaSelection::load_default(Default::default())
                .ok_or_else(|| {
                    UdtError::runtime(
                        "xla backend requires built artifacts (make artifacts) and the \
                         `xla` cargo feature",
                    )
                })?;
            Backend::Xla(std::sync::Arc::new(xla))
        }
        "binned" => {
            // Bin budget: `--max-bins` over the `train.max_bins` config
            // key (both bounds-checked).
            let max_bins = a.get_usize("max-bins", cfg.max_bins()?)?;
            udt::tree::validate_max_bins(max_bins)?;
            Backend::Binned { max_bins }
        }
        other => return Err(UdtError::usage(format!("unknown backend `{other}`"))),
    };
    let mut builder = Udt::builder()
        .criterion(criterion)
        .backend(backend)
        .min_samples_split(a.get_usize("min-split", 2)?)
        .threads(a.get_usize("threads", cfg.runtime_threads()?)?);
    if let Some(depth) = a.get("max-depth") {
        let depth: usize = depth
            .parse()
            .map_err(|_| UdtError::usage(format!("--max-depth expects an integer, got `{depth}`")))?;
        builder = builder.max_depth(depth);
    }
    builder.build()
}

/// Boosting knobs: `boost.*` config keys overridden by the dedicated
/// CLI flags (`--boosted` sets the round count at the call site;
/// `--max-depth` caps the per-round trees).
fn boost_config(a: &Args, cfg: &Config, n_threads: usize) -> Result<udt::BoostedConfig> {
    let mut bc = cfg.boost_config(n_threads)?;
    bc.learning_rate = a.get_f64("learning-rate", bc.learning_rate)?;
    bc.subsample = a.get_f64("subsample", bc.subsample)?;
    bc.max_depth = a.get_usize("max-depth", bc.max_depth)?;
    bc.validate()?;
    Ok(bc)
}

/// Train the family selected by `--forest N` / `--boosted N` (mutually
/// exclusive), or a single tree — shared by `train` and `serve`.
fn fit_model_from_flags(
    a: &Args,
    cfg: &Config,
    ds: &udt::Dataset,
    train_cfg: udt::TrainConfig,
) -> Result<Model> {
    match (a.get("forest"), a.get("boosted")) {
        (Some(_), Some(_)) => Err(UdtError::usage(
            "--forest and --boosted are mutually exclusive",
        )),
        (None, None) => Ok(Model::SingleTree(Tree::fit(ds, &train_cfg)?)),
        (Some(n), None) => {
            let n: usize = n
                .parse()
                .map_err(|_| UdtError::usage(format!("--forest expects an integer, got `{n}`")))?;
            let mut forest_cfg = cfg.forest_config(train_cfg)?;
            forest_cfg.n_trees = n;
            Ok(Model::Forest(Forest::fit(ds, &forest_cfg)?))
        }
        (None, Some(n)) => {
            let n: usize = n.parse().map_err(|_| {
                UdtError::usage(format!("--boosted expects an integer, got `{n}`"))
            })?;
            let mut boost_cfg = boost_config(a, cfg, train_cfg.n_threads)?;
            boost_cfg.n_rounds = n;
            Ok(Model::Boosted(Boosted::fit(ds, &boost_cfg)?))
        }
    }
}

/// Config file + `--set key=value` overrides.
fn base_config(a: &Args) -> Result<Config> {
    let mut cfg = Config::new();
    if let Some(path) = a.get("config") {
        cfg = Config::from_file(path)?;
    }
    for kv in a.get_all("set") {
        cfg.set_kv(kv)?;
    }
    Ok(cfg)
}

fn load_dataset(a: &Args) -> Result<udt::Dataset> {
    let seed = a.get_u64("seed", 42)?;
    if let Some(name) = a.get("dataset") {
        let entry = registry::find(name).ok_or_else(|| {
            UdtError::usage(format!("unknown dataset `{name}`; see `udt gen-data --list`"))
        })?;
        let scale: f64 = a.get_f64("scale", 1.0)?;
        return Ok(generate_any(&entry.spec.scaled(scale), seed));
    }
    if let Some(path) = a.positional.first() {
        let task = match a.get_or("task", "classification") {
            "classification" => TaskKind::Classification,
            "regression" => TaskKind::Regression,
            other => return Err(UdtError::usage(format!("unknown task `{other}`"))),
        };
        return load_csv(
            path,
            &CsvOptions {
                task,
                n_threads: a.get_usize("parse-threads", 0)?,
                ..Default::default()
            },
        );
    }
    Err(UdtError::usage("provide a CSV path or --dataset <name>"))
}

fn cmd_train(raw: &[String]) -> Result<()> {
    let cmd = Command::new("train", "train a decision tree, bagged forest or boosted ensemble")
        .opt("dataset", "registry dataset name (alternative to CSV)", None)
        .opt("scale", "row-count scale for registry datasets", Some("1.0"))
        .opt("task", "classification|regression (CSV input)", Some("classification"))
        .opt("criterion", "info_gain|gini|chi2", None)
        .opt("backend", "superfast|generic|xla|binned", None)
        .opt("max-bins", "bin budget for --backend binned (2..=65535)", None)
        .opt("max-depth", "maximum depth", None)
        .opt("min-split", "minimum samples to split", None)
        .opt("threads", "worker threads (0 = all cores)", None)
        .opt("parse-threads", "CSV ingest worker threads (0 = all cores)", Some("0"))
        .opt(
            "shards",
            "train out-of-core from a shard directory (see `udt shard`); forces --backend binned",
            None,
        )
        .opt("forest", "train a bagged forest of N trees instead", None)
        .opt("boosted", "train a gradient-boosted ensemble of N rounds instead", None)
        .opt("learning-rate", "boosting shrinkage (with --boosted)", None)
        .opt("subsample", "per-round row subsample in (0,1] (with --boosted)", None)
        .opt("seed", "rng seed", Some("42"))
        .opt("out", "write the trained model as JSON", None)
        .opt("config", "config file", None)
        .opt_multi("set", "config override key=value")
        .positional("input.csv");
    let a = cmd.parse(raw)?;
    let cfg = base_config(&a)?;
    if let Some(dir) = a.get("shards") {
        return cmd_train_sharded(&a, &cfg, dir);
    }
    let ds = load_dataset(&a)?;
    let train_cfg = train_config(&a, &cfg)?;

    let timer = Timer::start();
    let model = fit_model_from_flags(&a, &cfg, &ds, train_cfg)?;
    let ms = timer.ms();
    println!(
        "dataset={} rows={} features={} | kind={} nodes={} train={:.1}ms",
        ds.name,
        ds.n_rows(),
        ds.n_features(),
        model.kind(),
        model.n_nodes(),
        ms
    );
    if let Model::Boosted(b) = &model {
        println!(
            "boosted: {} rounds x {} score channel(s), learning_rate={}, {} member trees",
            b.n_rounds(),
            b.group(),
            b.learning_rate,
            b.trees.len()
        );
    }
    match model.evaluate(&ds)? {
        Quality::Accuracy(acc) => println!("train accuracy = {acc:.4}"),
        Quality::Regression { mae, rmse } => println!("train MAE = {mae:.4}, RMSE = {rmse:.4}"),
    }
    if let Some(out) = a.get("out") {
        SavedModel::new(model, &ds).save(out)?;
        println!("wrote {out}");
    }
    Ok(())
}

/// `train --shards DIR`: out-of-core training over an on-disk shard
/// directory (see `udt shard`). Histogram-only, so the binned backend is
/// forced; regression uses DirectSse (the only strategy the binned
/// engine supports).
fn cmd_train_sharded(a: &Args, cfg: &Config, dir: &str) -> Result<()> {
    if a.get("forest").is_some() || a.get("boosted").is_some() {
        return Err(UdtError::usage(
            "--forest/--boosted are not supported with --shards (single binned tree only)",
        ));
    }
    if a.get("out").is_some() {
        return Err(UdtError::usage(
            "--out is not supported with --shards (sharded training has no in-memory \
             dataset to bundle the model schema from)",
        ));
    }
    let sds = udt::data::ShardedDataset::open(dir)?;
    let mut train_cfg = train_config(a, cfg)?;
    if !matches!(train_cfg.backend, Backend::Binned { .. }) {
        let max_bins = a.get_usize("max-bins", cfg.max_bins()?)?;
        udt::tree::validate_max_bins(max_bins)?;
        train_cfg.backend = Backend::Binned { max_bins };
    }
    if sds.task() == TaskKind::Regression {
        train_cfg.reg_strategy = udt::tree::RegStrategy::DirectSse;
    }
    let sample_rows = cfg.shard_config()?.sample_rows;

    let timer = Timer::start();
    let (tree, stats) =
        udt::tree::sharded::fit_sharded_sampled(&sds, &train_cfg, sample_rows)?;
    let ms = timer.ms();
    println!(
        "dataset={} rows={} features={} shards={} | nodes={} depth={} train={:.1}ms",
        sds.manifest().name,
        sds.n_rows(),
        sds.n_features(),
        sds.n_shards(),
        tree.n_nodes(),
        tree.depth,
        ms
    );
    println!(
        "  out-of-core: peak shard window {} KiB, hist blocks {} KiB, row assignment \
         {} KiB, {} shard passes over {} levels",
        stats.peak_shard_window_bytes / 1024,
        stats.peak_hist_bytes / 1024,
        stats.assignment_bytes / 1024,
        stats.shard_passes,
        stats.n_levels
    );
    Ok(())
}

fn cmd_shard(raw: &[String]) -> Result<()> {
    let cmd = Command::new(
        "shard",
        "stream a CSV into an on-disk columnar shard directory",
    )
    .opt("out", "output shard directory (default: <input>.shards)", None)
    .opt("task", "classification|regression", Some("classification"))
    .opt(
        "rows-per-shard",
        "rows per shard file (default: the shard.rows config key, 65536)",
        None,
    )
    .opt("parse-threads", "CSV parse worker threads (0 = all cores)", Some("0"))
    .opt("config", "config file", None)
    .opt_multi("set", "config override key=value (e.g. shard.rows=…)")
    .positional("input.csv");
    let a = cmd.parse(raw)?;
    let cfg = base_config(&a)?;
    let path = a
        .positional
        .first()
        .ok_or_else(|| UdtError::usage("provide a CSV path to shard"))?;
    let task = match a.get_or("task", "classification") {
        "classification" => TaskKind::Classification,
        "regression" => TaskKind::Regression,
        other => return Err(UdtError::usage(format!("unknown task `{other}`"))),
    };
    let rows_per_shard = a.get_usize("rows-per-shard", cfg.shard_config()?.rows_per_shard)?;
    let out = match a.get("out") {
        Some(o) => std::path::PathBuf::from(o),
        None => std::path::Path::new(path).with_extension("shards"),
    };
    let opts = CsvOptions {
        task,
        n_threads: a.get_usize("parse-threads", 0)?,
        ..Default::default()
    };
    let input_bytes = std::fs::metadata(path).map(|m| m.len()).unwrap_or(0);

    let timer = Timer::start();
    let manifest = udt::data::shard::shard_csv_file(path, &out, &opts, rows_per_shard)?;
    let ms = timer.ms();
    let shard_bytes: usize = manifest.shards.iter().map(|s| s.bytes).sum();
    println!(
        "wrote {}: {} rows → {} shards ({:.1} MiB on disk) in {ms:.1} ms ({:.1} MB/s csv)",
        out.display(),
        manifest.n_rows,
        manifest.shards.len(),
        shard_bytes as f64 / (1u64 << 20) as f64,
        input_bytes as f64 / 1e6 / (ms / 1e3).max(1e-9)
    );
    println!(
        "  task={:?} features={} classes={}; train with `udt train --shards {}`",
        manifest.task,
        manifest.feature_names.len(),
        manifest.class_names.len(),
        out.display()
    );
    Ok(())
}

fn cmd_pipeline(raw: &[String]) -> Result<()> {
    let cmd = Command::new("pipeline", "train → tune once → prune → evaluate")
        .opt("dataset", "registry dataset name", None)
        .opt("scale", "row-count scale", Some("1.0"))
        .opt("task", "classification|regression (CSV input)", Some("classification"))
        .opt("criterion", "info_gain|gini|chi2", None)
        .opt("backend", "superfast|generic|xla|binned", None)
        .opt("max-bins", "bin budget for --backend binned (2..=65535)", None)
        .opt("max-depth", "maximum depth", None)
        .opt("min-split", "minimum samples to split", None)
        .opt("threads", "worker threads", None)
        .opt("parse-threads", "CSV ingest worker threads (0 = all cores)", Some("0"))
        .opt("seed", "rng seed", Some("42"))
        .opt("out", "write the tuned model as JSON", None)
        .opt("config", "config file", None)
        .opt_multi("set", "config override key=value (tune.* shapes the grid)")
        .positional("input.csv");
    let a = cmd.parse(raw)?;
    let cfg = base_config(&a)?;
    let ds = load_dataset(&a)?;
    let train_cfg = train_config(&a, &cfg)?;
    let grid = cfg.tune_grid()?;
    let (rep, model) = run_pipeline_model(&ds, &train_cfg, &grid, a.get_u64("seed", 42)?)?;
    println!(
        "{}: full tree {} nodes / depth {} in {:.0} ms; tuned in {:.1} ms over {} settings",
        rep.dataset, rep.full_nodes, rep.full_depth, rep.full_train_ms, rep.tune_ms, rep.n_settings
    );
    println!(
        "  best: max_depth={} min_split={} → tuned tree {} nodes / depth {} (retrain {:.0} ms)",
        rep.best_max_depth, rep.best_min_split, rep.tuned_nodes, rep.tuned_depth, rep.tuned_train_ms
    );
    match rep.quality {
        Quality::Accuracy(acc) => println!("  test accuracy = {acc:.4}"),
        Quality::Regression { mae, rmse } => println!("  test MAE = {mae:.4}, RMSE = {rmse:.4}"),
    }
    println!(
        "  memory: arena peak {} KiB, histogram scratch {} KiB",
        rep.peak_arena_bytes / 1024,
        rep.hist_scratch_bytes / 1024
    );
    println!(
        "  runtime: {} pool batches, {} tasks, {} threads spawned ({} cores)",
        rep.pool_batches,
        rep.pool_tasks,
        rep.pool_threads_spawned,
        udt::runtime::cores()
    );
    if let Some(out) = a.get("out") {
        SavedModel::new(model, &ds).save(out)?;
        println!("wrote {out} (tuned tree, servable)");
    }
    Ok(())
}

fn cmd_predict(raw: &[String]) -> Result<()> {
    let cmd = Command::new("predict", "evaluate a serialized model over a CSV")
        .opt("model", "model JSON (from `train --out` or `pipeline --out`)", None)
        .opt("dataset", "registry dataset name (alternative to CSV)", None)
        .opt("scale", "row-count scale", Some("1.0"))
        .opt("task", "classification|regression", Some("classification"))
        .opt("parse-threads", "CSV ingest worker threads (0 = all cores)", Some("0"))
        .opt("seed", "rng seed", Some("42"))
        .positional("input.csv");
    let a = cmd.parse(raw)?;
    let model_path = a
        .get("model")
        .ok_or_else(|| UdtError::usage("--model is required"))?;
    let mut ds = load_dataset(&a)?;
    let mut saved = SavedModel::load(model_path)?;
    // The CSV interned its strings and class labels independently of the
    // model bundle; remap the model's categorical operands into the
    // dataset's id space and the dataset's class ids into the model's.
    // The dataset's interner Arc is uniquely owned here, so this mutates
    // in place (no table copy; clones only if the Arc were shared).
    saved.align_to(std::sync::Arc::make_mut(&mut ds.interner))?;
    saved.align_labels(&mut ds);
    // Evaluation runs on the compiled inference path: flatten once,
    // parse the dataset into a columnar frame once, then block-predict.
    let compiled = saved.compile()?;
    let frame = udt::RowFrame::from_dataset(&ds);
    println!(
        "model: kind={} features={} nodes={} (compiled: {} nodes, {} trees)",
        saved.model.kind(),
        saved.model.n_features(),
        saved.model.n_nodes(),
        compiled.n_nodes(),
        compiled.n_trees(),
    );
    let timer = Timer::start();
    let quality = compiled.evaluate_frame(&frame, &ds.labels)?;
    let ms = timer.ms();
    match quality {
        Quality::Accuracy(acc) => println!("accuracy = {acc:.4}"),
        Quality::Regression { mae, rmse } => println!("MAE = {mae:.4}, RMSE = {rmse:.4}"),
    }
    println!(
        "predicted {} rows in {ms:.1} ms ({:.0} rows/s, compiled path)",
        ds.n_rows(),
        ds.n_rows() as f64 / (ms / 1e3).max(1e-9)
    );
    Ok(())
}

fn cmd_gen_data(raw: &[String]) -> Result<()> {
    let cmd = Command::new("gen-data", "materialize a registry dataset as CSV")
        .opt("dataset", "registry dataset name", None)
        .opt("scale", "row-count scale", Some("1.0"))
        .opt("seed", "rng seed", Some("42"))
        .opt("out", "output CSV path", None)
        .flag("list", "list registered datasets");
    let a = cmd.parse(raw)?;
    if a.flag("list") {
        for e in registry::classification_registry() {
            println!(
                "{:28} {:>9} rows {:>4} feats {:>3} classes",
                e.spec.name, e.spec.n_rows, e.spec.n_features, e.spec.n_classes
            );
        }
        for e in registry::regression_registry() {
            println!(
                "{:28} {:>9} rows {:>4} feats  regression",
                e.spec.name, e.spec.n_rows, e.spec.n_features
            );
        }
        return Ok(());
    }
    let ds = load_dataset(&a)?;
    let out = a
        .get("out")
        .map(String::from)
        .unwrap_or_else(|| format!("{}.csv", ds.name));
    std::fs::write(&out, udt::data::csv::to_csv_string(&ds))?;
    println!("wrote {} ({} rows)", out, ds.n_rows());
    Ok(())
}

fn cmd_rank_features(raw: &[String]) -> Result<()> {
    let cmd = Command::new(
        "rank-features",
        "rank features by best-split gain (Superfast Selection)",
    )
    .opt("dataset", "registry dataset name", None)
    .opt("scale", "row-count scale", Some("1.0"))
    .opt("task", "classification|regression (CSV input)", Some("classification"))
    .opt("criterion", "info_gain|gini|chi2", None)
    .opt("top", "print only the top K features", None)
    .opt("parse-threads", "CSV ingest worker threads (0 = all cores)", Some("0"))
    .opt("seed", "rng seed", Some("42"))
    .opt("config", "config file", None)
    .opt_multi("set", "config override key=value")
    .positional("input.csv");
    let a = cmd.parse(raw)?;
    let cfg = base_config(&a)?;
    let ds = load_dataset(&a)?;
    let train_cfg = train_config(&a, &cfg)?;
    let criterion = udt::selection::feature_rank::default_criterion(&ds, &train_cfg);
    let timer = Timer::start();
    let ranked = udt::selection::feature_rank::rank_features(&ds, criterion)?;
    let ms = timer.ms();
    let top = a.get_usize("top", ranked.len())?;
    println!("ranked {} features in {ms:.1} ms (criterion {:?}):", ranked.len(), criterion);
    for (i, f) in ranked.iter().take(top).enumerate() {
        println!("  {:>3}. {:24} gain={:.6}", i + 1, f.name, f.gain);
    }
    Ok(())
}

fn cmd_bench_selection(raw: &[String]) -> Result<()> {
    let cmd = Command::new(
        "bench-selection",
        "Table 5: generic vs superfast selection on a single feature",
    )
    .opt("sizes", "comma-separated sizes", Some("10000,20000,30000,40000,50000,60000,70000,80000,90000,100000"))
    .opt("runs", "repetitions per size", Some("3"))
    .opt("seed", "rng seed", Some("42"));
    let a = cmd.parse(raw)?;
    let sizes: Vec<usize> = a
        .get("sizes")
        .unwrap()
        .split(',')
        .map(|s| {
            s.trim()
                .parse()
                .map_err(|_| UdtError::usage(format!("bad size `{s}`")))
        })
        .collect::<Result<_>>()?;
    let runs = a.get_usize("runs", 3)?;
    let table = udt::bench_support::table5::run(&sizes, runs, a.get_u64("seed", 42)?);
    println!("{}", table.render());
    Ok(())
}

fn cmd_bench_suite(raw: &[String]) -> Result<()> {
    let cmd = Command::new("bench-suite", "Table 6/7 rows over the registry")
        .opt("task", "classification|regression|all", Some("all"))
        .opt("scale", "row-count scale (1.0 = paper-sized)", Some("0.1"))
        .opt("threads", "worker threads", Some("0"))
        .opt("only", "comma-separated dataset names", None)
        .opt("seed", "rng seed", Some("42"))
        .opt("config", "config file", None)
        .opt_multi("set", "config override key=value");
    let a = cmd.parse(raw)?;
    let cfg = base_config(&a)?;
    let grid = cfg.tune_grid()?;
    let scale = a.get_f64("scale", 0.1)?;
    let threads = a.get_usize("threads", 0)?;
    let seed = a.get_u64("seed", 42)?;
    let only: Option<Vec<String>> = a
        .get("only")
        .map(|s| s.split(',').map(|x| x.trim().to_string()).collect());
    let task = a.get_or("task", "all").to_string();

    let mut entries = Vec::new();
    if task == "classification" || task == "all" {
        entries.extend(registry::classification_registry());
    }
    if task == "regression" || task == "all" {
        entries.extend(registry::regression_registry());
    }
    if let Some(only) = &only {
        entries.retain(|e| only.contains(&e.spec.name));
    }

    let mut table = udt::bench_support::Table::new(&[
        "dataset", "rows", "feats", "nodes", "depth", "train(ms)", "tune(ms)", "quality",
        "t.nodes", "t.depth", "t.train(ms)",
    ]);
    for e in entries {
        let ds = generate_any(&e.spec.scaled(scale), seed);
        let train_cfg = Udt::builder().threads(threads).build()?;
        let (rep, _) = run_pipeline_model(&ds, &train_cfg, &grid, seed)?;
        let quality = match rep.quality {
            Quality::Accuracy(acc) => format!("acc={acc:.3}"),
            Quality::Regression { rmse, .. } => format!("rmse={rmse:.2}"),
        };
        table.row(vec![
            rep.dataset,
            rep.n_examples.to_string(),
            rep.n_features.to_string(),
            rep.full_nodes.to_string(),
            rep.full_depth.to_string(),
            format!("{:.0}", rep.full_train_ms),
            format!("{:.1}", rep.tune_ms),
            quality,
            rep.tuned_nodes.to_string(),
            rep.tuned_depth.to_string(),
            format!("{:.0}", rep.tuned_train_ms),
        ]);
        println!("{}", table.render());
    }
    Ok(())
}

/// Derive a registry name from a model path (`models/churn.json` →
/// `churn`).
fn model_name_from_path(path: &str) -> String {
    std::path::Path::new(path)
        .file_stem()
        .map(|s| s.to_string_lossy().to_string())
        .unwrap_or_else(|| "default".to_string())
}

fn cmd_serve(raw: &[String]) -> Result<()> {
    let cmd = Command::new("serve", "TCP prediction server (multi-model registry)")
        .opt_multi(
            "model",
            "model JSON to load, repeatable: name=path or path (first = default)",
        )
        .opt_multi("alias", "extra name for a loaded model: alias=name")
        .opt("dataset", "train on a registry dataset instead", None)
        .opt("scale", "row-count scale", Some("0.1"))
        .opt("forest", "with --dataset: train a forest of N trees", None)
        .opt("boosted", "with --dataset: train a boosted ensemble of N rounds", None)
        .opt("learning-rate", "boosting shrinkage (with --boosted)", None)
        .opt("subsample", "per-round row subsample (with --boosted)", None)
        .opt("max-depth", "maximum depth (per-round cap with --boosted)", None)
        .opt("seed", "rng seed", Some("42"))
        .opt("addr", "listen address", Some("127.0.0.1:7878"))
        .opt(
            "backend",
            "serve backend: reactor|threads (default: reactor on Linux)",
            None,
        )
        .opt("max-connections", "connection budget (reject above)", None)
        .opt("max-request-bytes", "per-line request size cap", None)
        .opt(
            "max-write-buffer-bytes",
            "reactor per-connection write buffer cap",
            None,
        )
        .opt("config", "config file", None)
        .opt_multi("set", "config override key=value")
        .positional("input.csv (when training from CSV)");
    let a = cmd.parse(raw)?;
    // Parse config + --set up front so malformed overrides error on the
    // --model path too (they only affect training, but should never be
    // silently ignored).
    let cfg = base_config(&a)?;

    // `serve --backend` selects the *serve* backend; the shared training
    // option of the same name (superfast|generic|xla|binned) must not
    // see it.
    // Training-from-dataset under `serve` picks its training backend from
    // the `train.backend` config key instead.
    let mut serve_cfg = cfg.serve_config()?;
    if let Some(v) = a.get("backend") {
        serve_cfg.backend = ServeBackend::parse(v).ok_or_else(|| {
            UdtError::usage(format!(
                "unknown serve backend `{v}` (expected `reactor` or `threads`)"
            ))
        })?;
    }
    serve_cfg.max_connections =
        a.get_usize("max-connections", serve_cfg.max_connections)?;
    serve_cfg.max_request_bytes =
        a.get_usize("max-request-bytes", serve_cfg.max_request_bytes)?;
    serve_cfg.max_write_buffer_bytes =
        a.get_usize("max-write-buffer-bytes", serve_cfg.max_write_buffer_bytes)?;
    let mut train_args = a.clone();
    train_args.options.remove("backend");

    let registry = ModelRegistry::new();
    let specs = a.get_all("model");
    if !specs.is_empty() {
        let mut seen = std::collections::BTreeSet::new();
        for spec in specs {
            let (name, path) = match spec.split_once('=') {
                Some((n, p)) => (n.to_string(), p.to_string()),
                None => (model_name_from_path(spec), spec.clone()),
            };
            // A repeated name would silently replace the earlier model in
            // the registry — make the operator pick distinct names.
            if !seen.insert(name.clone()) {
                return Err(UdtError::usage(format!(
                    "duplicate model name `{name}` (use --model <name>=<path> \
                     to disambiguate)"
                )));
            }
            registry.load(&name, SavedModel::load(&path)?)?;
        }
    } else {
        let ds = load_dataset(&train_args)?;
        let tree_cfg = train_config(&train_args, &cfg)?;
        let model = fit_model_from_flags(&train_args, &cfg, &ds, tree_cfg)?;
        let name = ds.name.clone();
        registry.load(&name, SavedModel::new(model, &ds))?;
    }
    let mut seen_aliases = std::collections::BTreeSet::new();
    for alias in a.get_all("alias") {
        let (al, target) = alias
            .split_once('=')
            .ok_or_else(|| UdtError::usage("--alias expects alias=name"))?;
        // Same contract as --model: a repeated alias would silently
        // overwrite the earlier mapping.
        if !seen_aliases.insert(al.to_string()) {
            return Err(UdtError::usage(format!("duplicate alias `{al}`")));
        }
        registry.alias(al, target)?;
    }

    for entry in registry.entries() {
        println!(
            "loaded {}: kind={} nodes={} trees={} features={}",
            entry.name(),
            entry.compiled.kind(),
            entry.compiled.n_nodes(),
            entry.compiled.n_trees(),
            entry.compiled.n_features()
        );
    }
    let server = Server::with_registry(registry);
    if let Some(default) = server.registry().default_name() {
        println!("default model: {default}");
    }
    let addr = a.get_or("addr", "127.0.0.1:7878").to_string();
    println!(
        "serving on {addr} via {} backend (max {} connections; send \"shutdown\" to stop)",
        serve_cfg.backend.name(),
        serve_cfg.max_connections
    );
    server.serve_with(serve_cfg, &addr, |bound| println!("bound {bound}"))
}

fn cmd_analyze(raw: &[String]) -> Result<()> {
    let cmd = Command::new("analyze", "run the udt-analyze source lint over the tree")
        .opt("root", "workspace or package root to scan", Some("."));
    let a = cmd.parse(raw)?;
    let root = a.get_or("root", ".");
    let report = udt::analysis::analyze_tree(std::path::Path::new(&root))?;
    print!("{}", report.render());
    let n = report.total_findings();
    if n > 0 {
        return Err(UdtError::Runtime(format!(
            "udt-analyze: {n} unwaived finding(s)"
        )));
    }
    Ok(())
}

fn cmd_artifacts(raw: &[String]) -> Result<()> {
    let cmd = Command::new("artifacts", "inspect the AOT artifact manifest")
        .opt("dir", "artifacts directory", Some("artifacts"));
    let a = cmd.parse(raw)?;
    let dir = a.get_or("dir", "artifacts");
    let manifest = udt::runtime::manifest::Manifest::load(dir)?;
    for spec in &manifest.artifacts {
        println!(
            "{:24} m={:>8} b={:>4} c={:>3}  {}",
            spec.name,
            spec.m,
            spec.b,
            spec.c,
            manifest.hlo_path(spec).display()
        );
    }
    Ok(())
}
