//! Training/tuning orchestration and serving: worker pools, the
//! end-to-end pipeline (train → tune → prune → evaluate), metrics, the
//! multi-model registry and the prediction server.

pub mod metrics;
pub mod parallel;
pub mod pipeline;
pub mod reactor;
pub mod registry;
pub mod serve;
