//! Training/tuning orchestration: worker pools, the end-to-end pipeline
//! (train → tune → prune → evaluate), metrics and the prediction server.

pub mod metrics;
pub mod parallel;
pub mod pipeline;
pub mod serve;
