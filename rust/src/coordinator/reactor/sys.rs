//! Thin `unsafe` wrappers over the handful of Linux syscalls the reactor
//! needs: `epoll_create1` / `epoll_ctl` / `epoll_pwait`, `pipe2` for the
//! self-wakeup channel, and `prlimit64` so the serve bench can lift the
//! fd ceiling before opening 10k+ sockets.
//!
//! The crate is dependency-free (no `libc`), so syscalls are issued with
//! raw `syscall`/`svc` instructions through `core::arch::asm!` using the
//! kernel's stable ABI. Only the Linux x86_64 and aarch64 ABIs are wired
//! up; [`super::SUPPORTED`] gates everything else to the portable
//! thread-per-connection backend. Sockets themselves stay `std::net`
//! types — raw syscalls cover exactly what `std` cannot express
//! (readiness notification and the wakeup pipe).
//!
//! Every wrapper returns `std::io::Result`, mapping the kernel's
//! negative-errno convention through [`std::io::Error::from_raw_os_error`]
//! so callers match on `ErrorKind` exactly as they do for `std` I/O.

use std::io;

#[cfg(target_arch = "x86_64")]
mod nr {
    pub const READ: i64 = 0;
    pub const WRITE: i64 = 1;
    pub const CLOSE: i64 = 3;
    pub const EPOLL_CTL: i64 = 233;
    pub const EPOLL_PWAIT: i64 = 281;
    pub const EPOLL_CREATE1: i64 = 291;
    pub const PIPE2: i64 = 293;
    pub const PRLIMIT64: i64 = 302;
}

#[cfg(target_arch = "aarch64")]
mod nr {
    pub const EPOLL_CREATE1: i64 = 20;
    pub const EPOLL_CTL: i64 = 21;
    pub const EPOLL_PWAIT: i64 = 22;
    pub const CLOSE: i64 = 57;
    pub const PIPE2: i64 = 59;
    pub const READ: i64 = 63;
    pub const WRITE: i64 = 64;
    pub const PRLIMIT64: i64 = 261;
}

// Compile-time pins on the syscall-number tables: a wrong number here
// doesn't fail cleanly, it *runs a different syscall* with whatever is
// in the argument registers. These duplicate the UAPI values on
// purpose — an accidental edit to either copy breaks the build instead
// of the kernel boundary. (Sources: arch/x86/entry/syscalls/
// syscall_64.tbl and include/uapi/asm-generic/unistd.h.)
#[cfg(target_arch = "x86_64")]
const _: () = {
    assert!(nr::READ == 0);
    assert!(nr::WRITE == 1);
    assert!(nr::CLOSE == 3);
    assert!(nr::EPOLL_CTL == 233);
    assert!(nr::EPOLL_PWAIT == 281);
    assert!(nr::EPOLL_CREATE1 == 291);
    assert!(nr::PIPE2 == 293);
    assert!(nr::PRLIMIT64 == 302);
};

#[cfg(target_arch = "aarch64")]
const _: () = {
    assert!(nr::EPOLL_CREATE1 == 20);
    assert!(nr::EPOLL_CTL == 21);
    assert!(nr::EPOLL_PWAIT == 22);
    assert!(nr::CLOSE == 57);
    assert!(nr::PIPE2 == 59);
    assert!(nr::READ == 63);
    assert!(nr::WRITE == 64);
    assert!(nr::PRLIMIT64 == 261);
};

/// Issue a raw 6-argument syscall (unused trailing arguments are 0).
///
/// # Safety
/// The caller must uphold the invariants of the specific syscall: valid
/// pointers with correct lengths, owned fds, etc. The asm block itself
/// only clobbers what the kernel ABI documents.
#[cfg(target_arch = "x86_64")]
unsafe fn syscall6(n: i64, a1: i64, a2: i64, a3: i64, a4: i64, a5: i64, a6: i64) -> i64 {
    let ret: i64;
    core::arch::asm!(
        "syscall",
        inlateout("rax") n => ret,
        in("rdi") a1,
        in("rsi") a2,
        in("rdx") a3,
        in("r10") a4,
        in("r8") a5,
        in("r9") a6,
        lateout("rcx") _,
        lateout("r11") _,
        options(nostack),
    );
    ret
}

/// Issue a raw 6-argument syscall (unused trailing arguments are 0).
///
/// # Safety
/// See the x86_64 variant; same contract under the aarch64 `svc 0` ABI.
#[cfg(target_arch = "aarch64")]
unsafe fn syscall6(n: i64, a1: i64, a2: i64, a3: i64, a4: i64, a5: i64, a6: i64) -> i64 {
    let ret: i64;
    core::arch::asm!(
        "svc 0",
        in("x8") n,
        inlateout("x0") a1 => ret,
        in("x1") a2,
        in("x2") a3,
        in("x3") a4,
        in("x4") a5,
        in("x5") a6,
        options(nostack),
    );
    ret
}

/// Map the kernel's `-errno` return convention to `io::Result`.
fn check(ret: i64) -> io::Result<i64> {
    if ret < 0 {
        // ANALYZE-ALLOW(as-truncation): kernel errnos are small positive ints, always in i32 range
        Err(io::Error::from_raw_os_error((-ret) as i32))
    } else {
        Ok(ret)
    }
}

// Readiness bits (linux/eventpoll.h).
pub const EPOLLIN: u32 = 0x001;
pub const EPOLLOUT: u32 = 0x004;
pub const EPOLLERR: u32 = 0x008;
pub const EPOLLHUP: u32 = 0x010;
pub const EPOLLRDHUP: u32 = 0x2000;

const EPOLL_CTL_ADD: i64 = 1;
const EPOLL_CTL_DEL: i64 = 2;
const EPOLL_CTL_MOD: i64 = 3;
const EPOLL_CLOEXEC: i64 = 0o2000000;
const O_NONBLOCK: i64 = 0o4000;
const O_CLOEXEC: i64 = 0o2000000;

/// Process-table-full / fd-table-full errnos, surfaced to the accept
/// loop so it can pause the listener instead of spinning on a
/// level-triggered readiness it cannot consume.
pub const ENFILE: i32 = 23;
pub const EMFILE: i32 = 24;

/// `struct epoll_event`. The kernel packs it on x86_64 only (the
/// `EPOLL_PACKED` attribute in the UAPI header), so the layout attribute
/// is arch-conditional to match the ABI byte-for-byte.
#[derive(Clone, Copy)]
#[cfg_attr(target_arch = "x86_64", repr(C, packed))]
#[cfg_attr(not(target_arch = "x86_64"), repr(C))]
pub struct EpollEvent {
    pub events: u32,
    /// Caller-chosen token identifying the registered fd.
    pub data: u64,
}

impl EpollEvent {
    pub const fn zeroed() -> EpollEvent {
        EpollEvent { events: 0, data: 0 }
    }
}

// Compile-time layout pins: `epoll_ctl`/`epoll_pwait` receive this
// struct by raw pointer, so its exact size and field offsets *are* the
// ABI. If a future edit drops the packed attribute (or a toolchain
// ever lays repr(C) out differently), the build fails here instead of
// the kernel reading garbage.
#[cfg(target_arch = "x86_64")]
const _: () = {
    assert!(std::mem::size_of::<EpollEvent>() == 12);
    assert!(std::mem::align_of::<EpollEvent>() == 1);
    assert!(std::mem::offset_of!(EpollEvent, events) == 0);
    assert!(std::mem::offset_of!(EpollEvent, data) == 4);
};

#[cfg(target_arch = "aarch64")]
const _: () = {
    assert!(std::mem::size_of::<EpollEvent>() == 16);
    assert!(std::mem::align_of::<EpollEvent>() == 8);
    assert!(std::mem::offset_of!(EpollEvent, events) == 0);
    assert!(std::mem::offset_of!(EpollEvent, data) == 8);
};

/// An owned epoll instance (closed on drop).
pub struct Epoll {
    fd: i32,
}

impl Epoll {
    pub fn new() -> io::Result<Epoll> {
        // SAFETY: epoll_create1 takes no pointers; flags is a valid bitset.
        let fd = check(unsafe { syscall6(nr::EPOLL_CREATE1, EPOLL_CLOEXEC, 0, 0, 0, 0, 0) })?;
        // ANALYZE-ALLOW(as-truncation): the kernel allocates fds in i32 range by definition
        Ok(Epoll { fd: fd as i32 })
    }

    /// Register `fd` for `events`, tagging readiness reports with `token`.
    pub fn add(&self, fd: i32, events: u32, token: u64) -> io::Result<()> {
        self.ctl(EPOLL_CTL_ADD, fd, events, token)
    }

    /// Change the interest set of an already-registered `fd`.
    pub fn modify(&self, fd: i32, events: u32, token: u64) -> io::Result<()> {
        self.ctl(EPOLL_CTL_MOD, fd, events, token)
    }

    /// Deregister `fd` (closing an fd deregisters it implicitly; this is
    /// for keeping a still-open fd out of the interest set).
    pub fn del(&self, fd: i32) -> io::Result<()> {
        // SAFETY: EPOLL_CTL_DEL passes no event pointer (the kernel
        // ignores that argument); both fds are plain integers.
        check(unsafe {
            syscall6(nr::EPOLL_CTL, self.fd as i64, EPOLL_CTL_DEL, fd as i64, 0, 0, 0)
        })?;
        Ok(())
    }

    fn ctl(&self, op: i64, fd: i32, events: u32, token: u64) -> io::Result<()> {
        let mut ev = EpollEvent { events, data: token };
        // SAFETY: `ev` is a live, layout-pinned EpollEvent (const
        // asserts above) that outlives the call; the kernel reads it
        // before returning and keeps no reference.
        check(unsafe {
            syscall6(
                nr::EPOLL_CTL,
                self.fd as i64,
                op,
                fd as i64,
                &mut ev as *mut EpollEvent as i64,
                0,
                0,
            )
        })?;
        Ok(())
    }

    /// Block until readiness (`timeout_ms < 0` = indefinitely; the
    /// reactor relies on the wakeup pipe, not timeouts, to interrupt
    /// this). Retries transparently on `EINTR`. Returns how many
    /// leading entries of `events` were filled.
    pub fn wait(&self, events: &mut [EpollEvent], timeout_ms: i32) -> io::Result<usize> {
        loop {
            // SAFETY: the buffer pointer/length come from a live
            // exclusive slice of layout-pinned EpollEvents; the kernel
            // writes at most `events.len()` entries into it.
            let ret = unsafe {
                syscall6(
                    nr::EPOLL_PWAIT,
                    self.fd as i64,
                    events.as_mut_ptr() as i64,
                    events.len() as i64,
                    timeout_ms as i64,
                    0, // no sigmask
                    8, // sizeof(sigset_t); ignored when the mask is null
                )
            };
            match check(ret) {
                Ok(n) => return Ok(n as usize),
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
    }
}

impl Drop for Epoll {
    fn drop(&mut self) {
        // SAFETY: `self.fd` is owned by this struct and closed exactly
        // once, here; close takes no pointers.
        unsafe {
            syscall6(nr::CLOSE, self.fd as i64, 0, 0, 0, 0, 0);
        }
    }
}

/// The write end of the self-wakeup pipe. Held (via `Arc`) by the
/// server's shutdown hook so any thread can interrupt a blocked
/// [`Epoll::wait`].
pub struct PipeWriter {
    fd: i32,
}

impl PipeWriter {
    /// Wake the reactor. Best-effort by design: a full pipe means a wake
    /// is already pending, which is all a waker needs to guarantee.
    pub fn wake(&self) {
        let byte = [1u8];
        // SAFETY: writes exactly one byte from a live local buffer to
        // an fd this struct owns; the result is deliberately ignored.
        unsafe {
            syscall6(nr::WRITE, self.fd as i64, byte.as_ptr() as i64, 1, 0, 0, 0);
        }
    }
}

impl Drop for PipeWriter {
    fn drop(&mut self) {
        // SAFETY: `self.fd` is owned by this struct and closed exactly
        // once, here; close takes no pointers.
        unsafe {
            syscall6(nr::CLOSE, self.fd as i64, 0, 0, 0, 0, 0);
        }
    }
}

/// A nonblocking self-wakeup pipe: the read end lives in the epoll
/// interest set, the write end is shared with whoever may need to
/// interrupt the event loop (the server's `shutdown` path).
pub struct WakePipe {
    read_fd: i32,
    writer: std::sync::Arc<PipeWriter>,
}

impl WakePipe {
    pub fn new() -> io::Result<WakePipe> {
        let mut fds = [0i32; 2];
        // SAFETY: pipe2 writes exactly two i32 fds into the live
        // two-element array passed by pointer.
        check(unsafe {
            syscall6(
                nr::PIPE2,
                fds.as_mut_ptr() as i64,
                O_NONBLOCK | O_CLOEXEC,
                0,
                0,
                0,
                0,
            )
        })?;
        Ok(WakePipe {
            read_fd: fds[0],
            writer: std::sync::Arc::new(PipeWriter { fd: fds[1] }),
        })
    }

    pub fn read_fd(&self) -> i32 {
        self.read_fd
    }

    /// A shareable handle to the write end.
    pub fn writer(&self) -> std::sync::Arc<PipeWriter> {
        std::sync::Arc::clone(&self.writer)
    }

    /// Drain pending wake bytes so a level-triggered epoll stops
    /// reporting the pipe readable.
    pub fn drain(&self) {
        let mut buf = [0u8; 64];
        loop {
            // SAFETY: reads at most `buf.len()` bytes into a live
            // exclusive local buffer from an fd this struct owns.
            let ret = unsafe {
                syscall6(
                    nr::READ,
                    self.read_fd as i64,
                    buf.as_mut_ptr() as i64,
                    buf.len() as i64,
                    0,
                    0,
                    0,
                )
            };
            if ret <= 0 {
                break;
            }
        }
    }
}

impl Drop for WakePipe {
    fn drop(&mut self) {
        // SAFETY: `read_fd` is owned by this struct and closed exactly
        // once, here (the writer end closes in PipeWriter's drop).
        unsafe {
            syscall6(nr::CLOSE, self.read_fd as i64, 0, 0, 0, 0, 0);
        }
    }
}

const RLIMIT_NOFILE: i64 = 7;

#[repr(C)]
struct RLimit64 {
    cur: u64,
    max: u64,
}

// Same ABI pin as EpollEvent: prlimit64 reads/writes this struct by
// raw pointer on every architecture, 16 bytes, soft limit first.
const _: () = {
    assert!(std::mem::size_of::<RLimit64>() == 16);
    assert!(std::mem::offset_of!(RLimit64, cur) == 0);
    assert!(std::mem::offset_of!(RLimit64, max) == 8);
};

/// Raise this process's soft open-file limit to its hard limit and
/// return the resulting soft limit. The serve bench calls this before
/// opening 10k+ client sockets; failure is non-fatal (the bench then
/// reports how many connections it actually achieved).
pub fn raise_nofile_limit() -> io::Result<u64> {
    let mut old = RLimit64 { cur: 0, max: 0 };
    // SAFETY: null new-limit pointer (read-only query); `old` is a
    // live, layout-pinned RLimit64 the kernel fills in.
    check(unsafe {
        syscall6(
            nr::PRLIMIT64,
            0, // self
            RLIMIT_NOFILE,
            0, // no new limit: read the current one
            &mut old as *mut RLimit64 as i64,
            0,
            0,
        )
    })?;
    if old.cur >= old.max {
        return Ok(old.cur);
    }
    let new = RLimit64 {
        cur: old.max,
        max: old.max,
    };
    // SAFETY: `new` is a live, layout-pinned RLimit64 the kernel reads;
    // the old-limit pointer is null (we already have it).
    check(unsafe {
        syscall6(
            nr::PRLIMIT64,
            0,
            RLIMIT_NOFILE,
            &new as *const RLimit64 as i64,
            0,
            0,
            0,
        )
    })?;
    Ok(new.cur)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epoll_reports_pipe_readability() {
        let ep = Epoll::new().unwrap();
        let pipe = WakePipe::new().unwrap();
        ep.add(pipe.read_fd(), EPOLLIN, 7).unwrap();

        let mut events = [EpollEvent::zeroed(); 8];
        // Nothing written yet: an immediate poll sees no readiness.
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);

        pipe.writer().wake();
        let n = ep.wait(&mut events, 1000).unwrap();
        assert_eq!(n, 1);
        let (bits, token) = (events[0].events, events[0].data);
        assert_eq!(token, 7);
        assert_ne!(bits & EPOLLIN, 0);

        // Draining clears the level-triggered readiness.
        pipe.drain();
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);
    }

    #[test]
    fn modify_and_del_change_the_interest_set() {
        let ep = Epoll::new().unwrap();
        let pipe = WakePipe::new().unwrap();
        ep.add(pipe.read_fd(), EPOLLIN, 1).unwrap();
        pipe.writer().wake();

        // Interest moved to a token we can recognize.
        ep.modify(pipe.read_fd(), EPOLLIN, 2).unwrap();
        let mut events = [EpollEvent::zeroed(); 8];
        let n = ep.wait(&mut events, 1000).unwrap();
        assert_eq!(n, 1);
        // Copy out of the (possibly packed) struct before asserting:
        // `assert_eq!` would otherwise take a reference to a packed field.
        let token = events[0].data;
        assert_eq!(token, 2);

        // Deregistered: readable but never reported.
        ep.del(pipe.read_fd()).unwrap();
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);
    }

    #[test]
    fn wake_is_best_effort_when_pipe_is_full() {
        let pipe = WakePipe::new().unwrap();
        // Saturate the pipe; further wakes must not block or panic.
        for _ in 0..100_000 {
            pipe.writer().wake();
        }
        pipe.drain();
        let ep = Epoll::new().unwrap();
        ep.add(pipe.read_fd(), EPOLLIN, 1).unwrap();
        let mut events = [EpollEvent::zeroed(); 4];
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);
    }

    #[test]
    fn nofile_limit_is_reported() {
        let lim = raise_nofile_limit().unwrap();
        assert!(lim > 0);
        // Idempotent: already at the hard limit.
        assert_eq!(raise_nofile_limit().unwrap(), lim);
    }
}
