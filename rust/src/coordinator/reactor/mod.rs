//! Epoll readiness-loop serving backend ([`ServeBackend::Reactor`]).
//!
//! One thread, one `epoll` instance, 10k+ connections: the reactor
//! drives nonblocking accept plus a per-connection state machine
//! (read buffer → line framing → [`Server::handle`] → write buffer)
//! instead of parking one OS thread per client the way the portable
//! threads backend does. The protocol brain is shared — both backends
//! call the same [`Server::handle`] — so responses are byte-identical
//! across backends (enforced by `tests/serve_parity.rs`).
//!
//! Design points, in the order they bite people:
//!
//! * **Zero dependencies.** The crate has no `libc`/`mio`/`tokio`, so
//!   the epoll/pipe/prlimit syscalls are issued directly via
//!   `core::arch::asm!` in [`sys`]. Sockets stay `std::net` types; raw
//!   syscalls cover only what `std` cannot express (readiness
//!   notification, the wakeup pipe, the fd rlimit).
//! * **Level-triggered discipline.** Interest is recomputed after every
//!   I/O burst: `EPOLLIN|EPOLLRDHUP` only while the peer's read side is
//!   open, `EPOLLOUT` only while the write buffer is non-empty. Dropping
//!   read interest after EOF and write interest after a drain is what
//!   keeps a level-triggered loop from spinning.
//! * **Pipelining.** Every complete newline-terminated line in a read
//!   burst is dispatched; responses accumulate in the write buffer and
//!   flush together.
//! * **Backpressure.** Writes go to a per-connection buffer with partial
//!   -write resumption; a transition from "draining" to "stalled"
//!   (EPOLLOUT interest added) counts a `backpressure_stalls` stat, and
//!   a peer that lets the buffer grow past
//!   [`ServeConfig::max_write_buffer_bytes`] is judged abusive and
//!   closed.
//! * **Connection budget.** Accepts past
//!   [`ServeConfig::max_connections`] get one typed JSON error line and
//!   an immediate close; fd exhaustion (`EMFILE`/`ENFILE`) pauses the
//!   listener's interest until a connection closes, instead of
//!   busy-looping on an accept that can never succeed.
//! * **Wakeup, not timeouts.** `epoll_pwait` blocks indefinitely; a
//!   self-wakeup pipe registered in the interest set lets
//!   [`Server::request_shutdown`] (or the protocol `shutdown` line)
//!   interrupt it immediately — shutdown latency is syscall-scale, not
//!   tick-scale.
//!
//! [`ServeBackend::Reactor`]: crate::coordinator::serve::ServeBackend
//! [`Server::handle`]: crate::coordinator::serve::Server::handle
//! [`Server::request_shutdown`]: crate::coordinator::serve::Server::request_shutdown
//! [`ServeConfig::max_write_buffer_bytes`]: crate::coordinator::serve::ServeConfig
//! [`ServeConfig::max_connections`]: crate::coordinator::serve::ServeConfig

use crate::coordinator::serve::{Server, ServeConfig};
use crate::error::Result;
use std::net::TcpListener;
use std::sync::Arc;

/// Whether the reactor backend exists on this target. Gates the default
/// backend choice and every platform-specific module below.
pub const SUPPORTED: bool = cfg!(all(
    target_os = "linux",
    any(target_arch = "x86_64", target_arch = "aarch64")
));

#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
pub mod sys;

/// Run the epoll event loop until shutdown. On unsupported targets this
/// returns a typed error directing the caller to `--backend threads`.
pub fn run(server: &Arc<Server>, listener: TcpListener, cfg: &ServeConfig) -> Result<()> {
    #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        imp::run(server, listener, cfg)
    }
    #[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
    {
        let _ = (server, listener, cfg);
        Err(crate::error::UdtError::runtime(
            "the reactor serve backend requires Linux on x86_64/aarch64; use --backend threads",
        ))
    }
}

/// Raise the process's soft fd limit to its hard limit (the serve bench
/// calls this before opening 10k+ sockets). `Unsupported` off-Linux.
pub fn raise_nofile_limit() -> std::io::Result<u64> {
    #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        sys::raise_nofile_limit()
    }
    #[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
    {
        Err(std::io::Error::from(std::io::ErrorKind::Unsupported))
    }
}

#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
mod imp {
    use super::sys;
    use crate::coordinator::serve::{over_budget_line, oversize_line, Server, ServeConfig};
    use crate::error::Result;
    use std::collections::HashMap;
    use std::io::{self, Read, Write};
    use std::net::{TcpListener, TcpStream};
    use std::os::fd::AsRawFd;
    use std::sync::Arc;

    const TOKEN_LISTENER: u64 = 0;
    const TOKEN_WAKE: u64 = 1;
    /// First token handed to an accepted connection.
    const TOKEN_BASE: u64 = 2;
    /// Readiness reports drained per `epoll_pwait`.
    const EVENTS_CAP: usize = 256;
    /// Read scratch size; also the per-`read` ceiling.
    const SCRATCH_BYTES: usize = 16 * 1024;
    /// Fairness bounds: how much one readiness report may consume before
    /// the loop moves on (level-triggered epoll re-reports leftovers).
    const MAX_READS_PER_EVENT: usize = 16;
    const MAX_ACCEPTS_PER_EVENT: usize = 1024;

    /// Per-connection state machine.
    struct Conn {
        stream: TcpStream,
        /// Bytes received but not yet framed into a complete line.
        read_buf: Vec<u8>,
        /// Queued response bytes; `written` of them are already on the
        /// wire (partial-write resumption).
        write_buf: Vec<u8>,
        written: usize,
        /// Interest bits currently registered with epoll.
        registered: u32,
        /// False once the peer EOFs — read interest is dropped so the
        /// level-triggered loop stops reporting a readability it would
        /// never consume.
        read_open: bool,
    }

    impl Conn {
        fn pending(&self) -> usize {
            self.write_buf.len() - self.written
        }

        fn queue(&mut self, resp: String) {
            self.write_buf.extend_from_slice(resp.as_bytes());
            self.write_buf.push(b'\n');
        }

        /// Nothing left to do: peer done sending, buffer drained.
        fn done(&self) -> bool {
            !self.read_open && self.pending() == 0
        }
    }

    enum LineOutcome {
        Ok,
        /// An oversized line was answered with a typed error; stop
        /// reading and close once the response flushes.
        CloseAfterFlush,
    }

    pub fn run(server: &Arc<Server>, listener: TcpListener, cfg: &ServeConfig) -> Result<()> {
        listener.set_nonblocking(true)?;
        let ep = sys::Epoll::new()?;
        let wake = sys::WakePipe::new()?;
        ep.add(listener.as_raw_fd(), sys::EPOLLIN, TOKEN_LISTENER)?;
        ep.add(wake.read_fd(), sys::EPOLLIN, TOKEN_WAKE)?;
        let writer = wake.writer();
        server.set_waker(Box::new(move || writer.wake()));
        Reactor {
            server,
            cfg,
            listener,
            ep,
            wake,
            conns: HashMap::new(),
            next_token: TOKEN_BASE,
            listener_paused: false,
            closed_since_pause: false,
            scratch: vec![0u8; SCRATCH_BYTES],
        }
        .event_loop()
    }

    struct Reactor<'a> {
        server: &'a Arc<Server>,
        cfg: &'a ServeConfig,
        listener: TcpListener,
        ep: sys::Epoll,
        wake: sys::WakePipe,
        conns: HashMap<u64, Conn>,
        next_token: u64,
        /// Listener interest withdrawn after `EMFILE`/`ENFILE`.
        listener_paused: bool,
        /// At least one connection closed since the pause, so an accept
        /// can succeed again.
        closed_since_pause: bool,
        scratch: Vec<u8>,
    }

    impl Reactor<'_> {
        fn event_loop(&mut self) -> Result<()> {
            let mut events = [sys::EpollEvent::zeroed(); EVENTS_CAP];
            loop {
                let n = self.ep.wait(&mut events, -1)?;
                for ev in events.iter().take(n) {
                    // Copy out of the (possibly packed) kernel struct.
                    let (bits, token) = (ev.events, ev.data);
                    match token {
                        TOKEN_LISTENER => self.accept_burst()?,
                        TOKEN_WAKE => self.wake.drain(),
                        token => self.conn_event(token, bits),
                    }
                    if self.server.shutting_down() {
                        break;
                    }
                }
                if self.server.shutting_down() {
                    self.final_flush();
                    return Ok(());
                }
                self.maybe_resume_listener()?;
            }
        }

        /// Accept until the backlog is drained (or a fairness bound).
        fn accept_burst(&mut self) -> Result<()> {
            for _ in 0..MAX_ACCEPTS_PER_EVENT {
                match self.listener.accept() {
                    Ok((stream, _)) => {
                        self.server.net().inc_accepted();
                        if self.conns.len() >= self.cfg.max_connections {
                            self.server.net().inc_rejected();
                            self.reject(stream);
                            continue;
                        }
                        // Registration failure (e.g. a racing close of
                        // the fd) just drops this one connection.
                        let _ = self.register(stream);
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => return Ok(()),
                    Err(e)
                        if e.kind() == io::ErrorKind::Interrupted
                            || e.kind() == io::ErrorKind::ConnectionAborted =>
                    {
                        continue
                    }
                    Err(e) if is_fd_exhaustion(&e) => {
                        // Nothing to accept *with*: withdraw listener
                        // interest until some fd frees up, or the
                        // level-triggered report would spin the loop.
                        self.pause_listener()?;
                        return Ok(());
                    }
                    Err(e) => {
                        // A structurally broken listener: shut down so
                        // serve_with() surfaces the error instead of
                        // leaving clients wedged on a dead loop.
                        self.server.request_shutdown();
                        return Err(e.into());
                    }
                }
            }
            Ok(())
        }

        /// Over-budget rejection: one best-effort typed error line, then
        /// the socket drops. Nonblocking, so a peer that never reads
        /// cannot stall the accept loop.
        fn reject(&self, stream: TcpStream) {
            let _ = stream.set_nonblocking(true);
            let mut line = over_budget_line(self.cfg.max_connections).into_bytes();
            line.push(b'\n');
            if let Ok(n) = (&mut &stream).write(&line) {
                self.server.net().add_bytes_out(n as u64);
            }
        }

        fn register(&mut self, stream: TcpStream) -> io::Result<()> {
            stream.set_nonblocking(true)?;
            // Response lines are small; don't let Nagle hold them back.
            let _ = stream.set_nodelay(true);
            let token = self.next_token;
            self.next_token += 1;
            let want = sys::EPOLLIN | sys::EPOLLRDHUP;
            self.ep.add(stream.as_raw_fd(), want, token)?;
            self.server.net().conn_opened();
            self.conns.insert(
                token,
                Conn {
                    stream,
                    read_buf: Vec::new(),
                    write_buf: Vec::new(),
                    written: 0,
                    registered: want,
                    read_open: true,
                },
            );
            Ok(())
        }

        fn conn_event(&mut self, token: u64, bits: u32) {
            // Taking the connection out of the map sidesteps aliasing
            // with the reactor's own fields and makes close the default.
            let Some(mut conn) = self.conns.remove(&token) else {
                return;
            };
            let alive = self.drive(&mut conn, bits);
            if !alive || conn.done() {
                self.close_conn(conn);
                return;
            }
            match self.update_interest(token, &mut conn) {
                Ok(()) => {
                    self.conns.insert(token, conn);
                }
                Err(_) => self.close_conn(conn),
            }
        }

        /// One readiness report: read burst, then flush. Returns false
        /// when the connection should close now.
        fn drive(&mut self, conn: &mut Conn, bits: u32) -> bool {
            if bits & (sys::EPOLLERR | sys::EPOLLHUP) != 0 {
                return false;
            }
            if conn.read_open
                && bits & (sys::EPOLLIN | sys::EPOLLRDHUP) != 0
                && !self.conn_readable(conn)
            {
                return false;
            }
            if conn.pending() > 0 && !self.try_flush(conn) {
                return false;
            }
            // The backpressure cap: a peer that won't drain its socket
            // while this much output is queued is abusive — close (the
            // stat that observes the stall itself is counted at the
            // EPOLLOUT transition in `update_interest`).
            conn.pending() <= self.cfg.max_write_buffer_bytes
        }

        /// Bounded read burst. Returns false on a fatal connection error.
        fn conn_readable(&mut self, conn: &mut Conn) -> bool {
            for _ in 0..MAX_READS_PER_EVENT {
                match conn.stream.read(&mut self.scratch) {
                    Ok(0) => {
                        conn.read_open = false;
                        // Peer EOF: a final unterminated line may remain.
                        self.finish_trailing_line(conn);
                        return true;
                    }
                    Ok(n) => {
                        self.server.net().add_bytes_in(n as u64);
                        conn.read_buf.extend_from_slice(&self.scratch[..n]);
                        match self.process_lines(conn) {
                            LineOutcome::Ok => {}
                            LineOutcome::CloseAfterFlush => {
                                conn.read_open = false;
                                return true;
                            }
                        }
                        if self.server.shutting_down() {
                            return true;
                        }
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => return true,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => return false,
                }
            }
            // Fairness bound hit mid-stream; level-triggered epoll will
            // re-report the leftover readability next iteration.
            true
        }

        /// Dispatch every complete line in `read_buf` (pipelining),
        /// leaving any trailing partial line — which may end mid-UTF-8
        /// sequence — buffered for the next read.
        fn process_lines(&mut self, conn: &mut Conn) -> LineOutcome {
            let mut start = 0usize;
            while let Some(pos) = conn.read_buf[start..].iter().position(|&b| b == b'\n') {
                let end = start + pos;
                if end - start > self.cfg.max_request_bytes {
                    conn.read_buf.clear();
                    conn.queue(oversize_line(self.cfg.max_request_bytes));
                    return LineOutcome::CloseAfterFlush;
                }
                let line = String::from_utf8_lossy(&conn.read_buf[start..end]).into_owned();
                start = end + 1;
                if !line.trim().is_empty() {
                    let resp = self.server.handle(&line);
                    conn.queue(resp);
                }
                // Stop dispatching once a shutdown (this line or another
                // thread) is in flight, or the peer is already abusive.
                if self.server.shutting_down()
                    || conn.pending() > self.cfg.max_write_buffer_bytes
                {
                    break;
                }
            }
            conn.read_buf.drain(..start);
            if conn.read_buf.len() > self.cfg.max_request_bytes {
                // The partial line alone already exceeds the cap — no
                // need to wait for its newline to reject it.
                conn.read_buf.clear();
                conn.queue(oversize_line(self.cfg.max_request_bytes));
                return LineOutcome::CloseAfterFlush;
            }
            LineOutcome::Ok
        }

        /// Peer EOF with an unterminated final line buffered: answer it,
        /// matching the threads backend byte-for-byte.
        fn finish_trailing_line(&mut self, conn: &mut Conn) {
            if conn.read_buf.is_empty() {
                return;
            }
            if conn.read_buf.len() > self.cfg.max_request_bytes {
                conn.read_buf.clear();
                conn.queue(oversize_line(self.cfg.max_request_bytes));
                return;
            }
            let line = String::from_utf8_lossy(&conn.read_buf).into_owned();
            conn.read_buf.clear();
            if !line.trim().is_empty() {
                let resp = self.server.handle(&line);
                conn.queue(resp);
            }
        }

        /// Write until drained or the kernel buffer fills. Returns false
        /// on a fatal connection error.
        fn try_flush(&mut self, conn: &mut Conn) -> bool {
            while conn.pending() > 0 {
                match conn.stream.write(&conn.write_buf[conn.written..]) {
                    Ok(0) => return false,
                    Ok(n) => {
                        conn.written += n;
                        self.server.net().add_bytes_out(n as u64);
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => return false,
                }
            }
            if conn.pending() == 0 {
                conn.write_buf.clear();
                conn.written = 0;
            } else if conn.written > SCRATCH_BYTES {
                // Compact occasionally so a long-lived slow peer doesn't
                // pin already-sent bytes forever.
                conn.write_buf.drain(..conn.written);
                conn.written = 0;
            }
            true
        }

        /// Recompute the level-triggered interest set after I/O: read
        /// interest while the peer may still send, write interest only
        /// while output is queued. The no-write-interest-when-drained
        /// rule is what makes backpressure observable — adding EPOLLOUT
        /// *is* the stall transition, and it's counted.
        fn update_interest(&mut self, token: u64, conn: &mut Conn) -> io::Result<()> {
            let mut want = 0u32;
            if conn.read_open {
                want |= sys::EPOLLIN | sys::EPOLLRDHUP;
            }
            if conn.pending() > 0 {
                want |= sys::EPOLLOUT;
            }
            if want != conn.registered {
                if want & sys::EPOLLOUT != 0 && conn.registered & sys::EPOLLOUT == 0 {
                    self.server.net().inc_backpressure_stalls();
                }
                self.ep.modify(conn.stream.as_raw_fd(), want, token)?;
                conn.registered = want;
            }
            Ok(())
        }

        fn close_conn(&mut self, conn: Conn) {
            // Dropping the stream closes the fd, which also deregisters
            // it from epoll (the fd was never duplicated).
            drop(conn);
            self.server.net().conn_closed();
            self.closed_since_pause = true;
        }

        fn pause_listener(&mut self) -> io::Result<()> {
            if !self.listener_paused {
                self.ep.del(self.listener.as_raw_fd())?;
                self.listener_paused = true;
                self.closed_since_pause = false;
            }
            Ok(())
        }

        /// Re-arm the paused listener once a close has freed an fd slot.
        /// Any backlog still pending is level-triggered-reported on the
        /// next `epoll_pwait`.
        fn maybe_resume_listener(&mut self) -> io::Result<()> {
            if self.listener_paused && self.closed_since_pause {
                self.ep
                    .add(self.listener.as_raw_fd(), sys::EPOLLIN, TOKEN_LISTENER)?;
                self.listener_paused = false;
            }
            Ok(())
        }

        /// Shutdown teardown: one best-effort nonblocking flush per
        /// connection (so "bye" and already-queued responses reach live
        /// peers), then everything closes.
        fn final_flush(&mut self) {
            let conns = std::mem::take(&mut self.conns);
            for (_, mut conn) in conns {
                let _ = self.try_flush(&mut conn);
                self.server.net().conn_closed();
            }
        }
    }

    fn is_fd_exhaustion(e: &io::Error) -> bool {
        matches!(e.raw_os_error(), Some(sys::EMFILE) | Some(sys::ENFILE))
    }
}
