//! Scoped worker-pool primitives built on `std::thread` (the offline
//! image ships no rayon). Work is pulled from an atomic cursor so uneven
//! item costs balance automatically; each worker owns a scratch value to
//! keep hot loops allocation-free.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Map `f` over `items`, preserving order, with `n_threads` workers and a
/// per-worker scratch created by `make_scratch`.
pub fn parallel_map_scratch<T, R, S>(
    items: Vec<T>,
    n_threads: usize,
    make_scratch: impl Fn() -> S + Sync,
    f: impl Fn(T, &mut S) -> R + Sync,
) -> Vec<R>
where
    T: Send,
    R: Send,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = n_threads.max(1).min(n);
    if workers == 1 {
        let mut scratch = make_scratch();
        return items.into_iter().map(|it| f(it, &mut scratch)).collect();
    }

    // Items move behind Mutex slots; results are written back by index.
    let slots: Vec<Mutex<Option<T>>> = items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let cursor = AtomicUsize::new(0);

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| {
                let mut scratch = make_scratch();
                loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let item = slots[i].lock().unwrap().take().unwrap();
                    let r = f(item, &mut scratch);
                    *results[i].lock().unwrap() = Some(r);
                }
            });
        }
    });

    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker completed"))
        .collect()
}

/// Map without scratch.
pub fn parallel_map<T, R>(
    items: Vec<T>,
    n_threads: usize,
    f: impl Fn(T) -> R + Sync,
) -> Vec<R>
where
    T: Send,
    R: Send,
{
    parallel_map_scratch(items, n_threads, || (), |t, _| f(t))
}

/// Effective worker count: `requested`, or all cores when 0.
pub fn effective_threads(requested: usize) -> usize {
    if requested == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    } else {
        requested
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let xs: Vec<usize> = (0..1000).collect();
        let ys = parallel_map(xs.clone(), 8, |x| x * 2);
        assert_eq!(ys, xs.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let ys = parallel_map(vec![1, 2, 3], 1, |x| x + 1);
        assert_eq!(ys, vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let ys: Vec<i32> = parallel_map(Vec::<i32>::new(), 4, |x| x);
        assert!(ys.is_empty());
    }

    #[test]
    fn scratch_is_per_worker() {
        // Each worker's scratch counts its own items; the sum must equal n.
        use std::sync::atomic::{AtomicUsize, Ordering};
        static TOTAL: AtomicUsize = AtomicUsize::new(0);
        struct Counter(usize);
        impl Drop for Counter {
            fn drop(&mut self) {
                TOTAL.fetch_add(self.0, Ordering::SeqCst);
            }
        }
        let _ = parallel_map_scratch(
            (0..100).collect::<Vec<_>>(),
            4,
            || Counter(0),
            |_, c| {
                c.0 += 1;
            },
        );
        assert_eq!(TOTAL.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn effective_threads_zero_means_all() {
        assert!(effective_threads(0) >= 1);
        assert_eq!(effective_threads(3), 3);
    }
}
