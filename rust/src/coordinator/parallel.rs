//! Scoped worker-pool primitives built on `std::thread` (the offline
//! image ships no rayon). Work is pulled from an atomic cursor so uneven
//! item costs balance automatically; each worker owns a scratch value to
//! keep hot loops allocation-free.
//!
//! The queue is lock-free: items and results live in index-addressed
//! cells, and the cursor's `fetch_add` hands every index to exactly one
//! worker, so the hot loop takes zero locks per item (the previous
//! design paid two `Mutex` acquisitions per item — a measurable tax when
//! the tree frontier fans out to thousands of small nodes).

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicUsize, Ordering};

/// One item/result cell of the work queue.
///
/// Access is externally synchronized: the atomic cursor returns each
/// index exactly once, so at most one worker ever touches a given cell,
/// and `thread::scope` join publishes all writes back to the caller.
struct Slot<V>(UnsafeCell<Option<V>>);

// SAFETY: a `Slot` is only accessed by the single worker that claimed
// its index from the cursor (see `parallel_map_scratch`); the scope join
// provides the happens-before edge for the caller's reads.
unsafe impl<V: Send> Sync for Slot<V> {}

impl<V> Slot<V> {
    fn new(v: Option<V>) -> Self {
        Slot(UnsafeCell::new(v))
    }
}

/// Map `f` over `items`, preserving order, with `n_threads` workers and a
/// per-worker scratch created by `make_scratch`.
pub fn parallel_map_scratch<T, R, S>(
    items: Vec<T>,
    n_threads: usize,
    make_scratch: impl Fn() -> S + Sync,
    f: impl Fn(T, &mut S) -> R + Sync,
) -> Vec<R>
where
    T: Send,
    R: Send,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = n_threads.max(1).min(n);
    if workers == 1 {
        let mut scratch = make_scratch();
        return items.into_iter().map(|it| f(it, &mut scratch)).collect();
    }

    // Index-addressed cells + one shared cursor: the only synchronization
    // in the hot loop is the cursor's `fetch_add`.
    let slots: Vec<Slot<T>> = items.into_iter().map(|t| Slot::new(Some(t))).collect();
    let results: Vec<Slot<R>> = (0..n).map(|_| Slot::new(None)).collect();
    let cursor = AtomicUsize::new(0);

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| {
                let mut scratch = make_scratch();
                loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    // SAFETY: `fetch_add` handed index `i` to this worker
                    // alone; nobody else reads or writes slot `i` until
                    // the scope joins.
                    let item = unsafe { (*slots[i].0.get()).take() }.expect("item present");
                    let r = f(item, &mut scratch);
                    // SAFETY: same exclusive claim on index `i`.
                    unsafe { *results[i].0.get() = Some(r) };
                }
            });
        }
    });

    results
        .into_iter()
        .map(|s| s.0.into_inner().expect("worker completed"))
        .collect()
}

/// Map without scratch.
pub fn parallel_map<T, R>(
    items: Vec<T>,
    n_threads: usize,
    f: impl Fn(T) -> R + Sync,
) -> Vec<R>
where
    T: Send,
    R: Send,
{
    parallel_map_scratch(items, n_threads, || (), |t, _| f(t))
}

/// Split `0..n` into `(start, end)` blocks of at most `chunk` items —
/// the shared scaffolding for block-parallel prediction (rows within a
/// chunk iterate tightly; chunks fan out over [`parallel_map`]).
pub fn chunk_ranges(n: usize, chunk: usize) -> Vec<(usize, usize)> {
    let chunk = chunk.max(1);
    (0..n).step_by(chunk).map(|s| (s, (s + chunk).min(n))).collect()
}

/// [`parallel_map`] over the `(start, end)` blocks of `0..n` — the one
/// chunk-parallel batch loop shared by the boxed and compiled predict
/// paths. Blocks come back stitched in order, so results are invariant
/// to the worker count (`n_threads` 0 = all cores, 1 = sequential).
pub fn parallel_map_chunked<R: Send>(
    n: usize,
    chunk: usize,
    n_threads: usize,
    f: impl Fn(usize, usize) -> R + Sync,
) -> Vec<R> {
    parallel_map(chunk_ranges(n, chunk), effective_threads(n_threads), |(s, e)| f(s, e))
}

/// Effective worker count: `requested`, or all cores when 0.
pub fn effective_threads(requested: usize) -> usize {
    if requested == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    } else {
        requested
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let xs: Vec<usize> = (0..1000).collect();
        let ys = parallel_map(xs.clone(), 8, |x| x * 2);
        assert_eq!(ys, xs.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let ys = parallel_map(vec![1, 2, 3], 1, |x| x + 1);
        assert_eq!(ys, vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let ys: Vec<i32> = parallel_map(Vec::<i32>::new(), 4, |x| x);
        assert!(ys.is_empty());
    }

    #[test]
    fn scratch_is_per_worker() {
        // Each worker's scratch counts its own items; the sum must equal n.
        use std::sync::atomic::{AtomicUsize, Ordering};
        static TOTAL: AtomicUsize = AtomicUsize::new(0);
        struct Counter(usize);
        impl Drop for Counter {
            fn drop(&mut self) {
                TOTAL.fetch_add(self.0, Ordering::SeqCst);
            }
        }
        let _ = parallel_map_scratch(
            (0..100).collect::<Vec<_>>(),
            4,
            || Counter(0),
            |_, c| {
                c.0 += 1;
            },
        );
        assert_eq!(TOTAL.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn effective_threads_zero_means_all() {
        assert!(effective_threads(0) >= 1);
        assert_eq!(effective_threads(3), 3);
    }

    #[test]
    fn chunk_ranges_tile_the_input() {
        assert_eq!(chunk_ranges(0, 4), Vec::<(usize, usize)>::new());
        assert_eq!(chunk_ranges(3, 4), vec![(0, 3)]);
        assert_eq!(chunk_ranges(8, 4), vec![(0, 4), (4, 8)]);
        assert_eq!(chunk_ranges(9, 4), vec![(0, 4), (4, 8), (8, 9)]);
        // Degenerate chunk size clamps to 1 instead of looping forever.
        assert_eq!(chunk_ranges(2, 0), vec![(0, 1), (1, 2)]);
    }

    #[test]
    fn moves_non_clone_items_through_the_queue() {
        // Items are moved out of their cells exactly once — `String` has
        // no `Copy` escape hatch, so double-takes would fail loudly.
        let items: Vec<String> = (0..257).map(|i| format!("s{i}")).collect();
        let ys = parallel_map(items.clone(), 5, |s| s.len());
        assert_eq!(ys, items.iter().map(|s| s.len()).collect::<Vec<_>>());
    }

    #[test]
    fn more_threads_than_items() {
        let ys = parallel_map(vec![10u64, 20, 30], 64, |x| x + 1);
        assert_eq!(ys, vec![11, 21, 31]);
    }
}
