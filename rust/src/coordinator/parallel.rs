//! Parallel-map entry points, now thin wrappers over the persistent
//! worker pool in [`crate::runtime::pool`].
//!
//! The signatures and semantics are unchanged from the scoped-thread
//! era — order-preserving, thread-count-invariant, per-worker scratch —
//! but no call spawns OS threads anymore: the pool spawns its workers
//! lazily once per process (capped at [`crate::runtime::cores`]) and
//! parks them between batches. `n_threads` semantics are now uniform
//! across all three entry points: `0` = all cores, `1` = inline
//! sequential, `k` = at most `k` executors (the submitting thread plus
//! `k - 1` pool workers).

use crate::runtime::pool;

/// Map `f` over `items`, preserving order, with up to
/// [`crate::runtime::threads`]`(n_threads)` executors and a per-executor
/// scratch created by `make_scratch` (never one per item).
pub fn parallel_map_scratch<T, R, S>(
    items: Vec<T>,
    n_threads: usize,
    make_scratch: impl Fn() -> S + Sync,
    f: impl Fn(T, &mut S) -> R + Sync,
) -> Vec<R>
where
    T: Send,
    R: Send,
{
    pool::map_scratch(items, n_threads, make_scratch, f)
}

/// Map without scratch.
pub fn parallel_map<T, R>(
    items: Vec<T>,
    n_threads: usize,
    f: impl Fn(T) -> R + Sync,
) -> Vec<R>
where
    T: Send,
    R: Send,
{
    pool::map_scratch(items, n_threads, || (), |t, _| f(t))
}

/// Split `0..n` into `(start, end)` blocks of at most `chunk` items —
/// the shared scaffolding for block-parallel prediction (rows within a
/// chunk iterate tightly; chunks fan out over [`parallel_map`]).
pub fn chunk_ranges(n: usize, chunk: usize) -> Vec<(usize, usize)> {
    let chunk = chunk.max(1);
    (0..n).step_by(chunk).map(|s| (s, (s + chunk).min(n))).collect()
}

/// [`parallel_map`] over the `(start, end)` blocks of `0..n` — the one
/// chunk-parallel batch loop shared by the boxed and compiled predict
/// paths. Blocks come back stitched in order, so results are invariant
/// to the worker count (`n_threads` 0 = all cores, 1 = sequential).
pub fn parallel_map_chunked<R: Send>(
    n: usize,
    chunk: usize,
    n_threads: usize,
    f: impl Fn(usize, usize) -> R + Sync,
) -> Vec<R> {
    parallel_map(chunk_ranges(n, chunk), n_threads, |(s, e)| f(s, e))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let xs: Vec<usize> = (0..1000).collect();
        let ys = parallel_map(xs.clone(), 8, |x| x * 2);
        assert_eq!(ys, xs.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let ys = parallel_map(vec![1, 2, 3], 1, |x| x + 1);
        assert_eq!(ys, vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let ys: Vec<i32> = parallel_map(Vec::<i32>::new(), 4, |x| x);
        assert!(ys.is_empty());
    }

    #[test]
    fn scratch_is_per_worker() {
        // Each worker's scratch counts its own items; the sum must equal n.
        use std::sync::atomic::{AtomicUsize, Ordering};
        static TOTAL: AtomicUsize = AtomicUsize::new(0);
        struct Counter(usize);
        impl Drop for Counter {
            fn drop(&mut self) {
                TOTAL.fetch_add(self.0, Ordering::SeqCst);
            }
        }
        let _ = parallel_map_scratch(
            (0..100).collect::<Vec<_>>(),
            4,
            || Counter(0),
            |_, c| {
                c.0 += 1;
            },
        );
        assert_eq!(TOTAL.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn zero_threads_resolves_to_all_cores_in_every_entry_point() {
        // Regression for the old inconsistency where map/map_scratch
        // clamped 0 → 1 (sequential) while chunked resolved 0 → cores.
        // All three now route through runtime::threads, so 0-thread
        // calls must produce the same (order-preserving) results as 1.
        let xs: Vec<usize> = (0..512).collect();
        let seq = parallel_map(xs.clone(), 1, |x| x * 7 + 1);
        assert_eq!(parallel_map(xs.clone(), 0, |x| x * 7 + 1), seq);
        assert_eq!(
            parallel_map_scratch(xs, 0, || (), |x, _| x * 7 + 1),
            seq
        );
        let chunked = parallel_map_chunked(512, 64, 0, |s, e| (e - s) * 7);
        assert_eq!(chunked.iter().sum::<usize>(), 512 * 7);
        assert_eq!(crate::runtime::threads(0), crate::runtime::cores());
    }

    #[test]
    fn chunk_ranges_tile_the_input() {
        assert_eq!(chunk_ranges(0, 4), Vec::<(usize, usize)>::new());
        assert_eq!(chunk_ranges(3, 4), vec![(0, 3)]);
        assert_eq!(chunk_ranges(8, 4), vec![(0, 4), (4, 8)]);
        assert_eq!(chunk_ranges(9, 4), vec![(0, 4), (4, 8), (8, 9)]);
        // Degenerate chunk size clamps to 1 instead of looping forever.
        assert_eq!(chunk_ranges(2, 0), vec![(0, 1), (1, 2)]);
    }

    #[test]
    fn moves_non_clone_items_through_the_queue() {
        // Items are moved out of their cells exactly once — `String` has
        // no `Copy` escape hatch, so double-takes would fail loudly.
        let items: Vec<String> = (0..257).map(|i| format!("s{i}")).collect();
        let ys = parallel_map(items.clone(), 5, |s| s.len());
        assert_eq!(ys, items.iter().map(|s| s.len()).collect::<Vec<_>>());
    }

    #[test]
    fn more_threads_than_items() {
        let ys = parallel_map(vec![10u64, 20, 30], 64, |x| x + 1);
        assert_eq!(ys, vec![11, 21, 31]);
    }
}
