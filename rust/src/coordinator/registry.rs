//! Multi-model serving registry.
//!
//! A [`ModelRegistry`] holds any number of named, compiled, serving-ready
//! models. Loading compiles the [`SavedModel`] once
//! ([`crate::inference::CompiledModel`]); every prediction after that
//! runs on the flattened artifact. Names can be aliased (`"prod"` →
//! `"churn-v3"`), models can be loaded and unloaded while serving, and
//! each entry keeps its own latency / throughput counters for the
//! server's `stats` report.
//!
//! The first loaded model becomes the **default** — the one legacy
//! bare-array requests (no `"model"` field) resolve to.

use crate::error::{Result, UdtError};
use crate::inference::{CompiledModel, Predictions, RowFrame};
use crate::model::SavedModel;
use crate::util::timer::Timer;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// One served model: its compiled artifact, the schema / interner needed
/// for request parsing and label rendering, and serving counters. The
/// boxed `Model` is **not** retained — after compilation the flattened
/// tables are the only prediction structure, so a loaded entry costs one
/// artifact, not two.
pub struct ModelEntry {
    name: String,
    pub schema: crate::model::Schema,
    pub interner: crate::data::interner::Interner,
    pub compiled: CompiledModel,
    predict_requests: AtomicU64,
    predictions: AtomicU64,
    /// Total time spent inside the compiled predict, in nanoseconds
    /// (nanos, not micros: a single-row walk is sub-microsecond, and
    /// truncating accumulation would report zero latency/throughput).
    predict_ns: AtomicU64,
}

impl ModelEntry {
    fn new(name: &str, saved: SavedModel) -> Result<ModelEntry> {
        let compiled = saved.compile()?;
        let SavedModel {
            schema, interner, ..
        } = saved;
        Ok(ModelEntry {
            name: name.to_string(),
            schema,
            interner,
            compiled,
            predict_requests: AtomicU64::new(0),
            predictions: AtomicU64::new(0),
            predict_ns: AtomicU64::new(0),
        })
    }

    /// Canonical name the model was loaded under (aliases resolve here).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Predict a frame on the compiled artifact, accounting the request
    /// into this entry's latency / throughput counters.
    pub fn predict_frame(&self, frame: &RowFrame) -> Result<Predictions> {
        let timer = Timer::start();
        let preds = self.compiled.predict_frame(frame)?;
        self.account(preds.len() as u64, &timer);
        Ok(preds)
    }

    /// Predict one model-space row on the compiled artifact (the
    /// single-row serving fast path: no frame, no per-request interner),
    /// with the same counter accounting as [`Self::predict_frame`].
    pub fn predict_row(&self, row: &[crate::data::value::Value]) -> Result<crate::tree::NodeLabel> {
        let timer = Timer::start();
        let label = self.compiled.predict_row(row)?;
        self.account(1, &timer);
        Ok(label)
    }

    fn account(&self, n_predictions: u64, timer: &Timer) {
        self.predict_requests.fetch_add(1, Ordering::Relaxed);
        self.predictions.fetch_add(n_predictions, Ordering::Relaxed);
        self.predict_ns
            .fetch_add(timer.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }

    /// `(predict_requests, predictions, busy_nanoseconds)`.
    pub fn counters(&self) -> (u64, u64, u64) {
        (
            self.predict_requests.load(Ordering::Relaxed),
            self.predictions.load(Ordering::Relaxed),
            self.predict_ns.load(Ordering::Relaxed),
        )
    }
}

/// The registry's name tables, all behind **one** lock so every
/// mutation validates and commits atomically — the shadowing checks in
/// `load`/`alias` are check-then-act, and the registry is documented
/// mutable while serving.
#[derive(Default)]
struct RegistryState {
    models: BTreeMap<String, Arc<ModelEntry>>,
    aliases: BTreeMap<String, String>,
    default_name: Option<String>,
}

impl RegistryState {
    /// Resolve a name or alias (canonical names win) to its entry.
    fn resolve(&self, name: &str) -> Result<Arc<ModelEntry>> {
        if let Some(entry) = self.models.get(name) {
            return Ok(Arc::clone(entry));
        }
        if let Some(target) = self.aliases.get(name) {
            if let Some(entry) = self.models.get(target) {
                return Ok(Arc::clone(entry));
            }
        }
        Err(UdtError::predict(format!("unknown model `{name}`")))
    }
}

/// Named collection of compiled models behind one serving surface.
#[derive(Default)]
pub struct ModelRegistry {
    state: RwLock<RegistryState>,
}

impl ModelRegistry {
    pub fn new() -> ModelRegistry {
        ModelRegistry::default()
    }

    /// All registry methods go through these two accessors, which
    /// recover from lock poisoning ([`crate::util::sync`]): the guarded
    /// sections are pure map/name bookkeeping that never leaves the
    /// state half-updated, and propagating a `PoisonError` here would
    /// take down every serving thread over one panicked request.
    fn read_state(&self) -> std::sync::RwLockReadGuard<'_, RegistryState> {
        crate::util::sync::read(&self.state)
    }

    fn write_state(&self) -> std::sync::RwLockWriteGuard<'_, RegistryState> {
        crate::util::sync::write(&self.state)
    }

    /// Compile and register a model under `name` (replacing any previous
    /// model of that name). The first load becomes the default target
    /// for unaddressed requests. A name may not shadow an existing alias
    /// — resolution prefers canonical names, so the alias would go
    /// silently dead while the listing still advertised it.
    pub fn load(&self, name: &str, saved: SavedModel) -> Result<()> {
        if name.is_empty() {
            return Err(UdtError::invalid_config("model name must be non-empty"));
        }
        // Compile outside the lock (it can be expensive); validate and
        // commit atomically under it.
        let entry = Arc::new(ModelEntry::new(name, saved)?);
        let mut st = self.write_state();
        if st.aliases.contains_key(name) {
            return Err(UdtError::invalid_config(format!(
                "model name `{name}` collides with an existing alias"
            )));
        }
        st.models.insert(name.to_string(), entry);
        if st.default_name.is_none() {
            st.default_name = Some(name.to_string());
        }
        Ok(())
    }

    /// Remove a model (and any aliases pointing at it). Returns whether
    /// a model of that name existed. A removed default falls back to the
    /// first remaining name.
    pub fn unload(&self, name: &str) -> bool {
        let mut st = self.write_state();
        let existed = st.models.remove(name).is_some();
        if existed {
            st.aliases.retain(|_, target| target.as_str() != name);
            if st.default_name.as_deref() == Some(name) {
                st.default_name = st.models.keys().next().cloned();
            }
        }
        existed
    }

    /// Register `alias` as another name for the loaded model `target`.
    /// An alias may not shadow a loaded model's name — `get` resolves
    /// canonical names first, so such an alias would be silently dead.
    pub fn alias(&self, alias: &str, target: &str) -> Result<()> {
        let mut st = self.write_state();
        if !st.models.contains_key(target) {
            return Err(UdtError::predict(format!("unknown model `{target}`")));
        }
        if st.models.contains_key(alias) {
            return Err(UdtError::invalid_config(format!(
                "alias `{alias}` collides with a loaded model name"
            )));
        }
        st.aliases.insert(alias.to_string(), target.to_string());
        Ok(())
    }

    /// Make `name` (a model or alias) the default for unaddressed
    /// requests. Stored canonically (an alias resolves to its target's
    /// name first), so unloading the model always triggers the
    /// first-remaining-name fallback even when the default was set via
    /// an alias.
    pub fn set_default(&self, name: &str) -> Result<()> {
        let mut st = self.write_state();
        let canonical = st.resolve(name)?.name().to_string();
        st.default_name = Some(canonical);
        Ok(())
    }

    /// Name unaddressed requests currently resolve to.
    pub fn default_name(&self) -> Option<String> {
        self.read_state().default_name.clone()
    }

    /// Resolve a request's model reference: a name, an alias, or `None`
    /// for the default — one consistent snapshot, so a concurrent
    /// unload cannot strand a default lookup halfway. Unknown names are
    /// typed predict errors (they surface as protocol `error` responses,
    /// not panics).
    pub fn get(&self, name: Option<&str>) -> Result<Arc<ModelEntry>> {
        let st = self.read_state();
        let name = match name {
            Some(n) => n,
            None => st
                .default_name
                .as_deref()
                .ok_or_else(|| UdtError::predict("no models loaded"))?,
        };
        st.resolve(name)
    }

    /// Loaded model names (canonical, sorted; aliases not included).
    pub fn names(&self) -> Vec<String> {
        self.read_state().models.keys().cloned().collect()
    }

    /// Alias table as `(alias, target)` pairs, sorted by alias.
    pub fn aliases_list(&self) -> Vec<(String, String)> {
        self.read_state()
            .aliases
            .iter()
            .map(|(a, t)| (a.clone(), t.clone()))
            .collect()
    }

    /// Snapshot of every loaded entry (stats reporting).
    pub fn entries(&self) -> Vec<Arc<ModelEntry>> {
        self.read_state().models.values().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.read_state().models.len()
    }

    pub fn is_empty(&self) -> bool {
        self.read_state().models.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate_classification, SynthSpec};
    use crate::model::{Model, Udt};

    fn saved(seed: u64) -> SavedModel {
        let mut spec = SynthSpec::classification("reg", 300, 4, 2);
        spec.cat_frac = 0.3;
        let ds = generate_classification(&spec, seed);
        SavedModel::new(Model::SingleTree(Udt::builder().fit(&ds).unwrap()), &ds)
    }

    #[test]
    fn first_load_becomes_default() {
        let r = ModelRegistry::new();
        assert!(r.get(None).is_err());
        r.load("a", saved(1)).unwrap();
        r.load("b", saved(2)).unwrap();
        assert_eq!(r.default_name().as_deref(), Some("a"));
        assert_eq!(r.get(None).unwrap().name(), "a");
        assert_eq!(r.get(Some("b")).unwrap().name(), "b");
        assert_eq!(r.names(), vec!["a", "b"]);
    }

    #[test]
    fn aliases_resolve_and_die_with_their_target() {
        let r = ModelRegistry::new();
        r.load("churn-v3", saved(3)).unwrap();
        r.alias("prod", "churn-v3").unwrap();
        assert_eq!(r.get(Some("prod")).unwrap().name(), "churn-v3");
        assert!(r.alias("x", "nope").is_err());
        // Shadowing a loaded model name would be a silently dead alias —
        // and loading over an existing alias would be the same hazard in
        // reverse.
        assert!(r.alias("churn-v3", "churn-v3").is_err());
        assert!(r.load("prod", saved(9)).is_err());
        assert!(r.unload("churn-v3"));
        assert!(r.get(Some("prod")).is_err());
        assert!(r.aliases_list().is_empty());
    }

    #[test]
    fn unloading_the_default_falls_back() {
        let r = ModelRegistry::new();
        r.load("a", saved(4)).unwrap();
        r.load("b", saved(5)).unwrap();
        assert!(r.unload("a"));
        assert_eq!(r.default_name().as_deref(), Some("b"));
        assert!(!r.unload("a"));
    }

    #[test]
    fn set_default_switches_unaddressed_requests() {
        let r = ModelRegistry::new();
        r.load("a", saved(6)).unwrap();
        r.load("b", saved(7)).unwrap();
        r.set_default("b").unwrap();
        assert_eq!(r.get(None).unwrap().name(), "b");
        assert!(r.set_default("missing").is_err());
    }

    #[test]
    fn default_set_via_alias_survives_unload_fallback() {
        let r = ModelRegistry::new();
        r.load("a", saved(10)).unwrap();
        r.load("b", saved(11)).unwrap();
        r.alias("prod", "b").unwrap();
        r.set_default("prod").unwrap();
        // Stored canonically, so the unload fallback fires.
        assert_eq!(r.default_name().as_deref(), Some("b"));
        assert!(r.unload("b"));
        assert_eq!(r.default_name().as_deref(), Some("a"));
        assert_eq!(r.get(None).unwrap().name(), "a");
    }

    #[test]
    fn entry_counters_account_predictions() {
        let r = ModelRegistry::new();
        let bundle = saved(8);
        let mut spec = SynthSpec::classification("reg", 300, 4, 2);
        spec.cat_frac = 0.3;
        let ds = generate_classification(&spec, 8);
        r.load("m", bundle).unwrap();
        let entry = r.get(Some("m")).unwrap();
        let frame = crate::inference::RowFrame::from_dataset(&ds);
        let preds = entry.predict_frame(&frame).unwrap();
        assert_eq!(preds.len(), ds.n_rows());
        let (reqs, n, _us) = entry.counters();
        assert_eq!(reqs, 1);
        assert_eq!(n, ds.n_rows() as u64);
    }
}
