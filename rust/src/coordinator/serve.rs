//! Batch prediction server over a [`ModelRegistry`] of compiled models.
//!
//! A small line-oriented TCP protocol (std::net + a worker pool; the
//! offline image has no tokio). Request lines:
//!
//! * `[1.0, "red", null]` — one row of feature cells → one prediction
//!   (legacy form; resolves to the registry's **default** model);
//! * `[[...], [...]]` — a batch of rows → an array of predictions;
//! * `{"model": "name", "rows": [[...], ...]}` — named-model addressing:
//!   predictions come back as `{"model": "name", "labels": [...]}`.
//!
//! Batches parse **once** into a columnar [`crate::inference::RowFrame`];
//! single rows take a leaner path (cells resolve straight through the
//! bundled interner into model-space values). Either way prediction runs
//! on the model's flattened [`crate::inference::CompiledModel`] tables —
//! the boxed trees are never walked at serving time.
//!
//! Control lines: `"ping"` → `"pong"`, `"models"` → the registry
//! listing, `"schema"` → the default model's schema (or
//! `{"schema": "name"}` for any loaded model), `"stats"` →
//! control/predict counters plus per-model latency & throughput, and
//! `"shutdown"` stops the listener (idle connections are reaped within a
//! read-timeout tick, so `serve` actually returns).

use crate::coordinator::registry::{ModelEntry, ModelRegistry};
use crate::data::value::Value;
use crate::error::{Result, UdtError};
use crate::inference::frame::json_cell;
use crate::inference::{Cell, RowFrame};
use crate::model::SavedModel;
use crate::tree::NodeLabel;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// How long a client read blocks before re-checking the shutdown flag.
/// Bounds how long an idle connection can pin the accept scope open.
const READ_TICK: Duration = Duration::from_millis(50);

/// Shared server state: the model registry plus global counters.
pub struct Server {
    registry: ModelRegistry,
    /// Protocol control lines handled (ping / stats / schema / models /
    /// shutdown) — *not* predictions.
    control_requests: AtomicU64,
    /// Prediction request lines handled (single rows and batches alike).
    predict_requests: AtomicU64,
    shutdown: AtomicBool,
}

impl Server {
    /// Serve a single model bundle under the name `"default"`.
    /// (Compilation happens here, once.)
    pub fn new(saved: SavedModel) -> Result<Arc<Self>> {
        let registry = ModelRegistry::new();
        registry.load("default", saved)?;
        Ok(Self::with_registry(registry))
    }

    /// Serve a pre-populated registry (multiple named models, aliases).
    pub fn with_registry(registry: ModelRegistry) -> Arc<Self> {
        Arc::new(Self {
            registry,
            control_requests: AtomicU64::new(0),
            predict_requests: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
        })
    }

    /// The live registry (models can be loaded / unloaded while serving).
    pub fn registry(&self) -> &ModelRegistry {
        &self.registry
    }

    /// Render a prediction: class name when the schema knows one.
    fn label_json(entry: &ModelEntry, label: NodeLabel) -> Json {
        match label {
            NodeLabel::Class(c) => match entry.schema.class_name(c) {
                Some(name) => Json::Str(name.to_string()),
                None => Json::Num(c as f64),
            },
            NodeLabel::Value(v) => Json::Num(v),
        }
    }

    /// Handle one request line; returns the response line.
    pub fn handle(&self, line: &str) -> String {
        let trimmed = line.trim();
        if let Some(resp) = self.handle_control(trimmed) {
            self.control_requests.fetch_add(1, Ordering::Relaxed);
            return resp;
        }
        let parsed = match Json::parse(trimmed) {
            Ok(p) => p,
            Err(e) => {
                self.predict_requests.fetch_add(1, Ordering::Relaxed);
                return error_json(&UdtError::predict(e.to_string()));
            }
        };
        // `{"schema": "name"}` — the addressed counterpart of the bare
        // "schema" control line (any loaded model, not just the default).
        if parsed.get("schema").is_some() {
            self.control_requests.fetch_add(1, Ordering::Relaxed);
            return match self.named_schema(&parsed) {
                Ok(j) => j.to_string(),
                Err(e) => error_json(&e),
            };
        }
        self.predict_requests.fetch_add(1, Ordering::Relaxed);
        match self.handle_predict(&parsed) {
            Ok(j) => j.to_string(),
            Err(e) => error_json(&e),
        }
    }

    /// Schema of a named model (or alias).
    fn named_schema(&self, parsed: &Json) -> Result<Json> {
        let name = parsed
            .get("schema")
            .and_then(Json::as_str)
            .ok_or_else(|| UdtError::predict("`schema` must be a model name string"))?;
        Ok(self.registry.get(Some(name))?.schema.to_json())
    }

    /// Control lines; `None` means the line is a prediction request.
    fn handle_control(&self, trimmed: &str) -> Option<String> {
        match trimmed {
            "\"ping\"" | "ping" => Some("\"pong\"".to_string()),
            "\"stats\"" | "stats" => Some(self.stats_json().to_string()),
            "\"models\"" | "models" => Some(self.models_json().to_string()),
            "\"schema\"" | "schema" => Some(match self.registry.get(None) {
                Ok(entry) => entry.schema.to_json().to_string(),
                Err(e) => Json::obj(vec![("error", Json::Str(e.to_string()))]).to_string(),
            }),
            "\"shutdown\"" | "shutdown" => {
                self.shutdown.store(true, Ordering::SeqCst);
                Some("\"bye\"".to_string())
            }
            _ => None,
        }
    }

    /// Registry listing: loaded names, aliases, the default.
    fn models_json(&self) -> Json {
        let aliases: BTreeMap<String, Json> = self
            .registry
            .aliases_list()
            .into_iter()
            .map(|(a, t)| (a, Json::Str(t)))
            .collect();
        Json::obj(vec![
            (
                "models",
                Json::Arr(self.registry.names().into_iter().map(Json::Str).collect()),
            ),
            ("aliases", Json::Obj(aliases)),
            (
                "default",
                self.registry
                    .default_name()
                    .map(Json::Str)
                    .unwrap_or(Json::Null),
            ),
        ])
    }

    /// Global + per-model counters. Latency is mean time inside the
    /// compiled predict per request; throughput is predictions per busy
    /// second.
    fn stats_json(&self) -> Json {
        let mut models: BTreeMap<String, Json> = BTreeMap::new();
        for entry in self.registry.entries() {
            let (reqs, preds, ns) = entry.counters();
            let busy_s = ns as f64 / 1e9;
            models.insert(
                entry.name().to_string(),
                Json::obj(vec![
                    ("kind", Json::Str(entry.compiled.kind().to_string())),
                    ("nodes", Json::Num(entry.compiled.n_nodes() as f64)),
                    (
                        "n_features",
                        Json::Num(entry.compiled.n_features() as f64),
                    ),
                    ("trees", Json::Num(entry.compiled.n_trees() as f64)),
                    // Boosting rounds (0 for non-boosted families).
                    ("rounds", Json::Num(entry.compiled.n_rounds() as f64)),
                    (
                        "table_bytes",
                        Json::Num(entry.compiled.table_bytes() as f64),
                    ),
                    ("predict_requests", Json::Num(reqs as f64)),
                    ("predictions", Json::Num(preds as f64)),
                    ("busy_ms", Json::Num(ns as f64 / 1e6)),
                    (
                        "mean_ms",
                        Json::Num(if reqs > 0 {
                            ns as f64 / 1e6 / reqs as f64
                        } else {
                            0.0
                        }),
                    ),
                    (
                        "rows_per_sec",
                        Json::Num(if busy_s > 0.0 {
                            preds as f64 / busy_s
                        } else {
                            0.0
                        }),
                    ),
                ]),
            );
        }
        Json::obj(vec![
            (
                "control_requests",
                Json::Num(self.control_requests.load(Ordering::Relaxed) as f64),
            ),
            (
                "predict_requests",
                Json::Num(self.predict_requests.load(Ordering::Relaxed) as f64),
            ),
            (
                "default",
                self.registry
                    .default_name()
                    .map(Json::Str)
                    .unwrap_or(Json::Null),
            ),
            ("models", Json::Obj(models)),
        ])
    }

    fn handle_predict(&self, parsed: &Json) -> Result<Json> {
        match parsed {
            // Legacy form: bare row / batch → the default model.
            Json::Arr(items) => {
                let entry = self.registry.get(None)?;
                if matches!(items.first(), Some(Json::Arr(_))) {
                    let labels = self.predict_rows(&entry, batch_rows(items)?)?;
                    Ok(Json::Arr(labels))
                } else {
                    self.predict_one(&entry, items)
                }
            }
            // Addressed form: {"model": "name", "rows": [...]}.
            Json::Obj(_) => {
                let name = match parsed.get("model") {
                    None => None,
                    Some(j) => Some(j.as_str().ok_or_else(|| {
                        UdtError::predict("`model` must be a string")
                    })?),
                };
                let rows = parsed
                    .get("rows")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| UdtError::predict("request object needs a `rows` array"))?;
                let entry = self.registry.get(name)?;
                let labels = if rows.is_empty() {
                    // A well-formed empty batch (e.g. a proxy flushing an
                    // empty buffer) gets empty labels, not an arity error.
                    Vec::new()
                } else if matches!(rows.first(), Some(Json::Arr(_))) {
                    self.predict_rows(&entry, batch_rows(rows)?)?
                } else {
                    vec![self.predict_one(&entry, rows)?]
                };
                Ok(Json::obj(vec![
                    ("model", Json::Str(entry.name().to_string())),
                    ("labels", Json::Arr(labels)),
                ]))
            }
            _ => Err(UdtError::predict("request must be a JSON array or object")),
        }
    }

    /// Single-row fast path: resolve cells straight into model-space
    /// values through the bundled interner (unseen category → missing,
    /// exactly the frame path's routing) and walk the compiled tables —
    /// no per-request frame, interner or translation tables. Cell
    /// classification is the frame path's [`json_cell`] rule, so the two
    /// paths cannot drift apart.
    fn predict_one(&self, entry: &ModelEntry, cells: &[Json]) -> Result<Json> {
        let row: Vec<Value> = cells
            .iter()
            .map(|j| {
                Ok(match json_cell(j)? {
                    Cell::Missing => Value::Missing,
                    Cell::Num(x) => Value::Num(x),
                    Cell::Str(s) => match entry.interner.get(s) {
                        Some(id) => Value::Cat(id),
                        None => Value::Missing,
                    },
                })
            })
            .collect::<Result<_>>()?;
        let label = entry.predict_row(&row)?;
        Ok(Self::label_json(entry, label))
    }

    /// Parse a batch of rows into a frame once, predict on the compiled
    /// artifact, render labels through the entry's schema.
    fn predict_rows(&self, entry: &ModelEntry, rows: Vec<&[Json]>) -> Result<Vec<Json>> {
        let frame = RowFrame::from_json_rows(&rows)?;
        let preds = entry.predict_frame(&frame)?;
        Ok(preds
            .labels()
            .iter()
            .map(|&l| Self::label_json(entry, l))
            .collect())
    }

    /// Serve until a `shutdown` request arrives. Returns the bound address
    /// through `on_bound` (useful with port 0 in tests).
    pub fn serve(
        self: &Arc<Self>,
        addr: &str,
        on_bound: impl FnOnce(std::net::SocketAddr),
    ) -> Result<()> {
        let listener = TcpListener::bind(addr)?;
        on_bound(listener.local_addr()?);
        listener.set_nonblocking(true)?;
        std::thread::scope(|scope| -> Result<()> {
            loop {
                if self.shutdown.load(Ordering::SeqCst) {
                    break;
                }
                match listener.accept() {
                    Ok((stream, _)) => {
                        let server = Arc::clone(self);
                        scope.spawn(move || {
                            let _ = server.client_loop(stream);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(e) => {
                        // Wake every client loop so the scope can join
                        // before the error propagates — otherwise an idle
                        // connection would pin serve() open forever with
                        // the error swallowed.
                        self.shutdown.store(true, Ordering::SeqCst);
                        return Err(e.into());
                    }
                }
            }
            Ok(())
        })
    }

    /// One connection. Reads tick every [`READ_TICK`] so an **idle**
    /// client notices `shutdown` and releases the serve scope (the
    /// pre-registry server blocked forever here); responses go through a
    /// `BufWriter` and flush once per line (one syscall, not two).
    fn client_loop(&self, stream: TcpStream) -> Result<()> {
        // On BSD-likes an accepted socket inherits the listener's
        // O_NONBLOCK, which would defeat the timeouts below (instant
        // WouldBlock → busy-spin). Force blocking mode first.
        stream.set_nonblocking(false)?;
        stream.set_read_timeout(Some(READ_TICK))?;
        stream.set_write_timeout(Some(READ_TICK))?;
        let mut writer = BufWriter::new(stream.try_clone()?);
        let mut reader = BufReader::new(stream);
        // Accumulate raw bytes, not a String: `read_line`'s UTF-8 guard
        // would *discard* bytes already consumed from the socket when a
        // timeout tick lands inside a multibyte character; `read_until`
        // keeps every partial read in the buffer across ticks. UTF-8
        // conversion happens once per complete line.
        let mut buf: Vec<u8> = Vec::new();
        loop {
            if self.shutdown.load(Ordering::SeqCst) {
                break;
            }
            match reader.read_until(b'\n', &mut buf) {
                Ok(0) => {
                    // Client hung up; a final unterminated line may still
                    // be buffered (read_until only returns it with the
                    // EOF read when no timeout tick intervened) — answer
                    // it like `BufReader::lines` used to.
                    let line = String::from_utf8_lossy(&buf);
                    if !line.trim().is_empty() {
                        let resp = self.handle(&line);
                        self.write_line(&mut writer, resp)?;
                    }
                    break;
                }
                Ok(_) => {
                    let line = String::from_utf8_lossy(&buf);
                    if !line.trim().is_empty() {
                        let resp = self.handle(&line);
                        self.write_line(&mut writer, resp)?;
                    }
                    buf.clear();
                }
                // Timeout tick: partial data (if any) stays in `buf`;
                // loop around and re-check the shutdown flag.
                Err(e) if is_tick(&e) => {}
                Err(e) => return Err(e.into()),
            }
        }
        Ok(())
    }

    /// Write one response line through the `BufWriter` and flush it once
    /// (one syscall per response in the common case). Writes carry the
    /// same tick discipline as reads: a peer that stops draining its
    /// socket (kernel send buffer full) times out every [`READ_TICK`]
    /// and the loop then checks the shutdown flag instead of pinning the
    /// serve scope open forever. The flag is checked only *after* a
    /// failed attempt — never before the first — so the `"bye"` reply to
    /// the very request that set it still goes out to a live client.
    /// Offsets track raw `write` calls, so a timed-out attempt never
    /// duplicates bytes; abandoning a response mid-shutdown is fine (the
    /// connection is going away).
    fn write_line(&self, writer: &mut BufWriter<TcpStream>, resp: String) -> Result<()> {
        let mut out = resp.into_bytes();
        out.push(b'\n');
        let mut off = 0;
        while off < out.len() {
            match writer.write(&out[off..]) {
                Ok(0) => return Err(std::io::Error::from(std::io::ErrorKind::WriteZero).into()),
                Ok(n) => off += n,
                Err(e) if is_tick(&e) => {
                    if self.shutdown.load(Ordering::SeqCst) {
                        return Ok(());
                    }
                }
                Err(e) => return Err(e.into()),
            }
        }
        loop {
            match writer.flush() {
                Ok(()) => return Ok(()),
                Err(e) if is_tick(&e) => {
                    if self.shutdown.load(Ordering::SeqCst) {
                        return Ok(());
                    }
                }
                Err(e) => return Err(e.into()),
            }
        }
    }
}

/// Render an error as a protocol `{"error": ...}` response line.
fn error_json(e: &UdtError) -> String {
    Json::obj(vec![("error", Json::Str(e.to_string()))]).to_string()
}

/// A retryable socket-timeout tick (vs a real I/O failure).
fn is_tick(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock
            | std::io::ErrorKind::TimedOut
            | std::io::ErrorKind::Interrupted
    )
}

/// Borrow a batch request's rows as slices, rejecting non-array rows.
fn batch_rows(items: &[Json]) -> Result<Vec<&[Json]>> {
    items
        .iter()
        .map(|row| {
            row.as_arr()
                .ok_or_else(|| UdtError::predict("batch rows must be arrays"))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate_classification, SynthSpec};
    use crate::model::{Model, Udt};

    fn server() -> Arc<Server> {
        let mut spec = SynthSpec::classification("srv", 500, 4, 2);
        spec.cat_frac = 0.3;
        let ds = generate_classification(&spec, 61);
        let tree = Udt::builder().fit(&ds).unwrap();
        Server::new(SavedModel::new(Model::SingleTree(tree), &ds)).unwrap()
    }

    #[test]
    fn ping_and_stats() {
        let s = server();
        assert_eq!(s.handle("\"ping\""), "\"pong\"");
        let stats = Json::parse(&s.handle("stats")).unwrap();
        assert!(stats.get("control_requests").unwrap().as_f64().unwrap() >= 1.0);
        assert_eq!(stats.get("default").unwrap().as_str().unwrap(), "default");
        let model = stats.get("models").unwrap().get("default").unwrap();
        assert_eq!(model.get("kind").unwrap().as_str().unwrap(), "single_tree");
        assert!(model.get("nodes").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn control_lines_do_not_count_as_predictions() {
        let s = server();
        s.handle("ping");
        s.handle("models");
        s.handle("[1.0, 2.0, 3.0, null]");
        let stats = Json::parse(&s.handle("stats")).unwrap();
        // ping + models (stats itself counts after the snapshot).
        assert_eq!(
            stats.get("control_requests").unwrap().as_f64().unwrap(),
            2.0
        );
        assert_eq!(
            stats.get("predict_requests").unwrap().as_f64().unwrap(),
            1.0
        );
        let model = stats.get("models").unwrap().get("default").unwrap();
        assert_eq!(
            model.get("predict_requests").unwrap().as_f64().unwrap(),
            1.0
        );
        assert_eq!(model.get("predictions").unwrap().as_f64().unwrap(), 1.0);
    }

    #[test]
    fn schema_request_lists_features() {
        let s = server();
        let schema = Json::parse(&s.handle("schema")).unwrap();
        assert_eq!(schema.get("features").unwrap().as_arr().unwrap().len(), 4);
    }

    #[test]
    fn single_and_batch_predictions() {
        let s = server();
        let row = "[1.0, 2.0, 3.0, null]";
        let r1 = s.handle(row);
        assert!(r1.starts_with('"') || r1.parse::<f64>().is_ok(), "{r1}");
        let batch = format!("[{row}, {row}]");
        let rb = Json::parse(&s.handle(&batch)).unwrap();
        assert_eq!(rb.as_arr().unwrap().len(), 2);
    }

    #[test]
    fn named_model_requests_address_the_registry() {
        let mut spec = SynthSpec::classification("srv2", 400, 4, 2);
        spec.cat_frac = 0.3;
        let ds = generate_classification(&spec, 67);
        let registry = ModelRegistry::new();
        registry
            .load(
                "a",
                SavedModel::new(Model::SingleTree(Udt::builder().fit(&ds).unwrap()), &ds),
            )
            .unwrap();
        registry
            .load(
                "b",
                SavedModel::new(
                    Model::Forest(
                        crate::tree::forest::Forest::fit(
                            &ds,
                            &crate::tree::forest::ForestConfig {
                                n_trees: 3,
                                ..Default::default()
                            },
                        )
                        .unwrap(),
                    ),
                    &ds,
                ),
            )
            .unwrap();
        registry.alias("prod", "b").unwrap();
        let s = Server::with_registry(registry);

        let resp = Json::parse(&s.handle(r#"{"model":"b","rows":[[1,2,3,4],[4,3,2,1]]}"#)).unwrap();
        assert_eq!(resp.get("model").unwrap().as_str().unwrap(), "b");
        assert_eq!(resp.get("labels").unwrap().as_arr().unwrap().len(), 2);
        // Aliases resolve to the canonical name.
        let resp = Json::parse(&s.handle(r#"{"model":"prod","rows":[1,2,3,4]}"#)).unwrap();
        assert_eq!(resp.get("model").unwrap().as_str().unwrap(), "b");
        assert_eq!(resp.get("labels").unwrap().as_arr().unwrap().len(), 1);
        // A well-formed empty batch yields empty labels, not an error.
        let resp = Json::parse(&s.handle(r#"{"model":"b","rows":[]}"#)).unwrap();
        assert_eq!(resp.get("labels").unwrap().as_arr().unwrap().len(), 0);
        // Any loaded model's schema is reachable by name.
        let schema = Json::parse(&s.handle(r#"{"schema":"b"}"#)).unwrap();
        assert_eq!(schema.get("features").unwrap().as_arr().unwrap().len(), 4);
        let resp = s.handle(r#"{"schema":"gone"}"#);
        assert!(resp.contains("error"), "{resp}");
        // Unknown names are protocol errors, not panics.
        let resp = Json::parse(&s.handle(r#"{"model":"nope","rows":[[1,2,3,4]]}"#)).unwrap();
        assert!(resp.get("error").unwrap().as_str().unwrap().contains("nope"));
        // Bare arrays still hit the default (first-loaded) model.
        let legacy = s.handle("[1.0, 2.0, 3.0, 4.0]");
        assert!(!legacy.contains("error"), "{legacy}");
        // Both models show in the listing.
        let models = Json::parse(&s.handle("models")).unwrap();
        assert_eq!(models.get("models").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(models.get("default").unwrap().as_str().unwrap(), "a");
        assert_eq!(
            models.get("aliases").unwrap().get("prod").unwrap().as_str().unwrap(),
            "b"
        );
    }

    #[test]
    fn wrong_arity_is_error() {
        let s = server();
        let resp = Json::parse(&s.handle("[1.0]")).unwrap();
        assert!(resp.get("error").is_some());
    }

    #[test]
    fn unseen_category_is_treated_as_missing() {
        let s = server();
        let r = s.handle("[\"never-seen-category\", 1.0, 1.0, 1.0]");
        assert!(!r.contains("error"), "{r}");
    }

    #[test]
    fn tcp_round_trip() {
        let s = server();
        let (tx, rx) = std::sync::mpsc::channel();
        let s2 = Arc::clone(&s);
        let handle = std::thread::spawn(move || {
            s2.serve("127.0.0.1:0", |addr| tx.send(addr).unwrap()).unwrap();
        });
        let addr = rx.recv().unwrap();
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(b"\"ping\"\n").unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "\"pong\"");
        stream.write_all(b"\"shutdown\"\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn shutdown_terminates_despite_idle_connection() {
        // Regression: an idle client used to pin `serve` open forever
        // (its blocking read kept the scope thread alive).
        let s = server();
        let (tx, rx) = std::sync::mpsc::channel();
        let (done_tx, done_rx) = std::sync::mpsc::channel();
        let s2 = Arc::clone(&s);
        let handle = std::thread::spawn(move || {
            s2.serve("127.0.0.1:0", |addr| tx.send(addr).unwrap()).unwrap();
            done_tx.send(()).unwrap();
        });
        let addr = rx.recv().unwrap();
        // A client that connects and then says nothing.
        let idle = TcpStream::connect(addr).unwrap();
        // A second client issues the shutdown.
        let mut ctl = TcpStream::connect(addr).unwrap();
        ctl.write_all(b"\"shutdown\"\n").unwrap();
        let mut reader = BufReader::new(ctl.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "\"bye\"");
        // serve() must return promptly even though `idle` never spoke.
        done_rx
            .recv_timeout(Duration::from_secs(10))
            .expect("serve() hung on the idle connection");
        handle.join().unwrap();
        drop(idle);
    }
}
