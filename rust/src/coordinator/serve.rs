//! Batch prediction server over a [`ModelRegistry`] of compiled models.
//!
//! A small line-oriented TCP protocol (std::net only; the offline image
//! has no tokio). Request lines:
//!
//! * `[1.0, "red", null]` — one row of feature cells → one prediction
//!   (legacy form; resolves to the registry's **default** model);
//! * `[[...], [...]]` — a batch of rows → an array of predictions;
//! * `{"model": "name", "rows": [[...], ...]}` — named-model addressing:
//!   predictions come back as `{"model": "name", "labels": [...]}`.
//!
//! Batches parse **once** into a columnar [`crate::inference::RowFrame`];
//! single rows take a leaner path (cells resolve straight through the
//! bundled interner into model-space values). Either way prediction runs
//! on the model's flattened [`crate::inference::CompiledModel`] tables —
//! the boxed trees are never walked at serving time.
//!
//! Control lines: `"ping"` → `"pong"`, `"models"` → the registry
//! listing, `"schema"` → the default model's schema (or
//! `{"schema": "name"}` for any loaded model), `"stats"` →
//! control/predict counters, per-model latency & throughput, and the
//! per-server connection/byte counters, and `"shutdown"` stops the
//! listener.
//!
//! ## Backends
//!
//! Two [`ServeBackend`]s sit behind one protocol implementation
//! ([`Server::handle`]), selected by [`ServeConfig::backend`]
//! (`serve --backend reactor|threads` on the CLI):
//!
//! * [`ServeBackend::Reactor`] — the default on Linux: a single-threaded
//!   epoll readiness loop ([`crate::coordinator::reactor`]) driving
//!   nonblocking accept and per-connection state machines. Scales to
//!   10k+ mostly-idle connections without 10k threads.
//! * [`ServeBackend::Threads`] — the portable fallback and behavioral
//!   oracle: one OS thread per connection, blocking I/O with short
//!   timeout ticks. Byte-identical protocol behavior (enforced by
//!   `tests/serve_parity.rs`).
//!
//! Both backends share the same limits ([`ServeConfig`]): a connection
//! budget with graceful over-limit rejection, and a per-line
//! `max_request_bytes` cap answered with a typed JSON error before the
//! connection closes. Shutdown is wakeup-based in both: the reactor owns
//! a self-wakeup pipe, the threads backend force-wakes every blocked
//! client read by shutting its socket down — no multi-tick polling on
//! the exit path.

use crate::coordinator::reactor;
use crate::coordinator::registry::{ModelEntry, ModelRegistry};
use crate::data::value::Value;
use crate::error::{Result, UdtError};
use crate::inference::frame::json_cell;
use crate::inference::{Cell, RowFrame};
use crate::model::SavedModel;
use crate::tree::NodeLabel;
use crate::util::json::Json;
use std::collections::{BTreeMap, HashMap};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

/// How long a threads-backend client read blocks before re-checking the
/// shutdown flag. Since shutdown force-wakes blocked reads, the tick is
/// only a backstop against missed wakeups, not the shutdown latency.
const READ_TICK: Duration = Duration::from_millis(50);

/// How a [`Server`] drives its sockets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeBackend {
    /// One OS thread per connection (portable; the behavioral oracle).
    Threads,
    /// Single-threaded epoll readiness loop (Linux; the scalable default).
    Reactor,
}

impl ServeBackend {
    /// Parse a CLI/config spelling.
    pub fn parse(s: &str) -> Option<ServeBackend> {
        match s {
            "threads" => Some(ServeBackend::Threads),
            "reactor" => Some(ServeBackend::Reactor),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ServeBackend::Threads => "threads",
            ServeBackend::Reactor => "reactor",
        }
    }

    /// The reactor where the platform supports it, threads elsewhere.
    pub fn default_for_platform() -> ServeBackend {
        if reactor::SUPPORTED {
            ServeBackend::Reactor
        } else {
            ServeBackend::Threads
        }
    }
}

/// Serving limits and backend selection, shared by both backends.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub backend: ServeBackend,
    /// Connection budget: accepts past this are answered with a typed
    /// JSON error line and closed immediately.
    pub max_connections: usize,
    /// Per-request-line byte cap (newline excluded). An oversized line
    /// gets a typed JSON error and the connection is closed.
    pub max_request_bytes: usize,
    /// Reactor-only: per-connection pending-write cap. A peer that stops
    /// draining its socket while this much output is buffered is judged
    /// abusive and closed (the threads backend blocks the one connection
    /// thread instead, which is its inherent backpressure).
    pub max_write_buffer_bytes: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            backend: ServeBackend::default_for_platform(),
            max_connections: 10_240,
            max_request_bytes: 1 << 20,
            max_write_buffer_bytes: 8 << 20,
        }
    }
}

impl ServeConfig {
    pub fn validate(&self) -> Result<()> {
        if self.max_connections == 0 {
            return Err(UdtError::invalid_config("serve.max_connections must be >= 1"));
        }
        if self.max_request_bytes == 0 {
            return Err(UdtError::invalid_config("serve.max_request_bytes must be >= 1"));
        }
        if self.max_write_buffer_bytes == 0 {
            return Err(UdtError::invalid_config(
                "serve.max_write_buffer_bytes must be >= 1",
            ));
        }
        Ok(())
    }
}

/// Per-server connection & byte counters, reported under `"server"` in
/// the `stats` response and updated by both backends.
#[derive(Default)]
pub struct NetStats {
    active: AtomicU64,
    peak: AtomicU64,
    accepted: AtomicU64,
    rejected: AtomicU64,
    closed: AtomicU64,
    bytes_in: AtomicU64,
    bytes_out: AtomicU64,
    backpressure_stalls: AtomicU64,
}

/// A point-in-time copy of [`NetStats`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NetSnapshot {
    pub active: u64,
    pub peak: u64,
    pub accepted: u64,
    pub rejected: u64,
    pub closed: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
    pub backpressure_stalls: u64,
}

impl NetStats {
    pub(crate) fn conn_opened(&self) {
        let now = self.active.fetch_add(1, Ordering::Relaxed) + 1;
        self.peak.fetch_max(now, Ordering::Relaxed);
    }

    pub(crate) fn conn_closed(&self) {
        self.active.fetch_sub(1, Ordering::Relaxed);
        self.closed.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn inc_accepted(&self) {
        self.accepted.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn inc_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn inc_backpressure_stalls(&self) {
        self.backpressure_stalls.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn add_bytes_in(&self, n: u64) {
        self.bytes_in.fetch_add(n, Ordering::Relaxed);
    }

    pub(crate) fn add_bytes_out(&self, n: u64) {
        self.bytes_out.fetch_add(n, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> NetSnapshot {
        NetSnapshot {
            active: self.active.load(Ordering::Relaxed),
            peak: self.peak.load(Ordering::Relaxed),
            accepted: self.accepted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            closed: self.closed.load(Ordering::Relaxed),
            bytes_in: self.bytes_in.load(Ordering::Relaxed),
            bytes_out: self.bytes_out.load(Ordering::Relaxed),
            backpressure_stalls: self.backpressure_stalls.load(Ordering::Relaxed),
        }
    }
}

/// The typed error line an over-budget connection receives before being
/// closed. Shared by both backends so rejection is byte-identical.
pub(crate) fn over_budget_line(max_connections: usize) -> String {
    error_json(&UdtError::predict(format!(
        "connection budget exhausted (max {max_connections} connections)"
    )))
}

/// The typed error line an oversized request line receives before its
/// connection is closed. Shared by both backends.
pub(crate) fn oversize_line(max_request_bytes: usize) -> String {
    error_json(&UdtError::predict(format!(
        "request line exceeds max_request_bytes ({max_request_bytes} bytes)"
    )))
}

/// Shared server state: the model registry plus global counters.
pub struct Server {
    registry: ModelRegistry,
    /// Protocol control lines handled (ping / stats / schema / models /
    /// shutdown) — *not* predictions.
    control_requests: AtomicU64,
    /// Prediction request lines handled (single rows and batches alike).
    predict_requests: AtomicU64,
    shutdown: AtomicBool,
    net: NetStats,
    /// Limits in force (set by [`Server::serve_with`]; defaults before).
    serve_cfg: RwLock<ServeConfig>,
    /// Which backend is currently serving, for the `stats` report.
    backend: RwLock<Option<ServeBackend>>,
    /// Backend-installed hook that interrupts blocked I/O so a shutdown
    /// takes effect immediately (reactor: self-wakeup pipe; threads:
    /// force-shutdown of every client socket).
    waker: Mutex<Option<Box<dyn Fn() + Send + Sync>>>,
}

impl Server {
    /// Serve a single model bundle under the name `"default"`.
    /// (Compilation happens here, once.)
    pub fn new(saved: SavedModel) -> Result<Arc<Self>> {
        let registry = ModelRegistry::new();
        registry.load("default", saved)?;
        Ok(Self::with_registry(registry))
    }

    /// Serve a pre-populated registry (multiple named models, aliases).
    pub fn with_registry(registry: ModelRegistry) -> Arc<Self> {
        Arc::new(Self {
            registry,
            control_requests: AtomicU64::new(0),
            predict_requests: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
            net: NetStats::default(),
            serve_cfg: RwLock::new(ServeConfig::default()),
            backend: RwLock::new(None),
            waker: Mutex::new(None),
        })
    }

    /// The live registry (models can be loaded / unloaded while serving).
    pub fn registry(&self) -> &ModelRegistry {
        &self.registry
    }

    /// Per-server connection & byte counters.
    pub fn net(&self) -> &NetStats {
        &self.net
    }

    /// Whether a shutdown has been requested (via the protocol or
    /// [`Server::request_shutdown`]).
    pub fn shutting_down(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Stop the server from any thread: sets the flag and fires the
    /// backend's wakeup hook so blocked I/O notices immediately.
    pub fn request_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.wake();
    }

    pub(crate) fn set_waker(&self, waker: Box<dyn Fn() + Send + Sync>) {
        *crate::util::sync::lock(&self.waker) = Some(waker);
    }

    pub(crate) fn clear_waker(&self) {
        *crate::util::sync::lock(&self.waker) = None;
    }

    pub(crate) fn wake(&self) {
        if let Some(w) = crate::util::sync::lock(&self.waker).as_ref() {
            w();
        }
    }

    /// Render a prediction: class name when the schema knows one.
    fn label_json(entry: &ModelEntry, label: NodeLabel) -> Json {
        match label {
            NodeLabel::Class(c) => match entry.schema.class_name(c) {
                Some(name) => Json::Str(name.to_string()),
                None => Json::Num(c as f64),
            },
            NodeLabel::Value(v) => Json::Num(v),
        }
    }

    /// Handle one request line; returns the response line.
    pub fn handle(&self, line: &str) -> String {
        let trimmed = line.trim();
        if let Some(resp) = self.handle_control(trimmed) {
            self.control_requests.fetch_add(1, Ordering::Relaxed);
            return resp;
        }
        let parsed = match Json::parse(trimmed) {
            Ok(p) => p,
            Err(e) => {
                self.predict_requests.fetch_add(1, Ordering::Relaxed);
                return error_json(&UdtError::predict(e.to_string()));
            }
        };
        // `{"schema": "name"}` — the addressed counterpart of the bare
        // "schema" control line (any loaded model, not just the default).
        if parsed.get("schema").is_some() {
            self.control_requests.fetch_add(1, Ordering::Relaxed);
            return match self.named_schema(&parsed) {
                Ok(j) => j.to_string(),
                Err(e) => error_json(&e),
            };
        }
        self.predict_requests.fetch_add(1, Ordering::Relaxed);
        match self.handle_predict(&parsed) {
            Ok(j) => j.to_string(),
            Err(e) => error_json(&e),
        }
    }

    /// Schema of a named model (or alias).
    fn named_schema(&self, parsed: &Json) -> Result<Json> {
        let name = parsed
            .get("schema")
            .and_then(Json::as_str)
            .ok_or_else(|| UdtError::predict("`schema` must be a model name string"))?;
        Ok(self.registry.get(Some(name))?.schema.to_json())
    }

    /// Control lines; `None` means the line is a prediction request.
    fn handle_control(&self, trimmed: &str) -> Option<String> {
        match trimmed {
            "\"ping\"" | "ping" => Some("\"pong\"".to_string()),
            "\"stats\"" | "stats" => Some(self.stats_json().to_string()),
            "\"models\"" | "models" => Some(self.models_json().to_string()),
            "\"schema\"" | "schema" => Some(match self.registry.get(None) {
                Ok(entry) => entry.schema.to_json().to_string(),
                Err(e) => Json::obj(vec![("error", Json::Str(e.to_string()))]).to_string(),
            }),
            "\"shutdown\"" | "shutdown" => {
                // Only the flag here: the backend fires its wakeup hook
                // *after* the "bye" reply is flushed, so the requester
                // still gets its response before sockets start closing.
                self.shutdown.store(true, Ordering::SeqCst);
                Some("\"bye\"".to_string())
            }
            _ => None,
        }
    }

    /// Registry listing: loaded names, aliases, the default.
    fn models_json(&self) -> Json {
        let aliases: BTreeMap<String, Json> = self
            .registry
            .aliases_list()
            .into_iter()
            .map(|(a, t)| (a, Json::Str(t)))
            .collect();
        Json::obj(vec![
            (
                "models",
                Json::Arr(self.registry.names().into_iter().map(Json::Str).collect()),
            ),
            ("aliases", Json::Obj(aliases)),
            (
                "default",
                self.registry
                    .default_name()
                    .map(Json::Str)
                    .unwrap_or(Json::Null),
            ),
        ])
    }

    /// The `"server"` section of `stats`: backend, limits, connection
    /// and byte counters.
    fn server_json(&self) -> Json {
        let cfg = crate::util::sync::read(&self.serve_cfg).clone();
        let backend = *crate::util::sync::read(&self.backend);
        let net = self.net.snapshot();
        Json::obj(vec![
            (
                "backend",
                backend.map(|b| Json::Str(b.name().to_string())).unwrap_or(Json::Null),
            ),
            ("max_connections", Json::Num(cfg.max_connections as f64)),
            ("max_request_bytes", Json::Num(cfg.max_request_bytes as f64)),
            (
                "max_write_buffer_bytes",
                Json::Num(cfg.max_write_buffer_bytes as f64),
            ),
            ("active_connections", Json::Num(net.active as f64)),
            ("peak_connections", Json::Num(net.peak as f64)),
            ("accepted", Json::Num(net.accepted as f64)),
            ("rejected", Json::Num(net.rejected as f64)),
            ("closed", Json::Num(net.closed as f64)),
            ("bytes_in", Json::Num(net.bytes_in as f64)),
            ("bytes_out", Json::Num(net.bytes_out as f64)),
            (
                "backpressure_stalls",
                Json::Num(net.backpressure_stalls as f64),
            ),
        ])
    }

    /// Global + per-model counters. Latency is mean time inside the
    /// compiled predict per request; throughput is predictions per busy
    /// second.
    fn stats_json(&self) -> Json {
        let mut models: BTreeMap<String, Json> = BTreeMap::new();
        for entry in self.registry.entries() {
            let (reqs, preds, ns) = entry.counters();
            let busy_s = ns as f64 / 1e9;
            models.insert(
                entry.name().to_string(),
                Json::obj(vec![
                    ("kind", Json::Str(entry.compiled.kind().to_string())),
                    ("nodes", Json::Num(entry.compiled.n_nodes() as f64)),
                    (
                        "n_features",
                        Json::Num(entry.compiled.n_features() as f64),
                    ),
                    ("trees", Json::Num(entry.compiled.n_trees() as f64)),
                    // Boosting rounds (0 for non-boosted families).
                    ("rounds", Json::Num(entry.compiled.n_rounds() as f64)),
                    (
                        "table_bytes",
                        Json::Num(entry.compiled.table_bytes() as f64),
                    ),
                    ("predict_requests", Json::Num(reqs as f64)),
                    ("predictions", Json::Num(preds as f64)),
                    ("busy_ms", Json::Num(ns as f64 / 1e6)),
                    (
                        "mean_ms",
                        Json::Num(if reqs > 0 {
                            ns as f64 / 1e6 / reqs as f64
                        } else {
                            0.0
                        }),
                    ),
                    (
                        "rows_per_sec",
                        Json::Num(if busy_s > 0.0 {
                            preds as f64 / busy_s
                        } else {
                            0.0
                        }),
                    ),
                ]),
            );
        }
        Json::obj(vec![
            (
                "control_requests",
                Json::Num(self.control_requests.load(Ordering::Relaxed) as f64),
            ),
            (
                "predict_requests",
                Json::Num(self.predict_requests.load(Ordering::Relaxed) as f64),
            ),
            (
                "default",
                self.registry
                    .default_name()
                    .map(Json::Str)
                    .unwrap_or(Json::Null),
            ),
            ("server", self.server_json()),
            ("pool", pool_json()),
            ("models", Json::Obj(models)),
        ])
    }

    fn handle_predict(&self, parsed: &Json) -> Result<Json> {
        match parsed {
            // Legacy form: bare row / batch → the default model.
            Json::Arr(items) => {
                let entry = self.registry.get(None)?;
                if matches!(items.first(), Some(Json::Arr(_))) {
                    let labels = self.predict_rows(&entry, batch_rows(items)?)?;
                    Ok(Json::Arr(labels))
                } else {
                    self.predict_one(&entry, items)
                }
            }
            // Addressed form: {"model": "name", "rows": [...]}.
            Json::Obj(_) => {
                let name = match parsed.get("model") {
                    None => None,
                    Some(j) => Some(j.as_str().ok_or_else(|| {
                        UdtError::predict("`model` must be a string")
                    })?),
                };
                let rows = parsed
                    .get("rows")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| UdtError::predict("request object needs a `rows` array"))?;
                let entry = self.registry.get(name)?;
                let labels = if rows.is_empty() {
                    // A well-formed empty batch (e.g. a proxy flushing an
                    // empty buffer) gets empty labels, not an arity error.
                    Vec::new()
                } else if matches!(rows.first(), Some(Json::Arr(_))) {
                    self.predict_rows(&entry, batch_rows(rows)?)?
                } else {
                    vec![self.predict_one(&entry, rows)?]
                };
                Ok(Json::obj(vec![
                    ("model", Json::Str(entry.name().to_string())),
                    ("labels", Json::Arr(labels)),
                ]))
            }
            _ => Err(UdtError::predict("request must be a JSON array or object")),
        }
    }

    /// Single-row fast path: resolve cells straight into model-space
    /// values through the bundled interner (unseen category → missing,
    /// exactly the frame path's routing) and walk the compiled tables —
    /// no per-request frame, interner or translation tables. Cell
    /// classification is the frame path's [`json_cell`] rule, so the two
    /// paths cannot drift apart.
    fn predict_one(&self, entry: &ModelEntry, cells: &[Json]) -> Result<Json> {
        let row: Vec<Value> = cells
            .iter()
            .map(|j| {
                Ok(match json_cell(j)? {
                    Cell::Missing => Value::Missing,
                    Cell::Num(x) => Value::Num(x),
                    Cell::Str(s) => match entry.interner.get(s) {
                        Some(id) => Value::Cat(id),
                        None => Value::Missing,
                    },
                })
            })
            .collect::<Result<_>>()?;
        let label = entry.predict_row(&row)?;
        Ok(Self::label_json(entry, label))
    }

    /// Parse a batch of rows into a frame once, predict on the compiled
    /// artifact, render labels through the entry's schema.
    fn predict_rows(&self, entry: &ModelEntry, rows: Vec<&[Json]>) -> Result<Vec<Json>> {
        let frame = RowFrame::from_json_rows(&rows)?;
        let preds = entry.predict_frame(&frame)?;
        Ok(preds
            .labels()
            .iter()
            .map(|&l| Self::label_json(entry, l))
            .collect())
    }

    /// Serve with default limits on the platform-default backend until a
    /// `shutdown` request arrives. Returns the bound address through
    /// `on_bound` (useful with port 0 in tests).
    pub fn serve(
        self: &Arc<Self>,
        addr: &str,
        on_bound: impl FnOnce(std::net::SocketAddr),
    ) -> Result<()> {
        self.serve_with(ServeConfig::default(), addr, on_bound)
    }

    /// Serve on the configured [`ServeBackend`] with explicit limits.
    pub fn serve_with(
        self: &Arc<Self>,
        cfg: ServeConfig,
        addr: &str,
        on_bound: impl FnOnce(std::net::SocketAddr),
    ) -> Result<()> {
        cfg.validate()?;
        let listener = TcpListener::bind(addr)?;
        on_bound(listener.local_addr()?);
        *crate::util::sync::write(&self.serve_cfg) = cfg.clone();
        *crate::util::sync::write(&self.backend) = Some(cfg.backend);
        let result = match cfg.backend {
            ServeBackend::Reactor => reactor::run(self, listener, &cfg),
            ServeBackend::Threads => self.serve_threads(listener, &cfg),
        };
        self.clear_waker();
        result
    }

    /// The thread-per-connection backend: nonblocking accept loop plus
    /// one scoped thread per client.
    fn serve_threads(self: &Arc<Self>, listener: TcpListener, cfg: &ServeConfig) -> Result<()> {
        listener.set_nonblocking(true)?;
        // Live client sockets, keyed by connection id. The waker closure
        // force-shuts every one of them so blocked reads return
        // immediately on shutdown instead of waiting out a READ_TICK.
        let conns: Arc<Mutex<HashMap<u64, TcpStream>>> = Arc::default();
        {
            let conns = Arc::clone(&conns);
            self.set_waker(Box::new(move || {
                for stream in crate::util::sync::lock(&conns).values() {
                    let _ = stream.shutdown(std::net::Shutdown::Both);
                }
            }));
        }
        let mut next_id = 0u64;
        // ANALYZE-ALLOW(thread-spawn): per-connection I/O threads ARE this backend's design; compute still goes through runtime::pool
        std::thread::scope(|scope| -> Result<()> {
            loop {
                if self.shutting_down() {
                    break;
                }
                match listener.accept() {
                    Ok((stream, _)) => {
                        self.net.inc_accepted();
                        if self.net.snapshot().active as usize >= cfg.max_connections {
                            self.net.inc_rejected();
                            let _ = reject_over_budget(&stream, cfg.max_connections, &self.net);
                            continue;
                        }
                        let Ok(handle) = stream.try_clone() else {
                            continue;
                        };
                        let id = next_id;
                        next_id += 1;
                        self.net.conn_opened();
                        crate::util::sync::lock(&conns).insert(id, handle);
                        let server = Arc::clone(self);
                        let conns = Arc::clone(&conns);
                        let max_request_bytes = cfg.max_request_bytes;
                        scope.spawn(move || {
                            let _ = server.client_loop(stream, max_request_bytes);
                            crate::util::sync::lock(&conns).remove(&id);
                            server.net.conn_closed();
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(e) => {
                        // Wake every client loop so the scope can join
                        // before the error propagates — otherwise an idle
                        // connection would pin serve() open forever with
                        // the error swallowed.
                        self.request_shutdown();
                        return Err(e.into());
                    }
                }
            }
            Ok(())
        })
    }

    /// One threads-backend connection. Reads are capped at
    /// `max_request_bytes` per line; responses go through a `BufWriter`
    /// and flush once per line (one syscall, not two). An **idle** client
    /// is woken by the shutdown waker (socket force-shutdown → EOF), with
    /// [`READ_TICK`] as the backstop.
    fn client_loop(&self, stream: TcpStream, max_request_bytes: usize) -> Result<()> {
        // On BSD-likes an accepted socket inherits the listener's
        // O_NONBLOCK, which would defeat the timeouts below (instant
        // WouldBlock → busy-spin). Force blocking mode first.
        stream.set_nonblocking(false)?;
        stream.set_read_timeout(Some(READ_TICK))?;
        stream.set_write_timeout(Some(READ_TICK))?;
        let mut writer = BufWriter::new(stream.try_clone()?);
        let mut reader = BufReader::new(stream);
        // Accumulate raw bytes, not a String: a UTF-8 guard would
        // *discard* bytes already consumed from the socket when a
        // timeout tick lands inside a multibyte character; the byte
        // buffer keeps every partial read across ticks. UTF-8 conversion
        // happens once per complete line.
        let mut buf: Vec<u8> = Vec::new();
        loop {
            if self.shutting_down() {
                break;
            }
            match read_step(&mut reader, &mut buf, &self.net)? {
                ReadStep::Tick => {}
                ReadStep::Eof => {
                    // Client hung up; a final unterminated line may still
                    // be buffered — answer it like `BufReader::lines`
                    // used to.
                    if buf.len() > max_request_bytes {
                        let _ = self.write_line(&mut writer, oversize_line(max_request_bytes));
                        break;
                    }
                    let line = String::from_utf8_lossy(&buf);
                    if !line.trim().is_empty() {
                        let resp = self.handle(&line);
                        self.write_line(&mut writer, resp)?;
                    }
                    break;
                }
                ReadStep::Line => {
                    // buf ends with the newline; the cap is on the line
                    // bytes proper.
                    if buf.len() - 1 > max_request_bytes {
                        let _ = self.write_line(&mut writer, oversize_line(max_request_bytes));
                        break;
                    }
                    let line = String::from_utf8_lossy(&buf);
                    if !line.trim().is_empty() {
                        let resp = self.handle(&line);
                        self.write_line(&mut writer, resp)?;
                    }
                    buf.clear();
                    if self.shutting_down() {
                        // This line's response (e.g. "bye") is flushed;
                        // now wake every other blocked client.
                        self.wake();
                        break;
                    }
                }
                ReadStep::Partial => {
                    if buf.len() > max_request_bytes {
                        let _ = self.write_line(&mut writer, oversize_line(max_request_bytes));
                        break;
                    }
                }
            }
        }
        Ok(())
    }

    /// Write one response line through the `BufWriter` and flush it once
    /// (one syscall per response in the common case). Writes carry the
    /// same tick discipline as reads: a peer that stops draining its
    /// socket (kernel send buffer full) times out every [`READ_TICK`]
    /// and the loop then checks the shutdown flag instead of pinning the
    /// serve scope open forever. The flag is checked only *after* a
    /// failed attempt — never before the first — so the `"bye"` reply to
    /// the very request that set it still goes out to a live client.
    /// Offsets track raw `write` calls, so a timed-out attempt never
    /// duplicates bytes; abandoning a response mid-shutdown is fine (the
    /// connection is going away).
    fn write_line(&self, writer: &mut BufWriter<TcpStream>, resp: String) -> Result<()> {
        let mut out = resp.into_bytes();
        out.push(b'\n');
        let mut off = 0;
        while off < out.len() {
            match writer.write(&out[off..]) {
                Ok(0) => return Err(std::io::Error::from(std::io::ErrorKind::WriteZero).into()),
                Ok(n) => off += n,
                Err(e) if is_tick(&e) => {
                    if self.shutting_down() {
                        return Ok(());
                    }
                }
                Err(e) => return Err(e.into()),
            }
        }
        loop {
            match writer.flush() {
                Ok(()) => {
                    self.net.add_bytes_out(out.len() as u64);
                    return Ok(());
                }
                Err(e) if is_tick(&e) => {
                    if self.shutting_down() {
                        return Ok(());
                    }
                }
                Err(e) => return Err(e.into()),
            }
        }
    }
}

/// What one bounded read step produced.
enum ReadStep {
    /// `buf` now ends with a complete, newline-terminated line.
    Line,
    /// More bytes arrived but no newline yet.
    Partial,
    /// Read timeout tick (partial data, if any, stays in `buf`).
    Tick,
    /// Peer closed its write side.
    Eof,
}

/// Pull the next chunk out of the reader into `buf`, stopping at the
/// first newline. Bounded by the `BufReader` buffer per call, so the
/// caller can enforce `max_request_bytes` between steps instead of
/// handing `read_until` an unbounded allocation.
fn read_step(
    reader: &mut BufReader<TcpStream>,
    buf: &mut Vec<u8>,
    net: &NetStats,
) -> Result<ReadStep> {
    let available = match reader.fill_buf() {
        Ok(a) => a,
        Err(e) if is_tick(&e) => return Ok(ReadStep::Tick),
        Err(e) => return Err(e.into()),
    };
    if available.is_empty() {
        return Ok(ReadStep::Eof);
    }
    let (take, complete) = match available.iter().position(|&b| b == b'\n') {
        Some(pos) => (pos + 1, true),
        None => (available.len(), false),
    };
    buf.extend_from_slice(&available[..take]);
    reader.consume(take);
    net.add_bytes_in(take as u64);
    Ok(if complete {
        ReadStep::Line
    } else {
        ReadStep::Partial
    })
}

/// Best-effort rejection of an over-budget connection: one typed error
/// line, then the socket drops. The write is bounded by a tick so a
/// malicious non-reading peer cannot stall the accept loop.
fn reject_over_budget(stream: &TcpStream, max_connections: usize, net: &NetStats) -> Result<()> {
    stream.set_nonblocking(false)?;
    stream.set_write_timeout(Some(READ_TICK))?;
    let mut line = over_budget_line(max_connections).into_bytes();
    line.push(b'\n');
    (&mut &*stream).write_all(&line)?;
    net.add_bytes_out(line.len() as u64);
    Ok(())
}

/// The persistent worker pool's process-wide counters (see
/// [`crate::runtime::pool`]) — the `stats` witness that concurrent
/// predict batches reuse one set of threads instead of spawning.
fn pool_json() -> Json {
    let s = crate::runtime::pool_stats();
    Json::obj(vec![
        ("cores", Json::Num(crate::runtime::cores() as f64)),
        (
            "threads_spawned_total",
            Json::Num(s.threads_spawned_total as f64),
        ),
        ("batches_submitted", Json::Num(s.batches_submitted as f64)),
        ("tasks_executed", Json::Num(s.tasks_executed as f64)),
        ("park_wakeups", Json::Num(s.park_wakeups as f64)),
    ])
}

/// Render an error as a protocol `{"error": ...}` response line.
fn error_json(e: &UdtError) -> String {
    Json::obj(vec![("error", Json::Str(e.to_string()))]).to_string()
}

/// A retryable socket-timeout tick (vs a real I/O failure).
fn is_tick(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock
            | std::io::ErrorKind::TimedOut
            | std::io::ErrorKind::Interrupted
    )
}

/// Borrow a batch request's rows as slices, rejecting non-array rows.
fn batch_rows(items: &[Json]) -> Result<Vec<&[Json]>> {
    items
        .iter()
        .map(|row| {
            row.as_arr()
                .ok_or_else(|| UdtError::predict("batch rows must be arrays"))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate_classification, SynthSpec};
    use crate::model::{Model, Udt};
    use std::time::Instant;

    fn server() -> Arc<Server> {
        let mut spec = SynthSpec::classification("srv", 500, 4, 2);
        spec.cat_frac = 0.3;
        let ds = generate_classification(&spec, 61);
        let tree = Udt::builder().fit(&ds).unwrap();
        Server::new(SavedModel::new(Model::SingleTree(tree), &ds)).unwrap()
    }

    fn backends() -> Vec<ServeBackend> {
        if reactor::SUPPORTED {
            vec![ServeBackend::Threads, ServeBackend::Reactor]
        } else {
            vec![ServeBackend::Threads]
        }
    }

    #[test]
    fn backend_parses_and_names_round_trip() {
        assert_eq!(ServeBackend::parse("threads"), Some(ServeBackend::Threads));
        assert_eq!(ServeBackend::parse("reactor"), Some(ServeBackend::Reactor));
        assert_eq!(ServeBackend::parse("tokio"), None);
        for b in [ServeBackend::Threads, ServeBackend::Reactor] {
            assert_eq!(ServeBackend::parse(b.name()), Some(b));
        }
        if reactor::SUPPORTED {
            assert_eq!(ServeBackend::default_for_platform(), ServeBackend::Reactor);
        }
    }

    #[test]
    fn serve_config_validates_limits() {
        assert!(ServeConfig::default().validate().is_ok());
        for field in 0..3 {
            let mut cfg = ServeConfig::default();
            match field {
                0 => cfg.max_connections = 0,
                1 => cfg.max_request_bytes = 0,
                _ => cfg.max_write_buffer_bytes = 0,
            }
            assert!(cfg.validate().is_err(), "field {field}");
        }
    }

    #[test]
    fn shared_error_lines_are_typed_json() {
        for line in [over_budget_line(7), oversize_line(64)] {
            let doc = Json::parse(&line).unwrap();
            assert!(doc.get("error").unwrap().as_str().is_some(), "{line}");
        }
        assert!(over_budget_line(7).contains("max 7 connections"));
        assert!(oversize_line(64).contains("64 bytes"));
    }

    #[test]
    fn ping_and_stats() {
        let s = server();
        assert_eq!(s.handle("\"ping\""), "\"pong\"");
        let stats = Json::parse(&s.handle("stats")).unwrap();
        assert!(stats.get("control_requests").unwrap().as_f64().unwrap() >= 1.0);
        assert_eq!(stats.get("default").unwrap().as_str().unwrap(), "default");
        let model = stats.get("models").unwrap().get("default").unwrap();
        assert_eq!(model.get("kind").unwrap().as_str().unwrap(), "single_tree");
        assert!(model.get("nodes").unwrap().as_f64().unwrap() > 0.0);
        // The per-server section is present even before serving starts.
        let srv = stats.get("server").unwrap();
        assert_eq!(srv.get("active_connections").unwrap().as_f64().unwrap(), 0.0);
        assert!(srv.get("max_connections").unwrap().as_f64().unwrap() >= 1.0);
        // The worker-pool section reports the process-wide counters;
        // the spawn total can never exceed the cores() - 1 cap.
        let pool = stats.get("pool").unwrap();
        let cores = pool.get("cores").unwrap().as_f64().unwrap();
        assert!(cores >= 1.0);
        let spawned = pool.get("threads_spawned_total").unwrap().as_f64().unwrap();
        assert!(spawned <= cores, "spawned {spawned} > cores {cores}");
        assert!(pool.get("batches_submitted").unwrap().as_f64().unwrap() >= 0.0);
    }

    #[test]
    fn control_lines_do_not_count_as_predictions() {
        let s = server();
        s.handle("ping");
        s.handle("models");
        s.handle("[1.0, 2.0, 3.0, null]");
        let stats = Json::parse(&s.handle("stats")).unwrap();
        // ping + models (stats itself counts after the snapshot).
        assert_eq!(
            stats.get("control_requests").unwrap().as_f64().unwrap(),
            2.0
        );
        assert_eq!(
            stats.get("predict_requests").unwrap().as_f64().unwrap(),
            1.0
        );
        let model = stats.get("models").unwrap().get("default").unwrap();
        assert_eq!(
            model.get("predict_requests").unwrap().as_f64().unwrap(),
            1.0
        );
        assert_eq!(model.get("predictions").unwrap().as_f64().unwrap(), 1.0);
    }

    #[test]
    fn schema_request_lists_features() {
        let s = server();
        let schema = Json::parse(&s.handle("schema")).unwrap();
        assert_eq!(schema.get("features").unwrap().as_arr().unwrap().len(), 4);
    }

    #[test]
    fn single_and_batch_predictions() {
        let s = server();
        let row = "[1.0, 2.0, 3.0, null]";
        let r1 = s.handle(row);
        assert!(r1.starts_with('"') || r1.parse::<f64>().is_ok(), "{r1}");
        let batch = format!("[{row}, {row}]");
        let rb = Json::parse(&s.handle(&batch)).unwrap();
        assert_eq!(rb.as_arr().unwrap().len(), 2);
    }

    #[test]
    fn named_model_requests_address_the_registry() {
        let mut spec = SynthSpec::classification("srv2", 400, 4, 2);
        spec.cat_frac = 0.3;
        let ds = generate_classification(&spec, 67);
        let registry = ModelRegistry::new();
        registry
            .load(
                "a",
                SavedModel::new(Model::SingleTree(Udt::builder().fit(&ds).unwrap()), &ds),
            )
            .unwrap();
        registry
            .load(
                "b",
                SavedModel::new(
                    Model::Forest(
                        crate::tree::forest::Forest::fit(
                            &ds,
                            &crate::tree::forest::ForestConfig {
                                n_trees: 3,
                                ..Default::default()
                            },
                        )
                        .unwrap(),
                    ),
                    &ds,
                ),
            )
            .unwrap();
        registry.alias("prod", "b").unwrap();
        let s = Server::with_registry(registry);

        let resp = Json::parse(&s.handle(r#"{"model":"b","rows":[[1,2,3,4],[4,3,2,1]]}"#)).unwrap();
        assert_eq!(resp.get("model").unwrap().as_str().unwrap(), "b");
        assert_eq!(resp.get("labels").unwrap().as_arr().unwrap().len(), 2);
        // Aliases resolve to the canonical name.
        let resp = Json::parse(&s.handle(r#"{"model":"prod","rows":[1,2,3,4]}"#)).unwrap();
        assert_eq!(resp.get("model").unwrap().as_str().unwrap(), "b");
        assert_eq!(resp.get("labels").unwrap().as_arr().unwrap().len(), 1);
        // A well-formed empty batch yields empty labels, not an error.
        let resp = Json::parse(&s.handle(r#"{"model":"b","rows":[]}"#)).unwrap();
        assert_eq!(resp.get("labels").unwrap().as_arr().unwrap().len(), 0);
        // Any loaded model's schema is reachable by name.
        let schema = Json::parse(&s.handle(r#"{"schema":"b"}"#)).unwrap();
        assert_eq!(schema.get("features").unwrap().as_arr().unwrap().len(), 4);
        let resp = s.handle(r#"{"schema":"gone"}"#);
        assert!(resp.contains("error"), "{resp}");
        // Unknown names are protocol errors, not panics.
        let resp = Json::parse(&s.handle(r#"{"model":"nope","rows":[[1,2,3,4]]}"#)).unwrap();
        assert!(resp.get("error").unwrap().as_str().unwrap().contains("nope"));
        // Bare arrays still hit the default (first-loaded) model.
        let legacy = s.handle("[1.0, 2.0, 3.0, 4.0]");
        assert!(!legacy.contains("error"), "{legacy}");
        // Both models show in the listing.
        let models = Json::parse(&s.handle("models")).unwrap();
        assert_eq!(models.get("models").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(models.get("default").unwrap().as_str().unwrap(), "a");
        assert_eq!(
            models.get("aliases").unwrap().get("prod").unwrap().as_str().unwrap(),
            "b"
        );
    }

    #[test]
    fn wrong_arity_is_error() {
        let s = server();
        let resp = Json::parse(&s.handle("[1.0]")).unwrap();
        assert!(resp.get("error").is_some());
    }

    #[test]
    fn unseen_category_is_treated_as_missing() {
        let s = server();
        let r = s.handle("[\"never-seen-category\", 1.0, 1.0, 1.0]");
        assert!(!r.contains("error"), "{r}");
    }

    #[test]
    fn tcp_round_trip_on_every_backend() {
        for backend in backends() {
            let s = server();
            let cfg = ServeConfig {
                backend,
                ..Default::default()
            };
            let (tx, rx) = std::sync::mpsc::channel();
            let s2 = Arc::clone(&s);
            let handle = std::thread::spawn(move || {
                s2.serve_with(cfg, "127.0.0.1:0", |addr| tx.send(addr).unwrap())
                    .unwrap();
            });
            let addr = rx.recv().unwrap();
            let mut stream = TcpStream::connect(addr).unwrap();
            stream.write_all(b"\"ping\"\n").unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            assert_eq!(line.trim(), "\"pong\"", "{}", backend.name());
            // The live stats report names the serving backend.
            stream.write_all(b"stats\n").unwrap();
            line.clear();
            reader.read_line(&mut line).unwrap();
            let stats = Json::parse(&line).unwrap();
            assert_eq!(
                stats.get("server").unwrap().get("backend").unwrap().as_str().unwrap(),
                backend.name()
            );
            stream.write_all(b"\"shutdown\"\n").unwrap();
            line.clear();
            reader.read_line(&mut line).unwrap();
            assert_eq!(line.trim(), "\"bye\"", "{}", backend.name());
            handle.join().unwrap();
        }
    }

    #[test]
    fn shutdown_terminates_despite_idle_connection() {
        // Regression: an idle client used to pin `serve` open forever,
        // then (pre-waker) for up to a READ_TICK. Shutdown is now
        // wakeup-driven in both backends, so the whole teardown —
        // including the idle connection — finishes in well under one
        // 50 ms tick.
        for backend in backends() {
            let s = server();
            let cfg = ServeConfig {
                backend,
                ..Default::default()
            };
            let (tx, rx) = std::sync::mpsc::channel();
            let (done_tx, done_rx) = std::sync::mpsc::channel();
            let s2 = Arc::clone(&s);
            let handle = std::thread::spawn(move || {
                s2.serve_with(cfg, "127.0.0.1:0", |addr| tx.send(addr).unwrap())
                    .unwrap();
                done_tx.send(()).unwrap();
            });
            let addr = rx.recv().unwrap();
            // A client that connects and then says nothing.
            let idle = TcpStream::connect(addr).unwrap();
            // A second client issues the shutdown.
            let mut ctl = TcpStream::connect(addr).unwrap();
            ctl.write_all(b"\"shutdown\"\n").unwrap();
            let mut reader = BufReader::new(ctl.try_clone().unwrap());
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            assert_eq!(line.trim(), "\"bye\"");
            // Sub-tick: serve() must return without waiting out a
            // READ_TICK on the idle connection.
            let start = Instant::now();
            done_rx
                .recv_timeout(Duration::from_secs(10))
                .expect("serve() hung on the idle connection");
            assert!(
                start.elapsed() < READ_TICK,
                "{} backend shutdown took {:?} (>= one {:?} tick)",
                backend.name(),
                start.elapsed(),
                READ_TICK
            );
            handle.join().unwrap();
            drop(idle);
        }
    }
}
