//! Batch prediction server over any [`Model`] family.
//!
//! A small line-oriented TCP protocol (std::net + a worker pool; the
//! offline image has no tokio): each request line is a JSON array of
//! feature values (numbers, strings, or null for missing) — or an array
//! of such arrays for a batch — and the response line is the JSON array
//! of predictions. Requests parse into rows once, then dispatch through
//! [`Model::predict_batch`], so the family match is amortized over the
//! whole batch and tuned trees / forests serve exactly like single trees.
//!
//! Control lines: `"ping"` → `"pong"`, `"stats"` → counters + model
//! identity, `"schema"` → the bundled [`Schema`], `"shutdown"` closes the
//! listener.

use crate::data::value::Value;
use crate::error::{Result, UdtError};
use crate::model::{Model, SavedModel};
use crate::tree::NodeLabel;
use crate::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

/// Shared server state: the model bundle plus counters.
pub struct Server {
    saved: SavedModel,
    requests: AtomicU64,
    predictions: AtomicU64,
    shutdown: AtomicBool,
}

impl Server {
    /// Serve a model bundle (any family; see [`SavedModel::load`]).
    pub fn new(saved: SavedModel) -> Arc<Self> {
        Arc::new(Self {
            saved,
            requests: AtomicU64::new(0),
            predictions: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
        })
    }

    /// The served model.
    pub fn model(&self) -> &Model {
        &self.saved.model
    }

    /// Parse one JSON value into a feature cell.
    fn cell(&self, j: &Json) -> Result<Value> {
        Ok(match j {
            Json::Null => Value::Missing,
            Json::Num(x) => Value::Num(*x),
            Json::Str(s) => match self.saved.interner.get(s) {
                Some(id) => Value::Cat(id),
                // Unseen category: behaves like "equal to nothing" — the
                // comparison semantics route it negative everywhere, which
                // is exactly what Missing does.
                None => Value::Missing,
            },
            other => return Err(UdtError::predict(format!("bad cell {other:?}"))),
        })
    }

    /// Parse one JSON row into feature cells.
    fn parse_row(&self, arr: &[Json]) -> Result<Vec<Value>> {
        arr.iter().map(|j| self.cell(j)).collect()
    }

    /// Render a prediction: class name when the schema knows one.
    fn label_json(&self, label: NodeLabel) -> Json {
        match label {
            NodeLabel::Class(c) => match self.saved.schema.class_name(c) {
                Some(name) => Json::Str(name.to_string()),
                None => Json::Num(c as f64),
            },
            NodeLabel::Value(v) => Json::Num(v),
        }
    }

    /// Handle one request line; returns the response line.
    pub fn handle(&self, line: &str) -> String {
        self.requests.fetch_add(1, Ordering::Relaxed);
        let trimmed = line.trim();
        if trimmed == "\"ping\"" || trimmed == "ping" {
            return "\"pong\"".to_string();
        }
        if trimmed == "\"stats\"" || trimmed == "stats" {
            return Json::obj(vec![
                (
                    "requests",
                    Json::Num(self.requests.load(Ordering::Relaxed) as f64),
                ),
                (
                    "predictions",
                    Json::Num(self.predictions.load(Ordering::Relaxed) as f64),
                ),
                ("kind", Json::Str(self.saved.model.kind().to_string())),
                ("nodes", Json::Num(self.saved.model.n_nodes() as f64)),
                (
                    "n_features",
                    Json::Num(self.saved.model.n_features() as f64),
                ),
            ])
            .to_string();
        }
        if trimmed == "\"schema\"" || trimmed == "schema" {
            return self.saved.schema.to_json().to_string();
        }
        if trimmed == "\"shutdown\"" || trimmed == "shutdown" {
            self.shutdown.store(true, Ordering::SeqCst);
            return "\"bye\"".to_string();
        }
        match self.handle_predict(trimmed) {
            Ok(j) => j.to_string(),
            Err(e) => Json::obj(vec![("error", Json::Str(e.to_string()))]).to_string(),
        }
    }

    fn handle_predict(&self, line: &str) -> Result<Json> {
        let parsed = Json::parse(line).map_err(|e| UdtError::predict(e.to_string()))?;
        let arr = parsed
            .as_arr()
            .ok_or_else(|| UdtError::predict("request must be a JSON array"))?;
        // Batch if the first element is itself an array.
        if matches!(arr.first(), Some(Json::Arr(_))) {
            let rows: Result<Vec<Vec<Value>>> = arr
                .iter()
                .map(|row| {
                    row.as_arr()
                        .ok_or_else(|| UdtError::predict("batch rows must be arrays"))
                        .and_then(|r| self.parse_row(r))
                })
                .collect();
            let rows = rows?;
            let labels = self.saved.model.predict_batch(&rows)?;
            self.predictions
                .fetch_add(labels.len() as u64, Ordering::Relaxed);
            Ok(Json::Arr(
                labels.into_iter().map(|l| self.label_json(l)).collect(),
            ))
        } else {
            let row = self.parse_row(arr)?;
            let label = self.saved.model.predict_row(&row)?;
            self.predictions.fetch_add(1, Ordering::Relaxed);
            Ok(self.label_json(label))
        }
    }

    /// Serve until a `shutdown` request arrives. Returns the bound address
    /// through `on_bound` (useful with port 0 in tests).
    pub fn serve(
        self: &Arc<Self>,
        addr: &str,
        on_bound: impl FnOnce(std::net::SocketAddr),
    ) -> Result<()> {
        let listener = TcpListener::bind(addr)?;
        on_bound(listener.local_addr()?);
        listener.set_nonblocking(true)?;
        std::thread::scope(|scope| -> Result<()> {
            loop {
                if self.shutdown.load(Ordering::SeqCst) {
                    break;
                }
                match listener.accept() {
                    Ok((stream, _)) => {
                        let server = Arc::clone(self);
                        scope.spawn(move || {
                            let _ = server.client_loop(stream);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(e) => return Err(e.into()),
                }
            }
            Ok(())
        })
    }

    fn client_loop(&self, stream: TcpStream) -> Result<()> {
        let mut writer = stream.try_clone()?;
        let reader = BufReader::new(stream);
        for line in reader.lines() {
            let line = line?;
            if line.is_empty() {
                continue;
            }
            let resp = self.handle(&line);
            writer.write_all(resp.as_bytes())?;
            writer.write_all(b"\n")?;
            if self.shutdown.load(Ordering::SeqCst) {
                break;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate_classification, SynthSpec};
    use crate::model::Udt;

    fn server() -> Arc<Server> {
        let mut spec = SynthSpec::classification("srv", 500, 4, 2);
        spec.cat_frac = 0.3;
        let ds = generate_classification(&spec, 61);
        let tree = Udt::builder().fit(&ds).unwrap();
        Server::new(SavedModel::new(Model::SingleTree(tree), &ds))
    }

    #[test]
    fn ping_and_stats() {
        let s = server();
        assert_eq!(s.handle("\"ping\""), "\"pong\"");
        let stats = Json::parse(&s.handle("stats")).unwrap();
        assert!(stats.get("requests").unwrap().as_f64().unwrap() >= 1.0);
        assert_eq!(stats.get("kind").unwrap().as_str().unwrap(), "single_tree");
    }

    #[test]
    fn schema_request_lists_features() {
        let s = server();
        let schema = Json::parse(&s.handle("schema")).unwrap();
        assert_eq!(schema.get("features").unwrap().as_arr().unwrap().len(), 4);
    }

    #[test]
    fn single_and_batch_predictions() {
        let s = server();
        let row = "[1.0, 2.0, 3.0, null]";
        let r1 = s.handle(row);
        assert!(r1.starts_with('"') || r1.parse::<f64>().is_ok(), "{r1}");
        let batch = format!("[{row}, {row}]");
        let rb = Json::parse(&s.handle(&batch)).unwrap();
        assert_eq!(rb.as_arr().unwrap().len(), 2);
    }

    #[test]
    fn wrong_arity_is_error() {
        let s = server();
        let resp = Json::parse(&s.handle("[1.0]")).unwrap();
        assert!(resp.get("error").is_some());
    }

    #[test]
    fn unseen_category_is_treated_as_missing() {
        let s = server();
        let r = s.handle("[\"never-seen-category\", 1.0, 1.0, 1.0]");
        assert!(!r.contains("error"), "{r}");
    }

    #[test]
    fn tcp_round_trip() {
        let s = server();
        let (tx, rx) = std::sync::mpsc::channel();
        let s2 = Arc::clone(&s);
        let handle = std::thread::spawn(move || {
            s2.serve("127.0.0.1:0", |addr| tx.send(addr).unwrap()).unwrap();
        });
        let addr = rx.recv().unwrap();
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(b"\"ping\"\n").unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "\"pong\"");
        stream.write_all(b"\"shutdown\"\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        handle.join().unwrap();
    }
}
