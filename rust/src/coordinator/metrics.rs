//! Evaluation metrics: accuracy, confusion matrix, per-class PR/F1,
//! MAE/RMSE/R² for regression.

use crate::data::dataset::{Dataset, TaskKind};
use crate::error::{Result, UdtError};
use crate::tree::{predict::predict_ds, require_task, Tree};

/// Confusion matrix with derived statistics.
#[derive(Debug, Clone)]
pub struct Confusion {
    pub n_classes: usize,
    /// `counts[actual][predicted]`.
    pub counts: Vec<Vec<u32>>,
}

impl Confusion {
    pub fn from_tree(tree: &Tree, ds: &Dataset, rows: &[u32]) -> Result<Self> {
        require_task(TaskKind::Classification, tree.task)?;
        require_task(TaskKind::Classification, ds.task())?;
        let c = ds.labels.n_classes();
        let mut counts = vec![vec![0u32; c]; c];
        for &r in rows {
            let pred = predict_ds(tree, ds, r as usize, usize::MAX, 0)
                .as_class()
                .unwrap_or(0) as usize;
            let actual = ds.labels.class(r as usize) as usize;
            // A deserialized model can carry class ids the dataset does
            // not know; surface that as a typed error, not a panic.
            let cell = counts
                .get_mut(actual)
                .and_then(|row| row.get_mut(pred))
                .ok_or_else(|| {
                    UdtError::predict(format!(
                        "class id out of range: predicted {pred}, actual {actual}, n_classes {c}"
                    ))
                })?;
            *cell += 1;
        }
        Ok(Self {
            n_classes: c,
            counts,
        })
    }

    pub fn total(&self) -> u64 {
        self.counts
            .iter()
            .flat_map(|r| r.iter())
            .map(|&x| x as u64)
            .sum()
    }

    pub fn accuracy(&self) -> f64 {
        let correct: u64 = (0..self.n_classes).map(|i| self.counts[i][i] as u64).sum();
        correct as f64 / self.total().max(1) as f64
    }

    /// (precision, recall, f1) for one class; NaN-free (0 where undefined).
    pub fn prf(&self, class: usize) -> (f64, f64, f64) {
        let tp = self.counts[class][class] as f64;
        let pred: f64 = (0..self.n_classes).map(|a| self.counts[a][class] as f64).sum();
        let actual: f64 = self.counts[class].iter().map(|&x| x as f64).sum();
        let precision = if pred > 0.0 { tp / pred } else { 0.0 };
        let recall = if actual > 0.0 { tp / actual } else { 0.0 };
        let f1 = if precision + recall > 0.0 {
            2.0 * precision * recall / (precision + recall)
        } else {
            0.0
        };
        (precision, recall, f1)
    }

    /// Macro-averaged F1.
    pub fn macro_f1(&self) -> f64 {
        (0..self.n_classes).map(|c| self.prf(c).2).sum::<f64>() / self.n_classes.max(1) as f64
    }
}

/// Regression report.
#[derive(Debug, Clone, Copy)]
pub struct RegReport {
    pub mae: f64,
    pub rmse: f64,
    pub r2: f64,
}

impl RegReport {
    pub fn from_tree(tree: &Tree, ds: &Dataset, rows: &[u32]) -> Result<Self> {
        require_task(TaskKind::Regression, tree.task)?;
        require_task(TaskKind::Regression, ds.task())?;
        let n = rows.len() as f64;
        let mean: f64 = rows
            .iter()
            .map(|&r| ds.labels.target(r as usize))
            .sum::<f64>()
            / n;
        let (mut abs, mut sq, mut tot_sq) = (0.0, 0.0, 0.0);
        for &r in rows {
            let y = ds.labels.target(r as usize);
            let pred = predict_ds(tree, ds, r as usize, usize::MAX, 0)
                .as_value()
                .unwrap_or(f64::NAN);
            abs += (pred - y).abs();
            sq += (pred - y) * (pred - y);
            tot_sq += (y - mean) * (y - mean);
        }
        Ok(RegReport {
            mae: abs / n,
            rmse: (sq / n).sqrt(),
            r2: if tot_sq > 0.0 { 1.0 - sq / tot_sq } else { 0.0 },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate_classification, generate_regression, SynthSpec};
    use crate::tree::TrainConfig;

    #[test]
    fn confusion_consistent_with_accuracy() {
        let spec = SynthSpec::classification("t", 800, 5, 3);
        let ds = generate_classification(&spec, 41);
        let tree = Tree::fit(&ds, &TrainConfig::default()).unwrap();
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let cm = Confusion::from_tree(&tree, &ds, &rows).unwrap();
        assert_eq!(cm.total() as usize, ds.n_rows());
        assert!((cm.accuracy() - tree.accuracy(&ds).unwrap()).abs() < 1e-12);
        assert!(cm.macro_f1() > 0.5);
    }

    #[test]
    fn prf_bounds() {
        let spec = SynthSpec::classification("t", 500, 4, 2);
        let ds = generate_classification(&spec, 43);
        let tree = Tree::fit(&ds, &TrainConfig::default()).unwrap();
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let cm = Confusion::from_tree(&tree, &ds, &rows).unwrap();
        for c in 0..2 {
            let (p, r, f1) = cm.prf(c);
            for v in [p, r, f1] {
                assert!((0.0..=1.0).contains(&v));
            }
        }
    }

    #[test]
    fn regression_report_r2_near_one_on_train() {
        let spec = SynthSpec::regression("r", 600, 5);
        let ds = generate_regression(&spec, 47);
        let tree = Tree::fit(&ds, &TrainConfig::default()).unwrap();
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let rep = RegReport::from_tree(&tree, &ds, &rows).unwrap();
        assert!(rep.r2 > 0.9, "r2={}", rep.r2);
        assert!(rep.mae <= rep.rmse + 1e-12);
    }
}
