//! The paper's end-to-end experiment pipeline (§4): 80/10/10 split →
//! train the full tree → Training-Only-Once Tuning on the validation set
//! → prune → report test quality → retrain once with the tuned
//! hyper-parameters (the paper's separately-reported "tuned tree
//! train(ms)" column).

use crate::data::dataset::{Dataset, TaskKind};
use crate::error::Result;
use crate::model::Model;
use crate::tree::tuning::{tune_and_prune, TuneGrid};
use crate::tree::{TrainConfig, Tree};
use crate::util::timer::Timer;

pub use crate::model::Quality;

/// One row of Table 6 / Table 7.
#[derive(Debug, Clone)]
pub struct PipelineReport {
    pub dataset: String,
    pub n_examples: usize,
    /// Rows the full tree actually trained on (the 80% split).
    pub n_train: usize,
    pub n_features: usize,
    pub n_labels: usize,
    // Full tree.
    pub full_nodes: usize,
    pub full_depth: u16,
    pub full_train_ms: f64,
    /// Peak bytes of the builder's double-buffered arenas during the
    /// full-tree fit (see [`crate::tree::frontier::ArenaStats`]).
    pub peak_arena_bytes: usize,
    /// Peak bytes of the binned backend's per-node histogram buffers
    /// during the full-tree fit; 0 for the exact backends.
    pub hist_scratch_bytes: usize,
    /// Out-of-core training only (`train --shards`): largest decoded
    /// shard window resident at any point — the bounded-RAM witness of
    /// [`crate::tree::sharded::ShardedStats`]. 0 for in-memory training.
    pub peak_shard_window_bytes: usize,
    /// Out-of-core training only: sequential passes over the shard
    /// directory. 0 for in-memory training.
    pub shard_passes: usize,
    /// Batches handed to the persistent worker pool during this
    /// pipeline run (full fit + tune + tuned retrain). 0 when the run
    /// was sequential (`n_threads == 1` or a 1-core machine).
    pub pool_batches: u64,
    /// Items executed on the pool during this run.
    pub pool_tasks: u64,
    /// Pool worker threads spawned *during* this run. At most
    /// [`crate::runtime::cores`]` - 1` on the first parallel batch of
    /// the process, 0 on every run after — the per-level/per-round
    /// spawn tax is gone (see [`crate::runtime::pool`]).
    pub pool_threads_spawned: u64,
    // Tuning.
    pub tune_ms: f64,
    pub n_settings: usize,
    pub best_max_depth: usize,
    pub best_min_split: usize,
    // Tuned tree.
    pub quality: Quality,
    pub tuned_nodes: usize,
    pub tuned_depth: u16,
    pub tuned_train_ms: f64,
}

/// Run the full paper pipeline on one dataset. The tuning `grid` comes
/// from [`TuneGrid::default`] or the `tune.*` configuration keys.
pub fn run_pipeline(
    ds: &Dataset,
    config: &TrainConfig,
    grid: &TuneGrid,
    split_seed: u64,
) -> Result<PipelineReport> {
    run_pipeline_model(ds, config, grid, split_seed).map(|(report, _)| report)
}

/// [`run_pipeline`], additionally returning the servable artifact: a
/// [`Model::TunedTree`] carrying the full tree plus the Training-Only-Once
/// effective `(max_depth, min_split)`.
pub fn run_pipeline_model(
    ds: &Dataset,
    config: &TrainConfig,
    grid: &TuneGrid,
    split_seed: u64,
) -> Result<(PipelineReport, Model)> {
    let (train, val, test) = ds.split_indices(0.8, 0.1, split_seed);
    let pool_before = crate::runtime::pool_stats();

    // Train the full ("full-fledged") tree.
    let timer = Timer::start();
    let (full, arena_stats) =
        crate::tree::builder::fit_rows_with_stats(ds, &train, config, None)?;
    let full_train_ms = timer.ms();

    // Training-Only-Once Tuning + pruning.
    let t_tune = Timer::start();
    let (tune_result, pruned) = tune_and_prune(&full, ds, &val, train.len(), grid)?;
    let tune_ms = t_tune.ms();

    // Test quality of the pruned tree.
    let quality = match ds.task() {
        TaskKind::Classification => Quality::Accuracy(pruned.accuracy_rows(ds, &test)?),
        TaskKind::Regression => {
            let (mae, rmse) = pruned.regression_error(ds, &test)?;
            Quality::Regression { mae, rmse }
        }
    };

    // Separate training run with the tuned hyper-parameters (the paper
    // reports this as the tuned tree's train(ms)).
    let tuned_cfg = TrainConfig {
        max_depth: tune_result.best_max_depth,
        min_samples_split: tune_result.best_min_split.max(2),
        ..config.clone()
    };
    let t_retrain = Timer::start();
    let retrained = Tree::fit_rows(ds, &train, &tuned_cfg)?;
    let tuned_train_ms = t_retrain.ms();

    let pool_delta = crate::runtime::pool_stats().delta_since(&pool_before);
    let report = PipelineReport {
        dataset: ds.name.clone(),
        n_examples: ds.n_rows(),
        n_train: train.len(),
        n_features: ds.n_features(),
        n_labels: ds.labels.n_classes(),
        full_nodes: full.n_nodes(),
        full_depth: full.depth,
        full_train_ms,
        peak_arena_bytes: arena_stats.peak_bytes,
        hist_scratch_bytes: arena_stats.hist_scratch_bytes,
        peak_shard_window_bytes: 0,
        shard_passes: 0,
        pool_batches: pool_delta.batches_submitted,
        pool_tasks: pool_delta.tasks_executed,
        pool_threads_spawned: pool_delta.threads_spawned_total,
        tune_ms,
        n_settings: tune_result.n_settings,
        best_max_depth: tune_result.best_max_depth,
        best_min_split: tune_result.best_min_split,
        quality,
        tuned_nodes: pruned.n_nodes(),
        tuned_depth: pruned.depth,
        tuned_train_ms: {
            let _ = &retrained;
            tuned_train_ms
        },
    };
    let model = Model::TunedTree {
        tree: full,
        max_depth: tune_result.best_max_depth,
        min_split: tune_result.best_min_split,
    };
    Ok((report, model))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate_any, SynthSpec};

    #[test]
    fn classification_pipeline_produces_sane_report() {
        let mut spec = SynthSpec::classification("pipe", 3000, 8, 3);
        spec.noise = 0.1;
        let ds = generate_any(&spec, 51);
        let rep = run_pipeline(&ds, &TrainConfig::default(), &TuneGrid::default(), 1).unwrap();
        assert_eq!(rep.n_examples, 3000);
        assert!(rep.full_nodes >= rep.tuned_nodes);
        assert!(rep.full_depth >= rep.tuned_depth);
        match rep.quality {
            Quality::Accuracy(a) => assert!(a > 0.6, "acc={a}"),
            _ => panic!("expected accuracy"),
        }
        // Settings = the depth sweep + the distinct min_split grid
        // values (duplicate grid points are counted once).
        assert_eq!(
            rep.n_settings,
            rep.full_depth as usize
                + crate::tree::tuning::distinct_split_grid(rep.n_train, &TuneGrid::default())
                    .len()
        );
        assert!(rep.n_settings > 90);
        assert!(rep.full_train_ms > 0.0 && rep.tune_ms >= 0.0);
        assert!(rep.peak_arena_bytes > 0);
        // Exact backend: no histogram scratch.
        assert_eq!(rep.hist_scratch_bytes, 0);
        // Full fit + tuned retrain: the column sort was still paid once.
        assert_eq!(ds.sort_index_builds(), 1);
        // Pool counters are deltas over this run; the spawn count can
        // never exceed the process-wide cap of cores() - 1.
        assert!(rep.pool_threads_spawned <= crate::runtime::cores() as u64);
    }

    #[test]
    fn binned_pipeline_reports_histogram_scratch() {
        let mut spec = SynthSpec::classification("bpipe", 2500, 6, 3);
        spec.numeric_cardinality = 32;
        let ds = generate_any(&spec, 54);
        let cfg = TrainConfig {
            backend: crate::tree::Backend::Binned { max_bins: 32 },
            ..TrainConfig::default()
        };
        let rep = run_pipeline(&ds, &cfg, &TuneGrid::default(), 4).unwrap();
        assert!(rep.hist_scratch_bytes > 0);
        assert!(rep.full_nodes >= 3);
        // Full fit + tuned retrain share one bin-lane build, just like
        // they share one root sort.
        assert_eq!(ds.bin_index_builds(), 1);
    }

    #[test]
    fn regression_pipeline_produces_sane_report() {
        let spec = SynthSpec::regression("rpipe", 2000, 6);
        let ds = generate_any(&spec, 52);
        let rep = run_pipeline(&ds, &TrainConfig::default(), &TuneGrid::default(), 2).unwrap();
        match rep.quality {
            Quality::Regression { mae, rmse } => {
                assert!(mae.is_finite() && rmse.is_finite());
                assert!(mae <= rmse + 1e-12);
            }
            _ => panic!("expected regression quality"),
        }
    }

    #[test]
    fn tuning_is_much_faster_than_training() {
        // The paper's headline: tune+prune ≪ full training.
        let spec = SynthSpec::classification("fast", 20_000, 10, 2);
        let ds = generate_any(&spec, 53);
        let rep = run_pipeline(&ds, &TrainConfig::default(), &TuneGrid::default(), 3).unwrap();
        assert!(
            rep.tune_ms < rep.full_train_ms,
            "tune {} !< train {}",
            rep.tune_ms,
            rep.full_train_ms
        );
    }
}
