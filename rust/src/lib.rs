//! # UDT — Ultrafast Decision Tree
//!
//! A production-grade reproduction of *"Superfast Selection for Decision
//! Tree Algorithms"* (Wang & Gupta, 2024) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the full decision-tree framework: hybrid
//!   tabular data substrate, Superfast Selection (`O(M + N·C)` split
//!   selection via prefix sums), the generic `O(M·N)` baseline, the UDT
//!   builder (`O(K·M log M)` total), Training-Only-Once Tuning, a
//!   thread-pool coordinator, CLI, metrics and a prediction server.
//! * **Layer 2 (python/compile/model.py)** — the same split-scoring
//!   dataflow expressed in JAX, AOT-lowered to HLO text at build time.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels for the
//!   histogram + prefix-scan + heuristic hot-spot, executed from Rust via
//!   the PJRT CPU client ([`runtime`]).
//!
//! Quick start:
//!
//! ```no_run
//! use udt::data::synth::{SynthSpec, generate_classification};
//! use udt::tree::{Tree, TrainConfig};
//!
//! let spec = SynthSpec::classification("demo", 1000, 8, 3);
//! let ds = generate_classification(&spec, 42);
//! let tree = Tree::fit(&ds, &TrainConfig::default()).unwrap();
//! let acc = tree.accuracy(&ds);
//! assert!(acc > 0.8);
//! ```

pub mod bench_support;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod runtime;
pub mod selection;
pub mod tree;
pub mod util;

pub use data::dataset::Dataset;
pub use selection::split::SplitPredicate;
pub use tree::{TrainConfig, Tree};

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
