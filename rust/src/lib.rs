//! # UDT — Ultrafast Decision Tree
//!
//! A production-grade reproduction of *"Superfast Selection for Decision
//! Tree Algorithms"* (Wang & Gupta, 2024) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the full decision-tree framework: hybrid
//!   tabular data substrate, Superfast Selection (`O(M + N·C)` split
//!   selection via prefix sums), the generic `O(M·N)` baseline, the UDT
//!   builder (`O(K·M log M)` total), Training-Only-Once Tuning, a
//!   thread-pool coordinator, CLI, metrics and an any-model prediction
//!   server.
//! * **Layer 2 (python/compile/model.py)** — the same split-scoring
//!   dataflow expressed in JAX, AOT-lowered to HLO text at build time.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels for the
//!   histogram + prefix-scan + heuristic hot-spot, executed from Rust via
//!   the PJRT CPU client ([`runtime`], behind the `xla` cargo feature).
//!
//! ## The model surface
//!
//! Training goes through the fluent [`Udt::builder`] / [`Forest::builder`]
//! API (boosting through [`Boosted::fit`] with a [`BoostedConfig`]);
//! every trained family implements [`Estimator`]
//! (`fit` / `predict_row` / `predict_batch` / `evaluate`); a trained
//! artifact ships as a [`Model`] — single tree, Training-Only-Once tuned
//! tree, bagged forest, or gradient-boosted ensemble — bundled with its
//! schema and interner in a [`SavedModel`], which `udt serve` and
//! `udt predict` round-trip.
//! User mistakes (bad configs, task mismatches, malformed model JSON,
//! wrong-arity requests) surface as typed [`UdtError`]s, never panics.
//!
//! ## The inference surface
//!
//! Serving is compile-once / predict-many: `Model::compile()` flattens
//! any family into a [`CompiledModel`] (struct-of-arrays node tables,
//! tuned caps and categorical lookups baked in — see [`inference`]),
//! inputs parse once into a columnar [`RowFrame`], and
//! [`CompiledModel::predict_frame`] block-iterates it in parallel,
//! returning labels plus forest vote margins. The TCP server holds a
//! [`coordinator::registry::ModelRegistry`] of named compiled models.
//!
//! ```no_run
//! use udt::data::synth::{generate_classification, SynthSpec};
//! use udt::selection::heuristic::ClassCriterion;
//! use udt::{Estimator, Model, SavedModel, Udt};
//!
//! fn main() -> udt::Result<()> {
//!     let spec = SynthSpec::classification("demo", 10_000, 8, 3);
//!     let ds = generate_classification(&spec, 42);
//!
//!     // Fluent, validating training surface.
//!     let tree = Udt::builder()
//!         .criterion(ClassCriterion::Gini)
//!         .max_depth(8)
//!         .threads(8)
//!         .fit(&ds)?;
//!
//!     // One contract for every family.
//!     let quality = tree.evaluate(&ds)?;
//!     println!("accuracy = {:.4}", quality.headline());
//!
//!     // Ship it: schema + interner travel with the model.
//!     SavedModel::new(Model::SingleTree(tree), &ds).save("model.json")?;
//!     Ok(())
//! }
//! ```

pub mod analysis;
pub mod bench_support;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod inference;
pub mod model;
pub mod runtime;
pub mod selection;
pub mod tree;
pub mod util;

pub use data::dataset::Dataset;
pub use error::{Result, UdtError};
pub use inference::{CompiledModel, Predictions, RowFrame, RowFrameBuilder};
pub use model::{
    Estimator, ForestBuilder, Model, Quality, SavedModel, Schema, Udt, UdtBuilder,
};
pub use selection::split::SplitPredicate;
pub use tree::boost::{Boosted, BoostedConfig};
pub use tree::forest::{Forest, ForestConfig};
pub use tree::{Backend, NodeLabel, RegStrategy, TrainConfig, Tree};
