//! Configuration model for the `udt` launcher.
//!
//! Sources, lowest to highest precedence:
//! 1. built-in defaults,
//! 2. a config file (`--config path`, simple `key = value` lines, `#`
//!    comments, sections ignored),
//! 3. CLI `--set key=value` overrides.
//!
//! Recognized key groups:
//!
//! * `train.criterion`, `train.backend`, `train.max_bins`,
//!   `train.threads` — builder defaults (`train.max_bins` is the bin
//!   budget of the histogram-binned backend, bounds-checked here);
//! * `runtime.threads` — pool-wide default thread count when
//!   `train.threads` is absent; 0 = all cores ([`Config::runtime_threads`]);
//! * `tune.min_split_max_frac`, `tune.min_split_steps` — the
//!   Training-Only-Once hyper-parameter grid ([`TuneGrid`]);
//! * `forest.n_trees`, `forest.feature_frac`, `forest.sample_frac`,
//!   `forest.seed` — ensemble knobs ([`ForestConfig`]);
//! * `boost.n_rounds`, `boost.learning_rate`, `boost.max_depth`,
//!   `boost.subsample`, `boost.seed` — gradient-boosting knobs
//!   ([`BoostedConfig`]);
//! * `serve.backend`, `serve.max_connections`, `serve.max_request_bytes`,
//!   `serve.max_write_buffer_bytes` — prediction-server backend and
//!   limits ([`ServeConfig`]);
//! * `shard.rows`, `shard.sample_rows` — out-of-core shard size and the
//!   edge-pass reservoir of `udt shard` / `train --shards`
//!   ([`ShardConfig`]).

use crate::coordinator::serve::{ServeBackend, ServeConfig};
use crate::tree::boost::BoostedConfig;
use crate::tree::forest::ForestConfig;
use crate::tree::tuning::TuneGrid;
use crate::tree::TrainConfig;
use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

/// Config error (unknown key, bad value, IO).
#[derive(Debug)]
pub struct ConfigError(pub String);

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config error: {}", self.0)
    }
}

impl std::error::Error for ConfigError {}

/// Out-of-core sharding knobs (`shard.*` keys): rows per on-disk shard
/// for `udt shard`, and the per-(shard, column) reservoir size of the
/// quantile edge pass for `train --shards` (0 = exact edges, which is
/// what makes sharded training node-for-node identical to in-memory
/// binned training).
#[derive(Debug, Clone, Copy)]
pub struct ShardConfig {
    pub rows_per_shard: usize,
    pub sample_rows: usize,
}

impl Default for ShardConfig {
    fn default() -> Self {
        Self {
            rows_per_shard: 65536,
            sample_rows: 0,
        }
    }
}

/// A flat typed view over string settings.
#[derive(Debug, Clone, Default)]
pub struct Config {
    values: BTreeMap<String, String>,
}

impl Config {
    pub fn new() -> Self {
        Self::default()
    }

    /// Parse `key = value` lines. `[sections]` become `section.key`.
    pub fn from_str(text: &str) -> Result<Self, ConfigError> {
        let mut cfg = Config::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                ConfigError(format!("line {}: expected `key = value`", lineno + 1))
            })?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            cfg.values
                .insert(key, v.trim().trim_matches('"').to_string());
        }
        Ok(cfg)
    }

    pub fn from_file(path: impl AsRef<Path>) -> Result<Self, ConfigError> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| ConfigError(format!("{}: {e}", path.as_ref().display())))?;
        Self::from_str(&text)
    }

    /// Apply a `key=value` override (CLI `--set`).
    pub fn set_kv(&mut self, kv: &str) -> Result<(), ConfigError> {
        let (k, v) = kv
            .split_once('=')
            .ok_or_else(|| ConfigError(format!("`--set {kv}`: expected key=value")))?;
        self.values.insert(k.trim().to_string(), v.trim().to_string());
        Ok(())
    }

    /// Merge `other` on top of `self`.
    pub fn merge(&mut self, other: &Config) {
        for (k, v) in &other.values {
            self.values.insert(k.clone(), v.clone());
        }
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, ConfigError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| ConfigError(format!("{key}: `{v}` is not an integer"))),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, ConfigError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| ConfigError(format!("{key}: `{v}` is not a number"))),
        }
    }

    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool, ConfigError> {
        match self.get(key) {
            None => Ok(default),
            Some("true" | "1" | "yes" | "on") => Ok(true),
            Some("false" | "0" | "no" | "off") => Ok(false),
            Some(v) => Err(ConfigError(format!("{key}: `{v}` is not a bool"))),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, ConfigError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| ConfigError(format!("{key}: `{v}` is not an integer"))),
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }

    /// The `train.max_bins` bin budget for the histogram-binned backend
    /// (default 256), bounds-checked at this config boundary: a budget
    /// below 2 cannot host a split, one above 65535 overflows the `u16`
    /// bin-id lanes.
    pub fn max_bins(&self) -> Result<usize, ConfigError> {
        let v = self.get_usize("train.max_bins", 256)?;
        crate::tree::validate_max_bins(v)
            .map_err(|e| ConfigError(format!("train.max_bins: {e}")))?;
        Ok(v)
    }

    /// The Training-Only-Once tuning grid from the `tune.*` keys.
    pub fn tune_grid(&self) -> Result<TuneGrid, ConfigError> {
        let defaults = TuneGrid::default();
        let grid = TuneGrid {
            min_split_max_frac: self
                .get_f64("tune.min_split_max_frac", defaults.min_split_max_frac)?,
            min_split_steps: self.get_usize("tune.min_split_steps", defaults.min_split_steps)?,
        };
        if !(0.0..=1.0).contains(&grid.min_split_max_frac) {
            return Err(ConfigError(format!(
                "tune.min_split_max_frac: `{}` must be in [0, 1]",
                grid.min_split_max_frac
            )));
        }
        if grid.min_split_steps == 0 {
            return Err(ConfigError(
                "tune.min_split_steps: must be >= 1".to_string(),
            ));
        }
        Ok(grid)
    }

    /// Ensemble knobs from the `forest.*` keys, around a per-tree config.
    pub fn forest_config(&self, tree: TrainConfig) -> Result<ForestConfig, ConfigError> {
        let defaults = ForestConfig::default();
        Ok(ForestConfig {
            n_trees: self.get_usize("forest.n_trees", defaults.n_trees)?,
            feature_frac: self.get_f64("forest.feature_frac", defaults.feature_frac)?,
            sample_frac: self.get_f64("forest.sample_frac", defaults.sample_frac)?,
            seed: self.get_u64("forest.seed", defaults.seed)?,
            tree,
        })
    }

    /// Gradient-boosting knobs from the `boost.*` keys. `n_threads`
    /// follows the per-tree training threads (the rounds fit through the
    /// same builder).
    pub fn boost_config(&self, n_threads: usize) -> Result<BoostedConfig, ConfigError> {
        let defaults = BoostedConfig::default();
        Ok(BoostedConfig {
            n_rounds: self.get_usize("boost.n_rounds", defaults.n_rounds)?,
            learning_rate: self.get_f64("boost.learning_rate", defaults.learning_rate)?,
            max_depth: self.get_usize("boost.max_depth", defaults.max_depth)?,
            subsample: self.get_f64("boost.subsample", defaults.subsample)?,
            seed: self.get_u64("boost.seed", defaults.seed)?,
            n_threads,
            backend: defaults.backend,
        })
    }

    /// Prediction-server backend and limits from the `serve.*` keys.
    /// (Zero-value limits are rejected later by `ServeConfig::validate`,
    /// at serve time, alongside CLI overrides.)
    pub fn serve_config(&self) -> Result<ServeConfig, ConfigError> {
        let defaults = ServeConfig::default();
        let backend = match self.get("serve.backend") {
            None => defaults.backend,
            Some(v) => ServeBackend::parse(v).ok_or_else(|| {
                ConfigError(format!(
                    "serve.backend: `{v}` is not a backend (expected `reactor` or `threads`)"
                ))
            })?,
        };
        Ok(ServeConfig {
            backend,
            max_connections: self
                .get_usize("serve.max_connections", defaults.max_connections)?,
            max_request_bytes: self
                .get_usize("serve.max_request_bytes", defaults.max_request_bytes)?,
            max_write_buffer_bytes: self.get_usize(
                "serve.max_write_buffer_bytes",
                defaults.max_write_buffer_bytes,
            )?,
        })
    }

    /// Training thread count: `train.threads`, falling back to the
    /// pool-wide `runtime.threads` key, then 1 (sequential). The value
    /// is a *requested* count resolved by [`crate::runtime::threads`]
    /// at use sites — 0 means "all cores" everywhere.
    pub fn runtime_threads(&self) -> Result<usize, ConfigError> {
        let pool_default = self.get_usize("runtime.threads", 1)?;
        self.get_usize("train.threads", pool_default)
    }

    /// Out-of-core sharding knobs from the `shard.*` keys.
    pub fn shard_config(&self) -> Result<ShardConfig, ConfigError> {
        let defaults = ShardConfig::default();
        let rows_per_shard = self.get_usize("shard.rows", defaults.rows_per_shard)?;
        if rows_per_shard == 0 {
            return Err(ConfigError("shard.rows: must be >= 1".to_string()));
        }
        Ok(ShardConfig {
            rows_per_shard,
            sample_rows: self.get_usize("shard.sample_rows", defaults.sample_rows)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_comments() {
        let cfg = Config::from_str(
            "# top\nthreads = 4\n[train]\nmax_depth = 12 # inline\ncriterion = \"gini\"\n",
        )
        .unwrap();
        assert_eq!(cfg.get_usize("threads", 0).unwrap(), 4);
        assert_eq!(cfg.get_usize("train.max_depth", 0).unwrap(), 12);
        assert_eq!(cfg.get("train.criterion"), Some("gini"));
    }

    #[test]
    fn overrides_win() {
        let mut cfg = Config::from_str("a = 1\n").unwrap();
        cfg.set_kv("a=2").unwrap();
        assert_eq!(cfg.get_usize("a", 0).unwrap(), 2);
    }

    #[test]
    fn merge_precedence() {
        let mut base = Config::from_str("a = 1\nb = 1\n").unwrap();
        let over = Config::from_str("b = 2\n").unwrap();
        base.merge(&over);
        assert_eq!(cfg_get(&base, "a"), "1");
        assert_eq!(cfg_get(&base, "b"), "2");
    }

    fn cfg_get(c: &Config, k: &str) -> String {
        c.get(k).unwrap().to_string()
    }

    #[test]
    fn typed_errors() {
        let cfg = Config::from_str("x = notanum\nflag = maybe\n").unwrap();
        assert!(cfg.get_usize("x", 0).is_err());
        assert!(cfg.get_f64("x", 0.0).is_err());
        assert!(cfg.get_bool("flag", false).is_err());
        assert!(cfg.get_bool("missing", true).unwrap());
    }

    #[test]
    fn bad_lines_error() {
        assert!(Config::from_str("just words\n").is_err());
        assert!(Config::new().set_kv("noequals").is_err());
    }

    #[test]
    fn tune_grid_from_keys() {
        let mut cfg = Config::new();
        cfg.set_kv("tune.min_split_max_frac=0.1").unwrap();
        cfg.set_kv("tune.min_split_steps=50").unwrap();
        let grid = cfg.tune_grid().unwrap();
        assert!((grid.min_split_max_frac - 0.1).abs() < 1e-12);
        assert_eq!(grid.min_split_steps, 50);
        // Defaults apply when keys are absent.
        let d = Config::new().tune_grid().unwrap();
        assert_eq!(d.min_split_steps, 200);
    }

    #[test]
    fn tune_grid_rejects_bad_values() {
        let mut cfg = Config::new();
        cfg.set_kv("tune.min_split_max_frac=2.0").unwrap();
        assert!(cfg.tune_grid().is_err());
        let mut cfg = Config::new();
        cfg.set_kv("tune.min_split_steps=0").unwrap();
        assert!(cfg.tune_grid().is_err());
    }

    #[test]
    fn max_bins_from_keys_is_validated() {
        assert_eq!(Config::new().max_bins().unwrap(), 256);
        let mut cfg = Config::new();
        cfg.set_kv("train.max_bins=64").unwrap();
        assert_eq!(cfg.max_bins().unwrap(), 64);
        // Out-of-range and non-numeric budgets are typed config errors.
        for bad in ["0", "1", "65536", "lots"] {
            let mut cfg = Config::new();
            cfg.set_kv(&format!("train.max_bins={bad}")).unwrap();
            assert!(cfg.max_bins().is_err(), "train.max_bins={bad} accepted");
        }
        // The extremes of the valid range pass.
        for good in ["2", "65535"] {
            let mut cfg = Config::new();
            cfg.set_kv(&format!("train.max_bins={good}")).unwrap();
            assert!(cfg.max_bins().is_ok(), "train.max_bins={good} rejected");
        }
    }

    #[test]
    fn forest_config_from_keys() {
        let mut cfg = Config::new();
        cfg.set_kv("forest.n_trees=25").unwrap();
        cfg.set_kv("forest.sample_frac=0.5").unwrap();
        let fc = cfg.forest_config(TrainConfig::default()).unwrap();
        assert_eq!(fc.n_trees, 25);
        assert!((fc.sample_frac - 0.5).abs() < 1e-12);
        // Untouched knobs keep their defaults.
        assert!((fc.feature_frac - 0.7).abs() < 1e-12);
    }

    #[test]
    fn boost_config_from_keys() {
        let mut cfg = Config::new();
        cfg.set_kv("boost.n_rounds=120").unwrap();
        cfg.set_kv("boost.learning_rate=0.05").unwrap();
        cfg.set_kv("boost.max_depth=6").unwrap();
        let bc = cfg.boost_config(4).unwrap();
        assert_eq!(bc.n_rounds, 120);
        assert!((bc.learning_rate - 0.05).abs() < 1e-12);
        assert_eq!(bc.max_depth, 6);
        assert_eq!(bc.n_threads, 4);
        // Untouched knobs keep their defaults.
        assert!((bc.subsample - 1.0).abs() < 1e-12);
        // Bad values are typed config errors.
        let mut bad = Config::new();
        bad.set_kv("boost.learning_rate=fast").unwrap();
        assert!(bad.boost_config(1).is_err());
    }

    #[test]
    fn serve_config_from_keys() {
        let mut cfg = Config::new();
        cfg.set_kv("serve.backend=threads").unwrap();
        cfg.set_kv("serve.max_connections=77").unwrap();
        cfg.set_kv("serve.max_request_bytes=4096").unwrap();
        let sc = cfg.serve_config().unwrap();
        assert_eq!(sc.backend, ServeBackend::Threads);
        assert_eq!(sc.max_connections, 77);
        assert_eq!(sc.max_request_bytes, 4096);
        // Untouched knobs keep their defaults.
        assert_eq!(sc.max_write_buffer_bytes, 8 << 20);
        // Defaults pick the platform backend.
        let d = Config::new().serve_config().unwrap();
        assert_eq!(d.backend, ServeBackend::default_for_platform());
        assert_eq!(d.max_connections, 10_240);
    }

    #[test]
    fn runtime_threads_fallback_chain() {
        // Default: sequential.
        assert_eq!(Config::new().runtime_threads().unwrap(), 1);
        // runtime.threads is the pool-wide default...
        let mut cfg = Config::new();
        cfg.set_kv("runtime.threads=0").unwrap();
        assert_eq!(cfg.runtime_threads().unwrap(), 0);
        // ...which train.threads overrides.
        cfg.set_kv("train.threads=4").unwrap();
        assert_eq!(cfg.runtime_threads().unwrap(), 4);
        // Non-numeric values are typed errors.
        let mut bad = Config::new();
        bad.set_kv("runtime.threads=many").unwrap();
        assert!(bad.runtime_threads().is_err());
    }

    #[test]
    fn shard_config_from_keys() {
        let d = Config::new().shard_config().unwrap();
        assert_eq!(d.rows_per_shard, 65536);
        assert_eq!(d.sample_rows, 0);
        let mut cfg = Config::new();
        cfg.set_kv("shard.rows=1000").unwrap();
        cfg.set_kv("shard.sample_rows=5000").unwrap();
        let sc = cfg.shard_config().unwrap();
        assert_eq!(sc.rows_per_shard, 1000);
        assert_eq!(sc.sample_rows, 5000);
        // Zero rows per shard and non-numeric values are typed errors.
        for bad in ["shard.rows=0", "shard.rows=many", "shard.sample_rows=x"] {
            let mut cfg = Config::new();
            cfg.set_kv(bad).unwrap();
            assert!(cfg.shard_config().is_err(), "{bad} accepted");
        }
    }

    #[test]
    fn serve_config_rejects_bad_values() {
        let mut cfg = Config::new();
        cfg.set_kv("serve.backend=tokio").unwrap();
        assert!(cfg.serve_config().is_err());
        let mut cfg = Config::new();
        cfg.set_kv("serve.max_connections=lots").unwrap();
        assert!(cfg.serve_config().is_err());
    }
}
