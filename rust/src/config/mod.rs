//! Launcher-grade configuration: `key=value` files + CLI overrides.

mod settings;

pub use settings::{Config, ConfigError, ShardConfig};
