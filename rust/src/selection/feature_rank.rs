//! Superfast Selection for **feature selection** — the second use-case in
//! the paper's title. Each feature is scored by the heuristic of its best
//! split over the whole training set (one `O(M + N·C)` Superfast pass per
//! feature instead of the generic `O(M·N)`), optionally as *gain* over
//! the unsplit baseline so scores are comparable across datasets.
//! Features are returned ranked; `top_k` gives a filtered dataset for
//! downstream training.

use super::heuristic::Criterion;
use super::superfast::{best_split_on_feat, FeatureView, LabelsView, ScoredSplit};
use crate::data::dataset::{Dataset, Labels, TaskKind};
use crate::error::Result;
use crate::tree::{require_task, TrainConfig};

/// One ranked feature.
#[derive(Debug, Clone)]
pub struct FeatureScore {
    pub feature: usize,
    pub name: String,
    /// Gain of the feature's best split over the no-split baseline
    /// (≥ 0; 0 = the feature is uninformative at the root).
    pub gain: f64,
    /// The best split itself, if any.
    pub best: Option<ScoredSplit>,
}

/// Rank all features of a dataset by best-split gain (descending).
///
/// Returns [`crate::error::UdtError::TaskMismatch`] when the criterion's
/// task does not match the dataset's labels (e.g. an SSE ranking over
/// classification labels) — the public-surface contract, never a panic.
pub fn rank_features(ds: &Dataset, criterion: Criterion) -> Result<Vec<FeatureScore>> {
    // Typed criterion/labels guard before any work.
    let criterion_task = match criterion {
        Criterion::Class(_) => TaskKind::Classification,
        Criterion::Sse => TaskKind::Regression,
    };
    require_task(criterion_task, ds.task())?;

    let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
    let labels = LabelsView::from_labels(&ds.labels);

    // No-split baseline under the same criterion. A row-less dataset has
    // nothing to score — baseline 0.0 (the `sum·sum/n` form would divide
    // by zero and poison every gain with NaN).
    let baseline = if rows.is_empty() {
        0.0
    } else {
        match (&ds.labels, criterion) {
            (Labels::Class { ids, n_classes }, Criterion::Class(crit)) => {
                let mut counts = vec![0.0f64; *n_classes];
                for &r in &rows {
                    counts[ids[r as usize] as usize] += 1.0;
                }
                crit.score(&counts, &vec![0.0; *n_classes])
            }
            (Labels::Reg { values }, Criterion::Sse) => {
                let n = rows.len() as f64;
                let sum: f64 = values.iter().sum();
                sum * sum / n
            }
            _ => unreachable!("criterion/labels kind checked above"),
        }
    };

    let mut scores: Vec<FeatureScore> = ds
        .columns
        .iter()
        .enumerate()
        .map(|(f, col)| {
            let (sorted_rows, sorted_vals) = col.sorted_numeric();
            let view = FeatureView::new(f, col, &rows, &sorted_rows, &sorted_vals);
            let best = best_split_on_feat(&view, &labels, criterion);
            let gain = best.map_or(0.0, |s| (s.score - baseline).max(0.0));
            FeatureScore {
                feature: f,
                name: col.name.clone(),
                gain,
                best,
            }
        })
        .collect();
    // `total_cmp`, not `partial_cmp().unwrap()`: the IEEE total order
    // never aborts, so a NaN gain sneaking through degenerate score
    // arithmetic can cost at most its own rank — not the whole
    // `rank-features` run.
    scores.sort_by(|a, b| b.gain.total_cmp(&a.gain).then(a.feature.cmp(&b.feature)));
    Ok(scores)
}

/// Keep the `k` highest-gain features; returns the filtered dataset and
/// the kept original feature indices (ascending). Propagates
/// [`crate::error::UdtError::TaskMismatch`] from the ranking.
pub fn top_k(ds: &Dataset, criterion: Criterion, k: usize) -> Result<(Dataset, Vec<usize>)> {
    let ranked = rank_features(ds, criterion)?;
    let mut keep: Vec<usize> = ranked.iter().take(k.max(1)).map(|s| s.feature).collect();
    keep.sort_unstable();
    let columns = keep.iter().map(|&f| ds.columns[f].clone()).collect();
    let mut filtered = Dataset::new(
        format!("{}_top{}", ds.name, keep.len()),
        columns,
        ds.labels.clone(),
        ds.interner.clone(),
    )
    // ANALYZE-ALLOW(no-unwrap): columns were validated when the source dataset was built
    .expect("columns already validated");
    filtered.class_names = ds.class_names.clone();
    Ok((filtered, keep))
}

/// Convenience: criterion matching a dataset's task under a config.
pub fn default_criterion(ds: &Dataset, config: &TrainConfig) -> Criterion {
    match ds.task() {
        TaskKind::Classification => Criterion::Class(config.criterion),
        TaskKind::Regression => Criterion::Sse,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::column::Column;
    use crate::data::dataset::Labels;
    use crate::data::interner::Interner;
    use crate::data::value::Value;
    use crate::selection::heuristic::ClassCriterion;

    fn dataset_with_planted_signal() -> Dataset {
        // f0: pure noise; f1: perfectly predictive; f2: weakly predictive.
        let n = 400;
        let mut f0 = Vec::new();
        let mut f1 = Vec::new();
        let mut f2 = Vec::new();
        let mut ids = Vec::new();
        let mut rng = crate::util::rng::Rng::new(5);
        for i in 0..n {
            let y = (i % 2) as u16;
            ids.push(y);
            f0.push(Value::Num(rng.below(7) as f64));
            f1.push(Value::Num(y as f64 * 10.0));
            // 70% correlated.
            let w = if rng.chance(0.7) { y as f64 } else { 1.0 - y as f64 };
            f2.push(Value::Num(w * 5.0));
        }
        Dataset::new(
            "planted",
            vec![
                Column::new("noise", f0),
                Column::new("signal", f1),
                Column::new("weak", f2),
            ],
            Labels::Class { ids, n_classes: 2 },
            Interner::new(),
        )
        .unwrap()
    }

    #[test]
    fn ranks_planted_signal_first() {
        let ds = dataset_with_planted_signal();
        let ranked = rank_features(&ds, Criterion::Class(ClassCriterion::InfoGain)).unwrap();
        assert_eq!(ranked[0].name, "signal");
        assert_eq!(ranked[1].name, "weak");
        assert_eq!(ranked[2].name, "noise");
        assert!(ranked[0].gain > ranked[1].gain);
        assert!(ranked[1].gain > ranked[2].gain);
        // Perfect predictor: gain equals the full class entropy (ln 2).
        assert!((ranked[0].gain - std::f64::consts::LN_2).abs() < 1e-9);
    }

    #[test]
    fn gain_is_nonnegative_for_all_criteria() {
        let ds = dataset_with_planted_signal();
        for crit in [
            ClassCriterion::InfoGain,
            ClassCriterion::Gini,
            ClassCriterion::ChiSquare,
        ] {
            for s in rank_features(&ds, Criterion::Class(crit)).unwrap() {
                assert!(s.gain >= 0.0, "{}: {}", crit.name(), s.gain);
            }
        }
    }

    #[test]
    fn top_k_filters_and_preserves_rows() {
        let ds = dataset_with_planted_signal();
        let (filtered, keep) = top_k(&ds, Criterion::Class(ClassCriterion::InfoGain), 2).unwrap();
        assert_eq!(filtered.n_features(), 2);
        assert_eq!(filtered.n_rows(), ds.n_rows());
        assert!(keep.contains(&1)); // the planted signal survives
        // Training on the filtered set still works perfectly.
        let tree = crate::Tree::fit(&filtered, &TrainConfig::default()).unwrap();
        assert_eq!(tree.accuracy(&filtered).unwrap(), 1.0);
    }

    #[test]
    fn empty_dataset_ranks_without_panicking() {
        // Regression guard: zero rows used to make the SSE baseline
        // `sum·sum/n` divide by zero (NaN), and the descending gain sort
        // aborted on `partial_cmp().unwrap()`. Both paths must now
        // produce a finite, complete ranking.
        use crate::data::column::Column;
        use crate::data::interner::Interner;
        let reg = Dataset::new(
            "empty_reg",
            vec![Column::new("f0", vec![]), Column::new("f1", vec![])],
            Labels::Reg { values: vec![] },
            Interner::new(),
        )
        .unwrap();
        let ranked = rank_features(&reg, Criterion::Sse).unwrap();
        assert_eq!(ranked.len(), 2);
        for s in &ranked {
            assert!(s.gain.is_finite(), "{}: gain {}", s.name, s.gain);
            assert_eq!(s.gain, 0.0);
            assert!(s.best.is_none());
        }
        let cls = Dataset::new(
            "empty_cls",
            vec![Column::new("f0", vec![])],
            Labels::Class {
                ids: vec![],
                n_classes: 2,
            },
            Interner::new(),
        )
        .unwrap();
        let ranked = rank_features(&cls, Criterion::Class(ClassCriterion::InfoGain)).unwrap();
        assert_eq!(ranked.len(), 1);
        assert!(ranked[0].gain.is_finite());
    }

    #[test]
    fn criterion_labels_mismatch_is_a_typed_error() {
        // Regression guard: a criterion/labels kind mismatch used to
        // `panic!` from the public surface; it must be a typed
        // `TaskMismatch`, propagated through `top_k` too.
        use crate::error::UdtError;
        let cls = dataset_with_planted_signal();
        assert!(matches!(
            rank_features(&cls, Criterion::Sse),
            Err(UdtError::TaskMismatch { .. })
        ));
        assert!(matches!(
            top_k(&cls, Criterion::Sse, 2),
            Err(UdtError::TaskMismatch { .. })
        ));
        let spec = crate::data::synth::SynthSpec::regression("mm", 50, 3);
        let reg = crate::data::synth::generate_regression(&spec, 9);
        assert!(matches!(
            rank_features(&reg, Criterion::Class(ClassCriterion::Gini)),
            Err(UdtError::TaskMismatch { .. })
        ));
    }

    #[test]
    fn regression_ranking_works() {
        let spec = crate::data::synth::SynthSpec::regression("r", 500, 5);
        let ds = crate::data::synth::generate_regression(&spec, 3);
        let ranked = rank_features(&ds, Criterion::Sse).unwrap();
        assert_eq!(ranked.len(), 5);
        for s in &ranked {
            assert!(s.gain >= 0.0);
        }
        // Descending order.
        for w in ranked.windows(2) {
            assert!(w[0].gain >= w[1].gain);
        }
    }
}
