//! Split predicates over hybrid values.
//!
//! A split is a unary predicate `pred(v) → bool` on one feature. The
//! positive branch holds rows where the predicate is true. Candidates
//! (paper §2 "Split Candidates"):
//!
//! * `≤ x` and `> x` for every numeric value `x` — note these are *not*
//!   complements in a hybrid column: categorical and missing cells
//!   evaluate false under both, so both are scored;
//! * `= c` for every categorical value `c` (`≠ c` is its complement and
//!   carries the same score under the symmetric criteria, so it is not
//!   enumerated separately);
//! * missing cells evaluate false under every candidate ("left
//!   untouched": always routed to the negative branch).

use crate::data::interner::{CatId, Interner};
use crate::data::value::Value;
use std::fmt;

/// The comparison operator + operand of a split.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SplitOp {
    /// Numeric `value ≤ threshold`.
    Le(f64),
    /// Numeric `value > threshold`.
    Gt(f64),
    /// Categorical `value = category`.
    Eq(CatId),
}

impl SplitOp {
    /// Evaluate against a cell value (Table 3 semantics).
    #[inline]
    pub fn eval(&self, v: Value) -> bool {
        match (self, v) {
            (SplitOp::Le(t), Value::Num(x)) => x <= *t,
            (SplitOp::Gt(t), Value::Num(x)) => x > *t,
            (SplitOp::Eq(c), Value::Cat(id)) => id == *c,
            // Cross-type and missing: always false.
            _ => false,
        }
    }
}

/// A complete split: feature index + operator.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SplitPredicate {
    pub feature: usize,
    pub op: SplitOp,
}

impl SplitPredicate {
    #[inline]
    pub fn eval_row(&self, row: &[Value]) -> bool {
        self.op.eval(row[self.feature])
    }

    #[inline]
    pub fn eval_value(&self, v: Value) -> bool {
        self.op.eval(v)
    }

    /// Render with the interner for categorical operands.
    pub fn display<'a>(&'a self, interner: &'a Interner) -> SplitDisplay<'a> {
        SplitDisplay {
            split: self,
            interner,
        }
    }
}

/// Pretty-printer bound to an interner.
pub struct SplitDisplay<'a> {
    split: &'a SplitPredicate,
    interner: &'a Interner,
}

impl fmt::Display for SplitDisplay<'_> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.split.op {
            SplitOp::Le(t) => write!(f, "f{} ≤ {t}", self.split.feature),
            SplitOp::Gt(t) => write!(f, "f{} > {t}", self.split.feature),
            SplitOp::Eq(c) => {
                write!(f, "f{} = {}", self.split.feature, self.interner.name(c))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::interner::Interner;

    #[test]
    fn le_gt_on_numeric() {
        assert!(SplitOp::Le(2.0).eval(Value::Num(2.0)));
        assert!(!SplitOp::Le(2.0).eval(Value::Num(2.1)));
        assert!(SplitOp::Gt(2.0).eval(Value::Num(2.1)));
        assert!(!SplitOp::Gt(2.0).eval(Value::Num(2.0)));
    }

    #[test]
    fn categorical_and_missing_fail_numeric_ops() {
        let mut i = Interner::new();
        let c = Value::Cat(i.intern("x"));
        assert!(!SplitOp::Le(1e9).eval(c));
        assert!(!SplitOp::Gt(-1e9).eval(c));
        assert!(!SplitOp::Le(1e9).eval(Value::Missing));
        assert!(!SplitOp::Gt(-1e9).eval(Value::Missing));
    }

    #[test]
    fn eq_on_categorical_only() {
        let mut i = Interner::new();
        let x = i.intern("x");
        let y = i.intern("y");
        assert!(SplitOp::Eq(x).eval(Value::Cat(x)));
        assert!(!SplitOp::Eq(x).eval(Value::Cat(y)));
        assert!(!SplitOp::Eq(x).eval(Value::Num(0.0)));
        assert!(!SplitOp::Eq(x).eval(Value::Missing));
    }

    #[test]
    fn le_and_gt_are_not_complements_on_hybrid() {
        let mut i = Interner::new();
        let c = Value::Cat(i.intern("x"));
        // Both false: the hybrid cell goes negative under either split.
        assert!(!SplitOp::Le(5.0).eval(c) && !SplitOp::Gt(5.0).eval(c));
    }

    #[test]
    fn eval_row_uses_feature_index() {
        let p = SplitPredicate {
            feature: 1,
            op: SplitOp::Le(3.0),
        };
        assert!(p.eval_row(&[Value::Num(100.0), Value::Num(2.0)]));
        assert!(!p.eval_row(&[Value::Num(2.0), Value::Num(100.0)]));
    }

    #[test]
    fn display_renders_categories() {
        let mut i = Interner::new();
        let id = i.intern("red");
        let p = SplitPredicate {
            feature: 3,
            op: SplitOp::Eq(id),
        };
        assert_eq!(format!("{}", p.display(&i)), "f3 = red");
    }
}
