//! Superfast Selection (paper Algorithms 2 & 4).
//!
//! One pass over the node's rows collects per-class statistics
//! (`O(M_node)`); a walk over the pre-sorted numeric rows maintains the
//! running prefix counts, scoring every `≤ x` / `> x` candidate in `O(C)`
//! at each distinct value boundary; categorical `= c` candidates are
//! scored from the per-category count table. Total: `O(M + N·C)` per
//! feature versus the generic engine's `O(M·N)`.

use super::heuristic::{sse_score, Criterion};
use super::split::SplitOp;
use crate::data::column::Column;
use crate::data::column_data::{present, ColumnData};
use crate::data::interner::CatId;
use std::collections::BTreeMap;

/// Label access for selection: class ids or regression targets.
#[derive(Debug, Clone, Copy)]
pub enum LabelsView<'a> {
    Class { ids: &'a [u16], n_classes: usize },
    Reg { values: &'a [f64] },
}

impl<'a> LabelsView<'a> {
    pub fn from_labels(labels: &'a crate::data::dataset::Labels) -> Self {
        match labels {
            crate::data::dataset::Labels::Class { ids, n_classes } => LabelsView::Class {
                ids,
                n_classes: *n_classes,
            },
            crate::data::dataset::Labels::Reg { values } => LabelsView::Reg { values },
        }
    }
}

/// One feature of one tree node, as the selection engines see it.
#[derive(Debug, Clone, Copy)]
pub struct FeatureView<'a> {
    /// Feature index (for the returned predicate).
    pub feature: usize,
    /// The full column (row-addressable).
    pub col: &'a Column,
    /// All rows of the node.
    pub rows: &'a [u32],
    /// The node's numeric rows for this feature, sorted ascending by value
    /// (UDT's maintained `X^A`).
    pub sorted_num: &'a [u32],
    /// Values parallel to `sorted_num` — carried through the builder's
    /// filtering so the prefix walk reads values sequentially.
    pub sorted_vals: &'a [f64],
    /// Per-class counts of *all* node rows (classification; may be empty,
    /// in which case pass 1 derives totals itself).
    pub class_counts: &'a [f64],
    /// `(count, sum)` of targets over all node rows (regression).
    pub reg_stats: Option<(f64, f64)>,
    /// Whether the column contains categorical/missing cells anywhere in
    /// the dataset. `false` lets the engine skip the O(M) statistics pass
    /// entirely (totals come from `class_counts` / `reg_stats`).
    pub col_has_nonnum: bool,
    /// The node's categorical rows for this feature, grouped by ascending
    /// category id (parallel arrays). When `cat_lists_valid`, the engine
    /// derives all statistics from the sorted lists — no column access.
    pub sorted_cat_rows: &'a [u32],
    /// Category ids parallel to `sorted_cat_rows` (non-decreasing).
    pub sorted_cat_ids: &'a [u32],
    /// Whether `sorted_cat_rows/ids` are authoritative for this node.
    pub cat_lists_valid: bool,
    /// Class labels parallel to `sorted_num` (classification only; may be
    /// empty — the engine then looks labels up through the row ids).
    pub sorted_labs: &'a [u16],
    /// Class labels parallel to `sorted_cat_rows` (same contract).
    pub sorted_cat_labs: &'a [u16],
}

impl<'a> FeatureView<'a> {
    /// Conservative constructor (always runs the statistics pass);
    /// convenient for tests, benches and one-off calls.
    pub fn new(
        feature: usize,
        col: &'a Column,
        rows: &'a [u32],
        sorted_num: &'a [u32],
        sorted_vals: &'a [f64],
    ) -> Self {
        debug_assert_eq!(sorted_num.len(), sorted_vals.len());
        Self {
            feature,
            col,
            rows,
            sorted_num,
            sorted_vals,
            class_counts: &[],
            reg_stats: None,
            col_has_nonnum: true,
            sorted_cat_rows: &[],
            sorted_cat_ids: &[],
            cat_lists_valid: false,
            sorted_labs: &[],
            sorted_cat_labs: &[],
        }
    }
}

/// A candidate split with its heuristic score (higher is better).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScoredSplit {
    pub score: f64,
    pub op: SplitOp,
}

/// `Option<ScoredSplit>` upgrade helper: keep the strictly-better
/// candidate; ignore non-finite scores (empty-side sentinels). Shared
/// with the binned engine so both tie-break identically.
pub(crate) trait Consider {
    fn consider(&mut self, score: f64, op: SplitOp);
}

impl Consider for Option<ScoredSplit> {
    #[inline]
    fn consider(&mut self, score: f64, op: SplitOp) {
        if !score.is_finite() {
            return;
        }
        match self {
            None => *self = Some(ScoredSplit { score, op }),
            Some(b) if score > b.score => *self = Some(ScoredSplit { score, op }),
            _ => {}
        }
    }
}

/// Reusable scratch buffers so per-node selection does not allocate in the
/// hot loop.
#[derive(Debug, Default)]
pub struct Scratch {
    pub(crate) cum: Vec<f64>,
    pub(crate) tot_num: Vec<f64>,
    pub(crate) rest: Vec<f64>,
    pub(crate) pos: Vec<f64>,
    pub(crate) neg: Vec<f64>,
    cat: BTreeMap<u32, Vec<f64>>,
    cat_reg: BTreeMap<u32, (f64, f64)>,
}

impl Scratch {
    pub fn new() -> Self {
        Self::default()
    }

    pub(crate) fn reset_class(&mut self, c: usize) {
        for v in [&mut self.cum, &mut self.tot_num, &mut self.rest, &mut self.pos, &mut self.neg]
        {
            v.clear();
            v.resize(c, 0.0);
        }
        self.cat.clear();
    }
}

/// Best split on one feature — allocating convenience wrapper.
pub fn best_split_on_feat(
    view: &FeatureView,
    labels: &LabelsView,
    criterion: Criterion,
) -> Option<ScoredSplit> {
    let mut scratch = Scratch::new();
    best_split_on_feat_with(view, labels, criterion, &mut scratch)
}

/// Best split on one feature using caller-provided scratch buffers.
pub fn best_split_on_feat_with(
    view: &FeatureView,
    labels: &LabelsView,
    criterion: Criterion,
    scratch: &mut Scratch,
) -> Option<ScoredSplit> {
    match (labels, criterion) {
        (LabelsView::Class { ids, n_classes }, Criterion::Class(crit)) => {
            classification(view, ids, *n_classes, crit, scratch)
        }
        (LabelsView::Reg { values }, Criterion::Sse) => regression(view, values, scratch),
        // ANALYZE-ALLOW(no-unwrap): criterion/labels pairing is fixed by task kind at config validation
        _ => panic!("criterion/labels kind mismatch"),
    }
}

fn classification(
    view: &FeatureView,
    ids: &[u16],
    n_classes: usize,
    crit: super::heuristic::ClassCriterion,
    scratch: &mut Scratch,
) -> Option<ScoredSplit> {
    let c = n_classes;
    scratch.reset_class(c);

    // Pass 1 (Algorithm 4 lines 2–9): per-class totals and the
    // per-category count table. `rest` = categorical + missing counts —
    // rows that evaluate false under every numeric candidate.
    //
    // Fast path (builder-provided node stats + maintained lists): derive
    // the numeric totals from the sorted numeric list, the rest by
    // subtraction from the node's class counts, and the per-category
    // table later from the grouped categorical list — no column access,
    // no hash map, everything sequential.
    let node_stats = view.class_counts.len() == c;
    if !view.col_has_nonnum && node_stats {
        scratch.tot_num.copy_from_slice(view.class_counts);
    } else if view.cat_lists_valid && node_stats {
        if view.sorted_labs.len() == view.sorted_num.len() {
            for &y in view.sorted_labs {
                scratch.tot_num[y as usize] += 1.0;
            }
        } else {
            for &r in view.sorted_num {
                scratch.tot_num[ids[r as usize] as usize] += 1.0;
            }
        }
        for y in 0..c {
            scratch.rest[y] = view.class_counts[y] - scratch.tot_num[y];
        }
    } else {
        // Statistics fallback (no maintained lists / node stats): stream
        // the column's typed lanes — one representation branch per call,
        // no tagged cell reads in the per-row loop.
        match &view.col.data {
            ColumnData::Num { valid, .. } => {
                for &r in view.rows {
                    let y = ids[r as usize] as usize;
                    if present(valid, r as usize) {
                        scratch.tot_num[y] += 1.0;
                    } else {
                        scratch.rest[y] += 1.0;
                    }
                }
            }
            ColumnData::Cat { ids: cat_ids, valid } => {
                for &r in view.rows {
                    let y = ids[r as usize] as usize;
                    scratch.rest[y] += 1.0;
                    if present(valid, r as usize) {
                        scratch
                            .cat
                            .entry(cat_ids[r as usize])
                            .or_insert_with(|| vec![0.0; c])[y] += 1.0;
                    }
                }
            }
            ColumnData::Hybrid {
                ids: cat_ids,
                num,
                cat,
                ..
            } => {
                for &r in view.rows {
                    let y = ids[r as usize] as usize;
                    if num.get(r as usize) {
                        scratch.tot_num[y] += 1.0;
                    } else {
                        scratch.rest[y] += 1.0;
                        if cat.get(r as usize) {
                            scratch
                                .cat
                                .entry(cat_ids[r as usize])
                                .or_insert_with(|| vec![0.0; c])[y] += 1.0;
                        }
                    }
                }
            }
        }
    }

    let mut best: Option<ScoredSplit> = None;

    // Pass 2 (lines 10–28): prefix-sum walk over the sorted numeric rows.
    // `cum[y]` is cnt_n[y, ≤ x] — the prefix sum — maintained incrementally.
    // Values stream sequentially from `sorted_vals`.
    let sorted = view.sorted_num;
    let vals = view.sorted_vals;
    let mut i = 0;
    let n_num_total: f64 = scratch.tot_num.iter().sum();
    let rest_total: f64 = scratch.rest.iter().sum();
    let mut cum_total = 0.0f64; // maintained incrementally (O(1)/candidate)
    let inline_labs = view.sorted_labs.len() == sorted.len();
    while i < sorted.len() {
        let x = vals[i];
        // Absorb the group of rows sharing value x. With inline labels
        // (builder-maintained) the accumulate streams sequentially.
        let group_start = i;
        if inline_labs {
            while i < sorted.len() && vals[i] == x {
                scratch.cum[view.sorted_labs[i] as usize] += 1.0;
                i += 1;
            }
        } else {
            while i < sorted.len() && vals[i] == x {
                scratch.cum[ids[sorted[i] as usize] as usize] += 1.0;
                i += 1;
            }
        }
        cum_total += (i - group_start) as f64;
        let (cum, tot_num, rest) = (&scratch.cum, &scratch.tot_num, &scratch.rest);
        // `≤ x`: pos = prefix counts; neg = remaining numerics + rest.
        // Totals are maintained incrementally, so each candidate is one
        // fused O(C) pass (no pos/neg arrays materialized).
        let pos_total = cum_total;
        let neg_total = n_num_total - cum_total + rest_total;
        if pos_total > 0.0 && neg_total > 0.0 {
            let score = crit.score_with_totals(c, pos_total, neg_total, |y| {
                (cum[y], tot_num[y] - cum[y] + rest[y])
            });
            best.consider(score, SplitOp::Le(x));
        }
        // `> x`: pos = suffix numerics; neg = prefix + rest.
        let pos_total = n_num_total - cum_total;
        let neg_total = cum_total + rest_total;
        if pos_total > 0.0 && neg_total > 0.0 {
            let score = crit.score_with_totals(c, pos_total, neg_total, |y| {
                (tot_num[y] - cum[y], cum[y] + rest[y])
            });
            best.consider(score, SplitOp::Gt(x));
        }
    }

    // Pass 3 (lines 29–36): categorical `= x` candidates.
    let all_total = n_num_total + rest_total;
    if view.cat_lists_valid && node_stats {
        // Grouped walk over the maintained categorical list (ids are
        // non-decreasing, so each category is one contiguous group).
        let cat_ids = view.sorted_cat_ids;
        let cat_rows = view.sorted_cat_rows;
        let inline_cat_labs = view.sorted_cat_labs.len() == cat_ids.len();
        let mut i = 0;
        while i < cat_ids.len() {
            let id = cat_ids[i];
            for y in 0..c {
                scratch.pos[y] = 0.0;
            }
            let mut pos_total = 0.0f64;
            while i < cat_ids.len() && cat_ids[i] == id {
                let y = if inline_cat_labs {
                    view.sorted_cat_labs[i] as usize
                } else {
                    ids[cat_rows[i] as usize] as usize
                };
                scratch.pos[y] += 1.0;
                pos_total += 1.0;
                i += 1;
            }
            let neg_total = all_total - pos_total;
            if pos_total > 0.0 && neg_total > 0.0 {
                for y in 0..c {
                    scratch.neg[y] =
                        scratch.tot_num[y] + scratch.rest[y] - scratch.pos[y];
                }
                let score = crit.score(&scratch.pos, &scratch.neg);
                best.consider(score, SplitOp::Eq(CatId(id)));
            }
        }
    } else {
        for (&id, cnt) in &scratch.cat {
            let pos_total: f64 = cnt.iter().sum();
            let neg_total = all_total - pos_total;
            if pos_total > 0.0 && neg_total > 0.0 {
                for y in 0..c {
                    scratch.pos[y] = cnt[y];
                    scratch.neg[y] = scratch.tot_num[y] + scratch.rest[y] - cnt[y];
                }
                let score = crit.score(&scratch.pos, &scratch.neg);
                best.consider(score, SplitOp::Eq(CatId(id)));
            }
        }
    }

    best
}

fn regression(view: &FeatureView, values: &[f64], scratch: &mut Scratch) -> Option<ScoredSplit> {
    scratch.cat_reg.clear();
    // Pass 1: totals. (count, sum) for numerics and for the rest. Skipped
    // for clean columns (totals provided by the caller).
    let (mut n_num, mut sum_num) = (0.0f64, 0.0f64);
    let (mut n_rest, mut sum_rest) = (0.0f64, 0.0f64);
    match (view.col_has_nonnum, view.reg_stats, view.cat_lists_valid) {
        (false, Some((n, sum)), _) => {
            n_num = n;
            sum_num = sum;
        }
        (true, Some((n_all_s, sum_all_s)), true) => {
            // Fast path: numeric totals from the sorted list; the rest by
            // subtraction. Categorical groups are handled in pass 3.
            n_num = view.sorted_num.len() as f64;
            for &r in view.sorted_num {
                sum_num += values[r as usize];
            }
            n_rest = n_all_s - n_num;
            sum_rest = sum_all_s - sum_num;
        }
        _ => {
            // Statistics fallback: stream the typed lanes (see the
            // classification pass for the representation contract).
            match &view.col.data {
                ColumnData::Num { valid, .. } => {
                    for &r in view.rows {
                        let y = values[r as usize];
                        if present(valid, r as usize) {
                            n_num += 1.0;
                            sum_num += y;
                        } else {
                            n_rest += 1.0;
                            sum_rest += y;
                        }
                    }
                }
                ColumnData::Cat { ids: cat_ids, valid } => {
                    for &r in view.rows {
                        let y = values[r as usize];
                        n_rest += 1.0;
                        sum_rest += y;
                        if present(valid, r as usize) {
                            let e = scratch
                                .cat_reg
                                .entry(cat_ids[r as usize])
                                .or_insert((0.0, 0.0));
                            e.0 += 1.0;
                            e.1 += y;
                        }
                    }
                }
                ColumnData::Hybrid {
                    ids: cat_ids,
                    num,
                    cat,
                    ..
                } => {
                    for &r in view.rows {
                        let y = values[r as usize];
                        if num.get(r as usize) {
                            n_num += 1.0;
                            sum_num += y;
                        } else {
                            n_rest += 1.0;
                            sum_rest += y;
                            if cat.get(r as usize) {
                                let e = scratch
                                    .cat_reg
                                    .entry(cat_ids[r as usize])
                                    .or_insert((0.0, 0.0));
                                e.0 += 1.0;
                                e.1 += y;
                            }
                        }
                    }
                }
            }
        }
    }
    let (n_all, sum_all) = (n_num + n_rest, sum_num + sum_rest);

    let mut best: Option<ScoredSplit> = None;

    // Pass 2: prefix-sum walk over sequential values.
    let sorted = view.sorted_num;
    let vals = view.sorted_vals;
    let mut i = 0;
    let (mut cum_n, mut cum_sum) = (0.0f64, 0.0f64);
    while i < sorted.len() {
        let x = vals[i];
        while i < sorted.len() && vals[i] == x {
            cum_n += 1.0;
            cum_sum += values[sorted[i] as usize];
            i += 1;
        }
        // `≤ x`
        let score = sse_score(cum_n, cum_sum, n_all - cum_n, sum_all - cum_sum);
        best.consider(score, SplitOp::Le(x));
        // `> x`
        let score = sse_score(
            n_num - cum_n,
            sum_num - cum_sum,
            cum_n + n_rest,
            cum_sum + sum_rest,
        );
        best.consider(score, SplitOp::Gt(x));
    }

    // Pass 3: categorical candidates.
    if view.cat_lists_valid && view.reg_stats.is_some() {
        // Grouped walk over the maintained categorical list.
        let cat_ids = view.sorted_cat_ids;
        let cat_rows = view.sorted_cat_rows;
        let mut i = 0;
        while i < cat_ids.len() {
            let id = cat_ids[i];
            let (mut cn, mut cs) = (0.0f64, 0.0f64);
            while i < cat_ids.len() && cat_ids[i] == id {
                cn += 1.0;
                cs += values[cat_rows[i] as usize];
                i += 1;
            }
            let score = sse_score(cn, cs, n_all - cn, sum_all - cs);
            best.consider(score, SplitOp::Eq(CatId(id)));
        }
    } else {
        for (&id, &(cn, cs)) in &scratch.cat_reg {
            let score = sse_score(cn, cs, n_all - cn, sum_all - cs);
            best.consider(score, SplitOp::Eq(CatId(id)));
        }
    }

    best
}

/// Best split across all features (paper Algorithm 4,
/// `best_split_on_all_feats`). Sequential; the coordinator provides a
/// parallel version.
pub fn best_split_on_all_feats(
    views: &[FeatureView],
    labels: &LabelsView,
    criterion: Criterion,
) -> Option<(usize, ScoredSplit)> {
    let mut scratch = Scratch::new();
    let mut best: Option<(usize, ScoredSplit)> = None;
    for view in views {
        if let Some(s) = best_split_on_feat_with(view, labels, criterion, &mut scratch) {
            let better = match &best {
                None => true,
                Some((_, b)) => s.score > b.score,
            };
            if better {
                best = Some((view.feature, s));
            }
        }
    }
    best
}

/// Paper worked-example fixture shared across test modules.
#[cfg(test)]
pub(crate) mod testdata {
    use crate::data::column::Column;
    use crate::data::interner::Interner;
    use crate::data::value::Value;

    /// Paper Tables 1–2: 22 examples, classes a/b/c, hybrid feature.
    pub(crate) fn paper_example() -> (Column, Vec<u16>, Interner) {
        let mut interner = Interner::new();
        let x = interner.intern("x");
        let y = interner.intern("y");
        let z = interner.intern("z");
        let mut vals = Vec::new();
        let mut labels = Vec::new();
        // class a (label 0): 3 4 4 5 x x y
        for v in [3.0, 4.0, 4.0, 5.0] {
            vals.push(Value::Num(v));
            labels.push(0);
        }
        for c in [x, x, y] {
            vals.push(Value::Cat(c));
            labels.push(0);
        }
        // class b (label 1): 1 1 2 2 3 y y z
        for v in [1.0, 1.0, 2.0, 2.0, 3.0] {
            vals.push(Value::Num(v));
            labels.push(1);
        }
        for c in [y, y, z] {
            vals.push(Value::Cat(c));
            labels.push(1);
        }
        // class c (label 2): 3 4 4 5 5 z z
        for v in [3.0, 4.0, 4.0, 5.0, 5.0] {
            vals.push(Value::Num(v));
            labels.push(2);
        }
        for c in [z, z] {
            vals.push(Value::Cat(c));
            labels.push(2);
        }
        (Column::new("f", vals), labels, interner)
    }
}

#[cfg(test)]
mod tests {
    use super::testdata::paper_example;
    use super::*;
    use crate::data::column::Column;
    use crate::data::interner::Interner;
    use crate::data::value::Value;
    use crate::selection::heuristic::ClassCriterion;

    fn view_of<'a>(
        col: &'a Column,
        rows: &'a [u32],
        sorted: &'a (Vec<u32>, Vec<f64>),
    ) -> FeatureView<'a> {
        FeatureView::new(0, col, rows, &sorted.0, &sorted.1)
    }

    #[test]
    fn paper_best_split_is_le_2_at_minus_0_87() {
        let (col, labels, _) = paper_example();
        let rows: Vec<u32> = (0..col.len() as u32).collect();
        let sorted = col.sorted_numeric();
        let view = view_of(&col, &rows, &sorted);
        let lv = LabelsView::Class {
            ids: &labels,
            n_classes: 3,
        };
        let best = best_split_on_feat(&view, &lv, Criterion::Class(ClassCriterion::InfoGain))
            .expect("has candidates");
        assert_eq!(best.op, SplitOp::Le(2.0));
        assert!((best.score - (-0.87)).abs() < 0.005, "score={}", best.score);
    }

    #[test]
    fn pure_numeric_perfect_split() {
        let col = Column::new(
            "f",
            (0..10).map(|i| Value::Num(i as f64)).collect::<Vec<_>>(),
        );
        let labels: Vec<u16> = (0..10).map(|i| (i >= 5) as u16).collect();
        let rows: Vec<u32> = (0..10).collect();
        let sorted = col.sorted_numeric();
        let view = view_of(&col, &rows, &sorted);
        let lv = LabelsView::Class {
            ids: &labels,
            n_classes: 2,
        };
        let best = best_split_on_feat(&view, &lv, Criterion::Class(ClassCriterion::InfoGain))
            .unwrap();
        assert_eq!(best.op, SplitOp::Le(4.0));
        assert!(best.score.abs() < 1e-12); // perfectly pure
    }

    #[test]
    fn all_same_value_no_split() {
        let col = Column::new("f", vec![Value::Num(1.0); 6]);
        let labels = vec![0u16, 1, 0, 1, 0, 1];
        let rows: Vec<u32> = (0..6).collect();
        let sorted = col.sorted_numeric();
        let view = view_of(&col, &rows, &sorted);
        let lv = LabelsView::Class {
            ids: &labels,
            n_classes: 2,
        };
        // `≤1` has an empty negative side and `>1` an empty positive side;
        // no categorical values — no usable candidate.
        assert!(best_split_on_feat(&view, &lv, Criterion::Class(ClassCriterion::InfoGain))
            .is_none());
    }

    #[test]
    fn missing_rows_always_negative() {
        // Feature: [1, 2, Missing, Missing]; classes [0, 0, 1, 1].
        let col = Column::new(
            "f",
            vec![
                Value::Num(1.0),
                Value::Num(2.0),
                Value::Missing,
                Value::Missing,
            ],
        );
        let labels = vec![0u16, 0, 1, 1];
        let rows: Vec<u32> = (0..4).collect();
        let sorted = col.sorted_numeric();
        let view = view_of(&col, &rows, &sorted);
        let lv = LabelsView::Class {
            ids: &labels,
            n_classes: 2,
        };
        let best = best_split_on_feat(&view, &lv, Criterion::Class(ClassCriterion::InfoGain))
            .unwrap();
        // `≤2` separates numerics (class 0) from missings (class 1): pure.
        assert_eq!(best.op, SplitOp::Le(2.0));
        assert!(best.score.abs() < 1e-12);
    }

    #[test]
    fn regression_exact_split() {
        let col = Column::new(
            "f",
            vec![
                Value::Num(1.0),
                Value::Num(2.0),
                Value::Num(10.0),
                Value::Num(11.0),
            ],
        );
        let targets = vec![5.0, 5.0, 50.0, 50.0];
        let rows: Vec<u32> = (0..4).collect();
        let sorted = col.sorted_numeric();
        let view = view_of(&col, &rows, &sorted);
        let lv = LabelsView::Reg { values: &targets };
        let best = best_split_on_feat(&view, &lv, Criterion::Sse).unwrap();
        assert_eq!(best.op, SplitOp::Le(2.0));
        // Perfect split: SSE form = 10²/2 + 100²/2 = 5050.
        assert!((best.score - 5050.0).abs() < 1e-9);
    }

    #[test]
    fn regression_categorical_candidate_wins() {
        let mut interner = Interner::new();
        let a = interner.intern("a");
        // A missing row breaks the tie between `= a` and `≤ 6` (which
        // would otherwise induce the same partition with the same score).
        let col = Column::new(
            "f",
            vec![
                Value::Cat(a),
                Value::Cat(a),
                Value::Num(5.0),
                Value::Num(6.0),
                Value::Missing,
            ],
        );
        let targets = vec![100.0, 100.0, 1.0, 2.0, 50.0];
        let rows: Vec<u32> = (0..5).collect();
        let sorted = col.sorted_numeric();
        let view = view_of(&col, &rows, &sorted);
        let best = best_split_on_feat(&view, &LabelsView::Reg { values: &targets }, Criterion::Sse)
            .unwrap();
        assert_eq!(best.op, SplitOp::Eq(a));
    }

    #[test]
    fn best_across_features_picks_informative_one() {
        // f0 is noise (each value maps to both classes); f1 separates.
        let col0 = Column::new("f0", vec![Value::Num(1.0), Value::Num(2.0), Value::Num(1.0), Value::Num(2.0)]);
        let col1 = Column::new("f1", vec![Value::Num(0.0), Value::Num(0.0), Value::Num(9.0), Value::Num(9.0)]);
        let labels = vec![0u16, 0, 1, 1];
        let rows: Vec<u32> = (0..4).collect();
        let s0 = col0.sorted_numeric();
        let s1 = col1.sorted_numeric();
        let views = vec![
            FeatureView::new(0, &col0, &rows, &s0.0, &s0.1),
            FeatureView::new(1, &col1, &rows, &s1.0, &s1.1),
        ];
        let lv = LabelsView::Class { ids: &labels, n_classes: 2 };
        let (f, s) = best_split_on_all_feats(&views, &lv, Criterion::Class(ClassCriterion::InfoGain)).unwrap();
        assert_eq!(f, 1);
        assert!(s.score.abs() < 1e-12);
    }

    #[test]
    fn node_subset_rows_respected() {
        // Selection must only see the node's rows, not the whole column.
        let (col, labels, _) = paper_example();
        // Restrict to class-b rows only → node is pure → no informative
        // split, but candidates still score (all score equally).
        let rows: Vec<u32> = (7..15).collect();
        let (all_rows, all_vals) = col.sorted_numeric();
        let mut sorted = (Vec::new(), Vec::new());
        for (r, v) in all_rows.into_iter().zip(all_vals) {
            if (7..15).contains(&(r as usize)) {
                sorted.0.push(r);
                sorted.1.push(v);
            }
        }
        let view = view_of(&col, &rows, &sorted);
        let lv = LabelsView::Class {
            ids: &labels,
            n_classes: 3,
        };
        let best = best_split_on_feat(&view, &lv, Criterion::Class(ClassCriterion::InfoGain))
            .unwrap();
        // Node is pure: conditional entropy is 0 for any split.
        assert!(best.score.abs() < 1e-12);
    }
}
