//! Histogram-binned split selection over pre-quantized bin lanes.
//!
//! The Superfast engine pays `O(M_node)` per feature per node to walk
//! the sorted numeric rows. The binned engine replaces that walk with a
//! per-node per-feature *label histogram* — `n_bins × C` class counts
//! (or `n_bins × (count, sum)` for regression) accumulated in `O(rows)`
//! by the builder — and scans it in `O(B)` for the best `≤ edge` /
//! `> edge` candidate. Because the builder derives the larger child's
//! histograms by parent-minus-sibling subtraction (see
//! `tree/builder.rs::BinnedState`), the amortized accumulate cost per
//! level is the *smaller* side of every split.
//!
//! Scoring replicates the Superfast formulas and tie-breaking exactly
//! (same `score_with_totals` closures, same empty-side guards, same
//! strictly-greater `Consider`), so when the dataset's bin lanes are
//! lossless (`BinLane::is_exact`: every column's distinct count ≤
//! `max_bins`) the chosen predicate, gain and partition are identical to
//! the exact engine — the property suite in `tests/prop_binned.rs`
//! enforces this. Categorical `= c` candidates carry no histogram; they
//! reuse the grouped walk over the maintained categorical lists, same
//! as the exact engine's pass 3.

use super::heuristic::{sse_score, Criterion};
use super::split::SplitOp;
use super::superfast::{Consider, FeatureView, LabelsView, ScoredSplit, Scratch};
use crate::data::interner::CatId;

/// Histogram layout width per bin: one slot per class, or `(count, sum)`
/// for regression.
pub fn hist_width(labels: &LabelsView) -> usize {
    match labels {
        LabelsView::Class { n_classes, .. } => *n_classes,
        LabelsView::Reg { .. } => 2,
    }
}

/// Best split on one feature from its node histogram.
///
/// `hist` is the node's label histogram for this feature
/// (`edges.len() * hist_width` slots); `edges` is the column's bin-edge
/// table (actual data values, so every candidate is a valid predicate).
/// Builder contract: `view.class_counts` holds the node's class counts
/// (classification), `view.reg_stats` the node `(n, sum)` (regression),
/// and the categorical lists are maintained (`cat_lists_valid`).
pub fn best_split_on_feat_binned(
    view: &FeatureView,
    labels: &LabelsView,
    criterion: Criterion,
    hist: &[f64],
    edges: &[f64],
    scratch: &mut Scratch,
) -> Option<ScoredSplit> {
    match (labels, criterion) {
        (LabelsView::Class { ids, n_classes }, Criterion::Class(crit)) => {
            classification(view, ids, *n_classes, crit, hist, edges, scratch)
        }
        (LabelsView::Reg { values }, Criterion::Sse) => {
            regression(view, values, hist, edges)
        }
        // ANALYZE-ALLOW(no-unwrap): criterion/labels pairing is fixed by task kind at config validation
        _ => panic!("criterion/labels kind mismatch"),
    }
}

fn classification(
    view: &FeatureView,
    ids: &[u16],
    n_classes: usize,
    crit: super::heuristic::ClassCriterion,
    hist: &[f64],
    edges: &[f64],
    scratch: &mut Scratch,
) -> Option<ScoredSplit> {
    let c = n_classes;
    let n_bins = edges.len();
    debug_assert_eq!(hist.len(), n_bins * c);
    debug_assert_eq!(view.class_counts.len(), c, "builder provides node stats");
    scratch.reset_class(c);

    // Totals: numeric per-class counts from the histogram, the rest
    // (categorical + missing rows — false under every numeric candidate)
    // by subtraction from the node's class counts.
    for row in hist.chunks_exact(c) {
        for y in 0..c {
            scratch.tot_num[y] += row[y];
        }
    }
    for y in 0..c {
        scratch.rest[y] = view.class_counts[y] - scratch.tot_num[y];
    }
    let n_num_total: f64 = scratch.tot_num.iter().sum();
    let rest_total: f64 = scratch.rest.iter().sum();

    let mut best: Option<ScoredSplit> = None;

    // `O(B)` prefix walk over the bins. Bins empty *in this node* are
    // skipped: their candidates induce the same partition as the last
    // non-empty bin's (never strictly better), and skipping keeps the
    // candidate set identical to the exact engine's distinct-value walk
    // when the lane is lossless.
    let mut cum_total = 0.0f64;
    for (b, row) in hist.chunks_exact(c).enumerate() {
        let bin_n: f64 = row.iter().sum();
        if bin_n == 0.0 {
            continue;
        }
        for y in 0..c {
            scratch.cum[y] += row[y];
        }
        cum_total += bin_n;
        let x = edges[b];
        let (cum, tot_num, rest) = (&scratch.cum, &scratch.tot_num, &scratch.rest);
        // `≤ x`: pos = prefix counts; neg = remaining numerics + rest.
        let pos_total = cum_total;
        let neg_total = n_num_total - cum_total + rest_total;
        if pos_total > 0.0 && neg_total > 0.0 {
            let score = crit.score_with_totals(c, pos_total, neg_total, |y| {
                (cum[y], tot_num[y] - cum[y] + rest[y])
            });
            best.consider(score, SplitOp::Le(x));
        }
        // `> x`: pos = suffix numerics; neg = prefix + rest.
        let pos_total = n_num_total - cum_total;
        let neg_total = cum_total + rest_total;
        if pos_total > 0.0 && neg_total > 0.0 {
            let score = crit.score_with_totals(c, pos_total, neg_total, |y| {
                (tot_num[y] - cum[y], cum[y] + rest[y])
            });
            best.consider(score, SplitOp::Gt(x));
        }
    }

    // Categorical `= x` candidates: no histogram — grouped walk over the
    // maintained categorical lists, exactly the exact engine's pass 3.
    let all_total = n_num_total + rest_total;
    let cat_ids = view.sorted_cat_ids;
    let cat_rows = view.sorted_cat_rows;
    let inline_cat_labs = view.sorted_cat_labs.len() == cat_ids.len();
    let mut i = 0;
    while i < cat_ids.len() {
        let id = cat_ids[i];
        for y in 0..c {
            scratch.pos[y] = 0.0;
        }
        let mut pos_total = 0.0f64;
        while i < cat_ids.len() && cat_ids[i] == id {
            let y = if inline_cat_labs {
                view.sorted_cat_labs[i] as usize
            } else {
                ids[cat_rows[i] as usize] as usize
            };
            scratch.pos[y] += 1.0;
            pos_total += 1.0;
            i += 1;
        }
        let neg_total = all_total - pos_total;
        if pos_total > 0.0 && neg_total > 0.0 {
            for y in 0..c {
                scratch.neg[y] = scratch.tot_num[y] + scratch.rest[y] - scratch.pos[y];
            }
            let score = crit.score(&scratch.pos, &scratch.neg);
            best.consider(score, SplitOp::Eq(CatId(id)));
        }
    }

    best
}

fn regression(
    view: &FeatureView,
    values: &[f64],
    hist: &[f64],
    edges: &[f64],
) -> Option<ScoredSplit> {
    let n_bins = edges.len();
    debug_assert_eq!(hist.len(), n_bins * 2);
    // Totals: numeric (count, sum) from the histogram, the rest by
    // subtraction from the node stats — same sequence as the exact
    // engine's fast path.
    let (mut n_num, mut sum_num) = (0.0f64, 0.0f64);
    for pair in hist.chunks_exact(2) {
        n_num += pair[0];
        sum_num += pair[1];
    }
    // ANALYZE-ALLOW(no-unwrap): the builder computes reg stats for every regression node
    let (n_all_s, sum_all_s) = view.reg_stats.expect("builder provides node reg stats");
    let n_rest = n_all_s - n_num;
    let sum_rest = sum_all_s - sum_num;
    let (n_all, sum_all) = (n_num + n_rest, sum_num + sum_rest);

    let mut best: Option<ScoredSplit> = None;

    // `O(B)` prefix walk (empty-in-node bins skipped, as above).
    let (mut cum_n, mut cum_sum) = (0.0f64, 0.0f64);
    for (b, pair) in hist.chunks_exact(2).enumerate() {
        if pair[0] == 0.0 {
            continue;
        }
        cum_n += pair[0];
        cum_sum += pair[1];
        let x = edges[b];
        // `≤ x`
        let score = sse_score(cum_n, cum_sum, n_all - cum_n, sum_all - cum_sum);
        best.consider(score, SplitOp::Le(x));
        // `> x`
        let score = sse_score(
            n_num - cum_n,
            sum_num - cum_sum,
            cum_n + n_rest,
            cum_sum + sum_rest,
        );
        best.consider(score, SplitOp::Gt(x));
    }

    // Categorical candidates: grouped walk, exact engine's pass 3.
    let cat_ids = view.sorted_cat_ids;
    let cat_rows = view.sorted_cat_rows;
    let mut i = 0;
    while i < cat_ids.len() {
        let id = cat_ids[i];
        let (mut cn, mut cs) = (0.0f64, 0.0f64);
        while i < cat_ids.len() && cat_ids[i] == id {
            cn += 1.0;
            cs += values[cat_rows[i] as usize];
            i += 1;
        }
        let score = sse_score(cn, cs, n_all - cn, sum_all - cs);
        best.consider(score, SplitOp::Eq(CatId(id)));
    }

    best
}

/// Best classification split on one feature from histograms alone — the
/// out-of-core twin of the view-based scorer above. Sharded training
/// has no `FeatureView` (no sorted lanes, no categorical lists); the
/// categorical candidates come from `cat`, a dense `cat_card × C` label
/// table accumulated shard-by-shard, walked in ascending-id order —
/// the same group order as the in-memory categorical lists, so the
/// candidate sequence (and therefore strictly-greater tie-breaking) is
/// identical. `class_counts` is the node's per-class row count.
pub(crate) fn best_split_class_stats(
    class_counts: &[f64],
    crit: super::heuristic::ClassCriterion,
    hist: &[f64],
    edges: &[f64],
    cat: &[f64],
    scratch: &mut Scratch,
) -> Option<ScoredSplit> {
    let c = class_counts.len();
    let n_bins = edges.len();
    debug_assert_eq!(hist.len(), n_bins * c);
    debug_assert_eq!(cat.len() % c.max(1), 0);
    scratch.reset_class(c);

    // Totals: numeric per-class counts from the histogram, the rest by
    // subtraction from the node's class counts (same arithmetic as the
    // view-based path, so every intermediate is bit-identical).
    for row in hist.chunks_exact(c) {
        for y in 0..c {
            scratch.tot_num[y] += row[y];
        }
    }
    for y in 0..c {
        scratch.rest[y] = class_counts[y] - scratch.tot_num[y];
    }
    let n_num_total: f64 = scratch.tot_num.iter().sum();
    let rest_total: f64 = scratch.rest.iter().sum();

    let mut best: Option<ScoredSplit> = None;

    // `O(B)` prefix walk, empty-in-node bins skipped (see above).
    let mut cum_total = 0.0f64;
    for (b, row) in hist.chunks_exact(c).enumerate() {
        let bin_n: f64 = row.iter().sum();
        if bin_n == 0.0 {
            continue;
        }
        for y in 0..c {
            scratch.cum[y] += row[y];
        }
        cum_total += bin_n;
        let x = edges[b];
        let (cum, tot_num, rest) = (&scratch.cum, &scratch.tot_num, &scratch.rest);
        let pos_total = cum_total;
        let neg_total = n_num_total - cum_total + rest_total;
        if pos_total > 0.0 && neg_total > 0.0 {
            let score = crit.score_with_totals(c, pos_total, neg_total, |y| {
                (cum[y], tot_num[y] - cum[y] + rest[y])
            });
            best.consider(score, SplitOp::Le(x));
        }
        let pos_total = n_num_total - cum_total;
        let neg_total = cum_total + rest_total;
        if pos_total > 0.0 && neg_total > 0.0 {
            let score = crit.score_with_totals(c, pos_total, neg_total, |y| {
                (tot_num[y] - cum[y], cum[y] + rest[y])
            });
            best.consider(score, SplitOp::Gt(x));
        }
    }

    // Categorical `= id` candidates from the dense table. Ids with no
    // rows in this node are skipped — they are exactly the ids the
    // grouped-list walk never visits.
    let all_total = n_num_total + rest_total;
    for (id, row) in cat.chunks_exact(c.max(1)).enumerate() {
        let pos_total: f64 = row.iter().sum();
        if pos_total == 0.0 {
            continue;
        }
        let neg_total = all_total - pos_total;
        if neg_total > 0.0 {
            for y in 0..c {
                scratch.pos[y] = row[y];
                scratch.neg[y] = scratch.tot_num[y] + scratch.rest[y] - row[y];
            }
            let score = crit.score(&scratch.pos, &scratch.neg);
            best.consider(score, SplitOp::Eq(CatId(id as u32)));
        }
    }

    best
}

/// Best regression (SSE) split from histograms alone — out-of-core twin
/// of the view-based `regression` scorer. `cat` is a dense
/// `cat_card × 2` `(count, sum)` table; `reg_stats` the node `(n, sum)`.
pub(crate) fn best_split_reg_stats(
    reg_stats: (f64, f64),
    hist: &[f64],
    edges: &[f64],
    cat: &[f64],
) -> Option<ScoredSplit> {
    let n_bins = edges.len();
    debug_assert_eq!(hist.len(), n_bins * 2);
    let (mut n_num, mut sum_num) = (0.0f64, 0.0f64);
    for pair in hist.chunks_exact(2) {
        n_num += pair[0];
        sum_num += pair[1];
    }
    let (n_all_s, sum_all_s) = reg_stats;
    let n_rest = n_all_s - n_num;
    let sum_rest = sum_all_s - sum_num;
    let (n_all, sum_all) = (n_num + n_rest, sum_num + sum_rest);

    let mut best: Option<ScoredSplit> = None;

    let (mut cum_n, mut cum_sum) = (0.0f64, 0.0f64);
    for (b, pair) in hist.chunks_exact(2).enumerate() {
        if pair[0] == 0.0 {
            continue;
        }
        cum_n += pair[0];
        cum_sum += pair[1];
        let x = edges[b];
        let score = sse_score(cum_n, cum_sum, n_all - cum_n, sum_all - cum_sum);
        best.consider(score, SplitOp::Le(x));
        let score = sse_score(
            n_num - cum_n,
            sum_num - cum_sum,
            cum_n + n_rest,
            cum_sum + sum_rest,
        );
        best.consider(score, SplitOp::Gt(x));
    }

    for (id, pair) in cat.chunks_exact(2).enumerate() {
        if pair[0] == 0.0 {
            continue;
        }
        let (cn, cs) = (pair[0], pair[1]);
        let score = sse_score(cn, cs, n_all - cn, sum_all - cs);
        best.consider(score, SplitOp::Eq(CatId(id as u32)));
    }

    best
}

/// Accumulate one node's rows into a feature histogram (classification:
/// `+1` at `[bin · C + class]`; regression: `(count, sum)` at
/// `[bin · 2]`). `rows` is the node's numeric row list for the feature;
/// `bin_of_row` is the column's dataset-level bin lane. `labs` is the
/// builder-maintained label list parallel to `rows` (may be empty —
/// labels are then looked up through the row ids).
pub fn accumulate(
    hist: &mut [f64],
    rows: &[u32],
    labs: &[u16],
    labels: &LabelsView,
    bin_of_row: impl Fn(usize) -> usize,
) {
    match labels {
        LabelsView::Class { ids, n_classes } => {
            let c = *n_classes;
            if labs.len() == rows.len() {
                for (i, &r) in rows.iter().enumerate() {
                    hist[bin_of_row(r as usize) * c + labs[i] as usize] += 1.0;
                }
            } else {
                for &r in rows {
                    hist[bin_of_row(r as usize) * c + ids[r as usize] as usize] += 1.0;
                }
            }
        }
        LabelsView::Reg { values } => {
            for &r in rows {
                let b = bin_of_row(r as usize) * 2;
                hist[b] += 1.0;
                hist[b + 1] += values[r as usize];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::column::Column;
    use crate::data::column_data::BinLane;
    use crate::data::value::Value;
    use crate::selection::heuristic::ClassCriterion;
    use crate::selection::superfast::best_split_on_feat;

    /// Build a lossless lane + node histogram for the whole column and
    /// check the binned scorer against the exact engine.
    fn assert_matches_exact(col: &Column, labels: LabelsView, criterion: Criterion) {
        let n = col.len();
        let rows: Vec<u32> = (0..n as u32).collect();
        let (sorted_rows, sorted_vals) = col.sorted_numeric();
        let lane = BinLane::build(&sorted_rows, &sorted_vals, n, 1 << 16);
        let (cat_rows, cat_ids) = col.sorted_categorical();

        // Exact oracle (conservative view: stats pass recomputes totals).
        let view = FeatureView::new(0, col, &rows, &sorted_rows, &sorted_vals);
        let exact = best_split_on_feat(&view, &labels, criterion);

        // Binned view needs the builder-contract fields filled in.
        let mut class_counts = Vec::new();
        let mut reg_stats = None;
        match &labels {
            LabelsView::Class { ids, n_classes } => {
                class_counts.resize(*n_classes, 0.0);
                for &r in &rows {
                    class_counts[ids[r as usize] as usize] += 1.0;
                }
            }
            LabelsView::Reg { values } => {
                let sum: f64 = rows.iter().map(|&r| values[r as usize]).sum();
                reg_stats = Some((n as f64, sum));
            }
        }
        let mut view = FeatureView::new(0, col, &rows, &sorted_rows, &sorted_vals);
        view.class_counts = &class_counts;
        view.reg_stats = reg_stats;
        view.sorted_cat_rows = &cat_rows;
        view.sorted_cat_ids = &cat_ids;
        view.cat_lists_valid = true;

        let binned = match &lane {
            Some(lane) => {
                assert!(lane.is_exact);
                let width = hist_width(&labels);
                let mut hist = vec![0.0; lane.n_bins() * width];
                accumulate(&mut hist, &sorted_rows, &[], &labels, |r| {
                    lane.bin_of_row(r)
                });
                let mut scratch = Scratch::new();
                best_split_on_feat_binned(
                    &view,
                    &labels,
                    criterion,
                    &hist,
                    &lane.edges,
                    &mut scratch,
                )
            }
            None => {
                // No numeric cells: empty histogram, empty edge table.
                let mut scratch = Scratch::new();
                best_split_on_feat_binned(&view, &labels, criterion, &[], &[], &mut scratch)
            }
        };
        assert_eq!(
            binned.map(|s| s.op),
            exact.map(|s| s.op),
            "op mismatch on {}",
            col.name
        );
        if let (Some(b), Some(e)) = (binned, exact) {
            assert!((b.score - e.score).abs() < 1e-12, "{} vs {}", b.score, e.score);
        }
    }

    #[test]
    fn matches_exact_on_paper_example() {
        let (col, labels, _) = crate::selection::superfast::testdata::paper_example();
        assert_matches_exact(
            &col,
            LabelsView::Class {
                ids: &labels,
                n_classes: 3,
            },
            Criterion::Class(ClassCriterion::InfoGain),
        );
    }

    #[test]
    fn matches_exact_on_every_criterion() {
        let (col, labels, _) = crate::selection::superfast::testdata::paper_example();
        for crit in [
            ClassCriterion::InfoGain,
            ClassCriterion::Gini,
            ClassCriterion::ChiSquare,
        ] {
            assert_matches_exact(
                &col,
                LabelsView::Class {
                    ids: &labels,
                    n_classes: 3,
                },
                Criterion::Class(crit),
            );
        }
    }

    #[test]
    fn matches_exact_on_regression_with_missing() {
        let col = Column::new(
            "f",
            vec![
                Value::Num(1.0),
                Value::Num(2.0),
                Value::Num(2.0),
                Value::Missing,
                Value::Num(10.0),
            ],
        );
        let targets = vec![5.0, 5.5, 4.5, 30.0, 50.0];
        assert_matches_exact(&col, LabelsView::Reg { values: &targets }, Criterion::Sse);
    }

    /// The stats-based twins must agree with the view-based scorers —
    /// same op, bit-identical score — given the same histograms and a
    /// dense cat table built from the same rows.
    fn assert_stats_twin_matches(col: &Column, labels: LabelsView, criterion: Criterion) {
        let n = col.len();
        let rows: Vec<u32> = (0..n as u32).collect();
        let (sorted_rows, sorted_vals) = col.sorted_numeric();
        let (cat_rows, cat_ids) = col.sorted_categorical();
        let lane = BinLane::build(&sorted_rows, &sorted_vals, n, 64);
        let (hist, edges): (Vec<f64>, Vec<f64>) = match &lane {
            Some(lane) => {
                let mut h = vec![0.0; lane.n_bins() * hist_width(&labels)];
                accumulate(&mut h, &sorted_rows, &[], &labels, |r| lane.bin_of_row(r));
                (h, lane.edges.to_vec())
            }
            None => (Vec::new(), Vec::new()),
        };
        let width = hist_width(&labels);
        let cat_card = cat_ids.iter().copied().max().map_or(0, |m| m as usize + 1);
        let mut cat = vec![0.0; cat_card * width];
        for (&id, &r) in cat_ids.iter().zip(&cat_rows) {
            match &labels {
                LabelsView::Class { ids, .. } => {
                    cat[id as usize * width + ids[r as usize] as usize] += 1.0;
                }
                LabelsView::Reg { values } => {
                    cat[id as usize * 2] += 1.0;
                    cat[id as usize * 2 + 1] += values[r as usize];
                }
            }
        }

        let mut class_counts = Vec::new();
        let mut reg_stats = None;
        match &labels {
            LabelsView::Class { ids, n_classes } => {
                class_counts.resize(*n_classes, 0.0);
                for &r in &rows {
                    class_counts[ids[r as usize] as usize] += 1.0;
                }
            }
            LabelsView::Reg { values } => {
                let sum: f64 = rows.iter().map(|&r| values[r as usize]).sum();
                reg_stats = Some((n as f64, sum));
            }
        }
        let mut view = FeatureView::new(0, col, &rows, &sorted_rows, &sorted_vals);
        view.class_counts = &class_counts;
        view.reg_stats = reg_stats;
        view.sorted_cat_rows = &cat_rows;
        view.sorted_cat_ids = &cat_ids;
        view.cat_lists_valid = true;
        let mut scratch = Scratch::new();
        let via_view =
            best_split_on_feat_binned(&view, &labels, criterion, &hist, &edges, &mut scratch);
        let via_stats = match (&labels, criterion) {
            (LabelsView::Class { .. }, Criterion::Class(crit)) => {
                let mut scratch = Scratch::new();
                best_split_class_stats(&class_counts, crit, &hist, &edges, &cat, &mut scratch)
            }
            (LabelsView::Reg { .. }, Criterion::Sse) => {
                best_split_reg_stats(reg_stats.unwrap(), &hist, &edges, &cat)
            }
            _ => unreachable!(),
        };
        assert_eq!(via_stats.as_ref().map(|s| s.op), via_view.as_ref().map(|s| s.op));
        if let (Some(a), Some(b)) = (via_stats, via_view) {
            assert_eq!(a.score.to_bits(), b.score.to_bits(), "score must be bit-identical");
        }
    }

    #[test]
    fn stats_twins_match_view_scorers() {
        // Hybrid column: numerics, two categorical groups, a missing.
        let mut i = crate::data::interner::Interner::new();
        let (red, blue) = (i.intern("red"), i.intern("blue"));
        let col = Column::new(
            "h",
            vec![
                Value::Num(1.0),
                Value::Cat(red),
                Value::Num(2.0),
                Value::Cat(blue),
                Value::Missing,
                Value::Num(2.0),
                Value::Cat(red),
                Value::Num(5.0),
            ],
        );
        let ids: Vec<u16> = vec![0, 1, 0, 2, 1, 1, 1, 2];
        for crit in [
            ClassCriterion::InfoGain,
            ClassCriterion::Gini,
            ClassCriterion::ChiSquare,
        ] {
            assert_stats_twin_matches(
                &col,
                LabelsView::Class { ids: &ids, n_classes: 3 },
                Criterion::Class(crit),
            );
        }
        let targets = vec![5.0, 9.0, 4.5, -2.0, 30.0, 5.5, 8.0, 50.0];
        assert_stats_twin_matches(&col, LabelsView::Reg { values: &targets }, Criterion::Sse);

        // Pure categorical (no numeric lane at all).
        let col = Column::new(
            "c",
            vec![Value::Cat(red), Value::Cat(blue), Value::Cat(red), Value::Cat(blue)],
        );
        let ids: Vec<u16> = vec![0, 1, 0, 1];
        assert_stats_twin_matches(
            &col,
            LabelsView::Class { ids: &ids, n_classes: 2 },
            Criterion::Class(ClassCriterion::Gini),
        );
    }

    #[test]
    fn lossy_bins_pick_a_valid_edge() {
        // 100 distinct values, 4 bins: the binned scorer must return one
        // of the bin edges (a real data value) with both sides non-empty.
        let cells: Vec<Value> = (0..100).map(|i| Value::Num(i as f64)).collect();
        let col = Column::new("f", cells);
        let ids: Vec<u16> = (0..100).map(|i| (i >= 50) as u16).collect();
        let labels = LabelsView::Class {
            ids: &ids,
            n_classes: 2,
        };
        let rows: Vec<u32> = (0..100).collect();
        let (sorted_rows, sorted_vals) = col.sorted_numeric();
        let lane = BinLane::build(&sorted_rows, &sorted_vals, 100, 4).unwrap();
        assert!(!lane.is_exact);
        let mut hist = vec![0.0; lane.n_bins() * 2];
        accumulate(&mut hist, &sorted_rows, &[], &labels, |r| lane.bin_of_row(r));
        let class_counts = [50.0, 50.0];
        let mut view = FeatureView::new(0, &col, &rows, &sorted_rows, &sorted_vals);
        view.class_counts = &class_counts;
        view.cat_lists_valid = true;
        let mut scratch = Scratch::new();
        let best = best_split_on_feat_binned(
            &view,
            &labels,
            Criterion::Class(ClassCriterion::Gini),
            &hist,
            &lane.edges,
            &mut scratch,
        )
        .unwrap();
        match best.op {
            SplitOp::Le(x) | SplitOp::Gt(x) => {
                assert!(lane.edges.contains(&x), "edge {x} not in table");
            }
            SplitOp::Eq(_) => panic!("numeric column produced Eq"),
        }
    }
}
