//! XLA-accelerated split selection backend (filled in with the runtime).
//!
//! Large nodes can evaluate the histogram + prefix-scan + scoring hot-spot
//! through the AOT-compiled JAX/Pallas artifacts (see
//! `python/compile/kernels/`) executed on the PJRT CPU client. The native
//! Rust engine remains exact and is the default; this backend bins numeric
//! values to 256 quantiles first (DESIGN.md §2).

// Implemented in `crate::runtime`; re-exported here for discoverability.
pub use crate::runtime::xla_split::{XlaSelection, XlaSelectionConfig};
