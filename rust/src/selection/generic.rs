//! Generic split selection (paper Algorithm 1) — the `O(M·N)` baseline.
//!
//! For every distinct value of the feature it re-scans *all* of the node's
//! rows to build the positive/negative class counts of each candidate,
//! then applies the same criterion as [`super::superfast`]. It must agree
//! with Superfast Selection on every candidate's score — that equivalence
//! is the core correctness property of the paper and is enforced by the
//! property tests in `rust/tests/prop_selection.rs`.
//!
//! Unlike the production engine, this oracle deliberately reads cells
//! through the tagged-[`Value`] boundary accessor ([`Column::get`] via
//! `view.col`) instead of the typed lanes — an independent code path is
//! exactly what makes the equivalence tests meaningful.
//!
//! [`Column::get`]: crate::data::column::Column::get

use super::heuristic::{sse_score, Criterion};
use super::split::SplitOp;
use super::superfast::{FeatureView, LabelsView, ScoredSplit};
use crate::data::interner::CatId;
use crate::data::value::Value;
use std::collections::BTreeSet;

/// Best split on one feature by exhaustive re-scanning.
pub fn best_split_on_feat_generic(
    view: &FeatureView,
    labels: &LabelsView,
    criterion: Criterion,
) -> Option<ScoredSplit> {
    // Collect the unique value sets (one O(M) scan, as Algorithm 1 line 2).
    let mut nums: Vec<f64> = Vec::new();
    let mut cats: BTreeSet<u32> = BTreeSet::new();
    for &r in view.rows {
        match view.col.get(r as usize) {
            Value::Num(x) => nums.push(x),
            Value::Cat(CatId(id)) => {
                cats.insert(id);
            }
            Value::Missing => {}
        }
    }
    // ANALYZE-ALLOW(no-unwrap): Value::Num cells are non-NaN (NaN ingests as Missing)
    nums.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
    nums.dedup();

    let mut best: Option<ScoredSplit> = None;
    let consider = |score: f64, op: SplitOp, best: &mut Option<ScoredSplit>| {
        if score.is_finite() {
            let better = match best {
                None => true,
                Some(b) => score > b.score,
            };
            if better {
                *best = Some(ScoredSplit { score, op });
            }
        }
    };

    // Candidate loop: one full O(M) scan per candidate (the cost the paper
    // eliminates). Candidates enumerate in the same order as superfast
    // (ascending numerics: ≤ then >; then ascending categorical ids) so
    // tie-breaking matches.
    let ops = nums
        .iter()
        .flat_map(|&x| [SplitOp::Le(x), SplitOp::Gt(x)])
        .chain(cats.iter().map(|&id| SplitOp::Eq(CatId(id))));
    for op in ops {
        match labels {
            LabelsView::Class { ids, n_classes } => {
                let c = *n_classes;
                let mut pos = vec![0.0f64; c];
                let mut neg = vec![0.0f64; c];
                for &r in view.rows {
                    let y = ids[r as usize] as usize;
                    if op.eval(view.col.get(r as usize)) {
                        pos[y] += 1.0;
                    } else {
                        neg[y] += 1.0;
                    }
                }
                let tp: f64 = pos.iter().sum();
                let tn: f64 = neg.iter().sum();
                if tp > 0.0 && tn > 0.0 {
                    let crit = match criterion {
                        Criterion::Class(cc) => cc,
                        // ANALYZE-ALLOW(no-unwrap): criterion/labels pairing is fixed by task kind at config validation
                        Criterion::Sse => panic!("criterion/labels kind mismatch"),
                    };
                    consider(crit.score(&pos, &neg), op, &mut best);
                }
            }
            LabelsView::Reg { values } => {
                let (mut np, mut sp, mut nn, mut sn) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
                for &r in view.rows {
                    let y = values[r as usize];
                    if op.eval(view.col.get(r as usize)) {
                        np += 1.0;
                        sp += y;
                    } else {
                        nn += 1.0;
                        sn += y;
                    }
                }
                consider(sse_score(np, sp, nn, sn), op, &mut best);
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::column::Column;
    use crate::selection::heuristic::ClassCriterion;
    use crate::selection::superfast::best_split_on_feat;

    #[test]
    fn matches_superfast_on_paper_example() {
        let (col, labels, _) = crate::selection::superfast::testdata::paper_example();
        let rows: Vec<u32> = (0..col.len() as u32).collect();
        let sorted = col.sorted_numeric();
        let view = FeatureView::new(0, &col, &rows, &sorted.0, &sorted.1);
        let lv = LabelsView::Class {
            ids: &labels,
            n_classes: 3,
        };
        let crit = Criterion::Class(ClassCriterion::InfoGain);
        let fast = best_split_on_feat(&view, &lv, crit).unwrap();
        let slow = best_split_on_feat_generic(&view, &lv, crit).unwrap();
        assert_eq!(fast.op, slow.op);
        assert!((fast.score - slow.score).abs() < 1e-12);
    }

    #[test]
    fn agrees_on_degenerate_column() {
        let col = Column::new("f", vec![Value::Missing; 4]);
        let labels = vec![0u16, 1, 0, 1];
        let rows: Vec<u32> = (0..4).collect();
        let sorted = col.sorted_numeric();
        let view = FeatureView::new(0, &col, &rows, &sorted.0, &sorted.1);
        let lv = LabelsView::Class {
            ids: &labels,
            n_classes: 2,
        };
        let crit = Criterion::Class(ClassCriterion::InfoGain);
        assert!(best_split_on_feat(&view, &lv, crit).is_none());
        assert!(best_split_on_feat_generic(&view, &lv, crit).is_none());
    }

    #[test]
    fn regression_agreement_small() {
        let col = Column::new(
            "f",
            vec![
                Value::Num(1.0),
                Value::Num(3.0),
                Value::Num(3.0),
                Value::Num(7.0),
                Value::Missing,
            ],
        );
        let targets = vec![1.0, 2.0, 2.5, 9.0, 5.0];
        let rows: Vec<u32> = (0..5).collect();
        let sorted = col.sorted_numeric();
        let view = FeatureView::new(0, &col, &rows, &sorted.0, &sorted.1);
        let lv = LabelsView::Reg { values: &targets };
        let fast = best_split_on_feat(&view, &lv, Criterion::Sse).unwrap();
        let slow = best_split_on_feat_generic(&view, &lv, Criterion::Sse).unwrap();
        assert_eq!(fast.op, slow.op);
        assert!((fast.score - slow.score).abs() < 1e-9 * fast.score.abs().max(1.0));
    }
}
