//! Split criteria. All scores follow the convention **higher is better**.
//!
//! Classification criteria consume the per-class positive/negative counts
//! of a binary split (paper Algorithm 3 signature); each evaluation is
//! `O(C)`, which is what makes Superfast Selection `O(M + N·C)` overall.
//! Regression uses the SSE criterion of paper Eq. 3 reduced to the
//! `Σ²/n` form that prefix sums can evaluate in `O(1)` per candidate.

/// Classification criterion selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ClassCriterion {
    /// Simplified information gain (paper Algorithm 3): `−H(T|a)` up to
    /// the constant `H(T)`.
    #[default]
    InfoGain,
    /// Negative weighted Gini impurity.
    Gini,
    /// Pearson χ² statistic of the 2×C contingency table.
    ChiSquare,
}

impl ClassCriterion {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "info_gain" | "ig" | "entropy" => Some(Self::InfoGain),
            "gini" => Some(Self::Gini),
            "chi2" | "chi_square" => Some(Self::ChiSquare),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::InfoGain => "info_gain",
            Self::Gini => "gini",
            Self::ChiSquare => "chi2",
        }
    }

    /// Score a binary split from per-class counts. `pos[i]` / `neg[i]` are
    /// the numbers of class-`i` examples on the positive / negative side.
    #[inline]
    pub fn score(&self, pos: &[f64], neg: &[f64]) -> f64 {
        match self {
            Self::InfoGain => info_gain(pos, neg),
            Self::Gini => neg_gini(pos, neg),
            Self::ChiSquare => chi_square(pos, neg),
        }
    }

    /// Hot-path variant: per-class counts come from a closure and the
    /// side totals are already known (Superfast Selection maintains them
    /// incrementally), so scoring is a single `O(C)` pass with no
    /// intermediate arrays. Must agree exactly with [`Self::score`].
    #[inline]
    pub fn score_with_totals(
        &self,
        c: usize,
        tot_p: f64,
        tot_n: f64,
        mut count_of: impl FnMut(usize) -> (f64, f64),
    ) -> f64 {
        let tot = tot_p + tot_n;
        if tot == 0.0 {
            return f64::NEG_INFINITY;
        }
        match self {
            Self::InfoGain => {
                // Accumulation order and expression forms mirror
                // [`info_gain`] exactly (all positive terms, then all
                // negative terms) so the two code paths are bit-identical
                // — cross-engine tie-breaking depends on it.
                let inv_tot = 1.0 / tot;
                let mut ret = 0.0;
                if tot_p > 0.0 {
                    let inv_p = 1.0 / tot_p;
                    for y in 0..c {
                        let (p, _) = count_of(y);
                        if p > 0.0 {
                            ret += p * inv_tot * (p * inv_p).ln();
                        }
                    }
                }
                if tot_n > 0.0 {
                    let inv_n = 1.0 / tot_n;
                    for y in 0..c {
                        let (_, n) = count_of(y);
                        if n > 0.0 {
                            ret += n * inv_tot * (n * inv_n).ln();
                        }
                    }
                }
                ret
            }
            Self::Gini => {
                let mut impurity = 0.0;
                if tot_p > 0.0 {
                    let mut s = 0.0;
                    for y in 0..c {
                        let (p, _) = count_of(y);
                        s += (p / tot_p) * (p / tot_p);
                    }
                    impurity += tot_p / tot * (1.0 - s);
                }
                if tot_n > 0.0 {
                    let mut s = 0.0;
                    for y in 0..c {
                        let (_, n) = count_of(y);
                        s += (n / tot_n) * (n / tot_n);
                    }
                    impurity += tot_n / tot * (1.0 - s);
                }
                -impurity
            }
            Self::ChiSquare => {
                if tot_p == 0.0 || tot_n == 0.0 {
                    return 0.0;
                }
                let mut stat = 0.0;
                for y in 0..c {
                    let (p, n) = count_of(y);
                    let class_tot = p + n;
                    if class_tot == 0.0 {
                        continue;
                    }
                    let exp_p = tot_p * class_tot / tot;
                    let exp_n = tot_n * class_tot / tot;
                    stat += (p - exp_p) * (p - exp_p) / exp_p;
                    stat += (n - exp_n) * (n - exp_n) / exp_n;
                }
                stat
            }
        }
    }
}

/// Task-level criterion (classification variants or regression SSE).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Criterion {
    Class(ClassCriterion),
    /// Regression: maximize `Σ_pos²/n_pos + Σ_neg²/n_neg` (equivalent to
    /// minimizing SSE, paper Eq. 3 with the constant term dropped).
    Sse,
}

impl Default for Criterion {
    fn default() -> Self {
        Criterion::Class(ClassCriterion::InfoGain)
    }
}

/// Paper Algorithm 3: simplified information gain,
/// `Σ_i (p_i/tot)·log(p_i/tot_p) + Σ_i (n_i/tot)·log(n_i/tot_n)`.
/// Natural log (matches the worked example's −0.87 at `≤ 2`).
#[inline]
pub fn info_gain(pos: &[f64], neg: &[f64]) -> f64 {
    let tot_p: f64 = pos.iter().sum();
    let tot_n: f64 = neg.iter().sum();
    let tot = tot_p + tot_n;
    if tot == 0.0 {
        return f64::NEG_INFINITY;
    }
    let inv_tot = 1.0 / tot;
    let mut ret = 0.0;
    if tot_p > 0.0 {
        let inv_p = 1.0 / tot_p;
        for &p in pos {
            if p > 0.0 {
                ret += p * inv_tot * (p * inv_p).ln();
            }
        }
    }
    if tot_n > 0.0 {
        let inv_n = 1.0 / tot_n;
        for &n in neg {
            if n > 0.0 {
                ret += n * inv_tot * (n * inv_n).ln();
            }
        }
    }
    ret
}

/// Negative weighted Gini impurity:
/// `−( tot_p/tot · (1 − Σ(p_i/tot_p)²) + tot_n/tot · (1 − Σ(n_i/tot_n)²) )`.
#[inline]
pub fn neg_gini(pos: &[f64], neg: &[f64]) -> f64 {
    let tot_p: f64 = pos.iter().sum();
    let tot_n: f64 = neg.iter().sum();
    let tot = tot_p + tot_n;
    if tot == 0.0 {
        return f64::NEG_INFINITY;
    }
    let mut impurity = 0.0;
    if tot_p > 0.0 {
        let s: f64 = pos.iter().map(|&p| (p / tot_p) * (p / tot_p)).sum();
        impurity += tot_p / tot * (1.0 - s);
    }
    if tot_n > 0.0 {
        let s: f64 = neg.iter().map(|&n| (n / tot_n) * (n / tot_n)).sum();
        impurity += tot_n / tot * (1.0 - s);
    }
    -impurity
}

/// Pearson χ² statistic over the 2×C table (sides × classes).
#[inline]
pub fn chi_square(pos: &[f64], neg: &[f64]) -> f64 {
    let tot_p: f64 = pos.iter().sum();
    let tot_n: f64 = neg.iter().sum();
    let tot = tot_p + tot_n;
    if tot == 0.0 || tot_p == 0.0 || tot_n == 0.0 {
        return 0.0; // no association measurable
    }
    let mut stat = 0.0;
    for (i, (&p, &n)) in pos.iter().zip(neg).enumerate() {
        let _ = i;
        let class_tot = p + n;
        if class_tot == 0.0 {
            continue;
        }
        let exp_p = tot_p * class_tot / tot;
        let exp_n = tot_n * class_tot / tot;
        stat += (p - exp_p) * (p - exp_p) / exp_p;
        stat += (n - exp_n) * (n - exp_n) / exp_n;
    }
    stat
}

/// Regression SSE criterion in prefix-sum form (higher is better):
/// `sum_p²/n_p + sum_n²/n_n`. Returns `-inf` if either side is empty
/// (no valid partition).
#[inline]
pub fn sse_score(n_pos: f64, sum_pos: f64, n_neg: f64, sum_neg: f64) -> f64 {
    if n_pos <= 0.0 || n_neg <= 0.0 {
        return f64::NEG_INFINITY;
    }
    sum_pos * sum_pos / n_pos + sum_neg * sum_neg / n_neg
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn info_gain_prefers_pure_split() {
        // Perfect separation of two classes...
        let pure = info_gain(&[10.0, 0.0], &[0.0, 10.0]);
        // ...beats a totally mixed one.
        let mixed = info_gain(&[5.0, 5.0], &[5.0, 5.0]);
        assert!(pure > mixed);
        assert!((pure - 0.0).abs() < 1e-12); // pure sides have zero cond. entropy
        assert!((mixed - (0.5f64.ln())).abs() < 1e-12);
    }

    #[test]
    fn paper_worked_example_le_2() {
        // Paper Tables 1/2/4: split `≤ 2` → pos = {4 examples of class b},
        // neg = {7 a, 4 b, 7 c}; score reported as −0.87.
        let score = info_gain(&[0.0, 4.0, 0.0], &[7.0, 4.0, 7.0]);
        assert!((score - (-0.87)).abs() < 0.005, "score={score}");
    }

    #[test]
    fn paper_worked_example_table4_rows() {
        // Rows of paper Table 4 that are arithmetically consistent with
        // Tables 1–2 (a few of the published cells appear to be typos;
        // see EXPERIMENTS.md §T1–T4 for the full re-derivation).
        // `≤ 1`: pos = 2 of class b; neg = a:7, b:6, c:7 → −0.99.
        let s = info_gain(&[0.0, 2.0, 0.0], &[7.0, 6.0, 7.0]);
        assert!((s - (-0.99)).abs() < 0.01, "{s}");
        // `= x` (categorical): pos = a:2; neg = a:5, b:8, c:7 → −0.98.
        let s = info_gain(&[2.0, 0.0, 0.0], &[5.0, 8.0, 7.0]);
        assert!((s - (-0.98)).abs() < 0.01, "{s}");
        // `> 1`: pos = a:4, b:3, c:5; neg = a:3, b:5, c:2 → −1.06.
        let s = info_gain(&[4.0, 3.0, 5.0], &[3.0, 5.0, 2.0]);
        assert!((s - (-1.06)).abs() < 0.01, "{s}");
        // `≤ 4`: pos = a:3, b:5, c:3; neg = a:4, b:3, c:4 → −1.08.
        let s = info_gain(&[3.0, 5.0, 3.0], &[4.0, 3.0, 4.0]);
        assert!((s - (-1.08)).abs() < 0.01, "{s}");
    }

    #[test]
    fn gini_prefers_pure_split() {
        let pure = neg_gini(&[10.0, 0.0], &[0.0, 10.0]);
        let mixed = neg_gini(&[5.0, 5.0], &[5.0, 5.0]);
        assert!(pure > mixed);
        assert_eq!(pure, 0.0);
        assert!((mixed - (-0.5)).abs() < 1e-12);
    }

    #[test]
    fn chi2_zero_when_independent() {
        // Same class mix on both sides → no association.
        let s = chi_square(&[6.0, 2.0], &[3.0, 1.0]);
        assert!(s.abs() < 1e-9, "{s}");
        // Perfect association is large.
        assert!(chi_square(&[8.0, 0.0], &[0.0, 8.0]) > 10.0);
    }

    #[test]
    fn criteria_handle_empty_sides() {
        for c in [
            ClassCriterion::InfoGain,
            ClassCriterion::Gini,
            ClassCriterion::ChiSquare,
        ] {
            let s = c.score(&[0.0, 0.0], &[3.0, 4.0]);
            assert!(s.is_finite() || s == f64::NEG_INFINITY);
        }
    }

    #[test]
    fn sse_score_prefix_form() {
        // Labels [1,1,5,5]: split in the middle is exact.
        let best = sse_score(2.0, 2.0, 2.0, 10.0);
        let worse = sse_score(1.0, 1.0, 3.0, 11.0);
        assert!(best > worse);
        assert_eq!(sse_score(0.0, 0.0, 4.0, 12.0), f64::NEG_INFINITY);
    }

    #[test]
    fn score_with_totals_bit_identical_to_score() {
        let cases: Vec<(Vec<f64>, Vec<f64>)> = vec![
            (vec![3.0, 0.0, 4.0], vec![4.0, 8.0, 3.0]),
            (vec![0.0, 2.0, 0.0], vec![7.0, 6.0, 7.0]),
            (vec![1.0, 1.0], vec![9.0, 0.0]),
            (vec![5.0], vec![5.0]),
        ];
        for crit in [
            ClassCriterion::InfoGain,
            ClassCriterion::Gini,
            ClassCriterion::ChiSquare,
        ] {
            for (pos, neg) in &cases {
                let a = crit.score(pos, neg);
                let tp: f64 = pos.iter().sum();
                let tn: f64 = neg.iter().sum();
                let b = crit.score_with_totals(pos.len(), tp, tn, |y| (pos[y], neg[y]));
                assert!(
                    a == b || (a.is_nan() && b.is_nan()),
                    "{crit:?} {pos:?}/{neg:?}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn parse_names_round_trip() {
        for c in [
            ClassCriterion::InfoGain,
            ClassCriterion::Gini,
            ClassCriterion::ChiSquare,
        ] {
            assert_eq!(ClassCriterion::parse(c.name()), Some(c));
        }
        assert_eq!(ClassCriterion::parse("nope"), None);
    }
}
