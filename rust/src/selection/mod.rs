//! Split selection — the paper's core contribution.
//!
//! * [`split`] — split predicates and the hybrid comparison semantics.
//! * [`heuristic`] — split criteria (information gain, Gini, χ², SSE).
//! * [`superfast`] — Superfast Selection: `O(M + N·C)` per feature via a
//!   single statistics pass + prefix sums (paper Algorithms 2 & 4).
//! * [`binned`] — histogram-binned selection: `O(B)` scans over
//!   pre-quantized bin lanes with parent-minus-sibling subtraction in
//!   the builder.
//! * [`generic`] — the `O(M·N)` baseline (paper Algorithm 1).
//! * [`xla_backend`] — alternate large-node backend that executes the
//!   AOT-compiled JAX/Pallas kernels through PJRT.

pub mod binned;
pub mod feature_rank;
pub mod generic;
pub mod heuristic;
pub mod split;
pub mod superfast;
pub mod xla_backend;

pub use heuristic::{ClassCriterion, Criterion};
pub use split::{SplitOp, SplitPredicate};
pub use superfast::{best_split_on_feat, FeatureView, LabelsView, ScoredSplit};
