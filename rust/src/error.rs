//! Typed errors for the public `udt` surface.
//!
//! Everything a user can get wrong — an invalid builder configuration, a
//! task mismatch (accuracy on a regression model), malformed CSV or model
//! JSON, a bad prediction request — surfaces as a [`UdtError`] variant
//! instead of a panic or an opaque string.

use crate::data::dataset::TaskKind;
use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, UdtError>;

/// The error type of the public `udt` API.
#[derive(Debug)]
pub enum UdtError {
    /// A builder or training configuration is invalid.
    InvalidConfig(String),
    /// The operation requires the other task kind (e.g. classification
    /// accuracy of a regression model).
    TaskMismatch { expected: TaskKind, got: TaskKind },
    /// Dataset construction or ingestion failed (CSV shape, mismatched
    /// column lengths, empty row sets, ...).
    Data(String),
    /// A serialized model document failed to parse or validate.
    Model(String),
    /// A prediction request is malformed (wrong arity, bad cell).
    Predict(String),
    /// Configuration file / `--set` override errors.
    Config(crate::config::ConfigError),
    /// Command-line usage errors.
    Usage(String),
    /// Accelerator runtime / artifact errors.
    Runtime(String),
    /// Underlying I/O failure.
    Io(std::io::Error),
}

impl UdtError {
    /// Shorthand for [`UdtError::InvalidConfig`].
    pub fn invalid_config(msg: impl Into<String>) -> Self {
        UdtError::InvalidConfig(msg.into())
    }

    /// Shorthand for [`UdtError::Data`].
    pub fn data(msg: impl Into<String>) -> Self {
        UdtError::Data(msg.into())
    }

    /// Shorthand for [`UdtError::Model`].
    pub fn model(msg: impl Into<String>) -> Self {
        UdtError::Model(msg.into())
    }

    /// Shorthand for [`UdtError::Predict`].
    pub fn predict(msg: impl Into<String>) -> Self {
        UdtError::Predict(msg.into())
    }

    /// Shorthand for [`UdtError::Usage`].
    pub fn usage(msg: impl Into<String>) -> Self {
        UdtError::Usage(msg.into())
    }

    /// Shorthand for [`UdtError::Runtime`].
    pub fn runtime(msg: impl Into<String>) -> Self {
        UdtError::Runtime(msg.into())
    }
}

fn task_name(t: TaskKind) -> &'static str {
    match t {
        TaskKind::Classification => "classification",
        TaskKind::Regression => "regression",
    }
}

impl fmt::Display for UdtError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            UdtError::InvalidConfig(m) => write!(f, "invalid config: {m}"),
            UdtError::TaskMismatch { expected, got } => write!(
                f,
                "task mismatch: expected {}, got {}",
                task_name(*expected),
                task_name(*got)
            ),
            UdtError::Data(m) => write!(f, "data error: {m}"),
            UdtError::Model(m) => write!(f, "model error: {m}"),
            UdtError::Predict(m) => write!(f, "predict error: {m}"),
            UdtError::Config(e) => write!(f, "{e}"),
            UdtError::Usage(m) => write!(f, "{m}"),
            UdtError::Runtime(m) => write!(f, "runtime error: {m}"),
            UdtError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for UdtError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            UdtError::Io(e) => Some(e),
            UdtError::Config(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for UdtError {
    fn from(e: std::io::Error) -> Self {
        UdtError::Io(e)
    }
}

impl From<crate::config::ConfigError> for UdtError {
    fn from(e: crate::config::ConfigError) -> Self {
        UdtError::Config(e)
    }
}

impl From<crate::util::json::JsonError> for UdtError {
    fn from(e: crate::util::json::JsonError) -> Self {
        UdtError::Model(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn displays_are_informative() {
        let e = UdtError::invalid_config("max_depth must be >= 1");
        assert!(e.to_string().contains("max_depth"));
        let e = UdtError::TaskMismatch {
            expected: TaskKind::Classification,
            got: TaskKind::Regression,
        };
        assert!(e.to_string().contains("classification"));
        assert!(e.to_string().contains("regression"));
    }

    #[test]
    fn io_errors_convert() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: UdtError = io.into();
        assert!(matches!(e, UdtError::Io(_)));
        assert!(std::error::Error::source(&e).is_some());
    }
}
