//! Deterministic PRNGs (SplitMix64 seeding + xoshiro256** core).
//!
//! The offline build image ships no `rand` crate, so we own a small,
//! well-known generator. xoshiro256** is the same algorithm the `rand`
//! ecosystem uses for `Xoshiro256StarStar`; SplitMix64 is the canonical
//! seeder recommended by its authors.

/// SplitMix64 — used to expand a single `u64` seed into xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — fast, high-quality 64-bit PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent stream for worker `i` (stable across runs).
    pub fn fork(&mut self, i: u64) -> Rng {
        Rng::new(self.next_u64() ^ i.wrapping_mul(0xA076_1D64_78BD_642F))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection method.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform `usize` in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f64` in `[lo, hi)`.
    #[inline]
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut target = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(4);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_is_half() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(6);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_respects_zero_weight() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let i = r.weighted(&[0.0, 1.0, 0.0]);
            assert_eq!(i, 1);
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(10);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
