//! Small self-contained substrates the offline build environment forces us
//! to own: PRNG, CLI parsing, JSON, property testing, timing, and
//! poison-recovering lock acquisition for the serving layer.

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod sync;
pub mod timer;
