//! Minimal JSON value model, parser and writer.
//!
//! Used to read `artifacts/manifest.json` (written by `python/compile/aot.py`),
//! to serialize trained trees, and to emit machine-readable bench reports.
//! No `serde`/`serde_json` is available in the offline build image, so this
//! is a small hand-rolled implementation covering the full JSON grammar.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` for deterministic output order.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if n.fract() == 0.0 && n.abs() < 1e15 {
                        out.push_str(&format!("{}", *n as i64));
                    } else {
                        out.push_str(&format!("{n}"));
                    }
                } else {
                    // JSON has no Inf/NaN; emit null like serde_json's default.
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect_lit(&mut self, lit: &str) -> Result<(), JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(())
        } else {
            Err(self.err(&format!("expected `{lit}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => {
                self.expect_lit("null")?;
                Ok(Json::Null)
            }
            Some(b't') => {
                self.expect_lit("true")?;
                Ok(Json::Bool(true))
            }
            Some(b'f') => {
                self.expect_lit("false")?;
                Ok(Json::Bool(false))
            }
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.bump(); // opening quote
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle surrogate pairs.
                        if (0xD800..0xDC00).contains(&cp) {
                            self.expect_lit("\\u")?;
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            s.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                        } else {
                            s.push(
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode the UTF-8 sequence starting at c.
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.bump();
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.bump();
        }
        if self.peek() == Some(b'.') {
            self.bump();
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.bump();
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.bump();
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.bump();
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.bump();
            }
        }
        // The scanned span is ASCII digits/sign/dot/exponent only, but
        // route the impossible error into the parse failure anyway —
        // cheaper than justifying an unwrap.
        std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number"))?
            .parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.bump(); // [
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.bump();
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.bump(); // {
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.bump();
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            if self.peek() != Some(b'"') {
                return Err(self.err("expected object key"));
            }
            let key = self.string()?;
            self.skip_ws();
            if self.bump() != Some(b':') {
                return Err(self.err("expected `:`"));
            }
            let value = self.value()?;
            map.insert(key, value);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    if first >= 0xF0 {
        4
    } else if first >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-2e3").unwrap(), Json::Num(-2000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64().unwrap(), 1.0);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn escapes_round_trip() {
        let s = "line\n\"quote\"\t\\slash\u{1F600}";
        let j = Json::Str(s.to_string());
        let text = j.to_string();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse(r#""A😀""#).unwrap(),
            Json::Str("A\u{1F600}".into())
        );
    }

    #[test]
    fn round_trip_pretty_and_compact() {
        let v = Json::obj(vec![
            ("nums", Json::Arr(vec![Json::Num(1.0), Json::Num(2.5)])),
            ("name", Json::Str("udt".into())),
            ("flag", Json::Bool(true)),
        ]);
        for text in [v.to_string(), v.to_pretty()] {
            assert_eq!(Json::parse(&text).unwrap(), v);
        }
    }

    #[test]
    fn integers_have_no_fraction() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.25).to_string(), "5.25");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"\\q\"").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(Json::Arr(vec![]).to_string(), "[]");
    }
}
