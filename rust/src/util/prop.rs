//! Miniature property-based testing harness (the offline image has no
//! `proptest`). A property is a closure over a seeded [`Rng`]; the runner
//! executes many cases and, on failure, retries the failing seed with
//! progressively smaller `size` hints to report a smaller counterexample.
//!
//! ```
//! use udt::util::prop::{check, Config};
//! check("reverse twice is identity", Config::default(), |rng, size| {
//!     let n = rng.range(0, size.max(1));
//!     let xs: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
//!     let mut ys = xs.clone();
//!     ys.reverse();
//!     ys.reverse();
//!     if ys == xs { Ok(()) } else { Err("mismatch".into()) }
//! });
//! ```

use super::rng::Rng;

/// Runner configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// Number of random cases to run.
    pub cases: usize,
    /// Base seed; each case uses `seed + case_index`.
    pub seed: u64,
    /// Maximum size hint passed to the property (grows over the run).
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            cases: 64,
            seed: 0xDEC1_51F0,
            max_size: 64,
        }
    }
}

impl Config {
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    pub fn max_size(mut self, s: usize) -> Self {
        self.max_size = s;
        self
    }
}

/// Run a property; panics with a reproducible report on failure.
///
/// The property receives a fresh deterministic [`Rng`] and a `size` hint
/// that ramps from 1 to `max_size` over the run, so earlier cases are
/// naturally smaller (cheap shrinking).
pub fn check<F>(name: &str, config: Config, mut property: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    for case in 0..config.cases {
        let size = ramp(case, config.cases, config.max_size);
        let seed = config.seed.wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        if let Err(msg) = property(&mut rng, size) {
            // Try to find a smaller failure with the same seed family.
            let mut smallest = (size, seed, msg);
            for shrink_size in (1..size).rev() {
                let mut r2 = Rng::new(seed);
                if let Err(m) = property(&mut r2, shrink_size) {
                    smallest = (shrink_size, seed, m);
                } else {
                    break;
                }
            }
            // ANALYZE-ALLOW(no-unwrap): the harness's job is to fail the calling test with a shrunken case
            panic!(
                "property `{name}` failed (case {case}/{}, size {}, seed {:#x}):\n  {}",
                config.cases, smallest.0, smallest.1, smallest.2
            );
        }
    }
}

fn ramp(case: usize, cases: usize, max_size: usize) -> usize {
    if cases <= 1 {
        return max_size;
    }
    1 + case * max_size.saturating_sub(1) / (cases - 1)
}

/// Convenience assertion helpers for property bodies.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Approximate float equality with context on failure.
pub fn ensure_close(a: f64, b: f64, tol: f64, ctx: &str) -> Result<(), String> {
    let scale = 1.0_f64.max(a.abs()).max(b.abs());
    if (a - b).abs() <= tol * scale || (a.is_nan() && b.is_nan()) {
        Ok(())
    } else {
        Err(format!("{ctx}: {a} vs {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add commutes", Config::default().cases(32), |rng, _| {
            let a = rng.next_u64() >> 1;
            let b = rng.next_u64() >> 1;
            ensure(a + b == b + a, "commute")
        });
    }

    #[test]
    #[should_panic(expected = "property `always fails`")]
    fn failing_property_panics_with_name() {
        check("always fails", Config::default().cases(4), |_, _| {
            Err("nope".into())
        });
    }

    #[test]
    fn size_ramps_up() {
        assert_eq!(ramp(0, 10, 100), 1);
        assert_eq!(ramp(9, 10, 100), 100);
        assert!(ramp(5, 10, 100) > 1);
    }

    #[test]
    fn ensure_close_scales() {
        assert!(ensure_close(1e9, 1e9 + 1.0, 1e-6, "big").is_ok());
        assert!(ensure_close(1.0, 1.1, 1e-6, "small").is_err());
        assert!(ensure_close(f64::NAN, f64::NAN, 0.0, "nan").is_ok());
    }
}
