//! Wall-clock timing helpers shared by the coordinator and the bench
//! framework.

use std::time::{Duration, Instant};

/// A simple stopwatch.
#[derive(Debug, Clone)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self {
            start: Instant::now(),
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn ms(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Time a closure, returning `(result, elapsed)`.
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t = Instant::now();
    let r = f();
    (r, t.elapsed())
}

/// Human-friendly duration formatting (µs / ms / s).
pub fn fmt_duration(d: Duration) -> String {
    let us = d.as_secs_f64() * 1e6;
    if us < 1e3 {
        format!("{us:.1}µs")
    } else if us < 1e6 {
        format!("{:.2}ms", us / 1e3)
    } else {
        format!("{:.3}s", us / 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timed_returns_value() {
        let (v, d) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(d.as_nanos() > 0 || d.as_nanos() == 0); // smoke
    }

    #[test]
    fn fmt_scales() {
        assert!(fmt_duration(Duration::from_micros(5)).ends_with("µs"));
        assert!(fmt_duration(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_duration(Duration::from_secs(5)).ends_with('s'));
    }
}
