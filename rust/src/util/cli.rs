//! Tiny CLI argument parser (the offline image has no `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, repeatable options
//! (`--set a=1 --set b=2`), positional args and subcommands. Each option
//! is declared up-front so `--help` output and unknown-flag errors are
//! automatic. Errors surface as [`UdtError::Usage`].

use crate::error::{Result, UdtError};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Declared option for help text and validation.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub value: bool, // takes a value?
    pub multi: bool, // may repeat?
    pub help: &'static str,
    pub default: Option<&'static str>,
}

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub multi: BTreeMap<String, Vec<String>>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// All values of a repeatable option, in order of appearance.
    pub fn get_all(&self, key: &str) -> &[String] {
        self.multi.get(key).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| UdtError::usage(format!("--{key} expects an integer, got `{v}`"))),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| UdtError::usage(format!("--{key} expects a number, got `{v}`"))),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| UdtError::usage(format!("--{key} expects an integer, got `{v}`"))),
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// A subcommand parser.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
    pub positional_help: &'static str,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self {
            name,
            about,
            opts: Vec::new(),
            positional_help: "",
        }
    }

    pub fn opt(
        mut self,
        name: &'static str,
        help: &'static str,
        default: Option<&'static str>,
    ) -> Self {
        self.opts.push(OptSpec {
            name,
            value: true,
            multi: false,
            help,
            default,
        });
        self
    }

    /// A value option that may repeat (e.g. `--set a=1 --set b=2`).
    pub fn opt_multi(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            value: true,
            multi: true,
            help,
            default: None,
        });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            value: false,
            multi: false,
            help,
            default: None,
        });
        self
    }

    pub fn positional(mut self, help: &'static str) -> Self {
        self.positional_help = help;
        self
    }

    /// Parse raw args (after the subcommand name).
    pub fn parse(&self, raw: &[String]) -> Result<Args> {
        let mut args = Args::default();
        // Seed defaults.
        for spec in &self.opts {
            if let Some(d) = spec.default {
                args.options.insert(spec.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < raw.len() {
            let tok = &raw[i];
            if let Some(rest) = tok.strip_prefix("--") {
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let spec = self.opts.iter().find(|s| s.name == key).ok_or_else(|| {
                    UdtError::usage(format!("unknown option --{key}\n\n{}", self.help()))
                })?;
                if spec.value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            raw.get(i)
                                .cloned()
                                .ok_or_else(|| UdtError::usage(format!("--{key} expects a value")))?
                        }
                    };
                    if spec.multi {
                        args.multi.entry(key).or_default().push(val);
                    } else {
                        args.options.insert(key, val);
                    }
                } else {
                    if inline_val.is_some() {
                        return Err(UdtError::usage(format!("--{key} does not take a value")));
                    }
                    args.flags.push(key);
                }
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    /// Render help text.
    pub fn help(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}", self.name, self.about);
        if !self.positional_help.is_empty() {
            let _ = writeln!(s, "  args: {}", self.positional_help);
        }
        for o in &self.opts {
            let kind = if o.value { " <value>" } else { "" };
            let rep = if o.multi { " (repeatable)" } else { "" };
            let def = o
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            let _ = writeln!(s, "  --{}{kind}\t{}{rep}{def}", o.name, o.help);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("train", "train a tree")
            .opt("dataset", "dataset name", Some("adult"))
            .opt("depth", "max depth", None)
            .opt_multi("set", "config override key=value")
            .flag("verbose", "chatty output")
            .positional("input files")
    }

    fn raw(toks: &[&str]) -> Vec<String> {
        toks.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = cmd().parse(&raw(&[])).unwrap();
        assert_eq!(a.get("dataset"), Some("adult"));
        assert_eq!(a.get("depth"), None);
    }

    #[test]
    fn key_value_both_styles() {
        let a = cmd().parse(&raw(&["--depth", "5", "--dataset=kdd"])).unwrap();
        assert_eq!(a.get_usize("depth", 0).unwrap(), 5);
        assert_eq!(a.get("dataset"), Some("kdd"));
    }

    #[test]
    fn flags_and_positionals() {
        let a = cmd().parse(&raw(&["file.csv", "--verbose", "x.csv"])).unwrap();
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["file.csv", "x.csv"]);
    }

    #[test]
    fn repeatable_options_accumulate() {
        let a = cmd()
            .parse(&raw(&["--set", "a=1", "--set=b=2", "--set", "c=3"]))
            .unwrap();
        assert_eq!(a.get_all("set"), &["a=1", "b=2", "c=3"]);
        assert!(a.get_all("missing").is_empty());
    }

    #[test]
    fn unknown_flag_errors() {
        assert!(cmd().parse(&raw(&["--nope"])).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(cmd().parse(&raw(&["--depth"])).is_err());
    }

    #[test]
    fn bad_number_errors() {
        let a = cmd().parse(&raw(&["--depth", "abc"])).unwrap();
        assert!(a.get_usize("depth", 0).is_err());
    }

    #[test]
    fn help_mentions_options() {
        let h = cmd().help();
        assert!(h.contains("--dataset"));
        assert!(h.contains("--verbose"));
        assert!(h.contains("repeatable"));
    }
}
