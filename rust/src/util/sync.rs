//! Poison-recovering lock acquisition.
//!
//! `Mutex`/`RwLock` poisoning exists to warn that a panic happened
//! mid-critical-section. In this crate's server paths the guarded
//! sections are pure bookkeeping (map inserts, config swaps, counter
//! bumps) that cannot leave the protected data half-updated in a way a
//! later reader would misread — but a propagated `PoisonError` *would*
//! take down every other serving thread that touches the same lock.
//! So the serving layer recovers deliberately: take the guard out of
//! the error and keep serving.
//!
//! These helpers exist so that policy is written (and justified) in
//! exactly one place instead of as scattered `.unwrap()` calls — which
//! the `udt-analyze` `no-unwrap` rule now rejects. Code whose locks
//! provably *cannot* be poisoned (the worker pool never holds its lock
//! while user code runs) instead documents that invariant with an
//! `ANALYZE-ALLOW` waiver at each site.

use std::sync::{Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// Lock a mutex, recovering the guard if a previous holder panicked.
pub fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Read-lock an `RwLock`, recovering the guard from poisoning.
pub fn read<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Write-lock an `RwLock`, recovering the guard from poisoning.
pub fn write<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(|poisoned| poisoned.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::{Mutex, RwLock};

    #[test]
    fn mutex_guard_survives_a_poisoning_panic() {
        let m = Mutex::new(7u32);
        let _ = catch_unwind(AssertUnwindSafe(|| {
            let _g = m.lock().unwrap();
            panic!("poison it");
        }));
        assert!(m.is_poisoned());
        assert_eq!(*lock(&m), 7);
        *lock(&m) = 8;
        assert_eq!(*lock(&m), 8);
    }

    #[test]
    fn rwlock_guards_survive_a_poisoning_panic() {
        let l = RwLock::new(vec![1, 2, 3]);
        let _ = catch_unwind(AssertUnwindSafe(|| {
            let _g = l.write().unwrap();
            panic!("poison it");
        }));
        assert_eq!(read(&l).len(), 3);
        write(&l).push(4);
        assert_eq!(read(&l).len(), 4);
    }
}
