//! Bagged ensembles of UDT trees (the paper's intro motivates ensemble
//! methods as a standard decision-tree optimization; this extension shows
//! Superfast Selection slotting into one unchanged).
//!
//! Subagging (subsample aggregation) + per-tree feature masking
//! (random-forest style): each tree trains on a random subsample drawn
//! *without replacement* — the UDT builder's maintained sorted lists
//! assume unique rows, and subagging is statistically equivalent to
//! bootstrap bagging at half the sample rate. At prediction time the
//! ensemble majority-votes (classification) or averages (regression).
//! Feature bagging hands the builder an active-feature mask — masked
//! features simply produce no split candidates — so all trees share one
//! dataset (and its sort-index cache) with no per-tree copies.

use super::{require_task, NodeLabel, TrainConfig, Tree};
use crate::coordinator::parallel::parallel_map_chunked;
use crate::data::dataset::{Dataset, TaskKind};
use crate::data::value::Value;
use crate::error::{Result, UdtError};
use crate::util::rng::Rng;

/// Forest configuration. Build one through [`Forest::builder`] to get
/// validation, or fill the fields directly.
#[derive(Debug, Clone)]
pub struct ForestConfig {
    pub n_trees: usize,
    /// Fraction of features each tree sees (1.0 = all).
    pub feature_frac: f64,
    /// Subsample size (without replacement) as a fraction of the
    /// training set.
    pub sample_frac: f64,
    pub tree: TrainConfig,
    pub seed: u64,
}

impl Default for ForestConfig {
    fn default() -> Self {
        Self {
            n_trees: 10,
            feature_frac: 0.7,
            sample_frac: 0.7,
            tree: TrainConfig::default(),
            seed: 0xF0_4E57,
        }
    }
}

impl ForestConfig {
    /// Validate the ensemble knobs ([`UdtError::InvalidConfig`] on bad ones).
    pub fn validate(&self) -> Result<()> {
        if self.n_trees == 0 {
            return Err(UdtError::invalid_config("n_trees must be >= 1"));
        }
        if !(self.feature_frac > 0.0 && self.feature_frac <= 1.0) {
            return Err(UdtError::invalid_config(format!(
                "feature_frac must be in (0, 1], got {}",
                self.feature_frac
            )));
        }
        if !(self.sample_frac > 0.0 && self.sample_frac <= 1.0) {
            return Err(UdtError::invalid_config(format!(
                "sample_frac must be in (0, 1], got {}",
                self.sample_frac
            )));
        }
        if self.tree.max_depth < 1 {
            return Err(UdtError::invalid_config("max_depth must be >= 1"));
        }
        Ok(())
    }
}

/// Majority-vote winner: most votes, ties broken toward the smaller
/// class id. The single tie-break shared by the boxed ensemble and the
/// compiled serving path ([`crate::inference::CompiledModel`]), which
/// must stay prediction-for-prediction identical.
pub(crate) fn vote_argmax(votes: &[u32]) -> usize {
    votes
        .iter()
        .enumerate()
        .max_by_key(|&(c, &v)| (v, std::cmp::Reverse(c)))
        .map(|(c, _)| c)
        .unwrap_or(0)
}

/// A trained ensemble. Each member remembers which features it saw.
#[derive(Debug, Clone)]
pub struct Forest {
    pub trees: Vec<Tree>,
    pub task: TaskKind,
    pub n_classes: usize,
}

impl Forest {
    /// Train `n_trees` bagged trees. Every bag trains against the same
    /// dataset (and therefore the same [`crate::data::SortedIndex`]
    /// cache — each column is sorted exactly once for the whole
    /// ensemble); feature bagging passes an active-feature mask to the
    /// builder instead of materializing a blanked dataset copy per tree.
    pub fn fit(ds: &Dataset, config: &ForestConfig) -> Result<Forest> {
        config.validate()?;
        let n = ds.n_rows();
        if n == 0 {
            return Err(UdtError::data("cannot fit a forest on an empty dataset"));
        }
        let mut rng = Rng::new(config.seed);
        // Round (not truncate) the subsample size so e.g. 0.7 × 99 draws
        // 69 rows, not 68.
        let sample_n = ((n as f64 * config.sample_frac).round() as usize).clamp(1, n);
        let keep_features = ((ds.n_features() as f64 * config.feature_frac).ceil() as usize)
            .clamp(1, ds.n_features());

        let mut trees = Vec::with_capacity(config.n_trees);
        let mut all_rows: Vec<u32> = (0..n as u32).collect();
        for t in 0..config.n_trees {
            let mut tree_rng = rng.fork(t as u64);
            // Subsample rows without replacement (partial Fisher–Yates).
            tree_rng.shuffle(&mut all_rows);
            let rows: Vec<u32> = all_rows[..sample_n].to_vec();
            // Feature bag: keep a random subset of columns active.
            let mut feats: Vec<usize> = (0..ds.n_features()).collect();
            tree_rng.shuffle(&mut feats);
            let tree = if keep_features == ds.n_features() {
                Tree::fit_rows(ds, &rows, &config.tree)?
            } else {
                let mut active = vec![false; ds.n_features()];
                for &f in &feats[..keep_features] {
                    active[f] = true;
                }
                Tree::fit_rows_masked(ds, &rows, &config.tree, Some(&active))?
            };
            trees.push(tree);
        }
        Ok(Forest {
            trees,
            task: ds.task(),
            n_classes: ds.labels.n_classes(),
        })
    }

    /// Number of features the member trees expect.
    pub fn n_features(&self) -> usize {
        self.trees.first().map(|t| t.n_features).unwrap_or(0)
    }

    /// Total node count across the ensemble.
    pub fn n_nodes(&self) -> usize {
        self.trees.iter().map(Tree::n_nodes).sum()
    }

    /// Aggregate the member predictions: majority vote (classification,
    /// ties broken toward the smaller class id) or mean (regression).
    fn aggregate(&self, per_tree: impl Iterator<Item = NodeLabel>) -> NodeLabel {
        match self.task {
            TaskKind::Classification => {
                let mut votes = vec![0u32; self.n_classes.max(1)];
                for label in per_tree {
                    if let Some(c) = label.as_class() {
                        if let Some(v) = votes.get_mut(c as usize) {
                            *v += 1;
                        }
                    }
                }
                NodeLabel::Class(vote_argmax(&votes) as u16)
            }
            TaskKind::Regression => {
                let mut sum = 0.0f64;
                let mut n = 0usize;
                for label in per_tree {
                    sum += label.as_value().unwrap_or(f64::NAN);
                    n += 1;
                }
                NodeLabel::Value(sum / n.max(1) as f64)
            }
        }
    }

    /// Majority-vote / averaged prediction for row `r` of `ds`.
    pub fn predict_ds(&self, ds: &Dataset, r: usize) -> NodeLabel {
        self.aggregate(
            self.trees
                .iter()
                .map(|t| super::predict::predict_ds(t, ds, r, usize::MAX, 0)),
        )
    }

    /// Ensemble prediction for one materialized row of values.
    pub fn predict_values(&self, row: &[Value]) -> NodeLabel {
        self.aggregate(
            self.trees
                .iter()
                .map(|t| super::predict::predict_row(t, row, usize::MAX, 0)),
        )
    }

    /// Ensemble predictions for a batch of rows, chunk-parallel over the
    /// worker pool (training parallelizes; serving should too). Rows are
    /// split into fixed blocks and each block predicts independently, so
    /// the output is identical for any thread count (0 = all cores,
    /// 1 = sequential) — member trees still aggregate per row in tree
    /// order. Arity is the caller's contract (the [`crate::Estimator`]
    /// impl checks it).
    pub fn predict_batch_rows(&self, rows: &[Vec<Value>], n_threads: usize) -> Vec<NodeLabel> {
        // Smaller blocks than the compiled path's 512: boxed rows are
        // fat (`Vec<Value>` each) and ensemble walks cost more per row,
        // so finer blocks load-balance better.
        const CHUNK: usize = 256;
        let out = parallel_map_chunked(rows.len(), CHUNK, n_threads, |start, end| {
            rows[start..end]
                .iter()
                .map(|r| self.predict_values(r))
                .collect::<Vec<_>>()
        });
        out.into_iter().flatten().collect()
    }

    /// Ensemble accuracy over rows.
    pub fn accuracy_rows(&self, ds: &Dataset, rows: &[u32]) -> Result<f64> {
        require_task(TaskKind::Classification, self.task)?;
        require_task(TaskKind::Classification, ds.task())?;
        let correct = rows
            .iter()
            .filter(|&&r| {
                self.predict_ds(ds, r as usize).as_class() == Some(ds.labels.class(r as usize))
            })
            .count();
        Ok(correct as f64 / rows.len().max(1) as f64)
    }

    /// Ensemble RMSE over rows (regression).
    pub fn rmse_rows(&self, ds: &Dataset, rows: &[u32]) -> Result<f64> {
        require_task(TaskKind::Regression, self.task)?;
        require_task(TaskKind::Regression, ds.task())?;
        let (_, rmse) = super::mae_rmse(rows.iter().map(|&r| {
            (
                self.predict_ds(ds, r as usize).as_value().unwrap_or(f64::NAN),
                ds.labels.target(r as usize),
            )
        }));
        Ok(rmse)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate_any, SynthSpec};

    #[test]
    fn forest_beats_or_matches_single_tree_on_noisy_holdout() {
        let mut spec = SynthSpec::classification("ft", 3000, 8, 2);
        spec.noise = 0.25;
        let ds = generate_any(&spec, 71);
        let (train, _, test) = ds.split_indices(0.8, 0.1, 9);

        let single = Tree::fit_rows(&ds, &train, &TrainConfig::default()).unwrap();
        let single_acc = single.accuracy_rows(&ds, &test).unwrap();

        let forest = Forest::fit(
            &ds.subset(&train),
            &ForestConfig {
                n_trees: 15,
                ..Default::default()
            },
        )
        .unwrap();
        let test_ds = ds.subset(&test);
        let all: Vec<u32> = (0..test_ds.n_rows() as u32).collect();
        let forest_acc = forest.accuracy_rows(&test_ds, &all).unwrap();
        assert!(
            forest_acc >= single_acc - 0.03,
            "forest {forest_acc} vs single {single_acc}"
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let spec = SynthSpec::classification("fd", 500, 5, 2);
        let ds = generate_any(&spec, 73);
        let cfg = ForestConfig {
            n_trees: 4,
            ..Default::default()
        };
        let a = Forest::fit(&ds, &cfg).unwrap();
        let b = Forest::fit(&ds, &cfg).unwrap();
        for (ta, tb) in a.trees.iter().zip(&b.trees) {
            assert_eq!(ta.n_nodes(), tb.n_nodes());
        }
    }

    #[test]
    fn regression_forest_averages() {
        let spec = SynthSpec::regression("fr", 800, 5);
        let ds = generate_any(&spec, 77);
        let forest = Forest::fit(
            &ds,
            &ForestConfig {
                n_trees: 8,
                ..Default::default()
            },
        )
        .unwrap();
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let rmse = forest.rmse_rows(&ds, &rows).unwrap();
        assert!(rmse.is_finite() && rmse < 50.0, "rmse {rmse}");
    }

    #[test]
    fn feature_masking_trains_on_subset() {
        let spec = SynthSpec::classification("fm", 400, 10, 2);
        let ds = generate_any(&spec, 79);
        let forest = Forest::fit(
            &ds,
            &ForestConfig {
                n_trees: 3,
                feature_frac: 0.3,
                ..Default::default()
            },
        )
        .unwrap();
        // Every tree's splits must use ≤ 3 distinct features.
        for tree in &forest.trees {
            let used: std::collections::HashSet<usize> = tree
                .nodes
                .iter()
                .filter_map(|n| n.split.as_ref().map(|s| s.feature))
                .collect();
            assert!(used.len() <= 3, "{used:?}");
        }
    }

    #[test]
    fn invalid_configs_are_rejected() {
        let spec = SynthSpec::classification("fv", 100, 4, 2);
        let ds = generate_any(&spec, 81);
        for cfg in [
            ForestConfig {
                n_trees: 0,
                ..Default::default()
            },
            ForestConfig {
                feature_frac: 0.0,
                ..Default::default()
            },
            ForestConfig {
                sample_frac: 1.5,
                ..Default::default()
            },
        ] {
            assert!(matches!(
                Forest::fit(&ds, &cfg),
                Err(UdtError::InvalidConfig(_))
            ));
        }
    }

    #[test]
    fn row_and_ds_predictions_agree() {
        let mut spec = SynthSpec::classification("fp", 600, 5, 3);
        spec.cat_frac = 0.3;
        let ds = generate_any(&spec, 83);
        let forest = Forest::fit(
            &ds,
            &ForestConfig {
                n_trees: 5,
                ..Default::default()
            },
        )
        .unwrap();
        for r in (0..ds.n_rows()).step_by(37) {
            let row = ds.row(r);
            assert_eq!(forest.predict_values(&row), forest.predict_ds(&ds, r));
        }
    }

    #[test]
    fn ensemble_sorts_each_column_exactly_once() {
        let spec = SynthSpec::classification("fo", 600, 6, 2);
        let ds = generate_any(&spec, 85);
        assert_eq!(ds.sort_index_builds(), 0);
        let forest = Forest::fit(
            &ds,
            &ForestConfig {
                n_trees: 6,
                feature_frac: 0.5,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(forest.trees.len(), 6);
        // One SortedIndex build for the whole ensemble — every bag
        // filtered the shared cache instead of re-sorting.
        assert_eq!(ds.sort_index_builds(), 1);
    }

    #[test]
    fn batch_prediction_is_thread_count_invariant() {
        let mut spec = SynthSpec::classification("fb", 900, 6, 3);
        spec.cat_frac = 0.3;
        spec.missing_frac = 0.05;
        let ds = generate_any(&spec, 89);
        let forest = Forest::fit(
            &ds,
            &ForestConfig {
                n_trees: 6,
                ..Default::default()
            },
        )
        .unwrap();
        let rows: Vec<Vec<Value>> = (0..ds.n_rows()).map(|r| ds.row(r)).collect();
        let seq = forest.predict_batch_rows(&rows, 1);
        let par = forest.predict_batch_rows(&rows, 8);
        assert_eq!(seq, par);
        // And both agree with the row-at-a-time path.
        for (r, label) in seq.iter().enumerate() {
            assert_eq!(*label, forest.predict_values(&rows[r]), "row {r}");
        }
    }

    #[test]
    fn subsample_size_rounds() {
        // 0.5 × 101 → 51 rows (round-half-up), not 50 (truncation).
        let spec = SynthSpec::classification("fs", 101, 3, 2);
        let ds = generate_any(&spec, 87);
        let forest = Forest::fit(
            &ds,
            &ForestConfig {
                n_trees: 1,
                sample_frac: 0.5,
                feature_frac: 1.0,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(forest.trees[0].nodes[0].n_samples, 51);
    }
}
