//! Numeric label selection for regression (paper Algorithm 6).
//!
//! Given the node's labels pre-sorted ascending, one prefix-sum pass
//! scores every label threshold with the SSE criterion (Eq. 3 with the
//! constant `Σy²` dropped) and returns the best threshold. UDT uses it to
//! binarize a regression node's targets into two pseudo-classes, after
//! which feature selection proceeds as 2-class classification — so `C`
//! stays 2 and the overall complexity is unchanged.

use crate::selection::heuristic::sse_score;

/// Best label threshold for `sorted_rows` (row ids sorted ascending by
/// target). Returns `(threshold, score)`; `None` if all labels are equal
/// (no binary partition exists).
pub fn best_label_split(sorted_rows: &[u32], targets: &[f64]) -> Option<(f64, f64)> {
    let n = sorted_rows.len();
    if n < 2 {
        return None;
    }
    let tot: f64 = sorted_rows.iter().map(|&r| targets[r as usize]).sum();
    let n_f = n as f64;

    let mut best: Option<(f64, f64)> = None;
    let mut cum_n = 0.0f64;
    let mut cum_sum = 0.0f64;
    let mut i = 0;
    while i < n {
        let y = targets[sorted_rows[i] as usize];
        // Absorb the run of equal labels.
        while i < n && targets[sorted_rows[i] as usize] == y {
            cum_n += 1.0;
            cum_sum += y;
            i += 1;
        }
        if i == n {
            break; // `≤ max` leaves the negative side empty
        }
        let score = sse_score(cum_n, cum_sum, n_f - cum_n, tot - cum_sum);
        if best.map_or(true, |(_, b)| score > b) {
            best = Some((y, score));
        }
    }
    best
}

/// Binarize node labels at `threshold` into pseudo-classes
/// (0: `y ≤ t`, 1: `y > t`), writing into `pseudo` (indexed by row id).
pub fn binarize(rows: &[u32], targets: &[f64], threshold: f64, pseudo: &mut [u16]) {
    for &r in rows {
        pseudo[r as usize] = (targets[r as usize] > threshold) as u16;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sorted_by_target(targets: &[f64]) -> Vec<u32> {
        let mut idx: Vec<u32> = (0..targets.len() as u32).collect();
        idx.sort_by(|&a, &b| {
            targets[a as usize]
                .partial_cmp(&targets[b as usize])
                .unwrap()
        });
        idx
    }

    #[test]
    fn bimodal_labels_split_at_gap() {
        let targets = [1.0, 1.1, 0.9, 10.0, 10.1, 9.9];
        let sorted = sorted_by_target(&targets);
        let (t, _) = best_label_split(&sorted, &targets).unwrap();
        assert!((0.9..10.0).contains(&t), "threshold {t}");
        // The best boundary is after the low cluster.
        assert_eq!(t, 1.1);
    }

    #[test]
    fn constant_labels_no_split() {
        let targets = [5.0; 8];
        let sorted = sorted_by_target(&targets);
        assert!(best_label_split(&sorted, &targets).is_none());
    }

    #[test]
    fn single_row_no_split() {
        assert!(best_label_split(&[0], &[1.0]).is_none());
    }

    #[test]
    fn matches_exhaustive_minimizer() {
        // Compare against brute-force SSE minimization over thresholds.
        let targets = [3.0, 1.0, 4.0, 1.5, 9.0, 2.6, 5.0, 3.5];
        let sorted = sorted_by_target(&targets);
        let (t_fast, s_fast) = best_label_split(&sorted, &targets).unwrap();

        let mut uniq: Vec<f64> = targets.to_vec();
        uniq.sort_by(|a, b| a.partial_cmp(b).unwrap());
        uniq.dedup();
        let mut best: Option<(f64, f64)> = None;
        for &t in &uniq[..uniq.len() - 1] {
            let (lo, hi): (Vec<f64>, Vec<f64>) = targets.iter().partition(|&&y| y <= t);
            let s = sse_score(
                lo.len() as f64,
                lo.iter().sum(),
                hi.len() as f64,
                hi.iter().sum(),
            );
            if best.map_or(true, |(_, b)| s > b) {
                best = Some((t, s));
            }
        }
        let (t_slow, s_slow) = best.unwrap();
        assert_eq!(t_fast, t_slow);
        assert!((s_fast - s_slow).abs() < 1e-9);
    }

    #[test]
    fn binarize_marks_sides() {
        let targets = [1.0, 5.0, 3.0];
        let mut pseudo = vec![0u16; 3];
        binarize(&[0, 1, 2], &targets, 3.0, &mut pseudo);
        assert_eq!(pseudo, vec![0, 1, 0]);
    }
}
