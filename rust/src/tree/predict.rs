//! Prediction over the boxed training arena (paper Algorithm 7).
//!
//! Every node carries a label, so prediction can stop early at any inner
//! node — the mechanism behind Training-Only-Once Tuning: `max_depth`
//! bounds the walk, and a node with fewer than `min_split` training
//! samples answers as if it were a leaf.
//!
//! This is the *oracle* path: flexible, allocation-per-row, used during
//! training, tuning and evaluation. Serving volume goes through
//! [`crate::inference::CompiledModel`], which flattens these nodes into
//! struct-of-arrays tables (with the caps below baked in structurally)
//! and is property-tested prediction-for-prediction identical to this
//! walk (`tests/prop_inference.rs`).

use super::{NodeLabel, Tree};
use crate::data::dataset::Dataset;
use crate::data::value::Value;

/// Predict for a materialized row of values.
#[inline]
pub fn predict_row(tree: &Tree, row: &[Value], max_depth: usize, min_split: usize) -> NodeLabel {
    let mut node = &tree.nodes[Tree::ROOT as usize];
    let mut depth = 1usize;
    loop {
        if node.is_leaf() || (node.n_samples as usize) < min_split || depth >= max_depth {
            return node.label;
        }
        // ANALYZE-ALLOW(no-unwrap): non-leaf nodes always carry a split
        let split = node.split.as_ref().unwrap();
        // ANALYZE-ALLOW(no-unwrap): non-leaf nodes always carry children
        let (pos, neg) = node.children.unwrap();
        let next = if split.eval_row(row) { pos } else { neg };
        node = &tree.nodes[next as usize];
        depth += 1;
    }
}

/// Predict for row `r` of a dataset without materializing the row.
#[inline]
pub fn predict_ds(
    tree: &Tree,
    ds: &Dataset,
    r: usize,
    max_depth: usize,
    min_split: usize,
) -> NodeLabel {
    let mut node = &tree.nodes[Tree::ROOT as usize];
    let mut depth = 1usize;
    loop {
        if node.is_leaf() || (node.n_samples as usize) < min_split || depth >= max_depth {
            return node.label;
        }
        // ANALYZE-ALLOW(no-unwrap): non-leaf nodes always carry a split
        let split = node.split.as_ref().unwrap();
        // ANALYZE-ALLOW(no-unwrap): non-leaf nodes always carry children
        let (pos, neg) = node.children.unwrap();
        let next = if split.eval_value(ds.value(split.feature, r)) {
            pos
        } else {
            neg
        };
        node = &tree.nodes[next as usize];
        depth += 1;
    }
}

/// The full root-to-leaf path of row `r` (node arena ids). Used by the
/// tuner to evaluate *all* hyper-parameter settings from one walk.
pub fn path_ds(tree: &Tree, ds: &Dataset, r: usize) -> Vec<u32> {
    let mut path = vec![Tree::ROOT];
    let mut node = &tree.nodes[Tree::ROOT as usize];
    while let (Some(split), Some((pos, neg))) = (&node.split, node.children) {
        let next = if split.eval_value(ds.value(split.feature, r)) {
            pos
        } else {
            neg
        };
        path.push(next);
        node = &tree.nodes[next as usize];
    }
    path
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::column::Column;
    use crate::data::dataset::{Dataset, Labels};
    use crate::data::interner::Interner;
    use crate::tree::TrainConfig;

    fn step_dataset() -> Dataset {
        // f0 < 5 → class 0, else class 1; plus a refinement at f0 < 2.
        let vals: Vec<Value> = (0..10).map(|i| Value::Num(i as f64)).collect();
        let ids: Vec<u16> = (0..10).map(|i| (i >= 5) as u16).collect();
        Dataset::new(
            "step",
            vec![Column::new("f0", vals)],
            Labels::Class { ids, n_classes: 2 },
            Interner::new(),
        )
        .unwrap()
    }

    #[test]
    fn full_depth_prediction_reaches_leaves() {
        let ds = step_dataset();
        let tree = Tree::fit(&ds, &TrainConfig::default()).unwrap();
        for r in 0..10 {
            let p = predict_ds(&tree, &ds, r, usize::MAX, 0);
            assert_eq!(p.as_class(), Some(ds.labels.class(r)));
        }
    }

    #[test]
    fn depth_1_returns_root_label() {
        let ds = step_dataset();
        let tree = Tree::fit(&ds, &TrainConfig::default()).unwrap();
        let root_label = tree.nodes[0].label;
        for r in 0..10 {
            assert_eq!(predict_ds(&tree, &ds, r, 1, 0), root_label);
        }
    }

    #[test]
    fn min_split_stops_at_small_nodes() {
        let ds = step_dataset();
        let tree = Tree::fit(&ds, &TrainConfig::default()).unwrap();
        // With min_split larger than the whole training set, even the root
        // acts as a leaf.
        for r in 0..10 {
            assert_eq!(predict_ds(&tree, &ds, r, usize::MAX, 11), tree.nodes[0].label);
        }
    }

    #[test]
    fn path_starts_at_root_ends_at_leaf() {
        let ds = step_dataset();
        let tree = Tree::fit(&ds, &TrainConfig::default()).unwrap();
        for r in 0..10 {
            let path = path_ds(&tree, &ds, r);
            assert_eq!(path[0], Tree::ROOT);
            assert!(tree.nodes[*path.last().unwrap() as usize].is_leaf());
            // Consecutive entries are parent→child.
            for w in path.windows(2) {
                let (pos, neg) = tree.nodes[w[0] as usize].children.unwrap();
                assert!(w[1] == pos || w[1] == neg);
            }
        }
    }

    #[test]
    fn predict_row_matches_predict_ds() {
        let ds = step_dataset();
        let tree = Tree::fit(&ds, &TrainConfig::default()).unwrap();
        for r in 0..10 {
            let row = ds.row(r);
            assert_eq!(
                predict_row(&tree, &row, usize::MAX, 0),
                predict_ds(&tree, &ds, r, usize::MAX, 0)
            );
        }
    }

    #[test]
    fn missing_value_routes_negative() {
        let ds = step_dataset();
        let tree = Tree::fit(&ds, &TrainConfig::default()).unwrap();
        // A missing value fails every predicate → always negative branch.
        let p = predict_row(&tree, &[Value::Missing], usize::MAX, 0);
        // Root split is f0 ≤ 4 (pos side = class 0); negative side → 1.
        assert_eq!(p.as_class(), Some(1));
    }
}
