//! Double-buffered arena frontier for the UDT builder.
//!
//! The builder used to thread eight owned `Vec` families through its
//! work queue and clone-filter all of them into fresh allocations at
//! every split, so allocator churn — not split selection — dominated
//! deep trees, and peak memory was `O(K·M·live-nodes)`. This module
//! replaces that with **flat per-feature arenas partitioned in place**:
//!
//! * each feature's sorted numeric `(rows, values, labels)` and grouped
//!   categorical `(rows, ids, labels)` lists for an entire tree level
//!   live in one contiguous arena, double-buffered (front/back);
//! * the node row lists (and the regression by-target order) live in a
//!   row arena with the same discipline;
//! * a node is just an `(offset, len)` range into every arena — no node
//!   owns any list;
//! * `split_node` partitions each range from the front buffer into the
//!   back buffer with a **stable two-pointer pass** (positives first,
//!   negatives after, both in original order), then the buffers flip.
//!
//! ## Invariants
//!
//! 1. **Stability.** The partition writes positives to
//!    `back[off..off+n_pos]` and negatives to `back[off+n_pos..off+len]`
//!    preserving the front buffer's relative order on both sides. Since
//!    the root lists are sorted (numeric ascending by `(value, row)`,
//!    categorical grouped by id, regression rows by target), every
//!    node's range **stays sorted for free** down the whole tree — the
//!    paper's "maintained sortedness" with zero per-node allocation.
//! 2. **Range disjointness / tiling.** The two children of a split node
//!    exactly tile the parent's range in every arena: the positive child
//!    gets `[off, off+n_pos)`, the negative child `[off+n_pos, off+len)`.
//!    Ranges of distinct nodes are therefore disjoint at every level,
//!    which is what lets the partition phase run workers over disjoint
//!    `&mut` arena regions with no locking (parallelism is per feature:
//!    each worker owns one feature's arrays outright).
//! 3. **Leaves leave garbage.** Ranges of nodes that became leaves are
//!    simply not copied to the back buffer; their back-buffer bytes are
//!    stale and must never be read. No live node references them, so the
//!    only rule is: a range is valid only in the *current* front buffer.
//! 4. **Fixed footprint.** Both buffers are allocated once from the root
//!    lists and never grow: peak arena memory is exactly
//!    `2 × O(Σ_f |sorted lists_f|)` (≈ `2×O(K·M)`), and after the root
//!    build the builder performs **zero** heap allocations for
//!    row/value/label lists ([`Frontier::arena_bytes`] is the
//!    enforcement hook — see `rust/tests/prop_builder.rs`).
//!
//! The level-wide positive-row bitmask is the only shared partition
//! state; it is filled once per level (node row sets are disjoint) and
//! read concurrently by the per-feature partition workers.

use crate::coordinator::parallel::parallel_map;
use crate::data::column_data::{present, ColumnData};
use crate::data::dataset::{Dataset, Labels};
use crate::data::sorted_index::SortedIndex;
use crate::selection::split::{SplitOp, SplitPredicate};

/// Byte accounting of the double-buffered arenas (row/value/label lists
/// only — the lists the old builder cloned per node).
#[derive(Debug, Clone, Copy, Default)]
pub struct ArenaStats {
    /// Arena footprint right after the root build.
    pub bytes_at_root: usize,
    /// Largest arena footprint observed at any level. Equal to
    /// `bytes_at_root` when the zero-per-node-allocation contract holds.
    pub peak_bytes: usize,
    /// Arena footprint when the build finished.
    pub final_bytes: usize,
    /// Peak bytes of the binned backend's per-node histogram buffers
    /// (tracked separately from the arenas; 0 for the exact backends).
    pub hist_scratch_bytes: usize,
    /// Per-feature numeric row entries accumulated into histograms
    /// across the whole fit — the parent-minus-sibling subtraction
    /// witness: the root plus only the *smaller* child of every split
    /// (0 for the exact backends).
    pub hist_rows_accumulated: usize,
}

/// One pending node of the current level: tree bookkeeping plus its
/// range in the row arena (per-feature ranges live in the frontier's
/// flat range tables).
#[derive(Debug, Clone, Copy)]
pub(crate) struct LevelNode {
    pub node_id: u32,
    /// Depth of this node (root = 1).
    pub depth: u16,
    pub row_off: u32,
    pub row_len: u32,
}

/// A split decision to apply to the arenas.
pub(crate) struct SplitTask {
    /// Index of the splitting node in the current level.
    pub slot: usize,
    pub predicate: SplitPredicate,
    /// Positive-row count of the node; filled by
    /// [`Frontier::partition_rows`].
    pub n_pos: u32,
}

/// Double-buffered per-feature arenas. Inactive features (masked out by
/// a forest bag) keep empty arenas and are skipped everywhere.
#[derive(Debug, Default)]
struct FeatureArena {
    active: bool,
    num_rows: [Vec<u32>; 2],
    num_vals: [Vec<f64>; 2],
    /// Classification only (empty for regression).
    num_labs: [Vec<u16>; 2],
    cat_rows: [Vec<u32>; 2],
    cat_ids: [Vec<u32>; 2],
    cat_labs: [Vec<u16>; 2],
}

/// The arena frontier of one `fit_rows` call.
pub(crate) struct Frontier {
    /// Feature count (including inactive features).
    k: usize,
    /// Which buffer of every pair is the front (0 or 1).
    cur: usize,
    /// Node row lists (root order = caller's row order).
    rows: [Vec<u32>; 2],
    /// Regression label-split only: node rows ascending by target.
    bylab: [Vec<u32>; 2],
    feats: Vec<FeatureArena>,
    /// Current level's nodes.
    nodes: Vec<LevelNode>,
    next_nodes: Vec<LevelNode>,
    /// `(offset, len)` into the numeric arenas, indexed `slot * k + f`.
    num_ranges: Vec<(u32, u32)>,
    /// `(offset, len)` into the categorical arenas, same indexing.
    cat_ranges: Vec<(u32, u32)>,
    next_num_ranges: Vec<(u32, u32)>,
    next_cat_ranges: Vec<(u32, u32)>,
    /// Level-wide positive-row bitmask over dataset row ids.
    posmask: Vec<u64>,
    /// Per `(feature, split)` positive counts `(n_pos_num, n_pos_cat)`,
    /// indexed `f * n_splits + s`; filled by the partition workers.
    pos_counts: Vec<(u32, u32)>,
}

#[inline]
fn in_pos(mask: &[u64], r: u32) -> bool {
    mask[(r >> 6) as usize] >> (r & 63) & 1 == 1
}

#[inline]
fn set_pos(mask: &mut [u64], r: u32) {
    mask[(r >> 6) as usize] |= 1u64 << (r & 63);
}

/// Evaluate a split predicate over the node's rows straight off the
/// column's typed lanes, recording positives in the level bitmask and
/// returning their count. One representation/operator branch per *call*
/// — the per-row loop never constructs a tagged `Value` (Table 3
/// semantics fall out of the lane layout: a `≤`/`>` can only match a
/// numeric cell, an `=` only a categorical one, missing matches nothing).
fn mark_matches(data: &ColumnData, op: SplitOp, rows: &[u32], mask: &mut [u64]) -> u32 {
    let mut n_pos = 0u32;
    match (data, op) {
        (ColumnData::Num { vals, valid }, SplitOp::Le(t)) => {
            for &r in rows {
                if present(valid, r as usize) && vals[r as usize] <= t {
                    set_pos(mask, r);
                    n_pos += 1;
                }
            }
        }
        (ColumnData::Num { vals, valid }, SplitOp::Gt(t)) => {
            for &r in rows {
                if present(valid, r as usize) && vals[r as usize] > t {
                    set_pos(mask, r);
                    n_pos += 1;
                }
            }
        }
        (ColumnData::Num { .. }, SplitOp::Eq(_)) => {}
        (ColumnData::Cat { ids, valid }, SplitOp::Eq(c)) => {
            for &r in rows {
                if present(valid, r as usize) && ids[r as usize] == c.0 {
                    set_pos(mask, r);
                    n_pos += 1;
                }
            }
        }
        (ColumnData::Cat { .. }, _) => {}
        (ColumnData::Hybrid { vals, num, .. }, SplitOp::Le(t)) => {
            for &r in rows {
                if num.get(r as usize) && vals[r as usize] <= t {
                    set_pos(mask, r);
                    n_pos += 1;
                }
            }
        }
        (ColumnData::Hybrid { vals, num, .. }, SplitOp::Gt(t)) => {
            for &r in rows {
                if num.get(r as usize) && vals[r as usize] > t {
                    set_pos(mask, r);
                    n_pos += 1;
                }
            }
        }
        (ColumnData::Hybrid { ids, cat, .. }, SplitOp::Eq(c)) => {
            for &r in rows {
                if cat.get(r as usize) && ids[r as usize] == c.0 {
                    set_pos(mask, r);
                    n_pos += 1;
                }
            }
        }
    }
    n_pos
}

/// Front (shared) and back (exclusive) views of a buffer pair.
fn split_pair<T>(pair: &mut [Vec<T>; 2], cur: usize) -> (&[T], &mut [T]) {
    let (a, b) = pair.split_at_mut(1);
    if cur == 0 {
        (a[0].as_slice(), b[0].as_mut_slice())
    } else {
        (b[0].as_slice(), a[0].as_mut_slice())
    }
}

/// Allocate the back buffer for a freshly-built front list.
fn pair<T: Default + Clone>(front: Vec<T>) -> [Vec<T>; 2] {
    let back = vec![T::default(); front.len()];
    [front, back]
}

/// Stable two-pointer partition of one `(rows, payload, labels)` range
/// from the front into the back buffer. Returns the positive count.
fn partition_lists<V: Copy>(
    rows: &mut [Vec<u32>; 2],
    vals: &mut [Vec<V>; 2],
    labs: &mut [Vec<u16>; 2],
    cur: usize,
    off: usize,
    len: usize,
    mask: &[u64],
) -> u32 {
    if len == 0 {
        return 0;
    }
    let mut n_pos = 0usize;
    for &r in &rows[cur][off..off + len] {
        n_pos += in_pos(mask, r) as usize;
    }
    let has_labs = !labs[cur].is_empty();
    let (fr, br) = split_pair(rows, cur);
    let (fv, bv) = split_pair(vals, cur);
    let (mut p, mut q) = (off, off + n_pos);
    if has_labs {
        let (fl, bl) = split_pair(labs, cur);
        for i in off..off + len {
            let r = fr[i];
            let dst = if in_pos(mask, r) {
                let d = p;
                p += 1;
                d
            } else {
                let d = q;
                q += 1;
                d
            };
            br[dst] = r;
            bv[dst] = fv[i];
            bl[dst] = fl[i];
        }
    } else {
        for i in off..off + len {
            let r = fr[i];
            let dst = if in_pos(mask, r) {
                let d = p;
                p += 1;
                d
            } else {
                let d = q;
                q += 1;
                d
            };
            br[dst] = r;
            bv[dst] = fv[i];
        }
    }
    n_pos as u32
}

impl Frontier {
    /// Build the root arenas by filtering the dataset's cached sort
    /// order down to `rows` (`member` is the row-membership mask, `full`
    /// short-circuits the filter when `rows` covers the whole dataset).
    /// Inactive features (forest feature masking) get empty arenas.
    /// `labels` is the fit's label view — usually `&ds.labels`, but a
    /// boosting round passes its per-round residuals instead (the arena
    /// label lists and the bylab order are derived from it, never from
    /// the dataset).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn build_root(
        ds: &Dataset,
        index: &SortedIndex,
        rows: &[u32],
        member: &[bool],
        full: bool,
        active: Option<&[bool]>,
        want_bylab: bool,
        root_id: u32,
        labels: &Labels,
    ) -> Frontier {
        let k = ds.n_features();
        let class_ids: Option<&[u16]> = match labels {
            Labels::Class { ids, .. } => Some(ids),
            Labels::Reg { .. } => None,
        };

        let mut feats = Vec::with_capacity(k);
        let mut num_ranges = Vec::with_capacity(k);
        let mut cat_ranges = Vec::with_capacity(k);
        for (f, fs) in index.features.iter().enumerate() {
            if active.is_some_and(|m| !m[f]) {
                feats.push(FeatureArena::default());
                num_ranges.push((0u32, 0u32));
                cat_ranges.push((0u32, 0u32));
                continue;
            }
            let (nr, nv) = if full {
                (fs.num_rows.clone(), fs.num_vals.clone())
            } else {
                let mut r = Vec::new();
                let mut v = Vec::new();
                for (&row, &val) in fs.num_rows.iter().zip(&fs.num_vals) {
                    if member[row as usize] {
                        r.push(row);
                        v.push(val);
                    }
                }
                (r, v)
            };
            let (cr, ci) = if full {
                (fs.cat_rows.clone(), fs.cat_ids.clone())
            } else {
                let mut r = Vec::new();
                let mut i = Vec::new();
                for (&row, &id) in fs.cat_rows.iter().zip(&fs.cat_ids) {
                    if member[row as usize] {
                        r.push(row);
                        i.push(id);
                    }
                }
                (r, i)
            };
            let nl: Vec<u16> = class_ids
                .map(|ids| nr.iter().map(|&r| ids[r as usize]).collect())
                .unwrap_or_default();
            let cl: Vec<u16> = class_ids
                .map(|ids| cr.iter().map(|&r| ids[r as usize]).collect())
                .unwrap_or_default();
            num_ranges.push((0u32, nr.len() as u32));
            cat_ranges.push((0u32, cr.len() as u32));
            feats.push(FeatureArena {
                active: true,
                num_rows: pair(nr),
                num_vals: pair(nv),
                num_labs: pair(nl),
                cat_rows: pair(cr),
                cat_ids: pair(ci),
                cat_labs: pair(cl),
            });
        }

        let bylab = if want_bylab {
            if full {
                index.reg_order.clone()
            } else {
                index
                    .reg_order
                    .iter()
                    .copied()
                    .filter(|&r| member[r as usize])
                    .collect()
            }
        } else {
            Vec::new()
        };

        Frontier {
            k,
            cur: 0,
            rows: pair(rows.to_vec()),
            bylab: pair(bylab),
            feats,
            nodes: vec![LevelNode {
                node_id: root_id,
                depth: 1,
                row_off: 0,
                row_len: rows.len() as u32,
            }],
            next_nodes: Vec::new(),
            num_ranges,
            cat_ranges,
            next_num_ranges: Vec::new(),
            next_cat_ranges: Vec::new(),
            posmask: vec![0u64; ds.n_rows().div_ceil(64)],
            pos_counts: Vec::new(),
        }
    }

    pub(crate) fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub(crate) fn node(&self, slot: usize) -> LevelNode {
        self.nodes[slot]
    }

    pub(crate) fn feature_active(&self, f: usize) -> bool {
        self.feats[f].active
    }

    /// All rows of the node, in maintained (root) order.
    pub(crate) fn node_rows(&self, slot: usize) -> &[u32] {
        let n = self.nodes[slot];
        &self.rows[self.cur][n.row_off as usize..(n.row_off + n.row_len) as usize]
    }

    /// The node's rows ascending by regression target (empty unless the
    /// frontier was built with `want_bylab`).
    pub(crate) fn node_bylab(&self, slot: usize) -> &[u32] {
        if self.bylab[self.cur].is_empty() {
            return &[];
        }
        let n = self.nodes[slot];
        &self.bylab[self.cur][n.row_off as usize..(n.row_off + n.row_len) as usize]
    }

    /// `(rows, values, labels)` of the node's sorted numeric cells for
    /// feature `f` (labels empty for regression).
    pub(crate) fn num_slices(&self, slot: usize, f: usize) -> (&[u32], &[f64], &[u16]) {
        let (off, len) = self.num_ranges[slot * self.k + f];
        let (off, len) = (off as usize, len as usize);
        let a = &self.feats[f];
        let labs: &[u16] = if a.num_labs[self.cur].is_empty() {
            &[]
        } else {
            &a.num_labs[self.cur][off..off + len]
        };
        (
            &a.num_rows[self.cur][off..off + len],
            &a.num_vals[self.cur][off..off + len],
            labs,
        )
    }

    /// `(rows, ids, labels)` of the node's grouped categorical cells for
    /// feature `f` (labels empty for regression).
    pub(crate) fn cat_slices(&self, slot: usize, f: usize) -> (&[u32], &[u32], &[u16]) {
        let (off, len) = self.cat_ranges[slot * self.k + f];
        let (off, len) = (off as usize, len as usize);
        let a = &self.feats[f];
        let labs: &[u16] = if a.cat_labs[self.cur].is_empty() {
            &[]
        } else {
            &a.cat_labs[self.cur][off..off + len]
        };
        (
            &a.cat_rows[self.cur][off..off + len],
            &a.cat_ids[self.cur][off..off + len],
            labs,
        )
    }

    /// Phase 1 of a level's partition: evaluate each split's predicate
    /// once per node row, record positives in the level bitmask, fill
    /// `SplitTask::n_pos`, and stably partition the row arena (and the
    /// regression by-target arena) into the back buffer.
    pub(crate) fn partition_rows(&mut self, ds: &Dataset, splits: &mut [SplitTask]) {
        self.posmask.fill(0);
        let cur = self.cur;
        {
            let (front, back) = split_pair(&mut self.rows, cur);
            for t in splits.iter_mut() {
                let node = self.nodes[t.slot];
                let off = node.row_off as usize;
                let len = node.row_len as usize;
                let n_pos = mark_matches(
                    &ds.columns[t.predicate.feature].data,
                    t.predicate.op,
                    &front[off..off + len],
                    &mut self.posmask,
                );
                t.n_pos = n_pos;
                // Selection guarantees both sides non-empty.
                debug_assert!(n_pos > 0 && (n_pos as usize) < len);
                let (mut p, mut q) = (off, off + n_pos as usize);
                for &r in &front[off..off + len] {
                    if in_pos(&self.posmask, r) {
                        back[p] = r;
                        p += 1;
                    } else {
                        back[q] = r;
                        q += 1;
                    }
                }
            }
        }
        if !self.bylab[cur].is_empty() {
            let (front, back) = split_pair(&mut self.bylab, cur);
            for t in splits.iter() {
                let node = self.nodes[t.slot];
                let off = node.row_off as usize;
                let len = node.row_len as usize;
                let (mut p, mut q) = (off, off + t.n_pos as usize);
                for &r in &front[off..off + len] {
                    if in_pos(&self.posmask, r) {
                        back[p] = r;
                        p += 1;
                    } else {
                        back[q] = r;
                        q += 1;
                    }
                }
            }
        }
    }

    /// Phase 2: partition every feature arena's split ranges into the
    /// back buffer. Parallelism is per feature — each worker owns one
    /// feature's arrays (`&mut FeatureArena`) and a disjoint chunk of
    /// the count table, so the phase is lock-free by construction.
    pub(crate) fn partition_features(&mut self, splits: &[SplitTask], n_threads: usize) {
        if splits.is_empty() {
            return;
        }
        let n_splits = splits.len();
        self.pos_counts.clear();
        self.pos_counts.resize(self.k * n_splits, (0u32, 0u32));
        let cur = self.cur;
        let k = self.k;
        let num_ranges = &self.num_ranges;
        let cat_ranges = &self.cat_ranges;
        let mask = &self.posmask;
        let jobs: Vec<(usize, &mut FeatureArena, &mut [(u32, u32)])> = self
            .feats
            .iter_mut()
            .zip(self.pos_counts.chunks_mut(n_splits))
            .enumerate()
            .map(|(f, (arena, counts))| (f, arena, counts))
            .collect();
        parallel_map(jobs, n_threads, |(f, arena, counts)| {
            if !arena.active {
                return;
            }
            for (s, t) in splits.iter().enumerate() {
                let (noff, nlen) = num_ranges[t.slot * k + f];
                let np = partition_lists(
                    &mut arena.num_rows,
                    &mut arena.num_vals,
                    &mut arena.num_labs,
                    cur,
                    noff as usize,
                    nlen as usize,
                    mask,
                );
                let (coff, clen) = cat_ranges[t.slot * k + f];
                let cp = partition_lists(
                    &mut arena.cat_rows,
                    &mut arena.cat_ids,
                    &mut arena.cat_labs,
                    cur,
                    coff as usize,
                    clen as usize,
                    mask,
                );
                counts[s] = (np, cp);
            }
        });
    }

    /// Phase 3: derive the children's ranges (they tile the parents'),
    /// install them as the next level, and flip the buffers.
    /// `children[s]` is the `(positive, negative)` node-id pair of
    /// `splits[s]`.
    pub(crate) fn advance(&mut self, splits: &[SplitTask], children: &[(u32, u32)]) {
        debug_assert_eq!(splits.len(), children.len());
        let n_splits = splits.len();
        self.next_nodes.clear();
        self.next_num_ranges.clear();
        self.next_cat_ranges.clear();
        for (s, t) in splits.iter().enumerate() {
            let parent = self.nodes[t.slot];
            let (pos_id, neg_id) = children[s];
            self.next_nodes.push(LevelNode {
                node_id: pos_id,
                depth: parent.depth + 1,
                row_off: parent.row_off,
                row_len: t.n_pos,
            });
            for f in 0..self.k {
                let (noff, _) = self.num_ranges[t.slot * self.k + f];
                let (coff, _) = self.cat_ranges[t.slot * self.k + f];
                let (np, cp) = self.pos_counts[f * n_splits + s];
                self.next_num_ranges.push((noff, np));
                self.next_cat_ranges.push((coff, cp));
            }
            self.next_nodes.push(LevelNode {
                node_id: neg_id,
                depth: parent.depth + 1,
                row_off: parent.row_off + t.n_pos,
                row_len: parent.row_len - t.n_pos,
            });
            for f in 0..self.k {
                let (noff, nlen) = self.num_ranges[t.slot * self.k + f];
                let (coff, clen) = self.cat_ranges[t.slot * self.k + f];
                let (np, cp) = self.pos_counts[f * n_splits + s];
                self.next_num_ranges.push((noff + np, nlen - np));
                self.next_cat_ranges.push((coff + cp, clen - cp));
            }
        }
        std::mem::swap(&mut self.nodes, &mut self.next_nodes);
        std::mem::swap(&mut self.num_ranges, &mut self.next_num_ranges);
        std::mem::swap(&mut self.cat_ranges, &mut self.next_cat_ranges);
        self.cur ^= 1;
    }

    /// Allocated bytes of the double-buffered row/value/label arenas.
    /// Constant from the root build to the end of the fit — the
    /// zero-per-node-allocation contract.
    pub(crate) fn arena_bytes(&self) -> usize {
        use std::mem::size_of;
        let mut b = 0usize;
        for buf in &self.rows {
            b += buf.capacity() * size_of::<u32>();
        }
        for buf in &self.bylab {
            b += buf.capacity() * size_of::<u32>();
        }
        for a in &self.feats {
            for v in &a.num_rows {
                b += v.capacity() * size_of::<u32>();
            }
            for v in &a.num_vals {
                b += v.capacity() * size_of::<f64>();
            }
            for v in &a.num_labs {
                b += v.capacity() * size_of::<u16>();
            }
            for v in &a.cat_rows {
                b += v.capacity() * size_of::<u32>();
            }
            for v in &a.cat_ids {
                b += v.capacity() * size_of::<u32>();
            }
            for v in &a.cat_labs {
                b += v.capacity() * size_of::<u16>();
            }
        }
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::column::Column;
    use crate::data::dataset::{Dataset, Labels};
    use crate::data::interner::Interner;
    use crate::data::value::Value;
    use crate::selection::split::SplitOp;

    fn ds_with_two_features() -> Dataset {
        // f0: 5 numerics; f1: mixed numeric/missing.
        let cols = vec![
            Column::new(
                "f0",
                vec![
                    Value::Num(4.0),
                    Value::Num(1.0),
                    Value::Num(3.0),
                    Value::Num(0.0),
                    Value::Num(2.0),
                ],
            ),
            Column::new(
                "f1",
                vec![
                    Value::Num(10.0),
                    Value::Missing,
                    Value::Num(30.0),
                    Value::Num(20.0),
                    Value::Missing,
                ],
            ),
        ];
        let labels = Labels::Class {
            ids: vec![0, 1, 0, 1, 0],
            n_classes: 2,
        };
        Dataset::new("fr", cols, labels, Interner::new()).unwrap()
    }

    #[test]
    fn stable_partition_preserves_sortedness() {
        let ds = ds_with_two_features();
        let rows: Vec<u32> = (0..5).collect();
        let member = vec![true; 5];
        let mut fr = Frontier::build_root(
            &ds,
            ds.sorted_index(),
            &rows,
            &member,
            true,
            None,
            false,
            0,
            &ds.labels,
        );
        // Root f0 sorted rows: values 0,1,2,3,4 → rows 3,1,4,2,0.
        assert_eq!(fr.num_slices(0, 0).0, &[3, 1, 4, 2, 0]);
        let bytes = fr.arena_bytes();

        // Split on f0 ≤ 2.0 → positives {3,1,4}, negatives {2,0}.
        let mut splits = vec![SplitTask {
            slot: 0,
            predicate: SplitPredicate {
                feature: 0,
                op: SplitOp::Le(2.0),
            },
            n_pos: 0,
        }];
        fr.partition_rows(&ds, &mut splits);
        assert_eq!(splits[0].n_pos, 3);
        fr.partition_features(&splits, 1);
        fr.advance(&splits, &[(1, 2)]);

        assert_eq!(fr.n_nodes(), 2);
        // Positive child keeps sorted order of its rows.
        assert_eq!(fr.num_slices(0, 0).0, &[3, 1, 4]);
        assert_eq!(fr.num_slices(0, 0).1, &[0.0, 1.0, 2.0]);
        assert_eq!(fr.num_slices(1, 0).0, &[2, 0]);
        // f1: positives {3,1,4} have one numeric cell (row 3 → 20.0);
        // negatives {2,0} have rows 0,2 → values 10.0, 30.0 in order.
        assert_eq!(fr.num_slices(0, 1).0, &[3]);
        assert_eq!(fr.num_slices(1, 1).0, &[0, 2]);
        // Node rows stay in root order on both sides.
        assert_eq!(fr.node_rows(0), &[1, 3, 4]);
        assert_eq!(fr.node_rows(1), &[0, 2]);
        // Zero growth.
        assert_eq!(fr.arena_bytes(), bytes);
    }

    #[test]
    fn mark_matches_agrees_with_value_oracle() {
        // Lane-specialized predicate marking ≡ Table 3 `op.eval` over
        // tagged cells, for every representation.
        let mut interner = Interner::new();
        let (a, b) = (interner.intern("a"), interner.intern("b"));
        let columns = vec![
            Column::new("num", vec![Value::Num(1.0), Value::Num(3.0), Value::Num(2.0)]),
            Column::new("nummiss", vec![Value::Num(1.0), Value::Missing, Value::Num(9.0)]),
            Column::new("cat", vec![Value::Cat(a), Value::Cat(b), Value::Cat(a)]),
            Column::new("catmiss", vec![Value::Cat(b), Value::Missing, Value::Cat(a)]),
            Column::new("hyb", vec![Value::Num(2.0), Value::Cat(a), Value::Missing]),
        ];
        let ops = [
            SplitOp::Le(2.0),
            SplitOp::Gt(1.0),
            SplitOp::Eq(a),
            SplitOp::Eq(b),
        ];
        let rows: Vec<u32> = vec![2, 0, 1];
        for col in &columns {
            for op in ops {
                let mut mask = vec![0u64; 1];
                let n = mark_matches(&col.data, op, &rows, &mut mask);
                let mut expect = 0u32;
                for &r in &rows {
                    let hit = op.eval(col.get(r as usize));
                    assert_eq!(in_pos(&mask, r), hit, "{} {op:?} row {r}", col.name);
                    expect += hit as u32;
                }
                assert_eq!(n, expect, "{} {op:?}", col.name);
            }
        }
    }

    #[test]
    fn inactive_features_have_empty_arenas() {
        let ds = ds_with_two_features();
        let rows: Vec<u32> = (0..5).collect();
        let member = vec![true; 5];
        let active = vec![true, false];
        let fr = Frontier::build_root(
            &ds,
            ds.sorted_index(),
            &rows,
            &member,
            true,
            Some(&active),
            false,
            0,
            &ds.labels,
        );
        assert!(fr.feature_active(0));
        assert!(!fr.feature_active(1));
        assert!(fr.num_slices(0, 1).0.is_empty());
        assert!(fr.cat_slices(0, 1).0.is_empty());
    }
}
