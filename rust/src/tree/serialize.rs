//! Tree ⇄ JSON serialization for deployment and the prediction server.
//!
//! Categorical split operands serialize as their *string* value so a tree
//! can be loaded against a fresh interner.

use super::{Node, NodeLabel, Tree};
use crate::data::dataset::TaskKind;
use crate::data::interner::Interner;
use crate::error::{Result, UdtError};
use crate::selection::split::{SplitOp, SplitPredicate};
use crate::util::json::Json;

/// Serialize a tree (with its interner for categorical operands).
pub fn to_json(tree: &Tree, interner: &Interner) -> Json {
    let nodes: Vec<Json> = tree
        .nodes
        .iter()
        .map(|n| {
            let mut fields: Vec<(&str, Json)> = vec![
                ("n", Json::Num(n.n_samples as f64)),
                ("d", Json::Num(n.depth as f64)),
                (
                    "label",
                    match n.label {
                        NodeLabel::Class(c) => Json::Num(c as f64),
                        NodeLabel::Value(v) => Json::Num(v),
                    },
                ),
            ];
            if let (Some(split), Some((pos, neg))) = (&n.split, n.children) {
                fields.push(("feature", Json::Num(split.feature as f64)));
                let (op, operand) = match split.op {
                    SplitOp::Le(t) => ("le", Json::Num(t)),
                    SplitOp::Gt(t) => ("gt", Json::Num(t)),
                    SplitOp::Eq(c) => ("eq", Json::Str(interner.name(c).to_string())),
                };
                fields.push(("op", Json::Str(op.to_string())));
                fields.push(("operand", operand));
                fields.push((
                    "children",
                    Json::Arr(vec![Json::Num(pos as f64), Json::Num(neg as f64)]),
                ));
            }
            Json::obj(fields)
        })
        .collect();

    Json::obj(vec![
        (
            "task",
            Json::Str(
                match tree.task {
                    TaskKind::Classification => "classification",
                    TaskKind::Regression => "regression",
                }
                .to_string(),
            ),
        ),
        ("n_features", Json::Num(tree.n_features as f64)),
        ("depth", Json::Num(tree.depth as f64)),
        ("nodes", Json::Arr(nodes)),
    ])
}

/// Deserialize a tree, interning categorical operands into `interner`.
pub fn from_json(json: &Json, interner: &mut Interner) -> Result<Tree> {
    let task = match json.get("task").and_then(Json::as_str) {
        Some("classification") => TaskKind::Classification,
        Some("regression") => TaskKind::Regression,
        other => return Err(UdtError::model(format!("bad task {other:?}"))),
    };
    let n_features = json
        .get("n_features")
        .and_then(Json::as_usize)
        .ok_or_else(|| UdtError::model("missing n_features"))?;
    let depth = json
        .get("depth")
        .and_then(Json::as_usize)
        .ok_or_else(|| UdtError::model("missing depth"))? as u16;
    let node_arr = json
        .get("nodes")
        .and_then(Json::as_arr)
        .ok_or_else(|| UdtError::model("missing nodes"))?;

    let mut nodes = Vec::with_capacity(node_arr.len());
    for (i, nj) in node_arr.iter().enumerate() {
        let field = |k: &str| {
            nj.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| UdtError::model(format!("node {i}: missing `{k}`")))
        };
        let n_samples = field("n")? as u32;
        let node_depth = field("d")? as u16;
        let label_num = field("label")?;
        let label = match task {
            TaskKind::Classification => NodeLabel::Class(label_num as u16),
            TaskKind::Regression => NodeLabel::Value(label_num),
        };
        let (split, children) = match nj.get("op") {
            None => (None, None),
            Some(op_json) => {
                let feature = nj
                    .get("feature")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| UdtError::model(format!("node {i}: missing `feature`")))?;
                let op = match (op_json.as_str(), nj.get("operand")) {
                    (Some("le"), Some(Json::Num(t))) => SplitOp::Le(*t),
                    (Some("gt"), Some(Json::Num(t))) => SplitOp::Gt(*t),
                    (Some("eq"), Some(Json::Str(s))) => SplitOp::Eq(interner.intern(s)),
                    other => {
                        return Err(UdtError::model(format!("node {i}: bad split {other:?}")))
                    }
                };
                let ch = nj
                    .get("children")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| UdtError::model(format!("node {i}: missing `children`")))?;
                if ch.len() != 2 {
                    return Err(UdtError::model(format!("node {i}: children must be a pair")));
                }
                let pos = ch[0]
                    .as_usize()
                    .ok_or_else(|| UdtError::model(format!("node {i}: bad child id")))?
                    as u32;
                let neg = ch[1]
                    .as_usize()
                    .ok_or_else(|| UdtError::model(format!("node {i}: bad child id")))?
                    as u32;
                (Some(SplitPredicate { feature, op }), Some((pos, neg)))
            }
        };
        nodes.push(Node {
            split,
            children,
            label,
            n_samples,
            depth: node_depth,
        });
    }

    // Validate the arena so prediction on a malformed document can
    // never index out of bounds or loop forever: at least one node,
    // children in range and strictly after their parent (the builder and
    // pruner both emit BFS order, so this holds for every legitimate
    // document and forces any root-to-leaf walk to terminate).
    if nodes.is_empty() {
        return Err(UdtError::model("tree must contain at least one node"));
    }
    for (i, n) in nodes.iter().enumerate() {
        if let Some((a, b)) = n.children {
            if a as usize >= nodes.len() || b as usize >= nodes.len() {
                return Err(UdtError::model(format!("node {i}: child out of range")));
            }
            if a as usize <= i || b as usize <= i {
                return Err(UdtError::model(format!(
                    "node {i}: children must come after their parent (got {a}, {b})"
                )));
            }
        }
        if let Some(split) = &n.split {
            if split.feature >= n_features {
                return Err(UdtError::model(format!(
                    "node {i}: split feature {} out of range (n_features {n_features})",
                    split.feature
                )));
            }
        }
    }

    Ok(Tree {
        nodes,
        task,
        n_features,
        depth,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate_any, generate_classification, SynthSpec};
    use crate::tree::{predict::predict_ds, TrainConfig};

    #[test]
    fn classification_round_trip_preserves_predictions() {
        let mut spec = SynthSpec::classification("t", 600, 6, 3);
        spec.cat_frac = 0.4; // exercise Eq splits
        let ds = generate_classification(&spec, 19);
        let tree = Tree::fit(&ds, &TrainConfig::default()).unwrap();
        let json = to_json(&tree, &ds.interner);
        let text = json.to_pretty();

        let mut interner2 = (*ds.interner).clone();
        let tree2 = from_json(&Json::parse(&text).unwrap(), &mut interner2).unwrap();
        assert_eq!(tree2.n_nodes(), tree.n_nodes());
        for r in (0..ds.n_rows()).step_by(13) {
            assert_eq!(
                predict_ds(&tree, &ds, r, usize::MAX, 0),
                predict_ds(&tree2, &ds, r, usize::MAX, 0)
            );
        }
    }

    #[test]
    fn regression_round_trip() {
        let spec = SynthSpec::regression("r", 400, 5);
        let ds = generate_any(&spec, 29);
        let tree = Tree::fit(&ds, &TrainConfig::default()).unwrap();
        let json = to_json(&tree, &ds.interner);
        let mut interner2 = (*ds.interner).clone();
        let tree2 = from_json(&json, &mut interner2).unwrap();
        for r in (0..ds.n_rows()).step_by(7) {
            let a = predict_ds(&tree, &ds, r, usize::MAX, 0).as_value().unwrap();
            let b = predict_ds(&tree2, &ds, r, usize::MAX, 0).as_value().unwrap();
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn rejects_malformed_documents() {
        let mut i = Interner::new();
        assert!(from_json(&Json::parse("{}").unwrap(), &mut i).is_err());
        let bad = r#"{"task":"classification","n_features":1,"depth":1,
            "nodes":[{"n":1,"d":1,"label":0,"op":"le","operand":1,
                      "feature":0,"children":[5,6]}]}"#;
        assert!(from_json(&Json::parse(bad).unwrap(), &mut i).is_err());
        // Empty arena would panic at the first prediction.
        let empty = r#"{"task":"classification","n_features":0,"depth":0,"nodes":[]}"#;
        assert!(from_json(&Json::parse(empty).unwrap(), &mut i).is_err());
        // Self-referencing children (in range) would loop forever.
        let cyclic = r#"{"task":"classification","n_features":1,"depth":1,
            "nodes":[{"n":9,"d":1,"label":0,"op":"le","operand":1,
                      "feature":0,"children":[0,0]}]}"#;
        assert!(from_json(&Json::parse(cyclic).unwrap(), &mut i).is_err());
        // Out-of-range split feature would index past the row.
        let bad_feature = r#"{"task":"classification","n_features":1,"depth":2,
            "nodes":[{"n":2,"d":1,"label":0,"op":"le","operand":1,
                      "feature":3,"children":[1,2]},
                     {"n":1,"d":2,"label":0},{"n":1,"d":2,"label":1}]}"#;
        assert!(from_json(&Json::parse(bad_feature).unwrap(), &mut i).is_err());
    }

    #[test]
    fn eq_operand_interns_into_fresh_interner() {
        let mut spec = SynthSpec::classification("t", 300, 3, 2);
        spec.cat_frac = 1.0;
        let ds = generate_classification(&spec, 37);
        let tree = Tree::fit(&ds, &TrainConfig::default()).unwrap();
        let json = to_json(&tree, &ds.interner);
        // Fresh interner: ids may differ but names must resolve.
        let mut fresh = Interner::new();
        let tree2 = from_json(&json, &mut fresh).unwrap();
        let has_eq = tree2.nodes.iter().any(|n| {
            matches!(
                n.split,
                Some(SplitPredicate {
                    op: SplitOp::Eq(_),
                    ..
                })
            )
        });
        assert!(has_eq, "expected at least one categorical split");
    }
}
