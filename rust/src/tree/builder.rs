//! UDT tree construction (paper Algorithm 5) on the arena frontier.
//!
//! Numeric values of every feature are sorted **once per dataset** (the
//! [`crate::data::sorted_index::SortedIndex`] cache; `O(K·M log M)` paid
//! on the first fit only — forest bags and tuning refits filter the
//! cached order by row membership in `O(K·M)`). Every `split_node` runs
//! Superfast Selection per feature in `O(M_node + N·C)` and partitions
//! the level's flat arenas **in place** with a stable two-pointer pass
//! (see [`super::frontier`]), so sortedness is maintained for free down
//! the whole tree and the builder performs zero per-node heap
//! allocations for row/value/label lists. Regression nodes additionally
//! maintain rows sorted by target for the Algorithm 6 label split.
//!
//! Hot-path engineering on top of the paper's description:
//! * sorted lists carry `(row, value, label)` in parallel arena arrays,
//!   so the prefix walk streams sequentially instead of chasing `Value`
//!   cells;
//! * node class counts are computed once per node and reused by every
//!   all-numeric column, eliminating the per-feature statistics pass for
//!   clean columns;
//! * partitioning evaluates the split predicate straight off the
//!   column's typed lanes (`f64`/`u32` + kind masks — no tagged `Value`
//!   cells anywhere in the loop), marks positive rows in a level-wide
//!   bitmask (L2-resident), and every arena range filters by bit tests.
//!
//! The frontier is processed level-synchronously: selection parallelizes
//! over the level's nodes (small frontiers fall back to feature-level
//! parallelism), the arena partition parallelizes over features — each
//! worker owns one feature's arrays, so both phases are lock-free.

use super::frontier::{ArenaStats, Frontier, LevelNode, SplitTask};
use super::label_split;
use super::{Backend, Node, NodeLabel, RegStrategy, TrainConfig, Tree};
use crate::coordinator::parallel::parallel_map_scratch;
use crate::data::dataset::{BinnedIndex, Dataset, Labels, TaskKind};
use crate::data::sorted_index::SortedIndex;
use crate::error::{Result, UdtError};
use crate::selection::binned::{accumulate, best_split_on_feat_binned, hist_width};
use crate::selection::generic::best_split_on_feat_generic;
use crate::selection::heuristic::Criterion;
use crate::selection::split::SplitPredicate;
use crate::selection::superfast::{
    best_split_on_feat_with, FeatureView, LabelsView, Scratch, ScoredSplit,
};
use std::sync::Arc;

/// Outcome of processing one frontier node.
struct Decision {
    /// Level slot the decision belongs to.
    slot: usize,
    node_id: u32,
    depth: u16,
    label: NodeLabel,
    n_samples: u32,
    /// `Some` when the node splits.
    predicate: Option<SplitPredicate>,
}

/// Per-worker scratch: selection buffers, the pseudo-label buffer for the
/// regression label-split strategy, and the class-count buffer.
struct BuildScratch {
    selection: Scratch,
    pseudo: Vec<u16>,
    class_counts: Vec<f64>,
}

impl BuildScratch {
    fn new() -> Self {
        Self {
            selection: Scratch::new(),
            pseudo: Vec::new(),
            class_counts: Vec::new(),
        }
    }
}

/// Immutable per-fit context shared by workers.
struct FitCtx<'a> {
    ds: &'a Dataset,
    config: &'a TrainConfig,
    /// The dataset's cached root sort (also provides per-column
    /// has-categorical/missing flags).
    index: &'a SortedIndex,
    /// The fit's label view — `&ds.labels` for a plain fit, or a
    /// caller-supplied override (gradient-boosting residuals) indexed by
    /// the same global row ids.
    labels: &'a Labels,
}

/// Train a tree over `rows` of `ds`.
pub fn fit_rows(ds: &Dataset, rows: &[u32], config: &TrainConfig) -> Result<Tree> {
    fit_rows_masked(ds, rows, config, None)
}

/// Train a tree over `rows`, optionally restricted to the features whose
/// `active` flag is true (forest feature bagging). Masked features never
/// produce split candidates; predicates still index the full feature
/// space, so the tree predicts over the original dataset shape.
pub fn fit_rows_masked(
    ds: &Dataset,
    rows: &[u32],
    config: &TrainConfig,
    active: Option<&[bool]>,
) -> Result<Tree> {
    fit_rows_with_stats(ds, rows, config, active).map(|(tree, _)| tree)
}

/// Train a tree over `rows` against an external label view: `labels`
/// replaces the dataset's own labels for every label read, while the
/// feature columns, the membership filter and — crucially — the cached
/// [`SortedIndex`] still come from `ds`. This is the gradient-boosting
/// entry point: residual targets change every round but feature order
/// does not, so every round filters the same root sort (the dataset's
/// sort is still built exactly once across an entire boost run) and the
/// residuals are never copied into the dataset.
///
/// `labels` must be indexed by global row id (`labels.len() ==
/// ds.n_rows()`). Regression overrides must use
/// [`RegStrategy::DirectSse`]: the cached by-target order reflects the
/// dataset's own labels, not the override, so the label-split strategy
/// would silently mis-sort.
pub fn fit_rows_with_labels(
    ds: &Dataset,
    rows: &[u32],
    config: &TrainConfig,
    labels: &Labels,
) -> Result<Tree> {
    fit_rows_core(ds, rows, config, None, Some(labels)).map(|(tree, _)| tree)
}

/// [`fit_rows_masked`], additionally returning the arena byte accounting
/// (perf instrumentation for benches and the zero-allocation tests).
pub fn fit_rows_with_stats(
    ds: &Dataset,
    rows: &[u32],
    config: &TrainConfig,
    active: Option<&[bool]>,
) -> Result<(Tree, ArenaStats)> {
    fit_rows_core(ds, rows, config, active, None)
}

fn fit_rows_core(
    ds: &Dataset,
    rows: &[u32],
    config: &TrainConfig,
    active: Option<&[bool]>,
    labels_override: Option<&Labels>,
) -> Result<(Tree, ArenaStats)> {
    let labels = labels_override.unwrap_or(&ds.labels);
    if rows.is_empty() {
        return Err(UdtError::data("cannot fit on an empty row set"));
    }
    if ds.n_features() == 0 {
        return Err(UdtError::data("dataset has no features"));
    }
    if config.max_depth < 1 {
        return Err(UdtError::invalid_config("max_depth must be >= 1"));
    }
    if let Some(mask) = active {
        if mask.len() != ds.n_features() {
            return Err(UdtError::invalid_config(format!(
                "feature mask has {} entries but the dataset has {} features",
                mask.len(),
                ds.n_features()
            )));
        }
    }
    if let Backend::Binned { max_bins } = &config.backend {
        super::validate_max_bins(*max_bins)?;
        if matches!(labels, Labels::Reg { .. }) && config.reg_strategy == RegStrategy::LabelSplit {
            return Err(UdtError::invalid_config(
                "the binned backend requires RegStrategy::DirectSse for regression \
                 (the label-split strategy re-labels every node, which defeats \
                 parent-minus-sibling histogram subtraction)",
            ));
        }
    }
    if let Some(over) = labels_override {
        if over.len() != ds.n_rows() {
            return Err(UdtError::data(format!(
                "label override has {} entries but the dataset has {} rows",
                over.len(),
                ds.n_rows()
            )));
        }
        if matches!(over, Labels::Reg { .. }) && config.reg_strategy == RegStrategy::LabelSplit {
            return Err(UdtError::invalid_config(
                "label override requires RegStrategy::DirectSse (the cached \
                 by-target order reflects the dataset's own labels)",
            ));
        }
    }

    let member = membership_mask(ds.n_rows(), rows);
    if member.iter().filter(|&&m| m).count() != rows.len() {
        return Err(UdtError::data(
            "duplicate rows in training subset (sample without replacement)",
        ));
    }
    let full = rows.len() == ds.n_rows();

    // Root arena build (Algorithm 5 line 2) from the dataset-level sort
    // cache: the first fit on `ds` sorts, every later fit only filters.
    let index = ds.sorted_index();
    let want_bylab =
        matches!(labels, Labels::Reg { .. }) && config.reg_strategy == RegStrategy::LabelSplit;
    let mut frontier = Frontier::build_root(
        ds,
        index,
        rows,
        &member,
        full,
        active,
        want_bylab,
        Tree::ROOT,
        labels,
    );
    let bytes_at_root = frontier.arena_bytes();
    let mut stats = ArenaStats {
        bytes_at_root,
        peak_bytes: bytes_at_root,
        final_bytes: bytes_at_root,
        hist_scratch_bytes: 0,
        hist_rows_accumulated: 0,
    };

    // Binned backend: the dataset-level bin lanes are built once (and
    // cached on the dataset, like the sort itself); the per-node
    // histogram state below pays one full accumulation at the root and
    // from then on only ever walks the *smaller* child of each split —
    // the larger sibling's histograms come from parent-minus-sibling
    // subtraction.
    let mut binned_state = if let Backend::Binned { max_bins } = &config.backend {
        let view = LabelsView::from_labels(labels);
        let mut st = BinnedState::new(
            ds.binned_index(*max_bins),
            hist_width(&view),
            config.max_depth,
        );
        st.begin_root(&frontier, &view);
        Some(st)
    } else {
        None
    };

    let ctx = FitCtx {
        ds,
        config,
        index,
        labels,
    };

    let mut tree = Tree {
        nodes: Vec::new(),
        task: labels.kind(),
        n_features: ds.n_features(),
        depth: 0,
    };
    tree.nodes.push(placeholder_node()); // root slot

    let n_threads = crate::runtime::threads(config.n_threads);

    loop {
        let n_level = frontier.n_nodes();
        if n_level == 0 {
            break;
        }
        // Frontier-level parallelism; small frontiers instead parallelize
        // the per-node selection across features.
        let feature_threads = if n_level < n_threads { n_threads } else { 1 };
        let decisions: Vec<Decision> = parallel_map_scratch(
            (0..n_level).collect(),
            n_threads,
            BuildScratch::new,
            |slot, scratch| {
                process_node(
                    &ctx,
                    &frontier,
                    slot,
                    scratch,
                    binned_state.as_ref(),
                    feature_threads,
                )
            },
        );

        // Apply decisions in slot order: node ids stay deterministic
        // regardless of worker interleaving.
        let mut splits: Vec<SplitTask> = Vec::new();
        let mut children: Vec<(u32, u32)> = Vec::new();
        for d in decisions {
            {
                let node = &mut tree.nodes[d.node_id as usize];
                node.label = d.label;
                node.n_samples = d.n_samples;
                node.depth = d.depth;
            }
            tree.depth = tree.depth.max(d.depth);
            if let Some(predicate) = d.predicate {
                let pos_id = tree.nodes.len() as u32;
                let neg_id = pos_id + 1;
                tree.nodes[d.node_id as usize].split = Some(predicate);
                tree.nodes[d.node_id as usize].children = Some((pos_id, neg_id));
                tree.nodes.push(placeholder_node());
                tree.nodes.push(placeholder_node());
                splits.push(SplitTask {
                    slot: d.slot,
                    predicate,
                    n_pos: 0,
                });
                children.push((pos_id, neg_id));
            }
        }
        if splits.is_empty() {
            break; // every frontier node became a leaf
        }

        // In-place stable partition: rows (+ regression by-target order)
        // sequentially, then all feature arenas in parallel.
        frontier.partition_rows(ds, &mut splits);
        frontier.partition_features(&splits, n_threads);
        frontier.advance(&splits, &children);
        if let Some(st) = binned_state.as_mut() {
            st.advance_level(&frontier, &splits, &LabelsView::from_labels(labels));
        }
        stats.peak_bytes = stats.peak_bytes.max(frontier.arena_bytes());
    }
    stats.final_bytes = frontier.arena_bytes();
    if let Some(st) = &binned_state {
        stats.hist_scratch_bytes = st.peak_bytes;
        stats.hist_rows_accumulated = st.rows_accumulated;
    }
    Ok((tree, stats))
}

/// Per-fit histogram state of the binned backend.
///
/// One contiguous f64 block per *tracked* node holds all its per-feature
/// label histograms: feature `f`'s histogram occupies
/// `feat_off[f]..feat_off[f + 1]` within the block (`n_bins_f × width`
/// slots; zero-sized for lane-less features). Blocks are double-buffered
/// across levels like the arenas. A node is tracked only while it can
/// still split (`depth < max_depth`) and is large enough that the `O(B)`
/// histogram scan beats the exact engine's direct walk
/// (`row_len ≥ max_bins`); untracked nodes — and every descendant of an
/// untracked node — fall back to exact Superfast selection.
///
/// The subtraction invariant: after a split, only the **smaller** child
/// is ever accumulated (`O(rows_small)`); a tracked larger sibling is
/// derived as `parent − smaller` in `O(block)`. When the smaller child
/// is itself untracked it is accumulated into `temp` just for the
/// derivation — still strictly cheaper than walking the larger side.
struct BinnedState {
    binned: Arc<BinnedIndex>,
    /// Block-relative histogram offsets per feature; `feat_off[k]` is
    /// the block length.
    feat_off: Vec<usize>,
    /// Minimum tracked node size (`= max_bins`).
    min_rows: usize,
    max_depth: usize,
    /// Block index per current-level slot (`None` = untracked).
    slot_block: Vec<Option<usize>>,
    hists: Vec<f64>,
    next_slot_block: Vec<Option<usize>>,
    next_hists: Vec<f64>,
    /// Scratch block for smaller children that are themselves untracked
    /// but whose sibling is derived by subtraction.
    temp: Vec<f64>,
    /// Total per-feature numeric row entries walked by `accumulate` —
    /// the subtraction witness (root + smaller children only).
    rows_accumulated: usize,
    /// Peak bytes of the histogram buffers.
    peak_bytes: usize,
}

impl BinnedState {
    fn new(binned: Arc<BinnedIndex>, width: usize, max_depth: usize) -> Self {
        let mut feat_off = Vec::with_capacity(binned.lanes.len() + 1);
        let mut off = 0usize;
        for lane in &binned.lanes {
            feat_off.push(off);
            if let Some(lane) = lane {
                off += lane.n_bins() * width;
            }
        }
        feat_off.push(off);
        BinnedState {
            min_rows: binned.max_bins,
            binned,
            feat_off,
            max_depth,
            slot_block: Vec::new(),
            hists: Vec::new(),
            next_slot_block: Vec::new(),
            next_hists: Vec::new(),
            temp: Vec::new(),
            rows_accumulated: 0,
            peak_bytes: 0,
        }
    }

    fn block_len(&self) -> usize {
        // ANALYZE-ALLOW(no-unwrap): feat_off always holds n_features + 1 entries
        *self.feat_off.last().unwrap()
    }

    fn tracks(&self, node: &LevelNode) -> bool {
        (node.row_len as usize) >= self.min_rows && (node.depth as usize) < self.max_depth
    }

    /// Block index of a current-level slot, `None` when untracked.
    fn block_of(&self, slot: usize) -> Option<usize> {
        self.slot_block[slot]
    }

    /// Feature `f`'s histogram within a current-level block.
    fn hist(&self, block: usize, f: usize) -> &[f64] {
        let base = block * self.block_len();
        &self.hists[base + self.feat_off[f]..base + self.feat_off[f + 1]]
    }

    /// Accumulate the root node — the only full-node accumulation of the
    /// whole fit.
    fn begin_root(&mut self, frontier: &Frontier, labels: &LabelsView) {
        let block = self.block_len();
        self.slot_block.clear();
        self.slot_block.push(None);
        if self.tracks(&frontier.node(0)) {
            self.slot_block[0] = Some(0);
            self.hists.clear();
            self.hists.resize(block, 0.0);
            let walked = accumulate_node_hists(
                &self.binned,
                &self.feat_off,
                frontier,
                0,
                labels,
                &mut self.hists,
            );
            self.rows_accumulated += walked;
        }
        self.update_peak();
    }

    /// Advance to the level the frontier just switched to: accumulate
    /// the smaller child of every split, derive tracked larger siblings
    /// by parent-minus-sibling subtraction. Call right after
    /// [`Frontier::advance`] (split `s`'s children sit at new-level
    /// slots `2s`/`2s+1`; `splits[s].slot` still names the parent's old
    /// slot).
    fn advance_level(&mut self, frontier: &Frontier, splits: &[SplitTask], labels: &LabelsView) {
        let block = self.block_len();
        self.next_slot_block.clear();
        self.next_slot_block.resize(frontier.n_nodes(), None);

        struct Plan {
            parent_block: usize,
            small_slot: usize,
            small_block: Option<usize>,
            large_block: Option<usize>,
        }
        let mut plans: Vec<Plan> = Vec::with_capacity(splits.len());
        let mut n_blocks = 0usize;
        for (s, t) in splits.iter().enumerate() {
            let Some(parent_block) = self.slot_block[t.slot] else {
                continue; // untracked parents beget untracked children
            };
            let (pos, neg) = (frontier.node(2 * s), frontier.node(2 * s + 1));
            let (small_slot, large_slot) = if pos.row_len <= neg.row_len {
                (2 * s, 2 * s + 1)
            } else {
                (2 * s + 1, 2 * s)
            };
            let small_block = if self.tracks(&frontier.node(small_slot)) {
                n_blocks += 1;
                Some(n_blocks - 1)
            } else {
                None
            };
            let large_block = if self.tracks(&frontier.node(large_slot)) {
                n_blocks += 1;
                Some(n_blocks - 1)
            } else {
                None
            };
            if small_block.is_none() && large_block.is_none() {
                continue;
            }
            self.next_slot_block[small_slot] = small_block;
            self.next_slot_block[large_slot] = large_block;
            plans.push(Plan {
                parent_block,
                small_slot,
                small_block,
                large_block,
            });
        }

        self.next_hists.clear();
        self.next_hists.resize(n_blocks * block, 0.0);

        let mut walked = 0usize;
        for p in &plans {
            // The smaller child is the only side ever accumulated; it
            // lands in `temp` first so an untracked smaller child can
            // still feed the sibling derivation.
            self.temp.clear();
            self.temp.resize(block, 0.0);
            walked += accumulate_node_hists(
                &self.binned,
                &self.feat_off,
                frontier,
                p.small_slot,
                labels,
                &mut self.temp,
            );
            if let Some(sb) = p.small_block {
                self.next_hists[sb * block..(sb + 1) * block].copy_from_slice(&self.temp);
            }
            if let Some(lb) = p.large_block {
                let parent = &self.hists[p.parent_block * block..(p.parent_block + 1) * block];
                let dst = &mut self.next_hists[lb * block..(lb + 1) * block];
                for (d, (&pa, &sm)) in dst.iter_mut().zip(parent.iter().zip(self.temp.iter())) {
                    *d = pa - sm;
                }
            }
        }
        self.rows_accumulated += walked;
        std::mem::swap(&mut self.hists, &mut self.next_hists);
        std::mem::swap(&mut self.slot_block, &mut self.next_slot_block);
        self.update_peak();
    }

    fn update_peak(&mut self) {
        let bytes = (self.hists.capacity() + self.next_hists.capacity() + self.temp.capacity())
            * std::mem::size_of::<f64>();
        self.peak_bytes = self.peak_bytes.max(bytes);
    }
}

/// Accumulate one node's per-feature histograms from its maintained
/// numeric arena lists; returns the number of row entries walked.
fn accumulate_node_hists(
    binned: &BinnedIndex,
    feat_off: &[usize],
    frontier: &Frontier,
    slot: usize,
    labels: &LabelsView,
    dst: &mut [f64],
) -> usize {
    let mut walked = 0usize;
    for (f, lane) in binned.lanes.iter().enumerate() {
        let Some(lane) = lane else { continue };
        if !frontier.feature_active(f) {
            continue;
        }
        let (rows, _vals, labs) = frontier.num_slices(slot, f);
        accumulate(&mut dst[feat_off[f]..feat_off[f + 1]], rows, labs, labels, |r| {
            lane.bin_of_row(r)
        });
        walked += rows.len();
    }
    walked
}

fn placeholder_node() -> Node {
    Node {
        split: None,
        children: None,
        label: NodeLabel::Class(0),
        n_samples: 0,
        depth: 0,
    }
}

fn membership_mask(n: usize, rows: &[u32]) -> Vec<bool> {
    let mut mask = vec![false; n];
    for &r in rows {
        mask[r as usize] = true;
    }
    mask
}

/// Paper's `split_node`: label the node and pick the best split. The
/// partition itself happens arena-wide after the whole level decided.
fn process_node(
    ctx: &FitCtx,
    frontier: &Frontier,
    slot: usize,
    scratch: &mut BuildScratch,
    binned: Option<&BinnedState>,
    feature_threads: usize,
) -> Decision {
    let ds = ctx.ds;
    let config = ctx.config;
    let node = frontier.node(slot);
    let rows = frontier.node_rows(slot);
    let (label, pure, reg_stats) = node_label(ctx.labels, rows, &mut scratch.class_counts);
    let mut decision = Decision {
        slot,
        node_id: node.node_id,
        depth: node.depth,
        label,
        n_samples: rows.len() as u32,
        predicate: None,
    };

    // Stopping rules (the "full-fledged" tree only stops on hard limits).
    if pure
        || node.depth as usize >= config.max_depth
        || rows.len() < config.min_samples_split.max(2)
    {
        return decision;
    }

    let BuildScratch {
        selection,
        pseudo,
        class_counts,
    } = scratch;

    // Build the label view. Regression with the paper's strategy first
    // binarizes the node's targets at the best SSE threshold
    // (Algorithm 6), then proceeds as 2-class classification.
    let mut pseudo_counts = [0.0f64; 2];
    let (labels_view, criterion): (LabelsView, Criterion) = match ctx.labels {
        Labels::Class { ids, n_classes } => (
            LabelsView::Class {
                ids,
                n_classes: *n_classes,
            },
            config.criterion_for(TaskKind::Classification),
        ),
        Labels::Reg { values } => match config.reg_strategy {
            RegStrategy::DirectSse => (LabelsView::Reg { values }, Criterion::Sse),
            RegStrategy::LabelSplit => {
                let Some((threshold, _)) =
                    label_split::best_label_split(frontier.node_bylab(slot), values)
                else {
                    return decision; // constant labels — leaf
                };
                if pseudo.len() < ds.n_rows() {
                    pseudo.resize(ds.n_rows(), 0);
                }
                label_split::binarize(rows, values, threshold, pseudo);
                for &r in rows {
                    pseudo_counts[pseudo[r as usize] as usize] += 1.0;
                }
                (
                    LabelsView::Class {
                        ids: &*pseudo,
                        n_classes: 2,
                    },
                    Criterion::Class(config.criterion),
                )
            }
        },
    };
    // Class counts aligned with the labels view (pseudo-labels for the
    // regression label-split strategy).
    let counts_for_view: &[f64] = match (ctx.labels, config.reg_strategy) {
        (Labels::Class { .. }, _) => class_counts,
        (Labels::Reg { .. }, RegStrategy::LabelSplit) => &pseudo_counts,
        (Labels::Reg { .. }, RegStrategy::DirectSse) => &[],
    };

    // Minimum-gain test reference point.
    let baseline = baseline_score(&labels_view, criterion, rows);

    // Best split across features (Algorithm 4 best_split_on_all_feats).
    let best = best_across_features(
        ctx,
        frontier,
        slot,
        rows,
        &labels_view,
        counts_for_view,
        reg_stats,
        criterion,
        selection,
        binned,
        feature_threads,
    );

    let Some((feature, best)) = best else {
        return decision;
    };
    if !(best.score - baseline > config.min_gain) {
        return decision; // no informative split
    }

    decision.predicate = Some(SplitPredicate {
        feature,
        op: best.op,
    });
    decision
}

/// Majority class (ties → smallest id) or mean target; plus purity flag
/// and regression `(n, sum)` stats. Class counts land in `counts_buf`.
fn node_label(
    labels: &Labels,
    rows: &[u32],
    counts_buf: &mut Vec<f64>,
) -> (NodeLabel, bool, Option<(f64, f64)>) {
    match labels {
        Labels::Class { ids, n_classes } => {
            counts_buf.clear();
            counts_buf.resize(*n_classes, 0.0);
            for &r in rows {
                counts_buf[ids[r as usize] as usize] += 1.0;
            }
            let (best, &max) = counts_buf
                .iter()
                .enumerate()
                // ANALYZE-ALLOW(no-unwrap): class counts are integral f64, never NaN
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap().then(b.0.cmp(&a.0)))
                // ANALYZE-ALLOW(no-unwrap): counts_buf holds n_classes >= 1 entries
                .unwrap();
            (
                NodeLabel::Class(best as u16),
                max as usize == rows.len(),
                None,
            )
        }
        Labels::Reg { values } => {
            let n = rows.len() as f64;
            let sum: f64 = rows.iter().map(|&r| values[r as usize]).sum();
            let mean = sum / n;
            let pure = rows
                .iter()
                .all(|&r| (values[r as usize] - mean).abs() < 1e-12);
            (NodeLabel::Value(mean), pure, Some((n, sum)))
        }
    }
}

/// Score of leaving the node unsplit, under the same criterion — the
/// reference point for the minimum-gain test.
fn baseline_score(labels: &LabelsView, criterion: Criterion, rows: &[u32]) -> f64 {
    match (labels, criterion) {
        (LabelsView::Class { ids, n_classes }, Criterion::Class(crit)) => {
            let mut counts = vec![0.0f64; *n_classes];
            for &r in rows {
                counts[ids[r as usize] as usize] += 1.0;
            }
            let zeros = vec![0.0f64; *n_classes];
            crit.score(&counts, &zeros)
        }
        (LabelsView::Reg { values }, Criterion::Sse) => {
            let n = rows.len() as f64;
            let sum: f64 = rows.iter().map(|&r| values[r as usize]).sum();
            sum * sum / n
        }
        _ => unreachable!("criterion/labels kind mismatch"),
    }
}

#[allow(clippy::too_many_arguments)]
fn best_across_features(
    ctx: &FitCtx,
    frontier: &Frontier,
    slot: usize,
    rows: &[u32],
    labels: &LabelsView,
    class_counts: &[f64],
    reg_stats: Option<(f64, f64)>,
    criterion: Criterion,
    selection: &mut Scratch,
    binned: Option<&BinnedState>,
    feature_threads: usize,
) -> Option<(usize, ScoredSplit)> {
    let ds = ctx.ds;
    let select = |f: usize, sel: &mut Scratch| -> Option<ScoredSplit> {
        if !frontier.feature_active(f) {
            return None; // masked out by a forest bag
        }
        let (sorted_num, sorted_vals, sorted_labs) = frontier.num_slices(slot, f);
        let (sorted_cat_rows, sorted_cat_ids, sorted_cat_labs) = frontier.cat_slices(slot, f);
        let view = FeatureView {
            feature: f,
            col: &ds.columns[f],
            rows,
            sorted_num,
            sorted_vals,
            class_counts,
            reg_stats,
            col_has_nonnum: ctx.index.features[f].has_nonnum,
            sorted_cat_rows,
            sorted_cat_ids,
            cat_lists_valid: true,
            sorted_labs,
            sorted_cat_labs,
        };
        match &ctx.config.backend {
            Backend::Superfast => best_split_on_feat_with(&view, labels, criterion, sel),
            Backend::Generic => best_split_on_feat_generic(&view, labels, criterion),
            Backend::Xla(xla) => xla.best_split_on_feat(&view, labels, criterion, sel),
            Backend::Binned { .. } => {
                match binned.and_then(|st| st.block_of(slot).map(|b| (st, b))) {
                    Some((st, block)) => {
                        // Lane-less features (no numeric cells) score
                        // with an empty histogram: only the categorical
                        // grouped walk runs.
                        let (hist, edges): (&[f64], &[f64]) = match &st.binned.lanes[f] {
                            Some(lane) => (st.hist(block, f), &lane.edges),
                            None => (&[], &[]),
                        };
                        best_split_on_feat_binned(&view, labels, criterion, hist, edges, sel)
                    }
                    // Untracked (small / depth-capped) node: the exact
                    // engine's direct walk is cheaper than a histogram.
                    None => best_split_on_feat_with(&view, labels, criterion, sel),
                }
            }
        }
    };

    let results: Vec<Option<ScoredSplit>> = if feature_threads > 1 && ds.n_features() > 1 {
        parallel_map_scratch(
            (0..ds.n_features()).collect(),
            feature_threads,
            Scratch::new,
            |f, sel| select(f, sel),
        )
    } else {
        (0..ds.n_features())
            .map(|f| select(f, selection))
            .collect()
    };

    let mut best: Option<(usize, ScoredSplit)> = None;
    for (f, r) in results.into_iter().enumerate() {
        if let Some(s) = r {
            let better = match &best {
                None => true,
                Some((_, b)) => s.score > b.score,
            };
            if better {
                best = Some((f, s));
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::column::Column;
    use crate::data::interner::Interner;
    use crate::data::value::Value;

    fn xor_dataset() -> Dataset {
        // Labels = XOR of two binary numeric features: needs depth 3.
        let mut f0 = Vec::new();
        let mut f1 = Vec::new();
        let mut ids = Vec::new();
        for a in 0..2 {
            for b in 0..2 {
                for _ in 0..10 {
                    f0.push(Value::Num(a as f64));
                    f1.push(Value::Num(b as f64));
                    ids.push((a ^ b) as u16);
                }
            }
        }
        Dataset::new(
            "xor",
            vec![Column::new("f0", f0), Column::new("f1", f1)],
            Labels::Class { ids, n_classes: 2 },
            Interner::new(),
        )
        .unwrap()
    }

    #[test]
    fn learns_xor_exactly() {
        let ds = xor_dataset();
        let tree = fit_rows(&ds, &(0..40).collect::<Vec<_>>(), &TrainConfig::default()).unwrap();
        assert_eq!(tree.accuracy(&ds).unwrap(), 1.0);
        assert_eq!(tree.depth, 3);
        assert_eq!(tree.n_nodes(), 7); // perfect binary tree
    }

    #[test]
    fn pure_node_stops() {
        let ds = xor_dataset();
        // All rows with label 0: (0,0) and (1,1) blocks → rows 0..10, 30..40.
        let rows: Vec<u32> = (0..10).chain(30..40).collect();
        let tree = fit_rows(&ds, &rows, &TrainConfig::default()).unwrap();
        assert_eq!(tree.n_nodes(), 1);
        assert_eq!(tree.nodes[0].label, NodeLabel::Class(0));
    }

    #[test]
    fn subset_fit_respects_membership() {
        let ds = xor_dataset();
        // Train on a strict subset; accuracy on that subset must be 1.
        let rows: Vec<u32> = (0..40).step_by(2).collect();
        let tree = fit_rows(&ds, &rows, &TrainConfig::default()).unwrap();
        assert_eq!(tree.accuracy_rows(&ds, &rows).unwrap(), 1.0);
        assert_eq!(tree.nodes[0].n_samples as usize, rows.len());
    }

    #[test]
    fn multithreaded_build_matches_sequential() {
        let spec = crate::data::synth::SynthSpec::classification("t", 1500, 8, 3);
        let ds = crate::data::synth::generate_classification(&spec, 21);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let seq = fit_rows(&ds, &rows, &TrainConfig::default()).unwrap();
        let par = fit_rows(
            &ds,
            &rows,
            &TrainConfig {
                n_threads: 4,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(seq.n_nodes(), par.n_nodes());
        assert_eq!(seq.depth, par.depth);
        // Same splits node-for-node: level-sync processing keeps ids stable.
        for (a, b) in seq.nodes.iter().zip(&par.nodes) {
            assert_eq!(a.split, b.split);
            assert_eq!(a.n_samples, b.n_samples);
        }
    }

    #[test]
    fn regression_strategies_both_learn() {
        let spec = crate::data::synth::SynthSpec::regression("r", 1200, 6);
        let ds = crate::data::synth::generate_regression(&spec, 31);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        for strategy in [RegStrategy::LabelSplit, RegStrategy::DirectSse] {
            let tree = fit_rows(
                &ds,
                &rows,
                &TrainConfig {
                    reg_strategy: strategy,
                    ..Default::default()
                },
            )
            .unwrap();
            let (mae, rmse) = tree.regression_error(&ds, &rows).unwrap();
            // Training error of a full tree should be near the noise floor.
            assert!(rmse < 3.0, "{strategy:?}: rmse={rmse}");
            assert!(mae <= rmse + 1e-12);
        }
    }

    #[test]
    fn sorted_lists_stay_sorted_down_the_tree() {
        // Production path (maintained arena lists, skipped stats passes,
        // bitmask partition) must produce the same tree as the oracle
        // generic engine that recomputes everything from the raw column.
        let mut spec = crate::data::synth::SynthSpec::classification("t", 800, 5, 2);
        spec.cat_frac = 0.3;
        spec.missing_frac = 0.05;
        let ds = crate::data::synth::generate_classification(&spec, 5);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let t1 = fit_rows(&ds, &rows, &TrainConfig::default()).unwrap();
        let t2 = fit_rows(
            &ds,
            &rows,
            &TrainConfig {
                backend: Backend::Generic,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(t1.n_nodes(), t2.n_nodes());
        for (a, b) in t1.nodes.iter().zip(&t2.nodes) {
            assert_eq!(a.split, b.split);
        }
    }

    #[test]
    fn arena_never_grows_after_root() {
        let mut spec = crate::data::synth::SynthSpec::classification("t", 1200, 6, 3);
        spec.cat_frac = 0.3;
        spec.missing_frac = 0.05;
        let ds = crate::data::synth::generate_classification(&spec, 9);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let (tree, stats) =
            fit_rows_with_stats(&ds, &rows, &TrainConfig::default(), None).unwrap();
        assert!(tree.n_nodes() > 1);
        assert!(stats.bytes_at_root > 0);
        // Zero per-node heap allocation for row/value/label lists: the
        // arena footprint is constant from root to finish.
        assert_eq!(stats.peak_bytes, stats.bytes_at_root);
        assert_eq!(stats.final_bytes, stats.bytes_at_root);
    }

    #[test]
    fn label_override_matches_in_dataset_labels() {
        // Fitting against an override that equals the dataset's own
        // labels must build the identical tree (DirectSse path), and the
        // label-split strategy is rejected for overrides (the cached
        // by-target order reflects the dataset's labels).
        let spec = crate::data::synth::SynthSpec::regression("lo", 600, 5);
        let ds = crate::data::synth::generate_regression(&spec, 47);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let cfg = TrainConfig {
            reg_strategy: RegStrategy::DirectSse,
            ..Default::default()
        };
        let direct = fit_rows(&ds, &rows, &cfg).unwrap();
        let over = ds.labels.clone();
        let via_override = fit_rows_with_labels(&ds, &rows, &cfg, &over).unwrap();
        assert_eq!(direct.n_nodes(), via_override.n_nodes());
        for (a, b) in direct.nodes.iter().zip(&via_override.nodes) {
            assert_eq!(a.split, b.split);
            assert_eq!(a.label, b.label);
        }
        assert!(matches!(
            fit_rows_with_labels(&ds, &rows, &TrainConfig::default(), &over),
            Err(UdtError::InvalidConfig(_))
        ));
        // Wrong-length overrides are rejected.
        let short = Labels::Reg { values: vec![0.0] };
        assert!(matches!(
            fit_rows_with_labels(&ds, &rows, &cfg, &short),
            Err(UdtError::Data(_))
        ));
    }

    #[test]
    fn regression_override_on_classification_dataset_builds_reg_tree() {
        // A classification dataset + regression residual override (the
        // logistic-boosting regime): the fitted tree is a regression tree
        // over the dataset's features, labeled by the override values.
        let spec = crate::data::synth::SynthSpec::classification("loc", 400, 4, 2);
        let ds = crate::data::synth::generate_classification(&spec, 53);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let residuals: Vec<f64> = (0..ds.n_rows())
            .map(|r| ds.labels.class(r) as f64 - 0.5)
            .collect();
        let over = Labels::Reg { values: residuals };
        let cfg = TrainConfig {
            reg_strategy: RegStrategy::DirectSse,
            max_depth: 4,
            ..Default::default()
        };
        let tree = fit_rows_with_labels(&ds, &rows, &cfg, &over).unwrap();
        assert_eq!(tree.task, crate::data::dataset::TaskKind::Regression);
        assert!(tree.nodes[0].label.as_value().is_some());
        assert!(tree.depth <= 4);
    }

    #[test]
    fn masked_features_are_never_split_on() {
        let spec = crate::data::synth::SynthSpec::classification("t", 500, 6, 2);
        let ds = crate::data::synth::generate_classification(&spec, 13);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let active = vec![true, false, true, false, false, true];
        let tree = fit_rows_masked(&ds, &rows, &TrainConfig::default(), Some(&active)).unwrap();
        for n in &tree.nodes {
            if let Some(s) = &n.split {
                assert!(active[s.feature], "split on masked feature {}", s.feature);
            }
        }
        // Wrong-arity masks are rejected.
        assert!(matches!(
            fit_rows_masked(&ds, &rows, &TrainConfig::default(), Some(&[true])),
            Err(UdtError::InvalidConfig(_))
        ));
    }

    #[test]
    fn mask_equivalent_to_blanked_columns() {
        // Masking a feature must build the same tree as materializing the
        // dataset with that column all-Missing (the pre-arena semantics).
        let mut spec = crate::data::synth::SynthSpec::classification("t", 400, 5, 2);
        spec.cat_frac = 0.2;
        let ds = crate::data::synth::generate_classification(&spec, 17);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let active = vec![true, true, false, true, false];

        let masked = fit_rows_masked(&ds, &rows, &TrainConfig::default(), Some(&active)).unwrap();

        let mut columns = ds.columns.clone();
        for (f, col) in columns.iter_mut().enumerate() {
            if !active[f] {
                let blank = Column::new(col.name.clone(), vec![Value::Missing; col.len()]);
                *col = blank;
            }
        }
        let blanked = Dataset::new(
            ds.name.clone(),
            columns,
            ds.labels.clone(),
            std::sync::Arc::clone(&ds.interner),
        )
        .unwrap();
        let oracle = fit_rows(&blanked, &rows, &TrainConfig::default()).unwrap();

        assert_eq!(masked.n_nodes(), oracle.n_nodes());
        for (a, b) in masked.nodes.iter().zip(&oracle.nodes) {
            assert_eq!(a.split, b.split);
            assert_eq!(a.n_samples, b.n_samples);
        }
    }

    #[test]
    fn binned_accumulates_only_the_smaller_child() {
        // One numeric feature, 50 distinct values × 4 rows each; class 0
        // for values < 10 (40 rows), class 1 otherwise (160 rows). The
        // root splits at Le(9) into two pure children, so the whole fit
        // is: accumulate the root (200 row entries), then accumulate
        // only the 40-row child and derive the 160-row sibling by
        // parent-minus-sibling subtraction — the 160 rows are never
        // walked.
        let cells: Vec<Value> = (0..200).map(|i| Value::Num((i / 4) as f64)).collect();
        let ids: Vec<u16> = (0..200).map(|i| ((i / 4) >= 10) as u16).collect();
        let ds = Dataset::new(
            "witness",
            vec![Column::new("f", cells)],
            Labels::Class { ids, n_classes: 2 },
            Interner::new(),
        )
        .unwrap();
        let rows: Vec<u32> = (0..200).collect();
        let cfg = TrainConfig {
            backend: Backend::Binned { max_bins: 64 },
            max_depth: 3,
            ..Default::default()
        };
        let (tree, stats) = fit_rows_with_stats(&ds, &rows, &cfg, None).unwrap();
        assert_eq!(tree.accuracy(&ds).unwrap(), 1.0);
        assert_eq!(tree.n_nodes(), 3);
        // Root (200) + smaller child (40): subtraction spares the large
        // sibling. A both-children accumulation would read 360.
        assert_eq!(stats.hist_rows_accumulated, 240);
        assert!(stats.hist_scratch_bytes > 0);
    }

    #[test]
    fn binned_backend_validates_config() {
        let ds = xor_dataset();
        let rows: Vec<u32> = (0..40).collect();
        for bad in [0usize, 1, 100_000] {
            let cfg = TrainConfig {
                backend: Backend::Binned { max_bins: bad },
                ..Default::default()
            };
            assert!(
                matches!(fit_rows(&ds, &rows, &cfg), Err(UdtError::InvalidConfig(_))),
                "max_bins {bad} accepted"
            );
        }
        // Regression + label-split re-labels every node, which defeats
        // histogram subtraction — rejected; DirectSse is the binned path.
        let spec = crate::data::synth::SynthSpec::regression("r", 120, 3);
        let rds = crate::data::synth::generate_regression(&spec, 3);
        let rrows: Vec<u32> = (0..rds.n_rows() as u32).collect();
        let cfg = TrainConfig {
            backend: Backend::Binned { max_bins: 32 },
            reg_strategy: RegStrategy::LabelSplit,
            ..Default::default()
        };
        assert!(matches!(
            fit_rows(&rds, &rrows, &cfg),
            Err(UdtError::InvalidConfig(_))
        ));
        let cfg = TrainConfig {
            backend: Backend::Binned { max_bins: 32 },
            reg_strategy: RegStrategy::DirectSse,
            ..Default::default()
        };
        assert!(fit_rows(&rds, &rrows, &cfg).is_ok());
    }

    #[test]
    fn binned_regression_matches_direct_sse_on_dyadic_targets() {
        // Quarter-rounded targets make every histogram, prefix and
        // subtraction sum exactly representable, so the binned engine
        // must reproduce the exact DirectSse tree bit-for-bit even
        // though it sums in a different order.
        let mut spec = crate::data::synth::SynthSpec::regression("rb", 400, 4);
        spec.numeric_cardinality = 16;
        let ds0 = crate::data::synth::generate_regression(&spec, 19);
        let values: Vec<f64> = (0..ds0.n_rows())
            .map(|r| (ds0.labels.target(r) * 4.0).round() / 4.0)
            .collect();
        let ds = Dataset::new(
            "rb",
            ds0.columns.clone(),
            Labels::Reg { values },
            std::sync::Arc::clone(&ds0.interner),
        )
        .unwrap();
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let exact = fit_rows(
            &ds,
            &rows,
            &TrainConfig {
                reg_strategy: RegStrategy::DirectSse,
                ..Default::default()
            },
        )
        .unwrap();
        let binned = fit_rows(
            &ds,
            &rows,
            &TrainConfig {
                backend: Backend::Binned { max_bins: 16 },
                reg_strategy: RegStrategy::DirectSse,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(ds.binned_index(16).all_exact());
        assert_eq!(exact.n_nodes(), binned.n_nodes());
        for (a, b) in exact.nodes.iter().zip(&binned.nodes) {
            assert_eq!(a.split, b.split);
            assert_eq!(a.label, b.label);
            assert_eq!(a.n_samples, b.n_samples);
        }
    }
}
