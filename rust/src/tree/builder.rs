//! UDT tree construction (paper Algorithm 5).
//!
//! Numeric values of every feature are sorted **once** at the root
//! (`O(K·M log M)`); every `split_node` then runs Superfast Selection per
//! feature in `O(M_node + N·C)` and partitions the sorted row lists with
//! an order-preserving filter (`filter_sorted_nums`), so sortedness is
//! maintained for free down the whole tree. Regression nodes additionally
//! maintain rows sorted by target for the Algorithm 6 label split.
//!
//! Hot-path engineering on top of the paper's description (§Perf in
//! EXPERIMENTS.md):
//! * sorted lists carry `(row, value)` in parallel arrays, so the prefix
//!   walk streams values sequentially instead of chasing `Value` cells;
//! * node class counts are computed once per node and reused by every
//!   all-numeric column, eliminating the per-feature statistics pass for
//!   clean columns;
//! * partitioning marks positive rows in a reusable bitmask (L2-resident)
//!   and filters every sorted list by bit tests instead of re-evaluating
//!   the predicate against the 16-byte column cells.
//!
//! The frontier is processed level-synchronously; with `n_threads > 1`
//! nodes of a level run on a worker pool (and small frontiers fall back
//! to feature-level parallelism).

use super::label_split;
use super::{Backend, Node, NodeLabel, RegStrategy, TrainConfig, Tree};
use crate::coordinator::parallel::parallel_map_scratch;
use crate::data::dataset::{Dataset, Labels, TaskKind};
use crate::selection::generic::best_split_on_feat_generic;
use crate::selection::heuristic::Criterion;
use crate::selection::split::SplitPredicate;
use crate::error::{Result, UdtError};
use crate::selection::superfast::{
    best_split_on_feat_with, FeatureView, LabelsView, Scratch, ScoredSplit,
};

/// Pending node: the row sets Algorithm 5 threads through the queue.
struct WorkItem {
    node_id: u32,
    depth: u16,
    /// All rows of this node.
    rows: Vec<u32>,
    /// Per feature: the node's numeric rows sorted ascending (`X^A`).
    sorted_num: Vec<Vec<u32>>,
    /// Per feature: values parallel to `sorted_num`.
    sorted_vals: Vec<Vec<f64>>,
    /// Per feature: the node's categorical rows grouped by category id.
    sorted_cat_rows: Vec<Vec<u32>>,
    /// Per feature: category ids parallel to `sorted_cat_rows`.
    sorted_cat_ids: Vec<Vec<u32>>,
    /// Per feature: class labels parallel to `sorted_num` (classification).
    sorted_labs: Vec<Vec<u16>>,
    /// Per feature: class labels parallel to `sorted_cat_rows`.
    sorted_cat_labs: Vec<Vec<u16>>,
    /// Regression only: the node's rows sorted ascending by target.
    sorted_labels: Vec<u32>,
}

/// Outcome of processing one node.
struct Decision {
    node_id: u32,
    depth: u16,
    label: NodeLabel,
    n_samples: u32,
    /// `Some` when the node splits.
    split: Option<SplitOutcome>,
}

struct SplitOutcome {
    predicate: SplitPredicate,
    pos: WorkPayload,
    neg: WorkPayload,
}

struct WorkPayload {
    rows: Vec<u32>,
    sorted_num: Vec<Vec<u32>>,
    sorted_vals: Vec<Vec<f64>>,
    sorted_cat_rows: Vec<Vec<u32>>,
    sorted_cat_ids: Vec<Vec<u32>>,
    sorted_labs: Vec<Vec<u16>>,
    sorted_cat_labs: Vec<Vec<u16>>,
    sorted_labels: Vec<u32>,
}

/// Per-worker scratch: selection buffers, the pseudo-label buffer for the
/// regression label-split strategy, class-count buffer, and the positive-
/// row bitmask used by partitioning.
struct BuildScratch {
    selection: Scratch,
    pseudo: Vec<u16>,
    class_counts: Vec<f64>,
    posmask: Vec<u64>,
}

impl BuildScratch {
    fn new() -> Self {
        Self {
            selection: Scratch::new(),
            pseudo: Vec::new(),
            class_counts: Vec::new(),
            posmask: Vec::new(),
        }
    }
}

/// Immutable per-fit context shared by workers.
struct FitCtx<'a> {
    ds: &'a Dataset,
    config: &'a TrainConfig,
    /// Per column: does it contain categorical/missing cells anywhere?
    col_has_nonnum: Vec<bool>,
}

/// Train a tree over `rows` of `ds`.
pub fn fit_rows(ds: &Dataset, rows: &[u32], config: &TrainConfig) -> Result<Tree> {
    if rows.is_empty() {
        return Err(UdtError::data("cannot fit on an empty row set"));
    }
    if ds.n_features() == 0 {
        return Err(UdtError::data("dataset has no features"));
    }
    if config.max_depth < 1 {
        return Err(UdtError::invalid_config("max_depth must be >= 1"));
    }

    // Root pre-sort (Algorithm 5 line 2): numeric (row, value) pairs per
    // feature, filtered to the requested row subset.
    let member = membership_mask(ds.n_rows(), rows);
    if member.iter().filter(|&&m| m).count() != rows.len() {
        return Err(UdtError::data(
            "duplicate rows in training subset (sample without replacement)",
        ));
    }
    let full = rows.len() == ds.n_rows();
    let mut sorted_num = Vec::with_capacity(ds.n_features());
    let mut sorted_vals = Vec::with_capacity(ds.n_features());
    let mut sorted_cat_rows = Vec::with_capacity(ds.n_features());
    let mut sorted_cat_ids = Vec::with_capacity(ds.n_features());
    for c in &ds.columns {
        let (r_all, v_all) = c.sorted_numeric();
        let (cr_all, ci_all) = c.sorted_categorical();
        if full {
            sorted_num.push(r_all);
            sorted_vals.push(v_all);
            sorted_cat_rows.push(cr_all);
            sorted_cat_ids.push(ci_all);
        } else {
            let mut r_f = Vec::new();
            let mut v_f = Vec::new();
            for (r, v) in r_all.into_iter().zip(v_all) {
                if member[r as usize] {
                    r_f.push(r);
                    v_f.push(v);
                }
            }
            sorted_num.push(r_f);
            sorted_vals.push(v_f);
            let mut cr_f = Vec::new();
            let mut ci_f = Vec::new();
            for (r, i) in cr_all.into_iter().zip(ci_all) {
                if member[r as usize] {
                    cr_f.push(r);
                    ci_f.push(i);
                }
            }
            sorted_cat_rows.push(cr_f);
            sorted_cat_ids.push(ci_f);
        }
    }
    // Classification: inline label arrays parallel to the sorted lists.
    let (sorted_labs, sorted_cat_labs) = match &ds.labels {
        Labels::Class { ids, .. } => (
            sorted_num
                .iter()
                .map(|l| l.iter().map(|&r| ids[r as usize]).collect())
                .collect(),
            sorted_cat_rows
                .iter()
                .map(|l| l.iter().map(|&r| ids[r as usize]).collect())
                .collect(),
        ),
        Labels::Reg { .. } => (
            vec![Vec::new(); ds.n_features()],
            vec![Vec::new(); ds.n_features()],
        ),
    };
    let sorted_labels = match &ds.labels {
        Labels::Reg { values } => {
            let mut idx = rows.to_vec();
            idx.sort_by(|&a, &b| {
                values[a as usize]
                    .partial_cmp(&values[b as usize])
                    .unwrap()
                    .then(a.cmp(&b))
            });
            idx
        }
        Labels::Class { .. } => Vec::new(),
    };

    let ctx = FitCtx {
        ds,
        config,
        col_has_nonnum: ds
            .columns
            .iter()
            .map(|c| {
                let s = c.stats();
                s.n_cat + s.n_missing > 0
            })
            .collect(),
    };

    let mut tree = Tree {
        nodes: Vec::new(),
        task: ds.task(),
        n_features: ds.n_features(),
        depth: 0,
    };
    tree.nodes.push(placeholder_node()); // root slot

    let mut frontier = vec![WorkItem {
        node_id: Tree::ROOT,
        depth: 1,
        rows: rows.to_vec(),
        sorted_num,
        sorted_vals,
        sorted_cat_rows,
        sorted_cat_ids,
        sorted_labs,
        sorted_cat_labs,
        sorted_labels,
    }];

    let n_threads = crate::coordinator::parallel::effective_threads(config.n_threads).max(1);

    while !frontier.is_empty() {
        let items = std::mem::take(&mut frontier);
        // Frontier-level parallelism; small frontiers instead parallelize
        // the per-node selection across features.
        let feature_threads = if items.len() < n_threads { n_threads } else { 1 };
        let decisions: Vec<Decision> = parallel_map_scratch(
            items,
            n_threads,
            BuildScratch::new,
            |item, scratch| process_node(&ctx, item, scratch, feature_threads),
        );

        for d in decisions {
            {
                let node = &mut tree.nodes[d.node_id as usize];
                node.label = d.label;
                node.n_samples = d.n_samples;
                node.depth = d.depth;
            }
            tree.depth = tree.depth.max(d.depth);
            if let Some(s) = d.split {
                let pos_id = tree.nodes.len() as u32;
                let neg_id = pos_id + 1;
                tree.nodes[d.node_id as usize].split = Some(s.predicate);
                tree.nodes[d.node_id as usize].children = Some((pos_id, neg_id));
                tree.nodes.push(placeholder_node());
                tree.nodes.push(placeholder_node());
                frontier.push(WorkItem {
                    node_id: pos_id,
                    depth: d.depth + 1,
                    rows: s.pos.rows,
                    sorted_num: s.pos.sorted_num,
                    sorted_vals: s.pos.sorted_vals,
                    sorted_cat_rows: s.pos.sorted_cat_rows,
                    sorted_cat_ids: s.pos.sorted_cat_ids,
                    sorted_labs: s.pos.sorted_labs,
                    sorted_cat_labs: s.pos.sorted_cat_labs,
                    sorted_labels: s.pos.sorted_labels,
                });
                frontier.push(WorkItem {
                    node_id: neg_id,
                    depth: d.depth + 1,
                    rows: s.neg.rows,
                    sorted_num: s.neg.sorted_num,
                    sorted_vals: s.neg.sorted_vals,
                    sorted_cat_rows: s.neg.sorted_cat_rows,
                    sorted_cat_ids: s.neg.sorted_cat_ids,
                    sorted_labs: s.neg.sorted_labs,
                    sorted_cat_labs: s.neg.sorted_cat_labs,
                    sorted_labels: s.neg.sorted_labels,
                });
            }
        }
    }
    Ok(tree)
}

fn placeholder_node() -> Node {
    Node {
        split: None,
        children: None,
        label: NodeLabel::Class(0),
        n_samples: 0,
        depth: 0,
    }
}

fn membership_mask(n: usize, rows: &[u32]) -> Vec<bool> {
    let mut mask = vec![false; n];
    for &r in rows {
        mask[r as usize] = true;
    }
    mask
}

/// Paper's `split_node`: label the node, pick the best split, partition.
fn process_node(
    ctx: &FitCtx,
    item: WorkItem,
    scratch: &mut BuildScratch,
    feature_threads: usize,
) -> Decision {
    let ds = ctx.ds;
    let config = ctx.config;
    let (label, pure, reg_stats) = node_label(ds, &item.rows, &mut scratch.class_counts);
    let n_samples = item.rows.len() as u32;
    let mut decision = Decision {
        node_id: item.node_id,
        depth: item.depth,
        label,
        n_samples,
        split: None,
    };

    // Stopping rules (the "full-fledged" tree only stops on hard limits).
    if pure
        || item.depth as usize >= config.max_depth
        || item.rows.len() < config.min_samples_split.max(2)
    {
        return decision;
    }

    let BuildScratch {
        selection,
        pseudo,
        class_counts,
        posmask,
    } = scratch;

    // Build the label view. Regression with the paper's strategy first
    // binarizes the node's targets at the best SSE threshold
    // (Algorithm 6), then proceeds as 2-class classification.
    let mut pseudo_counts = [0.0f64; 2];
    let (labels_view, criterion): (LabelsView, Criterion) = match &ds.labels {
        Labels::Class { ids, n_classes } => (
            LabelsView::Class {
                ids,
                n_classes: *n_classes,
            },
            config.criterion_for(TaskKind::Classification),
        ),
        Labels::Reg { values } => match config.reg_strategy {
            RegStrategy::DirectSse => (LabelsView::Reg { values }, Criterion::Sse),
            RegStrategy::LabelSplit => {
                let Some((threshold, _)) =
                    label_split::best_label_split(&item.sorted_labels, values)
                else {
                    return decision; // constant labels — leaf
                };
                if pseudo.len() < ds.n_rows() {
                    pseudo.resize(ds.n_rows(), 0);
                }
                label_split::binarize(&item.rows, values, threshold, pseudo);
                for &r in &item.rows {
                    pseudo_counts[pseudo[r as usize] as usize] += 1.0;
                }
                (
                    LabelsView::Class {
                        ids: &*pseudo,
                        n_classes: 2,
                    },
                    Criterion::Class(config.criterion),
                )
            }
        },
    };
    // Class counts aligned with the labels view (pseudo-labels for the
    // regression label-split strategy).
    let counts_for_view: &[f64] = match (&ds.labels, config.reg_strategy) {
        (Labels::Class { .. }, _) => class_counts,
        (Labels::Reg { .. }, RegStrategy::LabelSplit) => &pseudo_counts,
        (Labels::Reg { .. }, RegStrategy::DirectSse) => &[],
    };

    // Minimum-gain test reference point.
    let baseline = baseline_score(&labels_view, criterion, &item.rows);

    // Best split across features (Algorithm 4 best_split_on_all_feats).
    let best = best_across_features(
        ctx,
        &item,
        &labels_view,
        counts_for_view,
        reg_stats,
        criterion,
        selection,
        feature_threads,
    );

    let Some((feature, best)) = best else {
        return decision;
    };
    if !(best.score - baseline > config.min_gain) {
        return decision; // no informative split
    }

    let predicate = SplitPredicate {
        feature,
        op: best.op,
    };

    // eval_and_split + filter_sorted_nums: evaluate the predicate once per
    // node row, marking positives in the bitmask; every sorted list (and
    // the sorted-labels list) then filters by bit test.
    let words = ds.n_rows().div_ceil(64);
    if posmask.len() < words {
        posmask.resize(words, 0);
    }
    let col = &ds.columns[feature];
    let mut rows_pos = Vec::new();
    let mut rows_neg = Vec::new();
    for &r in &item.rows {
        if predicate.op.eval(col.get(r as usize)) {
            posmask[(r >> 6) as usize] |= 1u64 << (r & 63);
            rows_pos.push(r);
        } else {
            rows_neg.push(r);
        }
    }
    debug_assert!(!rows_pos.is_empty() && !rows_neg.is_empty());

    let in_pos = |r: u32| posmask[(r >> 6) as usize] >> (r & 63) & 1 == 1;
    let mut pos_sorted = Vec::with_capacity(ds.n_features());
    let mut neg_sorted = Vec::with_capacity(ds.n_features());
    let mut pos_vals = Vec::with_capacity(ds.n_features());
    let mut neg_vals = Vec::with_capacity(ds.n_features());
    // Positive fraction of node rows — used to pre-size the filtered
    // lists so pushes never reallocate.
    let pos_frac = rows_pos.len() as f64 / item.rows.len() as f64;
    let cap = |len: usize, frac: f64| ((len as f64 * frac) as usize + 16).min(len);
    let has_labs = !item.sorted_labs.is_empty() && !item.sorted_labs[0].is_empty()
        || matches!(&ds.labels, Labels::Class { .. });
    let mut pos_labs = Vec::with_capacity(ds.n_features());
    let mut neg_labs = Vec::with_capacity(ds.n_features());
    for ((f_rows, f_vals), f_labs) in item
        .sorted_num
        .iter()
        .zip(&item.sorted_vals)
        .zip(&item.sorted_labs)
    {
        let mut pr = Vec::with_capacity(cap(f_rows.len(), pos_frac));
        let mut pv = Vec::with_capacity(cap(f_rows.len(), pos_frac));
        let mut pl = Vec::with_capacity(if has_labs { cap(f_rows.len(), pos_frac) } else { 0 });
        let mut nr = Vec::with_capacity(cap(f_rows.len(), 1.0 - pos_frac));
        let mut nv = Vec::with_capacity(cap(f_rows.len(), 1.0 - pos_frac));
        let mut nl = Vec::with_capacity(if has_labs { cap(f_rows.len(), 1.0 - pos_frac) } else { 0 });
        if has_labs {
            for ((&r, &v), &y) in f_rows.iter().zip(f_vals).zip(f_labs) {
                if in_pos(r) {
                    pr.push(r);
                    pv.push(v);
                    pl.push(y);
                } else {
                    nr.push(r);
                    nv.push(v);
                    nl.push(y);
                }
            }
        } else {
            for (&r, &v) in f_rows.iter().zip(f_vals) {
                if in_pos(r) {
                    pr.push(r);
                    pv.push(v);
                } else {
                    nr.push(r);
                    nv.push(v);
                }
            }
        }
        pos_sorted.push(pr);
        pos_vals.push(pv);
        pos_labs.push(pl);
        neg_sorted.push(nr);
        neg_vals.push(nv);
        neg_labs.push(nl);
    }
    let mut pos_cat_rows = Vec::with_capacity(ds.n_features());
    let mut neg_cat_rows = Vec::with_capacity(ds.n_features());
    let mut pos_cat_ids = Vec::with_capacity(ds.n_features());
    let mut neg_cat_ids = Vec::with_capacity(ds.n_features());
    let mut pos_cat_labs = Vec::with_capacity(ds.n_features());
    let mut neg_cat_labs = Vec::with_capacity(ds.n_features());
    for ((f_rows, f_ids), f_labs) in item
        .sorted_cat_rows
        .iter()
        .zip(&item.sorted_cat_ids)
        .zip(&item.sorted_cat_labs)
    {
        let mut pr = Vec::with_capacity(cap(f_rows.len(), pos_frac));
        let mut pi = Vec::with_capacity(cap(f_rows.len(), pos_frac));
        let mut pl = Vec::with_capacity(if has_labs { cap(f_rows.len(), pos_frac) } else { 0 });
        let mut nr = Vec::with_capacity(cap(f_rows.len(), 1.0 - pos_frac));
        let mut ni = Vec::with_capacity(cap(f_rows.len(), 1.0 - pos_frac));
        let mut nl = Vec::with_capacity(if has_labs { cap(f_rows.len(), 1.0 - pos_frac) } else { 0 });
        if has_labs {
            for ((&r, &id), &y) in f_rows.iter().zip(f_ids).zip(f_labs) {
                if in_pos(r) {
                    pr.push(r);
                    pi.push(id);
                    pl.push(y);
                } else {
                    nr.push(r);
                    ni.push(id);
                    nl.push(y);
                }
            }
        } else {
            for (&r, &id) in f_rows.iter().zip(f_ids) {
                if in_pos(r) {
                    pr.push(r);
                    pi.push(id);
                } else {
                    nr.push(r);
                    ni.push(id);
                }
            }
        }
        pos_cat_rows.push(pr);
        pos_cat_ids.push(pi);
        pos_cat_labs.push(pl);
        neg_cat_rows.push(nr);
        neg_cat_ids.push(ni);
        neg_cat_labs.push(nl);
    }
    let (pos_labels, neg_labels) = if item.sorted_labels.is_empty() {
        (Vec::new(), Vec::new())
    } else {
        item.sorted_labels.iter().partition(|&&r| in_pos(r))
    };

    // Clear only the bits we set (the mask is worker-reused).
    for &r in &rows_pos {
        posmask[(r >> 6) as usize] &= !(1u64 << (r & 63));
    }

    decision.split = Some(SplitOutcome {
        predicate,
        pos: WorkPayload {
            rows: rows_pos,
            sorted_num: pos_sorted,
            sorted_vals: pos_vals,
            sorted_cat_rows: pos_cat_rows,
            sorted_cat_ids: pos_cat_ids,
            sorted_labs: pos_labs,
            sorted_cat_labs: pos_cat_labs,
            sorted_labels: pos_labels,
        },
        neg: WorkPayload {
            rows: rows_neg,
            sorted_num: neg_sorted,
            sorted_vals: neg_vals,
            sorted_cat_rows: neg_cat_rows,
            sorted_cat_ids: neg_cat_ids,
            sorted_labs: neg_labs,
            sorted_cat_labs: neg_cat_labs,
            sorted_labels: neg_labels,
        },
    });
    decision
}

/// Majority class (ties → smallest id) or mean target; plus purity flag
/// and regression `(n, sum)` stats. Class counts land in `counts_buf`.
fn node_label(
    ds: &Dataset,
    rows: &[u32],
    counts_buf: &mut Vec<f64>,
) -> (NodeLabel, bool, Option<(f64, f64)>) {
    match &ds.labels {
        Labels::Class { ids, n_classes } => {
            counts_buf.clear();
            counts_buf.resize(*n_classes, 0.0);
            for &r in rows {
                counts_buf[ids[r as usize] as usize] += 1.0;
            }
            let (best, &max) = counts_buf
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap().then(b.0.cmp(&a.0)))
                .unwrap();
            (
                NodeLabel::Class(best as u16),
                max as usize == rows.len(),
                None,
            )
        }
        Labels::Reg { values } => {
            let n = rows.len() as f64;
            let sum: f64 = rows.iter().map(|&r| values[r as usize]).sum();
            let mean = sum / n;
            let pure = rows
                .iter()
                .all(|&r| (values[r as usize] - mean).abs() < 1e-12);
            (NodeLabel::Value(mean), pure, Some((n, sum)))
        }
    }
}

/// Score of leaving the node unsplit, under the same criterion — the
/// reference point for the minimum-gain test.
fn baseline_score(labels: &LabelsView, criterion: Criterion, rows: &[u32]) -> f64 {
    match (labels, criterion) {
        (LabelsView::Class { ids, n_classes }, Criterion::Class(crit)) => {
            let mut counts = vec![0.0f64; *n_classes];
            for &r in rows {
                counts[ids[r as usize] as usize] += 1.0;
            }
            let zeros = vec![0.0f64; *n_classes];
            crit.score(&counts, &zeros)
        }
        (LabelsView::Reg { values }, Criterion::Sse) => {
            let n = rows.len() as f64;
            let sum: f64 = rows.iter().map(|&r| values[r as usize]).sum();
            sum * sum / n
        }
        _ => unreachable!("criterion/labels kind mismatch"),
    }
}

#[allow(clippy::too_many_arguments)]
fn best_across_features(
    ctx: &FitCtx,
    item: &WorkItem,
    labels: &LabelsView,
    class_counts: &[f64],
    reg_stats: Option<(f64, f64)>,
    criterion: Criterion,
    selection: &mut Scratch,
    feature_threads: usize,
) -> Option<(usize, ScoredSplit)> {
    let ds = ctx.ds;
    let select = |f: usize, sel: &mut Scratch| -> Option<ScoredSplit> {
        let view = FeatureView {
            feature: f,
            col: &ds.columns[f],
            rows: &item.rows,
            sorted_num: &item.sorted_num[f],
            sorted_vals: &item.sorted_vals[f],
            class_counts,
            reg_stats,
            col_has_nonnum: ctx.col_has_nonnum[f],
            sorted_cat_rows: &item.sorted_cat_rows[f],
            sorted_cat_ids: &item.sorted_cat_ids[f],
            cat_lists_valid: true,
            sorted_labs: &item.sorted_labs[f],
            sorted_cat_labs: &item.sorted_cat_labs[f],
        };
        match &ctx.config.backend {
            Backend::Superfast => best_split_on_feat_with(&view, labels, criterion, sel),
            Backend::Generic => best_split_on_feat_generic(&view, labels, criterion),
            Backend::Xla(xla) => xla.best_split_on_feat(&view, labels, criterion, sel),
        }
    };

    let results: Vec<Option<ScoredSplit>> = if feature_threads > 1 && ds.n_features() > 1 {
        parallel_map_scratch(
            (0..ds.n_features()).collect(),
            feature_threads,
            Scratch::new,
            |f, sel| select(f, sel),
        )
    } else {
        (0..ds.n_features())
            .map(|f| select(f, selection))
            .collect()
    };

    let mut best: Option<(usize, ScoredSplit)> = None;
    for (f, r) in results.into_iter().enumerate() {
        if let Some(s) = r {
            let better = match &best {
                None => true,
                Some((_, b)) => s.score > b.score,
            };
            if better {
                best = Some((f, s));
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::column::Column;
    use crate::data::interner::Interner;
    use crate::data::value::Value;

    fn xor_dataset() -> Dataset {
        // Labels = XOR of two binary numeric features: needs depth 3.
        let mut f0 = Vec::new();
        let mut f1 = Vec::new();
        let mut ids = Vec::new();
        for a in 0..2 {
            for b in 0..2 {
                for _ in 0..10 {
                    f0.push(Value::Num(a as f64));
                    f1.push(Value::Num(b as f64));
                    ids.push((a ^ b) as u16);
                }
            }
        }
        Dataset::new(
            "xor",
            vec![Column::new("f0", f0), Column::new("f1", f1)],
            Labels::Class { ids, n_classes: 2 },
            Interner::new(),
        )
        .unwrap()
    }

    #[test]
    fn learns_xor_exactly() {
        let ds = xor_dataset();
        let tree = fit_rows(&ds, &(0..40).collect::<Vec<_>>(), &TrainConfig::default()).unwrap();
        assert_eq!(tree.accuracy(&ds).unwrap(), 1.0);
        assert_eq!(tree.depth, 3);
        assert_eq!(tree.n_nodes(), 7); // perfect binary tree
    }

    #[test]
    fn pure_node_stops() {
        let ds = xor_dataset();
        // All rows with label 0: (0,0) and (1,1) blocks → rows 0..10, 30..40.
        let rows: Vec<u32> = (0..10).chain(30..40).collect();
        let tree = fit_rows(&ds, &rows, &TrainConfig::default()).unwrap();
        assert_eq!(tree.n_nodes(), 1);
        assert_eq!(tree.nodes[0].label, NodeLabel::Class(0));
    }

    #[test]
    fn subset_fit_respects_membership() {
        let ds = xor_dataset();
        // Train on a strict subset; accuracy on that subset must be 1.
        let rows: Vec<u32> = (0..40).step_by(2).collect();
        let tree = fit_rows(&ds, &rows, &TrainConfig::default()).unwrap();
        assert_eq!(tree.accuracy_rows(&ds, &rows).unwrap(), 1.0);
        assert_eq!(tree.nodes[0].n_samples as usize, rows.len());
    }

    #[test]
    fn multithreaded_build_matches_sequential() {
        let spec = crate::data::synth::SynthSpec::classification("t", 1500, 8, 3);
        let ds = crate::data::synth::generate_classification(&spec, 21);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let seq = fit_rows(&ds, &rows, &TrainConfig::default()).unwrap();
        let par = fit_rows(
            &ds,
            &rows,
            &TrainConfig {
                n_threads: 4,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(seq.n_nodes(), par.n_nodes());
        assert_eq!(seq.depth, par.depth);
        // Same splits node-for-node: level-sync processing keeps ids stable.
        for (a, b) in seq.nodes.iter().zip(&par.nodes) {
            assert_eq!(a.split, b.split);
            assert_eq!(a.n_samples, b.n_samples);
        }
    }

    #[test]
    fn regression_strategies_both_learn() {
        let spec = crate::data::synth::SynthSpec::regression("r", 1200, 6);
        let ds = crate::data::synth::generate_regression(&spec, 31);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        for strategy in [RegStrategy::LabelSplit, RegStrategy::DirectSse] {
            let tree = fit_rows(
                &ds,
                &rows,
                &TrainConfig {
                    reg_strategy: strategy,
                    ..Default::default()
                },
            )
            .unwrap();
            let (mae, rmse) = tree.regression_error(&ds, &rows).unwrap();
            // Training error of a full tree should be near the noise floor.
            assert!(rmse < 3.0, "{strategy:?}: rmse={rmse}");
            assert!(mae <= rmse + 1e-12);
        }
    }

    #[test]
    fn sorted_lists_stay_sorted_down_the_tree() {
        // Production path (filtered sorted lists, skipped stats passes,
        // bitmask partition) must produce the same tree as the oracle
        // generic engine that recomputes everything from the raw column.
        let mut spec = crate::data::synth::SynthSpec::classification("t", 800, 5, 2);
        spec.cat_frac = 0.3;
        spec.missing_frac = 0.05;
        let ds = crate::data::synth::generate_classification(&spec, 5);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let t1 = fit_rows(&ds, &rows, &TrainConfig::default()).unwrap();
        let t2 = fit_rows(
            &ds,
            &rows,
            &TrainConfig {
                backend: Backend::Generic,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(t1.n_nodes(), t2.n_nodes());
        for (a, b) in t1.nodes.iter().zip(&t2.nodes) {
            assert_eq!(a.split, b.split);
        }
    }
}
