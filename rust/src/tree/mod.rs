//! Ultrafast Decision Tree (paper §3): CART driven by Superfast Selection
//! with an amortized pre-sort, Training-Only-Once Tuning and pruning.

pub mod boost;
pub mod builder;
pub mod forest;
pub mod frontier;
pub mod label_split;
pub mod predict;
pub mod prune;
pub mod serialize;
pub mod sharded;
pub mod tuning;

use crate::data::dataset::{Dataset, TaskKind};
use crate::error::{Result, UdtError};
use crate::selection::heuristic::{ClassCriterion, Criterion};
use crate::selection::split::SplitPredicate;

/// Which selection engine drives the builder.
#[derive(Debug, Clone, Default)]
pub enum Backend {
    /// Superfast Selection (paper Algorithm 2/4) — the default.
    #[default]
    Superfast,
    /// The `O(M·N)` generic baseline (paper Algorithm 1); for benches.
    Generic,
    /// AOT-compiled JAX/Pallas kernels through PJRT for large nodes
    /// (binned; falls back to native for small nodes — see
    /// [`crate::runtime::xla_split`]).
    Xla(std::sync::Arc<crate::runtime::xla_split::XlaSelection>),
    /// Histogram-binned selection over dataset-level quantile bin lanes
    /// (see [`crate::selection::binned`]): `O(rows)` accumulate +
    /// `O(max_bins)` scan per node per feature, with parent-minus-sibling
    /// subtraction so only the smaller child of every split is
    /// accumulated. Exact-equivalent to Superfast whenever every column's
    /// distinct numeric count ≤ `max_bins`; approximate (bin-edge
    /// candidates only) beyond that. Nodes smaller than `max_bins` rows
    /// fall back to the exact engine, where the direct walk is cheaper
    /// than a histogram scan.
    Binned {
        /// Bin budget per column; must satisfy [`validate_max_bins`].
        max_bins: usize,
    },
}

/// Validate a binned-backend bin budget: at least 2 (a one-bin lane
/// cannot host a split on both sides) and at most 65535 (the `u16`
/// bin-id lane limit).
pub fn validate_max_bins(max_bins: usize) -> Result<()> {
    if max_bins < 2 {
        return Err(UdtError::invalid_config(format!(
            "max_bins must be >= 2, got {max_bins}"
        )));
    }
    if max_bins > 65535 {
        return Err(UdtError::invalid_config(format!(
            "max_bins must be <= 65535 (u16 bin-id lane limit), got {max_bins}"
        )));
    }
    Ok(())
}

/// How regression nodes select feature splits.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RegStrategy {
    /// Paper Algorithm 6: binarize the node's labels at the best SSE
    /// threshold, then run 2-class Superfast Selection.
    #[default]
    LabelSplit,
    /// Classic CART: score feature splits directly with the SSE criterion.
    DirectSse,
}

/// Training configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Classification criterion (ignored for regression).
    pub criterion: ClassCriterion,
    /// Maximum tree depth (`usize::MAX` = unlimited, the paper's
    /// "full-fledged" tree).
    pub max_depth: usize,
    /// Minimum node size eligible for splitting.
    pub min_samples_split: usize,
    /// Minimum heuristic gain over the parent to accept a split. The
    /// default (`-1e-9`) accepts zero-gain splits — the paper's
    /// "full-fledged tree without any limitation", which lets the tree
    /// work through locally-uninformative splits (e.g. XOR patterns) and
    /// reproduces the paper's large full-tree node counts; termination is
    /// still guaranteed because children are strictly smaller. Set a
    /// small positive value to require strict improvement.
    pub min_gain: f64,
    /// Selection engine.
    pub backend: Backend,
    /// Regression split strategy.
    pub reg_strategy: RegStrategy,
    /// Worker threads (0 = all cores, 1 = sequential; resolved by
    /// [`crate::runtime::threads`]). The coordinator parallelizes
    /// level-synchronously over frontier nodes and over features on the
    /// persistent pool ([`crate::runtime::pool`]).
    pub n_threads: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            criterion: ClassCriterion::InfoGain,
            max_depth: usize::MAX,
            min_samples_split: 2,
            min_gain: -1e-9,
            backend: Backend::Superfast,
            reg_strategy: RegStrategy::LabelSplit,
            n_threads: 1,
        }
    }
}

impl TrainConfig {
    pub fn criterion_for(&self, task: TaskKind) -> Criterion {
        match task {
            TaskKind::Classification => Criterion::Class(self.criterion),
            TaskKind::Regression => Criterion::Sse,
        }
    }
}

/// Prediction payload of a node. Every node carries one (not only
/// leaves) — that is what makes Training-Only-Once Tuning possible:
/// Algorithm 7 can stop at any inner node and answer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum NodeLabel {
    Class(u16),
    Value(f64),
}

impl NodeLabel {
    /// The class id, or `None` for a regression label.
    #[inline]
    pub fn as_class(&self) -> Option<u16> {
        match self {
            NodeLabel::Class(c) => Some(*c),
            NodeLabel::Value(_) => None,
        }
    }

    /// The regression value, or `None` for a classification label.
    #[inline]
    pub fn as_value(&self) -> Option<f64> {
        match self {
            NodeLabel::Value(v) => Some(*v),
            NodeLabel::Class(_) => None,
        }
    }
}

/// One tree node in the arena.
#[derive(Debug, Clone)]
pub struct Node {
    /// Split predicate; `None` for leaves.
    pub split: Option<SplitPredicate>,
    /// Arena ids of (positive, negative) children; `None` for leaves.
    pub children: Option<(u32, u32)>,
    /// Majority class / mean target of the node's training examples.
    pub label: NodeLabel,
    /// Number of training examples that reached this node (`|node.E|`).
    pub n_samples: u32,
    /// Depth (root = 1, matching the paper's depth accounting).
    pub depth: u16,
}

impl Node {
    pub fn is_leaf(&self) -> bool {
        self.children.is_none()
    }
}

/// A trained decision tree.
#[derive(Debug, Clone)]
pub struct Tree {
    pub nodes: Vec<Node>,
    pub task: TaskKind,
    pub n_features: usize,
    /// Maximum node depth (root = 1).
    pub depth: u16,
}

impl Tree {
    pub const ROOT: u32 = 0;

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn n_leaves(&self) -> usize {
        self.nodes.iter().filter(|n| n.is_leaf()).count()
    }

    /// Train on a dataset with the given config (paper Algorithm 5).
    pub fn fit(ds: &Dataset, config: &TrainConfig) -> Result<Tree> {
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        builder::fit_rows(ds, &rows, config)
    }

    /// Train on a subset of rows.
    pub fn fit_rows(ds: &Dataset, rows: &[u32], config: &TrainConfig) -> Result<Tree> {
        builder::fit_rows(ds, rows, config)
    }

    /// Train on a subset of rows with a feature mask (see
    /// [`builder::fit_rows_masked`]); used by forest feature bagging.
    pub fn fit_rows_masked(
        ds: &Dataset,
        rows: &[u32],
        config: &TrainConfig,
        active: Option<&[bool]>,
    ) -> Result<Tree> {
        builder::fit_rows_masked(ds, rows, config, active)
    }

    /// Classification accuracy over a dataset (full-depth predictions).
    ///
    /// Returns [`UdtError::TaskMismatch`] when the tree or the dataset is
    /// a regression one.
    pub fn accuracy(&self, ds: &Dataset) -> Result<f64> {
        self.accuracy_rows(ds, &(0..ds.n_rows() as u32).collect::<Vec<_>>())
    }

    /// Accuracy over selected rows.
    pub fn accuracy_rows(&self, ds: &Dataset, rows: &[u32]) -> Result<f64> {
        require_task(TaskKind::Classification, self.task)?;
        require_task(TaskKind::Classification, ds.task())?;
        if rows.is_empty() {
            return Ok(f64::NAN);
        }
        let correct = rows
            .iter()
            .filter(|&&r| {
                predict::predict_ds(self, ds, r as usize, usize::MAX, 0).as_class()
                    == Some(ds.labels.class(r as usize))
            })
            .count();
        Ok(correct as f64 / rows.len() as f64)
    }

    /// (MAE, RMSE) over selected rows (regression).
    pub fn regression_error(&self, ds: &Dataset, rows: &[u32]) -> Result<(f64, f64)> {
        require_task(TaskKind::Regression, self.task)?;
        require_task(TaskKind::Regression, ds.task())?;
        if rows.is_empty() {
            return Ok((f64::NAN, f64::NAN));
        }
        Ok(mae_rmse(rows.iter().map(|&r| {
            (
                predict::predict_ds(self, ds, r as usize, usize::MAX, 0)
                    .as_value()
                    .unwrap_or(f64::NAN),
                ds.labels.target(r as usize),
            )
        })))
    }
}

/// Typed task guard used across the estimator surface.
pub(crate) fn require_task(expected: TaskKind, got: TaskKind) -> Result<()> {
    if expected == got {
        Ok(())
    } else {
        Err(UdtError::TaskMismatch { expected, got })
    }
}

/// Shared MAE/RMSE fold over `(prediction, target)` pairs — the single
/// implementation behind tree, forest and model evaluation (yields 0.0
/// on empty input; callers wanting NaN-on-empty check first).
pub(crate) fn mae_rmse(pairs: impl Iterator<Item = (f64, f64)>) -> (f64, f64) {
    let mut abs = 0.0;
    let mut sq = 0.0;
    let mut n = 0usize;
    for (pred, y) in pairs {
        let err = pred - y;
        abs += err.abs();
        sq += err * err;
        n += 1;
    }
    let nf = n.max(1) as f64;
    (abs / nf, (sq / nf).sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate_classification, SynthSpec};

    #[test]
    fn fit_learns_synthetic_data() {
        let spec = SynthSpec::classification("t", 2000, 6, 3);
        let ds = generate_classification(&spec, 11);
        let tree = Tree::fit(&ds, &TrainConfig::default()).unwrap();
        let acc = tree.accuracy(&ds).unwrap();
        // Full tree on training data should fit nearly perfectly
        // (residual error only where identical rows carry different labels).
        assert!(acc > 0.95, "train accuracy {acc}");
        assert!(tree.n_nodes() > 10);
        assert!(tree.depth >= 3);
    }

    #[test]
    fn max_depth_1_is_single_leaf() {
        let spec = SynthSpec::classification("t", 200, 4, 2);
        let ds = generate_classification(&spec, 1);
        let cfg = TrainConfig {
            max_depth: 1,
            ..Default::default()
        };
        let tree = Tree::fit(&ds, &cfg).unwrap();
        assert_eq!(tree.n_nodes(), 1);
        assert!(tree.nodes[0].is_leaf());
    }

    #[test]
    fn min_samples_split_limits_growth() {
        let spec = SynthSpec::classification("t", 1000, 5, 2);
        let ds = generate_classification(&spec, 2);
        let full = Tree::fit(&ds, &TrainConfig::default()).unwrap();
        let limited = Tree::fit(
            &ds,
            &TrainConfig {
                min_samples_split: 200,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(limited.n_nodes() < full.n_nodes());
    }

    #[test]
    fn binned_backend_builds_same_tree_when_bins_are_exact() {
        // Cap the numeric grid below the bin budget so every lane is
        // exact: the binned engine must then reproduce Superfast
        // node-for-node (same predicates, labels and sample counts).
        let mut spec = SynthSpec::classification("t", 600, 5, 3);
        spec.numeric_cardinality = 32;
        let ds = generate_classification(&spec, 7);
        let exact = Tree::fit(&ds, &TrainConfig::default()).unwrap();
        let binned = Tree::fit(
            &ds,
            &TrainConfig {
                backend: Backend::Binned { max_bins: 32 },
                ..Default::default()
            },
        )
        .unwrap();
        assert!(ds.binned_index(32).all_exact());
        assert_eq!(exact.n_nodes(), binned.n_nodes());
        assert_eq!(exact.depth, binned.depth);
        for (a, b) in exact.nodes.iter().zip(&binned.nodes) {
            assert_eq!(a.split, b.split);
            assert_eq!(a.label, b.label);
            assert_eq!(a.n_samples, b.n_samples);
        }
    }

    #[test]
    fn max_bins_bounds_are_enforced() {
        for bad in [0usize, 1, 65536] {
            assert!(validate_max_bins(bad).is_err(), "max_bins {bad}");
        }
        assert!(validate_max_bins(2).is_ok());
        assert!(validate_max_bins(65535).is_ok());
    }

    #[test]
    fn generic_backend_builds_same_tree() {
        let spec = SynthSpec::classification("t", 400, 5, 2);
        let ds = generate_classification(&spec, 3);
        let fast = Tree::fit(&ds, &TrainConfig::default()).unwrap();
        let slow = Tree::fit(
            &ds,
            &TrainConfig {
                backend: Backend::Generic,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(fast.n_nodes(), slow.n_nodes());
        assert_eq!(fast.depth, slow.depth);
        for (a, b) in fast.nodes.iter().zip(&slow.nodes) {
            assert_eq!(a.split, b.split);
            assert_eq!(a.label, b.label);
        }
    }
}
