//! Out-of-core tree training over a shard directory.
//!
//! [`fit_sharded`] is a second, streaming driver for the binned
//! selection engine: the level-synchronous loop, stop rules, label /
//! purity computation, baseline and tie-breaking all mirror
//! `tree/builder.rs` statement for statement, but node state lives in
//! per-node histogram blocks fed shard-by-shard instead of an in-RAM
//! row arena. Resident memory is bounded by one decoded shard window
//! plus the frontier's histogram blocks plus one `u32` per row
//! (the node-assignment lane) — independent of dataset size.
//!
//! Each level costs two sequential passes over the bin-lane sidecars:
//!
//! 1. **route** — every live row evaluates its node's freshly chosen
//!    predicate on the bin-id/cat-id lanes (a `≤ x` threshold becomes a
//!    `bin ≤ bin(x)` comparison) and moves to a child slot; child label
//!    stats (class counts / regression `n, Σy, min, max`) accumulate in
//!    the same pass in ascending row order;
//! 2. **accumulate** — only the *smaller* child of every split
//!    accumulates histograms from the lanes; the larger child derives
//!    its block by parent-minus-sibling subtraction, exactly like the
//!    in-memory `BinnedState`.
//!
//! Scoring uses the histogram-only twins in `selection/binned.rs`
//! (`best_split_class_stats` / `best_split_reg_stats`), which replicate
//! the view-based scorers' candidate order and arithmetic, so on
//! lossless bin lanes the resulting tree is node-for-node identical to
//! in-memory `--backend binned` training on the same `max_bins`
//! (property-tested in `tests/prop_shard.rs`).

use crate::coordinator::parallel::parallel_map_scratch;
use crate::data::dataset::TaskKind;
use crate::data::shard::dataset::{ShardBins, ShardedDataset};
use crate::data::shard::format::{BinsMeta, LabelLane, NO_CAT};
use crate::error::{Result, UdtError};
use crate::selection::binned::{best_split_class_stats, best_split_reg_stats};
use crate::selection::split::{SplitOp, SplitPredicate};
use crate::selection::superfast::{ScoredSplit, Scratch};

use super::{validate_max_bins, Backend, Node, NodeLabel, RegStrategy, TrainConfig, Tree};

/// Witnesses of the bounded-RAM contract, returned alongside the tree
/// and surfaced in the pipeline report.
#[derive(Debug, Clone, Copy, Default)]
pub struct ShardedStats {
    /// Largest decoded shard window resident at any point (bytes).
    /// Windows are read → accumulated → dropped one at a time, so this
    /// is `max` over shards, never a sum.
    pub peak_shard_window_bytes: usize,
    /// Sequential passes over the shard directory: 2 if the bin
    /// sidecars were built (edge pass + lane pass), 1 for the root
    /// histogram, then 2 per split level (route + accumulate).
    pub shard_passes: usize,
    /// Peak bytes held in per-node histogram blocks (incl. the
    /// accumulation scratch of the current level).
    pub peak_hist_bytes: usize,
    /// Bytes of the per-row node-assignment lane (`4 · n_rows`).
    pub assignment_bytes: usize,
    /// Histogram add operations ((row, numeric-feature) and
    /// (row, categorical-feature) entries actually accumulated).
    pub hist_rows_accumulated: u64,
    /// Frontier levels processed (root = 1).
    pub n_levels: usize,
}

/// Per-feature offsets into a node's flat histogram block: numeric
/// histogram (`n_edges × width`) then dense categorical table
/// (`cat_card × width`), per feature, concatenated. One block per
/// scoreable node; subtraction runs over the whole block at once.
struct Layout {
    width: usize,
    hist_off: Vec<usize>,
    n_edges: Vec<usize>,
    cat_off: Vec<usize>,
    cat_card: Vec<usize>,
    block_len: usize,
}

impl Layout {
    fn new(meta: &BinsMeta, width: usize) -> Layout {
        let nf = meta.edges.len();
        let mut l = Layout {
            width,
            hist_off: Vec::with_capacity(nf),
            n_edges: Vec::with_capacity(nf),
            cat_off: Vec::with_capacity(nf),
            cat_card: Vec::with_capacity(nf),
            block_len: 0,
        };
        for f in 0..nf {
            let ne = meta.edges[f].as_ref().map_or(0, Vec::len);
            l.hist_off.push(l.block_len);
            l.n_edges.push(ne);
            l.block_len += ne * width;
            l.cat_off.push(l.block_len);
            l.cat_card.push(meta.cat_card[f] as usize);
            l.block_len += meta.cat_card[f] as usize * width;
        }
        l
    }

    fn hist<'b>(&self, block: &'b [f64], f: usize) -> &'b [f64] {
        &block[self.hist_off[f]..self.hist_off[f] + self.n_edges[f] * self.width]
    }

    fn cat<'b>(&self, block: &'b [f64], f: usize) -> &'b [f64] {
        &block[self.cat_off[f]..self.cat_off[f] + self.cat_card[f] * self.width]
    }
}

/// Node label statistics, accumulated in ascending global row order so
/// regression sums (and therefore means) are bit-identical to the
/// in-memory builder's ascending-row walks.
#[derive(Debug, Clone)]
enum NodeStats {
    Class(Vec<f64>),
    Reg { n: f64, sum: f64, min: f64, max: f64 },
}

impl NodeStats {
    fn new(task: TaskKind, n_classes: usize) -> NodeStats {
        match task {
            TaskKind::Classification => NodeStats::Class(vec![0.0; n_classes]),
            TaskKind::Regression => NodeStats::Reg {
                n: 0.0,
                sum: 0.0,
                min: f64::INFINITY,
                max: f64::NEG_INFINITY,
            },
        }
    }

    #[inline]
    fn add(&mut self, labels: &LabelLane, r: usize) {
        match (self, labels) {
            (NodeStats::Class(counts), LabelLane::Class(ids)) => {
                counts[ids[r] as usize] += 1.0;
            }
            (NodeStats::Reg { n, sum, min, max }, LabelLane::Reg(values)) => {
                let v = values[r];
                *n += 1.0;
                *sum += v;
                *min = min.min(v);
                *max = max.max(v);
            }
            _ => unreachable!("label lane kind mismatch"),
        }
    }
}

/// One frontier node of the current level.
struct LevelNode {
    tree_id: u32,
    depth: u16,
    n_rows: usize,
    stats: NodeStats,
    /// Histogram block; `None` for nodes that can never split (the
    /// depth/size stop rules already fired when the level was formed).
    block: Option<Vec<f64>>,
}

/// Scoring outcome for one frontier node (applied in slot order, like
/// the in-memory builder's decisions).
struct Decision {
    slot: usize,
    label: NodeLabel,
    depth: u16,
    predicate: Option<SplitPredicate>,
}

/// A split predicate translated onto the bin-id / cat-id lanes.
#[derive(Clone, Copy)]
enum RouteOp {
    /// Numeric `≤ x` ⇔ `bin ≤ bin(x)` (edges are bin maxima).
    LeBin(u32),
    /// Numeric `> x` ⇔ `bin > bin(x)`.
    GtBin(u32),
    /// Categorical `= id`.
    EqCat(u32),
}

#[derive(Clone, Copy)]
struct Route {
    feature: usize,
    op: RouteOp,
}

/// Row slot sentinel: the row's node is settled (leaf), stop tracking.
const SETTLED: u32 = u32::MAX;

fn placeholder_node() -> Node {
    Node {
        split: None,
        children: None,
        label: NodeLabel::Class(0),
        n_samples: 0,
        depth: 0,
    }
}

/// Train a binned tree out-of-core over a shard directory. Requires
/// `Backend::Binned`; bin sidecars for the configured `max_bins` are
/// built on first use and reused afterwards.
pub fn fit_sharded(sds: &ShardedDataset, config: &TrainConfig) -> Result<(Tree, ShardedStats)> {
    fit_sharded_sampled(sds, config, 0)
}

/// [`fit_sharded`] with a per-(shard, column) reservoir size for the
/// quantile edge pass. `sample_rows == 0` computes exact edges (and
/// node-for-node identity with in-memory binned training on lossless
/// lanes); `> 0` bounds edge-pass memory at the cost of approximate
/// bin boundaries.
pub fn fit_sharded_sampled(
    sds: &ShardedDataset,
    config: &TrainConfig,
    sample_rows: usize,
) -> Result<(Tree, ShardedStats)> {
    let n_rows = sds.n_rows();
    let n_features = sds.n_features();
    if n_rows == 0 {
        return Err(UdtError::data("cannot fit on an empty row set"));
    }
    if n_features == 0 {
        return Err(UdtError::data("dataset has no features"));
    }
    if config.max_depth < 1 {
        return Err(UdtError::invalid_config("max_depth must be >= 1"));
    }
    let Backend::Binned { max_bins } = &config.backend else {
        return Err(UdtError::invalid_config(
            "sharded training requires the binned backend (set backend = binned)",
        ));
    };
    let max_bins = *max_bins;
    validate_max_bins(max_bins)?;
    let task = sds.task();
    if task == TaskKind::Regression && config.reg_strategy == RegStrategy::LabelSplit {
        return Err(UdtError::invalid_config(
            "the binned backend requires RegStrategy::DirectSse for regression \
             (the label-split strategy re-labels every node, which defeats \
             parent-minus-sibling histogram subtraction)",
        ));
    }

    let mut stats = ShardedStats {
        assignment_bytes: n_rows * 4,
        ..ShardedStats::default()
    };
    let bins = sds.ensure_bins(max_bins, sample_rows, config.n_threads)?;
    if bins.built {
        stats.shard_passes += 2;
    }
    let meta = bins.meta();
    let n_classes = sds.n_classes().max(1);
    let width = match task {
        TaskKind::Classification => n_classes,
        TaskKind::Regression => 2,
    };
    let layout = Layout::new(meta, width);

    let mut tree = Tree {
        nodes: vec![placeholder_node()],
        task,
        n_features,
        depth: 0,
    };

    // Root pass: label stats + root histogram block in one sweep.
    let mut root_stats = NodeStats::new(task, n_classes);
    let mut root_block = vec![0.0f64; layout.block_len];
    for i in 0..sds.n_shards() {
        let w = read_window(&bins, i, &mut stats)?;
        for r in 0..w.n_rows {
            root_stats.add(&w.labels, r);
            accumulate_row(&w, r, &layout, &mut root_block, &mut stats);
        }
    }
    stats.shard_passes += 1;

    let mut assign: Vec<u32> = vec![0; n_rows];
    let mut level: Vec<LevelNode> = vec![LevelNode {
        tree_id: 0,
        depth: 1,
        n_rows,
        stats: root_stats,
        block: Some(root_block),
    }];

    loop {
        stats.n_levels += 1;
        track_hist_peak(&mut stats, &level, &layout, 0);

        // Score every frontier node (order-preserving parallel map, so
        // decisions are invariant to the thread count).
        let decisions: Vec<Decision> = parallel_map_scratch(
            (0..level.len()).collect(),
            config.n_threads,
            Scratch::new,
            |slot, scratch| score_node(&level[slot], slot, config, meta, &layout, scratch),
        );

        // Apply decisions in slot order — same arena order as the
        // in-memory builder (positive child first, then negative).
        let mut splits: Vec<(usize, SplitPredicate)> = Vec::new();
        for d in &decisions {
            let node = &level[d.slot];
            let id = node.tree_id as usize;
            tree.nodes[id].label = d.label;
            tree.nodes[id].n_samples = node.n_rows as u32;
            tree.nodes[id].depth = d.depth;
            tree.depth = tree.depth.max(d.depth);
            if let Some(pred) = d.predicate {
                let pos_id = tree.nodes.len() as u32;
                tree.nodes[id].split = Some(pred);
                tree.nodes[id].children = Some((pos_id, pos_id + 1));
                tree.nodes.push(placeholder_node());
                tree.nodes.push(placeholder_node());
                splits.push((d.slot, pred));
            }
        }
        if splits.is_empty() {
            break;
        }

        // Translate predicates onto the bin/cat lanes.
        let mut split_of_slot: Vec<Option<u32>> = vec![None; level.len()];
        let routes: Vec<Route> = splits
            .iter()
            .enumerate()
            .map(|(s, &(slot, pred))| {
                split_of_slot[slot] = Some(s as u32);
                let f = pred.feature;
                let bin_of = |t: f64| {
                    let edges = meta.edges[f]
                        .as_ref()
                        // ANALYZE-ALLOW(no-unwrap): numeric splits only come from binned columns
                        .expect("numeric split on a column with bin edges");
                    edges.partition_point(|e| *e < t) as u32
                };
                let op = match pred.op {
                    SplitOp::Le(t) => RouteOp::LeBin(bin_of(t)),
                    SplitOp::Gt(t) => RouteOp::GtBin(bin_of(t)),
                    SplitOp::Eq(c) => RouteOp::EqCat(c.0),
                };
                Route { feature: f, op }
            })
            .collect();

        // Pass 1 — route rows to child slots, accumulate child label
        // stats (ascending row order) and child row counts.
        let n_children = 2 * splits.len();
        let mut child_counts = vec![0usize; n_children];
        let mut child_stats: Vec<NodeStats> = (0..n_children)
            .map(|_| NodeStats::new(task, n_classes))
            .collect();
        for i in 0..sds.n_shards() {
            let w = read_window(&bins, i, &mut stats)?;
            let offset = sds.manifest().shards[i].row_offset;
            for r in 0..w.n_rows {
                let slot = assign[offset + r];
                if slot == SETTLED {
                    continue;
                }
                let Some(s) = split_of_slot[slot as usize] else {
                    assign[offset + r] = SETTLED;
                    continue;
                };
                let route = routes[s as usize];
                let pos = match route.op {
                    RouteOp::LeBin(bt) => w.bins[route.feature]
                        .as_ref()
                        .and_then(|lane| lane.get(r))
                        .is_some_and(|b| b <= bt),
                    RouteOp::GtBin(bt) => w.bins[route.feature]
                        .as_ref()
                        .and_then(|lane| lane.get(r))
                        .is_some_and(|b| b > bt),
                    RouteOp::EqCat(id) => w.cats[route.feature]
                        .as_ref()
                        .is_some_and(|ids| ids[r] == id),
                };
                let child = 2 * s + if pos { 0 } else { 1 };
                assign[offset + r] = child;
                child_counts[child as usize] += 1;
                child_stats[child as usize].add(&w.labels, r);
            }
        }
        stats.shard_passes += 1;

        // Which children need histogram blocks next level? Only those
        // the depth/size stop rules cannot settle (purity is discovered
        // at scoring time; a pure child's block goes unused, same as
        // the in-memory builder's tracked-but-pure nodes).
        let min_split = config.min_samples_split.max(2);
        let child_needs: Vec<bool> = (0..n_children)
            .map(|cslot| {
                let depth = level[splits[cslot / 2].0].depth as usize + 1;
                depth < config.max_depth && child_counts[cslot] >= min_split
            })
            .collect();

        // Pass 2 — accumulate only the smaller child of each split
        // (when either side needs a block); the larger side is derived
        // by subtraction afterwards.
        let mut acc_of_slot: Vec<Option<u32>> = vec![None; n_children];
        let mut acc_blocks: Vec<Vec<f64>> = Vec::new();
        let mut small_of_split: Vec<u32> = Vec::with_capacity(splits.len());
        for s in 0..splits.len() {
            let (pos, neg) = (2 * s, 2 * s + 1);
            let small = if child_counts[pos] <= child_counts[neg] {
                pos
            } else {
                neg
            };
            small_of_split.push(small as u32);
            if child_needs[pos] || child_needs[neg] {
                acc_of_slot[small] = Some(acc_blocks.len() as u32);
                acc_blocks.push(vec![0.0f64; layout.block_len]);
            }
        }
        track_hist_peak(&mut stats, &level, &layout, acc_blocks.len());
        if !acc_blocks.is_empty() {
            for i in 0..sds.n_shards() {
                let w = read_window(&bins, i, &mut stats)?;
                let offset = sds.manifest().shards[i].row_offset;
                for r in 0..w.n_rows {
                    let slot = assign[offset + r];
                    if slot == SETTLED {
                        continue;
                    }
                    if let Some(a) = acc_of_slot[slot as usize] {
                        accumulate_row(&w, r, &layout, &mut acc_blocks[a as usize], &mut stats);
                    }
                }
            }
        }
        stats.shard_passes += 1;

        // Assemble the next level: smaller child takes its accumulated
        // block, larger child takes parent − smaller.
        let mut next: Vec<LevelNode> = Vec::with_capacity(n_children);
        for (s, &(slot, _)) in splits.iter().enumerate() {
            let parent_block = level[slot].block.take();
            let parent_depth = level[slot].depth;
            let (pos_id, neg_id) = tree.nodes[level[slot].tree_id as usize]
                .children
                // ANALYZE-ALLOW(no-unwrap): split nodes were just given children this level
                .expect("split node has children");
            let small = small_of_split[s] as usize;
            let large = small ^ 1;
            let small_block = acc_of_slot[small].map(|a| std::mem::take(&mut acc_blocks[a as usize]));
            let mut blocks: [Option<Vec<f64>>; 2] = [None, None];
            if child_needs[large] {
                // ANALYZE-ALLOW(no-unwrap): level protocol keeps blocks on scored nodes until split
                let mut pb = parent_block.expect("scored node keeps its block until split");
                let sm = small_block
                    .as_ref()
                    // ANALYZE-ALLOW(no-unwrap): the smaller child is always accumulated when its sibling needs a block
                    .expect("smaller child accumulated when sibling needs a block");
                for (d, sv) in pb.iter_mut().zip(sm) {
                    *d -= sv;
                }
                blocks[large & 1] = Some(pb);
            }
            if child_needs[small] {
                blocks[small & 1] = small_block;
            }
            let [pos_block, neg_block] = blocks;
            for (cslot, tree_id, block) in [
                (2 * s, pos_id, pos_block),
                (2 * s + 1, neg_id, neg_block),
            ] {
                next.push(LevelNode {
                    tree_id,
                    depth: parent_depth + 1,
                    n_rows: child_counts[cslot],
                    stats: std::mem::replace(
                        &mut child_stats[cslot],
                        NodeStats::Class(Vec::new()),
                    ),
                    block,
                });
            }
        }
        level = next;
    }

    Ok((tree, stats))
}

/// Read one shard's training window, updating the resident-window
/// witness.
fn read_window(
    bins: &ShardBins,
    i: usize,
    stats: &mut ShardedStats,
) -> Result<crate::data::shard::format::BinWindow> {
    let w = bins.read_window(i)?;
    stats.peak_shard_window_bytes = stats.peak_shard_window_bytes.max(w.approx_bytes());
    Ok(w)
}

/// Add one row's lanes into a histogram block.
#[inline]
fn accumulate_row(
    w: &crate::data::shard::format::BinWindow,
    r: usize,
    layout: &Layout,
    block: &mut [f64],
    stats: &mut ShardedStats,
) {
    let width = layout.width;
    let (lab, target) = match &w.labels {
        LabelLane::Class(ids) => (ids[r] as usize, 0.0),
        LabelLane::Reg(values) => (0, values[r]),
    };
    let class = matches!(&w.labels, LabelLane::Class(_));
    for f in 0..layout.hist_off.len() {
        if let Some(lane) = &w.bins[f] {
            if let Some(b) = lane.get(r) {
                let at = layout.hist_off[f] + b as usize * width;
                if class {
                    block[at + lab] += 1.0;
                } else {
                    block[at] += 1.0;
                    block[at + 1] += target;
                }
                stats.hist_rows_accumulated += 1;
            }
        }
        if let Some(ids) = &w.cats[f] {
            let id = ids[r];
            if id != NO_CAT {
                let at = layout.cat_off[f] + id as usize * width;
                if class {
                    block[at + lab] += 1.0;
                } else {
                    block[at] += 1.0;
                    block[at + 1] += target;
                }
                stats.hist_rows_accumulated += 1;
            }
        }
    }
}

/// Update the histogram-block memory witness for the current frontier
/// plus `extra` accumulation scratch blocks.
fn track_hist_peak(stats: &mut ShardedStats, level: &[LevelNode], layout: &Layout, extra: usize) {
    let live = level.iter().filter(|n| n.block.is_some()).count() + extra;
    stats.peak_hist_bytes = stats.peak_hist_bytes.max(live * layout.block_len * 8);
}

/// Label, purity, stop rules, per-feature scoring, baseline and
/// minimum-gain test for one frontier node — the statement-for-
/// statement mirror of the in-memory builder's `process_node`, driven
/// by accumulated statistics instead of row slices.
fn score_node(
    node: &LevelNode,
    slot: usize,
    config: &TrainConfig,
    meta: &BinsMeta,
    layout: &Layout,
    scratch: &mut Scratch,
) -> Decision {
    let (label, pure) = match &node.stats {
        NodeStats::Class(counts) => {
            let (best, &max) = counts
                .iter()
                .enumerate()
                // ANALYZE-ALLOW(no-unwrap): class counts are integral f64, never NaN
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap().then(b.0.cmp(&a.0)))
                // ANALYZE-ALLOW(no-unwrap): counts holds n_classes >= 1 entries
                .unwrap();
            (NodeLabel::Class(best as u16), max as usize == node.n_rows)
        }
        NodeStats::Reg { n, sum, min, max } => {
            let mean = sum / n;
            // Equivalent to the all-rows `|y − mean| < 1e-12` scan:
            // the deviation is maximized at the extremes.
            let pure = (min - mean).abs() < 1e-12 && (max - mean).abs() < 1e-12;
            (NodeLabel::Value(mean), pure)
        }
    };
    let mut decision = Decision {
        slot,
        label,
        depth: node.depth,
        predicate: None,
    };
    if pure
        || node.depth as usize >= config.max_depth
        || node.n_rows < config.min_samples_split.max(2)
    {
        return decision;
    }
    let block = node
        .block
        .as_ref()
        // ANALYZE-ALLOW(no-unwrap): the level protocol keeps blocks on scoreable nodes
        .expect("scoreable node carries a histogram block");

    // Winner fold across features: strictly greater, feature order —
    // identical tie-breaking to `best_across_features`.
    let mut best: Option<(usize, ScoredSplit)> = None;
    for f in 0..layout.hist_off.len() {
        let hist = layout.hist(block, f);
        let edges = meta.edges[f].as_deref().unwrap_or(&[]);
        let cat = layout.cat(block, f);
        let scored = match &node.stats {
            NodeStats::Class(counts) => {
                best_split_class_stats(counts, config.criterion, hist, edges, cat, scratch)
            }
            NodeStats::Reg { n, sum, .. } => best_split_reg_stats((*n, *sum), hist, edges, cat),
        };
        if let Some(s) = scored {
            let better = match &best {
                None => true,
                Some((_, b)) => s.score > b.score,
            };
            if better {
                best = Some((f, s));
            }
        }
    }
    let Some((feature, best)) = best else {
        return decision;
    };
    let baseline = match &node.stats {
        NodeStats::Class(counts) => {
            let zeros = vec![0.0f64; counts.len()];
            config.criterion.score(counts, &zeros)
        }
        NodeStats::Reg { n, sum, .. } => sum * sum / n,
    };
    if !(best.score - baseline > config.min_gain) {
        return decision;
    }
    decision.predicate = Some(SplitPredicate {
        feature,
        op: best.op,
    });
    decision
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::csv::{load_csv_str, CsvOptions};
    use crate::data::shard::writer::write_dataset_shards;
    use crate::selection::heuristic::ClassCriterion;

    fn temp_dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!(
            "udt-sharded-fit-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    pub(crate) fn assert_same_tree(a: &Tree, b: &Tree) {
        assert_eq!(a.n_nodes(), b.n_nodes(), "node count");
        assert_eq!(a.depth, b.depth, "depth");
        for (i, (x, y)) in a.nodes.iter().zip(&b.nodes).enumerate() {
            assert_eq!(x.split, y.split, "node {i} split");
            assert_eq!(x.children, y.children, "node {i} children");
            assert_eq!(x.label, y.label, "node {i} label");
            assert_eq!(x.n_samples, y.n_samples, "node {i} n_samples");
            assert_eq!(x.depth, y.depth, "node {i} depth");
        }
    }

    fn mixed_csv() -> String {
        let mut s = String::from("num,mix,cat,label\n");
        for i in 0..120usize {
            let num = format!("{}", (i * 17 % 23) as f64 * 0.5);
            let mix = match i % 5 {
                0 => "?".to_string(),
                1 | 2 => format!("m{}", i % 3),
                _ => format!("{}", i % 7),
            };
            let cat = format!("c{}", i * 11 % 4);
            let y = ["a", "b", "c"][(i * 7 + i / 13) % 3];
            s.push_str(&format!("{num},{mix},{cat},{y}\n"));
        }
        s
    }

    #[test]
    fn sharded_matches_in_memory_binned_classification() {
        let csv = mixed_csv();
        let ds = load_csv_str("t", &csv, &CsvOptions::default()).unwrap();
        let dir = temp_dir("cls");
        write_dataset_shards(&ds, &dir, 26).unwrap();
        let sds = ShardedDataset::open(&dir).unwrap();

        for criterion in [ClassCriterion::InfoGain, ClassCriterion::Gini] {
            for threads in [1, 4] {
                let config = TrainConfig {
                    backend: Backend::Binned { max_bins: 64 },
                    criterion,
                    n_threads: threads,
                    ..TrainConfig::default()
                };
                let mem = Tree::fit(&ds, &config).unwrap();
                let (shd, st) = fit_sharded(&sds, &config).unwrap();
                assert_same_tree(&shd, &mem);
                assert!(st.peak_shard_window_bytes > 0);
                assert!(st.shard_passes >= 3, "{}", st.shard_passes);
                assert_eq!(st.assignment_bytes, 120 * 4);
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sharded_matches_in_memory_binned_regression() {
        // Dyadic targets: every sum is exact, so histogram subtraction
        // and accumulation order cannot perturb the arithmetic.
        let mut csv = String::from("x,g,y\n");
        for i in 0..80usize {
            let x = format!("{}", (i * 13 % 17) as f64);
            let g = format!("g{}", i % 3);
            let y = ((i * 29 % 31) as f64 * 4.0).round() / 4.0;
            csv.push_str(&format!("{x},{g},{y}\n"));
        }
        let opts = CsvOptions {
            task: TaskKind::Regression,
            ..CsvOptions::default()
        };
        let ds = load_csv_str("t", &csv, &opts).unwrap();
        let dir = temp_dir("reg");
        write_dataset_shards(&ds, &dir, 19).unwrap();
        let sds = ShardedDataset::open(&dir).unwrap();
        let config = TrainConfig {
            backend: Backend::Binned { max_bins: 64 },
            reg_strategy: RegStrategy::DirectSse,
            ..TrainConfig::default()
        };
        let mem = Tree::fit(&ds, &config).unwrap();
        let (shd, _) = fit_sharded(&sds, &config).unwrap();
        assert_same_tree(&shd, &mem);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn config_validation_mirrors_in_memory_builder() {
        let csv = mixed_csv();
        let ds = load_csv_str("t", &csv, &CsvOptions::default()).unwrap();
        let dir = temp_dir("val");
        write_dataset_shards(&ds, &dir, 60).unwrap();
        let sds = ShardedDataset::open(&dir).unwrap();

        // Non-binned backend.
        let err = fit_sharded(&sds, &TrainConfig::default()).unwrap_err();
        assert!(matches!(err, UdtError::InvalidConfig(_)), "{err:?}");
        // Bad max_bins.
        let config = TrainConfig {
            backend: Backend::Binned { max_bins: 1 },
            ..TrainConfig::default()
        };
        assert!(matches!(
            fit_sharded(&sds, &config),
            Err(UdtError::InvalidConfig(_))
        ));
        // max_depth 0.
        let config = TrainConfig {
            backend: Backend::Binned { max_bins: 16 },
            max_depth: 0,
            ..TrainConfig::default()
        };
        assert!(matches!(
            fit_sharded(&sds, &config),
            Err(UdtError::InvalidConfig(_))
        ));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn depth_limit_and_min_samples_respected() {
        let csv = mixed_csv();
        let ds = load_csv_str("t", &csv, &CsvOptions::default()).unwrap();
        let dir = temp_dir("depth");
        write_dataset_shards(&ds, &dir, 26).unwrap();
        let sds = ShardedDataset::open(&dir).unwrap();
        for (max_depth, min_split) in [(1, 2), (2, 2), (3, 25), (4, 2)] {
            let config = TrainConfig {
                backend: Backend::Binned { max_bins: 64 },
                max_depth,
                min_samples_split: min_split,
                ..TrainConfig::default()
            };
            let mem = Tree::fit(&ds, &config).unwrap();
            let (shd, _) = fit_sharded(&sds, &config).unwrap();
            assert_same_tree(&shd, &mem);
            assert!(shd.depth as usize <= max_depth);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
