//! Gradient-boosted UDT ensembles on the shared sort cache.
//!
//! Boosting is the workload the dataset-level
//! [`crate::data::sorted_index::SortedIndex`] cache was built for:
//! residual targets change every round but **feature order does not**,
//! so every round's shallow regression tree filters the same root
//! pre-sort instead of re-sorting (`Dataset::sort_index_builds()` stays
//! at 1 across an entire boost run, regardless of round count — see the
//! tests). Residual labels are supplied to the builder as a per-round
//! [`Labels`] view via [`crate::tree::builder::fit_rows_with_labels`];
//! they are never copied into the dataset.
//!
//! Three loss regimes, all fitting plain SSE regression trees on
//! gradient residuals ([`RegStrategy::DirectSse`] — the label-split
//! strategy is unavailable because the cached by-target order reflects
//! the dataset's original labels, not the residuals):
//!
//! * **Regression** — squared error: residual `y − F(x)`, prediction
//!   `base + η · Σ leaf`.
//! * **Binary classification** — logistic loss on a single score:
//!   residual `y − σ(F(x))` with `y ∈ {0, 1}`, prediction class 1 iff
//!   the final logit is positive.
//! * **Multiclass** — one-vs-rest: one score (and one tree per round)
//!   per class, each boosted with the binary rule; prediction is the
//!   argmax score, ties toward the smaller class id (the crate-wide
//!   tie-break).
//!
//! The boxed ([`Boosted::predict_values`]) and compiled
//! ([`crate::inference::CompiledModel`]) paths share one scoring rule,
//! [`decide_scores`], and accumulate member leaves in the same storage
//! order (round-major, class-minor), so compiled predictions are
//! bit-identical to boxed ones.

use super::{predict, require_task, Backend, NodeLabel, RegStrategy, TrainConfig, Tree};
use crate::coordinator::parallel::parallel_map_chunked;
use crate::data::dataset::{Dataset, Labels, TaskKind};
use crate::data::value::Value;
use crate::error::{Result, UdtError};
use crate::util::rng::Rng;

/// Gradient-boosting configuration. Fill the fields directly (or start
/// from [`BoostedConfig::default`]) and call
/// [`BoostedConfig::validate`]; [`Boosted::fit`] validates too.
#[derive(Debug, Clone)]
pub struct BoostedConfig {
    /// Boosting rounds (trees per score channel).
    pub n_rounds: usize,
    /// Shrinkage `η` applied to every leaf contribution.
    pub learning_rate: f64,
    /// Depth cap of each round's tree (shallow trees are the point).
    pub max_depth: usize,
    /// Per-round row subsample (without replacement) in (0, 1];
    /// 1.0 = every round sees all rows (stochastic gradient boosting
    /// below that).
    pub subsample: f64,
    /// Subsampling seed.
    pub seed: u64,
    /// Worker threads for each round's fit (0 = all cores).
    pub n_threads: usize,
    /// Selection engine for every round's tree. [`Backend::Binned`] is
    /// the natural fit for boosting (many shallow trees over the same
    /// quantize-once bin lanes); residual fits always run
    /// [`RegStrategy::DirectSse`], which is exactly the regression mode
    /// the binned engine supports.
    pub backend: Backend,
}

impl Default for BoostedConfig {
    fn default() -> Self {
        Self {
            n_rounds: 50,
            learning_rate: 0.1,
            max_depth: 4,
            subsample: 1.0,
            seed: 0xB0_0575,
            n_threads: 1,
            backend: Backend::Superfast,
        }
    }
}

impl BoostedConfig {
    /// Validate the boosting knobs ([`UdtError::InvalidConfig`] on bad ones).
    pub fn validate(&self) -> Result<()> {
        if self.n_rounds == 0 {
            return Err(UdtError::invalid_config("n_rounds must be >= 1"));
        }
        if !(self.learning_rate.is_finite() && self.learning_rate > 0.0) {
            return Err(UdtError::invalid_config(format!(
                "learning_rate must be finite and > 0, got {}",
                self.learning_rate
            )));
        }
        if self.max_depth < 1 {
            return Err(UdtError::invalid_config("max_depth must be >= 1"));
        }
        if !(self.subsample > 0.0 && self.subsample <= 1.0) {
            return Err(UdtError::invalid_config(format!(
                "subsample must be in (0, 1], got {}",
                self.subsample
            )));
        }
        if let Backend::Binned { max_bins } = &self.backend {
            super::validate_max_bins(*max_bins)?;
        }
        Ok(())
    }

    /// The per-round tree configuration this boost run trains with.
    fn round_config(&self) -> TrainConfig {
        TrainConfig {
            max_depth: self.max_depth,
            reg_strategy: RegStrategy::DirectSse,
            n_threads: self.n_threads,
            backend: self.backend.clone(),
            ..Default::default()
        }
    }
}

/// A trained gradient-boosted ensemble.
///
/// `trees` is stored round-major, class-minor: regression and binary
/// classification keep one tree per round; an `n_classes > 2` model
/// keeps `n_classes` one-vs-rest trees per round
/// (`trees[round * n_classes + class]`). Every member is a shallow
/// regression tree over the training dataset's feature space.
#[derive(Debug, Clone)]
pub struct Boosted {
    pub trees: Vec<Tree>,
    pub task: TaskKind,
    pub n_features: usize,
    /// Label-space classes (0 for regression, ≥ 2 for classification).
    pub n_classes: usize,
    /// Shrinkage applied to every leaf contribution.
    pub learning_rate: f64,
    /// Initial score per channel: the target mean (regression) or the
    /// class-prior log-odds (classification; one entry for binary,
    /// `n_classes` for one-vs-rest).
    pub base: Vec<f64>,
}

/// Logistic sigmoid.
#[inline]
pub(crate) fn sigmoid(x: f64) -> f64 {
    1.0 / (1.0 + (-x).exp())
}

/// Prior probability → finite log-odds (clamped away from 0/1 so a
/// single-class training set still yields a finite base score).
fn prior_logit(p: f64) -> f64 {
    let p = p.clamp(1e-6, 1.0 - 1e-6);
    (p / (1.0 - p)).ln()
}

/// The single scoring rule shared by the boxed and compiled prediction
/// paths: per-channel leaf sums → final label. `sums[k]` is the plain
/// sum of channel `k`'s member leaf values (storage order); the scale
/// and base apply here, once, so both paths perform identical float
/// operations.
#[inline]
pub(crate) fn decide_scores(
    task: TaskKind,
    base: &[f64],
    learning_rate: f64,
    sums: &[f64],
) -> NodeLabel {
    match task {
        TaskKind::Regression => NodeLabel::Value(base[0] + learning_rate * sums[0]),
        TaskKind::Classification => {
            if sums.len() == 1 {
                // Binary: class 1 iff the logit is strictly positive
                // (σ(0) = 0.5 ties toward the smaller class id).
                let score = base[0] + learning_rate * sums[0];
                NodeLabel::Class(u16::from(score > 0.0))
            } else {
                // One-vs-rest: argmax score, ties toward the smaller id
                // (strict `>` keeps the first maximum).
                let mut best = 0usize;
                let mut best_score = f64::NEG_INFINITY;
                for (k, &s) in sums.iter().enumerate() {
                    let score = base[k] + learning_rate * s;
                    if score > best_score {
                        best_score = score;
                        best = k;
                    }
                }
                NodeLabel::Class(best as u16)
            }
        }
    }
}

impl Boosted {
    /// Trees per round: one score channel for regression/binary, one per
    /// class for one-vs-rest multiclass.
    pub fn group(&self) -> usize {
        group_of(self.task, self.n_classes)
    }

    /// Boosting rounds this model trained for.
    pub fn n_rounds(&self) -> usize {
        self.trees.len() / self.group().max(1)
    }

    /// Total node count across all member trees.
    pub fn n_nodes(&self) -> usize {
        self.trees.iter().map(Tree::n_nodes).sum()
    }

    /// Train a boosted ensemble. Every round fits a shallow SSE
    /// regression tree on the current residuals through the arena
    /// frontier builder, reusing the dataset's cached
    /// [`crate::data::sorted_index::SortedIndex`] — the root sort is
    /// paid exactly once for the whole run.
    pub fn fit(ds: &Dataset, config: &BoostedConfig) -> Result<Boosted> {
        config.validate()?;
        let n = ds.n_rows();
        if n == 0 {
            return Err(UdtError::data("cannot boost on an empty dataset"));
        }
        let round_cfg = config.round_config();
        let mut rng = Rng::new(config.seed);
        let sample_n = ((n as f64 * config.subsample).round() as usize).clamp(1, n);
        let mut all_rows: Vec<u32> = (0..n as u32).collect();
        let mut round_rows = |rng: &mut Rng, round: usize| -> Vec<u32> {
            if sample_n == n {
                all_rows.clone()
            } else {
                let mut round_rng = rng.fork(round as u64);
                round_rng.shuffle(&mut all_rows);
                all_rows[..sample_n].to_vec()
            }
        };

        match &ds.labels {
            Labels::Reg { values } => {
                let base = values.iter().sum::<f64>() / n as f64;
                let mut score = vec![base; n];
                let mut residual = Labels::Reg {
                    values: vec![0.0; n],
                };
                let mut trees = Vec::with_capacity(config.n_rounds);
                for round in 0..config.n_rounds {
                    if let Labels::Reg { values: res } = &mut residual {
                        for ((res, &y), &s) in res.iter_mut().zip(values).zip(&score) {
                            *res = y - s;
                        }
                    }
                    let rows = round_rows(&mut rng, round);
                    let tree =
                        super::builder::fit_rows_with_labels(ds, &rows, &round_cfg, &residual)?;
                    for (i, s) in score.iter_mut().enumerate() {
                        *s += config.learning_rate * leaf_value_ds(&tree, ds, i);
                    }
                    trees.push(tree);
                }
                Ok(Boosted {
                    trees,
                    task: TaskKind::Regression,
                    n_features: ds.n_features(),
                    n_classes: 0,
                    learning_rate: config.learning_rate,
                    base: vec![base],
                })
            }
            Labels::Class { ids, n_classes } => {
                if *n_classes < 2 {
                    return Err(UdtError::data(format!(
                        "boosted classification needs >= 2 classes, got {n_classes}"
                    )));
                }
                let group = group_of(TaskKind::Classification, *n_classes);
                // Score channel k targets class k (the single binary
                // channel targets class 1).
                let target = |k: usize| if group == 1 { 1u16 } else { k as u16 };
                let base: Vec<f64> = (0..group)
                    .map(|k| {
                        let pos = ids.iter().filter(|&&c| c == target(k)).count();
                        prior_logit(pos as f64 / n as f64)
                    })
                    .collect();
                let mut score: Vec<Vec<f64>> = base.iter().map(|&b| vec![b; n]).collect();
                let mut residual = Labels::Reg {
                    values: vec![0.0; n],
                };
                let mut trees = Vec::with_capacity(config.n_rounds * group);
                for round in 0..config.n_rounds {
                    // One subsample per round, shared by all class
                    // channels (the one-vs-rest trees of a round see the
                    // same rows).
                    let rows = round_rows(&mut rng, round);
                    for k in 0..group {
                        if let Labels::Reg { values: res } = &mut residual {
                            for ((res, &c), &s) in res.iter_mut().zip(ids).zip(&score[k]) {
                                let y = if c == target(k) { 1.0 } else { 0.0 };
                                *res = y - sigmoid(s);
                            }
                        }
                        let tree = super::builder::fit_rows_with_labels(
                            ds, &rows, &round_cfg, &residual,
                        )?;
                        for (i, s) in score[k].iter_mut().enumerate() {
                            *s += config.learning_rate * leaf_value_ds(&tree, ds, i);
                        }
                        trees.push(tree);
                    }
                }
                Ok(Boosted {
                    trees,
                    task: TaskKind::Classification,
                    n_features: ds.n_features(),
                    n_classes: *n_classes,
                    learning_rate: config.learning_rate,
                    base,
                })
            }
        }
    }

    /// Per-channel leaf sums for one materialized row, in storage order
    /// (the accumulation order the compiled path replicates exactly).
    fn sums_values(&self, row: &[Value]) -> Vec<f64> {
        let group = self.group().max(1);
        let mut sums = vec![0.0f64; group];
        for (t, tree) in self.trees.iter().enumerate() {
            sums[t % group] += predict::predict_row(tree, row, usize::MAX, 0)
                .as_value()
                .unwrap_or(f64::NAN);
        }
        sums
    }

    /// Boosted prediction for one materialized row of values.
    pub fn predict_values(&self, row: &[Value]) -> NodeLabel {
        decide_scores(
            self.task,
            &self.base,
            self.learning_rate,
            &self.sums_values(row),
        )
    }

    /// Boosted prediction for row `r` of a dataset (no materialization).
    pub fn predict_ds(&self, ds: &Dataset, r: usize) -> NodeLabel {
        let group = self.group().max(1);
        let mut sums = vec![0.0f64; group];
        for (t, tree) in self.trees.iter().enumerate() {
            sums[t % group] += leaf_value_ds(tree, ds, r);
        }
        decide_scores(self.task, &self.base, self.learning_rate, &sums)
    }

    /// Batch predictions, chunk-parallel over the worker pool (thread
    /// count never changes the output — chunks are independent and
    /// stitched in order). Arity is the caller's contract (the
    /// [`crate::Estimator`] impl checks it).
    pub fn predict_batch_rows(&self, rows: &[Vec<Value>], n_threads: usize) -> Vec<NodeLabel> {
        const CHUNK: usize = 256;
        let out = parallel_map_chunked(rows.len(), CHUNK, n_threads, |start, end| {
            rows[start..end]
                .iter()
                .map(|r| self.predict_values(r))
                .collect::<Vec<_>>()
        });
        out.into_iter().flatten().collect()
    }

    /// Ensemble accuracy over rows (classification).
    pub fn accuracy_rows(&self, ds: &Dataset, rows: &[u32]) -> Result<f64> {
        require_task(TaskKind::Classification, self.task)?;
        require_task(TaskKind::Classification, ds.task())?;
        let correct = rows
            .iter()
            .filter(|&&r| {
                self.predict_ds(ds, r as usize).as_class() == Some(ds.labels.class(r as usize))
            })
            .count();
        Ok(correct as f64 / rows.len().max(1) as f64)
    }

    /// Ensemble (MAE, RMSE) over rows (regression).
    pub fn regression_error(&self, ds: &Dataset, rows: &[u32]) -> Result<(f64, f64)> {
        require_task(TaskKind::Regression, self.task)?;
        require_task(TaskKind::Regression, ds.task())?;
        Ok(super::mae_rmse(rows.iter().map(|&r| {
            (
                self.predict_ds(ds, r as usize)
                    .as_value()
                    .unwrap_or(f64::NAN),
                ds.labels.target(r as usize),
            )
        })))
    }
}

/// Trees per round for a task/class-count pair (shared with the
/// compiled path so the two can never disagree on the layout).
#[inline]
pub(crate) fn group_of(task: TaskKind, n_classes: usize) -> usize {
    if task == TaskKind::Classification && n_classes > 2 {
        n_classes
    } else {
        1
    }
}

/// A member tree's leaf value for dataset row `r` (members are always
/// regression trees; NaN mirrors the compiled table's corrupt-label
/// sentinel and is unreachable for a well-formed model).
#[inline]
fn leaf_value_ds(tree: &Tree, ds: &Dataset, r: usize) -> f64 {
    predict::predict_ds(tree, ds, r, usize::MAX, 0)
        .as_value()
        .unwrap_or(f64::NAN)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate_any, generate_classification, SynthSpec};

    fn reg_ds() -> Dataset {
        generate_any(&SynthSpec::regression("boostr", 1200, 6), 71)
    }

    fn binary_ds() -> Dataset {
        let mut spec = SynthSpec::classification("boostb", 1200, 6, 2);
        spec.cat_frac = 0.25;
        spec.missing_frac = 0.05;
        generate_classification(&spec, 73)
    }

    #[test]
    fn regression_boosting_improves_with_rounds() {
        let ds = reg_ds();
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let few = Boosted::fit(
            &ds,
            &BoostedConfig {
                n_rounds: 1,
                ..Default::default()
            },
        )
        .unwrap();
        let many = Boosted::fit(
            &ds,
            &BoostedConfig {
                n_rounds: 40,
                ..Default::default()
            },
        )
        .unwrap();
        let (_, rmse_few) = few.regression_error(&ds, &rows).unwrap();
        let (_, rmse_many) = many.regression_error(&ds, &rows).unwrap();
        assert!(
            rmse_many < rmse_few,
            "40 rounds ({rmse_many}) must beat 1 round ({rmse_few})"
        );
        assert_eq!(many.n_rounds(), 40);
        assert_eq!(many.trees.len(), 40);
    }

    #[test]
    fn binary_boosting_learns() {
        let ds = binary_ds();
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let model = Boosted::fit(
            &ds,
            &BoostedConfig {
                n_rounds: 40,
                ..Default::default()
            },
        )
        .unwrap();
        let acc = model.accuracy_rows(&ds, &rows).unwrap();
        assert!(acc > 0.8, "train accuracy {acc}");
        assert_eq!(model.group(), 1);
        assert_eq!(model.base.len(), 1);
    }

    #[test]
    fn multiclass_ovr_learns_and_lays_out_round_major() {
        let spec = SynthSpec::classification("boostm", 900, 5, 4);
        let ds = generate_classification(&spec, 79);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let model = Boosted::fit(
            &ds,
            &BoostedConfig {
                n_rounds: 20,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(model.group(), 4);
        assert_eq!(model.trees.len(), 20 * 4);
        assert_eq!(model.n_rounds(), 20);
        assert_eq!(model.base.len(), 4);
        let acc = model.accuracy_rows(&ds, &rows).unwrap();
        assert!(acc > 0.5, "train accuracy {acc}");
    }

    #[test]
    fn boost_run_sorts_each_column_exactly_once() {
        let ds = reg_ds();
        assert_eq!(ds.sort_index_builds(), 0);
        let model = Boosted::fit(
            &ds,
            &BoostedConfig {
                n_rounds: 25,
                subsample: 0.8,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(model.trees.len(), 25);
        // 25 rounds of residual fits, one root sort: every round
        // filtered the dataset's cached SortedIndex.
        assert_eq!(ds.sort_index_builds(), 1);

        // Same property through the classification (one-vs-rest) path.
        let cds = generate_classification(&SynthSpec::classification("bsi", 500, 4, 3), 83);
        Boosted::fit(
            &cds,
            &BoostedConfig {
                n_rounds: 10,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(cds.sort_index_builds(), 1);
    }

    #[test]
    fn binned_backend_boosts_and_quantizes_once() {
        let ds = reg_ds();
        let cfg = |n_rounds| BoostedConfig {
            n_rounds,
            backend: Backend::Binned { max_bins: 64 },
            ..Default::default()
        };
        let few = Boosted::fit(&ds, &cfg(1)).unwrap();
        let many = Boosted::fit(&ds, &cfg(25)).unwrap();
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let (_, rmse_few) = few.regression_error(&ds, &rows).unwrap();
        let (_, rmse_many) = many.regression_error(&ds, &rows).unwrap();
        assert!(
            rmse_many < rmse_few,
            "25 binned rounds ({rmse_many}) must beat 1 ({rmse_few})"
        );
        // Quantize once: 26 residual fits across both runs share a
        // single bin-lane build, just like they share one root sort.
        assert_eq!(ds.bin_index_builds(), 1);
        assert_eq!(ds.sort_index_builds(), 1);
    }

    #[test]
    fn member_trees_respect_the_depth_cap() {
        let ds = binary_ds();
        let model = Boosted::fit(
            &ds,
            &BoostedConfig {
                n_rounds: 8,
                max_depth: 3,
                ..Default::default()
            },
        )
        .unwrap();
        for tree in &model.trees {
            assert!(tree.depth <= 3, "member depth {}", tree.depth);
            assert_eq!(tree.task, TaskKind::Regression);
        }
    }

    #[test]
    fn subsampled_boosting_is_deterministic_per_seed() {
        let ds = binary_ds();
        let cfg = BoostedConfig {
            n_rounds: 6,
            subsample: 0.6,
            ..Default::default()
        };
        let a = Boosted::fit(&ds, &cfg).unwrap();
        let b = Boosted::fit(&ds, &cfg).unwrap();
        assert_eq!(a.trees.len(), b.trees.len());
        for (ta, tb) in a.trees.iter().zip(&b.trees) {
            assert_eq!(ta.n_nodes(), tb.n_nodes());
            for (na, nb) in ta.nodes.iter().zip(&tb.nodes) {
                assert_eq!(na.split, nb.split);
                assert_eq!(na.label, nb.label);
            }
        }
        // Each round subsampled, not trained on everything.
        assert_eq!(a.trees[0].nodes[0].n_samples, 720);
    }

    #[test]
    fn ds_and_row_predictions_agree() {
        let ds = binary_ds();
        let model = Boosted::fit(
            &ds,
            &BoostedConfig {
                n_rounds: 10,
                ..Default::default()
            },
        )
        .unwrap();
        for r in (0..ds.n_rows()).step_by(37) {
            assert_eq!(model.predict_values(&ds.row(r)), model.predict_ds(&ds, r));
        }
        // Batch path is thread-count invariant and agrees row-for-row.
        let rows: Vec<Vec<Value>> = (0..ds.n_rows()).map(|r| ds.row(r)).collect();
        let seq = model.predict_batch_rows(&rows, 1);
        let par = model.predict_batch_rows(&rows, 8);
        assert_eq!(seq, par);
        for (r, label) in seq.iter().enumerate() {
            assert_eq!(*label, model.predict_values(&rows[r]), "row {r}");
        }
    }

    #[test]
    fn invalid_configs_are_rejected() {
        let ds = binary_ds();
        for cfg in [
            BoostedConfig {
                n_rounds: 0,
                ..Default::default()
            },
            BoostedConfig {
                learning_rate: 0.0,
                ..Default::default()
            },
            BoostedConfig {
                learning_rate: f64::NAN,
                ..Default::default()
            },
            BoostedConfig {
                max_depth: 0,
                ..Default::default()
            },
            BoostedConfig {
                subsample: 1.5,
                ..Default::default()
            },
        ] {
            assert!(matches!(
                Boosted::fit(&ds, &cfg),
                Err(UdtError::InvalidConfig(_))
            ));
        }
    }

    #[test]
    fn single_class_training_set_stays_finite() {
        // All rows carry class 0: the prior logit is clamped, residuals
        // are near-constant, and prediction is the majority class.
        let spec = SynthSpec::classification("bone", 120, 3, 2);
        let mut ds = generate_classification(&spec, 91);
        if let Labels::Class { ids, .. } = &mut ds.labels {
            ids.iter_mut().for_each(|c| *c = 0);
        }
        let model = Boosted::fit(
            &ds,
            &BoostedConfig {
                n_rounds: 3,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(model.base[0].is_finite());
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        assert_eq!(model.accuracy_rows(&ds, &rows).unwrap(), 1.0);
    }

    #[test]
    fn decide_scores_tie_breaks_toward_smaller_class() {
        // Binary: a zero logit is class 0 (σ(0) = 0.5, not > 0.5).
        assert_eq!(
            decide_scores(TaskKind::Classification, &[0.0], 0.1, &[0.0]),
            NodeLabel::Class(0)
        );
        // Multiclass: equal scores pick the smallest id.
        assert_eq!(
            decide_scores(TaskKind::Classification, &[1.0, 1.0, 1.0], 0.1, &[2.0, 2.0, 2.0]),
            NodeLabel::Class(0)
        );
        // Regression passes the scaled sum through.
        assert_eq!(
            decide_scores(TaskKind::Regression, &[10.0], 0.5, &[4.0]),
            NodeLabel::Value(12.0)
        );
    }
}
