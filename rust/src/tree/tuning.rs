//! Training-Only-Once Tuning (paper §3–§4).
//!
//! Because every node of the full tree carries a label, the effect of any
//! `(max_depth, min_split)` pair is computable from the validation
//! examples' root-to-leaf *paths* — no retraining. The tuner walks each
//! validation path once, then sweeps the paper's grid:
//! `max_depth ∈ 1..=full_depth` first, then `min_split` from 0 to 4% of
//! the training-set size in 0.02% steps (up to 200 *distinct* settings —
//! grid points that collapse to the same integer `min_split`, and the
//! `min_split = 0` point phase 1 already evaluated, are swept and
//! counted once; see [`distinct_split_grid`]).
//!
//! [`tune_by_retraining`] is the generic baseline (one full training per
//! setting) used by the `ablation_tuning` bench to reproduce the paper's
//! "16.8 s vs 10 ms" churn-modeling comparison. All its retrains hit the
//! dataset's [`crate::data::SortedIndex`] cache, so even the baseline
//! sorts each column exactly once per dataset.

use super::predict::path_ds;
use super::{prune, NodeLabel, TrainConfig, Tree};
use crate::data::dataset::{Dataset, TaskKind};
use crate::error::{Result, UdtError};
use crate::util::timer::Timer;

/// Outcome of a tuning sweep.
#[derive(Debug, Clone)]
pub struct TuneResult {
    pub best_max_depth: usize,
    pub best_min_split: usize,
    /// Validation metric of the winner: accuracy (classification) or
    /// −RMSE (regression) — higher is better in both cases.
    pub best_metric: f64,
    /// Number of hyper-parameter settings evaluated.
    pub n_settings: usize,
    /// Wall-clock of the sweep, milliseconds.
    pub tune_ms: f64,
}

/// The paper's hyper-parameter grid.
#[derive(Debug, Clone)]
pub struct TuneGrid {
    /// `min_split` sweeps `0..=max_frac·n_train` with `n_steps` steps.
    pub min_split_max_frac: f64,
    pub min_split_steps: usize,
}

impl Default for TuneGrid {
    fn default() -> Self {
        Self {
            min_split_max_frac: 0.04,
            min_split_steps: 200,
        }
    }
}

/// Tune on pre-computed validation paths; returns the best setting.
pub fn tune(
    tree: &Tree,
    ds: &Dataset,
    val_rows: &[u32],
    n_train: usize,
    grid: &TuneGrid,
) -> Result<TuneResult> {
    let timer = Timer::start();
    if val_rows.is_empty() {
        return Err(UdtError::data("validation set is empty"));
    }

    // One walk per validation example: node ids along its path.
    let paths: Vec<Vec<u32>> = val_rows
        .iter()
        .map(|&r| path_ds(tree, ds, r as usize))
        .collect();

    // Metric of a prediction set is accumulated incrementally per setting.
    let full_depth = tree.depth as usize;
    let mut n_settings = 0usize;

    // Phase 1: sweep max_depth with min_split = 0.
    let mut best_depth = 1usize;
    let mut best_metric = f64::NEG_INFINITY;
    for depth in 1..=full_depth.max(1) {
        let metric = eval_setting(tree, ds, val_rows, &paths, depth, 0);
        n_settings += 1;
        if metric > best_metric {
            best_metric = metric;
            best_depth = depth;
        }
    }

    // Phase 2: sweep min_split at the chosen depth, over the *distinct*
    // grid values only. `max_split·i/steps` collapses to a handful of
    // values when `max_split < steps` (hundreds of duplicate settings),
    // and `i = 0` repeats the phase-1 winner `(best_depth, 0)` — both
    // used to inflate `n_settings` (the paper's "214.8 sets" headline
    // metric) without evaluating anything new.
    let mut best_split = 0usize;
    for s in distinct_split_grid(n_train, grid) {
        let metric = eval_setting(tree, ds, val_rows, &paths, best_depth, s);
        n_settings += 1;
        if metric > best_metric {
            best_metric = metric;
            best_split = s;
        }
    }

    Ok(TuneResult {
        best_max_depth: best_depth,
        best_min_split: best_split,
        best_metric,
        n_settings,
        tune_ms: timer.ms(),
    })
}

/// The paper grid's *distinct* phase-2 `min_split` values, ascending:
/// `max_split·i/steps` for `i ∈ 0..=steps` with duplicates and the `0`
/// entry removed (`(depth, 0)` is already evaluated by the phase-1 depth
/// sweep). The values are non-decreasing in `i`, so adjacent
/// deduplication is exact.
pub fn distinct_split_grid(n_train: usize, grid: &TuneGrid) -> Vec<usize> {
    let max_split = (n_train as f64 * grid.min_split_max_frac) as usize;
    let steps = grid.min_split_steps.max(1);
    let mut out = Vec::new();
    let mut prev = 0usize;
    for i in 0..=steps {
        let s = max_split * i / steps;
        if s > 0 && s != prev {
            out.push(s);
            prev = s;
        }
    }
    out
}

/// Metric of one `(max_depth, min_split)` setting using the cached paths.
fn eval_setting(
    tree: &Tree,
    ds: &Dataset,
    val_rows: &[u32],
    paths: &[Vec<u32>],
    max_depth: usize,
    min_split: usize,
) -> f64 {
    match ds.task() {
        TaskKind::Classification => {
            let mut correct = 0usize;
            for (&r, path) in val_rows.iter().zip(paths) {
                let label = label_at(tree, path, max_depth, min_split);
                if label.as_class() == Some(ds.labels.class(r as usize)) {
                    correct += 1;
                }
            }
            correct as f64 / val_rows.len() as f64
        }
        TaskKind::Regression => {
            let mut sq = 0.0f64;
            for (&r, path) in val_rows.iter().zip(paths) {
                let label = label_at(tree, path, max_depth, min_split);
                let err = label.as_value().unwrap_or(f64::NAN) - ds.labels.target(r as usize);
                sq += err * err;
            }
            -(sq / val_rows.len() as f64).sqrt()
        }
    }
}

/// Prediction along a cached path under the given hyper-parameters —
/// the path equivalent of Algorithm 7.
#[inline]
fn label_at(tree: &Tree, path: &[u32], max_depth: usize, min_split: usize) -> NodeLabel {
    let mut last = path[0];
    for (i, &node_id) in path.iter().enumerate() {
        let node = &tree.nodes[node_id as usize];
        last = node_id;
        let depth = i + 1;
        if node.is_leaf() || (node.n_samples as usize) < min_split || depth >= max_depth {
            break;
        }
    }
    tree.nodes[last as usize].label
}

/// Full pipeline step: tune, then prune the tree to the winning setting.
pub fn tune_and_prune(
    tree: &Tree,
    ds: &Dataset,
    val_rows: &[u32],
    n_train: usize,
    grid: &TuneGrid,
) -> Result<(TuneResult, Tree)> {
    let result = tune(tree, ds, val_rows, n_train, grid)?;
    let pruned = prune::prune(tree, result.best_max_depth, result.best_min_split);
    Ok((result, pruned))
}

/// Generic baseline: retrain a tree for every grid setting (what the
/// paper's "generic tuning process" does). Returns the same `TuneResult`
/// shape; `tune_ms` then contains the full retraining cost.
pub fn tune_by_retraining(
    ds: &Dataset,
    train_rows: &[u32],
    val_rows: &[u32],
    base: &TrainConfig,
    full_depth: usize,
    grid: &TuneGrid,
) -> Result<TuneResult> {
    let timer = Timer::start();
    let mut n_settings = 0usize;
    let mut best = (1usize, 0usize, f64::NEG_INFINITY);

    let eval = |max_depth: usize, min_split: usize| -> Result<f64> {
        let cfg = TrainConfig {
            max_depth,
            min_samples_split: min_split.max(2),
            ..base.clone()
        };
        let tree = Tree::fit_rows(ds, train_rows, &cfg)?;
        Ok(match ds.task() {
            TaskKind::Classification => tree.accuracy_rows(ds, val_rows)?,
            TaskKind::Regression => -tree.regression_error(ds, val_rows)?.1,
        })
    };

    for depth in 1..=full_depth.max(1) {
        let m = eval(depth, 0)?;
        n_settings += 1;
        if m > best.2 {
            best = (depth, 0, m);
        }
    }
    // Same deduplicated grid as `tune` — the two tuners must evaluate
    // (and count) identical setting lists for the bench comparison to be
    // apples-to-apples.
    for s in distinct_split_grid(train_rows.len(), grid) {
        let m = eval(best.0, s)?;
        n_settings += 1;
        if m > best.2 {
            best = (best.0, s, m);
        }
    }

    Ok(TuneResult {
        best_max_depth: best.0,
        best_min_split: best.1,
        best_metric: best.2,
        n_settings,
        tune_ms: timer.ms(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate_classification, SynthSpec};

    fn noisy_ds() -> Dataset {
        let mut spec = SynthSpec::classification("t", 3000, 6, 2);
        spec.noise = 0.25; // overfitting-prone
        generate_classification(&spec, 17)
    }

    #[test]
    fn tuned_never_worse_than_full_tree_on_val() {
        let ds = noisy_ds();
        let (train, val, _) = ds.split_indices(0.8, 0.1, 3);
        let tree = Tree::fit_rows(&ds, &train, &TrainConfig::default()).unwrap();
        let full_acc = tree.accuracy_rows(&ds, &val).unwrap();
        let grid = TuneGrid::default();
        let r = tune(&tree, &ds, &val, train.len(), &grid).unwrap();
        assert!(
            r.best_metric >= full_acc - 1e-12,
            "tuned {} < full {full_acc}",
            r.best_metric
        );
        // The grid includes the full tree's own setting, so this is
        // exact; settings = the depth sweep + the distinct min_split
        // values (duplicates and the re-evaluated 0 are not counted).
        assert_eq!(
            r.n_settings,
            tree.depth as usize + distinct_split_grid(train.len(), &grid).len()
        );
        assert!(r.n_settings > 50);
    }

    #[test]
    fn tuning_reduces_overfit_gap() {
        let ds = noisy_ds();
        let (train, val, test) = ds.split_indices(0.8, 0.1, 4);
        let tree = Tree::fit_rows(&ds, &train, &TrainConfig::default()).unwrap();
        let (r, pruned) =
            tune_and_prune(&tree, &ds, &val, train.len(), &TuneGrid::default()).unwrap();
        let full_test = tree.accuracy_rows(&ds, &test).unwrap();
        let tuned_test = pruned.accuracy_rows(&ds, &test).unwrap();
        // With 25% label noise the full tree memorizes noise; the tuned
        // tree should do at least as well on held-out data (allow a tiny
        // slack for val/test mismatch).
        assert!(
            tuned_test >= full_test - 0.02,
            "tuned {tuned_test} vs full {full_test} (picked depth {}, split {})",
            r.best_max_depth,
            r.best_min_split
        );
        assert!(pruned.n_nodes() <= tree.n_nodes());
    }

    #[test]
    fn path_based_metric_matches_direct_prediction() {
        let ds = noisy_ds();
        let (train, val, _) = ds.split_indices(0.8, 0.1, 5);
        let tree = Tree::fit_rows(&ds, &train, &TrainConfig::default()).unwrap();
        let paths: Vec<Vec<u32>> = val
            .iter()
            .map(|&r| super::path_ds(&tree, &ds, r as usize))
            .collect();
        for (depth, split) in [(1, 0), (3, 0), (5, 10), (100, 50)] {
            let via_paths = eval_setting(&tree, &ds, &val, &paths, depth, split);
            let direct = {
                let correct = val
                    .iter()
                    .filter(|&&r| {
                        super::super::predict::predict_ds(&tree, &ds, r as usize, depth, split)
                            .as_class()
                            == Some(ds.labels.class(r as usize))
                    })
                    .count();
                correct as f64 / val.len() as f64
            };
            assert!(
                (via_paths - direct).abs() < 1e-12,
                "depth={depth} split={split}: {via_paths} vs {direct}"
            );
        }
    }

    #[test]
    fn retraining_baseline_agrees_on_winner_quality() {
        // Small instance: the once-tuned metric and the retrained metric
        // for the same (depth=full, split=0) must coincide; and the two
        // tuners must find settings of comparable validation quality.
        let mut spec = SynthSpec::classification("t", 600, 4, 2);
        spec.noise = 0.2;
        let ds = generate_classification(&spec, 23);
        let (train, val, _) = ds.split_indices(0.8, 0.1, 6);
        let cfg = TrainConfig::default();
        let tree = Tree::fit_rows(&ds, &train, &cfg).unwrap();
        let grid = TuneGrid {
            min_split_steps: 20,
            ..Default::default()
        };
        let fast = tune(&tree, &ds, &val, train.len(), &grid).unwrap();
        let slow =
            tune_by_retraining(&ds, &train, &val, &cfg, tree.depth as usize, &grid).unwrap();
        assert!((fast.best_metric - slow.best_metric).abs() < 0.05);
        assert_eq!(fast.n_settings, slow.n_settings);
    }

    #[test]
    fn retraining_tuner_sorts_each_column_once() {
        let mut spec = SynthSpec::classification("ts", 400, 4, 2);
        spec.noise = 0.1;
        let ds = generate_classification(&spec, 41);
        let (train, val, _) = ds.split_indices(0.8, 0.1, 9);
        let cfg = TrainConfig::default();
        let tree = Tree::fit_rows(&ds, &train, &cfg).unwrap();
        let grid = TuneGrid {
            min_split_steps: 5,
            ..Default::default()
        };
        let _ = tune_by_retraining(&ds, &train, &val, &cfg, tree.depth as usize, &grid).unwrap();
        // Dozens of retrains, one sort: every fit filtered the cache.
        assert_eq!(ds.sort_index_builds(), 1);
    }

    #[test]
    fn phase2_grid_counts_only_distinct_settings() {
        // Regression guard for the duplicate-grid bug: with 100 training
        // rows and the default 200-step grid, `max_split = 4` and the
        // old sweep evaluated 201 phase-2 settings — 197 of them
        // duplicates of {0, 1, 2, 3, 4}, with i = 0 re-evaluating the
        // phase-1 winner. The deduplicated sweep pins n_settings to
        // depth + 4 exactly.
        let spec = SynthSpec::classification("dedup", 125, 4, 2);
        let ds = generate_classification(&spec, 61);
        let train: Vec<u32> = (0..100).collect();
        let val: Vec<u32> = (100..125).collect();
        let tree = Tree::fit_rows(&ds, &train, &TrainConfig::default()).unwrap();
        let grid = TuneGrid::default();
        // 100 train rows × 4% = max_split 4 → distinct values {1, 2, 3, 4}.
        assert_eq!(distinct_split_grid(train.len(), &grid), vec![1, 2, 3, 4]);
        let r = tune(&tree, &ds, &val, train.len(), &grid).unwrap();
        assert_eq!(r.n_settings, tree.depth as usize + 4);

        // The retraining baseline counts the identical grid.
        let slow = tune_by_retraining(
            &ds,
            &train,
            &val,
            &TrainConfig::default(),
            tree.depth as usize,
            &grid,
        )
        .unwrap();
        assert_eq!(slow.n_settings, r.n_settings);

        // A grid finer than max_split keeps every distinct value once; a
        // coarser one subsamples without duplicates.
        let coarse = TuneGrid {
            min_split_steps: 2,
            ..Default::default()
        };
        assert_eq!(distinct_split_grid(train.len(), &coarse), vec![2, 4]);
        assert_eq!(distinct_split_grid(0, &grid), Vec::<usize>::new());
    }

    #[test]
    fn regression_tuning_runs() {
        let spec = crate::data::synth::SynthSpec::regression("r", 800, 5);
        let ds = crate::data::synth::generate_regression(&spec, 7);
        let (train, val, _) = ds.split_indices(0.8, 0.1, 8);
        let tree = Tree::fit_rows(&ds, &train, &TrainConfig::default()).unwrap();
        let r = tune(&tree, &ds, &val, train.len(), &TuneGrid::default()).unwrap();
        assert!(r.best_metric.is_finite());
        assert!(r.best_max_depth >= 1);
    }
}
