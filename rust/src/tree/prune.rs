//! Pruning: materialize the sub-tree a `(max_depth, min_split)` setting
//! actually uses, dropping everything below the cut (paper §3: "the tree
//! model will be pruned based on the optimal evaluation result").

use super::{Node, Tree};

/// Return a new tree equivalent to predicting on `tree` with the given
/// hyper-parameters: nodes at `depth == max_depth` or with
/// `n_samples < min_split` become leaves; unreachable nodes are dropped
/// and the arena is re-packed breadth-first.
pub fn prune(tree: &Tree, max_depth: usize, min_split: usize) -> Tree {
    let mut nodes: Vec<Node> = Vec::new();
    let mut depth = 0u16;
    // BFS with id remapping. Queue holds (old_id, new_parent_slot, is_pos).
    let mut queue: Vec<(u32, u32)> = Vec::new(); // (old id, new id)
    nodes.push(tree.nodes[Tree::ROOT as usize].clone());
    queue.push((Tree::ROOT, 0));

    let mut qi = 0;
    while qi < queue.len() {
        let (old_id, new_id) = queue[qi];
        qi += 1;
        let old = &tree.nodes[old_id as usize];
        depth = depth.max(old.depth);
        let cut = old.is_leaf()
            || old.depth as usize >= max_depth
            || (old.n_samples as usize) < min_split;
        if cut {
            let n = &mut nodes[new_id as usize];
            n.split = None;
            n.children = None;
        } else {
            // ANALYZE-ALLOW(no-unwrap): un-cut nodes are non-leaf and carry children
            let (pos, neg) = old.children.unwrap();
            let pos_new = nodes.len() as u32;
            let neg_new = pos_new + 1;
            nodes.push(tree.nodes[pos as usize].clone());
            nodes.push(tree.nodes[neg as usize].clone());
            nodes[new_id as usize].children = Some((pos_new, neg_new));
            queue.push((pos, pos_new));
            queue.push((neg, neg_new));
        }
    }

    // Depth of the pruned tree = max over kept nodes.
    let depth = nodes.iter().map(|n| n.depth).max().unwrap_or(0);
    Tree {
        nodes,
        task: tree.task,
        n_features: tree.n_features,
        depth,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate_classification, SynthSpec};
    use crate::tree::{predict::predict_ds, TrainConfig};

    fn tree_and_ds() -> (Tree, crate::data::dataset::Dataset) {
        let spec = SynthSpec::classification("t", 1000, 5, 3);
        let ds = generate_classification(&spec, 13);
        let tree = Tree::fit(&ds, &TrainConfig::default()).unwrap();
        (tree, ds)
    }

    #[test]
    fn pruned_predictions_match_hyperparameter_predictions() {
        let (tree, ds) = tree_and_ds();
        for (depth, split) in [(1, 0), (4, 0), (6, 25), (1000, 100)] {
            let pruned = prune(&tree, depth, split);
            for r in (0..ds.n_rows()).step_by(37) {
                let a = predict_ds(&tree, &ds, r, depth, split);
                let b = predict_ds(&pruned, &ds, r, usize::MAX, 0);
                assert_eq!(a, b, "depth={depth} split={split} row={r}");
            }
        }
    }

    #[test]
    fn prune_to_depth_1_is_single_node() {
        let (tree, _) = tree_and_ds();
        let p = prune(&tree, 1, 0);
        assert_eq!(p.n_nodes(), 1);
        assert!(p.nodes[0].is_leaf());
        assert_eq!(p.depth, 1);
    }

    #[test]
    fn prune_with_no_limits_is_identity_shape() {
        let (tree, _) = tree_and_ds();
        let p = prune(&tree, usize::MAX, 0);
        assert_eq!(p.n_nodes(), tree.n_nodes());
        assert_eq!(p.depth, tree.depth);
        assert_eq!(p.n_leaves(), tree.n_leaves());
    }

    #[test]
    fn pruned_tree_is_smaller_and_consistent() {
        let (tree, _) = tree_and_ds();
        let p = prune(&tree, (tree.depth / 2).max(1) as usize, 10);
        assert!(p.n_nodes() < tree.n_nodes());
        // Arena invariants: children in range, leaves have no split.
        for n in &p.nodes {
            match (n.split.as_ref(), n.children) {
                (Some(_), Some((a, b))) => {
                    assert!((a as usize) < p.n_nodes() && (b as usize) < p.n_nodes());
                }
                (None, None) => {}
                other => panic!("inconsistent node {other:?}"),
            }
        }
    }
}
