//! Hybrid feature values and the paper's comparison semantics (§2, Table 3).
//!
//! A feature cell is numeric, categorical, or missing — *without
//! pre-encoding*. Comparisons are total but deliberately "false-biased":
//!
//! * numeric ⋈ numeric — usual IEEE ordering / equality;
//! * categorical = categorical — identity; `≤ / >` between categoricals is
//!   **false** (no order is assumed);
//! * numeric ⋈ categorical — equality false, inequality true, ordered
//!   comparisons false (Table 3: `10 ≤ 'cat'` → false, `10 > 'cat'` → false);
//! * missing ⋈ anything — every split predicate evaluates false, which is
//!   exactly the paper's "leave missing values untouched": they always
//!   flow to the negative branch and never contribute to a positive set.

use super::interner::CatId;

/// One cell of a hybrid feature column.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Value {
    /// Numeric value (parsed as `f64`).
    Num(f64),
    /// Interned categorical value.
    Cat(CatId),
    /// Missing entry — kept untouched, never imputed.
    Missing,
}

impl Value {
    #[inline]
    pub fn is_num(&self) -> bool {
        matches!(self, Value::Num(_))
    }

    #[inline]
    pub fn is_cat(&self) -> bool {
        matches!(self, Value::Cat(_))
    }

    #[inline]
    pub fn is_missing(&self) -> bool {
        matches!(self, Value::Missing)
    }

    #[inline]
    pub fn as_num(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    #[inline]
    pub fn as_cat(&self) -> Option<CatId> {
        match self {
            Value::Cat(c) => Some(*c),
            _ => None,
        }
    }

    /// Paper Table 3 equality: same-type identity, cross-type always false,
    /// missing equals nothing (including missing).
    #[inline]
    pub fn eq_value(&self, other: &Value) -> bool {
        match (self, other) {
            (Value::Num(a), Value::Num(b)) => a == b,
            (Value::Cat(a), Value::Cat(b)) => a == b,
            _ => false,
        }
    }

    /// Paper Table 3 `≤`: only defined (possibly true) between numerics.
    #[inline]
    pub fn le_value(&self, other: &Value) -> bool {
        match (self, other) {
            (Value::Num(a), Value::Num(b)) => a <= b,
            _ => false,
        }
    }

    /// Paper Table 3 `>`: only defined (possibly true) between numerics.
    #[inline]
    pub fn gt_value(&self, other: &Value) -> bool {
        match (self, other) {
            (Value::Num(a), Value::Num(b)) => a > b,
            _ => false,
        }
    }
}

/// Parse a raw text cell using the paper's "read as a number first,
/// convert to categorical if the conversion fails" rule. `intern` is
/// called only for categorical cells.
pub fn parse_cell(raw: &str, mut intern: impl FnMut(&str) -> CatId) -> Value {
    let t = raw.trim();
    if t.is_empty() || t == "?" || t.eq_ignore_ascii_case("na") || t.eq_ignore_ascii_case("nan")
        || t.eq_ignore_ascii_case("null")
    {
        return Value::Missing;
    }
    match t.parse::<f64>() {
        Ok(x) if x.is_finite() => Value::Num(x),
        _ => Value::Cat(intern(t)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::interner::Interner;

    #[test]
    fn table3_semantics() {
        let mut i = Interner::new();
        let cat = Value::Cat(i.intern("cat"));
        let ten = Value::Num(10.0);
        // Table 3 rows:
        assert!(!ten.eq_value(&cat)); // 10 = 'cat' → False
        assert!(!ten.le_value(&cat)); // 10 ≤ 'cat' → False
        assert!(!ten.gt_value(&cat)); // 10 > 'cat' → False
                                      // 10 ≠ 'cat' → True is the negation of eq:
        assert!(!ten.eq_value(&cat));
    }

    #[test]
    fn same_type_comparisons() {
        let mut i = Interner::new();
        let a = Value::Cat(i.intern("a"));
        let a2 = Value::Cat(i.intern("a"));
        let b = Value::Cat(i.intern("b"));
        assert!(a.eq_value(&a2));
        assert!(!a.eq_value(&b));
        assert!(!a.le_value(&a2)); // no order between categoricals
        assert!(Value::Num(1.0).le_value(&Value::Num(1.0)));
        assert!(Value::Num(2.0).gt_value(&Value::Num(1.0)));
        assert!(!Value::Num(1.0).gt_value(&Value::Num(1.0)));
    }

    #[test]
    fn missing_compares_false_with_everything() {
        let m = Value::Missing;
        for v in [Value::Num(0.0), Value::Missing] {
            assert!(!m.eq_value(&v));
            assert!(!m.le_value(&v));
            assert!(!m.gt_value(&v));
            assert!(!v.le_value(&m));
            assert!(!v.gt_value(&m));
        }
    }

    #[test]
    fn parse_cell_hybrid_rule() {
        let mut i = Interner::new();
        assert_eq!(parse_cell("3.5", |s| i.intern(s)), Value::Num(3.5));
        assert_eq!(parse_cell(" -2 ", |s| i.intern(s)), Value::Num(-2.0));
        assert!(parse_cell("cat", |s| i.intern(s)).is_cat());
        assert!(parse_cell("", |s| i.intern(s)).is_missing());
        assert!(parse_cell("?", |s| i.intern(s)).is_missing());
        assert!(parse_cell("NA", |s| i.intern(s)).is_missing());
        // "inf" parses as f64 infinity — not finite, so treated categorical.
        assert!(parse_cell("inf", |s| i.intern(s)).is_cat());
        // Mixed column entry like "12abc" is categorical.
        assert!(parse_cell("12abc", |s| i.intern(s)).is_cat());
    }
}
