//! String interner for categorical values.
//!
//! Every distinct categorical string in a dataset maps to a dense
//! [`CatId`]; columns store the 4-byte id instead of the string, and split
//! predicates compare ids. One interner is shared per dataset so ids are
//! stable across columns (a value like `"unknown"` appearing in several
//! columns interns once).

use std::collections::HashMap;

/// Dense id of an interned categorical string.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CatId(pub u32);

/// Two-way string ↔ id table.
#[derive(Debug, Clone, Default)]
pub struct Interner {
    by_name: HashMap<String, CatId>,
    names: Vec<String>,
}

impl Interner {
    pub fn new() -> Self {
        Self::default()
    }

    /// Intern a string, returning its stable id.
    pub fn intern(&mut self, s: &str) -> CatId {
        if let Some(&id) = self.by_name.get(s) {
            return id;
        }
        let id = CatId(self.names.len() as u32);
        self.names.push(s.to_string());
        self.by_name.insert(s.to_string(), id);
        id
    }

    /// Look up without interning.
    pub fn get(&self, s: &str) -> Option<CatId> {
        self.by_name.get(s).copied()
    }

    /// Resolve an id back to its string.
    pub fn name(&self, id: CatId) -> &str {
        &self.names[id.0 as usize]
    }

    /// All interned strings in id order (id `i` ↔ `names()[i]`). Interning
    /// them into a fresh interner in order reproduces identical ids — the
    /// basis of model-bundle serialization.
    pub fn names(&self) -> &[String] {
        &self.names
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intern_is_idempotent() {
        let mut i = Interner::new();
        let a = i.intern("red");
        let b = i.intern("red");
        assert_eq!(a, b);
        assert_eq!(i.len(), 1);
    }

    #[test]
    fn ids_are_dense_and_resolve() {
        let mut i = Interner::new();
        let ids: Vec<CatId> = ["x", "y", "z"].iter().map(|s| i.intern(s)).collect();
        assert_eq!(ids, vec![CatId(0), CatId(1), CatId(2)]);
        assert_eq!(i.name(ids[1]), "y");
        assert_eq!(i.get("z"), Some(CatId(2)));
        assert_eq!(i.get("w"), None);
    }

    #[test]
    fn distinct_strings_distinct_ids() {
        let mut i = Interner::new();
        assert_ne!(i.intern("a"), i.intern("b"));
    }
}
