//! Dataset container: hybrid feature columns + labels + interner.

use super::column::Column;
use super::column_data::BinLane;
use super::interner::Interner;
use super::sorted_index::SortedIndex;
use super::value::Value;
use crate::error::{Result, UdtError};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};

/// Classification or regression.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    Classification,
    Regression,
}

/// Label storage. Classification labels are dense `u16` class ids;
/// regression labels are `f64` targets.
#[derive(Debug, Clone)]
pub enum Labels {
    Class { ids: Vec<u16>, n_classes: usize },
    Reg { values: Vec<f64> },
}

impl Labels {
    pub fn len(&self) -> usize {
        match self {
            Labels::Class { ids, .. } => ids.len(),
            Labels::Reg { values } => values.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn kind(&self) -> TaskKind {
        match self {
            Labels::Class { .. } => TaskKind::Classification,
            Labels::Reg { .. } => TaskKind::Regression,
        }
    }

    pub fn n_classes(&self) -> usize {
        match self {
            Labels::Class { n_classes, .. } => *n_classes,
            Labels::Reg { .. } => 0,
        }
    }

    #[inline]
    pub fn class(&self, row: usize) -> u16 {
        match self {
            Labels::Class { ids, .. } => ids[row],
            // ANALYZE-ALLOW(no-unwrap): accessor misuse across task kinds is an internal bug
            Labels::Reg { .. } => panic!("class() on regression labels"),
        }
    }

    #[inline]
    pub fn target(&self, row: usize) -> f64 {
        match self {
            Labels::Reg { values } => values[row],
            // ANALYZE-ALLOW(no-unwrap): accessor misuse across task kinds is an internal bug
            Labels::Class { .. } => panic!("target() on classification labels"),
        }
    }
}

/// Dataset-level quantization for binned training: one [`BinLane`] per
/// numeric-bearing column, all built from the cached [`SortedIndex`] at
/// a single `max_bins`. Memoized on the dataset next to the sort cache
/// (see [`Dataset::binned_index`]) so forest bags and boosting rounds
/// quantize each column exactly once.
#[derive(Debug, Clone)]
pub struct BinnedIndex {
    /// The bin budget the lanes were built with.
    pub max_bins: usize,
    /// One entry per feature; `None` when the column has no numeric
    /// cells (pure categorical / all missing).
    pub lanes: Vec<Option<BinLane>>,
}

impl BinnedIndex {
    /// Quantize every numeric lane of the cached root sort. `O(K·M)` —
    /// each column's sorted value lane is walked once.
    pub fn build(index: &SortedIndex, n_rows: usize, max_bins: usize) -> BinnedIndex {
        let lanes = index
            .features
            .iter()
            .map(|f| BinLane::build(&f.num_rows, &f.num_vals, n_rows, max_bins))
            .collect();
        BinnedIndex { max_bins, lanes }
    }

    /// True when every built lane binned losslessly (each column's
    /// distinct numeric count ≤ `max_bins`), i.e. binned selection is
    /// exact-equivalent to the Superfast path.
    pub fn all_exact(&self) -> bool {
        self.lanes.iter().flatten().all(|l| l.is_exact)
    }

    /// Resident bytes of all bin-id lanes and edge tables.
    pub fn approx_bytes(&self) -> usize {
        self.lanes.iter().flatten().map(BinLane::approx_bytes).sum()
    }

    /// Resident bytes, counting each lane allocation at most once across
    /// every index threaded through the same `seen` set.
    pub fn approx_bytes_dedup(&self, seen: &mut std::collections::HashSet<usize>) -> usize {
        self.lanes
            .iter()
            .flatten()
            .map(|l| l.approx_bytes_dedup(seen))
            .sum()
    }
}

/// An in-memory tabular dataset.
///
/// The string interner and class names are `Arc`-shared: row-subset
/// views ([`Dataset::subset`]) and model bundles reference them instead
/// of deep-cloning per call. The per-feature root sort is memoized in a
/// [`SortedIndex`] built lazily on first fit (see
/// [`Dataset::sorted_index`]).
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    pub columns: Vec<Column>,
    pub labels: Labels,
    pub interner: Arc<Interner>,
    /// Human-readable class names (classification only, may be empty).
    pub class_names: Arc<Vec<String>>,
    /// Lazily-built per-feature sort cache (see `data/sorted_index.rs`).
    sorted: OnceLock<Arc<SortedIndex>>,
    /// How many times this dataset built a `SortedIndex` (test
    /// instrumentation for the sort-once contract).
    sort_builds: Arc<AtomicUsize>,
    /// Lazily-built quantization cache for binned training (see
    /// [`Dataset::binned_index`]).
    binned: OnceLock<Arc<BinnedIndex>>,
    /// How many times this dataset built a `BinnedIndex` (test
    /// instrumentation for the quantize-once contract).
    bin_builds: Arc<AtomicUsize>,
}

impl Dataset {
    pub fn new(
        name: impl Into<String>,
        columns: Vec<Column>,
        labels: Labels,
        interner: impl Into<Arc<Interner>>,
    ) -> Result<Self> {
        let n = labels.len();
        for c in &columns {
            if c.len() != n {
                return Err(UdtError::data(format!(
                    "column `{}` has {} rows but labels have {}",
                    c.name,
                    c.len(),
                    n
                )));
            }
        }
        Ok(Self {
            name: name.into(),
            columns,
            labels,
            interner: interner.into(),
            class_names: Arc::new(Vec::new()),
            sorted: OnceLock::new(),
            sort_builds: Arc::new(AtomicUsize::new(0)),
            binned: OnceLock::new(),
            bin_builds: Arc::new(AtomicUsize::new(0)),
        })
    }

    pub fn n_rows(&self) -> usize {
        self.labels.len()
    }

    pub fn n_features(&self) -> usize {
        self.columns.len()
    }

    pub fn task(&self) -> TaskKind {
        self.labels.kind()
    }

    #[inline]
    pub fn value(&self, feature: usize, row: usize) -> Value {
        self.columns[feature].get(row)
    }

    /// One example as a row of values (allocates; for serving/tests).
    pub fn row(&self, row: usize) -> Vec<Value> {
        self.columns.iter().map(|c| c.get(row)).collect()
    }

    /// Number of distinct numeric values of feature `f` — the paper's `N`
    /// on the numeric side — memoized alongside the sort-index cache
    /// (derived from the sorted value lane, never re-sorted per call).
    pub fn unique_numeric_count(&self, f: usize) -> usize {
        self.sorted_index().features[f].n_unique_num
    }

    /// The cached per-feature root sort (UDT Algorithm 5 line 2), built
    /// on first use and shared by every subsequent fit — forest bags and
    /// tuning refits filter this order by row membership instead of
    /// re-sorting.
    ///
    /// Contract: the cache mirrors `columns` (and, for regression,
    /// `labels`) as of the first call. Nothing in this crate mutates a
    /// dataset after construction, but both fields are public — callers
    /// that edit cell values (e.g. imputation) **must** call
    /// [`Dataset::invalidate_sort_cache`] before the next fit, or the
    /// stale order silently corrupts training.
    pub fn sorted_index(&self) -> &SortedIndex {
        self.sorted.get_or_init(|| {
            self.sort_builds.fetch_add(1, Ordering::Relaxed);
            Arc::new(SortedIndex::build(&self.columns, &self.labels))
        })
    }

    /// Drop the memoized [`SortedIndex`] (and the [`BinnedIndex`]
    /// derived from it) after mutating `columns` or regression `labels`;
    /// the next fit re-sorts (and the build counters advance again).
    pub fn invalidate_sort_cache(&mut self) {
        self.sorted = OnceLock::new();
        self.binned = OnceLock::new();
    }

    /// How many times [`Dataset::sorted_index`] actually sorted (0 until
    /// the first fit, then exactly 1 for the lifetime of the dataset).
    pub fn sort_index_builds(&self) -> usize {
        self.sort_builds.load(Ordering::Relaxed)
    }

    /// The cached dataset-level quantization at `max_bins`, built on
    /// first use from the sorted index and shared by every binned fit —
    /// forest bags and boosting rounds reuse the same bin lanes. A call
    /// with a *different* `max_bins` than the cached one builds a fresh
    /// uncached instance (the common paths — one configured B per
    /// training run — always hit the cache).
    pub fn binned_index(&self, max_bins: usize) -> Arc<BinnedIndex> {
        let cached = self.binned.get_or_init(|| {
            self.bin_builds.fetch_add(1, Ordering::Relaxed);
            Arc::new(BinnedIndex::build(
                self.sorted_index(),
                self.n_rows(),
                max_bins,
            ))
        });
        if cached.max_bins == max_bins {
            Arc::clone(cached)
        } else {
            self.bin_builds.fetch_add(1, Ordering::Relaxed);
            Arc::new(BinnedIndex::build(
                self.sorted_index(),
                self.n_rows(),
                max_bins,
            ))
        }
    }

    /// How many times [`Dataset::binned_index`] actually quantized (test
    /// instrumentation for the quantize-once contract).
    pub fn bin_index_builds(&self) -> usize {
        self.bin_builds.load(Ordering::Relaxed)
    }

    /// Deterministic train/validation/test split by shuffled row ids
    /// (the paper uses 80/10/10).
    pub fn split_indices(
        &self,
        train_frac: f64,
        val_frac: f64,
        seed: u64,
    ) -> (Vec<u32>, Vec<u32>, Vec<u32>) {
        let n = self.n_rows();
        let mut idx: Vec<u32> = (0..n as u32).collect();
        let mut rng = crate::util::rng::Rng::new(seed);
        rng.shuffle(&mut idx);
        let n_train = ((n as f64) * train_frac).round() as usize;
        let n_val = ((n as f64) * val_frac).round() as usize;
        let n_train = n_train.min(n);
        let n_val = n_val.min(n - n_train);
        let train = idx[..n_train].to_vec();
        let val = idx[n_train..n_train + n_val].to_vec();
        let test = idx[n_train + n_val..].to_vec();
        (train, val, test)
    }

    /// Materialize a subset of rows as a new dataset (used by tests and
    /// the bench harness; the tree builder itself works on index sets).
    /// The interner and class names are shared, not deep-cloned.
    pub fn subset(&self, rows: &[u32]) -> Dataset {
        let columns = self
            .columns
            .iter()
            .map(|c| Column::from_data(c.name.clone(), c.data.gather(rows)))
            .collect();
        let labels = match &self.labels {
            Labels::Class { ids, n_classes } => Labels::Class {
                ids: rows.iter().map(|&r| ids[r as usize]).collect(),
                n_classes: *n_classes,
            },
            Labels::Reg { values } => Labels::Reg {
                values: rows.iter().map(|&r| values[r as usize]).collect(),
            },
        };
        Dataset {
            name: self.name.clone(),
            columns,
            labels,
            interner: Arc::clone(&self.interner),
            class_names: Arc::clone(&self.class_names),
            sorted: OnceLock::new(),
            sort_builds: Arc::new(AtomicUsize::new(0)),
            binned: OnceLock::new(),
            bin_builds: Arc::new(AtomicUsize::new(0)),
        }
    }

    /// Approximate resident memory of the feature matrix, in bytes
    /// (typed lanes + kind masks — pure columns carry one lane, only
    /// hybrid columns pay for both — plus the bin-id lanes and edge
    /// tables of the quantization cache when it has been built).
    ///
    /// `Arc`-shared lane allocations are counted once even when several
    /// columns alias the same storage; to sum multiple datasets that
    /// share lanes (forest bags, subsets holding clones), thread one
    /// `seen` set through [`Dataset::approx_bytes_dedup`] instead of
    /// adding the per-dataset numbers.
    pub fn approx_bytes(&self) -> usize {
        self.approx_bytes_dedup(&mut std::collections::HashSet::new())
    }

    /// [`Dataset::approx_bytes`] with caller-owned dedup state: lane
    /// allocations already recorded in `seen` contribute 0 bytes, so
    /// summing clones over one set counts shared storage exactly once.
    pub fn approx_bytes_dedup(&self, seen: &mut std::collections::HashSet<usize>) -> usize {
        self.columns
            .iter()
            .map(|c| c.data.approx_bytes_dedup(seen))
            .sum::<usize>()
            + match &self.labels {
                Labels::Class { ids, .. } => ids.len() * 2,
                Labels::Reg { values } => values.len() * 8,
            }
            + self.binned.get().map_or(0, |b| b.approx_bytes_dedup(seen))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        let mut interner = Interner::new();
        let a = interner.intern("a");
        let cols = vec![
            Column::new("f0", vec![Value::Num(1.0), Value::Num(2.0), Value::Cat(a)]),
            Column::new("f1", vec![Value::Missing, Value::Num(0.5), Value::Num(0.1)]),
        ];
        let labels = Labels::Class {
            ids: vec![0, 1, 0],
            n_classes: 2,
        };
        Dataset::new("tiny", cols, labels, interner).unwrap()
    }

    #[test]
    fn shape_accessors() {
        let d = tiny();
        assert_eq!(d.n_rows(), 3);
        assert_eq!(d.n_features(), 2);
        assert_eq!(d.task(), TaskKind::Classification);
        assert_eq!(d.labels.n_classes(), 2);
    }

    #[test]
    fn mismatched_lengths_rejected() {
        let cols = vec![Column::new("f0", vec![Value::Num(1.0)])];
        let labels = Labels::Class {
            ids: vec![0, 1],
            n_classes: 2,
        };
        assert!(Dataset::new("bad", cols, labels, Interner::new()).is_err());
    }

    #[test]
    fn split_partitions_rows() {
        let d = tiny();
        let (tr, va, te) = d.split_indices(0.34, 0.33, 7);
        let mut all: Vec<u32> = tr.iter().chain(&va).chain(&te).copied().collect();
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2]);
    }

    #[test]
    fn subset_extracts_rows() {
        let d = tiny();
        let s = d.subset(&[2, 0]);
        assert_eq!(s.n_rows(), 2);
        assert_eq!(s.labels.class(0), 0);
        assert!(s.value(0, 0).is_cat());
        assert_eq!(s.value(0, 1), Value::Num(1.0));
    }

    #[test]
    fn subset_shares_interner_and_class_names() {
        let mut d = tiny();
        d.class_names = Arc::new(vec!["no".into(), "yes".into()]);
        let s = d.subset(&[0, 1]);
        assert!(Arc::ptr_eq(&d.interner, &s.interner));
        assert!(Arc::ptr_eq(&d.class_names, &s.class_names));
    }

    #[test]
    fn sorted_index_builds_once() {
        let d = tiny();
        assert_eq!(d.sort_index_builds(), 0);
        let a = d.sorted_index().features[0].num_rows.clone();
        let b = d.sorted_index().features[0].num_rows.clone();
        assert_eq!(a, b);
        assert_eq!(a, vec![0, 1]); // rows 0,1 numeric, ascending
        assert_eq!(d.sort_index_builds(), 1);
    }

    #[test]
    fn invalidation_resorts_after_column_mutation() {
        let mut d = tiny();
        assert_eq!(d.sorted_index().features[0].num_rows, vec![0, 1]);
        // Swap the two numeric cells of f0 and invalidate.
        let mut cells = d.columns[0].data.cells();
        cells.swap(0, 1);
        let name = d.columns[0].name.clone();
        d.columns[0] = Column::new(name, cells);
        d.invalidate_sort_cache();
        assert_eq!(d.sorted_index().features[0].num_rows, vec![1, 0]);
        assert_eq!(d.sort_index_builds(), 2);
    }

    #[test]
    fn unique_numeric_count_is_memoized_with_the_index() {
        let d = tiny();
        // f0 has numeric cells {1.0, 2.0}; f1 has {0.5, 0.1}.
        assert_eq!(d.unique_numeric_count(0), 2);
        assert_eq!(d.unique_numeric_count(1), 2);
        // Derived from the cached index: no extra sort builds.
        assert_eq!(d.sort_index_builds(), 1);
    }

    #[test]
    fn binned_index_builds_once_per_bin_budget() {
        let d = tiny();
        assert_eq!(d.bin_index_builds(), 0);
        let a = d.binned_index(8);
        let b = d.binned_index(8);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(d.bin_index_builds(), 1);
        // tiny() columns have ≤ 2 distinct numeric values each → exact.
        assert!(a.all_exact());
        assert_eq!(a.lanes.len(), 2);
        assert!(a.lanes.iter().all(Option::is_some));
        // A different budget rebuilds (uncached) without disturbing the
        // cached instance.
        let c = d.binned_index(4);
        assert_eq!(c.max_bins, 4);
        assert_eq!(d.bin_index_builds(), 2);
        assert!(Arc::ptr_eq(&d.binned_index(8), &a));
        assert_eq!(d.bin_index_builds(), 2);
    }

    #[test]
    fn approx_bytes_counts_built_bin_lanes() {
        let d = tiny();
        let before = d.approx_bytes();
        let idx = d.binned_index(8);
        assert_eq!(d.approx_bytes(), before + idx.approx_bytes());
        assert!(idx.approx_bytes() > 0);
    }

    #[test]
    fn invalidation_drops_binned_cache_too() {
        let mut d = tiny();
        d.binned_index(8);
        assert_eq!(d.bin_index_builds(), 1);
        d.invalidate_sort_cache();
        d.binned_index(8);
        assert_eq!(d.bin_index_builds(), 2);
    }

    #[test]
    fn retrain_after_mutation_rebuilds_binned_index_exactly_once() {
        // Regression: `invalidate_sort_cache` drops the BinnedIndex, and
        // the *training path* (not just a direct `binned_index` call)
        // must rebuild it exactly once on the next fit — no stale reuse,
        // no double build.
        use crate::tree::{Backend, TrainConfig, Tree};
        let mut d = tiny();
        let tc = TrainConfig {
            backend: Backend::Binned { max_bins: 8 },
            ..Default::default()
        };
        Tree::fit(&d, &tc).unwrap();
        assert_eq!(d.bin_index_builds(), 1);
        // Refit without mutation: cache hit, no rebuild.
        Tree::fit(&d, &tc).unwrap();
        assert_eq!(d.bin_index_builds(), 1);
        // Mutate a column, invalidate, retrain: exactly one rebuild.
        let mut cells = d.columns[0].data.cells();
        cells.swap(0, 1);
        let name = d.columns[0].name.clone();
        d.columns[0] = Column::new(name, cells);
        d.invalidate_sort_cache();
        Tree::fit(&d, &tc).unwrap();
        assert_eq!(d.bin_index_builds(), 2);
        Tree::fit(&d, &tc).unwrap();
        assert_eq!(d.bin_index_builds(), 2);
    }

    #[test]
    fn approx_bytes_does_not_double_count_shared_lanes() {
        // Regression: two columns aliasing one `ColumnData` (Arc-shared
        // lanes) must contribute their lane bytes once, not per column.
        let d = tiny();
        let shared = d.columns[0].data.clone();
        let cols = vec![
            Column::from_data("f0".to_string(), shared.clone()),
            Column::from_data("f0_alias".to_string(), shared.clone()),
        ];
        let labels = Labels::Class {
            ids: vec![0, 1, 0],
            n_classes: 2,
        };
        let two = Dataset::new("aliased", cols, labels.clone(), Interner::new()).unwrap();
        let one = Dataset::new(
            "single",
            vec![Column::from_data("f0".to_string(), shared.clone())],
            labels,
            Interner::new(),
        )
        .unwrap();
        assert_eq!(two.approx_bytes(), one.approx_bytes());

        // Summing clones through one seen set counts shared lanes once.
        let clone = d.clone();
        let mut seen = std::collections::HashSet::new();
        let first = d.approx_bytes_dedup(&mut seen);
        assert_eq!(first, d.approx_bytes());
        let second = clone.approx_bytes_dedup(&mut seen);
        // Only the (deep-cloned) label vector remains to count.
        assert_eq!(second, clone.labels.len() * 2);
    }

    #[test]
    fn row_view() {
        let d = tiny();
        let r = d.row(1);
        assert_eq!(r[0], Value::Num(2.0));
        assert_eq!(r[1], Value::Num(0.5));
    }
}
