//! The one typed columnar store shared by training, inference and ingest.
//!
//! A feature column used to exist twice: training walked `Vec<Value>`
//! (16-byte tagged cells) while serving re-materialized the same data as
//! a typed `RowFrame` column with a lossy copy at the boundary. This
//! module is the single replacement: [`ColumnData`] keeps a dense `f64`
//! numeric lane, a dense `u32` category-id lane, and per-cell kind
//! bitmasks — and *specializes*:
//!
//! * a pure-numeric or pure-categorical column carries **one** lane and,
//!   when it has no missing cells, no mask at all;
//! * only a genuinely hybrid column (numeric *and* categorical cells
//!   mixed) pays for both lanes plus the two kind masks.
//!
//! Lanes and masks are `Arc`-shared, so a [`crate::inference::RowFrame`]
//! built from a [`crate::Dataset`] is a zero-copy view over the same
//! storage. [`crate::data::value::Value`] survives only as the boundary
//! accessor type ([`ColumnData::get`]): the selection kernel, the arena
//! partition and the compiled traversal all read the lanes directly.
//!
//! Invariants (upheld by [`ColumnShard`], the only constructor):
//!
//! * every present lane has exactly `len()` elements;
//! * `Num`/`Cat` with `valid: None` means *no* missing cells;
//! * `Hybrid` has at least one numeric and one categorical cell, and the
//!   `num`/`cat` masks are disjoint (a cell set in neither is missing);
//! * lane slots of non-matching kind hold placeholders (`0.0` / `0`)
//!   that must never be read without consulting the mask.

use super::interner::CatId;
use super::value::Value;
use std::sync::Arc;

/// Immutable bit-per-row mask (set = the property holds for the row).
/// Backed by `Arc<[u64]>` words so column views share it without copies.
#[derive(Debug, Clone)]
pub struct Bitmask {
    bits: Arc<[u64]>,
    len: usize,
}

impl Bitmask {
    /// Build from per-row flags.
    pub fn from_flags(flags: &[bool]) -> Bitmask {
        let mut bits = vec![0u64; flags.len().div_ceil(64)];
        for (i, &v) in flags.iter().enumerate() {
            if v {
                bits[i >> 6] |= 1u64 << (i & 63);
            }
        }
        Bitmask {
            bits: bits.into(),
            len: flags.len(),
        }
    }

    /// Build from raw words (only bits below `len` may be set).
    pub(crate) fn from_words(words: Vec<u64>, len: usize) -> Bitmask {
        debug_assert_eq!(words.len(), len.div_ceil(64));
        Bitmask {
            bits: words.into(),
            len,
        }
    }

    /// Whether bit `i` is set.
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.bits[i >> 6] >> (i & 63)) & 1 == 1
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Number of set bits.
    pub fn count_set(&self) -> usize {
        self.bits.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// The packed words (only bits below `len()` may be set) — the shard
    /// serializer writes these verbatim.
    pub(crate) fn words(&self) -> &[u64] {
        &self.bits
    }

    /// Mask bytes, counted once per allocation: 0 when this mask's word
    /// allocation was already recorded in `seen`.
    fn bytes_dedup(&self, seen: &mut std::collections::HashSet<usize>) -> usize {
        count_lane(self.bits.as_ptr(), self.bits.len() * 8, seen)
    }
}

/// `bytes` if the lane allocation at `ptr` has not been counted into
/// `seen` yet, else 0. `Arc`-shared lanes (dataset clones, forest bags,
/// zero-copy `RowFrame` views) alias the same allocation, so resident
/// byte accounting must dedupe by data pointer.
fn count_lane<T>(ptr: *const T, bytes: usize, seen: &mut std::collections::HashSet<usize>) -> usize {
    if seen.insert(ptr as usize) {
        bytes
    } else {
        0
    }
}

/// `true` when an optional validity mask allows row `i` (`None` = every
/// row present).
#[inline]
pub fn present(valid: &Option<Bitmask>, i: usize) -> bool {
    match valid {
        None => true,
        Some(m) => m.get(i),
    }
}

/// Typed storage of one feature column. See the module docs for the
/// specialization rules and invariants.
#[derive(Debug, Clone)]
pub enum ColumnData {
    /// Every present cell is numeric. `valid: None` ⇒ no missing cells.
    Num {
        vals: Arc<[f64]>,
        valid: Option<Bitmask>,
    },
    /// Every present cell is categorical. `valid: None` ⇒ no missing
    /// cells. Ids live in the owner's interner space (dataset interner
    /// for `Dataset` columns, frame interner for `RowFrame` columns).
    Cat {
        ids: Arc<[u32]>,
        valid: Option<Bitmask>,
    },
    /// Genuinely hybrid column: both lanes plus disjoint kind masks
    /// (`num` ∪ `cat` ⊊ rows ⇒ the remainder is missing).
    Hybrid {
        vals: Arc<[f64]>,
        ids: Arc<[u32]>,
        num: Bitmask,
        cat: Bitmask,
    },
}

impl ColumnData {
    /// Number of rows.
    pub fn len(&self) -> usize {
        match self {
            ColumnData::Num { vals, .. } => vals.len(),
            ColumnData::Cat { ids, .. } => ids.len(),
            ColumnData::Hybrid { vals, .. } => vals.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Boundary accessor: the cell at `row` as a tagged [`Value`].
    #[inline]
    pub fn get(&self, row: usize) -> Value {
        match self {
            ColumnData::Num { vals, valid } => {
                if present(valid, row) {
                    Value::Num(vals[row])
                } else {
                    Value::Missing
                }
            }
            ColumnData::Cat { ids, valid } => {
                if present(valid, row) {
                    Value::Cat(CatId(ids[row]))
                } else {
                    Value::Missing
                }
            }
            ColumnData::Hybrid {
                vals,
                ids,
                num,
                cat,
            } => {
                if num.get(row) {
                    Value::Num(vals[row])
                } else if cat.get(row) {
                    Value::Cat(CatId(ids[row]))
                } else {
                    Value::Missing
                }
            }
        }
    }

    /// Specialize a slice of tagged cells into typed storage.
    pub fn from_cells(cells: &[Value]) -> ColumnData {
        let mut s = ColumnShard::default();
        for &v in cells {
            s.push_value(v);
        }
        s.finish()
    }

    /// Materialize every cell as a tagged [`Value`] (boundary / tests).
    pub fn cells(&self) -> Vec<Value> {
        (0..self.len()).map(|r| self.get(r)).collect()
    }

    /// Extract the given rows as a new column (re-specialized: a hybrid
    /// column whose subset is pure collapses to a single lane).
    pub fn gather(&self, rows: &[u32]) -> ColumnData {
        let mut s = ColumnShard::default();
        for &r in rows {
            s.push_value(self.get(r as usize));
        }
        s.finish()
    }

    /// `(n_num, n_cat, n_missing)` cell counts.
    pub fn counts(&self) -> (usize, usize, usize) {
        let n = self.len();
        match self {
            ColumnData::Num { valid, .. } => {
                let p = valid.as_ref().map_or(n, Bitmask::count_set);
                (p, 0, n - p)
            }
            ColumnData::Cat { valid, .. } => {
                let p = valid.as_ref().map_or(n, Bitmask::count_set);
                (0, p, n - p)
            }
            ColumnData::Hybrid { num, cat, .. } => {
                let (nn, nc) = (num.count_set(), cat.count_set());
                (nn, nc, n - nn - nc)
            }
        }
    }

    /// `(rows, values)` of the numeric cells, ascending by `(value, row)`
    /// — the UDT `X^A` root pre-sort, read straight off the lanes.
    pub fn sorted_numeric(&self) -> (Vec<u32>, Vec<f64>) {
        let mut pairs: Vec<(f64, u32)> = match self {
            ColumnData::Num { vals, valid } => (0..vals.len())
                .filter(|&r| present(valid, r))
                .map(|r| (vals[r], r as u32))
                .collect(),
            ColumnData::Cat { .. } => Vec::new(),
            ColumnData::Hybrid { vals, num, .. } => (0..vals.len())
                .filter(|&r| num.get(r))
                .map(|r| (vals[r], r as u32))
                .collect(),
        };
        // ANALYZE-ALLOW(no-unwrap): numeric cells are non-NaN (NaN ingests as Missing)
        pairs.sort_unstable_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        (
            pairs.iter().map(|p| p.1).collect(),
            pairs.iter().map(|p| p.0).collect(),
        )
    }

    /// `(rows, cat_ids)` of the categorical cells, grouped by ascending
    /// `(id, row)`, read straight off the lanes.
    pub fn sorted_categorical(&self) -> (Vec<u32>, Vec<u32>) {
        let mut pairs: Vec<(u32, u32)> = match self {
            ColumnData::Num { .. } => Vec::new(),
            ColumnData::Cat { ids, valid } => (0..ids.len())
                .filter(|&r| present(valid, r))
                .map(|r| (ids[r], r as u32))
                .collect(),
            ColumnData::Hybrid { ids, cat, .. } => (0..ids.len())
                .filter(|&r| cat.get(r))
                .map(|r| (ids[r], r as u32))
                .collect(),
        };
        pairs.sort_unstable();
        (
            pairs.iter().map(|p| p.1).collect(),
            pairs.iter().map(|p| p.0).collect(),
        )
    }

    /// Resident bytes of the lanes and masks.
    pub fn approx_bytes(&self) -> usize {
        self.approx_bytes_dedup(&mut std::collections::HashSet::new())
    }

    /// Resident bytes, counting each lane/mask allocation at most once
    /// across every column threaded through the same `seen` set —
    /// `Arc`-shared lanes alias one allocation and must not be summed
    /// per clone.
    pub fn approx_bytes_dedup(&self, seen: &mut std::collections::HashSet<usize>) -> usize {
        match self {
            ColumnData::Num { vals, valid } => {
                count_lane(vals.as_ptr(), vals.len() * 8, seen)
                    + valid.as_ref().map_or(0, |m| m.bytes_dedup(seen))
            }
            ColumnData::Cat { ids, valid } => {
                count_lane(ids.as_ptr(), ids.len() * 4, seen)
                    + valid.as_ref().map_or(0, |m| m.bytes_dedup(seen))
            }
            ColumnData::Hybrid {
                vals,
                ids,
                num,
                cat,
            } => {
                count_lane(vals.as_ptr(), vals.len() * 8, seen)
                    + count_lane(ids.as_ptr(), ids.len() * 4, seen)
                    + num.bytes_dedup(seen)
                    + cat.bytes_dedup(seen)
            }
        }
    }
}

/// Bin-id lane of a pre-quantized numeric column: `u8` when the binning
/// used ≤ 256 bins, `u16` otherwise (the config boundary caps `max_bins`
/// at 65535). `Arc`-shared like the f64/u32 lanes so every fit, forest
/// bag and boosting round reads the same quantization.
#[derive(Debug, Clone)]
pub enum BinIds {
    U8(Arc<[u8]>),
    U16(Arc<[u16]>),
}

impl BinIds {
    /// Bin id of row `i`. Only meaningful for rows holding numeric
    /// cells; other slots carry placeholder 0 and must not be read
    /// (callers iterate the sorted numeric row lists, which contain
    /// numeric rows only).
    #[inline]
    pub fn get(&self, i: usize) -> u32 {
        match self {
            BinIds::U8(v) => v[i] as u32,
            BinIds::U16(v) => v[i] as u32,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            BinIds::U8(v) => v.len(),
            BinIds::U16(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Resident bytes of the id lane.
    pub fn approx_bytes(&self) -> usize {
        match self {
            BinIds::U8(v) => v.len(),
            BinIds::U16(v) => v.len() * 2,
        }
    }

    /// Resident bytes, counted once per allocation (see
    /// [`ColumnData::approx_bytes_dedup`]).
    pub fn approx_bytes_dedup(&self, seen: &mut std::collections::HashSet<usize>) -> usize {
        match self {
            BinIds::U8(v) => count_lane(v.as_ptr(), v.len(), seen),
            BinIds::U16(v) => count_lane(v.as_ptr(), v.len() * 2, seen),
        }
    }
}

/// Dataset-level quantile binning of one numeric column: a row-indexed
/// bin-id lane plus the bin-edge table. Built once next to the
/// `SortedIndex` cache (see `Dataset::binned_index`) and shared by every
/// binned fit. Edges are actual data values, so `value ≤ edges[b]` is a
/// valid split predicate at every bin boundary.
#[derive(Debug, Clone)]
pub struct BinLane {
    /// Bin id per row (placeholder 0 at non-numeric rows).
    pub ids: BinIds,
    /// Upper edge value of each bin, ascending.
    pub edges: Arc<[f64]>,
    /// Whether the binning is lossless (distinct values ≤ `max_bins`):
    /// each bin holds exactly one distinct value and its edge *is* that
    /// value, so a binned scan scores exactly the exact-path candidates.
    pub is_exact: bool,
}

impl BinLane {
    /// Quantize a column's sorted numeric lane (`num_rows`/`num_vals`
    /// from the `SortedIndex`) into at most `max_bins` bins, scattered
    /// back to row order. `None` when the column has no numeric cells.
    pub fn build(
        num_rows: &[u32],
        num_vals: &[f64],
        n_rows: usize,
        max_bins: usize,
    ) -> Option<BinLane> {
        let binning = crate::runtime::binning::quantile_bins(num_vals, max_bins)?;
        let n_bins = binning.n_bins();
        let ids = if n_bins <= 256 {
            let mut lane = vec![0u8; n_rows];
            for (i, &r) in num_rows.iter().enumerate() {
                lane[r as usize] = binning.bin_of_sorted[i] as u8;
            }
            BinIds::U8(lane.into())
        } else {
            let mut lane = vec![0u16; n_rows];
            for (i, &r) in num_rows.iter().enumerate() {
                lane[r as usize] = binning.bin_of_sorted[i] as u16;
            }
            BinIds::U16(lane.into())
        };
        Some(BinLane {
            ids,
            edges: binning.edges.into(),
            is_exact: binning.is_exact,
        })
    }

    /// Bin id of `row` (which must hold a numeric cell).
    #[inline]
    pub fn bin_of_row(&self, row: usize) -> usize {
        self.ids.get(row) as usize
    }

    pub fn n_bins(&self) -> usize {
        self.edges.len()
    }

    /// Resident bytes of the id lane plus the edge table.
    pub fn approx_bytes(&self) -> usize {
        self.ids.approx_bytes() + self.edges.len() * std::mem::size_of::<f64>()
    }

    /// Resident bytes, counted once per allocation (see
    /// [`ColumnData::approx_bytes_dedup`]).
    pub fn approx_bytes_dedup(&self, seen: &mut std::collections::HashSet<usize>) -> usize {
        self.ids.approx_bytes_dedup(seen)
            + count_lane(
                self.edges.as_ptr(),
                self.edges.len() * std::mem::size_of::<f64>(),
                seen,
            )
    }
}

/// Incremental typed column builder: the shared sink of CSV chunk
/// parsing, [`crate::inference::RowFrameBuilder`] and
/// [`ColumnData::from_cells`]. Cells append in row order; [`finish`]
/// picks the densest representation the content allows.
///
/// While building, both lanes are kept full-length (placeholders in the
/// non-matching lane); the lane a pure column does not need is dropped
/// at [`finish`].
///
/// [`finish`]: ColumnShard::finish
#[derive(Debug, Clone, Default)]
pub struct ColumnShard {
    vals: Vec<f64>,
    ids: Vec<u32>,
    num_bits: Vec<u64>,
    cat_bits: Vec<u64>,
    len: usize,
    n_num: usize,
    n_cat: usize,
}

/// Kind of one appended cell.
enum CellKind {
    Num,
    Cat,
    Missing,
}

/// Append the first `n` bits of `src` (a packed bit vector whose bits at
/// index ≥ `n` are all zero) onto `dst`, which currently holds `dst_len`
/// bits in exactly `dst_len.div_ceil(64)` words. Preserves both
/// invariants for the result, so interleaving with per-cell pushes stays
/// correct.
fn append_bits(dst: &mut Vec<u64>, dst_len: usize, src: &[u64], n: usize) {
    debug_assert_eq!(dst.len(), dst_len.div_ceil(64));
    debug_assert_eq!(src.len(), n.div_ceil(64));
    if n == 0 {
        return;
    }
    let shift = dst_len & 63;
    if shift == 0 {
        dst.extend_from_slice(src);
        return;
    }
    // Each src word contributes its low `64 - shift` bits to the current
    // last word and (when more of it is live) its high `shift` bits to a
    // freshly pushed word; the split point is the same for every word.
    let low = 64 - shift;
    let mut rem = n;
    for &w in src {
        // ANALYZE-ALLOW(no-unwrap): caller seeds dst with a partial word when shift != 0
        *dst.last_mut().expect("shift != 0 implies a partial word") |= w << shift;
        if rem > low {
            dst.push(w >> low);
        }
        rem = rem.saturating_sub(64);
    }
}

impl ColumnShard {
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn push_cell(&mut self, val: f64, id: u32, kind: CellKind) {
        if self.len % 64 == 0 {
            self.num_bits.push(0);
            self.cat_bits.push(0);
        }
        let (w, b) = (self.len >> 6, self.len & 63);
        match kind {
            CellKind::Num => {
                self.num_bits[w] |= 1u64 << b;
                self.n_num += 1;
            }
            CellKind::Cat => {
                self.cat_bits[w] |= 1u64 << b;
                self.n_cat += 1;
            }
            CellKind::Missing => {}
        }
        self.vals.push(val);
        self.ids.push(id);
        self.len += 1;
    }

    /// Append a numeric cell.
    #[inline]
    pub fn push_num(&mut self, x: f64) {
        self.push_cell(x, 0, CellKind::Num);
    }

    /// Append a categorical cell (id in the owner's interner space).
    #[inline]
    pub fn push_cat(&mut self, id: u32) {
        self.push_cell(0.0, id, CellKind::Cat);
    }

    /// Append a missing cell.
    #[inline]
    pub fn push_missing(&mut self) {
        self.push_cell(0.0, 0, CellKind::Missing);
    }

    /// Append a tagged cell.
    #[inline]
    pub fn push_value(&mut self, v: Value) {
        match v {
            Value::Num(x) => self.push_num(x),
            Value::Cat(CatId(id)) => self.push_cat(id),
            Value::Missing => self.push_missing(),
        }
    }

    /// Append every cell of `other`, translating its categorical ids
    /// through `remap` (`remap[local_id] = id in this shard's space`) —
    /// the per-chunk → global merge step of streaming CSV ingest.
    ///
    /// This is the serial section between the parallel chunk parses, so
    /// it is bulk-wise: lanes append via `extend_from_slice`, masks via
    /// a shifted word-wise bit append, and only the cells the cat mask
    /// marks are touched individually (to remap their ids).
    pub fn append_remapped(&mut self, other: &ColumnShard, remap: &[u32]) {
        if other.len == 0 {
            return;
        }
        let old_len = self.len;
        self.vals.extend_from_slice(&other.vals);
        let id_start = self.ids.len();
        self.ids.extend_from_slice(&other.ids);
        // Remap categorical slots only, iterating the set bits of the
        // cat mask word by word.
        for (w, &word) in other.cat_bits.iter().enumerate() {
            let mut word = word;
            while word != 0 {
                let i = w * 64 + word.trailing_zeros() as usize;
                let id = &mut self.ids[id_start + i];
                *id = remap[*id as usize];
                word &= word - 1;
            }
        }
        append_bits(&mut self.num_bits, old_len, &other.num_bits, other.len);
        append_bits(&mut self.cat_bits, old_len, &other.cat_bits, other.len);
        self.len += other.len;
        self.n_num += other.n_num;
        self.n_cat += other.n_cat;
    }

    /// Specialize into the final typed storage.
    pub fn finish(self) -> ColumnData {
        let ColumnShard {
            vals,
            ids,
            num_bits,
            cat_bits,
            len,
            n_num,
            n_cat,
        } = self;
        let any_missing = n_num + n_cat < len;
        if n_num > 0 && n_cat > 0 {
            ColumnData::Hybrid {
                vals: vals.into(),
                ids: ids.into(),
                num: Bitmask::from_words(num_bits, len),
                cat: Bitmask::from_words(cat_bits, len),
            }
        } else if n_cat > 0 {
            ColumnData::Cat {
                ids: ids.into(),
                valid: any_missing.then(|| Bitmask::from_words(cat_bits, len)),
            }
        } else {
            // All-numeric, all-missing, or empty — the Num layout
            // represents each (an all-zero mask marks every row missing).
            ColumnData::Num {
                vals: vals.into(),
                valid: any_missing.then(|| Bitmask::from_words(num_bits, len)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::interner::Interner;

    #[test]
    fn bitmask_round_trips() {
        let flags: Vec<bool> = (0..130).map(|i| i % 3 != 0).collect();
        let m = Bitmask::from_flags(&flags);
        assert_eq!(m.len(), 130);
        for (i, &f) in flags.iter().enumerate() {
            assert_eq!(m.get(i), f, "bit {i}");
        }
        assert_eq!(m.count_set(), flags.iter().filter(|&&f| f).count());
        assert!(!m.is_empty());
    }

    #[test]
    fn shard_specializes_representations() {
        // Pure numeric, no missing → Num with no mask.
        let d = ColumnData::from_cells(&[Value::Num(1.0), Value::Num(2.0)]);
        assert!(matches!(&d, ColumnData::Num { valid: None, .. }));
        assert_eq!(d.counts(), (2, 0, 0));

        // Numeric with a missing cell → Num with a validity mask.
        let d = ColumnData::from_cells(&[Value::Num(1.0), Value::Missing]);
        assert!(matches!(&d, ColumnData::Num { valid: Some(_), .. }));
        assert_eq!(d.counts(), (1, 0, 1));

        // Pure categorical → Cat, single u32 lane.
        let mut i = Interner::new();
        let (a, b) = (i.intern("a"), i.intern("b"));
        let d = ColumnData::from_cells(&[Value::Cat(a), Value::Cat(b)]);
        assert!(matches!(&d, ColumnData::Cat { valid: None, .. }));
        assert_eq!(d.counts(), (0, 2, 0));

        // Hybrid → both lanes + kind masks.
        let d = ColumnData::from_cells(&[Value::Num(1.0), Value::Cat(a), Value::Missing]);
        assert!(matches!(&d, ColumnData::Hybrid { .. }));
        assert_eq!(d.counts(), (1, 1, 1));

        // All-missing and empty both take the Num layout.
        let d = ColumnData::from_cells(&[Value::Missing, Value::Missing]);
        assert!(matches!(&d, ColumnData::Num { valid: Some(_), .. }));
        assert_eq!(d.counts(), (0, 0, 2));
        assert!(ColumnData::from_cells(&[]).is_empty());
    }

    #[test]
    fn cells_round_trip_every_kind() {
        let mut i = Interner::new();
        let x = i.intern("x");
        let cells = vec![
            Value::Num(3.5),
            Value::Cat(x),
            Value::Missing,
            Value::Num(-1.0),
        ];
        let d = ColumnData::from_cells(&cells);
        assert_eq!(d.len(), 4);
        assert_eq!(d.cells(), cells);
        for (r, &c) in cells.iter().enumerate() {
            assert_eq!(d.get(r), c, "row {r}");
        }
    }

    #[test]
    fn gather_respecializes() {
        let mut i = Interner::new();
        let x = i.intern("x");
        let d = ColumnData::from_cells(&[Value::Num(2.0), Value::Cat(x), Value::Num(1.0)]);
        assert!(matches!(&d, ColumnData::Hybrid { .. }));
        let g = d.gather(&[2, 0]);
        // Numeric-only subset collapses to the single-lane layout.
        assert!(matches!(&g, ColumnData::Num { valid: None, .. }));
        assert_eq!(g.cells(), vec![Value::Num(1.0), Value::Num(2.0)]);
    }

    #[test]
    fn sorted_lanes_match_value_oracle() {
        let mut i = Interner::new();
        let (a, b) = (i.intern("a"), i.intern("b"));
        let cells = vec![
            Value::Num(3.0),
            Value::Cat(b),
            Value::Num(1.0),
            Value::Missing,
            Value::Num(1.0),
            Value::Cat(a),
        ];
        let d = ColumnData::from_cells(&cells);
        let (nr, nv) = d.sorted_numeric();
        assert_eq!(nr, vec![2, 4, 0]);
        assert_eq!(nv, vec![1.0, 1.0, 3.0]);
        let (cr, ci) = d.sorted_categorical();
        assert_eq!(cr, vec![5, 1]);
        assert_eq!(ci, vec![a.0, b.0]);
    }

    #[test]
    fn append_remapped_translates_ids() {
        let mut a = ColumnShard::default();
        a.push_cat(0); // global id 0
        let mut b = ColumnShard::default();
        b.push_cat(0); // chunk-local id 0 → global 7
        b.push_num(5.0);
        b.push_missing();
        a.append_remapped(&b, &[7]);
        let d = a.finish();
        assert_eq!(d.counts(), (1, 2, 1));
        assert_eq!(d.get(1), Value::Cat(CatId(7)));
        assert_eq!(d.get(2), Value::Num(5.0));
        assert!(d.get(3).is_missing());
    }

    #[test]
    fn append_remapped_matches_per_cell_oracle_across_alignments() {
        // The bulk word-wise merge must agree with sequential pushes for
        // every mask alignment: below/at/above word boundaries, across
        // multiple words, and repeated unaligned appends.
        let kinds = |seed: u64, n: usize| -> Vec<Value> {
            (0..n)
                .map(|i| {
                    match (seed.wrapping_mul(6364136223846793005).wrapping_add(i as u64)
                        >> 33)
                        % 3
                    {
                        0 => Value::Num(i as f64),
                        1 => Value::Cat(CatId((i % 5) as u32)),
                        _ => Value::Missing,
                    }
                })
                .collect()
        };
        let identity: Vec<u32> = (0..5).collect();
        for (base_n, add_ns) in [
            (0usize, vec![1usize, 63, 64, 65]),
            (1, vec![63, 64, 130]),
            (63, vec![1, 64, 2]),
            (64, vec![64, 63, 65]),
            (70, vec![130, 1, 200]),
        ] {
            let base = kinds(base_n as u64 + 1, base_n);
            let mut bulk = ColumnShard::default();
            let mut oracle = ColumnShard::default();
            for &v in &base {
                bulk.push_value(v);
                oracle.push_value(v);
            }
            for (k, &n) in add_ns.iter().enumerate() {
                let cells = kinds(n as u64 * 31 + k as u64, n);
                let mut chunk = ColumnShard::default();
                for &v in &cells {
                    chunk.push_value(v);
                    oracle.push_value(v);
                }
                bulk.append_remapped(&chunk, &identity);
            }
            assert_eq!(bulk.len(), oracle.len(), "base {base_n} adds {add_ns:?}");
            let (a, b) = (bulk.finish(), oracle.finish());
            assert_eq!(a.cells(), b.cells(), "base {base_n} adds {add_ns:?}");
            assert_eq!(a.counts(), b.counts(), "base {base_n} adds {add_ns:?}");
        }
    }

    #[test]
    fn bin_lane_scatters_to_row_order() {
        // Rows: 3.0, cat, 1.0, missing, 1.0 — numeric lane sorted is
        // rows [2, 4, 0] with values [1.0, 1.0, 3.0].
        let mut i = Interner::new();
        let a = i.intern("a");
        let d = ColumnData::from_cells(&[
            Value::Num(3.0),
            Value::Cat(a),
            Value::Num(1.0),
            Value::Missing,
            Value::Num(1.0),
        ]);
        let (nr, nv) = d.sorted_numeric();
        let lane = BinLane::build(&nr, &nv, d.len(), 8).unwrap();
        assert!(lane.is_exact);
        assert_eq!(lane.n_bins(), 2);
        assert_eq!(lane.edges.as_ref(), &[1.0, 3.0]);
        assert_eq!(lane.bin_of_row(2), 0);
        assert_eq!(lane.bin_of_row(4), 0);
        assert_eq!(lane.bin_of_row(0), 1);
        assert!(matches!(lane.ids, BinIds::U8(_)));
        assert_eq!(lane.approx_bytes(), 5 + 2 * 8);
        // No numeric cells → no lane.
        let cat = ColumnData::from_cells(&[Value::Cat(a)]);
        let (nr, nv) = cat.sorted_numeric();
        assert!(BinLane::build(&nr, &nv, 1, 8).is_none());
    }

    #[test]
    fn bin_lane_widens_past_256_bins() {
        let cells: Vec<Value> = (0..600).map(|i| Value::Num(i as f64)).collect();
        let d = ColumnData::from_cells(&cells);
        let (nr, nv) = d.sorted_numeric();
        let lane = BinLane::build(&nr, &nv, d.len(), 512).unwrap();
        assert!(lane.n_bins() > 256, "{}", lane.n_bins());
        assert!(matches!(lane.ids, BinIds::U16(_)));
        // Every row's value ≤ its bin edge, > previous edge.
        for r in 0..600 {
            let v = r as f64;
            let b = lane.bin_of_row(r);
            assert!(v <= lane.edges[b]);
            if b > 0 {
                assert!(v > lane.edges[b - 1]);
            }
        }
        // At u8 capacity the narrow lane is kept.
        let lane = BinLane::build(&nr, &nv, d.len(), 256).unwrap();
        assert!(lane.n_bins() <= 256);
        assert!(matches!(lane.ids, BinIds::U8(_)));
        assert!(!lane.is_exact);
    }

    #[test]
    fn approx_bytes_dedup_counts_shared_lanes_once() {
        let d = ColumnData::from_cells(&vec![Value::Num(1.0); 64]);
        let clone = d.clone(); // Arc-shared lanes, same allocation
        let mut seen = std::collections::HashSet::new();
        let first = d.approx_bytes_dedup(&mut seen);
        assert_eq!(first, d.approx_bytes());
        // The clone aliases every lane — nothing new to count.
        assert_eq!(clone.approx_bytes_dedup(&mut seen), 0);
        // An equal-content but distinct allocation counts fully.
        let other = ColumnData::from_cells(&vec![Value::Num(1.0); 64]);
        assert_eq!(other.approx_bytes_dedup(&mut seen), first);
    }

    #[test]
    fn approx_bytes_specializes() {
        let num = ColumnData::from_cells(&vec![Value::Num(1.0); 64]);
        let mut i = Interner::new();
        let a = i.intern("a");
        let cat = ColumnData::from_cells(&vec![Value::Cat(a); 64]);
        // Pure categorical stores 4-byte ids, not 8-byte values.
        assert!(cat.approx_bytes() < num.approx_bytes());
        let mut cells = vec![Value::Num(1.0); 63];
        cells.push(Value::Cat(a));
        let hybrid = ColumnData::from_cells(&cells);
        assert!(hybrid.approx_bytes() > num.approx_bytes());
    }
}
