//! A single hybrid feature column with cached summary statistics.

use super::value::Value;

/// Columnar storage for one feature.
#[derive(Debug, Clone, Default)]
pub struct Column {
    pub name: String,
    pub values: Vec<Value>,
}

/// Cheap summary of a column's composition.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ColumnStats {
    pub n_num: usize,
    pub n_cat: usize,
    pub n_missing: usize,
}

impl Column {
    pub fn new(name: impl Into<String>, values: Vec<Value>) -> Self {
        Self {
            name: name.into(),
            values,
        }
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    #[inline]
    pub fn get(&self, row: usize) -> Value {
        self.values[row]
    }

    pub fn stats(&self) -> ColumnStats {
        let mut s = ColumnStats::default();
        for v in &self.values {
            match v {
                Value::Num(_) => s.n_num += 1,
                Value::Cat(_) => s.n_cat += 1,
                Value::Missing => s.n_missing += 1,
            }
        }
        s
    }

    /// Row indices holding numeric values, sorted ascending by value
    /// (ties broken by row id for determinism). This is the `X^A`
    /// pre-sort of UDT Algorithm 5, done once per feature.
    pub fn sorted_numeric_rows(&self) -> Vec<u32> {
        self.sorted_numeric().0
    }

    /// `(rows, values)` of the numeric cells, sorted ascending by value
    /// (ties by row id). The value array is carried through the builder's
    /// sorted-list filtering so the selection hot loop reads values
    /// sequentially instead of chasing 16-byte `Value` cells.
    pub fn sorted_numeric(&self) -> (Vec<u32>, Vec<f64>) {
        // Sort (value, row) pairs directly — sequential key access beats
        // sorting indices with indirect loads.
        let mut pairs: Vec<(f64, u32)> = self
            .values
            .iter()
            .enumerate()
            .filter_map(|(r, v)| v.as_num().map(|x| (x, r as u32)))
            .collect();
        pairs.sort_unstable_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        let rows = pairs.iter().map(|p| p.1).collect();
        let vals = pairs.iter().map(|p| p.0).collect();
        (rows, vals)
    }

    /// `(rows, cat_ids)` of the categorical cells, grouped by ascending
    /// category id (ties by row id). Maintained through the builder's
    /// filtering so per-node per-category counts come from a sequential
    /// group walk instead of a hash map over all node rows.
    pub fn sorted_categorical(&self) -> (Vec<u32>, Vec<u32>) {
        let mut pairs: Vec<(u32, u32)> = self
            .values
            .iter()
            .enumerate()
            .filter_map(|(r, v)| v.as_cat().map(|c| (c.0, r as u32)))
            .collect();
        pairs.sort_unstable();
        let rows = pairs.iter().map(|p| p.1).collect();
        let ids = pairs.iter().map(|p| p.0).collect();
        (rows, ids)
    }

    /// Number of distinct numeric values (the paper's `N` on the numeric
    /// side). `O(M log M)`.
    pub fn unique_numeric_count(&self) -> usize {
        let mut nums: Vec<f64> = self.values.iter().filter_map(|v| v.as_num()).collect();
        nums.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        nums.dedup();
        nums.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::interner::Interner;

    fn col() -> (Column, Interner) {
        let mut i = Interner::new();
        let x = i.intern("x");
        let c = Column::new(
            "f",
            vec![
                Value::Num(3.0),
                Value::Cat(x),
                Value::Num(1.0),
                Value::Missing,
                Value::Num(1.0),
                Value::Num(2.0),
            ],
        );
        (c, i)
    }

    #[test]
    fn stats_count_kinds() {
        let (c, _) = col();
        let s = c.stats();
        assert_eq!(
            s,
            ColumnStats {
                n_num: 4,
                n_cat: 1,
                n_missing: 1
            }
        );
    }

    #[test]
    fn sorted_rows_ascending_stable() {
        let (c, _) = col();
        let idx = c.sorted_numeric_rows();
        // values at rows: 2→1.0, 4→1.0, 5→2.0, 0→3.0
        assert_eq!(idx, vec![2, 4, 5, 0]);
    }

    #[test]
    fn unique_numeric() {
        let (c, _) = col();
        assert_eq!(c.unique_numeric_count(), 3);
    }
}
