//! A single hybrid feature column: a name over typed columnar storage.
//!
//! Storage is a [`ColumnData`] (dense `f64` / `u32` lanes + kind masks,
//! `Arc`-shared with inference frames); [`Value`] appears only at the
//! boundary accessors ([`Column::get`], [`Column::iter`]).

use super::column_data::ColumnData;
use super::value::Value;

/// Columnar storage for one feature.
#[derive(Debug, Clone)]
pub struct Column {
    pub name: String,
    pub data: ColumnData,
}

/// Cheap summary of a column's composition.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ColumnStats {
    pub n_num: usize,
    pub n_cat: usize,
    pub n_missing: usize,
}

impl Column {
    /// Build from tagged cells (tests, synthetic generation); ingest and
    /// frames build typed storage directly through
    /// [`super::column_data::ColumnShard`].
    pub fn new(name: impl Into<String>, values: Vec<Value>) -> Self {
        Self {
            name: name.into(),
            data: ColumnData::from_cells(&values),
        }
    }

    /// Wrap already-typed storage.
    pub fn from_data(name: impl Into<String>, data: ColumnData) -> Self {
        Self {
            name: name.into(),
            data,
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Boundary accessor: the cell at `row` as a tagged [`Value`].
    #[inline]
    pub fn get(&self, row: usize) -> Value {
        self.data.get(row)
    }

    /// Iterate cells as tagged values (boundary / diagnostics).
    pub fn iter(&self) -> impl Iterator<Item = Value> + '_ {
        (0..self.len()).map(move |r| self.data.get(r))
    }

    pub fn stats(&self) -> ColumnStats {
        let (n_num, n_cat, n_missing) = self.data.counts();
        ColumnStats {
            n_num,
            n_cat,
            n_missing,
        }
    }

    /// Row indices holding numeric values, sorted ascending by value
    /// (ties broken by row id for determinism). This is the `X^A`
    /// pre-sort of UDT Algorithm 5, done once per feature.
    pub fn sorted_numeric_rows(&self) -> Vec<u32> {
        self.sorted_numeric().0
    }

    /// `(rows, values)` of the numeric cells, sorted ascending by value
    /// (ties by row id). The value array is carried through the builder's
    /// sorted-list filtering so the selection hot loop reads values
    /// sequentially.
    pub fn sorted_numeric(&self) -> (Vec<u32>, Vec<f64>) {
        self.data.sorted_numeric()
    }

    /// `(rows, cat_ids)` of the categorical cells, grouped by ascending
    /// category id (ties by row id). Maintained through the builder's
    /// filtering so per-node per-category counts come from a sequential
    /// group walk instead of a hash map over all node rows.
    pub fn sorted_categorical(&self) -> (Vec<u32>, Vec<u32>) {
        self.data.sorted_categorical()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::interner::Interner;

    fn col() -> (Column, Interner) {
        let mut i = Interner::new();
        let x = i.intern("x");
        let c = Column::new(
            "f",
            vec![
                Value::Num(3.0),
                Value::Cat(x),
                Value::Num(1.0),
                Value::Missing,
                Value::Num(1.0),
                Value::Num(2.0),
            ],
        );
        (c, i)
    }

    #[test]
    fn stats_count_kinds() {
        let (c, _) = col();
        let s = c.stats();
        assert_eq!(
            s,
            ColumnStats {
                n_num: 4,
                n_cat: 1,
                n_missing: 1
            }
        );
    }

    #[test]
    fn sorted_rows_ascending_stable() {
        let (c, _) = col();
        let idx = c.sorted_numeric_rows();
        // values at rows: 2→1.0, 4→1.0, 5→2.0, 0→3.0
        assert_eq!(idx, vec![2, 4, 5, 0]);
    }

    #[test]
    fn get_and_iter_read_tagged_cells() {
        let (c, _) = col();
        assert_eq!(c.get(0), Value::Num(3.0));
        assert!(c.get(1).is_cat());
        assert!(c.get(3).is_missing());
        let cells: Vec<Value> = c.iter().collect();
        assert_eq!(cells.len(), 6);
        assert_eq!(cells[5], Value::Num(2.0));
    }
}
