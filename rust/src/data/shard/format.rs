//! Binary (de)serialization of shard files and the JSON manifests.
//!
//! Everything on disk is little-endian and versioned behind a 4-byte
//! magic; every decode error (bad magic, truncated lane, trailing
//! bytes, checksum mismatch at the reader layer) surfaces as a typed
//! [`UdtError::Data`]. See the module docs in [`super`] for the full
//! layout diagram.

use crate::data::column_data::{Bitmask, ColumnData};
use crate::data::dataset::TaskKind;
use crate::error::{Result, UdtError};
use crate::util::json::Json;

/// Raw shard file magic (`shard-*.uds`).
pub const SHARD_MAGIC: &[u8; 4] = b"UDSH";
/// Bin-lane sidecar file magic (`bins-*/shard-*.udb`).
pub const BINS_MAGIC: &[u8; 4] = b"UDSB";
/// Edge-table file magic (`bins-*/edges.bin`).
pub const EDGES_MAGIC: &[u8; 4] = b"UDSE";
/// On-disk format version, bumped on any layout change.
pub const FORMAT_VERSION: u32 = 1;

/// FNV-1a 64-bit checksum of a byte stream — recorded per file in the
/// manifests and verified on every windowed read.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// One shard's label lane (class ids are already in the global class
/// space, regression targets verbatim).
#[derive(Debug, Clone)]
pub enum LabelLane {
    Class(Vec<u16>),
    Reg(Vec<f64>),
}

impl LabelLane {
    pub fn len(&self) -> usize {
        match self {
            LabelLane::Class(v) => v.len(),
            LabelLane::Reg(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn kind(&self) -> TaskKind {
        match self {
            LabelLane::Class(_) => TaskKind::Classification,
            LabelLane::Reg(_) => TaskKind::Regression,
        }
    }

    /// Resident bytes of the lane.
    pub fn approx_bytes(&self) -> usize {
        match self {
            LabelLane::Class(v) => v.len() * 2,
            LabelLane::Reg(v) => v.len() * 8,
        }
    }
}

// ---------------------------------------------------------------------
// Little-endian write helpers.

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64s(out: &mut Vec<u8>, vs: &[f64]) {
    for &v in vs {
        put_f64(out, v);
    }
}

fn put_u32s(out: &mut Vec<u8>, vs: &[u32]) {
    for &v in vs {
        put_u32(out, v);
    }
}

fn put_u64s(out: &mut Vec<u8>, vs: &[u64]) {
    for &v in vs {
        put_u64(out, v);
    }
}

fn put_u16s(out: &mut Vec<u8>, vs: &[u16]) {
    for &v in vs {
        put_u16(out, v);
    }
}

// ---------------------------------------------------------------------
// Bounds-checked little-endian reader: every premature end is a typed
// `Data` error naming what was being read — the truncated-lane tests
// exercise these paths.

struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Cur { buf, pos: 0 }
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        if self.buf.len() - self.pos < n {
            return Err(UdtError::data(format!(
                "truncated shard file: expected {n} bytes of {what} at offset {}, {} left",
                self.pos,
                self.buf.len() - self.pos
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        let b = self.take(4, what)?;
        // ANALYZE-ALLOW(no-unwrap): take(4) pins the slice length for try_into
        Ok(u32::from_le_bytes(b.try_into().unwrap()))
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        let b = self.take(8, what)?;
        // ANALYZE-ALLOW(no-unwrap): take(8) pins the slice length for try_into
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }

    fn counted(&mut self, what: &str) -> Result<usize> {
        let v = self.u64(what)?;
        usize::try_from(v)
            .ok()
            .filter(|&n| n <= self.buf.len().max(1 << 32))
            .ok_or_else(|| UdtError::data(format!("implausible {what} count {v} in shard file")))
    }

    fn f64s(&mut self, n: usize, what: &str) -> Result<Vec<f64>> {
        let b = self.take(n * 8, what)?;
        Ok(b.chunks_exact(8)
            // ANALYZE-ALLOW(no-unwrap): chunks_exact(8) pins the chunk length
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn u64s(&mut self, n: usize, what: &str) -> Result<Vec<u64>> {
        let b = self.take(n * 8, what)?;
        Ok(b.chunks_exact(8)
            // ANALYZE-ALLOW(no-unwrap): chunks_exact(8) pins the chunk length
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn u32s(&mut self, n: usize, what: &str) -> Result<Vec<u32>> {
        let b = self.take(n * 4, what)?;
        Ok(b.chunks_exact(4)
            // ANALYZE-ALLOW(no-unwrap): chunks_exact(4) pins the chunk length
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn u16s(&mut self, n: usize, what: &str) -> Result<Vec<u16>> {
        let b = self.take(n * 2, what)?;
        Ok(b.chunks_exact(2)
            // ANALYZE-ALLOW(no-unwrap): chunks_exact(2) pins the chunk length
            .map(|c| u16::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn bytes(&mut self, n: usize, what: &str) -> Result<Vec<u8>> {
        Ok(self.take(n, what)?.to_vec())
    }

    fn magic(&mut self, expect: &[u8; 4], kind: &str) -> Result<()> {
        let got = self.take(4, "magic")?;
        if got != expect {
            return Err(UdtError::data(format!(
                "not a {kind} file (magic {:?}, expected {:?})",
                got, expect
            )));
        }
        let version = self.u32("version")?;
        if version != FORMAT_VERSION {
            return Err(UdtError::data(format!(
                "unsupported {kind} format version {version} (this build reads {FORMAT_VERSION})"
            )));
        }
        Ok(())
    }

    fn finish(&self, kind: &str) -> Result<()> {
        if self.pos != self.buf.len() {
            return Err(UdtError::data(format!(
                "{} trailing bytes after {kind} payload (corrupt file?)",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Raw shard files (`.uds`): per-column typed lanes mirroring ColumnData.

const KIND_NUM: u8 = 0;
const KIND_CAT: u8 = 1;
const KIND_HYBRID: u8 = 2;
const FLAG_VALID: u8 = 1;

fn mask_words(n_rows: usize) -> usize {
    n_rows.div_ceil(64)
}

/// Serialize one shard's columns + label lane to the `.uds` byte layout.
pub fn encode_shard(columns: &[ColumnData], labels: &LabelLane) -> Vec<u8> {
    let n_rows = labels.len();
    debug_assert!(columns.iter().all(|c| c.len() == n_rows));
    let mut out = Vec::new();
    out.extend_from_slice(SHARD_MAGIC);
    put_u32(&mut out, FORMAT_VERSION);
    put_u64(&mut out, n_rows as u64);
    put_u64(&mut out, columns.len() as u64);
    for col in columns {
        match col {
            ColumnData::Num { vals, valid } => {
                out.push(KIND_NUM);
                out.push(if valid.is_some() { FLAG_VALID } else { 0 });
                put_f64s(&mut out, vals);
                if let Some(m) = valid {
                    put_u64s(&mut out, m.words());
                }
            }
            ColumnData::Cat { ids, valid } => {
                out.push(KIND_CAT);
                out.push(if valid.is_some() { FLAG_VALID } else { 0 });
                put_u32s(&mut out, ids);
                if let Some(m) = valid {
                    put_u64s(&mut out, m.words());
                }
            }
            ColumnData::Hybrid {
                vals,
                ids,
                num,
                cat,
            } => {
                out.push(KIND_HYBRID);
                out.push(0);
                put_f64s(&mut out, vals);
                put_u32s(&mut out, ids);
                put_u64s(&mut out, num.words());
                put_u64s(&mut out, cat.words());
            }
        }
    }
    match labels {
        LabelLane::Class(ids) => {
            out.push(0);
            put_u16s(&mut out, ids);
        }
        LabelLane::Reg(values) => {
            out.push(1);
            put_f64s(&mut out, values);
        }
    }
    out
}

/// Parse a `.uds` byte buffer back into typed columns + label lane.
/// `expect_cols` comes from the manifest; a mismatch is a `Data` error.
pub fn decode_shard(bytes: &[u8], expect_cols: usize) -> Result<(Vec<ColumnData>, LabelLane)> {
    let mut cur = Cur::new(bytes);
    cur.magic(SHARD_MAGIC, "shard")?;
    let n_rows = cur.counted("row")?;
    let n_cols = cur.counted("column")?;
    if n_cols != expect_cols {
        return Err(UdtError::data(format!(
            "shard has {n_cols} columns but the manifest says {expect_cols}"
        )));
    }
    let words = mask_words(n_rows);
    let mut columns = Vec::with_capacity(n_cols);
    for c in 0..n_cols {
        let kind = cur.u8("column kind")?;
        let flags = cur.u8("column flags")?;
        let col = match kind {
            KIND_NUM => {
                let vals = cur.f64s(n_rows, "numeric lane")?;
                let valid = if flags & FLAG_VALID != 0 {
                    Some(Bitmask::from_words(cur.u64s(words, "validity mask")?, n_rows))
                } else {
                    None
                };
                ColumnData::Num {
                    vals: vals.into(),
                    valid,
                }
            }
            KIND_CAT => {
                let ids = cur.u32s(n_rows, "categorical lane")?;
                let valid = if flags & FLAG_VALID != 0 {
                    Some(Bitmask::from_words(cur.u64s(words, "validity mask")?, n_rows))
                } else {
                    None
                };
                ColumnData::Cat {
                    ids: ids.into(),
                    valid,
                }
            }
            KIND_HYBRID => {
                let vals = cur.f64s(n_rows, "numeric lane")?;
                let ids = cur.u32s(n_rows, "categorical lane")?;
                let num = Bitmask::from_words(cur.u64s(words, "numeric kind mask")?, n_rows);
                let cat = Bitmask::from_words(cur.u64s(words, "categorical kind mask")?, n_rows);
                ColumnData::Hybrid {
                    vals: vals.into(),
                    ids: ids.into(),
                    num,
                    cat,
                }
            }
            k => {
                return Err(UdtError::data(format!(
                    "unknown column kind tag {k} for column {c}"
                )))
            }
        };
        columns.push(col);
    }
    let labels = match cur.u8("label kind")? {
        0 => LabelLane::Class(cur.u16s(n_rows, "class-id lane")?),
        1 => LabelLane::Reg(cur.f64s(n_rows, "target lane")?),
        k => return Err(UdtError::data(format!("unknown label kind tag {k}"))),
    };
    cur.finish("shard")?;
    Ok((columns, labels))
}

// ---------------------------------------------------------------------
// Bin-lane sidecar files (`.udb`): the training window. Numeric cells
// carry their dataset-level bin id, categorical cells their interner
// id; sentinels mark the other kinds so routing and accumulation never
// touch the f64 lanes again.

/// Sentinel bin id: the row holds no numeric cell for this column.
pub const NO_BIN_U8: u8 = u8::MAX;
/// Sentinel bin id (wide lane).
pub const NO_BIN_U16: u16 = u16::MAX;
/// Sentinel categorical id: the row holds no categorical cell.
pub const NO_CAT: u32 = u32::MAX;

/// Bin-id lane of one column in one shard. `U8` when the edge table has
/// ≤ 255 bins (255 is the sentinel), `U16` otherwise (`max_bins` is
/// capped at 65535, so 65535 is free for the sentinel).
#[derive(Debug, Clone)]
pub enum BinIdLane {
    U8(Vec<u8>),
    U16(Vec<u16>),
}

impl BinIdLane {
    /// Bin id of row `i`, `None` for non-numeric cells.
    #[inline]
    pub fn get(&self, i: usize) -> Option<u32> {
        match self {
            // ANALYZE-ALLOW(as-truncation): u8 -> u32 widens, it cannot truncate
            BinIdLane::U8(v) => (v[i] != NO_BIN_U8).then(|| v[i] as u32),
            // ANALYZE-ALLOW(as-truncation): u16 -> u32 widens, it cannot truncate
            BinIdLane::U16(v) => (v[i] != NO_BIN_U16).then(|| v[i] as u32),
        }
    }

    pub fn len(&self) -> usize {
        match self {
            BinIdLane::U8(v) => v.len(),
            BinIdLane::U16(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Resident bytes of the lane.
    pub fn approx_bytes(&self) -> usize {
        match self {
            BinIdLane::U8(v) => v.len(),
            BinIdLane::U16(v) => v.len() * 2,
        }
    }
}

/// One shard's decoded training window: bin-id + cat-id lanes and the
/// label lane. This — not the raw f64 columns — is what every training
/// pass holds in memory, one shard at a time (read → accumulate →
/// drop).
#[derive(Debug, Clone)]
pub struct BinWindow {
    pub n_rows: usize,
    /// Per feature: bin-id lane, `None` when the column has no numeric
    /// cells anywhere in the dataset.
    pub bins: Vec<Option<BinIdLane>>,
    /// Per feature: categorical-id lane (sentinel [`NO_CAT`]), `None`
    /// when the column has no categorical cells anywhere.
    pub cats: Vec<Option<Vec<u32>>>,
    pub labels: LabelLane,
}

impl BinWindow {
    /// Resident bytes of every lane in the window — the quantity the
    /// `peak_shard_window_bytes` witness tracks.
    pub fn approx_bytes(&self) -> usize {
        self.bins
            .iter()
            .flatten()
            .map(BinIdLane::approx_bytes)
            .sum::<usize>()
            + self
                .cats
                .iter()
                .flatten()
                .map(|v| v.len() * 4)
                .sum::<usize>()
            + self.labels.approx_bytes()
    }
}

/// Serialize one shard's training window to the `.udb` byte layout.
pub fn encode_bin_window(w: &BinWindow) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(BINS_MAGIC);
    put_u32(&mut out, FORMAT_VERSION);
    put_u64(&mut out, w.n_rows as u64);
    put_u64(&mut out, w.bins.len() as u64);
    for (bin, cat) in w.bins.iter().zip(&w.cats) {
        match bin {
            None => out.push(0),
            Some(BinIdLane::U8(v)) => {
                out.push(1);
                out.extend_from_slice(v);
            }
            Some(BinIdLane::U16(v)) => {
                out.push(2);
                put_u16s(&mut out, v);
            }
        }
        match cat {
            None => out.push(0),
            Some(ids) => {
                out.push(1);
                put_u32s(&mut out, ids);
            }
        }
    }
    match &w.labels {
        LabelLane::Class(ids) => {
            out.push(0);
            put_u16s(&mut out, ids);
        }
        LabelLane::Reg(values) => {
            out.push(1);
            put_f64s(&mut out, values);
        }
    }
    out
}

/// Parse a `.udb` byte buffer back into a training window.
pub fn decode_bin_window(bytes: &[u8], expect_cols: usize) -> Result<BinWindow> {
    let mut cur = Cur::new(bytes);
    cur.magic(BINS_MAGIC, "bin-lane sidecar")?;
    let n_rows = cur.counted("row")?;
    let n_cols = cur.counted("column")?;
    if n_cols != expect_cols {
        return Err(UdtError::data(format!(
            "bin sidecar has {n_cols} columns but the manifest says {expect_cols}"
        )));
    }
    let mut bins = Vec::with_capacity(n_cols);
    let mut cats = Vec::with_capacity(n_cols);
    for _ in 0..n_cols {
        bins.push(match cur.u8("bin lane tag")? {
            0 => None,
            1 => Some(BinIdLane::U8(cur.bytes(n_rows, "u8 bin lane")?)),
            2 => Some(BinIdLane::U16(cur.u16s(n_rows, "u16 bin lane")?)),
            t => return Err(UdtError::data(format!("unknown bin lane tag {t}"))),
        });
        cats.push(match cur.u8("cat lane tag")? {
            0 => None,
            1 => Some(cur.u32s(n_rows, "cat-id lane")?),
            t => return Err(UdtError::data(format!("unknown cat lane tag {t}"))),
        });
    }
    let labels = match cur.u8("label kind")? {
        0 => LabelLane::Class(cur.u16s(n_rows, "class-id lane")?),
        1 => LabelLane::Reg(cur.f64s(n_rows, "target lane")?),
        k => return Err(UdtError::data(format!("unknown label kind tag {k}"))),
    };
    cur.finish("bin sidecar")?;
    Ok(BinWindow {
        n_rows,
        bins,
        cats,
        labels,
    })
}

// ---------------------------------------------------------------------
// Edge tables (`edges.bin`): the global quantile bin edges + per-column
// categorical cardinality, stored in binary so every f64 round-trips
// bit-exactly (node-for-node identity with in-memory training depends
// on it).

/// Global binning metadata of one `bins-<B>` directory.
#[derive(Debug, Clone)]
pub struct BinsMeta {
    pub max_bins: usize,
    /// Per-shard reservoir size used by the edge pass (0 = exact).
    pub sample_rows: usize,
    /// Per feature: ascending bin-edge table (actual data values);
    /// `None` when the column has no numeric cells.
    pub edges: Vec<Option<Vec<f64>>>,
    /// Per feature: number of distinct categorical ids (max id + 1);
    /// 0 when the column has no categorical cells.
    pub cat_card: Vec<u32>,
    /// Sidecar file name + FNV-1a checksum, aligned with the manifest's
    /// shard list.
    pub shard_files: Vec<(String, u64)>,
}

/// Serialize edge tables + cardinalities to the `edges.bin` layout.
pub fn encode_edges(meta: &BinsMeta) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(EDGES_MAGIC);
    put_u32(&mut out, FORMAT_VERSION);
    put_u64(&mut out, meta.max_bins as u64);
    put_u64(&mut out, meta.sample_rows as u64);
    put_u64(&mut out, meta.edges.len() as u64);
    for (edges, &card) in meta.edges.iter().zip(&meta.cat_card) {
        match edges {
            None => out.push(0),
            Some(e) => {
                out.push(1);
                put_u64(&mut out, e.len() as u64);
                put_f64s(&mut out, e);
            }
        }
        put_u32(&mut out, card);
    }
    out
}

/// Parse an `edges.bin` buffer; `shard_files` is filled by the caller
/// from `bins.json`.
pub fn decode_edges(bytes: &[u8], expect_cols: usize) -> Result<BinsMeta> {
    let mut cur = Cur::new(bytes);
    cur.magic(EDGES_MAGIC, "edge table")?;
    let max_bins = cur.counted("max_bins")?;
    let sample_rows = cur.counted("sample_rows")?;
    let n_cols = cur.counted("column")?;
    if n_cols != expect_cols {
        return Err(UdtError::data(format!(
            "edge table has {n_cols} columns but the manifest says {expect_cols}"
        )));
    }
    let mut edges = Vec::with_capacity(n_cols);
    let mut cat_card = Vec::with_capacity(n_cols);
    for _ in 0..n_cols {
        edges.push(match cur.u8("edge tag")? {
            0 => None,
            1 => {
                let n = cur.counted("edge")?;
                Some(cur.f64s(n, "edge values")?)
            }
            t => return Err(UdtError::data(format!("unknown edge tag {t}"))),
        });
        cat_card.push(cur.u32("categorical cardinality")?);
    }
    cur.finish("edge table")?;
    Ok(BinsMeta {
        max_bins,
        sample_rows,
        edges,
        cat_card,
        shard_files: Vec::new(),
    })
}

// ---------------------------------------------------------------------
// Manifests.

/// One shard's entry in `manifest.json`.
#[derive(Debug, Clone)]
pub struct ShardEntry {
    pub file: String,
    pub n_rows: usize,
    /// Global row id of this shard's first row.
    pub row_offset: usize,
    /// File size in bytes (verified before decode).
    pub bytes: usize,
    /// FNV-1a 64 of the file contents (verified before decode).
    pub checksum: u64,
}

/// The `manifest.json` of a shard directory: schema (feature names,
/// interner, class names), task kind, row counts and the shard list.
#[derive(Debug, Clone)]
pub struct ShardManifest {
    pub name: String,
    pub task: TaskKind,
    pub n_rows: usize,
    pub feature_names: Vec<String>,
    /// The merged interner's names in id order — re-interning them in
    /// order reproduces every categorical id on the lanes.
    pub cat_names: Vec<String>,
    /// Class names in class-id order (classification; empty for
    /// regression).
    pub class_names: Vec<String>,
    pub shards: Vec<ShardEntry>,
}

fn hex_u64(v: u64) -> String {
    format!("{v:016x}")
}

fn parse_hex_u64(s: &str, what: &str) -> Result<u64> {
    u64::from_str_radix(s, 16)
        .map_err(|_| UdtError::data(format!("manifest: bad {what} checksum `{s}`")))
}

fn str_array(j: &Json, key: &str) -> Result<Vec<String>> {
    let arr = j
        .get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| UdtError::data(format!("manifest: missing array `{key}`")))?;
    arr.iter()
        .map(|v| {
            v.as_str()
                .map(str::to_string)
                .ok_or_else(|| UdtError::data(format!("manifest: `{key}` holds a non-string")))
        })
        .collect()
}

fn usize_field(j: &Json, key: &str) -> Result<usize> {
    j.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| UdtError::data(format!("manifest: missing number `{key}`")))
}

fn str_field<'j>(j: &'j Json, key: &str) -> Result<&'j str> {
    j.get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| UdtError::data(format!("manifest: missing string `{key}`")))
}

impl ShardManifest {
    pub fn n_features(&self) -> usize {
        self.feature_names.len()
    }

    pub fn to_json(&self) -> Json {
        let task = match self.task {
            TaskKind::Classification => "classification",
            TaskKind::Regression => "regression",
        };
        Json::obj(vec![
            ("format", Json::Str("udt-shards".into())),
            ("version", Json::Num(FORMAT_VERSION as f64)),
            ("name", Json::Str(self.name.clone())),
            ("task", Json::Str(task.into())),
            ("n_rows", Json::Num(self.n_rows as f64)),
            (
                "feature_names",
                Json::Arr(self.feature_names.iter().map(|s| Json::Str(s.clone())).collect()),
            ),
            (
                "cat_names",
                Json::Arr(self.cat_names.iter().map(|s| Json::Str(s.clone())).collect()),
            ),
            (
                "class_names",
                Json::Arr(self.class_names.iter().map(|s| Json::Str(s.clone())).collect()),
            ),
            (
                "shards",
                Json::Arr(
                    self.shards
                        .iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("file", Json::Str(s.file.clone())),
                                ("n_rows", Json::Num(s.n_rows as f64)),
                                ("row_offset", Json::Num(s.row_offset as f64)),
                                ("bytes", Json::Num(s.bytes as f64)),
                                ("checksum", Json::Str(hex_u64(s.checksum))),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ShardManifest> {
        if str_field(j, "format")? != "udt-shards" {
            return Err(UdtError::data("manifest: not a udt-shards manifest"));
        }
        let version = usize_field(j, "version")?;
        if version != FORMAT_VERSION as usize {
            return Err(UdtError::data(format!(
                "manifest: unsupported version {version} (this build reads {FORMAT_VERSION})"
            )));
        }
        let task = match str_field(j, "task")? {
            "classification" => TaskKind::Classification,
            "regression" => TaskKind::Regression,
            t => return Err(UdtError::data(format!("manifest: unknown task `{t}`"))),
        };
        let n_rows = usize_field(j, "n_rows")?;
        let shards_json = j
            .get("shards")
            .and_then(Json::as_arr)
            .ok_or_else(|| UdtError::data("manifest: missing array `shards`"))?;
        let mut shards = Vec::with_capacity(shards_json.len());
        let mut expect_offset = 0usize;
        for s in shards_json {
            let entry = ShardEntry {
                file: str_field(s, "file")?.to_string(),
                n_rows: usize_field(s, "n_rows")?,
                row_offset: usize_field(s, "row_offset")?,
                bytes: usize_field(s, "bytes")?,
                checksum: parse_hex_u64(str_field(s, "checksum")?, "shard")?,
            };
            if entry.row_offset != expect_offset {
                return Err(UdtError::data(format!(
                    "manifest: shard `{}` starts at row {} but the previous shards \
                     cover {} rows",
                    entry.file, entry.row_offset, expect_offset
                )));
            }
            expect_offset += entry.n_rows;
            shards.push(entry);
        }
        if expect_offset != n_rows {
            return Err(UdtError::data(format!(
                "manifest: shards cover {expect_offset} rows but n_rows is {n_rows}"
            )));
        }
        let manifest = ShardManifest {
            name: str_field(j, "name")?.to_string(),
            task,
            n_rows,
            feature_names: str_array(j, "feature_names")?,
            cat_names: str_array(j, "cat_names")?,
            class_names: str_array(j, "class_names")?,
            shards,
        };
        if manifest.feature_names.is_empty() {
            return Err(UdtError::data("manifest: no feature columns"));
        }
        Ok(manifest)
    }
}

/// Serialize the `bins.json` document for a sidecar directory.
pub fn bins_json(meta: &BinsMeta, edges_checksum: u64) -> Json {
    Json::obj(vec![
        ("format", Json::Str("udt-bins".into())),
        ("version", Json::Num(FORMAT_VERSION as f64)),
        ("max_bins", Json::Num(meta.max_bins as f64)),
        ("sample_rows", Json::Num(meta.sample_rows as f64)),
        ("edges_checksum", Json::Str(hex_u64(edges_checksum))),
        (
            "shards",
            Json::Arr(
                meta.shard_files
                    .iter()
                    .map(|(file, sum)| {
                        Json::obj(vec![
                            ("file", Json::Str(file.clone())),
                            ("checksum", Json::Str(hex_u64(*sum))),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Parse a `bins.json` document: `(max_bins, sample_rows,
/// edges_checksum, shard files)`.
pub fn parse_bins_json(j: &Json) -> Result<(usize, usize, u64, Vec<(String, u64)>)> {
    if str_field(j, "format")? != "udt-bins" {
        return Err(UdtError::data("bins.json: not a udt-bins manifest"));
    }
    let version = usize_field(j, "version")?;
    if version != FORMAT_VERSION as usize {
        return Err(UdtError::data(format!(
            "bins.json: unsupported version {version} (this build reads {FORMAT_VERSION})"
        )));
    }
    let max_bins = usize_field(j, "max_bins")?;
    let sample_rows = usize_field(j, "sample_rows")?;
    let edges_checksum = parse_hex_u64(str_field(j, "edges_checksum")?, "edge table")?;
    let shards_json = j
        .get("shards")
        .and_then(Json::as_arr)
        .ok_or_else(|| UdtError::data("bins.json: missing array `shards`"))?;
    let mut files = Vec::with_capacity(shards_json.len());
    for s in shards_json {
        files.push((
            str_field(s, "file")?.to_string(),
            parse_hex_u64(str_field(s, "checksum")?, "sidecar")?,
        ));
    }
    Ok((max_bins, sample_rows, edges_checksum, files))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::value::Value;

    fn hybrid_cols() -> Vec<ColumnData> {
        vec![
            ColumnData::from_cells(&[Value::Num(1.5), Value::Num(-2.0), Value::Missing]),
            ColumnData::from_cells(&[
                Value::Cat(crate::data::interner::CatId(3)),
                Value::Num(7.0),
                Value::Cat(crate::data::interner::CatId(0)),
            ]),
        ]
    }

    #[test]
    fn shard_round_trips_every_column_kind() {
        let cols = hybrid_cols();
        let labels = LabelLane::Class(vec![0, 1, 0]);
        let bytes = encode_shard(&cols, &labels);
        let (back, lab) = decode_shard(&bytes, 2).unwrap();
        assert_eq!(back.len(), 2);
        for (a, b) in cols.iter().zip(&back) {
            assert_eq!(a.cells(), b.cells());
        }
        match lab {
            LabelLane::Class(ids) => assert_eq!(ids, vec![0, 1, 0]),
            LabelLane::Reg(_) => panic!("wrong label kind"),
        }

        let reg = LabelLane::Reg(vec![0.25, -1.5, 9.0]);
        let bytes = encode_shard(&cols, &reg);
        let (_, lab) = decode_shard(&bytes, 2).unwrap();
        match lab {
            LabelLane::Reg(v) => assert_eq!(v, vec![0.25, -1.5, 9.0]),
            LabelLane::Class(_) => panic!("wrong label kind"),
        }
    }

    #[test]
    fn truncated_and_corrupt_shards_are_typed_data_errors() {
        let cols = hybrid_cols();
        let bytes = encode_shard(&cols, &LabelLane::Class(vec![0, 1, 0]));
        // Truncation at any prefix is a Data error, never a panic.
        for cut in [0, 3, 4, 8, 16, bytes.len() / 2, bytes.len() - 1] {
            match decode_shard(&bytes[..cut], 2) {
                Err(UdtError::Data(_)) => {}
                other => panic!("cut at {cut}: expected Data error, got {other:?}"),
            }
        }
        // Trailing garbage is detected too.
        let mut padded = bytes.clone();
        padded.push(0);
        assert!(matches!(decode_shard(&padded, 2), Err(UdtError::Data(_))));
        // Wrong magic.
        let mut wrong = bytes.clone();
        wrong[0] = b'X';
        assert!(matches!(decode_shard(&wrong, 2), Err(UdtError::Data(_))));
        // Column-count mismatch against the manifest.
        assert!(matches!(decode_shard(&bytes, 3), Err(UdtError::Data(_))));
    }

    #[test]
    fn bin_window_round_trips() {
        let w = BinWindow {
            n_rows: 3,
            bins: vec![
                Some(BinIdLane::U8(vec![0, 2, NO_BIN_U8])),
                None,
                Some(BinIdLane::U16(vec![300, NO_BIN_U16, 1])),
            ],
            cats: vec![
                None,
                Some(vec![1, NO_CAT, 0]),
                Some(vec![NO_CAT, 2, NO_CAT]),
            ],
            labels: LabelLane::Reg(vec![1.0, 2.0, 3.0]),
        };
        let bytes = encode_bin_window(&w);
        let back = decode_bin_window(&bytes, 3).unwrap();
        assert_eq!(back.n_rows, 3);
        assert_eq!(back.approx_bytes(), w.approx_bytes());
        assert_eq!(back.bins[0].as_ref().unwrap().get(0), Some(0));
        assert_eq!(back.bins[0].as_ref().unwrap().get(2), None);
        assert_eq!(back.bins[2].as_ref().unwrap().get(0), Some(300));
        assert_eq!(back.bins[2].as_ref().unwrap().get(1), None);
        assert!(back.bins[1].is_none());
        assert_eq!(back.cats[1].as_ref().unwrap(), &vec![1, NO_CAT, 0]);
        // Truncated sidecar → typed Data error.
        assert!(matches!(
            decode_bin_window(&bytes[..bytes.len() - 2], 3),
            Err(UdtError::Data(_))
        ));
    }

    #[test]
    fn edges_round_trip_bit_exactly() {
        let meta = BinsMeta {
            max_bins: 256,
            sample_rows: 0,
            edges: vec![
                Some(vec![0.1, 0.30000000000000004, 1e300, -0.0]),
                None,
            ],
            cat_card: vec![0, 7],
            shard_files: Vec::new(),
        };
        let bytes = encode_edges(&meta);
        let back = decode_edges(&bytes, 2).unwrap();
        assert_eq!(back.max_bins, 256);
        let e = back.edges[0].as_ref().unwrap();
        for (a, b) in meta.edges[0].as_ref().unwrap().iter().zip(e) {
            assert_eq!(a.to_bits(), b.to_bits(), "edge must round-trip bit-exactly");
        }
        assert!(back.edges[1].is_none());
        assert_eq!(back.cat_card, vec![0, 7]);
    }

    #[test]
    fn manifest_round_trips_and_rejects_corruption() {
        let m = ShardManifest {
            name: "t".into(),
            task: TaskKind::Classification,
            n_rows: 10,
            feature_names: vec!["a".into(), "b".into()],
            cat_names: vec!["x".into()],
            class_names: vec!["no".into(), "yes".into()],
            shards: vec![
                ShardEntry {
                    file: "shard-00000.uds".into(),
                    n_rows: 6,
                    row_offset: 0,
                    bytes: 100,
                    checksum: 0xdeadbeef,
                },
                ShardEntry {
                    file: "shard-00001.uds".into(),
                    n_rows: 4,
                    row_offset: 6,
                    bytes: 80,
                    checksum: 1,
                },
            ],
        };
        let text = m.to_json().to_pretty();
        let back = ShardManifest::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.n_rows, 10);
        assert_eq!(back.feature_names, m.feature_names);
        assert_eq!(back.shards[1].checksum, 1);
        assert_eq!(back.shards[1].row_offset, 6);
        assert_eq!(back.task, TaskKind::Classification);

        // Row-coverage mismatches are rejected.
        let mut bad = m.clone();
        bad.shards[1].n_rows = 5;
        let j = Json::parse(&bad.to_json().to_string()).unwrap();
        assert!(matches!(ShardManifest::from_json(&j), Err(UdtError::Data(_))));
        let mut bad = m.clone();
        bad.shards[1].row_offset = 7;
        let j = Json::parse(&bad.to_json().to_string()).unwrap();
        assert!(matches!(ShardManifest::from_json(&j), Err(UdtError::Data(_))));
        // Missing fields are rejected.
        let j = Json::parse(r#"{"format":"udt-shards","version":1}"#).unwrap();
        assert!(matches!(ShardManifest::from_json(&j), Err(UdtError::Data(_))));
        // Wrong format string.
        let j = Json::parse(r#"{"format":"something-else"}"#).unwrap();
        assert!(matches!(ShardManifest::from_json(&j), Err(UdtError::Data(_))));
    }

    #[test]
    fn fnv_is_stable() {
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_ne!(fnv1a64(b"ab"), fnv1a64(b"ba"));
    }
}
