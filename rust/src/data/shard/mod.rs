//! Out-of-core columnar shards: a versioned on-disk dataset format for
//! training far beyond RAM.
//!
//! A shard directory is produced by `udt shard` (streaming CSV, never
//! materializing the dataset) or [`writer::write_dataset_shards`], and
//! consumed by [`dataset::ShardedDataset`] +
//! [`crate::tree::sharded::fit_sharded`], which trains with one shard
//! window resident at a time.
//!
//! # On-disk layout
//!
//! ```text
//! mydata.shards/
//! ├── manifest.json            schema + shard list (see below)
//! ├── shard-00000.uds          raw typed lanes, rows [0, n0)
//! ├── shard-00001.uds          rows [n0, n0+n1)   …
//! └── bins-256/                sidecars for max_bins=256 (built lazily)
//!     ├── bins.json            parameters + checksums
//!     ├── edges.bin            global quantile bin edges (binary f64)
//!     ├── shard-00000.udb      bin-id/cat-id training window
//!     └── shard-00001.udb      …
//!
//! shard-NNNNN.uds  ("UDSH", version u32, n_rows u64, n_cols u64, LE)
//!   per column:
//!     kind u8 (0=Num 1=Cat 2=Hybrid) · flags u8 (bit0: validity mask)
//!     Num:    vals f64×n  [+ mask u64×⌈n/64⌉]
//!     Cat:    ids  u32×n  [+ mask]
//!     Hybrid: vals f64×n · ids u32×n · num-mask · cat-mask
//!   label lane: tag u8 (0=class u16×n, 1=target f64×n)
//!
//! shard-NNNNN.udb  ("UDSB", header as above)
//!   per column:
//!     bin tag u8 (0=none, 1=u8 lane sentinel 255, 2=u16 lane
//!     sentinel 65535) · lane, then cat tag u8 (0=none, 1=u32 lane
//!     sentinel 2³²−1) · lane
//!   label lane duplicated, so training passes touch only this file
//!
//! edges.bin  ("UDSE", version, max_bins u64, sample_rows u64, n_cols)
//!   per column: tag u8 (0=no numeric lane, 1=edges) ·
//!   [n_edges u64 · edges f64×n] · cat_card u32
//! ```
//!
//! `manifest.json` fields: `format`/`version`, dataset `name`, `task`,
//! total `n_rows`, `feature_names`, `cat_names` (the merged interner's
//! names in id order — re-interning them in order reproduces every
//! categorical id), `class_names`, and `shards` (per shard: `file`,
//! `n_rows`, `row_offset`, `bytes`, FNV-1a-64 `checksum` as hex).
//! Every file read is verified against its recorded size/checksum
//! before decoding; any mismatch, truncation, bad magic, version skew
//! or trailing garbage is a typed [`crate::error::UdtError::Data`].
//!
//! # RAM model
//!
//! Training memory is bounded by **one** shard's decoded window plus
//! per-node histogram scratch, independent of total dataset size:
//!
//! * edge pass — per-column distinct-value run maps (or bounded
//!   reservoirs with `shard.sample_rows`), one raw shard resident;
//! * histogram passes — one decoded `.udb` window (u8/u16 bin ids +
//!   u32 cat ids + labels) resident at a time: read → accumulate →
//!   drop; per-node histograms use parent-minus-sibling subtraction so
//!   only the smaller child is ever accumulated;
//! * a `peak_shard_window_bytes` witness tracks the largest resident
//!   window and is asserted in tests and surfaced in the pipeline
//!   report.
//!
//! Bin edges are computed by the same run-based quantile loop as
//! in-memory binning, so sharded training is node-for-node identical
//! to `--backend binned` on the same `max_bins` (property-tested).

pub mod dataset;
pub mod format;
pub mod writer;

pub use dataset::{ShardBins, ShardedDataset};
pub use format::{BinWindow, BinsMeta, LabelLane, ShardEntry, ShardManifest};
pub use writer::{shard_csv_file, shard_csv_str, write_dataset_shards};
