//! `ShardedDataset`: the windowed reader over a shard directory.
//!
//! Opening a directory loads only `manifest.json`; shard lanes come and
//! go one window at a time through [`ShardedDataset::read_shard`] /
//! [`ShardBins::read_window`], each read verified against the
//! manifest's byte count and FNV-1a checksum before decoding. The
//! global quantile bin edges and per-shard bin-id sidecars are built
//! (or reloaded) by [`ShardedDataset::ensure_bins`]; the edge pass
//! merges per-column distinct-value runs across shards and feeds the
//! *same* bin-assignment loop as in-memory binning
//! ([`crate::runtime::binning::quantile_bins_from_runs`]), so the edge
//! tables are bit-identical to `Dataset::binned_index` on the
//! assembled data.

use std::collections::HashMap;
use std::fs;
use std::path::{Path, PathBuf};

use crate::coordinator::parallel::parallel_map;
use crate::data::column_data::ColumnData;
use crate::data::dataset::TaskKind;
use crate::data::value::Value;
use crate::error::{Result, UdtError};
use crate::runtime::binning::quantile_bins_from_runs;
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::format::{
    bins_json, decode_bin_window, decode_edges, decode_shard, encode_bin_window, encode_edges,
    fnv1a64, parse_bins_json, BinIdLane, BinWindow, BinsMeta, LabelLane, ShardManifest,
    NO_BIN_U16, NO_BIN_U8, NO_CAT,
};

/// A shard directory opened for windowed reading. Holds the manifest
/// only — never more than one shard's lanes are resident at a time.
#[derive(Debug, Clone)]
pub struct ShardedDataset {
    dir: PathBuf,
    manifest: ShardManifest,
}

impl ShardedDataset {
    /// Open a shard directory by parsing and validating its
    /// `manifest.json`.
    pub fn open(dir: impl AsRef<Path>) -> Result<ShardedDataset> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = fs::read_to_string(&path).map_err(|e| {
            UdtError::data(format!(
                "cannot read shard manifest `{}`: {e}",
                path.display()
            ))
        })?;
        let json = Json::parse(&text)
            .map_err(|e| UdtError::data(format!("manifest.json: {e}")))?;
        let manifest = ShardManifest::from_json(&json)?;
        Ok(ShardedDataset { dir, manifest })
    }

    pub fn manifest(&self) -> &ShardManifest {
        &self.manifest
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn n_shards(&self) -> usize {
        self.manifest.shards.len()
    }

    pub fn n_rows(&self) -> usize {
        self.manifest.n_rows
    }

    pub fn n_features(&self) -> usize {
        self.manifest.n_features()
    }

    pub fn task(&self) -> TaskKind {
        self.manifest.task
    }

    pub fn n_classes(&self) -> usize {
        self.manifest.class_names.len()
    }

    /// Read, verify and decode raw shard `i` (typed f64/u32 lanes).
    pub fn read_shard(&self, i: usize) -> Result<(Vec<ColumnData>, LabelLane)> {
        let entry = &self.manifest.shards[i];
        let bytes = read_verified(
            &self.dir.join(&entry.file),
            entry.bytes,
            entry.checksum,
            &entry.file,
        )?;
        let (cols, labels) = decode_shard(&bytes, self.n_features())?;
        if labels.len() != entry.n_rows {
            return Err(UdtError::data(format!(
                "shard `{}` holds {} rows but the manifest says {}",
                entry.file,
                labels.len(),
                entry.n_rows
            )));
        }
        if labels.kind() != self.manifest.task {
            return Err(UdtError::data(format!(
                "shard `{}` label lane does not match the manifest task",
                entry.file
            )));
        }
        Ok((cols, labels))
    }

    fn bins_dir(&self, max_bins: usize, sample_rows: usize) -> PathBuf {
        if sample_rows == 0 {
            self.dir.join(format!("bins-{max_bins}"))
        } else {
            self.dir.join(format!("bins-{max_bins}-s{sample_rows}"))
        }
    }

    /// Load the bin sidecars for (`max_bins`, `sample_rows`), building
    /// them if absent or stale. Building costs two passes over the raw
    /// shards (edge/cardinality statistics, then bin-id lane writes);
    /// reloading costs none. `sample_rows > 0` reservoir-samples that
    /// many numeric values per (shard, column) during the edge pass —
    /// approximate edges, bounded edge-pass memory.
    pub fn ensure_bins(
        &self,
        max_bins: usize,
        sample_rows: usize,
        n_threads: usize,
    ) -> Result<ShardBins> {
        let dir = self.bins_dir(max_bins, sample_rows);
        if let Some(bins) = self.try_load_bins(&dir, max_bins, sample_rows)? {
            return Ok(bins);
        }
        self.build_bins(&dir, max_bins, sample_rows, n_threads)
    }

    /// Reload an existing sidecar directory; `Ok(None)` when absent or
    /// written for different parameters (stale sidecars rebuild).
    fn try_load_bins(
        &self,
        dir: &Path,
        max_bins: usize,
        sample_rows: usize,
    ) -> Result<Option<ShardBins>> {
        let meta_path = dir.join("bins.json");
        let Ok(text) = fs::read_to_string(&meta_path) else {
            return Ok(None);
        };
        let json =
            Json::parse(&text).map_err(|e| UdtError::data(format!("bins.json: {e}")))?;
        let (got_bins, got_sample, edges_sum, files) = parse_bins_json(&json)?;
        if got_bins != max_bins || got_sample != sample_rows || files.len() != self.n_shards() {
            return Ok(None);
        }
        let edge_bytes = read_verified(&dir.join("edges.bin"), usize::MAX, edges_sum, "edges.bin")?;
        let mut meta = decode_edges(&edge_bytes, self.n_features())?;
        meta.shard_files = files;
        if meta.max_bins != max_bins || meta.sample_rows != sample_rows {
            return Ok(None);
        }
        Ok(Some(ShardBins {
            dir: dir.to_path_buf(),
            n_features: self.n_features(),
            meta,
            built: false,
        }))
    }

    /// Two-pass sidecar build: (1) merge per-column distinct-value runs
    /// (or reservoir samples) and categorical cardinalities across
    /// shards, fix global bin edges; (2) re-read each shard, scatter
    /// its cells into bin-id / cat-id lanes, write the `.udb` file.
    fn build_bins(
        &self,
        dir: &Path,
        max_bins: usize,
        sample_rows: usize,
        n_threads: usize,
    ) -> Result<ShardBins> {
        fs::create_dir_all(dir)?;
        let n_features = self.n_features();

        // Pass 1: per-column value statistics. Exact mode keeps one
        // (value-bits → count) map per column; sampling keeps one
        // bounded reservoir per column instead.
        let mut counts: Vec<HashMap<u64, usize>> = vec![HashMap::new(); n_features];
        let mut reservoirs: Vec<Reservoir> = (0..n_features)
            .map(|c| Reservoir::new(sample_rows, c as u64))
            .collect();
        let mut cat_card = vec![0u32; n_features];
        for i in 0..self.n_shards() {
            let (cols, _) = self.read_shard(i)?;
            for (c, col) in cols.iter().enumerate() {
                for r in 0..col.len() {
                    match col.get(r) {
                        Value::Num(v) => {
                            // -0.0 and 0.0 are equal values; key them as
                            // one run like the in-memory `==` scan does.
                            let v = if v == 0.0 { 0.0 } else { v };
                            if sample_rows == 0 {
                                *counts[c].entry(v.to_bits()).or_insert(0) += 1;
                            } else {
                                reservoirs[c].offer(v);
                            }
                        }
                        Value::Cat(id) => cat_card[c] = cat_card[c].max(id.0 + 1),
                        Value::Missing => {}
                    }
                }
            }
            // Sampling mode: each shard contributes at most
            // `sample_rows` values per column.
            if sample_rows > 0 {
                for res in &mut reservoirs {
                    res.commit(&mut counts);
                }
            }
        }

        let mut edges: Vec<Option<Vec<f64>>> = Vec::with_capacity(n_features);
        for map in &mut counts {
            if map.is_empty() {
                edges.push(None);
                continue;
            }
            let mut runs: Vec<(f64, usize)> = map
                .drain()
                .map(|(bits, n)| (f64::from_bits(bits), n))
                .collect();
            // ANALYZE-ALLOW(no-unwrap): keys are bits of non-NaN cells (NaN ingests as Missing)
            runs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            edges.push(quantile_bins_from_runs(&runs, max_bins).map(|rb| rb.edges));
        }

        let mut meta = BinsMeta {
            max_bins,
            sample_rows,
            edges,
            cat_card,
            shard_files: Vec::new(),
        };

        // Pass 2: scatter every shard into bin-id / cat-id lanes.
        for i in 0..self.n_shards() {
            let (cols, labels) = self.read_shard(i)?;
            let n_rows = labels.len();
            let lanes = parallel_map(
                (0..n_features).collect(),
                n_threads,
                |c| build_lanes(&cols[c], &meta.edges[c], meta.cat_card[c], n_rows),
            );
            let mut window = BinWindow {
                n_rows,
                bins: Vec::with_capacity(n_features),
                cats: Vec::with_capacity(n_features),
                labels,
            };
            for (bin, cat) in lanes {
                window.bins.push(bin);
                window.cats.push(cat);
            }
            let bytes = encode_bin_window(&window);
            let file = format!("shard-{i:05}.udb");
            fs::write(dir.join(&file), &bytes)?;
            meta.shard_files.push((file, fnv1a64(&bytes)));
        }

        let edge_bytes = encode_edges(&meta);
        let edges_sum = fnv1a64(&edge_bytes);
        fs::write(dir.join("edges.bin"), &edge_bytes)?;
        fs::write(
            dir.join("bins.json"),
            bins_json(&meta, edges_sum).to_pretty() + "\n",
        )?;
        Ok(ShardBins {
            dir: dir.to_path_buf(),
            n_features,
            meta,
            built: true,
        })
    }
}

/// One column's bounded reservoir for the sampled edge pass, reseeded
/// deterministically per shard in [`Reservoir::commit`].
struct Reservoir {
    cap: usize,
    col: u64,
    shard: u64,
    seen: usize,
    vals: Vec<f64>,
    rng: Rng,
}

impl Reservoir {
    fn new(cap: usize, col: u64) -> Reservoir {
        Reservoir {
            cap,
            col,
            shard: 0,
            seen: 0,
            vals: Vec::new(),
            rng: Rng::new(0x5eed_0000 ^ col),
        }
    }

    /// Algorithm R over this shard's numeric values of the column.
    fn offer(&mut self, v: f64) {
        self.seen += 1;
        if self.vals.len() < self.cap {
            self.vals.push(v);
        } else {
            let j = self.rng.below(self.seen as u64) as usize;
            if j < self.cap {
                self.vals[j] = v;
            }
        }
    }

    /// Fold the shard's sample into the global per-column run counts
    /// and reset for the next shard.
    fn commit(&mut self, counts: &mut [HashMap<u64, usize>]) {
        for &v in &self.vals {
            *counts[self.col as usize].entry(v.to_bits()).or_insert(0) += 1;
        }
        self.vals.clear();
        self.seen = 0;
        self.shard += 1;
        self.rng = Rng::new(0x5eed_0000 ^ self.col ^ (self.shard << 32));
    }
}

/// Build one column's bin-id and cat-id lanes for one shard.
fn build_lanes(
    col: &ColumnData,
    edges: &Option<Vec<f64>>,
    cat_card: u32,
    n_rows: usize,
) -> (Option<BinIdLane>, Option<Vec<u32>>) {
    let bins = edges.as_ref().map(|edges| {
        let last = edges.len().saturating_sub(1);
        let bin_of = |r: usize| -> Option<usize> {
            match col.get(r) {
                Value::Num(v) => {
                    // First edge ≥ v is v's bin (edges are bin maxima);
                    // sampled edge tables may not cover the extremes, so
                    // clamp overshoot into the last bin.
                    Some(edges.partition_point(|e| *e < v).min(last))
                }
                _ => None,
            }
        };
        if edges.len() <= NO_BIN_U8 as usize {
            BinIdLane::U8(
                (0..n_rows)
                    .map(|r| bin_of(r).map_or(NO_BIN_U8, |b| b as u8))
                    .collect(),
            )
        } else {
            BinIdLane::U16(
                (0..n_rows)
                    .map(|r| bin_of(r).map_or(NO_BIN_U16, |b| b as u16))
                    .collect(),
            )
        }
    });
    let cats = (cat_card > 0).then(|| {
        (0..n_rows)
            .map(|r| match col.get(r) {
                Value::Cat(id) => id.0,
                _ => NO_CAT,
            })
            .collect()
    });
    (bins, cats)
}

/// A loaded (or freshly built) sidecar directory: global edges +
/// cardinalities plus the per-shard `.udb` window files.
#[derive(Debug, Clone)]
pub struct ShardBins {
    dir: PathBuf,
    n_features: usize,
    meta: BinsMeta,
    /// True when this call built the sidecars (two raw-shard passes),
    /// false when they were reloaded from disk (zero passes).
    pub built: bool,
}

impl ShardBins {
    pub fn meta(&self) -> &BinsMeta {
        &self.meta
    }

    /// Read, verify and decode shard `i`'s training window.
    pub fn read_window(&self, i: usize) -> Result<BinWindow> {
        let (file, checksum) = &self.meta.shard_files[i];
        let bytes = read_verified(&self.dir.join(file), usize::MAX, *checksum, file)?;
        decode_bin_window(&bytes, self.n_features)
    }
}

/// Read a file and verify its size (`usize::MAX` skips the size check)
/// and FNV-1a checksum before handing the bytes to a decoder.
fn read_verified(path: &Path, expect_bytes: usize, checksum: u64, label: &str) -> Result<Vec<u8>> {
    let bytes = fs::read(path).map_err(|e| {
        UdtError::data(format!("cannot read shard file `{label}`: {e}"))
    })?;
    if expect_bytes != usize::MAX && bytes.len() != expect_bytes {
        return Err(UdtError::data(format!(
            "shard file `{label}` is {} bytes but the manifest says {expect_bytes} \
             (truncated or overwritten?)",
            bytes.len()
        )));
    }
    if fnv1a64(&bytes) != checksum {
        return Err(UdtError::data(format!(
            "checksum mismatch in shard file `{label}` (corrupt data?)"
        )));
    }
    Ok(bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::csv::{load_csv_str, CsvOptions};
    use crate::data::shard::writer::write_dataset_shards;

    fn temp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "udt-shard-ds-{tag}-{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&d);
        d
    }

    fn sample_dataset() -> crate::data::dataset::Dataset {
        let mut csv = String::from("a,b,c,label\n");
        for i in 0..90 {
            let a = format!("{}", (i * 7 % 23) as f64 * 0.5);
            let b = if i % 4 == 0 { "red".into() } else { format!("{}", i % 6) };
            let c = if i % 9 == 0 { "?".into() } else { format!("{}", i % 13) };
            let y = ["u", "v", "w"][i % 3];
            csv.push_str(&format!("{a},{b},{c},{y}\n"));
        }
        load_csv_str("t", &csv, &CsvOptions::default()).unwrap()
    }

    #[test]
    fn edges_match_in_memory_binning() {
        let ds = sample_dataset();
        let dir = temp_dir("edges");
        write_dataset_shards(&ds, &dir, 17).unwrap();
        let sds = ShardedDataset::open(&dir).unwrap();
        let bins = sds.ensure_bins(8, 0, 2).unwrap();
        assert!(bins.built);

        let idx = ds.binned_index(8);
        for (c, lane) in idx.lanes.iter().enumerate() {
            match (lane, &bins.meta().edges[c]) {
                (Some(l), Some(e)) => {
                    assert_eq!(l.edges.len(), e.len(), "col {c}");
                    for (a, b) in l.edges.iter().zip(e) {
                        assert_eq!(a.to_bits(), b.to_bits(), "col {c}");
                    }
                }
                (None, None) => {}
                (a, b) => panic!("col {c}: lane {:?} vs edges {:?}", a.is_some(), b.is_some()),
            }
        }

        // Window bin ids match the in-memory lane row for row.
        let mut row = 0usize;
        for i in 0..sds.n_shards() {
            let w = bins.read_window(i).unwrap();
            for r in 0..w.n_rows {
                for c in 0..sds.n_features() {
                    let mem = idx.lanes[c].as_ref().and_then(|l| {
                        ds.columns[c].data.get(row).is_num().then(|| l.bin_of_row(row) as u32)
                    });
                    assert_eq!(
                        w.bins[c].as_ref().and_then(|lane| lane.get(r)),
                        mem,
                        "row {row} col {c}"
                    );
                }
                row += 1;
            }
        }
        assert_eq!(row, 90);

        // Second call reloads instead of rebuilding.
        let again = sds.ensure_bins(8, 0, 2).unwrap();
        assert!(!again.built);
        assert_eq!(again.meta().edges, bins.meta().edges);
        assert_eq!(again.meta().shard_files, bins.meta().shard_files);
        // Different parameters build a separate sidecar directory.
        let other = sds.ensure_bins(4, 0, 2).unwrap();
        assert!(other.built);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn sampled_edges_are_bounded_and_usable() {
        let ds = sample_dataset();
        let dir = temp_dir("sampled");
        write_dataset_shards(&ds, &dir, 30).unwrap();
        let sds = ShardedDataset::open(&dir).unwrap();
        let bins = sds.ensure_bins(8, 5, 1).unwrap();
        let e = bins.meta().edges[0].as_ref().unwrap();
        assert!(!e.is_empty() && e.len() <= 8);
        // Every numeric cell lands in a valid bin even if the sample
        // missed the extremes.
        let w = bins.read_window(0).unwrap();
        for r in 0..w.n_rows {
            if let Some(b) = w.bins[0].as_ref().unwrap().get(r) {
                assert!((b as usize) < e.len());
            }
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_and_truncated_files_are_typed_data_errors() {
        let ds = sample_dataset();
        let dir = temp_dir("corrupt");
        write_dataset_shards(&ds, &dir, 40).unwrap();

        // Corrupt manifest JSON.
        let mpath = dir.join("manifest.json");
        let good = fs::read_to_string(&mpath).unwrap();
        fs::write(&mpath, good.replace("udt-shards", "nonsense")).unwrap();
        assert!(matches!(ShardedDataset::open(&dir), Err(UdtError::Data(_))));
        fs::write(&mpath, "{not json").unwrap();
        assert!(matches!(ShardedDataset::open(&dir), Err(UdtError::Data(_))));
        fs::write(&mpath, &good).unwrap();

        let sds = ShardedDataset::open(&dir).unwrap();
        assert!(sds.read_shard(0).is_ok());

        // Truncated lane file: size check fires.
        let spath = dir.join(&sds.manifest().shards[0].file);
        let bytes = fs::read(&spath).unwrap();
        fs::write(&spath, &bytes[..bytes.len() - 9]).unwrap();
        assert!(matches!(sds.read_shard(0), Err(UdtError::Data(_))));

        // Same size, flipped byte: checksum fires.
        let mut flipped = bytes.clone();
        let mid = flipped.len() / 2;
        flipped[mid] ^= 0xff;
        fs::write(&spath, &flipped).unwrap();
        assert!(matches!(sds.read_shard(0), Err(UdtError::Data(_))));
        fs::write(&spath, &bytes).unwrap();
        assert!(sds.read_shard(0).is_ok());

        // Missing shard file.
        fs::remove_file(&spath).unwrap();
        assert!(matches!(sds.read_shard(0), Err(UdtError::Data(_))));
        let _ = fs::remove_dir_all(&dir);
    }
}
