//! Streaming CSV → shard conversion and `Dataset` → shard export.
//!
//! The CSV path never materializes the full dataset: file bytes stream
//! through a line-aligned [`BlockReader`], each block runs through the
//! chunk-parallel typed parser in [`crate::data::csv`], and typed rows
//! accumulate in `ColumnShard`s that flush to a `.uds` file whenever
//! they reach `rows_per_shard`. Chunk-local categorical/class ids remap
//! into the global id space in arrival order — first-seen order
//! composes across blocks and chunks, so the manifest's interner and
//! class map are byte-identical to an in-memory `load_csv_str` of the
//! same file, at any thread count or block size.

use std::collections::HashMap;
use std::fs;
use std::io::Read;
use std::path::{Path, PathBuf};

use crate::coordinator::parallel::parallel_map;
use crate::data::column_data::{ColumnData, ColumnShard};
use crate::data::csv::{
    first_data_width, line_aligned_chunks, parse_chunk, split_header, ChunkShard, CsvOptions,
    LabelMode,
};
use crate::data::dataset::{Dataset, Labels, TaskKind};
use crate::data::interner::Interner;
use crate::error::{Result, UdtError};

use super::format::{encode_shard, fnv1a64, LabelLane, ShardEntry, ShardManifest};

/// Default streaming block size (bytes); each block is cut on a line
/// boundary before parsing.
const DEFAULT_BLOCK_BYTES: usize = 8 << 20;

/// Reads line-aligned UTF-8 blocks of roughly `target` bytes from any
/// byte stream. A block always ends on a `'\n'` (except the final one),
/// so chunking and cell parsing never see a split line or a split
/// multi-byte character.
struct BlockReader<R: Read> {
    src: R,
    target: usize,
    /// Bytes read but not yet emitted (tail after the last newline).
    carry: Vec<u8>,
    done: bool,
}

impl<R: Read> BlockReader<R> {
    fn new(src: R, target: usize) -> Self {
        BlockReader {
            src,
            target: target.max(1),
            carry: Vec::new(),
            done: false,
        }
    }

    /// Next line-aligned block, `Ok(None)` at end of stream.
    fn next_block(&mut self, name: &str) -> Result<Option<String>> {
        if self.done && self.carry.is_empty() {
            return Ok(None);
        }
        let mut buf = std::mem::take(&mut self.carry);
        let mut scratch = vec![0u8; 64 << 10];
        while !self.done && buf.len() < self.target {
            let n = self.src.read(&mut scratch)?;
            if n == 0 {
                self.done = true;
            } else {
                buf.extend_from_slice(&scratch[..n]);
            }
        }
        // Keep reading until the block can end on a newline (a line
        // longer than `target` extends the block rather than splitting).
        while !self.done && !buf.contains(&b'\n') {
            let n = self.src.read(&mut scratch)?;
            if n == 0 {
                self.done = true;
            } else {
                buf.extend_from_slice(&scratch[..n]);
            }
        }
        if buf.is_empty() {
            return Ok(None);
        }
        let cut = if self.done {
            buf.len()
        } else {
            match buf.iter().rposition(|&b| b == b'\n') {
                Some(i) => i + 1,
                None => buf.len(),
            }
        };
        self.carry = buf.split_off(cut);
        String::from_utf8(buf)
            .map(Some)
            .map_err(|_| UdtError::data(format!("csv `{name}` is not valid UTF-8")))
    }
}

/// Accumulates merged typed rows and flushes them to numbered `.uds`
/// files; owns the global interner / class map and the manifest under
/// construction.
struct ShardSink {
    dir: PathBuf,
    rows_per_shard: usize,
    n_features: usize,
    task: TaskKind,
    interner: Interner,
    class_names: Vec<String>,
    global_class: HashMap<String, u16>,
    cols: Vec<ColumnShard>,
    class_ids: Vec<u16>,
    reg_vals: Vec<f64>,
    pending_rows: usize,
    rows_flushed: usize,
    shards: Vec<ShardEntry>,
}

impl ShardSink {
    fn new(dir: &Path, rows_per_shard: usize, n_features: usize, task: TaskKind) -> Self {
        ShardSink {
            dir: dir.to_path_buf(),
            rows_per_shard,
            n_features,
            task,
            interner: Interner::new(),
            class_names: Vec::new(),
            global_class: HashMap::new(),
            cols: (0..n_features).map(|_| ColumnShard::default()).collect(),
            class_ids: Vec::new(),
            reg_vals: Vec::new(),
            pending_rows: 0,
            rows_flushed: 0,
            shards: Vec::new(),
        }
    }

    fn rows_seen(&self) -> usize {
        self.rows_flushed + self.pending_rows
    }

    /// Ordered merge of one chunk's typed shard — the same remap idiom
    /// as `parse_typed_csv`, against sink-global id spaces.
    fn merge_chunk(&mut self, shard: &ChunkShard) {
        let remap: Vec<u32> = shard
            .interner
            .names()
            .iter()
            .map(|n| self.interner.intern(n).0)
            .collect();
        for (dst, src) in self.cols.iter_mut().zip(&shard.cols) {
            dst.append_remapped(src, &remap);
        }
        if !shard.class_names.is_empty() || !shard.class_ids.is_empty() {
            let cmap: Vec<u16> = shard
                .class_names
                .iter()
                .map(|n| match self.global_class.get(n) {
                    Some(&id) => id,
                    None => {
                        let id = self.class_names.len() as u16;
                        self.class_names.push(n.clone());
                        self.global_class.insert(n.clone(), id);
                        id
                    }
                })
                .collect();
            self.class_ids
                .extend(shard.class_ids.iter().map(|&l| cmap[l as usize]));
        }
        self.reg_vals.extend_from_slice(&shard.reg_vals);
        self.pending_rows += shard.n_rows;
    }

    /// Write all pending rows as one shard file.
    fn flush(&mut self) -> Result<()> {
        if self.pending_rows == 0 {
            return Ok(());
        }
        let cols: Vec<ColumnData> = std::mem::replace(
            &mut self.cols,
            (0..self.n_features).map(|_| ColumnShard::default()).collect(),
        )
        .into_iter()
        .map(ColumnShard::finish)
        .collect();
        let labels = match self.task {
            TaskKind::Classification => LabelLane::Class(std::mem::take(&mut self.class_ids)),
            TaskKind::Regression => LabelLane::Reg(std::mem::take(&mut self.reg_vals)),
        };
        let bytes = encode_shard(&cols, &labels);
        let file = format!("shard-{:05}.uds", self.shards.len());
        fs::write(self.dir.join(&file), &bytes)?;
        self.shards.push(ShardEntry {
            file,
            n_rows: self.pending_rows,
            row_offset: self.rows_flushed,
            bytes: bytes.len(),
            checksum: fnv1a64(&bytes),
        });
        self.rows_flushed += self.pending_rows;
        self.pending_rows = 0;
        Ok(())
    }

    fn into_manifest(self, name: &str, feature_names: Vec<String>) -> ShardManifest {
        ShardManifest {
            name: name.to_string(),
            task: self.task,
            n_rows: self.rows_flushed,
            feature_names,
            cat_names: self.interner.names().to_vec(),
            class_names: self.class_names,
            shards: self.shards,
        }
    }
}

/// Per-file parse state fixed by the first block that carries data:
/// record width, label placement and feature names.
struct CsvShape {
    width: usize,
    n_features: usize,
    label: LabelMode,
    feature_names: Vec<String>,
}

fn resolve_shape(
    name: &str,
    header: Option<&[String]>,
    body: &str,
    opts: &CsvOptions,
) -> Result<Option<CsvShape>> {
    let width = match header.map(<[String]>::len) {
        Some(w) => w,
        None => match first_data_width(body, opts.delimiter) {
            Some(w) => w,
            None => return Ok(None),
        },
    };
    if width < 2 {
        return Err(UdtError::data(format!(
            "csv `{name}` needs at least one feature column plus a label"
        )));
    }
    let label_col = opts.label_col.unwrap_or(width - 1);
    if label_col >= width {
        return Err(UdtError::data(format!(
            "label column {label_col} out of range (width {width})"
        )));
    }
    let label = match opts.task {
        TaskKind::Classification => LabelMode::Class(label_col),
        TaskKind::Regression => LabelMode::Reg(label_col),
    };
    let feature_names = (0..width)
        .filter(|&c| c != label_col)
        .map(|c| {
            header
                .and_then(|h| h.get(c).cloned())
                .unwrap_or_else(|| format!("f{c}"))
        })
        .collect();
    Ok(Some(CsvShape {
        width,
        n_features: width - 1,
        label,
        feature_names,
    }))
}

fn shard_stream<R: Read>(
    name: &str,
    src: R,
    dir: &Path,
    opts: &CsvOptions,
    rows_per_shard: usize,
    block_bytes: usize,
) -> Result<ShardManifest> {
    if rows_per_shard == 0 {
        return Err(UdtError::invalid_config("shard.rows must be >= 1"));
    }
    fs::create_dir_all(dir)?;
    let threads = crate::runtime::threads(opts.n_threads);
    let mut reader = BlockReader::new(src, block_bytes);

    let mut shape: Option<CsvShape> = None;
    let mut sink: Option<ShardSink> = None;
    let mut header: Option<Vec<String>> = None;
    let mut need_header = opts.has_header;
    while let Some(block) = reader.next_block(name)? {
        let body: &str = if need_header {
            // Keep scanning blocks until the header line shows up (a
            // block of nothing but blank lines yields an empty body).
            let (h, b) = split_header(&block, opts.delimiter, true);
            if h.is_some() {
                header = h;
                need_header = false;
            }
            b
        } else {
            &block
        };
        if shape.is_none() {
            shape = resolve_shape(name, header.as_deref(), body, opts)?;
        }
        let Some(sh) = shape.as_ref() else { continue };
        let sink = sink.get_or_insert_with(|| {
            ShardSink::new(dir, rows_per_shard, sh.n_features, opts.task)
        });
        let target = if opts.chunk_bytes > 0 {
            opts.chunk_bytes
        } else if threads <= 1 {
            body.len().max(1)
        } else {
            (body.len() / (threads * 4)).max(1 << 16)
        };
        let chunks = line_aligned_chunks(body, target);
        let parsed = parallel_map(chunks, threads, |chunk| {
            parse_chunk(chunk, sh.width, sh.n_features, sh.label, opts.delimiter)
        });
        for res in parsed {
            let chunk = match res {
                Ok(c) => c,
                Err(e) => return Err(e.into_error(sink.rows_seen(), sh.width)),
            };
            sink.merge_chunk(&chunk);
            if sink.pending_rows >= rows_per_shard {
                sink.flush()?;
            }
        }
    }
    let (Some(shape), Some(mut sink)) = (shape, sink) else {
        return Err(UdtError::data(format!("csv `{name}` has no data rows")));
    };
    sink.flush()?;
    if sink.rows_flushed == 0 {
        return Err(UdtError::data(format!("csv `{name}` has no data rows")));
    }
    let manifest = sink.into_manifest(name, shape.feature_names);
    write_manifest(dir, &manifest)?;
    Ok(manifest)
}

fn write_manifest(dir: &Path, manifest: &ShardManifest) -> Result<()> {
    fs::write(
        dir.join("manifest.json"),
        manifest.to_json().to_pretty() + "\n",
    )?;
    Ok(())
}

/// Stream a CSV file into a shard directory without materializing the
/// dataset; returns the written manifest.
pub fn shard_csv_file(
    path: impl AsRef<Path>,
    dir: impl AsRef<Path>,
    opts: &CsvOptions,
    rows_per_shard: usize,
) -> Result<ShardManifest> {
    let path = path.as_ref();
    let name = path
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or("dataset")
        .to_string();
    let file = fs::File::open(path)?;
    shard_stream(
        &name,
        file,
        dir.as_ref(),
        opts,
        rows_per_shard,
        DEFAULT_BLOCK_BYTES,
    )
}

/// Shard CSV text through the same streaming path (tests, small data).
pub fn shard_csv_str(
    name: &str,
    text: &str,
    dir: impl AsRef<Path>,
    opts: &CsvOptions,
    rows_per_shard: usize,
) -> Result<ShardManifest> {
    shard_stream(
        name,
        text.as_bytes(),
        dir.as_ref(),
        opts,
        rows_per_shard,
        DEFAULT_BLOCK_BYTES,
    )
}

/// Export an in-memory [`Dataset`] as a shard directory (row order
/// preserved; interner and class map copied verbatim).
pub fn write_dataset_shards(
    ds: &Dataset,
    dir: impl AsRef<Path>,
    rows_per_shard: usize,
) -> Result<ShardManifest> {
    if rows_per_shard == 0 {
        return Err(UdtError::invalid_config("shard.rows must be >= 1"));
    }
    let dir = dir.as_ref();
    fs::create_dir_all(dir)?;
    let n_rows = ds.labels.len();
    if n_rows == 0 {
        return Err(UdtError::data("cannot shard an empty dataset"));
    }
    let mut shards = Vec::new();
    let mut offset = 0usize;
    while offset < n_rows {
        let end = (offset + rows_per_shard).min(n_rows);
        let rows: Vec<u32> = (offset as u32..end as u32).collect();
        let cols: Vec<ColumnData> = ds.columns.iter().map(|c| c.data.gather(&rows)).collect();
        let labels = match &ds.labels {
            Labels::Class { ids, .. } => {
                LabelLane::Class(rows.iter().map(|&r| ids[r as usize]).collect())
            }
            Labels::Reg { values } => {
                LabelLane::Reg(rows.iter().map(|&r| values[r as usize]).collect())
            }
        };
        let bytes = encode_shard(&cols, &labels);
        let file = format!("shard-{:05}.uds", shards.len());
        fs::write(dir.join(&file), &bytes)?;
        shards.push(ShardEntry {
            file,
            n_rows: end - offset,
            row_offset: offset,
            bytes: bytes.len(),
            checksum: fnv1a64(&bytes),
        });
        offset = end;
    }
    let manifest = ShardManifest {
        name: ds.name.clone(),
        task: ds.task(),
        n_rows,
        feature_names: ds.columns.iter().map(|c| c.name.clone()).collect(),
        cat_names: ds.interner.names().to_vec(),
        class_names: ds.class_names.as_ref().clone(),
        shards,
    };
    write_manifest(dir, &manifest)?;
    Ok(manifest)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::csv::load_csv_str;
    use crate::data::shard::dataset::ShardedDataset;

    fn temp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "udt-shard-writer-{tag}-{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&d);
        d
    }

    fn sample_csv() -> String {
        let mut s = String::from("a,b,label\n");
        for i in 0..100 {
            let a = if i % 7 == 0 {
                "?".to_string()
            } else {
                format!("{}", (i * 13 % 29) as f64 / 2.0)
            };
            let b = if i % 3 == 0 {
                format!("cat{}", i % 5)
            } else {
                format!("{}", i % 11)
            };
            let y = if i % 2 == 0 { "yes" } else { "no" };
            s.push_str(&format!("{a},{b},{y}\n"));
        }
        s
    }

    #[test]
    fn streamed_shards_match_in_memory_parse() {
        let csv = sample_csv();
        let dir = temp_dir("match");
        // Tiny blocks + tiny chunks + multiple shards: every boundary in
        // one test.
        let opts = CsvOptions {
            chunk_bytes: 64,
            n_threads: 2,
            ..CsvOptions::default()
        };
        let manifest =
            shard_stream("t", csv.as_bytes(), &dir, &opts, 17, 128).unwrap();
        assert!(manifest.shards.len() > 1, "want multiple shards");
        assert_eq!(manifest.n_rows, 100);

        let ds = load_csv_str("t", &csv, &CsvOptions::default()).unwrap();
        let sds = ShardedDataset::open(&dir).unwrap();
        assert_eq!(sds.manifest().cat_names, ds.interner.names());
        assert_eq!(sds.manifest().class_names, *ds.class_names);
        assert_eq!(
            sds.manifest().feature_names,
            ds.columns.iter().map(|c| c.name.clone()).collect::<Vec<_>>()
        );
        // Reassembled cells equal the in-memory parse row for row.
        let mut row = 0usize;
        for i in 0..sds.n_shards() {
            let (cols, labels) = sds.read_shard(i).unwrap();
            for r in 0..labels.len() {
                for (c, col) in cols.iter().enumerate() {
                    assert_eq!(col.get(r), ds.columns[c].data.get(row), "row {row} col {c}");
                }
                match &labels {
                    LabelLane::Class(ids) => assert_eq!(ids[r], ds.labels.class(row)),
                    LabelLane::Reg(_) => panic!("classification expected"),
                }
                row += 1;
            }
        }
        assert_eq!(row, 100);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn dataset_export_round_trips() {
        let csv = sample_csv();
        let ds = load_csv_str("t", &csv, &CsvOptions::default()).unwrap();
        let dir = temp_dir("export");
        let manifest = write_dataset_shards(&ds, &dir, 33).unwrap();
        assert_eq!(manifest.shards.len(), 4);
        assert_eq!(manifest.shards[3].n_rows, 1);
        let sds = ShardedDataset::open(&dir).unwrap();
        let (cols, _) = sds.read_shard(3).unwrap();
        assert_eq!(cols[0].get(0), ds.columns[0].data.get(99));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn bad_csv_errors_are_typed() {
        let dir = temp_dir("bad");
        let opts = CsvOptions::default();
        // No data rows.
        let err = shard_csv_str("t", "a,label\n", &dir, &opts, 10).unwrap_err();
        assert!(matches!(err, UdtError::Data(_)), "{err:?}");
        // Ragged row, with the global row index fixed up across shards.
        let mut csv = String::from("a,label\n");
        for i in 0..40 {
            csv.push_str(&format!("{i},x\n"));
        }
        csv.push_str("1,2,3\n");
        let err = shard_csv_str("t", &csv, &dir, &opts, 8).unwrap_err();
        match err {
            UdtError::Data(m) => assert!(m.contains("row 41"), "{m}"),
            other => panic!("expected Data, got {other:?}"),
        }
        // rows_per_shard = 0 is a config error.
        let err = shard_csv_str("t", "a,label\n1,x\n", &dir, &opts, 0).unwrap_err();
        assert!(matches!(err, UdtError::InvalidConfig(_)));
        let _ = fs::remove_dir_all(&dir);
    }
}
