//! Registry of shape-matched substitutes for every dataset the paper
//! evaluates (Table 6 classification, Table 7 regression). The
//! `(n_rows, n_features, n_classes)` triples are exactly the paper's;
//! the remaining knobs (categorical mix, cardinality, ground-truth depth,
//! noise) are chosen so tree sizes and accuracy land in the paper's bands.

use super::SynthSpec;

/// A registry entry: the paper's dataset stats plus our synth knobs.
#[derive(Debug, Clone)]
pub struct DatasetEntry {
    pub spec: SynthSpec,
    /// Paper-reported numbers for EXPERIMENTS.md comparisons
    /// (full-tree train ms, tune ms, accuracy-or-RMSE).
    pub paper_train_ms: f64,
    pub paper_tune_ms: f64,
    pub paper_quality: f64,
}

fn cls(
    name: &str,
    m: usize,
    k: usize,
    c: usize,
    cat_frac: f64,
    cardinality: usize,
    gt_depth: usize,
    noise: f64,
    paper: (f64, f64, f64),
) -> DatasetEntry {
    let mut spec = SynthSpec::classification(name, m, k, c);
    spec.cat_frac = cat_frac;
    spec.hybrid_frac = 0.05;
    spec.missing_frac = 0.02;
    spec.numeric_cardinality = cardinality;
    spec.gt_depth = gt_depth;
    spec.noise = noise;
    DatasetEntry {
        spec,
        paper_train_ms: paper.0,
        paper_tune_ms: paper.1,
        paper_quality: paper.2,
    }
}

fn reg(
    name: &str,
    m: usize,
    k: usize,
    cardinality: usize,
    gt_depth: usize,
    noise: f64,
    paper: (f64, f64, f64),
) -> DatasetEntry {
    let mut spec = SynthSpec::regression(name, m, k);
    spec.cat_frac = 0.1;
    spec.hybrid_frac = 0.05;
    spec.missing_frac = 0.01;
    spec.numeric_cardinality = cardinality;
    spec.gt_depth = gt_depth;
    spec.noise = noise;
    DatasetEntry {
        spec,
        paper_train_ms: paper.0,
        paper_tune_ms: paper.1,
        paper_quality: paper.2,
    }
}

/// The 19 classification datasets of Table 6 (name, M, K, C as reported).
/// Paper columns recorded: (train ms, tune ms, accuracy).
pub fn classification_registry() -> Vec<DatasetEntry> {
    vec![
        cls("adult", 32_561, 14, 2, 0.5, 128, 10, 0.12, (586.0, 50.0, 0.86)),
        cls("credit_card", 30_000, 23, 2, 0.2, 256, 10, 0.16, (1340.0, 52.0, 0.82)),
        cls("rain_in_australia", 145_460, 23, 3, 0.3, 256, 11, 0.15, (4229.0, 288.0, 0.83)),
        cls("parkinson", 765, 753, 2, 0.0, 128, 5, 0.15, (611.0, 2.0, 0.80)),
        cls("intention", 12_330, 17, 2, 0.4, 128, 8, 0.08, (170.0, 6.0, 0.90)),
        cls("shuttle", 58_000, 9, 7, 0.0, 128, 5, 0.002, (36.0, 21.0, 1.0)),
        cls("wall_robot", 5_456, 24, 4, 0.0, 128, 6, 0.01, (70.0, 2.0, 0.99)),
        cls("nursery", 12_960, 8, 5, 1.0, 8, 8, 0.004, (18.0, 5.0, 1.0)),
        cls("page_blocks", 5_473, 10, 5, 0.0, 128, 7, 0.03, (40.0, 2.0, 0.96)),
        cls("weight_lifting", 4_024, 154, 5, 0.0, 128, 5, 0.005, (75.0, 1.0, 1.0)),
        cls("letter", 20_000, 16, 26, 0.0, 16, 12, 0.10, (276.0, 20.0, 0.87)),
        cls("nearest_earth_objects", 90_836, 7, 2, 0.0, 256, 11, 0.07, (943.0, 73.0, 0.91)),
        cls("optidigits", 3_823, 64, 10, 0.0, 17, 9, 0.09, (121.0, 2.0, 0.89)),
        cls("heart_disease_indicators", 253_680, 21, 2, 0.5, 64, 11, 0.08, (5802.0, 453.0, 0.91)),
        cls("credit_card_fraud", 1_000_000, 7, 2, 0.15, 256, 6, 0.002, (5832.0, 285.0, 1.0)),
        cls("churn_modeling", 10_000, 10, 2, 0.3, 256, 9, 0.13, (155.0, 10.0, 0.85)),
        cls("covertype", 581_012, 54, 7, 0.8, 128, 13, 0.05, (16_573.0, 1023.0, 0.94)),
        cls("kdd99_10", 494_020, 41, 23, 0.2, 128, 7, 0.001, (977.0, 245.0, 1.0)),
        cls("kdd99_full", 4_898_431, 41, 23, 0.2, 128, 8, 0.001, (24_926.0, 3140.0, 1.0)),
    ]
}

/// The 5 regression datasets of Table 7 (paper columns: train ms, tune ms,
/// RMSE).
pub fn regression_registry() -> Vec<DatasetEntry> {
    vec![
        reg("bike_sharing_hour", 17_379, 12, 256, 10, 0.10, (1216.0, 26.0, 64.2)),
        reg("california_housing", 20_640, 9, 256, 10, 0.12, (1439.0, 40.0, 57_633.3)),
        reg("wine_quality", 6_497, 11, 128, 8, 0.10, (180.0, 6.0, 0.83)),
        reg("wave_energy_farm", 36_043, 148, 256, 9, 0.10, (18_630.0, 147.0, 7979.9)),
        reg("appliances_energy", 19_735, 27, 256, 10, 0.15, (2576.0, 40.0, 94.6)),
    ]
}

/// Find a dataset entry by name in either registry.
pub fn find(name: &str) -> Option<DatasetEntry> {
    classification_registry()
        .into_iter()
        .chain(regression_registry())
        .find(|e| e.spec.name == name)
}

/// Names of all registered datasets.
pub fn all_names() -> Vec<String> {
    classification_registry()
        .into_iter()
        .chain(regression_registry())
        .map(|e| e.spec.name)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_matches_paper_counts() {
        assert_eq!(classification_registry().len(), 19);
        assert_eq!(regression_registry().len(), 5);
    }

    #[test]
    fn paper_shapes_pinned() {
        let e = find("kdd99_10").unwrap();
        assert_eq!(e.spec.n_rows, 494_020);
        assert_eq!(e.spec.n_features, 41);
        assert_eq!(e.spec.n_classes, 23);
        let e = find("churn_modeling").unwrap();
        assert_eq!((e.spec.n_rows, e.spec.n_features, e.spec.n_classes), (10_000, 10, 2));
        let e = find("credit_card_fraud").unwrap();
        assert_eq!((e.spec.n_rows, e.spec.n_features), (1_000_000, 7));
    }

    #[test]
    fn names_unique() {
        let names = all_names();
        let mut dedup = names.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), names.len());
    }

    #[test]
    fn find_unknown_is_none() {
        assert!(find("no_such_dataset").is_none());
    }

    #[test]
    fn regression_specs_have_no_classes() {
        for e in regression_registry() {
            assert!(e.spec.is_regression());
        }
    }
}
