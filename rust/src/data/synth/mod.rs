//! Synthetic dataset generation.
//!
//! The paper evaluates on 19 UCI/Kaggle classification datasets and 5
//! regression datasets. Those downloads are unavailable in this
//! environment, so for every dataset in [`registry`] we generate a
//! *shape-matched* synthetic table: same number of examples, features and
//! label classes, with a controlled mix of numeric / categorical / hybrid
//! features and missing cells. Labels are produced by a hidden random
//! ground-truth decision tree plus label noise, so the learning problem is
//! tree-realizable (accuracy bands comparable to the paper) and numeric
//! cardinality `N` is controlled (preserving the `O(M·N)` vs `O(M)`
//! contrast Table 5 measures). See DESIGN.md §6.

pub mod registry;

use super::column::Column;
use super::dataset::{Dataset, Labels};
use super::interner::Interner;
use super::value::Value;
use crate::util::rng::Rng;

/// Parameters of one synthetic dataset.
#[derive(Debug, Clone)]
pub struct SynthSpec {
    pub name: String,
    pub n_rows: usize,
    pub n_features: usize,
    /// Number of classes; 0 for regression.
    pub n_classes: usize,
    /// Fraction of purely categorical features.
    pub cat_frac: f64,
    /// Fraction of hybrid features (numeric cells + occasional categorical).
    pub hybrid_frac: f64,
    /// Probability of a missing cell.
    pub missing_frac: f64,
    /// Distinct numeric levels per numeric feature (the paper's `N`).
    pub numeric_cardinality: usize,
    /// Vocabulary size of categorical features.
    pub cat_vocab: usize,
    /// Depth of the hidden ground-truth tree.
    pub gt_depth: usize,
    /// Probability a label is resampled uniformly (classification) or the
    /// standard deviation of the additive noise (regression).
    pub noise: f64,
}

impl SynthSpec {
    /// Reasonable defaults for an ad-hoc classification problem.
    pub fn classification(name: &str, n_rows: usize, n_features: usize, n_classes: usize) -> Self {
        Self {
            name: name.to_string(),
            n_rows,
            n_features,
            n_classes,
            cat_frac: 0.25,
            hybrid_frac: 0.1,
            missing_frac: 0.02,
            numeric_cardinality: 256,
            cat_vocab: 8,
            gt_depth: 8,
            noise: 0.05,
        }
    }

    /// Reasonable defaults for an ad-hoc regression problem.
    pub fn regression(name: &str, n_rows: usize, n_features: usize) -> Self {
        Self {
            n_classes: 0,
            ..Self::classification(name, n_rows, n_features, 0)
        }
    }

    pub fn is_regression(&self) -> bool {
        self.n_classes == 0
    }

    /// Scale the number of rows (used by bench harnesses to shrink the
    /// paper's largest datasets).
    pub fn scaled(&self, factor: f64) -> Self {
        let mut s = self.clone();
        s.n_rows = ((self.n_rows as f64 * factor).round() as usize).max(64);
        s
    }
}

/// Kind of a generated feature column.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum FeatKind {
    Numeric,
    Categorical,
    Hybrid,
}

/// Hidden ground-truth tree used to label examples.
#[derive(Debug)]
enum GtNode {
    Leaf {
        class: u16,
        value: f64,
    },
    Inner {
        feature: usize,
        /// `None` → categorical equality test on `cat`, else `≤ threshold`.
        threshold: Option<f64>,
        cat: u32,
        left: Box<GtNode>,
        right: Box<GtNode>,
    },
}

impl GtNode {
    fn eval(&self, row: &[Value]) -> (u16, f64) {
        match self {
            GtNode::Leaf { class, value } => (*class, *value),
            GtNode::Inner {
                feature,
                threshold,
                cat,
                left,
                right,
            } => {
                let v = &row[*feature];
                let go_left = match threshold {
                    Some(t) => v.le_value(&Value::Num(*t)),
                    None => v.eq_value(&Value::Cat(super::interner::CatId(*cat))),
                };
                if go_left {
                    left.eval(row)
                } else {
                    right.eval(row)
                }
            }
        }
    }
}

fn build_gt(
    rng: &mut Rng,
    depth: usize,
    kinds: &[FeatKind],
    spec: &SynthSpec,
    lo: f64,
    hi: f64,
) -> GtNode {
    if depth == 0 {
        let class = if spec.n_classes > 0 {
            rng.below(spec.n_classes as u64) as u16
        } else {
            0
        };
        return GtNode::Leaf {
            class,
            value: rng.f64_range(lo, hi),
        };
    }
    let feature = rng.range(0, kinds.len());
    let (threshold, cat) = match kinds[feature] {
        FeatKind::Categorical => (None, rng.below(spec.cat_vocab as u64) as u32),
        _ => {
            // Thresholds land on the numeric grid so splits are learnable.
            let level = rng.range(1, spec.numeric_cardinality.max(2));
            (
                Some(level as f64 * 100.0 / spec.numeric_cardinality as f64),
                0,
            )
        }
    };
    let mid = (lo + hi) / 2.0;
    GtNode::Inner {
        feature,
        threshold,
        cat,
        left: Box::new(build_gt(rng, depth - 1, kinds, spec, lo, mid)),
        right: Box::new(build_gt(rng, depth - 1, kinds, spec, mid, hi)),
    }
}

fn feature_kinds(rng: &mut Rng, spec: &SynthSpec) -> Vec<FeatKind> {
    (0..spec.n_features)
        .map(|_| {
            let r = rng.f64();
            if r < spec.cat_frac {
                FeatKind::Categorical
            } else if r < spec.cat_frac + spec.hybrid_frac {
                FeatKind::Hybrid
            } else {
                FeatKind::Numeric
            }
        })
        .collect()
}

/// Shared generator core.
fn generate(spec: &SynthSpec, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0x5EED_5EED);
    let kinds = feature_kinds(&mut rng, spec);

    // Interner: pre-intern the categorical vocabulary so CatIds are dense
    // and the ground-truth tree can reference them by index.
    let mut interner = Interner::new();
    for i in 0..spec.cat_vocab {
        interner.intern(&format!("v{i}"));
    }

    let gt = build_gt(&mut rng.fork(1), spec.gt_depth, &kinds, spec, -100.0, 100.0);

    let mut cell_cols: Vec<Vec<Value>> = kinds
        .iter()
        .map(|_| Vec::with_capacity(spec.n_rows))
        .collect();
    let mut class_ids: Vec<u16> = Vec::new();
    let mut reg_values: Vec<f64> = Vec::new();

    let mut row_buf: Vec<Value> = vec![Value::Missing; spec.n_features];
    let mut data_rng = rng.fork(2);
    let mut noise_rng = rng.fork(3);
    let quant = spec.numeric_cardinality.max(1) as f64;
    for _ in 0..spec.n_rows {
        for (f, kind) in kinds.iter().enumerate() {
            let v = if data_rng.chance(spec.missing_frac) {
                Value::Missing
            } else {
                match kind {
                    FeatKind::Numeric => {
                        let level = data_rng.below(quant as u64) as f64;
                        Value::Num(level * 100.0 / quant)
                    }
                    FeatKind::Categorical => Value::Cat(super::interner::CatId(
                        data_rng.below(spec.cat_vocab as u64) as u32,
                    )),
                    FeatKind::Hybrid => {
                        if data_rng.chance(0.2) {
                            Value::Cat(super::interner::CatId(
                                data_rng.below(spec.cat_vocab as u64) as u32,
                            ))
                        } else {
                            let level = data_rng.below(quant as u64) as f64;
                            Value::Num(level * 100.0 / quant)
                        }
                    }
                }
            };
            row_buf[f] = v;
            cell_cols[f].push(v);
        }
        let (class, value) = gt.eval(&row_buf);
        if spec.is_regression() {
            reg_values.push(value + spec.noise * noise_rng.normal() * 10.0);
        } else {
            let label = if noise_rng.chance(spec.noise) {
                noise_rng.below(spec.n_classes as u64) as u16
            } else {
                class
            };
            class_ids.push(label);
        }
    }

    let labels = if spec.is_regression() {
        Labels::Reg { values: reg_values }
    } else {
        Labels::Class {
            ids: class_ids,
            n_classes: spec.n_classes,
        }
    };
    let columns: Vec<Column> = cell_cols
        .into_iter()
        .enumerate()
        .map(|(i, cells)| Column::new(format!("f{i}"), cells))
        .collect();
    let mut ds = Dataset::new(spec.name.clone(), columns, labels, interner)
        // ANALYZE-ALLOW(no-unwrap): the generator emits well-formed columns by construction
        .expect("synthetic dataset is always well-formed");
    if !spec.is_regression() {
        ds.class_names =
            std::sync::Arc::new((0..spec.n_classes).map(|c| format!("c{c}")).collect());
    }
    ds
}

/// Generate a classification dataset from a spec.
pub fn generate_classification(spec: &SynthSpec, seed: u64) -> Dataset {
    assert!(spec.n_classes >= 2, "classification needs ≥2 classes");
    generate(spec, seed)
}

/// Generate a regression dataset from a spec.
pub fn generate_regression(spec: &SynthSpec, seed: u64) -> Dataset {
    assert!(spec.is_regression(), "spec has classes; use classification");
    generate(spec, seed)
}

/// Generate from a spec of either task kind.
pub fn generate_any(spec: &SynthSpec, seed: u64) -> Dataset {
    if spec.is_regression() {
        generate_regression(spec, seed)
    } else {
        generate_classification(spec, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::TaskKind;

    #[test]
    fn shapes_match_spec() {
        let spec = SynthSpec::classification("t", 500, 12, 4);
        let ds = generate_classification(&spec, 1);
        assert_eq!(ds.n_rows(), 500);
        assert_eq!(ds.n_features(), 12);
        assert_eq!(ds.labels.n_classes(), 4);
        assert_eq!(ds.task(), TaskKind::Classification);
    }

    #[test]
    fn deterministic_per_seed() {
        let spec = SynthSpec::classification("t", 100, 5, 2);
        let a = generate_classification(&spec, 9);
        let b = generate_classification(&spec, 9);
        for f in 0..5 {
            for r in 0..100 {
                assert!(a.value(f, r).eq_value(&b.value(f, r)) || a.value(f, r).is_missing());
            }
        }
        let c = generate_classification(&spec, 10);
        let diff = (0..100).filter(|&r| a.labels.class(r) != c.labels.class(r)).count();
        assert!(diff > 0, "different seeds should differ");
    }

    #[test]
    fn contains_all_value_kinds() {
        let mut spec = SynthSpec::classification("t", 2000, 10, 2);
        spec.cat_frac = 0.3;
        spec.hybrid_frac = 0.2;
        spec.missing_frac = 0.05;
        let ds = generate_classification(&spec, 2);
        let mut has = (false, false, false);
        for c in &ds.columns {
            let s = c.stats();
            has.0 |= s.n_num > 0;
            has.1 |= s.n_cat > 0;
            has.2 |= s.n_missing > 0;
        }
        assert!(has.0 && has.1 && has.2, "{has:?}");
    }

    #[test]
    fn labels_are_learnable_not_uniform() {
        // With a ground-truth tree, class distribution conditioned on a
        // feature must deviate from uniform somewhere; a crude sanity
        // check that labels are not pure noise.
        let spec = SynthSpec::classification("t", 4000, 6, 2);
        let ds = generate_classification(&spec, 3);
        let n1 = (0..ds.n_rows()).filter(|&r| ds.labels.class(r) == 1).count();
        assert!(n1 > 100 && n1 < 3900, "degenerate labels: {n1}");
    }

    #[test]
    fn regression_values_finite() {
        let spec = SynthSpec::regression("r", 300, 7);
        let ds = generate_regression(&spec, 4);
        for r in 0..300 {
            assert!(ds.labels.target(r).is_finite());
        }
    }

    #[test]
    fn numeric_cardinality_bounded() {
        let mut spec = SynthSpec::classification("t", 5000, 3, 2);
        spec.numeric_cardinality = 32;
        spec.cat_frac = 0.0;
        spec.hybrid_frac = 0.0;
        spec.missing_frac = 0.0;
        let ds = generate_classification(&spec, 5);
        for f in 0..ds.n_features() {
            assert!(ds.unique_numeric_count(f) <= 32);
        }
    }

    #[test]
    fn scaled_shrinks_rows() {
        let spec = SynthSpec::classification("t", 10_000, 4, 2).scaled(0.1);
        assert_eq!(spec.n_rows, 1000);
    }
}
