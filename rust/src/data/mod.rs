//! Tabular data substrate: hybrid values (numeric + categorical + missing),
//! string interning, the typed columnar store ([`column_data`]) shared by
//! training and inference, streaming CSV ingestion and the synthetic
//! dataset registry substituting for the paper's UCI/Kaggle downloads.

pub mod column;
pub mod column_data;
pub mod csv;
pub mod dataset;
pub mod interner;
pub mod shard;
pub mod sorted_index;
pub mod synth;
pub mod value;

pub use column_data::{BinIds, BinLane, Bitmask, ColumnData, ColumnShard};
pub use dataset::{BinnedIndex, Dataset, Labels, TaskKind};
pub use shard::{ShardBins, ShardManifest, ShardedDataset};
pub use sorted_index::SortedIndex;
pub use interner::{CatId, Interner};
pub use value::Value;
