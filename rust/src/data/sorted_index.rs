//! Dataset-level sort-index cache: the UDT root pre-sort, computed once
//! per dataset and shared immutably by every fit.
//!
//! The paper's `O(M)`-per-feature claim rests on sorting each column once
//! and *maintaining* sortedness down the tree. Before this cache the
//! builder re-sorted every column on every `fit_rows` call, so a
//! `Forest::fit` with `T` trees or a retraining tuning sweep re-paid the
//! `O(K·M log M)` root sort `T` times. [`crate::data::dataset::Dataset`]
//! now memoizes one [`SortedIndex`] behind a `OnceLock`; forest bags and
//! tuned retrains filter the cached order by row membership (an `O(K·M)`
//! scan) instead of sorting.
//!
//! Contract:
//! * the cache is built lazily on first use and never mutated — columns
//!   and (for the regression by-target order) label values must not
//!   change after the first fit (nothing in the crate mutates them;
//!   `align_labels` only remaps *classification* ids, which the index
//!   does not store);
//! * `num_rows` is ascending by `(value, row)` and `cat_rows` is grouped
//!   by ascending `(category id, row)` — exactly the order the builder's
//!   in-place partition preserves down the tree;
//! * the per-dataset build counter ([`Dataset::sort_index_builds`]) lets
//!   tests assert the "sort each column exactly once" property.
//!
//! [`Dataset::sort_index_builds`]: crate::data::dataset::Dataset::sort_index_builds

use super::column::Column;
use super::dataset::Labels;

/// Root-level sorted artifacts of one feature column.
#[derive(Debug, Clone, Default)]
pub struct FeatureSorted {
    /// Rows holding numeric cells, ascending by `(value, row)`.
    pub num_rows: Vec<u32>,
    /// Values parallel to `num_rows`.
    pub num_vals: Vec<f64>,
    /// Rows holding categorical cells, grouped by ascending `(id, row)`.
    pub cat_rows: Vec<u32>,
    /// Category ids parallel to `cat_rows` (non-decreasing).
    pub cat_ids: Vec<u32>,
    /// Whether the column holds any categorical or missing cell (lets
    /// the selection engine skip its per-node statistics pass on clean
    /// numeric columns).
    pub has_nonnum: bool,
    /// Number of distinct numeric values — the paper's `N` — derived in
    /// one `O(M)` pass over the already-sorted value lane and memoized
    /// here (see [`crate::data::dataset::Dataset::unique_numeric_count`]).
    pub n_unique_num: usize,
}

/// The cached root pre-sort of a whole dataset (Algorithm 5 line 2).
#[derive(Debug, Clone)]
pub struct SortedIndex {
    /// One entry per feature column.
    pub features: Vec<FeatureSorted>,
    /// Regression only: all rows ascending by `(target, row)` — the
    /// Algorithm 6 label-split order. Empty for classification.
    pub reg_order: Vec<u32>,
}

impl SortedIndex {
    /// Sort every column (and, for regression, the targets). `O(K·M log M)`
    /// — paid once per dataset; every fit afterwards filters this order.
    pub fn build(columns: &[Column], labels: &Labels) -> SortedIndex {
        let features = columns
            .iter()
            .map(|c| {
                // Both orders come straight off the typed lanes — no
                // tagged-cell scan, no re-classification.
                let (num_rows, num_vals) = c.sorted_numeric();
                let (cat_rows, cat_ids) = c.sorted_categorical();
                let has_nonnum = num_rows.len() != c.len();
                let n_unique_num = num_vals
                    .windows(2)
                    .filter(|w| w[0] != w[1])
                    .count()
                    + usize::from(!num_vals.is_empty());
                FeatureSorted {
                    num_rows,
                    num_vals,
                    cat_rows,
                    cat_ids,
                    has_nonnum,
                    n_unique_num,
                }
            })
            .collect();
        let reg_order = match labels {
            Labels::Reg { values } => {
                let mut idx: Vec<u32> = (0..values.len() as u32).collect();
                idx.sort_by(|&a, &b| {
                    values[a as usize]
                        .partial_cmp(&values[b as usize])
                        // ANALYZE-ALLOW(no-unwrap): surfaces NaN targets loudly; total_cmp would reorder ±0.0 ties and change tree identity
                        .unwrap()
                        .then(a.cmp(&b))
                });
                idx
            }
            Labels::Class { .. } => Vec::new(),
        };
        SortedIndex {
            features,
            reg_order,
        }
    }

    /// Approximate resident bytes of the cached order.
    pub fn approx_bytes(&self) -> usize {
        let mut b = self.reg_order.len() * std::mem::size_of::<u32>();
        for f in &self.features {
            b += f.num_rows.len() * std::mem::size_of::<u32>()
                + f.num_vals.len() * std::mem::size_of::<f64>()
                + f.cat_rows.len() * std::mem::size_of::<u32>()
                + f.cat_ids.len() * std::mem::size_of::<u32>();
        }
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::value::Value;

    #[test]
    fn numeric_order_and_nonnum_flag() {
        let clean = Column::new("c", vec![Value::Num(2.0), Value::Num(1.0)]);
        let dirty = Column::new("d", vec![Value::Num(5.0), Value::Missing]);
        let labels = Labels::Class {
            ids: vec![0, 1],
            n_classes: 2,
        };
        let idx = SortedIndex::build(&[clean, dirty], &labels);
        assert_eq!(idx.features[0].num_rows, vec![1, 0]);
        assert_eq!(idx.features[0].num_vals, vec![1.0, 2.0]);
        assert!(!idx.features[0].has_nonnum);
        assert!(idx.features[1].has_nonnum);
        assert!(idx.reg_order.is_empty());
        assert_eq!(idx.features[0].n_unique_num, 2);
        assert_eq!(idx.features[1].n_unique_num, 1);
    }

    #[test]
    fn unique_count_deduplicates_ties() {
        let col = Column::new(
            "c",
            vec![
                Value::Num(2.0),
                Value::Num(1.0),
                Value::Num(2.0),
                Value::Num(1.0),
                Value::Missing,
            ],
        );
        let labels = Labels::Class {
            ids: vec![0; 5],
            n_classes: 1,
        };
        let idx = SortedIndex::build(&[col], &labels);
        assert_eq!(idx.features[0].n_unique_num, 2);
        // Empty numeric lane → zero distinct values.
        let empty = Column::new("e", vec![Value::Missing; 3]);
        let labels = Labels::Class {
            ids: vec![0; 3],
            n_classes: 1,
        };
        let idx = SortedIndex::build(&[empty], &labels);
        assert_eq!(idx.features[0].n_unique_num, 0);
    }

    #[test]
    fn regression_order_sorts_by_target_then_row() {
        let col = Column::new("c", vec![Value::Num(0.0); 4]);
        let labels = Labels::Reg {
            values: vec![3.0, 1.0, 3.0, -2.0],
        };
        let idx = SortedIndex::build(&[col], &labels);
        assert_eq!(idx.reg_order, vec![3, 1, 0, 2]);
        assert!(idx.approx_bytes() > 0);
    }
}
