//! Streaming CSV ingestion with hybrid type inference.
//!
//! Cells parse as numbers first and fall back to interned categoricals
//! (`?`, `NA`, empty → missing) — the paper's no-pre-encoding rule. The
//! last column is the label by default. Handles quoted fields, embedded
//! commas/quotes and CRLF line endings.
//!
//! ## The streaming pipeline
//!
//! The ingest path never materializes the file as rows of `String`s.
//! Input text is split into **line-aligned byte chunks**; each chunk
//! parses in parallel straight into typed per-column
//! [`ColumnShard`]s — on the unquoted fast path fields are borrowed
//! `&str` slices of the input, so a cell allocates only when it is a
//! *new* categorical string (interned into a chunk-local
//! [`Interner`]). Chunks then merge in order: each chunk's interner
//! (and, for classification, its class-name table) remaps into the
//! global id space, and shards concatenate. Because every chunk
//! preserves row order and first-seen order composes across ordered
//! chunks, the result is **bit-identical** to a sequential parse for
//! every thread count and chunk size (`rust/tests/prop_ingest.rs`).
//!
//! [`load_csv_str_rowwise`] keeps the legacy row-materializing parser as
//! the equivalence oracle and the baseline of `benches/ingest.rs`.

use super::column::Column;
use super::column_data::{ColumnData, ColumnShard};
use super::dataset::{Dataset, Labels, TaskKind};
use super::interner::Interner;
use super::value::{parse_cell, Value};
use crate::coordinator::parallel::parallel_map;
use crate::error::{Result, UdtError};
use std::collections::HashMap;
use std::path::Path;

/// CSV loading options.
#[derive(Debug, Clone)]
pub struct CsvOptions {
    /// Whether the first row is a header.
    pub has_header: bool,
    /// Column index of the label; `None` means the last column.
    pub label_col: Option<usize>,
    /// Task kind; `Classification` interns label strings into class ids,
    /// `Regression` requires numeric labels.
    pub task: TaskKind,
    /// Field delimiter.
    pub delimiter: char,
    /// Parse worker threads (0 = all cores, 1 = sequential). The parsed
    /// dataset is bit-identical for every thread count.
    pub n_threads: usize,
    /// Target chunk size in bytes for the streaming parser (0 = auto:
    /// ~4 chunks per worker, at least 64 KiB). Exposed for tests and
    /// benches; does not affect the parsed result.
    pub chunk_bytes: usize,
}

impl Default for CsvOptions {
    fn default() -> Self {
        Self {
            has_header: true,
            label_col: None,
            task: TaskKind::Classification,
            delimiter: ',',
            n_threads: 0,
            chunk_bytes: 0,
        }
    }
}

/// Parse one CSV record honoring quotes. Returns fields.
pub fn parse_record(line: &str, delim: char) -> Vec<String> {
    let mut fields = Vec::new();
    let mut cur = String::new();
    let mut chars = line.chars().peekable();
    let mut in_quotes = false;
    while let Some(c) = chars.next() {
        if in_quotes {
            if c == '"' {
                if chars.peek() == Some(&'"') {
                    cur.push('"');
                    chars.next();
                } else {
                    in_quotes = false;
                }
            } else {
                cur.push(c);
            }
        } else if c == '"' {
            in_quotes = true;
        } else if c == delim {
            fields.push(std::mem::take(&mut cur));
        } else if c != '\r' {
            cur.push(c);
        }
    }
    fields.push(cur);
    fields
}

/// What the chunk parser does with the label column.
#[derive(Debug, Clone, Copy)]
pub(crate) enum LabelMode {
    /// Every column is a feature (the `RowFrame` CSV path).
    None,
    /// Column `i` holds class-name labels.
    Class(usize),
    /// Column `i` holds numeric regression targets.
    Reg(usize),
}

/// Typed parse output of one line-aligned chunk. Categorical ids (and
/// classification class ids) are chunk-local; the merge step remaps.
pub(crate) struct ChunkShard {
    pub(crate) cols: Vec<ColumnShard>,
    pub(crate) interner: Interner,
    pub(crate) class_ids: Vec<u16>,
    pub(crate) class_names: Vec<String>,
    pub(crate) reg_vals: Vec<f64>,
    pub(crate) n_rows: usize,
}

/// A parse failure local to one chunk; row indices are chunk-relative
/// and fixed up against the preceding chunks' row counts at merge time.
pub(crate) struct ChunkError {
    local_row: usize,
    kind: ChunkErrorKind,
}

enum ChunkErrorKind {
    Ragged { got: usize },
    BadRegLabel,
}

impl ChunkError {
    pub(crate) fn into_error(self, rows_before: usize, width: usize) -> UdtError {
        match self.kind {
            ChunkErrorKind::Ragged { got } => UdtError::data(format!(
                "row {} has {got} fields, expected {width}",
                rows_before + self.local_row + 1
            )),
            ChunkErrorKind::BadRegLabel => UdtError::data(format!(
                "row {}: non-numeric regression label",
                rows_before + self.local_row
            )),
        }
    }
}

/// Split `body` into chunks of roughly `target` bytes, each ending on a
/// line boundary ('\n' is ASCII, so every cut is a char boundary).
pub(crate) fn line_aligned_chunks(body: &str, target: usize) -> Vec<&str> {
    let bytes = body.as_bytes();
    let target = target.max(1);
    let mut chunks = Vec::new();
    let mut start = 0usize;
    while start < bytes.len() {
        let mut end = (start + target).min(bytes.len());
        while end < bytes.len() && bytes[end - 1] != b'\n' {
            end += 1;
        }
        chunks.push(&body[start..end]);
        start = end;
    }
    chunks
}

/// Parse one chunk into typed shards. `width` is the expected field
/// count of every record; `n_features` is `width` minus the label
/// column, if any.
pub(crate) fn parse_chunk(
    chunk: &str,
    width: usize,
    n_features: usize,
    label: LabelMode,
    delim: char,
) -> std::result::Result<ChunkShard, ChunkError> {
    let mut shard = ChunkShard {
        cols: (0..n_features).map(|_| ColumnShard::default()).collect(),
        interner: Interner::new(),
        class_ids: Vec::new(),
        class_names: Vec::new(),
        reg_vals: Vec::new(),
        n_rows: 0,
    };
    let mut class_map: HashMap<String, u16> = HashMap::new();
    // Reused across lines on the fast path; holds only `chunk`-borrowed
    // slices, so one Vec serves the whole chunk without reallocation.
    let mut fields: Vec<&str> = Vec::with_capacity(width);
    for line in chunk.lines() {
        if line.trim().is_empty() {
            continue;
        }
        let row = shard.n_rows;
        // One scan decides the path ('"' and '\r' are ASCII, so a byte
        // scan is UTF-8-correct).
        if line.bytes().any(|b| b == b'"' || b == b'\r') {
            // Slow path: quoted fields / stray carriage returns go
            // through the one record parser so semantics cannot drift.
            let owned = parse_record(line, delim);
            if owned.len() != width {
                return Err(ChunkError {
                    local_row: row,
                    kind: ChunkErrorKind::Ragged { got: owned.len() },
                });
            }
            push_fields(
                &mut shard,
                &mut class_map,
                owned.iter().map(String::as_str),
                label,
                row,
            )?;
        } else {
            // Fast path: borrowed `&str` field slices straight off the
            // input — no per-cell `String`, and the single split pass
            // both validates the width and feeds the cell parser.
            fields.clear();
            fields.extend(line.split(delim));
            if fields.len() != width {
                return Err(ChunkError {
                    local_row: row,
                    kind: ChunkErrorKind::Ragged { got: fields.len() },
                });
            }
            push_fields(
                &mut shard,
                &mut class_map,
                fields.iter().copied(),
                label,
                row,
            )?;
        }
        shard.n_rows += 1;
    }
    Ok(shard)
}

/// Append one validated record's cells to the chunk's typed shards.
fn push_fields<'x>(
    shard: &mut ChunkShard,
    class_map: &mut HashMap<String, u16>,
    fields: impl Iterator<Item = &'x str>,
    label: LabelMode,
    row: usize,
) -> std::result::Result<(), ChunkError> {
    let ChunkShard {
        cols,
        interner,
        class_ids,
        class_names,
        reg_vals,
        ..
    } = shard;
    let mut slot = 0usize;
    for (c, raw) in fields.enumerate() {
        match label {
            LabelMode::Class(lc) if c == lc => {
                let name = raw.trim();
                let id = match class_map.get(name) {
                    Some(&id) => id,
                    None => {
                        let id = class_names.len() as u16;
                        class_names.push(name.to_string());
                        class_map.insert(name.to_string(), id);
                        id
                    }
                };
                class_ids.push(id);
            }
            LabelMode::Reg(lc) if c == lc => {
                let v: f64 = raw.trim().parse().map_err(|_| ChunkError {
                    local_row: row,
                    kind: ChunkErrorKind::BadRegLabel,
                })?;
                reg_vals.push(v);
            }
            _ => {
                cols[slot].push_value(parse_cell(raw, |s| interner.intern(s)));
                slot += 1;
            }
        }
    }
    Ok(())
}

/// Consume the header line (if any); returns the parsed header fields
/// and the remaining body text.
pub(crate) fn split_header(text: &str, delim: char, has_header: bool) -> (Option<Vec<String>>, &str) {
    if !has_header {
        return (None, text);
    }
    let mut offset = 0usize;
    for raw in text.split_inclusive('\n') {
        let line = raw.strip_suffix('\n').unwrap_or(raw);
        offset += raw.len();
        if line.trim().is_empty() {
            continue;
        }
        let fields = parse_record(line, delim)
            .into_iter()
            .map(|f| f.trim().to_string())
            .collect();
        return (Some(fields), &text[offset..]);
    }
    (None, &text[text.len()..])
}

/// Field count of the first data record (width source when there is no
/// header).
pub(crate) fn first_data_width(body: &str, delim: char) -> Option<usize> {
    for line in body.lines() {
        if line.trim().is_empty() {
            continue;
        }
        return Some(if line.contains('"') {
            parse_record(line, delim).len()
        } else {
            line.split(delim).count()
        });
    }
    None
}

/// Everything the streaming parser produces; shared by the dataset path
/// ([`load_csv_str`]) and the feature-frame path
/// ([`crate::inference::RowFrame::from_csv_str`]).
pub(crate) struct TypedCsv {
    pub feature_names: Vec<String>,
    pub columns: Vec<ColumnData>,
    pub interner: Interner,
    pub labels: Option<Labels>,
    pub class_names: Vec<String>,
    pub n_rows: usize,
}

/// The streaming chunk-parallel core: split → typed chunk shards →
/// ordered merge with interner/class remapping. `with_label` selects
/// dataset semantics (label column split out per `opts`) versus frame
/// semantics (every column a feature).
pub(crate) fn parse_typed_csv(
    name: &str,
    text: &str,
    opts: &CsvOptions,
    with_label: bool,
) -> Result<TypedCsv> {
    let delim = opts.delimiter;
    let (header, body) = split_header(text, delim, opts.has_header);

    // Expected record width: the header's when present (a mismatched
    // header is an error, not a silent misalignment), else the first
    // data record's.
    let width = match header.as_ref().map(Vec::len) {
        Some(w) => w,
        None => first_data_width(body, delim)
            .ok_or_else(|| UdtError::data(format!("csv `{name}` has no data rows")))?,
    };

    let label = if with_label {
        if width < 2 {
            return Err(UdtError::data(format!(
                "csv `{name}` needs at least one feature column plus a label"
            )));
        }
        let label_col = opts.label_col.unwrap_or(width - 1);
        if label_col >= width {
            return Err(UdtError::data(format!(
                "label column {label_col} out of range (width {width})"
            )));
        }
        match opts.task {
            TaskKind::Classification => LabelMode::Class(label_col),
            TaskKind::Regression => LabelMode::Reg(label_col),
        }
    } else {
        LabelMode::None
    };
    let n_features = match label {
        LabelMode::None => width,
        _ => width - 1,
    };

    let threads = crate::runtime::threads(opts.n_threads);
    let target = if opts.chunk_bytes > 0 {
        opts.chunk_bytes
    } else if threads <= 1 {
        body.len().max(1)
    } else {
        (body.len() / (threads * 4)).max(1 << 16)
    };
    let chunks = line_aligned_chunks(body, target);
    let shards = parallel_map(chunks, threads, |chunk| {
        parse_chunk(chunk, width, n_features, label, delim)
    });

    // Ordered merge: chunk-local id spaces remap into the global ones.
    // First-seen order composes across ordered chunks, so interner ids
    // and class ids match a sequential parse exactly.
    let mut interner = Interner::new();
    let mut cols: Vec<ColumnShard> = (0..n_features).map(|_| ColumnShard::default()).collect();
    let mut class_names: Vec<String> = Vec::new();
    let mut global_class: HashMap<String, u16> = HashMap::new();
    let mut class_ids: Vec<u16> = Vec::new();
    let mut reg_vals: Vec<f64> = Vec::new();
    let mut rows_before = 0usize;
    for res in shards {
        let shard = match res {
            Ok(s) => s,
            Err(e) => return Err(e.into_error(rows_before, width)),
        };
        let remap: Vec<u32> = shard
            .interner
            .names()
            .iter()
            .map(|n| interner.intern(n).0)
            .collect();
        for (dst, src) in cols.iter_mut().zip(&shard.cols) {
            dst.append_remapped(src, &remap);
        }
        if !shard.class_names.is_empty() || !shard.class_ids.is_empty() {
            let cmap: Vec<u16> = shard
                .class_names
                .iter()
                .map(|n| match global_class.get(n) {
                    Some(&id) => id,
                    None => {
                        let id = class_names.len() as u16;
                        class_names.push(n.clone());
                        global_class.insert(n.clone(), id);
                        id
                    }
                })
                .collect();
            class_ids.extend(shard.class_ids.iter().map(|&l| cmap[l as usize]));
        }
        reg_vals.extend_from_slice(&shard.reg_vals);
        rows_before += shard.n_rows;
    }
    if rows_before == 0 {
        return Err(UdtError::data(format!("csv `{name}` has no data rows")));
    }

    let feature_names = (0..width)
        .filter(|&c| !matches!(label, LabelMode::Class(lc) | LabelMode::Reg(lc) if lc == c))
        .map(|c| {
            header
                .as_ref()
                .and_then(|h| h.get(c).cloned())
                .unwrap_or_else(|| format!("f{c}"))
        })
        .collect();
    let labels = match label {
        LabelMode::None => None,
        LabelMode::Class(_) => Some(Labels::Class {
            ids: class_ids,
            n_classes: class_names.len(),
        }),
        LabelMode::Reg(_) => Some(Labels::Reg { values: reg_vals }),
    };
    Ok(TypedCsv {
        feature_names,
        columns: cols.into_iter().map(ColumnShard::finish).collect(),
        interner,
        labels,
        class_names,
        n_rows: rows_before,
    })
}

/// Load a dataset from CSV text through the streaming chunk-parallel
/// parser (see the module docs; bit-identical for any
/// `CsvOptions::n_threads` / `chunk_bytes`).
pub fn load_csv_str(name: &str, text: &str, opts: &CsvOptions) -> Result<Dataset> {
    let parsed = parse_typed_csv(name, text, opts, true)?;
    let columns = parsed
        .feature_names
        .into_iter()
        .zip(parsed.columns)
        .map(|(n, d)| Column::from_data(n, d))
        .collect();
    // ANALYZE-ALLOW(no-unwrap): dataset-mode parse always produces a labels column
    let labels = parsed.labels.expect("dataset parse always yields labels");
    let mut ds = Dataset::new(name, columns, labels, parsed.interner)?;
    ds.class_names = std::sync::Arc::new(parsed.class_names);
    Ok(ds)
}

/// The legacy row-materializing parser (every cell a heap `String`
/// before typing). Kept as the equivalence oracle for
/// `rust/tests/prop_ingest.rs` and the baseline of `benches/ingest.rs`;
/// production callers use [`load_csv_str`].
pub fn load_csv_str_rowwise(name: &str, text: &str, opts: &CsvOptions) -> Result<Dataset> {
    let mut lines = text.lines().filter(|l| !l.trim().is_empty());
    let mut header: Option<Vec<String>> = None;
    if opts.has_header {
        header = lines
            .next()
            .map(|l| parse_record(l, opts.delimiter))
            .map(|fs| fs.into_iter().map(|f| f.trim().to_string()).collect());
    }

    let mut rows: Vec<Vec<String>> = Vec::new();
    for (i, line) in lines.enumerate() {
        let fields = parse_record(line, opts.delimiter);
        // Validate against the header when there is one (a header whose
        // width disagrees with the data must not silently misalign the
        // feature names), else against the first data row.
        let expected = header
            .as_ref()
            .map(Vec::len)
            .or_else(|| rows.first().map(Vec::len));
        if let Some(expected) = expected {
            if fields.len() != expected {
                return Err(UdtError::data(format!(
                    "row {} has {} fields, expected {}",
                    i + 1,
                    fields.len(),
                    expected
                )));
            }
        }
        rows.push(fields);
    }
    if rows.is_empty() {
        return Err(UdtError::data(format!("csv `{name}` has no data rows")));
    }
    let width = rows[0].len();
    if width < 2 {
        return Err(UdtError::data(format!(
            "csv `{name}` needs at least one feature column plus a label"
        )));
    }
    let label_col = opts.label_col.unwrap_or(width - 1);
    if label_col >= width {
        return Err(UdtError::data(format!(
            "label column {label_col} out of range (width {width})"
        )));
    }

    let mut interner = Interner::new();
    let feature_cols: Vec<usize> = (0..width).filter(|&c| c != label_col).collect();
    let mut cells: Vec<Vec<Value>> = feature_cols
        .iter()
        .map(|_| Vec::with_capacity(rows.len()))
        .collect();
    for row in &rows {
        for (slot, &c) in feature_cols.iter().enumerate() {
            cells[slot].push(parse_cell(&row[c], |s| interner.intern(s)));
        }
    }
    let columns: Vec<Column> = feature_cols
        .iter()
        .zip(cells)
        .map(|(&c, vals)| {
            let col_name = header
                .as_ref()
                .and_then(|h| h.get(c).cloned())
                .unwrap_or_else(|| format!("f{c}"));
            Column::new(col_name, vals)
        })
        .collect();

    let labels = match opts.task {
        TaskKind::Classification => {
            let mut class_ids: HashMap<String, u16> = HashMap::new();
            let mut names: Vec<String> = Vec::new();
            let ids: Vec<u16> = rows
                .iter()
                .map(|r| {
                    let raw = r[label_col].trim().to_string();
                    *class_ids.entry(raw.clone()).or_insert_with(|| {
                        names.push(raw.clone());
                        (names.len() - 1) as u16
                    })
                })
                .collect();
            let n_classes = names.len();
            let mut ds = Dataset::new(
                name,
                columns,
                Labels::Class { ids, n_classes },
                interner,
            )?;
            ds.class_names = std::sync::Arc::new(names);
            return Ok(ds);
        }
        TaskKind::Regression => {
            let values: Result<Vec<f64>> = rows
                .iter()
                .enumerate()
                .map(|(i, r)| {
                    r[label_col].trim().parse::<f64>().map_err(|_| {
                        UdtError::data(format!("row {i}: non-numeric regression label"))
                    })
                })
                .collect();
            Labels::Reg { values: values? }
        }
    };
    Dataset::new(name, columns, labels, interner)
}

/// Load a dataset from a CSV file on disk.
pub fn load_csv(path: impl AsRef<Path>, opts: &CsvOptions) -> Result<Dataset> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path)
        .map_err(|e| UdtError::data(format!("reading {}: {e}", path.display())))?;
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().to_string())
        .unwrap_or_else(|| "csv".to_string());
    load_csv_str(&name, &text, opts)
}

/// Write a dataset back to CSV text (used by `gen-data` and tests).
pub fn to_csv_string(ds: &Dataset) -> String {
    let mut out = String::new();
    for c in &ds.columns {
        out.push_str(&c.name);
        out.push(',');
    }
    out.push_str("label\n");
    for row in 0..ds.n_rows() {
        for c in &ds.columns {
            match c.get(row) {
                Value::Num(x) => out.push_str(&format_num(x)),
                Value::Cat(id) => {
                    let name = ds.interner.name(id);
                    if name.contains(',') || name.contains('"') {
                        out.push('"');
                        out.push_str(&name.replace('"', "\"\""));
                        out.push('"');
                    } else {
                        out.push_str(name);
                    }
                }
                Value::Missing => {}
            }
            out.push(',');
        }
        match &ds.labels {
            Labels::Class { ids, .. } => {
                let id = ids[row] as usize;
                if let Some(n) = ds.class_names.get(id) {
                    out.push_str(n);
                } else {
                    out.push_str(&format!("c{id}"));
                }
            }
            Labels::Reg { values } => out.push_str(&format_num(values[row])),
        }
        out.push('\n');
    }
    out
}

fn format_num(x: f64) -> String {
    if x.fract() == 0.0 && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_quoted_fields() {
        let fs = parse_record(r#"a,"b,c","d""e",f"#, ',');
        assert_eq!(fs, vec!["a", "b,c", "d\"e", "f"]);
    }

    #[test]
    fn loads_classification_csv() {
        let text = "age,color,label\n3,red,yes\n4,blue,no\n?,red,yes\n";
        let ds = load_csv_str("t", text, &CsvOptions::default()).unwrap();
        assert_eq!(ds.n_rows(), 3);
        assert_eq!(ds.n_features(), 2);
        assert_eq!(ds.labels.n_classes(), 2);
        assert_eq!(ds.value(0, 0), Value::Num(3.0));
        assert!(ds.value(1, 0).is_cat());
        assert!(ds.value(0, 2).is_missing());
        assert_eq!(*ds.class_names, vec!["yes", "no"]);
        assert_eq!(ds.columns[0].name, "age");
        assert_eq!(ds.columns[1].name, "color");
    }

    #[test]
    fn loads_regression_csv() {
        let text = "x,y\n1,0.5\n2,1.5\n";
        let opts = CsvOptions {
            task: TaskKind::Regression,
            ..Default::default()
        };
        let ds = load_csv_str("r", text, &opts).unwrap();
        assert_eq!(ds.task(), TaskKind::Regression);
        assert_eq!(ds.labels.target(1), 1.5);
    }

    #[test]
    fn regression_rejects_text_labels() {
        let text = "x,y\n1,abc\n";
        let opts = CsvOptions {
            task: TaskKind::Regression,
            ..Default::default()
        };
        assert!(load_csv_str("r", text, &opts).is_err());
        assert!(load_csv_str_rowwise("r", text, &opts).is_err());
    }

    #[test]
    fn ragged_rows_rejected() {
        let text = "a,b,label\n1,2,x\n1,x\n";
        assert!(load_csv_str("t", text, &CsvOptions::default()).is_err());
        assert!(load_csv_str_rowwise("t", text, &CsvOptions::default()).is_err());
    }

    #[test]
    fn header_width_mismatch_rejected() {
        // Regression test: a header narrower (or wider) than the data
        // used to be silently accepted, misaligning feature names.
        let narrow = "a,b\n1,2,x\n3,4,y\n";
        let wide = "a,b,c,label\n1,2,x\n3,4,y\n";
        for text in [narrow, wide] {
            assert!(
                load_csv_str("t", text, &CsvOptions::default()).is_err(),
                "accepted mismatched header: {text:?}"
            );
            assert!(
                load_csv_str_rowwise("t", text, &CsvOptions::default()).is_err(),
                "rowwise accepted mismatched header: {text:?}"
            );
        }
        // A consistent header still loads.
        assert!(load_csv_str("t", "a,label\n1,x\n", &CsvOptions::default()).is_ok());
    }

    #[test]
    fn hybrid_column_round_trips() {
        let text = "f,label\n1,y\ncat,n\n,y\n2.5,n\n";
        let ds = load_csv_str("t", text, &CsvOptions::default()).unwrap();
        let csv = to_csv_string(&ds);
        let ds2 = load_csv_str("t2", &csv, &CsvOptions::default()).unwrap();
        assert_eq!(ds2.n_rows(), ds.n_rows());
        for r in 0..ds.n_rows() {
            match (ds.value(0, r), ds2.value(0, r)) {
                (Value::Num(a), Value::Num(b)) => assert_eq!(a, b),
                (Value::Cat(a), Value::Cat(b)) => {
                    assert_eq!(ds.interner.name(a), ds2.interner.name(b))
                }
                (Value::Missing, Value::Missing) => {}
                (a, b) => panic!("mismatch {a:?} vs {b:?}"),
            }
            assert_eq!(ds.labels.class(r), ds2.labels.class(r));
        }
    }

    #[test]
    fn label_col_override() {
        let text = "label,f\nyes,1\nno,2\n";
        let opts = CsvOptions {
            label_col: Some(0),
            ..Default::default()
        };
        let ds = load_csv_str("t", text, &opts).unwrap();
        assert_eq!(ds.n_features(), 1);
        assert_eq!(ds.value(0, 1), Value::Num(2.0));
        assert_eq!(ds.columns[0].name, "f");
    }

    #[test]
    fn chunked_parse_matches_sequential_exactly() {
        // Tiny chunk size forces many chunks through the merge path;
        // interner ids and class ids must still match the sequential
        // parse bit-for-bit.
        let text = "f,g,label\nzebra,1,y\napple,2,n\nzebra,pear,y\n,3,n\napple,4,y\n";
        let seq = load_csv_str("t", text, &CsvOptions::default()).unwrap();
        let chunked = load_csv_str(
            "t",
            text,
            &CsvOptions {
                n_threads: 3,
                chunk_bytes: 8,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(seq.n_rows(), chunked.n_rows());
        assert_eq!(seq.interner.names(), chunked.interner.names());
        assert_eq!(*seq.class_names, *chunked.class_names);
        for f in 0..seq.n_features() {
            for r in 0..seq.n_rows() {
                assert_eq!(seq.value(f, r), chunked.value(f, r), "cell ({f},{r})");
            }
        }
        for r in 0..seq.n_rows() {
            assert_eq!(seq.labels.class(r), chunked.labels.class(r));
        }
    }

    #[test]
    fn line_aligned_chunks_tile_the_input() {
        let body = "aa\nbbbb\nc\n";
        for target in 1..=body.len() + 1 {
            let chunks = line_aligned_chunks(body, target);
            let joined: String = chunks.concat();
            assert_eq!(joined, body, "target {target}");
            for c in &chunks[..chunks.len().saturating_sub(1)] {
                assert!(c.ends_with('\n'), "chunk {c:?} not line-aligned");
            }
        }
        assert!(line_aligned_chunks("", 8).is_empty());
    }

    #[test]
    fn crlf_and_quotes_survive_streaming() {
        let text = "a,b,label\r\n\"x,1\",2,yes\r\n\"say \"\"hi\"\"\",3,no\r\n";
        for opts in [
            CsvOptions::default(),
            CsvOptions {
                n_threads: 2,
                chunk_bytes: 4,
                ..Default::default()
            },
        ] {
            let ds = load_csv_str("t", text, &opts).unwrap();
            assert_eq!(ds.n_rows(), 2);
            assert_eq!(ds.interner.name(ds.value(0, 0).as_cat().unwrap()), "x,1");
            assert_eq!(
                ds.interner.name(ds.value(0, 1).as_cat().unwrap()),
                "say \"hi\""
            );
            assert_eq!(ds.value(1, 1), Value::Num(3.0));
            assert_eq!(*ds.class_names, vec!["yes", "no"]);
        }
    }
}
