//! CSV ingestion with hybrid type inference.
//!
//! Cells parse as numbers first and fall back to interned categoricals
//! (`?`, `NA`, empty → missing) — the paper's no-pre-encoding rule. The
//! last column is the label by default. Handles quoted fields, embedded
//! commas/quotes and CRLF line endings.

use super::column::Column;
use super::dataset::{Dataset, Labels, TaskKind};
use super::interner::Interner;
use super::value::{parse_cell, Value};
use crate::error::{Result, UdtError};
use std::collections::HashMap;
use std::path::Path;

/// CSV loading options.
#[derive(Debug, Clone)]
pub struct CsvOptions {
    /// Whether the first row is a header.
    pub has_header: bool,
    /// Column index of the label; `None` means the last column.
    pub label_col: Option<usize>,
    /// Task kind; `Classification` interns label strings into class ids,
    /// `Regression` requires numeric labels.
    pub task: TaskKind,
    /// Field delimiter.
    pub delimiter: char,
}

impl Default for CsvOptions {
    fn default() -> Self {
        Self {
            has_header: true,
            label_col: None,
            task: TaskKind::Classification,
            delimiter: ',',
        }
    }
}

/// Parse one CSV record honoring quotes. Returns fields.
pub fn parse_record(line: &str, delim: char) -> Vec<String> {
    let mut fields = Vec::new();
    let mut cur = String::new();
    let mut chars = line.chars().peekable();
    let mut in_quotes = false;
    while let Some(c) = chars.next() {
        if in_quotes {
            if c == '"' {
                if chars.peek() == Some(&'"') {
                    cur.push('"');
                    chars.next();
                } else {
                    in_quotes = false;
                }
            } else {
                cur.push(c);
            }
        } else if c == '"' {
            in_quotes = true;
        } else if c == delim {
            fields.push(std::mem::take(&mut cur));
        } else if c != '\r' {
            cur.push(c);
        }
    }
    fields.push(cur);
    fields
}

/// Load a dataset from CSV text.
pub fn load_csv_str(name: &str, text: &str, opts: &CsvOptions) -> Result<Dataset> {
    let mut lines = text.lines().filter(|l| !l.trim().is_empty());
    let mut header: Option<Vec<String>> = None;
    if opts.has_header {
        header = lines
            .next()
            .map(|l| parse_record(l, opts.delimiter))
            .map(|fs| fs.into_iter().map(|f| f.trim().to_string()).collect());
    }

    let mut rows: Vec<Vec<String>> = Vec::new();
    for (i, line) in lines.enumerate() {
        let fields = parse_record(line, opts.delimiter);
        if let Some(prev) = rows.first() {
            if fields.len() != prev.len() {
                return Err(UdtError::data(format!(
                    "row {} has {} fields, expected {}",
                    i + 1,
                    fields.len(),
                    prev.len()
                )));
            }
        }
        rows.push(fields);
    }
    if rows.is_empty() {
        return Err(UdtError::data(format!("csv `{name}` has no data rows")));
    }
    let width = rows[0].len();
    if width < 2 {
        return Err(UdtError::data(format!(
            "csv `{name}` needs at least one feature column plus a label"
        )));
    }
    let label_col = opts.label_col.unwrap_or(width - 1);
    if label_col >= width {
        return Err(UdtError::data(format!(
            "label column {label_col} out of range (width {width})"
        )));
    }

    let mut interner = Interner::new();
    let feature_cols: Vec<usize> = (0..width).filter(|&c| c != label_col).collect();
    let mut columns: Vec<Column> = feature_cols
        .iter()
        .map(|&c| {
            let col_name = header
                .as_ref()
                .and_then(|h| h.get(c).cloned())
                .unwrap_or_else(|| format!("f{c}"));
            Column::new(col_name, Vec::with_capacity(rows.len()))
        })
        .collect();

    for row in &rows {
        for (slot, &c) in feature_cols.iter().enumerate() {
            let v = parse_cell(&row[c], |s| interner.intern(s));
            columns[slot].values.push(v);
        }
    }

    let labels = match opts.task {
        TaskKind::Classification => {
            let mut class_ids: HashMap<String, u16> = HashMap::new();
            let mut names: Vec<String> = Vec::new();
            let ids: Vec<u16> = rows
                .iter()
                .map(|r| {
                    let raw = r[label_col].trim().to_string();
                    *class_ids.entry(raw.clone()).or_insert_with(|| {
                        names.push(raw.clone());
                        (names.len() - 1) as u16
                    })
                })
                .collect();
            let n_classes = names.len();
            let mut ds = Dataset::new(
                name,
                columns,
                Labels::Class { ids, n_classes },
                interner,
            )?;
            ds.class_names = std::sync::Arc::new(names);
            return Ok(ds);
        }
        TaskKind::Regression => {
            let values: Result<Vec<f64>> = rows
                .iter()
                .enumerate()
                .map(|(i, r)| {
                    r[label_col].trim().parse::<f64>().map_err(|_| {
                        UdtError::data(format!("row {i}: non-numeric regression label"))
                    })
                })
                .collect();
            Labels::Reg { values: values? }
        }
    };
    Dataset::new(name, columns, labels, interner)
}

/// Load a dataset from a CSV file on disk.
pub fn load_csv(path: impl AsRef<Path>, opts: &CsvOptions) -> Result<Dataset> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path)
        .map_err(|e| UdtError::data(format!("reading {}: {e}", path.display())))?;
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().to_string())
        .unwrap_or_else(|| "csv".to_string());
    load_csv_str(&name, &text, opts)
}

/// Write a dataset back to CSV text (used by `gen-data` and tests).
pub fn to_csv_string(ds: &Dataset) -> String {
    let mut out = String::new();
    for c in &ds.columns {
        out.push_str(&c.name);
        out.push(',');
    }
    out.push_str("label\n");
    for row in 0..ds.n_rows() {
        for c in &ds.columns {
            match c.values[row] {
                Value::Num(x) => out.push_str(&format_num(x)),
                Value::Cat(id) => {
                    let name = ds.interner.name(id);
                    if name.contains(',') || name.contains('"') {
                        out.push('"');
                        out.push_str(&name.replace('"', "\"\""));
                        out.push('"');
                    } else {
                        out.push_str(name);
                    }
                }
                Value::Missing => {}
            }
            out.push(',');
        }
        match &ds.labels {
            Labels::Class { ids, .. } => {
                let id = ids[row] as usize;
                if let Some(n) = ds.class_names.get(id) {
                    out.push_str(n);
                } else {
                    out.push_str(&format!("c{id}"));
                }
            }
            Labels::Reg { values } => out.push_str(&format_num(values[row])),
        }
        out.push('\n');
    }
    out
}

fn format_num(x: f64) -> String {
    if x.fract() == 0.0 && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_quoted_fields() {
        let fs = parse_record(r#"a,"b,c","d""e",f"#, ',');
        assert_eq!(fs, vec!["a", "b,c", "d\"e", "f"]);
    }

    #[test]
    fn loads_classification_csv() {
        let text = "age,color,label\n3,red,yes\n4,blue,no\n?,red,yes\n";
        let ds = load_csv_str("t", text, &CsvOptions::default()).unwrap();
        assert_eq!(ds.n_rows(), 3);
        assert_eq!(ds.n_features(), 2);
        assert_eq!(ds.labels.n_classes(), 2);
        assert_eq!(ds.value(0, 0), Value::Num(3.0));
        assert!(ds.value(1, 0).is_cat());
        assert!(ds.value(0, 2).is_missing());
        assert_eq!(*ds.class_names, vec!["yes", "no"]);
    }

    #[test]
    fn loads_regression_csv() {
        let text = "x,y\n1,0.5\n2,1.5\n";
        let opts = CsvOptions {
            task: TaskKind::Regression,
            ..Default::default()
        };
        let ds = load_csv_str("r", text, &opts).unwrap();
        assert_eq!(ds.task(), TaskKind::Regression);
        assert_eq!(ds.labels.target(1), 1.5);
    }

    #[test]
    fn regression_rejects_text_labels() {
        let text = "x,y\n1,abc\n";
        let opts = CsvOptions {
            task: TaskKind::Regression,
            ..Default::default()
        };
        assert!(load_csv_str("r", text, &opts).is_err());
    }

    #[test]
    fn ragged_rows_rejected() {
        let text = "a,b,label\n1,2,x\n1,x\n";
        assert!(load_csv_str("t", text, &CsvOptions::default()).is_err());
    }

    #[test]
    fn hybrid_column_round_trips() {
        let text = "f,label\n1,y\ncat,n\n,y\n2.5,n\n";
        let ds = load_csv_str("t", text, &CsvOptions::default()).unwrap();
        let csv = to_csv_string(&ds);
        let ds2 = load_csv_str("t2", &csv, &CsvOptions::default()).unwrap();
        assert_eq!(ds2.n_rows(), ds.n_rows());
        for r in 0..ds.n_rows() {
            match (ds.value(0, r), ds2.value(0, r)) {
                (Value::Num(a), Value::Num(b)) => assert_eq!(a, b),
                (Value::Cat(a), Value::Cat(b)) => {
                    assert_eq!(ds.interner.name(a), ds2.interner.name(b))
                }
                (Value::Missing, Value::Missing) => {}
                (a, b) => panic!("mismatch {a:?} vs {b:?}"),
            }
            assert_eq!(ds.labels.class(r), ds2.labels.class(r));
        }
    }

    #[test]
    fn label_col_override() {
        let text = "label,f\nyes,1\nno,2\n";
        let opts = CsvOptions {
            label_col: Some(0),
            ..Default::default()
        };
        let ds = load_csv_str("t", text, &opts).unwrap();
        assert_eq!(ds.n_features(), 1);
        assert_eq!(ds.value(0, 1), Value::Num(2.0));
    }
}
