//! Artifact manifest: `python/compile/aot.py` writes
//! `artifacts/manifest.json` describing every lowered HLO module and its
//! static shapes; the Rust engine loads executables from it.

use crate::error::{Result, UdtError};
use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// One AOT-compiled artifact (a `jax.jit`-lowered module in HLO text).
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// Logical name, e.g. `split_select_m4096`.
    pub name: String,
    /// HLO text file, relative to the manifest directory.
    pub path: PathBuf,
    /// Static example count (padded M).
    pub m: usize,
    /// Number of numeric bins (B).
    pub b: usize,
    /// Padded class count (C).
    pub c: usize,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| UdtError::runtime(format!("reading {}: {e}", path.display())))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest JSON with the given base directory.
    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let json = Json::parse(text).map_err(|e| UdtError::runtime(format!("manifest: {e}")))?;
        let arr = json
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| UdtError::runtime("manifest: missing `artifacts` array"))?;
        let mut artifacts = Vec::with_capacity(arr.len());
        for (i, a) in arr.iter().enumerate() {
            let get_str = |k: &str| {
                a.get(k)
                    .and_then(Json::as_str)
                    .ok_or_else(|| UdtError::runtime(format!("artifact {i}: missing `{k}`")))
            };
            let get_num = |k: &str| {
                a.get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| UdtError::runtime(format!("artifact {i}: missing `{k}`")))
            };
            artifacts.push(ArtifactSpec {
                name: get_str("name")?.to_string(),
                path: PathBuf::from(get_str("path")?),
                m: get_num("m")?,
                b: get_num("b")?,
                c: get_num("c")?,
            });
        }
        Ok(Manifest { dir, artifacts })
    }

    /// Absolute path of an artifact's HLO file.
    pub fn hlo_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.path)
    }

    /// Smallest variant whose padded `m` fits `n` rows (and matches `c`).
    pub fn variant_for(&self, n: usize, n_classes: usize) -> Option<&ArtifactSpec> {
        self.artifacts
            .iter()
            .filter(|a| a.m >= n && a.c >= n_classes)
            .min_by_key(|a| a.m)
    }

    /// The default artifacts directory (env `UDT_ARTIFACTS` or
    /// `artifacts/` relative to the workspace).
    pub fn default_dir() -> PathBuf {
        std::env::var_os("UDT_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "version": 1,
        "artifacts": [
            {"name": "split_select_m4096", "path": "split_select_m4096.hlo.txt",
             "m": 4096, "b": 256, "c": 32},
            {"name": "split_select_m32768", "path": "split_select_m32768.hlo.txt",
             "m": 32768, "b": 256, "c": 32}
        ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        assert_eq!(m.artifacts[0].b, 256);
        assert_eq!(
            m.hlo_path(&m.artifacts[0]),
            PathBuf::from("/tmp/a/split_select_m4096.hlo.txt")
        );
    }

    #[test]
    fn variant_selection_picks_smallest_fit() {
        let m = Manifest::parse(SAMPLE, PathBuf::from(".")).unwrap();
        assert_eq!(m.variant_for(100, 2).unwrap().m, 4096);
        assert_eq!(m.variant_for(4096, 2).unwrap().m, 4096);
        assert_eq!(m.variant_for(4097, 2).unwrap().m, 32768);
        assert!(m.variant_for(1_000_000, 2).is_none());
        assert!(m.variant_for(10, 64).is_none()); // too many classes
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{}", PathBuf::from(".")).is_err());
        assert!(Manifest::parse("[1,2]", PathBuf::from(".")).is_err());
        assert!(
            Manifest::parse(r#"{"artifacts":[{"name":"x"}]}"#, PathBuf::from(".")).is_err()
        );
    }
}
