//! Process-wide runtime services.
//!
//! Two halves live here:
//!
//! - [`pool`]: the persistent worker pool behind every `parallel_map*`
//!   call (see [`crate::coordinator::parallel`]), plus the memoized
//!   [`cores`] count and the uniform [`threads`] resolver (`0` = all
//!   cores) used by every `n_threads` knob in the crate.
//! - The PJRT runtime: load AOT-compiled HLO artifacts and execute them
//!   from the Rust request path (Python is build-time only). The PJRT
//!   execution engine needs the external `xla` crate, which the offline
//!   build image does not carry — it compiles only under the `xla`
//!   cargo feature. Without it, [`xla_split::XlaSelection`] is a stub
//!   whose loader reports "no artifacts" and whose selection falls back
//!   to the exact native engine, so every caller keeps working.

pub mod binning;
#[cfg(feature = "xla")]
pub mod engine;
pub mod manifest;
pub mod pool;
pub mod xla_split;

pub use pool::{cores, stats as pool_stats, threads, PoolStats};
