//! PJRT runtime: load AOT-compiled HLO artifacts and execute them from the
//! Rust request path (Python is build-time only).
//!
//! The PJRT execution engine needs the external `xla` crate, which the
//! offline build image does not carry — it compiles only under the `xla`
//! cargo feature. Without it, [`xla_split::XlaSelection`] is a stub whose
//! loader reports "no artifacts" and whose selection falls back to the
//! exact native engine, so every caller keeps working.

pub mod binning;
#[cfg(feature = "xla")]
pub mod engine;
pub mod manifest;
pub mod xla_split;
