//! PJRT runtime: load AOT-compiled HLO artifacts and execute them from the
//! Rust request path (Python is build-time only).

pub mod binning;
pub mod engine;
pub mod manifest;
pub mod xla_split;
