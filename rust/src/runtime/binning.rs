//! Equal-frequency (quantile) binning of a sorted numeric lane.
//!
//! Two consumers share this helper: the accelerator path maps a
//! per-unique-value scan onto fixed VMEM tiles (DESIGN.md §2
//! Hardware-Adaptation), and the binned training backend
//! (`selection/binned.rs`) quantizes whole dataset columns once into
//! `u8`/`u16` bin-id lanes. Bin edges are actual data values, so a
//! bin-boundary split is a valid `≤ edge` predicate; when the lane has
//! ≤ B distinct values the binning is exact and a binned scan scores
//! exactly the candidates the native path does.

/// Binning of one ascending value lane.
#[derive(Debug, Clone)]
pub struct Binning {
    /// Upper edge value of each used bin (ascending). `edges.len() ≤ B`.
    pub edges: Vec<f64>,
    /// Bin id of every input row, aligned with the sorted input.
    pub bin_of_sorted: Vec<u32>,
    /// True when every distinct-value run got its own bin (distinct
    /// values ≤ `max_bins`), so a binned scan is lossless: each bin is
    /// one distinct value and its edge *is* that value.
    pub is_exact: bool,
}

impl Binning {
    pub fn n_bins(&self) -> usize {
        self.edges.len()
    }
}

/// Bin `values` (ascending) into at most `max_bins` equal-frequency bins
/// whose boundaries never split a run of equal values. Returns `None`
/// when `values` is empty.
pub fn quantile_bins(values: &[f64], max_bins: usize) -> Option<Binning> {
    let n = values.len();
    if n == 0 || max_bins == 0 {
        return None;
    }
    // Pre-sized: at most min(max_bins, n) edges, exactly n bin ids. The
    // id lane is bulk-filled one equal-value run at a time instead of
    // pushed per row.
    let mut edges: Vec<f64> = Vec::with_capacity(max_bins.min(n));
    let mut bin_of_sorted: Vec<u32> = vec![0; n];

    // Distinct-value runs, assigned to bins by a target per-bin count.
    let target = (n as f64 / max_bins as f64).max(1.0);
    let mut current_bin = 0u32;
    let mut in_bin = 0usize; // rows already placed in current bin
    let mut n_runs = 0usize; // distinct-value runs seen
    let mut i = 0usize;
    while i < n {
        // Find the run of equal values.
        let v = values[i];
        let mut j = i;
        while j < n && values[j] == v {
            j += 1;
        }
        let run = j - i;
        n_runs += 1;
        // Close the current bin if adding this run overshoots the target
        // (and the bin is non-empty, and more bins are available).
        if in_bin > 0
            && (in_bin + run) as f64 > target
            && (current_bin as usize) < max_bins - 1
        {
            current_bin += 1;
            in_bin = 0;
        }
        if in_bin == 0 {
            edges.push(v);
        } else {
            *edges.last_mut().unwrap() = v;
        }
        bin_of_sorted[i..j].fill(current_bin);
        in_bin += run;
        i = j;
    }
    let is_exact = edges.len() == n_runs;
    Some(Binning {
        edges,
        bin_of_sorted,
        is_exact,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bin_values(vals: &[f64], max_bins: usize) -> Binning {
        // vals must already be ascending for this helper.
        quantile_bins(vals, max_bins).unwrap()
    }

    #[test]
    fn distinct_values_under_bins_is_exact() {
        let vals = [1.0, 2.0, 3.0, 4.0];
        let b = bin_values(&vals, 8);
        assert_eq!(b.edges, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(b.bin_of_sorted, vec![0, 1, 2, 3]);
        assert!(b.is_exact);
    }

    #[test]
    fn equal_runs_never_split() {
        let vals = [1.0, 1.0, 1.0, 1.0, 2.0, 2.0, 2.0, 2.0];
        let b = bin_values(&vals, 2);
        assert_eq!(b.edges, vec![1.0, 2.0]);
        assert_eq!(&b.bin_of_sorted[..4], &[0, 0, 0, 0]);
        assert_eq!(&b.bin_of_sorted[4..], &[1, 1, 1, 1]);
        assert!(b.is_exact);
    }

    #[test]
    fn respects_max_bins() {
        let vals: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let b = bin_values(&vals, 16);
        assert!(b.n_bins() <= 16);
        assert!(!b.is_exact);
        // Equal-frequency: bins are balanced within a factor of ~2.
        let mut counts = vec![0usize; b.n_bins()];
        for &bin in &b.bin_of_sorted {
            counts[bin as usize] += 1;
        }
        let (min, max) = (
            counts.iter().min().unwrap(),
            counts.iter().max().unwrap(),
        );
        assert!(max / min.max(&1) <= 2, "{counts:?}");
    }

    #[test]
    fn edges_are_bin_maxima_and_monotonic() {
        let vals = [0.5, 0.5, 1.5, 2.0, 2.0, 2.0, 9.0];
        let b = bin_values(&vals, 3);
        for w in b.edges.windows(2) {
            assert!(w[0] < w[1]);
        }
        // Every row's value ≤ its bin's edge, and > previous bin's edge.
        for (i, &bin) in b.bin_of_sorted.iter().enumerate() {
            let v = vals[i];
            assert!(v <= b.edges[bin as usize]);
            if bin > 0 {
                assert!(v > b.edges[bin as usize - 1]);
            }
        }
    }

    #[test]
    fn empty_input_is_none() {
        assert!(quantile_bins(&[], 4).is_none());
    }

    #[test]
    fn single_value_single_bin() {
        let b = bin_values(&[7.0, 7.0, 7.0], 4);
        assert_eq!(b.edges, vec![7.0]);
        assert_eq!(b.bin_of_sorted, vec![0, 0, 0]);
        assert!(b.is_exact);
    }

    #[test]
    fn exact_flag_tracks_distinct_run_count() {
        // 4 distinct runs, 4 bins available → exact.
        let vals = [1.0, 1.0, 2.0, 3.0, 3.0, 4.0];
        assert!(bin_values(&vals, 4).is_exact);
        // Same data, 3 bins → at least one bin merges runs → lossy.
        let b = bin_values(&vals, 3);
        assert!(!b.is_exact);
        assert!(b.n_bins() <= 3);
    }
}
