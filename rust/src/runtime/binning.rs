//! Equal-frequency (quantile) binning of a sorted numeric lane.
//!
//! Two consumers share this helper: the accelerator path maps a
//! per-unique-value scan onto fixed VMEM tiles (DESIGN.md §2
//! Hardware-Adaptation), and the binned training backend
//! (`selection/binned.rs`) quantizes whole dataset columns once into
//! `u8`/`u16` bin-id lanes. Bin edges are actual data values, so a
//! bin-boundary split is a valid `≤ edge` predicate; when the lane has
//! ≤ B distinct values the binning is exact and a binned scan scores
//! exactly the candidates the native path does.

/// Binning of one ascending value lane.
#[derive(Debug, Clone)]
pub struct Binning {
    /// Upper edge value of each used bin (ascending). `edges.len() ≤ B`.
    pub edges: Vec<f64>,
    /// Bin id of every input row, aligned with the sorted input.
    pub bin_of_sorted: Vec<u32>,
    /// True when every distinct-value run got its own bin (distinct
    /// values ≤ `max_bins`), so a binned scan is lossless: each bin is
    /// one distinct value and its edge *is* that value.
    pub is_exact: bool,
}

impl Binning {
    pub fn n_bins(&self) -> usize {
        self.edges.len()
    }
}

/// Binning computed from distinct-value runs alone (no per-row lane):
/// the edge table plus, per run, the bin it landed in. This is the
/// shard-training entry point — out-of-core edge building merges
/// per-shard `(value, count)` run lists and never materializes a sorted
/// row lane.
#[derive(Debug, Clone)]
pub struct RunBinning {
    /// Upper edge value of each used bin (ascending). `edges.len() ≤ B`.
    pub edges: Vec<f64>,
    /// Bin id of each input run, aligned with the run list.
    pub bin_of_run: Vec<u32>,
    /// True when every run got its own bin (see [`Binning::is_exact`]).
    pub is_exact: bool,
}

/// Bin a list of distinct-value `(value, count)` runs (values strictly
/// ascending) into at most `max_bins` equal-frequency bins. This is the
/// one bin-assignment loop: [`quantile_bins`] delegates here after
/// collapsing its sorted lane into runs, so in-memory and sharded edge
/// building are bit-identical by construction. Returns `None` when the
/// run list is empty.
pub fn quantile_bins_from_runs(runs: &[(f64, usize)], max_bins: usize) -> Option<RunBinning> {
    if runs.is_empty() || max_bins == 0 {
        return None;
    }
    let n: usize = runs.iter().map(|&(_, c)| c).sum();
    let mut edges: Vec<f64> = Vec::with_capacity(max_bins.min(runs.len()));
    let mut bin_of_run: Vec<u32> = Vec::with_capacity(runs.len());

    // Distinct-value runs, assigned to bins by a target per-bin count.
    let target = (n as f64 / max_bins as f64).max(1.0);
    let mut current_bin = 0u32;
    let mut in_bin = 0usize; // rows already placed in current bin
    for &(v, run) in runs {
        // Close the current bin if adding this run overshoots the target
        // (and the bin is non-empty, and more bins are available).
        if in_bin > 0
            && (in_bin + run) as f64 > target
            && (current_bin as usize) < max_bins - 1
        {
            current_bin += 1;
            in_bin = 0;
        }
        match edges.last_mut() {
            Some(last) if in_bin > 0 => *last = v,
            _ => edges.push(v),
        }
        bin_of_run.push(current_bin);
        in_bin += run;
    }
    let is_exact = edges.len() == runs.len();
    Some(RunBinning {
        edges,
        bin_of_run,
        is_exact,
    })
}

/// Bin `values` (ascending) into at most `max_bins` equal-frequency bins
/// whose boundaries never split a run of equal values. Returns `None`
/// when `values` is empty.
pub fn quantile_bins(values: &[f64], max_bins: usize) -> Option<Binning> {
    let n = values.len();
    if n == 0 || max_bins == 0 {
        return None;
    }
    // Collapse the sorted lane into distinct-value runs, delegate the
    // bin assignment, then expand run bins back over the row lane.
    let mut runs: Vec<(f64, usize)> = Vec::new();
    let mut i = 0usize;
    while i < n {
        let v = values[i];
        let mut j = i;
        while j < n && values[j] == v {
            j += 1;
        }
        runs.push((v, j - i));
        i = j;
    }
    let rb = quantile_bins_from_runs(&runs, max_bins)?;
    let mut bin_of_sorted: Vec<u32> = vec![0; n];
    let mut at = 0usize;
    for (&(_, run), &bin) in runs.iter().zip(&rb.bin_of_run) {
        bin_of_sorted[at..at + run].fill(bin);
        at += run;
    }
    Some(Binning {
        edges: rb.edges,
        bin_of_sorted,
        is_exact: rb.is_exact,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bin_values(vals: &[f64], max_bins: usize) -> Binning {
        // vals must already be ascending for this helper.
        quantile_bins(vals, max_bins).unwrap()
    }

    #[test]
    fn distinct_values_under_bins_is_exact() {
        let vals = [1.0, 2.0, 3.0, 4.0];
        let b = bin_values(&vals, 8);
        assert_eq!(b.edges, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(b.bin_of_sorted, vec![0, 1, 2, 3]);
        assert!(b.is_exact);
    }

    #[test]
    fn equal_runs_never_split() {
        let vals = [1.0, 1.0, 1.0, 1.0, 2.0, 2.0, 2.0, 2.0];
        let b = bin_values(&vals, 2);
        assert_eq!(b.edges, vec![1.0, 2.0]);
        assert_eq!(&b.bin_of_sorted[..4], &[0, 0, 0, 0]);
        assert_eq!(&b.bin_of_sorted[4..], &[1, 1, 1, 1]);
        assert!(b.is_exact);
    }

    #[test]
    fn respects_max_bins() {
        let vals: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let b = bin_values(&vals, 16);
        assert!(b.n_bins() <= 16);
        assert!(!b.is_exact);
        // Equal-frequency: bins are balanced within a factor of ~2.
        let mut counts = vec![0usize; b.n_bins()];
        for &bin in &b.bin_of_sorted {
            counts[bin as usize] += 1;
        }
        let (min, max) = (
            counts.iter().min().unwrap(),
            counts.iter().max().unwrap(),
        );
        assert!(max / min.max(&1) <= 2, "{counts:?}");
    }

    #[test]
    fn edges_are_bin_maxima_and_monotonic() {
        let vals = [0.5, 0.5, 1.5, 2.0, 2.0, 2.0, 9.0];
        let b = bin_values(&vals, 3);
        for w in b.edges.windows(2) {
            assert!(w[0] < w[1]);
        }
        // Every row's value ≤ its bin's edge, and > previous bin's edge.
        for (i, &bin) in b.bin_of_sorted.iter().enumerate() {
            let v = vals[i];
            assert!(v <= b.edges[bin as usize]);
            if bin > 0 {
                assert!(v > b.edges[bin as usize - 1]);
            }
        }
    }

    #[test]
    fn empty_input_is_none() {
        assert!(quantile_bins(&[], 4).is_none());
    }

    #[test]
    fn single_value_single_bin() {
        let b = bin_values(&[7.0, 7.0, 7.0], 4);
        assert_eq!(b.edges, vec![7.0]);
        assert_eq!(b.bin_of_sorted, vec![0, 0, 0]);
        assert!(b.is_exact);
    }

    #[test]
    fn runs_entry_point_matches_lane_entry_point() {
        // The same data presented as a sorted lane and as (value, count)
        // runs must produce identical edges and bin assignments — the
        // sharded edge pass relies on this.
        let vals = [0.5, 0.5, 1.5, 2.0, 2.0, 2.0, 3.0, 9.0, 9.0];
        let runs = [(0.5, 2), (1.5, 1), (2.0, 3), (3.0, 1), (9.0, 2)];
        for max_bins in [1, 2, 3, 4, 8] {
            let a = quantile_bins(&vals, max_bins).unwrap();
            let b = quantile_bins_from_runs(&runs, max_bins).unwrap();
            assert_eq!(a.edges, b.edges, "B={max_bins}");
            assert_eq!(a.is_exact, b.is_exact, "B={max_bins}");
            let mut expanded = Vec::new();
            for (&(_, c), &bin) in runs.iter().zip(&b.bin_of_run) {
                expanded.extend(std::iter::repeat(bin).take(c));
            }
            assert_eq!(a.bin_of_sorted, expanded, "B={max_bins}");
        }
        assert!(quantile_bins_from_runs(&[], 4).is_none());
    }

    #[test]
    fn exact_flag_tracks_distinct_run_count() {
        // 4 distinct runs, 4 bins available → exact.
        let vals = [1.0, 1.0, 2.0, 3.0, 3.0, 4.0];
        assert!(bin_values(&vals, 4).is_exact);
        // Same data, 3 bins → at least one bin merges runs → lossy.
        let b = bin_values(&vals, 3);
        assert!(!b.is_exact);
        assert!(b.n_bins() <= 3);
    }
}
