//! Equal-frequency (quantile) binning of a node's numeric rows.
//!
//! The accelerator path works on fixed-width histograms (B bins), the
//! standard way to map a per-unique-value scan onto fixed VMEM tiles
//! (DESIGN.md §2 Hardware-Adaptation). Bin edges are actual data values,
//! so a bin-boundary split is a valid `≤ edge` predicate; when the node
//! has ≤ B distinct values the binning is exact and the XLA path scores
//! exactly the candidates the native path does.

/// Binning of one feature at one node.
#[derive(Debug, Clone)]
pub struct Binning {
    /// Upper edge value of each used bin (ascending). `edges.len() ≤ B`.
    pub edges: Vec<f64>,
    /// Bin id of every input row, aligned with the `sorted_rows` input.
    pub bin_of_sorted: Vec<u32>,
}

impl Binning {
    pub fn n_bins(&self) -> usize {
        self.edges.len()
    }
}

/// Bin `values` (ascending) into at most `max_bins` equal-frequency bins
/// whose boundaries never split a run of equal values. Returns `None`
/// when `values` is empty.
pub fn quantile_bins(values: &[f64], max_bins: usize) -> Option<Binning> {
    let n = values.len();
    if n == 0 || max_bins == 0 {
        return None;
    }
    let mut edges: Vec<f64> = Vec::new();
    let mut bin_of_sorted: Vec<u32> = Vec::with_capacity(n);

    // Distinct-value runs, assigned to bins by a target per-bin count.
    let target = (n as f64 / max_bins as f64).max(1.0);
    let mut current_bin = 0u32;
    let mut in_bin = 0usize; // rows already placed in current bin
    let mut i = 0usize;
    while i < n {
        // Find the run of equal values.
        let v = values[i];
        let mut j = i;
        while j < n && values[j] == v {
            j += 1;
        }
        let run = j - i;
        // Close the current bin if adding this run overshoots the target
        // (and the bin is non-empty, and more bins are available).
        if in_bin > 0
            && (in_bin + run) as f64 > target
            && (current_bin as usize) < max_bins - 1
        {
            current_bin += 1;
            in_bin = 0;
        }
        if in_bin == 0 {
            edges.push(v);
        } else {
            *edges.last_mut().unwrap() = v;
        }
        for _ in 0..run {
            bin_of_sorted.push(current_bin);
        }
        in_bin += run;
        i = j;
    }
    Some(Binning {
        edges,
        bin_of_sorted,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bin_values(vals: &[f64], max_bins: usize) -> Binning {
        // vals must already be ascending for this helper.
        quantile_bins(vals, max_bins).unwrap()
    }

    #[test]
    fn distinct_values_under_bins_is_exact() {
        let vals = [1.0, 2.0, 3.0, 4.0];
        let b = bin_values(&vals, 8);
        assert_eq!(b.edges, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(b.bin_of_sorted, vec![0, 1, 2, 3]);
    }

    #[test]
    fn equal_runs_never_split() {
        let vals = [1.0, 1.0, 1.0, 1.0, 2.0, 2.0, 2.0, 2.0];
        let b = bin_values(&vals, 2);
        assert_eq!(b.edges, vec![1.0, 2.0]);
        assert_eq!(&b.bin_of_sorted[..4], &[0, 0, 0, 0]);
        assert_eq!(&b.bin_of_sorted[4..], &[1, 1, 1, 1]);
    }

    #[test]
    fn respects_max_bins() {
        let vals: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let b = bin_values(&vals, 16);
        assert!(b.n_bins() <= 16);
        // Equal-frequency: bins are balanced within a factor of ~2.
        let mut counts = vec![0usize; b.n_bins()];
        for &bin in &b.bin_of_sorted {
            counts[bin as usize] += 1;
        }
        let (min, max) = (
            counts.iter().min().unwrap(),
            counts.iter().max().unwrap(),
        );
        assert!(max / min.max(&1) <= 2, "{counts:?}");
    }

    #[test]
    fn edges_are_bin_maxima_and_monotonic() {
        let vals = [0.5, 0.5, 1.5, 2.0, 2.0, 2.0, 9.0];
        let b = bin_values(&vals, 3);
        for w in b.edges.windows(2) {
            assert!(w[0] < w[1]);
        }
        // Every row's value ≤ its bin's edge, and > previous bin's edge.
        for (i, &bin) in b.bin_of_sorted.iter().enumerate() {
            let v = vals[i];
            assert!(v <= b.edges[bin as usize]);
            if bin > 0 {
                assert!(v > b.edges[bin as usize - 1]);
            }
        }
    }

    #[test]
    fn empty_input_is_none() {
        assert!(quantile_bins(&[], 4).is_none());
    }

    #[test]
    fn single_value_single_bin() {
        let b = bin_values(&[7.0, 7.0, 7.0], 4);
        assert_eq!(b.edges, vec![7.0]);
        assert_eq!(b.bin_of_sorted, vec![0, 0, 0]);
    }
}
